//! Behavioral ↔ event-driven equivalence: the fast behavioral models used
//! by every experiment sweep are validated against the gate-level
//! discrete-event simulator on the same structures.

use tdpc::fabric::{Device, VariationModel, VariationParams, LUT_LOGIC_DELAY};
use tdpc::flow::{place_pdls, route_pdl, FlowConfig, PinAssignment};
use tdpc::pdl::{Pdl, Polarity};
use tdpc::timing::{Circuit, Simulator};
use tdpc::util::prop;
use tdpc::util::Ps;

/// Build the event-driven mux chain for a PDL and propagate a start edge.
fn event_driven_traversal(pdl: &Pdl, bits: &[bool]) -> Ps {
    let mut c = Circuit::new();
    let start = c.net();
    let mut prev = start;
    let mut sels = Vec::new();
    for (i, e) in pdl.elements.iter().enumerate() {
        // Polarity is net swapping in hardware; precompute the effective
        // select so the circuit itself stays positive-polarity.
        let effective = match e.polarity {
            Polarity::Positive => bits[i],
            Polarity::Negative => !bits[i],
        };
        let sel = c.net_init(effective);
        sels.push(sel);
        prev = c.pdl_element(prev, sel, e.lo, e.hi, LUT_LOGIC_DELAY);
    }
    let mut sim = Simulator::new(&c);
    sim.watch(prev);
    // The start-sync FF launches the rising edge at clk-to-Q.
    sim.schedule(start, true, pdl.start_sync);
    sim.run_until(Ps(u64::MAX / 2));
    sim.first_edge(prev, true).expect("transition must reach the chain end")
}

fn build_pdl(n: usize, die: u64, polarities: Vec<Polarity>) -> Pdl {
    let d = Device::xc7z020();
    let p = place_pdls(&d, 1, n).unwrap().remove(0);
    let var = VariationModel::new(die, VariationParams::default());
    let cfg = FlowConfig::table1_default();
    let routed = route_pdl(&d, &p, &PinAssignment::fastest_pair(), &cfg, &var).unwrap();
    Pdl::from_routed(&routed, &polarities)
}

#[test]
fn pdl_behavioral_equals_event_driven() {
    let pdl = build_pdl(40, 5, Pdl::tm_polarities(40));
    for pattern in [
        vec![true; 40],
        vec![false; 40],
        (0..40).map(|i| i % 3 == 0).collect::<Vec<_>>(),
    ] {
        let behavioral = pdl.propagate(&pattern);
        let event = event_driven_traversal(&pdl, &pattern);
        assert_eq!(behavioral, event, "pattern {pattern:?}");
    }
}

#[test]
fn prop_pdl_equivalence_random() {
    prop::check("behavioral == event-driven PDL", 25, |g| {
        let n = g.int(1, 60) as usize;
        let die = g.int(0, 10_000) as u64;
        let pols: Vec<Polarity> = (0..n)
            .map(|_| if g.boolean(0.5) { Polarity::Positive } else { Polarity::Negative })
            .collect();
        let pdl = build_pdl(n, die, pols);
        let bits = g.bits(n, 0.5);
        assert_eq!(pdl.propagate(&bits), event_driven_traversal(&pdl, &bits));
    });
}

#[test]
fn race_order_preserved_in_event_sim() {
    // Two PDLs raced through the event simulator order exactly as the
    // behavioral arbiter model expects: higher effective weight → earlier.
    let pdl = build_pdl(30, 9, vec![Polarity::Positive; 30]);
    let mut heavy = vec![false; 30];
    heavy[..20].fill(true);
    let mut light = vec![false; 30];
    light[..10].fill(true);
    let t_heavy = event_driven_traversal(&pdl, &heavy);
    let t_light = event_driven_traversal(&pdl, &light);
    assert!(t_heavy < t_light, "{t_heavy} !< {t_light}");
    // And the gap is ~10 stage deltas.
    let delta = pdl.mean_delta();
    let gap = t_light - t_heavy;
    let expect = Ps(delta.0 * 10);
    assert!(gap.abs_diff(expect) < Ps(expect.0 / 5), "gap {gap} vs expected {expect}");
}

#[test]
fn mousetrap_event_cycle_matches_behavioral_model() {
    use tdpc::asynctm::{mousetrap, MousetrapStage};
    let stage = MousetrapStage::default();
    let mut c = Circuit::new();
    let nets = mousetrap::build_event_circuit(&mut c, &stage);
    let mut sim = Simulator::new(&c);
    sim.watch(nets.req_out);
    sim.watch(nets.enable);
    sim.schedule(nets.req_in, true, Ps(0));
    sim.run_until(Ps(1_000_000));
    // Forward latency: one latch delay.
    assert_eq!(
        sim.first_edge(nets.req_out, true),
        Some(stage.forward_latency())
    );
    // Enable closes one XNOR delay after req_out toggles.
    assert_eq!(
        sim.first_edge(nets.enable, false),
        Some(stage.forward_latency() + stage.xnor_delay)
    );
}
