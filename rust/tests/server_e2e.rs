//! Network serving e2e: the TCP front end against a live coordinator.
//!
//! The acceptance path for the serving layer:
//! * two tenant models (widths straddling the u64 word boundary) served
//!   over real TCP produce **bit-identical** predictions to direct
//!   `Coordinator` calls on the same pool;
//! * typed `InferError`s surface as protocol error codes on the wire
//!   (unknown model → 1, width mismatch → 2);
//! * framing abuse — garbage magic, a foreign version, an oversized
//!   declared length, a mid-frame disconnect — is refused per-connection
//!   and never harms the next client;
//! * accept-time admission refuses connections past `max_conns` with an
//!   `OVERLOADED` frame;
//! * the in-process load generator drives the whole path and writes a
//!   parseable `BENCH_serving.json`.

use std::io::Write;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, ReplayPolicy, ShedPolicy,
};
use tdpc::runtime::BackendSpec;
use tdpc::server::{
    code, loadgen, read_frame, Client, ClientError, Kind, Server, ServerConfig, WireError,
    HEADER_LEN, MAGIC, MAX_PAYLOAD, VERSION,
};
use tdpc::tm::TmModel;
use tdpc::util::SplitMix64;

fn model_a() -> Arc<TmModel> {
    Arc::new(TmModel::synthetic("tenant_a", 3, 11, 63, 0.2, 101))
}

fn model_b() -> Arc<TmModel> {
    Arc::new(TmModel::synthetic("tenant_b", 2, 9, 65, 0.25, 202))
}

fn inputs_for(model: &TmModel, n: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..model.n_features).map(|_| rng.next_bool(0.5)).collect()).collect()
}

fn unused_root() -> PathBuf {
    PathBuf::from("/nonexistent-artifacts-root")
}

fn pool_config(n_workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(300) },
        n_workers,
        dispatch: DispatchPolicy::RoundRobin,
        backend: BackendSpec::InMemorySet(Arc::new(vec![model_a(), model_b()])),
        replay: ReplayPolicy::Off,
        queue_limit: None,
        shed: ShedPolicy::RejectNew,
        ..CoordinatorConfig::default()
    }
}

/// Start a two-tenant pool and a TCP front end on an OS-assigned port.
fn start_server(n_workers: usize, cfg: ServerConfig) -> (Arc<Coordinator>, Server) {
    let coord = Arc::new(
        Coordinator::start_multi(unused_root(), &["tenant_a", "tenant_b"], pool_config(n_workers))
            .unwrap(),
    );
    let server = Server::start(coord.clone(), "127.0.0.1:0", cfg).unwrap();
    (coord, server)
}

/// The ISSUE's loopback acceptance criterion: two tenant models over
/// real TCP, bit-identical to direct coordinator submission on the very
/// same pool (same backends, same generations).
#[test]
fn loopback_two_tenants_bit_identical_to_direct_calls() {
    let (a, b) = (model_a(), model_b());
    let n_each = 20;
    let xa = inputs_for(&a, n_each, 11);
    let xb = inputs_for(&b, n_each, 12);
    let (coord, server) = start_server(2, ServerConfig::default());
    let addr = server.local_addr();

    let mut client = Client::connect(addr).unwrap();
    // Shape discovery over the wire matches the pool's own tables.
    let info_a = client.model_info("tenant_a").unwrap();
    assert_eq!((info_a.n_features, info_a.n_classes, info_a.generation), (63, 3, 0));
    let info_b = client.model_info("tenant_b").unwrap();
    assert_eq!((info_b.n_features, info_b.n_classes, info_b.generation), (65, 2, 0));

    for (name, inputs) in [("tenant_a", &xa), ("tenant_b", &xb)] {
        let mid = coord.model_id(name).unwrap();
        for x in inputs {
            let direct = coord.infer_blocking(mid, x).unwrap();
            let wire = client.infer(name, x).unwrap();
            assert_eq!(wire.pred as usize, direct.pred, "{name}: pred must be bit-identical");
            assert_eq!(wire.sums, direct.sums, "{name}: sums must be bit-identical");
            assert_eq!(wire.generation, direct.generation);
        }
    }
    server.shutdown();
}

/// Pipelining: many requests written before any reply is read come back
/// complete and in submission order (correlation ids echo verbatim).
#[test]
fn pipelined_requests_answered_in_submission_order() {
    use tdpc::server::{write_frame, InferRequestMsg, InferResponseMsg};
    use tdpc::tm::BitVec64;

    let a = model_a();
    let xs = inputs_for(&a, 16, 21);
    let (_coord, server) = start_server(2, ServerConfig::default());
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();

    for (i, x) in xs.iter().enumerate() {
        let packed = BitVec64::from_bools(x);
        let req = InferRequestMsg {
            corr: 1000 + i as u64,
            model: "tenant_a".to_string(),
            n_features: packed.len() as u32,
            words: packed.into_words(),
        };
        write_frame(&mut stream, Kind::InferRequest.as_u8(), &req.encode()).unwrap();
    }
    for i in 0..xs.len() {
        let (kind, payload) = read_frame(&mut stream).unwrap().unwrap();
        assert_eq!(kind, Kind::InferResponse.as_u8());
        let resp = InferResponseMsg::decode(&payload).unwrap();
        assert_eq!(resp.corr, 1000 + i as u64, "replies must arrive in submission order");
        assert_eq!(resp.sums.len(), 3);
    }
    server.shutdown();
}

/// Typed coordinator errors surface as protocol error codes, and the
/// connection survives them (they are request-scoped, not
/// connection-fatal).
#[test]
fn typed_errors_surface_as_wire_codes() {
    let a = model_a();
    let x = &inputs_for(&a, 1, 31)[0];
    let (_coord, server) = start_server(1, ServerConfig::default());
    let mut client = Client::connect(server.local_addr()).unwrap();

    match client.infer("ghost_model", x) {
        Err(ClientError::Server { code: c, message }) => {
            assert_eq!(c, code::UNKNOWN_MODEL);
            assert!(message.contains("ghost_model"), "{message}");
        }
        other => panic!("expected UnknownModel error frame, got {other:?}"),
    }
    match client.model_info("ghost_model") {
        Err(ClientError::Server { code: c, .. }) => assert_eq!(c, code::UNKNOWN_MODEL),
        other => panic!("expected UnknownModel for the query, got {other:?}"),
    }
    // Wrong width for a served model: 10 bits against tenant_a's 63.
    match client.infer_packed("tenant_a", 10, vec![0x2AA]) {
        Err(ClientError::Server { code: c, message }) => {
            assert_eq!(c, code::WIDTH_MISMATCH);
            assert!(message.contains("63"), "{message}");
        }
        other => panic!("expected WidthMismatch error frame, got {other:?}"),
    }
    // The same connection still serves healthy requests afterwards.
    let ok = client.infer("tenant_a", x).unwrap();
    assert_eq!(ok.sums.len(), 3);
    server.shutdown();
}

/// Build a raw frame header (valid unless corrupted by the caller).
fn raw_header(kind: u8, payload_len: u32) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..4].copy_from_slice(&MAGIC);
    h[4] = VERSION;
    h[5] = kind;
    h[8..12].copy_from_slice(&payload_len.to_le_bytes());
    h
}

/// Read the server's reaction to an abusive frame: expect a BAD_FRAME
/// error frame, then connection close.
fn expect_bad_frame_then_close(stream: &mut TcpStream) {
    use tdpc::server::ErrorMsg;
    let (kind, payload) = read_frame(stream).unwrap().expect("an error frame before close");
    assert_eq!(kind, Kind::Error.as_u8());
    let err = ErrorMsg::decode(&payload).unwrap();
    assert_eq!(err.code, code::BAD_FRAME);
    assert_eq!(err.corr, 0, "framing errors are connection-scoped");
    // After the error frame the server hangs up.
    match read_frame(stream) {
        Ok(None) => {}
        Err(WireError::Io(_)) => {} // RST instead of FIN is also a close
        other => panic!("expected the connection to close, got {other:?}"),
    }
}

/// Framing abuse is refused per-connection — and the listener keeps
/// serving fresh connections afterwards.
#[test]
fn framing_abuse_is_refused_and_server_stays_healthy() {
    let a = model_a();
    let x = &inputs_for(&a, 1, 41)[0];
    let (_coord, server) = start_server(1, ServerConfig::default());
    let addr = server.local_addr();

    // Garbage magic.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        expect_bad_frame_then_close(&mut s);
    }
    // Version from the future.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut h = raw_header(Kind::InferRequest.as_u8(), 0);
        h[4] = VERSION + 9;
        s.write_all(&h).unwrap();
        expect_bad_frame_then_close(&mut s);
    }
    // Declared length over the cap: refused before any payload allocation.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let h = raw_header(Kind::InferRequest.as_u8(), MAX_PAYLOAD + 1);
        s.write_all(&h).unwrap();
        expect_bad_frame_then_close(&mut s);
    }
    // Undecodable payload under a valid header.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let h = raw_header(Kind::InferRequest.as_u8(), 3);
        s.write_all(&h).unwrap();
        s.write_all(&[1, 2, 3]).unwrap();
        expect_bad_frame_then_close(&mut s);
    }
    // A fresh connection still serves.
    let mut client = Client::connect(addr).unwrap();
    assert_eq!(client.infer("tenant_a", x).unwrap().sums.len(), 3);
    server.shutdown();
}

/// A client that dies mid-frame (header promised more than it sent)
/// leaves the server fully healthy.
#[test]
fn mid_frame_disconnect_leaves_server_healthy() {
    let a = model_a();
    let x = &inputs_for(&a, 1, 51)[0];
    let (_coord, server) = start_server(1, ServerConfig::default());
    let addr = server.local_addr();
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let h = raw_header(Kind::InferRequest.as_u8(), 64);
        s.write_all(&h).unwrap();
        s.write_all(&[0u8; 10]).unwrap(); // 10 of the promised 64 bytes
    } // dropped here: mid-frame disconnect
    let mut client = Client::connect(addr).unwrap();
    assert_eq!(client.infer("tenant_a", x).unwrap().sums.len(), 3);
    server.shutdown();
}

/// Past `max_conns`, the listener refuses at accept with one OVERLOADED
/// error frame — overload sheds at the socket.
#[test]
fn connection_limit_refuses_with_overloaded() {
    let a = model_a();
    let x = &inputs_for(&a, 1, 61)[0];
    let (_coord, server) = start_server(1, ServerConfig { max_conns: 1 });
    let addr = server.local_addr();

    // Connection 1 occupies the only slot (and proves it works).
    let mut first = Client::connect(addr).unwrap();
    assert_eq!(first.infer("tenant_a", x).unwrap().sums.len(), 3);
    // Connection 2 must be refused. Read the refusal without writing
    // anything: the accept loop registered connection 1 before accepting
    // this one, so the limit check is deterministic, and a pure read
    // cannot race the close into an RST that discards the frame.
    {
        use tdpc::server::ErrorMsg;
        let mut second = TcpStream::connect(addr).unwrap();
        let (kind, payload) = read_frame(&mut second).unwrap().expect("a refusal frame");
        assert_eq!(kind, Kind::Error.as_u8());
        let err = ErrorMsg::decode(&payload).unwrap();
        assert_eq!(err.code, code::OVERLOADED);
        assert_eq!(err.corr, 0, "accept-time refusals are connection-scoped");
        assert!(err.message.contains("retry"), "{}", err.message);
    }
    // The first connection is unaffected.
    assert_eq!(first.infer("tenant_a", x).unwrap().sums.len(), 3);
    server.shutdown();
}

/// The in-process load generator end-to-end: drives both tenants over
/// TCP in closed-loop mode, observes zero protocol errors, and writes a
/// parseable BENCH_serving.json.
#[test]
fn loadgen_smoke_writes_parseable_bench_json() {
    let (_coord, server) = start_server(2, ServerConfig::default());
    let cfg = loadgen::LoadgenConfig {
        addr: server.local_addr().to_string(),
        mode: loadgen::Mode::Closed { conns: 4 },
        duration: Duration::from_millis(500),
        max_requests: Some(400),
        models: vec![("tenant_a".to_string(), 3), ("tenant_b".to_string(), 1)],
        burst: loadgen::BurstShape::Steady,
        seed: 7,
    };
    let report = loadgen::run(&cfg).unwrap();
    assert!(report.ok > 0, "closed-loop smoke must answer requests: {report:?}");
    assert_eq!(report.protocol_errors, 0, "the wire must stay clean: {report:?}");
    assert_eq!(report.sent, report.ok + report.shed + report.errors);
    assert!(report.goodput_rps > 0.0);
    assert!(report.lat_p50_us > 0.0 && report.lat_p99_us >= report.lat_p50_us);

    let path = std::env::temp_dir()
        .join(format!("tdpc-bench-serving-{}.json", std::process::id()));
    loadgen::write_report(&report, &path).unwrap();
    let parsed = tdpc::util::json::parse_file(&path).unwrap();
    assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), "tdpc-bench-serving/v1");
    assert_eq!(
        parsed.get("ok").unwrap().as_usize().unwrap() as u64,
        report.ok,
        "the JSON must round-trip the counters"
    );
    let _ = std::fs::remove_file(&path);
    server.shutdown();

    // Submitting against the coordinator after the server is gone still
    // works — the front end never owned the pool.
    let (tx, rx) = mpsc::channel();
    _coord.submit_named("tenant_a", &inputs_for(&model_a(), 1, 71)[0], tx);
    assert!(rx.recv().unwrap().is_ok());
}
