//! End-to-end coordinator tests: requests through admission → dispatch →
//! per-worker batching → backend forward → policy-driven hardware
//! replay, with typed fail-soft errors, metrics aggregation and shutdown
//! behaviour.
//!
//! These run against in-memory models (`BackendSpec::InMemory` /
//! `BackendSpec::FaultInjecting` / `BackendSpec::TimeDomain { model:
//! Some(_) }`), so they need no artifacts and exercise the full pool —
//! including simulated-hardware serving and the fail-soft error path —
//! on every CI run.

use std::collections::HashMap;
use std::num::NonZeroU32;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, InferError, ReplayPolicy,
    ShedPolicy,
};
use tdpc::flow::FlowConfig;
use tdpc::hw::HwArch;
use tdpc::runtime::{BackendSpec, FaultInjectingBackend};
use tdpc::tm::TmModel;
use tdpc::util::{Ps, SplitMix64};

/// Deterministic iris-scale random model: 3 classes × 10 clauses over 16
/// Boolean features.
fn test_model(seed: u64) -> Arc<TmModel> {
    Arc::new(TmModel::synthetic("e2e_model", 3, 10, 16, 0.15, seed))
}

fn test_inputs(model: &TmModel, n: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..model.n_features).map(|_| rng.next_bool(0.5)).collect()).collect()
}

/// Artifacts root placeholder — in-memory specs never read it.
fn unused_root() -> PathBuf {
    PathBuf::from("/nonexistent-artifacts-root")
}

fn pool_config(
    n_workers: usize,
    dispatch: DispatchPolicy,
    model: Arc<TmModel>,
) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(300) },
        n_workers,
        dispatch,
        backend: BackendSpec::InMemory(model),
        replay: ReplayPolicy::Off,
        queue_limit: None,
        shed: ShedPolicy::RejectNew,
        ..CoordinatorConfig::default()
    }
}

/// An in-memory time-domain spec for `model` with the given architecture.
/// Uses the ideal (zero-variation) flow at Table-I nominal delays so the
/// async-vs-functional exactness assertions below are deterministic —
/// variation robustness is table1's delay-tuning concern, exercised by
/// the experiments suite, not by this pool-plumbing e2e.
fn hw_spec(arch: HwArch, model: Arc<TmModel>) -> BackendSpec {
    BackendSpec::TimeDomain {
        arch,
        flow: FlowConfig::ideal(Ps(380), Ps(618)),
        model: Some(model),
    }
}

#[test]
fn serves_requests_with_correct_predictions() {
    let model = test_model(1);
    let cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    for (i, x) in test_inputs(&model, 20, 2).into_iter().enumerate() {
        let resp = coord.infer_blocking(mid, &x).unwrap();
        assert_eq!(resp.pred, model.predict(&x), "request {i}");
        assert_eq!(resp.sums, model.class_sums(&x), "request {i}");
        assert!(resp.hw_decision_latency.is_none());
        assert!(resp.service_latency_us > 0.0);
        assert_eq!(resp.worker, 0);
    }
    let m = coord.metrics();
    assert_eq!(m.requests, 20);
    assert!(m.batches >= 1);
    assert_eq!((m.rejected_requests, m.shed_requests, m.failed_batches), (0, 0, 0));
    // A single-worker pool's aggregate equals its only worker's snapshot
    // (no admission-time events happened).
    assert_eq!(coord.worker_metrics()[0], m);
    coord.shutdown();
}

#[test]
fn four_worker_pool_answers_each_request_once_and_metrics_sum() {
    let model = test_model(3);
    let cfg = pool_config(4, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    assert_eq!(coord.n_workers(), 4);

    let n = 200;
    let inputs = test_inputs(&model, n, 4);
    let (tx, rx) = std::sync::mpsc::channel();
    for x in &inputs {
        coord.submit(mid, x, tx.clone());
    }
    drop(tx);
    let responses: Vec<_> =
        rx.iter().take(n).map(|r| r.expect("valid requests all serve")).collect();
    assert_eq!(responses.len(), n);

    // Every request id answered exactly once, each with the right result.
    let mut ids: Vec<u64> = responses.iter().map(|r| r.request_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n);
    for r in &responses {
        assert_eq!(r.pred, model.predict(&inputs[r.request_id as usize]));
        assert!(r.worker < 4);
    }
    // All four workers actually served traffic (round-robin → 50 each).
    for w in 0..4 {
        assert!(
            responses.iter().any(|r| r.worker == w),
            "worker {w} served nothing"
        );
    }

    let m = coord.metrics();
    let per_worker = coord.worker_metrics();
    assert_eq!(m.requests as usize, n, "aggregate request count");
    assert_eq!(
        per_worker.iter().map(|w| w.requests).sum::<u64>(),
        m.requests,
        "per-worker requests must sum to the aggregate"
    );
    assert_eq!(
        per_worker.iter().map(|w| w.batches).sum::<u64>(),
        m.batches,
        "per-worker batch counts must sum to the aggregate"
    );
    for (i, w) in per_worker.iter().enumerate() {
        assert_eq!(w.requests, 50, "round-robin shares traffic evenly (worker {i})");
        assert!(w.batches >= 1, "worker {i} executed no batches");
    }
    coord.shutdown();
}

#[test]
fn least_loaded_prefers_idle_workers() {
    let model = test_model(5);
    let cfg = pool_config(2, DispatchPolicy::LeastLoaded, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    // Sequential blocking requests: the pool is idle at each submit, so the
    // tie-break (lowest index) pins every request to worker 0.
    for x in test_inputs(&model, 10, 6) {
        let resp = coord.infer_blocking(mid, &x).unwrap();
        assert_eq!(resp.worker, 0);
    }
    // A burst deepens worker 0's queue, so worker 1 must pick up load.
    let n = 100;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 7) {
        coord.submit(mid, &x, tx.clone());
    }
    drop(tx);
    let responses: Vec<_> =
        rx.iter().take(n).map(|r| r.expect("valid requests all serve")).collect();
    assert_eq!(responses.len(), n);
    assert!(
        responses.iter().any(|r| r.worker == 1),
        "burst load never spilled to the second worker"
    );
    coord.shutdown();
}

#[test]
fn batches_form_under_burst_load() {
    let model = test_model(8);
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(50) },
        n_workers: 1,
        backend: BackendSpec::InMemory(model.clone()),
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let n = 200;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 9) {
        coord.submit(mid, &x, tx.clone());
    }
    drop(tx);
    assert_eq!(rx.iter().take(n).filter(|r| r.is_ok()).count(), n);
    let m = coord.metrics();
    assert_eq!(m.requests as usize, n);
    assert!(
        m.mean_batch_size > 2.0,
        "burst submission must produce real batches, got {}",
        m.mean_batch_size
    );
    coord.shutdown();
}

#[test]
fn big_batches_reach_the_sliced_engine_and_report_it_in_metrics() {
    // A `--max-batch`-sized cap (≥ tm::SLICED_MIN_ROWS) with a generous
    // deadline: a fast 64-request burst accumulates into one
    // size-triggered batch, which the dispatcher routes to the bit-sliced
    // engine — proven end to end by the sliced counters flowing from the
    // backend's scratch through the per-batch delta into the pool
    // metrics, while every answer stays bit-exact.
    let model = test_model(21);
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(200) },
        n_workers: 1,
        backend: BackendSpec::InMemory(model.clone()),
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let inputs = test_inputs(&model, 64, 22);
    let (tx, rx) = std::sync::mpsc::channel();
    for x in &inputs {
        coord.submit(mid, x, tx.clone());
    }
    drop(tx);
    let replies: Vec<_> = rx.iter().take(inputs.len()).collect();
    for (i, reply) in replies.iter().enumerate() {
        let resp = reply.as_ref().expect("burst requests succeed");
        assert_eq!(resp.pred, model.predict(&inputs[resp.request_id as usize]), "reply {i}");
    }
    let m = coord.metrics();
    assert_eq!(m.requests, 64);
    assert!(
        m.sliced_groups >= 1,
        "a 64-row batch must reach the sliced engine (groups={}, rows={})",
        m.sliced_groups,
        m.sliced_rows
    );
    assert_eq!(m.sliced_rows, 64, "every row of the burst ran sliced");
    assert_eq!(m.hot_rows, 64);
    coord.shutdown();
}

/// The tentpole acceptance path: a 4-worker pool served entirely through
/// `BackendSpec::TimeDomain` with full replay. Every response must carry
/// `hw_decision_latency`/`hw_winner`, and predictions must be identical
/// to the native backend (same packed forward pass); the async arbiter
/// may disagree with the functional argmax only on exact class-sum ties.
#[test]
fn four_worker_time_domain_pool_replays_every_response() {
    let model = test_model(10);
    let mut cfg = pool_config(4, DispatchPolicy::RoundRobin, model.clone());
    cfg.backend = hw_spec(HwArch::Async, model.clone());
    cfg.replay = ReplayPolicy::Full;
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();

    let n = 80;
    let inputs = test_inputs(&model, n, 11);
    let (tx, rx) = std::sync::mpsc::channel();
    for x in &inputs {
        coord.submit(mid, x, tx.clone());
    }
    drop(tx);
    let responses: Vec<_> =
        rx.iter().take(n).map(|r| r.expect("valid requests all serve")).collect();
    assert_eq!(responses.len(), n);

    let mut mismatch_without_tie = 0;
    for r in &responses {
        let x = &inputs[r.request_id as usize];
        assert_eq!(r.pred, model.predict(x), "functional path identical to native");
        let lat = r.hw_decision_latency.expect("full replay must tag every response");
        assert!(lat.as_ns() > 1.0, "plausible on-chip latency");
        let winner = r.hw_winner.expect("full replay must report the hardware argmax");
        let sums = model.class_sums(x);
        let top = *sums.iter().max().unwrap();
        let tied = sums.iter().filter(|&&s| s == top).count() > 1;
        if winner != r.pred && !tied {
            mismatch_without_tie += 1;
        }
    }
    assert_eq!(mismatch_without_tie, 0, "hw argmax must match on non-tied samples");

    let m = coord.metrics();
    assert!(m.hw_mean_ns > 0.0);
    assert!(m.hw_p50 > Ps::ZERO && m.hw_p99 >= m.hw_p50, "hw percentiles populated");
    coord.shutdown();
}

#[test]
fn sampled_replay_tags_exactly_one_in_n() {
    let model = test_model(17);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.backend = hw_spec(HwArch::Adder, model.clone());
    cfg.replay = ReplayPolicy::Sample(NonZeroU32::new(4).unwrap());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let n = 64;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 18) {
        coord.submit(mid, &x, tx.clone());
    }
    drop(tx);
    let responses: Vec<_> =
        rx.iter().take(n).map(|r| r.expect("valid requests all serve")).collect();
    // One worker serves rows 0..64 in order ⇒ exactly every 4th replayed.
    let replayed = responses.iter().filter(|r| r.hw_decision_latency.is_some()).count();
    assert_eq!(replayed, n / 4, "1-in-4 sampling on a single worker is exact");
    // The synchronous adder engine's tie-break matches the functional
    // argmax bit-exactly, ties included.
    for r in &responses {
        if let Some(w) = r.hw_winner {
            assert_eq!(w, r.pred, "sync engine argmax identical to functional");
        }
    }
    coord.shutdown();
}

#[test]
fn shutdown_drains_queued_requests() {
    let model = test_model(12);
    let cfg = pool_config(3, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let n = 120;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 13) {
        coord.submit(mid, &x, tx.clone());
    }
    drop(tx);
    // Graceful shutdown must answer everything already accepted.
    coord.shutdown();
    assert_eq!(rx.iter().filter(|r| r.is_ok()).count(), n, "shutdown dropped queued requests");
}

#[test]
fn startup_fails_cleanly_on_missing_artifacts() {
    // Native spec with no artifacts: every worker fails to open the
    // manifest, and start reports it instead of hanging.
    let cfg = CoordinatorConfig {
        n_workers: 4,
        ..CoordinatorConfig::default()
    };
    let err = Coordinator::start(unused_root(), "nonexistent_model", cfg);
    assert!(err.is_err(), "missing artifacts must fail at startup, not at first request");

    // Same guarantee for a manifest-backed time-domain spec.
    let cfg = CoordinatorConfig {
        n_workers: 2,
        backend: BackendSpec::TimeDomain {
            arch: HwArch::Async,
            flow: FlowConfig::table1_default(),
            model: None,
        },
        ..CoordinatorConfig::default()
    };
    assert!(Coordinator::start(unused_root(), "nonexistent_model", cfg).is_err());
}

#[test]
fn start_rejects_zero_workers_and_wrong_in_memory_model() {
    let model = test_model(14);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.n_workers = 0;
    assert!(Coordinator::start(unused_root(), "e2e_model", cfg).is_err());

    // A time-domain spec holding the wrong in-memory model fails at
    // startup (the "unknown model fails early" guarantee).
    let cfg = CoordinatorConfig {
        n_workers: 1,
        backend: hw_spec(HwArch::Adder, model),
        ..CoordinatorConfig::default()
    };
    assert!(Coordinator::start(unused_root(), "some_other_model", cfg).is_err());
}

#[test]
fn drop_without_shutdown_does_not_hang() {
    let model = test_model(15);
    let cfg = pool_config(2, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let _ = coord.infer_blocking(mid, &test_inputs(&model, 1, 16)[0]).unwrap();
    drop(coord); // Drop impl joins all workers — must not deadlock.
}

#[test]
fn word_boundary_models_batch_correctly_through_four_workers() {
    // The packed request path end-to-end at clause/feature counts that
    // straddle u64 word edges: pack at submit → dispatch → per-worker
    // batch assembly → packed forward → popcount sums, for 4 workers,
    // cross-checked per response against the bool-wise reference forward.
    for (k, cpc, f) in [(1usize, 63usize, 63usize), (2, 32, 64), (5, 13, 65), (1, 127, 31)] {
        let model =
            Arc::new(TmModel::synthetic("e2e_model", k, cpc, f, 0.15, (k * cpc + f) as u64));
        let cfg = pool_config(4, DispatchPolicy::RoundRobin, model.clone());
        let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
        let mid = coord.model_id("e2e_model").unwrap();
        let n = 64;
        let inputs = test_inputs(&model, n, 21);
        let (tx, rx) = std::sync::mpsc::channel();
        for x in &inputs {
            coord.submit(mid, x, tx.clone());
        }
        drop(tx);
        let responses: Vec<_> =
            rx.iter().take(n).map(|r| r.expect("valid requests all serve")).collect();
        assert_eq!(responses.len(), n, "k={k} cpc={cpc} f={f}");
        for r in &responses {
            let x = &inputs[r.request_id as usize];
            let (_, sums, pred) = model.forward_reference(x);
            assert_eq!(r.sums, sums, "k={k} cpc={cpc} f={f} request {}", r.request_id);
            assert_eq!(r.pred, pred, "k={k} cpc={cpc} f={f} request {}", r.request_id);
        }
        coord.shutdown();
    }
}

/// The fail-soft acceptance path: a width-mismatched submit in the middle
/// of a burst is rejected with a *typed* `WidthMismatch` at ingestion,
/// and every concurrent valid request on the same worker is served —
/// the bad row never reaches a batch, so it cannot poison its
/// `max_batch − 1` neighbors.
#[test]
fn width_mismatch_rejected_typed_while_neighbors_serve() {
    let model = test_model(30);
    let cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let f = model.n_features;
    assert_eq!(coord.n_features_for(mid), Some(f), "model width cached at startup");

    let inputs = test_inputs(&model, 10, 31);
    let (tx, rx) = std::sync::mpsc::channel();
    let (bad_tx, bad_rx) = std::sync::mpsc::channel();
    let mut expected: HashMap<u64, &Vec<bool>> = HashMap::new();
    for (i, x) in inputs.iter().enumerate() {
        if i == 5 {
            coord.submit(mid, &vec![true; f + 3], bad_tx.clone());
        }
        let id = coord.submit(mid, x, tx.clone());
        expected.insert(id, x);
    }
    drop(tx);
    drop(bad_tx);

    // The malformed row gets a typed rejection, not a dead channel.
    match bad_rx.recv().expect("rejected request still gets a reply") {
        Err(InferError::WidthMismatch { got, expected }) => {
            assert_eq!((got, expected), (f + 3, f));
        }
        other => panic!("expected WidthMismatch, got {other:?}"),
    }
    // Every neighbor in the same burst is served, correctly.
    let responses: Vec<_> = rx.iter().map(|r| r.expect("valid rows all serve")).collect();
    assert_eq!(responses.len(), inputs.len());
    for r in &responses {
        assert_eq!(r.pred, model.predict(expected[&r.request_id]));
    }
    let m = coord.metrics();
    assert_eq!(m.rejected_requests, 1, "the rejection is visible in metrics");
    assert_eq!(m.requests, 10);
    assert_eq!(m.failed_batches, 0, "no batch ever failed");
    // Width rejections happen at admission, before any worker is
    // involved — they appear in the aggregate, not per-worker.
    assert_eq!(coord.worker_metrics()[0].rejected_requests, 0);
    coord.shutdown();
}

/// Saturation with the default reject-new policy: a burst beyond
/// `queue_limit` sheds exactly the overflow, each shed caller gets a
/// typed `QueueFull`, and the accepted requests are all served.
#[test]
fn saturation_sheds_exactly_beyond_queue_limit() {
    let model = test_model(40);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    // A deadline the test never reaches: the worker cannot flush (and
    // free capacity) mid-burst even on a badly stalled CI machine, so
    // admission decisions are deterministic. The accepted requests are
    // served by the shutdown drain below, not the deadline.
    cfg.batcher = BatcherConfig { max_batch: 64, max_wait: Duration::from_secs(60) };
    cfg.queue_limit = Some(4);
    cfg.shed = ShedPolicy::RejectNew;
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();

    let n = 20;
    let limit = 4;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 41) {
        coord.submit(mid, &x, tx.clone());
    }
    drop(tx);

    // The n − limit rejections were delivered synchronously at submit.
    let rejects: Vec<_> = rx.iter().take(n - limit).collect();
    for r in &rejects {
        match r {
            Err(e) => assert_eq!(*e, InferError::QueueFull { depth: limit, limit }),
            Ok(resp) => panic!("nothing can be served before the drain, got {resp:?}"),
        }
    }
    let m = coord.metrics();
    assert_eq!(m.shed_requests as usize, n - limit, "sheds exactly beyond the limit");
    assert_eq!(m.requests, 0, "nothing served yet");
    // Reject-new sheds are admission-time events: aggregate-only, like
    // width rejections — not attributed to any worker.
    assert_eq!(coord.worker_metrics()[0].shed_requests, 0);

    // Graceful shutdown serves everything that was admitted.
    coord.shutdown();
    let served: Vec<_> = rx.iter().collect();
    assert_eq!(served.len(), limit, "exactly queue_limit requests were admitted");
    assert!(served.iter().all(|r| r.is_ok()));
}

/// Drop-oldest under a heavy burst. Whatever the interleaving of the
/// worker's drain/shed/flush with the submit loop:
/// (a) every request is answered exactly once,
/// (b) the freshest `queue_limit` ids are always *served* — evicting
///     id k requires more than `limit` unanswered requests at-or-after
///     k, impossible for the last `limit` submissions — so sheds hit
///     only stale work,
/// (c) sheds are typed `QueueFull` and the counters reconcile.
#[test]
fn drop_oldest_sheds_stalest_never_freshest() {
    let model = test_model(45);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.batcher = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(200) };
    cfg.queue_limit = Some(4);
    cfg.shed = ShedPolicy::DropOldest;
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();

    let n = 200;
    let limit = 4u64;
    let inputs = test_inputs(&model, n, 46);
    let (tx, rx) = std::sync::mpsc::channel();
    let mut ids = Vec::with_capacity(n);
    for x in &inputs {
        ids.push(coord.submit(mid, x, tx.clone()));
    }
    drop(tx);
    // A fresh pool assigns sequential ids, so id order == submission age.
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
    let replies: Vec<_> = rx.iter().collect();
    assert_eq!(replies.len(), n, "every submit is answered exactly once");

    let served: Vec<u64> = replies
        .iter()
        .filter_map(|r| r.as_ref().ok().map(|x| x.request_id))
        .collect();
    let shed = replies.iter().filter(|r| r.is_err()).count();
    assert_eq!(served.len() + shed, n, "each request served xor shed");
    for id in (n as u64 - limit)..n as u64 {
        assert!(
            served.contains(&id),
            "drop-oldest must never shed one of the freshest {limit} requests (id {id})"
        );
    }
    // A tight 200-request burst against a 4-deep queue must shed: for
    // zero sheds the worker would have to fully drain and serve between
    // ~200 consecutive sub-µs submits, with each serve paying a forward
    // pass.
    assert!(shed > 0, "the burst must actually exercise shedding");
    for r in &replies {
        if let Err(e) = r {
            assert!(
                matches!(e, InferError::QueueFull { limit: 4, .. }),
                "expected QueueFull, got {e:?}"
            );
        }
    }
    let m = coord.metrics();
    assert_eq!(m.shed_requests as usize, shed);
    assert_eq!(m.requests as usize, served.len());
    assert_eq!(coord.worker_metrics()[0].shed_requests as usize, shed);
    coord.shutdown();
}

/// The zero-capacity drop-oldest degenerate is deterministic: every
/// admitted request is shed by the worker before anything can be served.
#[test]
fn drop_oldest_with_zero_limit_sheds_everything() {
    let model = test_model(47);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.queue_limit = Some(0);
    cfg.shed = ShedPolicy::DropOldest;
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let n = 30;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 48) {
        coord.submit(mid, &x, tx.clone());
    }
    drop(tx);
    let replies: Vec<_> = rx.iter().collect();
    assert_eq!(replies.len(), n);
    for r in &replies {
        assert!(
            matches!(r, Err(InferError::QueueFull { limit: 0, .. })),
            "a zero-length queue sheds everything, got {r:?}"
        );
    }
    let m = coord.metrics();
    assert_eq!(m.shed_requests as usize, n);
    assert_eq!(m.requests, 0);
    coord.shutdown();
}

/// Reject-new only sheds when the *pool* is saturated: with round-robin
/// dispatch over two bounded workers, a burst fills both workers to the
/// limit (spilling if the pick is full) before the first `QueueFull`.
#[test]
fn reject_new_sheds_only_when_whole_pool_is_full() {
    let model = test_model(49);
    let mut cfg = pool_config(2, DispatchPolicy::RoundRobin, model.clone());
    // Unreachable deadline: no worker can flush mid-burst, so admission
    // is deterministic; the shutdown drain serves the admitted requests.
    cfg.batcher = BatcherConfig { max_batch: 64, max_wait: Duration::from_secs(60) };
    cfg.queue_limit = Some(3);
    cfg.shed = ShedPolicy::RejectNew;
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();

    let n = 20;
    let pool_capacity = 2 * 3;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 50) {
        coord.submit(mid, &x, tx.clone());
    }
    drop(tx);

    let rejects: Vec<_> = rx.iter().take(n - pool_capacity).collect();
    for r in &rejects {
        match r {
            Err(e) => assert_eq!(*e, InferError::QueueFull { depth: 3, limit: 3 }),
            Ok(resp) => panic!("nothing can be served before the drain, got {resp:?}"),
        }
    }
    let m = coord.metrics();
    assert_eq!(m.shed_requests as usize, n - pool_capacity);
    assert_eq!(m.requests, 0, "nothing served yet");

    coord.shutdown();
    let served: Vec<_> = rx.iter().collect();
    assert_eq!(served.len(), pool_capacity, "both workers filled to the limit");
    assert!(served.iter().all(|r| r.is_ok()));
}

/// A panicking backend is contained: the panic becomes a typed
/// `BackendFailed`, neighbors in the batch are served via per-row retry,
/// and the worker thread survives to serve later traffic.
#[test]
fn backend_panic_contained_as_typed_error() {
    let model = test_model(55);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.backend = BackendSpec::FaultInjecting(model.clone());
    cfg.batcher = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(200) };
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();

    let inputs = test_inputs(&model, 7, 56);
    for x in &inputs {
        assert!(!x.iter().all(|&b| b), "input collides with the poison marker");
        assert!(
            x[0] || !x[1..].iter().all(|&b| b),
            "input collides with the panic marker"
        );
    }
    let (tx, rx) = std::sync::mpsc::channel();
    let (bad_tx, bad_rx) = std::sync::mpsc::channel();
    let mut expected: HashMap<u64, &Vec<bool>> = HashMap::new();
    for (i, x) in inputs.iter().enumerate() {
        if i == 2 {
            coord.submit(mid, &FaultInjectingBackend::panic_row(model.n_features), bad_tx.clone());
        }
        let id = coord.submit(mid, x, tx.clone());
        expected.insert(id, x);
    }
    drop(tx);
    drop(bad_tx);

    match bad_rx.recv().expect("a panicking row still gets a typed reply") {
        Err(InferError::BackendFailed(msg)) => {
            assert!(msg.contains("panicked"), "{msg}")
        }
        other => panic!("expected BackendFailed, got {other:?}"),
    }
    let responses: Vec<_> = rx
        .iter()
        .map(|r| r.expect("healthy rows must be served despite the panicking batch"))
        .collect();
    assert_eq!(responses.len(), inputs.len());
    for r in &responses {
        assert_eq!(r.pred, model.predict(expected[&r.request_id]));
    }
    // The worker thread survived the panic and keeps serving.
    let x = &inputs[0];
    assert_eq!(coord.infer_blocking(mid, x).unwrap().pred, model.predict(x));
    assert!(coord.metrics().failed_batches >= 1);
    coord.shutdown();
}

/// One poisonous row must cost only itself: the batch it lands in fails,
/// the coordinator splits and retries per-row, every healthy neighbor is
/// served, and only the poison caller gets `BackendFailed`.
#[test]
fn backend_failure_isolated_to_poison_row_neighbors_served() {
    let model = test_model(50);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.backend = BackendSpec::FaultInjecting(model.clone());
    cfg.batcher = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(200) };
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();

    let inputs = test_inputs(&model, 7, 51);
    for x in &inputs {
        assert!(!x.iter().all(|&b| b), "seeded inputs must not be poison rows");
    }
    let (tx, rx) = std::sync::mpsc::channel();
    let (bad_tx, bad_rx) = std::sync::mpsc::channel();
    let mut expected: HashMap<u64, &Vec<bool>> = HashMap::new();
    for (i, x) in inputs.iter().enumerate() {
        if i == 3 {
            coord.submit(mid, &FaultInjectingBackend::poison_row(model.n_features), bad_tx.clone());
        }
        let id = coord.submit(mid, x, tx.clone());
        expected.insert(id, x);
    }
    drop(tx);
    drop(bad_tx);

    match bad_rx.recv().expect("failed request still gets a typed reply") {
        Err(InferError::BackendFailed(msg)) => {
            assert!(msg.contains("injected fault"), "{msg}")
        }
        other => panic!("expected BackendFailed, got {other:?}"),
    }
    let responses: Vec<_> = rx
        .iter()
        .map(|r| r.expect("healthy rows must be served despite the poisoned batch"))
        .collect();
    assert_eq!(responses.len(), inputs.len());
    for r in &responses {
        assert_eq!(r.pred, model.predict(expected[&r.request_id]));
    }
    let m = coord.metrics();
    assert_eq!(m.requests as usize, inputs.len());
    assert!(
        m.failed_batches >= 1,
        "the failed forward call(s) must be visible, got {}",
        m.failed_batches
    );
    assert_eq!(m.rejected_requests, 0);
    coord.shutdown();
}

/// `infer_blocking` surfaces typed `InferError`s — never a bare
/// closed-channel error — for rejected, shed, and backend-failed rows.
#[test]
fn infer_blocking_surfaces_typed_errors() {
    let model = test_model(60);

    // Rejected: the admission width gate.
    let cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let err = coord.infer_blocking(mid, &vec![true; model.n_features + 1]).unwrap_err();
    let want = InferError::WidthMismatch {
        got: model.n_features + 1,
        expected: model.n_features,
    };
    assert_eq!(err.downcast_ref::<InferError>(), Some(&want));
    coord.shutdown();

    // Shed: a zero-length queue rejects every request as QueueFull.
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.queue_limit = Some(0);
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let x = test_inputs(&model, 1, 61).remove(0);
    let err = coord.infer_blocking(mid, &x).unwrap_err();
    assert_eq!(
        err.downcast_ref::<InferError>(),
        Some(&InferError::QueueFull { depth: 0, limit: 0 })
    );
    assert_eq!(coord.metrics().shed_requests, 1);
    coord.shutdown();

    // Backend-failed: the fault-injecting backend's poison row, alone in
    // its batch (no neighbors to save, no retry possible).
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.backend = BackendSpec::FaultInjecting(model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let err = coord
        .infer_blocking(mid, &FaultInjectingBackend::poison_row(model.n_features))
        .unwrap_err();
    match err.downcast_ref::<InferError>() {
        Some(InferError::BackendFailed(msg)) => {
            assert!(msg.contains("injected fault"), "{msg}")
        }
        other => panic!("expected BackendFailed, got {other:?}"),
    }
    assert_eq!(coord.metrics().failed_batches, 1);
    // The pool survives the failure and keeps serving.
    let resp = coord.infer_blocking(mid, &x).unwrap();
    assert_eq!(resp.pred, model.predict(&x));
    coord.shutdown();
}

// --- scatter/reduce (clause-sharded) pools -------------------------------

/// The sharded tentpole acceptance path: a 3-shard scatter/reduce pool is
/// *bit-identical* to the unsharded forward pass — class sums, argmax,
/// and lowest-index tie behaviour — while `shape_for` reports the plan.
#[test]
fn sharded_pool_is_bit_identical_to_the_unsharded_forward() {
    let model = test_model(70);
    let cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start_sharded(unused_root(), "e2e_model", 3, cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    assert_eq!(coord.n_shards(), 3);
    assert_eq!(coord.n_workers(), 3, "sharded pools run one worker per shard");
    let shape = coord.shape_for(mid).unwrap();
    assert_eq!(
        (shape.n_features, shape.n_classes, shape.generation, shape.n_shards),
        (model.n_features, model.n_classes, 0, 3)
    );

    let n = 60;
    let mut inputs = test_inputs(&model, n - 1, 71);
    // All-false: with no literal set, sums often tie at zero — the merged
    // re-argmax must still pick the lowest class, like forward_packed.
    inputs.push(vec![false; model.n_features]);
    let (tx, rx) = std::sync::mpsc::channel();
    let mut expected: HashMap<u64, &Vec<bool>> = HashMap::new();
    for x in &inputs {
        expected.insert(coord.submit(mid, x, tx.clone()), x);
    }
    drop(tx);
    let responses: Vec<_> =
        rx.iter().take(n).map(|r| r.expect("valid requests all serve")).collect();
    assert_eq!(responses.len(), n);
    let mut ids: Vec<u64> = responses.iter().map(|r| r.request_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "each scattered request is answered exactly once");
    for r in &responses {
        let x = expected[&r.request_id];
        let (_, sums, pred) = model.forward_reference(x);
        assert_eq!(r.sums, sums, "request {}", r.request_id);
        assert_eq!(r.pred, pred, "request {}", r.request_id);
        assert_eq!(r.generation, 0);
        assert!(r.worker < 3, "worker tags a shard index");
        assert!(r.hw_decision_latency.is_none(), "no engine attached");
    }
    coord.shutdown();
}

/// Sharded serving through simulated hardware: every shard carries its
/// own die, the merged reply's decision latency is the max over shards
/// (the critical path), and `hw_winner` is cleared — a shard-local
/// arbiter winner is meaningless for the merged argmax.
#[test]
fn sharded_hw_pool_reports_critical_path_latency() {
    let model = test_model(72);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.backend = hw_spec(HwArch::Adder, model.clone());
    cfg.replay = ReplayPolicy::Full;
    let coord = Coordinator::start_sharded(unused_root(), "e2e_model", 2, cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    for x in test_inputs(&model, 12, 73) {
        let resp = coord.infer_blocking(mid, &x).unwrap();
        assert_eq!(resp.pred, model.predict(&x), "functional path bit-exact");
        let lat = resp.hw_decision_latency.expect("full replay tags every merged reply");
        assert!(lat > Ps::ZERO);
        assert!(resp.hw_winner.is_none(), "shard-local winners must not leak");
    }
    assert!(coord.metrics().hw_mean_ns > 0.0);
    coord.shutdown();
}

/// Hot-swap through a sharded pool: a mid-burst reload loses nothing,
/// and the generation bump lands in `shape_for` and later replies.
#[test]
fn sharded_pool_reloads_without_losing_requests() {
    let model = test_model(74);
    let cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start_sharded(unused_root(), "e2e_model", 3, cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();

    let n = 90;
    let inputs = test_inputs(&model, n, 75);
    let (tx, rx) = std::sync::mpsc::channel();
    for (i, x) in inputs.iter().enumerate() {
        if i == n / 2 {
            coord.reload(mid).unwrap();
        }
        coord.submit(mid, x, tx.clone());
    }
    drop(tx);
    let responses: Vec<_> =
        rx.iter().take(n).map(|r| r.expect("reload must lose nothing")).collect();
    assert_eq!(responses.len(), n);
    for r in &responses {
        assert_eq!(r.pred, model.predict(&inputs[r.request_id as usize]));
        assert!(r.generation <= 1, "generations only 0 (pre) or 1 (post)");
    }
    assert!(
        responses.iter().any(|r| r.generation == 1),
        "post-reload requests must carry the new generation"
    );
    assert_eq!(coord.shape_for(mid).unwrap().generation, 1);
    // A straggler-free burst: no reduce slot ever timed out.
    assert_eq!(coord.metrics().failed_batches, 0);
    coord.shutdown();
}

/// Typed fail-soft still holds on the scatter path: width mismatches are
/// rejected at admission (before any shard sees the row), and a
/// zero-capacity queue sheds with `QueueFull` — exactly once per request,
/// not once per shard.
#[test]
fn sharded_pool_admission_errors_stay_typed_and_single() {
    let model = test_model(76);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.queue_limit = Some(0);
    cfg.shed = ShedPolicy::RejectNew;
    let coord = Coordinator::start_sharded(unused_root(), "e2e_model", 4, cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();

    let err = coord.infer_blocking(mid, &vec![true; model.n_features + 2]).unwrap_err();
    let want = InferError::WidthMismatch {
        got: model.n_features + 2,
        expected: model.n_features,
    };
    assert_eq!(err.downcast_ref::<InferError>(), Some(&want));

    // Zero capacity: the scatter sheds before registering a reduce slot,
    // so the caller sees exactly one QueueFull.
    let x = test_inputs(&model, 1, 77).remove(0);
    let (tx, rx) = std::sync::mpsc::channel();
    coord.submit(mid, &x, tx.clone());
    drop(tx);
    let replies: Vec<_> = rx.iter().collect();
    assert_eq!(replies.len(), 1, "one reply per request, never one per shard");
    assert!(
        matches!(replies[0], Err(InferError::QueueFull { limit: 0, .. })),
        "expected QueueFull, got {:?}",
        replies[0]
    );
    assert_eq!(coord.metrics().shed_requests, 1);
    coord.shutdown();
}

/// Shutdown with a sharded plan neither hangs nor drops: queued work is
/// drained through the reduce, then the collector exits.
#[test]
fn sharded_pool_shutdown_drains_and_joins() {
    let model = test_model(78);
    let cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start_sharded(unused_root(), "e2e_model", 2, cfg).unwrap();
    let mid = coord.model_id("e2e_model").unwrap();
    let n = 40;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 79) {
        coord.submit(mid, &x, tx.clone());
    }
    drop(tx);
    coord.shutdown();
    assert_eq!(
        rx.iter().filter(|r| r.is_ok()).count(),
        n,
        "graceful shutdown answers everything admitted to the scatter"
    );
}
