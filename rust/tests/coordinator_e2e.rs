//! End-to-end coordinator tests: requests through dispatch → per-worker
//! batching → backend forward → policy-driven hardware replay, with
//! metrics aggregation and shutdown behaviour.
//!
//! These run against in-memory models (`BackendSpec::InMemory` /
//! `BackendSpec::TimeDomain { model: Some(_) }`), so they need no
//! artifacts and exercise the full pool — including simulated-hardware
//! serving — on every CI run.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, ReplayPolicy,
};
use tdpc::flow::FlowConfig;
use tdpc::hw::HwArch;
use tdpc::runtime::BackendSpec;
use tdpc::tm::TmModel;
use tdpc::util::{Ps, SplitMix64};

/// Deterministic iris-scale random model: 3 classes × 10 clauses over 16
/// Boolean features.
fn test_model(seed: u64) -> Arc<TmModel> {
    Arc::new(TmModel::synthetic("e2e_model", 3, 10, 16, 0.15, seed))
}

fn test_inputs(model: &TmModel, n: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..model.n_features).map(|_| rng.next_bool(0.5)).collect()).collect()
}

/// Artifacts root placeholder — in-memory specs never read it.
fn unused_root() -> PathBuf {
    PathBuf::from("/nonexistent-artifacts-root")
}

fn pool_config(
    n_workers: usize,
    dispatch: DispatchPolicy,
    model: Arc<TmModel>,
) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(300) },
        n_workers,
        dispatch,
        backend: BackendSpec::InMemory(model),
        replay: ReplayPolicy::Off,
    }
}

/// An in-memory time-domain spec for `model` with the given architecture.
/// Uses the ideal (zero-variation) flow at Table-I nominal delays so the
/// async-vs-functional exactness assertions below are deterministic —
/// variation robustness is table1's delay-tuning concern, exercised by
/// the experiments suite, not by this pool-plumbing e2e.
fn hw_spec(arch: HwArch, model: Arc<TmModel>) -> BackendSpec {
    BackendSpec::TimeDomain {
        arch,
        flow: FlowConfig::ideal(Ps(380), Ps(618)),
        model: Some(model),
    }
}

#[test]
fn serves_requests_with_correct_predictions() {
    let model = test_model(1);
    let cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    for (i, x) in test_inputs(&model, 20, 2).into_iter().enumerate() {
        let resp = coord.infer_blocking(&x).unwrap();
        assert_eq!(resp.pred, model.predict(&x), "request {i}");
        assert_eq!(resp.sums, model.class_sums(&x), "request {i}");
        assert!(resp.hw_decision_latency.is_none());
        assert!(resp.service_latency_us > 0.0);
        assert_eq!(resp.worker, 0);
    }
    let m = coord.metrics();
    assert_eq!(m.requests, 20);
    assert!(m.batches >= 1);
    // A single-worker pool's aggregate equals its only worker's snapshot.
    assert_eq!(coord.worker_metrics()[0], m);
    coord.shutdown();
}

#[test]
fn four_worker_pool_answers_each_request_once_and_metrics_sum() {
    let model = test_model(3);
    let cfg = pool_config(4, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    assert_eq!(coord.n_workers(), 4);

    let n = 200;
    let inputs = test_inputs(&model, n, 4);
    let (tx, rx) = std::sync::mpsc::channel();
    for x in &inputs {
        coord.submit(x, tx.clone()).unwrap();
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().take(n).collect();
    assert_eq!(responses.len(), n);

    // Every request id answered exactly once, each with the right result.
    let mut ids: Vec<u64> = responses.iter().map(|r| r.request_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n);
    for r in &responses {
        assert_eq!(r.pred, model.predict(&inputs[r.request_id as usize]));
        assert!(r.worker < 4);
    }
    // All four workers actually served traffic (round-robin → 50 each).
    for w in 0..4 {
        assert!(
            responses.iter().any(|r| r.worker == w),
            "worker {w} served nothing"
        );
    }

    let m = coord.metrics();
    let per_worker = coord.worker_metrics();
    assert_eq!(m.requests as usize, n, "aggregate request count");
    assert_eq!(
        per_worker.iter().map(|w| w.requests).sum::<u64>(),
        m.requests,
        "per-worker requests must sum to the aggregate"
    );
    assert_eq!(
        per_worker.iter().map(|w| w.batches).sum::<u64>(),
        m.batches,
        "per-worker batch counts must sum to the aggregate"
    );
    for (i, w) in per_worker.iter().enumerate() {
        assert_eq!(w.requests, 50, "round-robin shares traffic evenly (worker {i})");
        assert!(w.batches >= 1, "worker {i} executed no batches");
    }
    coord.shutdown();
}

#[test]
fn least_loaded_prefers_idle_workers() {
    let model = test_model(5);
    let cfg = pool_config(2, DispatchPolicy::LeastLoaded, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    // Sequential blocking requests: the pool is idle at each submit, so the
    // tie-break (lowest index) pins every request to worker 0.
    for x in test_inputs(&model, 10, 6) {
        let resp = coord.infer_blocking(&x).unwrap();
        assert_eq!(resp.worker, 0);
    }
    // A burst deepens worker 0's queue, so worker 1 must pick up load.
    let n = 100;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 7) {
        coord.submit(&x, tx.clone()).unwrap();
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().take(n).collect();
    assert_eq!(responses.len(), n);
    assert!(
        responses.iter().any(|r| r.worker == 1),
        "burst load never spilled to the second worker"
    );
    coord.shutdown();
}

#[test]
fn batches_form_under_burst_load() {
    let model = test_model(8);
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(50) },
        n_workers: 1,
        dispatch: DispatchPolicy::RoundRobin,
        backend: BackendSpec::InMemory(model.clone()),
        replay: ReplayPolicy::Off,
    };
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let n = 200;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 9) {
        coord.submit(&x, tx.clone()).unwrap();
    }
    drop(tx);
    assert_eq!(rx.iter().take(n).count(), n);
    let m = coord.metrics();
    assert_eq!(m.requests as usize, n);
    assert!(
        m.mean_batch_size > 2.0,
        "burst submission must produce real batches, got {}",
        m.mean_batch_size
    );
    coord.shutdown();
}

/// The tentpole acceptance path: a 4-worker pool served entirely through
/// `BackendSpec::TimeDomain` with full replay. Every response must carry
/// `hw_decision_latency`/`hw_winner`, and predictions must be identical
/// to the native backend (same packed forward pass); the async arbiter
/// may disagree with the functional argmax only on exact class-sum ties.
#[test]
fn four_worker_time_domain_pool_replays_every_response() {
    let model = test_model(10);
    let mut cfg = pool_config(4, DispatchPolicy::RoundRobin, model.clone());
    cfg.backend = hw_spec(HwArch::Async, model.clone());
    cfg.replay = ReplayPolicy::Full;
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();

    let n = 80;
    let inputs = test_inputs(&model, n, 11);
    let (tx, rx) = std::sync::mpsc::channel();
    for x in &inputs {
        coord.submit(x, tx.clone()).unwrap();
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().take(n).collect();
    assert_eq!(responses.len(), n);

    let mut mismatch_without_tie = 0;
    for r in &responses {
        let x = &inputs[r.request_id as usize];
        assert_eq!(r.pred, model.predict(x), "functional path identical to native");
        let lat = r.hw_decision_latency.expect("full replay must tag every response");
        assert!(lat.as_ns() > 1.0, "plausible on-chip latency");
        let winner = r.hw_winner.expect("full replay must report the hardware argmax");
        let sums = model.class_sums(x);
        let top = *sums.iter().max().unwrap();
        let tied = sums.iter().filter(|&&s| s == top).count() > 1;
        if winner != r.pred && !tied {
            mismatch_without_tie += 1;
        }
    }
    assert_eq!(mismatch_without_tie, 0, "hw argmax must match on non-tied samples");

    let m = coord.metrics();
    assert!(m.hw_mean_ns > 0.0);
    assert!(m.hw_p50 > Ps::ZERO && m.hw_p99 >= m.hw_p50, "hw percentiles populated");
    coord.shutdown();
}

#[test]
fn sampled_replay_tags_exactly_one_in_n() {
    let model = test_model(17);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.backend = hw_spec(HwArch::Adder, model.clone());
    cfg.replay = ReplayPolicy::Sample(4);
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let n = 64;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 18) {
        coord.submit(&x, tx.clone()).unwrap();
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().take(n).collect();
    // One worker serves rows 0..64 in order ⇒ exactly every 4th replayed.
    let replayed = responses.iter().filter(|r| r.hw_decision_latency.is_some()).count();
    assert_eq!(replayed, n / 4, "1-in-4 sampling on a single worker is exact");
    // The synchronous adder engine's tie-break matches the functional
    // argmax bit-exactly, ties included.
    for r in &responses {
        if let Some(w) = r.hw_winner {
            assert_eq!(w, r.pred, "sync engine argmax identical to functional");
        }
    }
    coord.shutdown();
}

#[test]
fn shutdown_drains_queued_requests() {
    let model = test_model(12);
    let cfg = pool_config(3, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let n = 120;
    let (tx, rx) = std::sync::mpsc::channel();
    for x in test_inputs(&model, n, 13) {
        coord.submit(&x, tx.clone()).unwrap();
    }
    drop(tx);
    // Graceful shutdown must answer everything already accepted.
    coord.shutdown();
    assert_eq!(rx.iter().count(), n, "shutdown dropped queued requests");
}

#[test]
fn startup_fails_cleanly_on_missing_artifacts() {
    // Native spec with no artifacts: every worker fails to open the
    // manifest, and start reports it instead of hanging.
    let cfg = CoordinatorConfig {
        n_workers: 4,
        ..CoordinatorConfig::default()
    };
    let err = Coordinator::start(unused_root(), "nonexistent_model", cfg);
    assert!(err.is_err(), "missing artifacts must fail at startup, not at first request");

    // Same guarantee for a manifest-backed time-domain spec.
    let cfg = CoordinatorConfig {
        n_workers: 2,
        backend: BackendSpec::TimeDomain {
            arch: HwArch::Async,
            flow: FlowConfig::table1_default(),
            model: None,
        },
        ..CoordinatorConfig::default()
    };
    assert!(Coordinator::start(unused_root(), "nonexistent_model", cfg).is_err());
}

#[test]
fn start_rejects_zero_workers_and_wrong_in_memory_model() {
    let model = test_model(14);
    let mut cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    cfg.n_workers = 0;
    assert!(Coordinator::start(unused_root(), "e2e_model", cfg).is_err());

    // A time-domain spec holding the wrong in-memory model fails at
    // startup (the "unknown model fails early" guarantee).
    let cfg = CoordinatorConfig {
        n_workers: 1,
        backend: hw_spec(HwArch::Adder, model),
        ..CoordinatorConfig::default()
    };
    assert!(Coordinator::start(unused_root(), "some_other_model", cfg).is_err());
}

#[test]
fn drop_without_shutdown_does_not_hang() {
    let model = test_model(15);
    let cfg = pool_config(2, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let _ = coord.infer_blocking(&test_inputs(&model, 1, 16)[0]).unwrap();
    drop(coord); // Drop impl joins all workers — must not deadlock.
}

#[test]
fn word_boundary_models_batch_correctly_through_four_workers() {
    // The packed request path end-to-end at clause/feature counts that
    // straddle u64 word edges: pack at submit → dispatch → per-worker
    // batch assembly → packed forward → popcount sums, for 4 workers,
    // cross-checked per response against the bool-wise reference forward.
    for (k, cpc, f) in [(1usize, 63usize, 63usize), (2, 32, 64), (5, 13, 65), (1, 127, 31)] {
        let model =
            Arc::new(TmModel::synthetic("e2e_model", k, cpc, f, 0.15, (k * cpc + f) as u64));
        let cfg = pool_config(4, DispatchPolicy::RoundRobin, model.clone());
        let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
        let n = 64;
        let inputs = test_inputs(&model, n, 21);
        let (tx, rx) = std::sync::mpsc::channel();
        for x in &inputs {
            coord.submit(x, tx.clone()).unwrap();
        }
        drop(tx);
        let responses: Vec<_> = rx.iter().take(n).collect();
        assert_eq!(responses.len(), n, "k={k} cpc={cpc} f={f}");
        for r in &responses {
            let x = &inputs[r.request_id as usize];
            let (_, sums, pred) = model.forward_reference(x);
            assert_eq!(r.sums, sums, "k={k} cpc={cpc} f={f} request {}", r.request_id);
            assert_eq!(r.pred, pred, "k={k} cpc={cpc} f={f} request {}", r.request_id);
        }
        coord.shutdown();
    }
}

#[test]
fn width_mismatched_request_fails_batch_not_pool() {
    // A wrong-width request poisons only the batch it lands in: its reply
    // channel closes, and the pool keeps serving later requests.
    let model = test_model(30);
    let cfg = pool_config(1, DispatchPolicy::RoundRobin, model.clone());
    let coord = Coordinator::start(unused_root(), "e2e_model", cfg).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    coord.submit(&vec![true; model.n_features + 3], tx).unwrap();
    assert!(rx.recv().is_err(), "mismatched request must get no reply");
    let x = test_inputs(&model, 1, 31).remove(0);
    let resp = coord.infer_blocking(&x).unwrap();
    assert_eq!(resp.pred, model.predict(&x), "pool must survive the bad batch");
    coord.shutdown();
}
