//! End-to-end coordinator tests: requests through batching → PJRT →
//! hardware replay, with metrics and shutdown behaviour.

use std::time::Duration;

use tdpc::asynctm::AsyncTmEngine;
use tdpc::baselines::DesignParams;
use tdpc::coordinator::{BatcherConfig, Coordinator};
use tdpc::fabric::Device;
use tdpc::flow::FlowConfig;
use tdpc::tm::{Manifest, TestSet, TmModel};

fn setup() -> Option<(std::path::PathBuf, TestSet, TmModel)> {
    let root = Manifest::default_root();
    let Ok(manifest) = Manifest::load(&root) else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    };
    let entry = manifest.entry("iris_c10").unwrap().clone();
    let test = TestSet::load(&entry.test_data_path).unwrap();
    let model = TmModel::load(&entry.model_path).unwrap();
    Some((root, test, model))
}

#[test]
fn serves_requests_with_correct_predictions() {
    let Some((root, test, model)) = setup() else { return };
    let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(300) };
    let coord = Coordinator::start(root, "iris_c10", cfg, None).unwrap();
    for i in 0..20 {
        let x = test.x[i % test.len()].clone();
        let resp = coord.infer_blocking(x.clone()).unwrap();
        assert_eq!(resp.pred, model.predict(&x), "request {i}");
        assert!(resp.hw_decision_latency.is_none());
        assert!(resp.service_latency_us > 0.0);
    }
    let m = coord.metrics();
    assert_eq!(m.requests, 20);
    assert!(m.batches >= 1);
    coord.shutdown();
}

#[test]
fn batches_form_under_concurrent_load() {
    let Some((root, test, _model)) = setup() else { return };
    let cfg = BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(4) };
    let coord = Coordinator::start(root, "iris_c10", cfg, None).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    let n = 200;
    for i in 0..n {
        coord.submit(test.x[i % test.len()].clone(), tx.clone()).unwrap();
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().take(n).collect();
    assert_eq!(responses.len(), n);
    let m = coord.metrics();
    assert_eq!(m.requests as usize, n);
    assert!(
        m.mean_batch_size > 2.0,
        "burst submission must produce real batches, got {}",
        m.mean_batch_size
    );
    // Every request id answered exactly once.
    let mut ids: Vec<u64> = responses.iter().map(|r| r.request_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n);
    coord.shutdown();
}

#[test]
fn hardware_replay_reports_latency_and_agrees() {
    let Some((root, test, model)) = setup() else { return };
    let d = DesignParams::from_model(&model);
    let engine =
        AsyncTmEngine::build(&Device::xc7z020(), &d, &FlowConfig::table1_default(), 3).unwrap();
    let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(200) };
    let coord = Coordinator::start(root, "iris_c10", cfg, Some(engine)).unwrap();
    let mut mismatch_with_margin = 0;
    for i in 0..30 {
        let x = test.x[i % test.len()].clone();
        let resp = coord.infer_blocking(x.clone()).unwrap();
        let lat = resp.hw_decision_latency.expect("hw engine attached");
        assert!(lat.as_ns() > 1.0, "plausible on-chip latency");
        // Hardware may only disagree on argmax ties.
        let sums = model.class_sums(&x);
        let top = *sums.iter().max().unwrap();
        let tied = sums.iter().filter(|&&s| s == top).count() > 1;
        if resp.hw_winner != Some(resp.pred) && !tied {
            mismatch_with_margin += 1;
        }
    }
    assert_eq!(mismatch_with_margin, 0, "hw argmax must match on non-tied samples");
    let m = coord.metrics();
    assert!(m.hw_mean_ns > 0.0);
    coord.shutdown();
}

#[test]
fn startup_fails_cleanly_on_bad_model() {
    let Some((root, _, _)) = setup() else { return };
    let cfg = BatcherConfig::default();
    let err = Coordinator::start(root, "nonexistent_model", cfg, None);
    assert!(err.is_err(), "unknown model must fail at startup, not at first request");
}

#[test]
fn drop_without_shutdown_does_not_hang() {
    let Some((root, test, _)) = setup() else { return };
    let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(100) };
    let coord = Coordinator::start(root, "iris_c10", cfg, None).unwrap();
    let _ = coord.infer_blocking(test.x[0].clone()).unwrap();
    drop(coord); // Drop impl joins the worker — must not deadlock.
}
