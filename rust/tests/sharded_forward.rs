//! Property suite for the scatter/reduce merge: across feature widths and
//! clause counts straddling the 64-bit word boundary, and shard counts
//! that split class blocks mid-word, the sum of per-shard partial outputs
//! must reproduce the unsharded `forward_packed` bit for bit — sums,
//! fired words, and argmax (ties to the lowest class index) alike.

use std::sync::Arc;

use tdpc::tm::{merge_partials, ClauseShard, PackedBatch, PartialOutput, TmModel};
use tdpc::util::SplitMix64;

/// Random rows plus the two degenerate ones: all-false (no literal set —
/// only empty-include clauses fire, often an all-zero-sums argmax tie)
/// and all-true.
fn test_rows(n: usize, f: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    let mut rows: Vec<Vec<bool>> =
        (0..n).map(|_| (0..f).map(|_| rng.next_bool(0.5)).collect()).collect();
    rows.push(vec![false; f]);
    rows.push(vec![true; f]);
    rows
}

fn partials(shards: &[ClauseShard], batch: &PackedBatch) -> Vec<PartialOutput> {
    shards.iter().map(|s| s.partial(batch).unwrap()).collect()
}

/// The grid from the PR spec: f ∈ {31, 63, 64, 65} × c_total ∈
/// {63, 64, 65, 127} (via (n_classes, clauses_per_class) pairs) ×
/// n_shards ∈ {1, 2, 3, 7}. Odd shard counts against these clause counts
/// force shard boundaries inside classes and inside fired words.
#[test]
fn shard_partials_merge_to_the_unsharded_forward_across_the_geometry_grid() {
    for &f in &[31usize, 63, 64, 65] {
        for &(k, cpc) in &[(3usize, 21usize), (4, 16), (5, 13), (1, 127)] {
            let model = Arc::new(TmModel::synthetic(
                &format!("prop_f{f}_k{k}x{cpc}"),
                k,
                cpc,
                f,
                0.3,
                f as u64 * 1000 + (k * cpc) as u64,
            ));
            let batch = PackedBatch::from_rows(&test_rows(6, f, 99)).unwrap();
            let full = model.forward_packed(&batch).unwrap();
            let total_slots = ClauseShard::new(model.clone(), 0, 1).unwrap().n_slots();
            for &n_shards in &[1usize, 2, 3, 7] {
                let shards = ClauseShard::split(&model, n_shards).unwrap();
                // The shards partition the scan arena: no slot lost, none
                // double-counted.
                assert_eq!(
                    shards.iter().map(ClauseShard::n_slots).sum::<usize>(),
                    total_slots,
                    "f={f} k={k} cpc={cpc} n_shards={n_shards}: slot partition"
                );
                let merged = merge_partials(&partials(&shards, &batch)).unwrap();
                assert_eq!(
                    merged, full,
                    "f={f} k={k} cpc={cpc} n_shards={n_shards}: merged != unsharded"
                );
            }
        }
    }
}

/// More shards than scan slots: the trailing shards own empty slot
/// ranges, contribute all-zero partials, and the merge is unchanged.
#[test]
fn empty_shards_contribute_nothing_and_still_merge_exactly() {
    let model = Arc::new(TmModel::synthetic("prop_tiny", 1, 2, 9, 0.5, 3));
    let batch = PackedBatch::from_rows(&test_rows(4, 9, 7)).unwrap();
    let full = model.forward_packed(&batch).unwrap();
    let n_shards = 5; // c_total = 2 ⟹ at least three empty shards
    let shards = ClauseShard::split(&model, n_shards).unwrap();
    let empty = shards.iter().filter(|s| s.n_slots() == 0).count();
    assert!(empty >= 3, "expected ≥ 3 empty shards, got {empty}");
    let parts = partials(&shards, &batch);
    for (s, p) in shards.iter().zip(&parts) {
        if s.n_slots() == 0 {
            assert!(p.sums.iter().all(|&v| v == 0), "empty shard emitted votes");
            assert!(
                (0..p.batch).all(|r| p.fired_words_row(r).iter().all(|&w| w == 0)),
                "empty shard fired clauses"
            );
        }
    }
    assert_eq!(merge_partials(&parts).unwrap(), full);
}

/// Ties break to the lowest class index after the reduce, exactly as the
/// unsharded argmax does. The all-false row on a model whose clauses all
/// include at least one literal yields all-zero sums — a full k-way tie —
/// and sharding must not perturb the winner.
#[test]
fn merged_argmax_breaks_ties_to_the_lowest_class() {
    let model = Arc::new(TmModel::synthetic("prop_tie", 5, 13, 64, 0.3, 11));
    let all_false = vec![vec![false; 64]];
    let batch = PackedBatch::from_rows(&all_false).unwrap();
    let full = model.forward_packed(&batch).unwrap();
    for &n_shards in &[2usize, 3, 7] {
        let shards = ClauseShard::split(&model, n_shards).unwrap();
        let merged = merge_partials(&partials(&shards, &batch)).unwrap();
        assert_eq!(merged.pred, full.pred, "n_shards={n_shards}");
        // If the row really tied (no clause fired), the winner is class 0.
        if merged.sums.iter().all(|&s| s == 0) {
            assert_eq!(merged.pred[0], 0, "all-zero tie must go to class 0");
        }
    }
}

/// Per-class upper bounds decompose across shards: each shard's
/// `class_ub` sums to the one-shard (whole-model) bound, and the suffix
/// table is a proper suffix maximum with the `i32::MIN` sentinel.
#[test]
fn shard_class_bounds_partition_the_model_bound() {
    let model = Arc::new(TmModel::synthetic("prop_ub", 4, 16, 65, 0.3, 17));
    let whole = ClauseShard::new(model.clone(), 0, 1).unwrap();
    for &n_shards in &[2usize, 3, 7] {
        let shards = ClauseShard::split(&model, n_shards).unwrap();
        for k in 0..model.n_classes {
            let sum: i32 = shards.iter().map(|s| s.class_ub()[k]).sum();
            assert_eq!(sum, whole.class_ub()[k], "class {k}, n_shards={n_shards}");
        }
        for s in &shards {
            let suffix = s.class_ub_suffix();
            assert_eq!(suffix.len(), model.n_classes + 1);
            assert_eq!(suffix[model.n_classes], i32::MIN);
            for k in (0..model.n_classes).rev() {
                assert_eq!(suffix[k], s.class_ub()[k].max(suffix[k + 1]));
            }
        }
    }
}
