//! Content-addressed artifact store e2e: the v2 tree serving through a
//! live coordinator pool.
//!
//! Covers the manifest-v2 acceptance invariants:
//! * **delta-aware reload** on a live sharded pool: a reload that
//!   changed 1 of N clause-block objects re-opens exactly 1 shard
//!   (`reload_shards_reused == N − 1`), with bit-identical responses
//!   across the swap (the rewritten shard mutates only a dead clause)
//!   and zero request loss;
//! * **corruption is fail-soft**: a flipped byte, a dangling hash, or a
//!   truncated manifest fails `reload` with a typed error and the pool
//!   keeps serving the previous generation;
//! * **GC safety on a live pool**: objects referenced by the current
//!   manifest or pinned by a worker's payload cache are never deleted;
//!   a superseded object is collected only after the reload releases it;
//! * **v1 migration**: `pack_from_v1` converts a bare-directory tree in
//!   place and the migrated pool serves bit-identically.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, ReplayPolicy, ShedPolicy,
};
use tdpc::runtime::BackendSpec;
use tdpc::tm::artifact::{self, PackOptions};
use tdpc::tm::{Manifest, TmModel};
use tdpc::util::SplitMix64;

fn tmp_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tdpc-art-{tag}-{}", std::process::id()))
}

fn pool_config(n_workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(300) },
        n_workers,
        dispatch: DispatchPolicy::RoundRobin,
        backend: BackendSpec::Native,
        replay: ReplayPolicy::Off,
        queue_limit: None,
        shed: ShedPolicy::RejectNew,
        ..CoordinatorConfig::default()
    }
}

fn inputs(n: usize, width: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..width).map(|_| rng.next_bool(0.5)).collect()).collect()
}

/// A synthetic model with one clause forced dead (`nonempty` is the
/// authoritative liveness flag: a dead clause never fires, whatever its
/// include bits say). Rewriting that clause's include bits changes the
/// containing object's content hash without changing a single answer —
/// the lever every bit-identical delta-reload assertion below uses.
fn model_with_dead_clause(name: &str, dead_ix: usize, seed: u64) -> TmModel {
    let mut m = TmModel::synthetic(name, 2, 8, 20, 0.25, seed);
    assert!(dead_ix < m.c_total());
    m.nonempty[dead_ix] = false;
    m
}

/// The tentpole acceptance path: a 4-shard scatter/reduce pool on a v2
/// tree, where each worker opened only its own clause-block object.
/// Rewriting exactly one object and reloading mid-burst must (a) lose
/// zero requests, (b) answer bit-identically before and after (the
/// mutation touches only a dead clause), and (c) re-open exactly one
/// shard — `reload_shards_reused == n_shards − 1`.
#[test]
fn delta_reload_on_live_sharded_pool_reopens_one_shard() {
    let root = tmp_root("delta");
    std::fs::remove_dir_all(&root).ok();
    let n_shards = 4;
    // c_total = 16, packed as 4 blocks of 4; clause 13 lives in block 3.
    let m = model_with_dead_clause("delta", 13, 7);
    artifact::pack(&root, &[&m], &PackOptions { n_shards, ..Default::default() }).unwrap();

    let coord =
        Coordinator::start_sharded(root.clone(), "delta", n_shards, pool_config(1)).unwrap();
    let mid = coord.model_id("delta").unwrap();
    let n_phase = 120;
    let xs = inputs(2 * n_phase, m.n_features, 11);

    let (tx, rx) = mpsc::channel();
    for x in &xs[..n_phase] {
        coord.submit(mid, x, tx.clone());
    }
    // One object changes; its clause range (and every answer) does not.
    let new_hash = artifact::rewrite_shard(&root, "delta", 3, |b| {
        let c = 13 - b.clause_lo;
        assert!(!b.nonempty[c], "the mutated clause must be dead");
        b.include[c][0] = !b.include[c][0];
    })
    .unwrap();
    assert_eq!(new_hash.len(), 64);
    coord.reload(mid).unwrap();
    for x in &xs[n_phase..] {
        coord.submit(mid, x, tx.clone());
    }
    drop(tx);

    let replies: Vec<_> = rx.iter().collect();
    assert_eq!(replies.len(), 2 * n_phase, "zero requests lost across the delta reload");
    for reply in replies {
        let resp = reply.expect("every reply is a prediction, never an error");
        let i = resp.request_id as usize;
        assert_eq!(
            (resp.pred, &resp.sums),
            (m.predict(&xs[i]), &m.class_sums(&xs[i])),
            "request {i} must be bit-identical across the dead-clause rewrite"
        );
    }

    let pm = coord.metrics_for(mid).unwrap();
    assert_eq!(pm.reload_attempts, 1);
    assert_eq!(pm.reload_failures, 0);
    assert_eq!(
        pm.reload_shards_reused,
        (n_shards - 1) as u64,
        "exactly one of {n_shards} shard objects may be re-read"
    );
    // Worker-side metrics count per-shard partials: every request visits
    // all n_shards workers.
    assert_eq!(pm.requests, (2 * n_phase * n_shards) as u64);
    assert_eq!(pm.failed_batches, 0);
    // The pool aggregate carries the same counters.
    assert_eq!(coord.metrics().reload_shards_reused, (n_shards - 1) as u64);
    coord.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// Corruption across all three typed failure modes, against a live
/// multi-worker pool: each failed reload returns an actionable error and
/// the previous generation keeps serving bit-identically; fixing the
/// tree and retrying converges.
#[test]
fn corrupt_artifacts_fail_reload_and_keep_old_generation_serving() {
    let root = tmp_root("corrupt");
    std::fs::remove_dir_all(&root).ok();
    let m = model_with_dead_clause("swap", 5, 9);
    artifact::pack(&root, &[&m], &PackOptions { n_shards: 4, ..Default::default() }).unwrap();

    let coord = Coordinator::start_multi(root.clone(), &["swap"], pool_config(2)).unwrap();
    let mid = coord.model_id("swap").unwrap();
    let xs = inputs(8, m.n_features, 13);
    let assert_old_generation_serves = |expected_gen: u64| {
        for x in &xs {
            let resp = coord.infer_blocking(mid, x).unwrap();
            assert_eq!(
                (resp.generation, resp.pred),
                (expected_gen, m.predict(x)),
                "the surviving generation keeps serving"
            );
        }
    };
    assert_old_generation_serves(0);

    // 1. Flipped byte: rewrite a shard (so the re-open has a genuinely
    //    new object the worker's hash-keyed cache cannot satisfy — a
    //    corrupted *unchanged* object would never be re-read), then
    //    corrupt the new object in place.
    let new_hash = artifact::rewrite_shard(&root, "swap", 1, |b| {
        let c = 5 - b.clause_lo;
        b.include[c][0] = !b.include[c][0];
    })
    .unwrap();
    let obj = artifact::object_path(&root, &new_hash);
    let clean = std::fs::read(&obj).unwrap();
    let mut bytes = clean.clone();
    bytes[0] ^= 0x01;
    std::fs::write(&obj, &bytes).unwrap();
    let err = format!("{:#}", coord.reload(mid).unwrap_err());
    assert!(err.contains("sha256"), "typed hash-mismatch error, got: {err}");
    assert_old_generation_serves(0);

    // 2. Dangling hash: the referenced object vanishes entirely.
    std::fs::remove_file(&obj).unwrap();
    let err = format!("{:#}", coord.reload(mid).unwrap_err());
    assert!(err.contains("missing artifact object"), "typed missing-object error, got: {err}");
    assert_old_generation_serves(0);

    // 3. Truncated manifest: unparseable → typed Malformed at open.
    let manifest_path = root.join("manifest.json");
    let full = std::fs::read(&manifest_path).unwrap();
    std::fs::write(&manifest_path, &full[..full.len() / 2]).unwrap();
    let err = format!("{:#}", coord.reload(mid).unwrap_err());
    assert!(err.contains("malformed artifact"), "typed malformed error, got: {err}");
    assert_old_generation_serves(0);

    // Repair: restore the manifest and the clean object bytes; the retry
    // converges (3 failed attempts consumed generations 1..=3), and the
    // answers are unchanged because only a dead clause was rewritten.
    std::fs::write(&manifest_path, &full).unwrap();
    std::fs::write(&obj, &clean).unwrap();
    coord.reload(mid).unwrap();
    assert_old_generation_serves(4);

    let pm = coord.metrics_for(mid).unwrap();
    assert_eq!(pm.reload_attempts, 4);
    assert_eq!(pm.reload_failures, 3);
    coord.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// GC on a live pool: a superseded object stays on disk while any
/// worker's payload cache still pins it, and is collected only after the
/// reload releases it — never an object the manifest references.
#[test]
fn gc_on_live_pool_spares_pinned_and_referenced_objects() {
    let root = tmp_root("gc");
    std::fs::remove_dir_all(&root).ok();
    let m = model_with_dead_clause("keep", 2, 17);
    artifact::pack(&root, &[&m], &PackOptions { n_shards: 4, ..Default::default() }).unwrap();

    // One worker, so exactly one payload cache holds the pins.
    let coord = Coordinator::start_multi(root.clone(), &["keep"], pool_config(1)).unwrap();
    let mid = coord.model_id("keep").unwrap();
    let xs = inputs(6, m.n_features, 19);
    for x in &xs {
        assert_eq!(coord.infer_blocking(mid, x).unwrap().pred, m.predict(x));
    }

    // Supersede one object: the old one is now manifest-unreferenced but
    // still pinned by the live (not yet reloaded) worker.
    artifact::rewrite_shard(&root, "keep", 0, |b| b.include[2][0] = !b.include[2][0]).unwrap();
    let report = coord.gc_artifacts(false).unwrap();
    assert_eq!(report.scanned, 5);
    assert_eq!(report.live, 4, "current manifest references 3 old + 1 new object");
    assert_eq!(report.kept_pinned, 1, "the superseded object is pinned by the live worker");
    assert_eq!(report.deleted, 0);

    // The reload swaps the worker onto the new object and releases the
    // stale pin; only then does GC collect the superseded object.
    coord.reload(mid).unwrap();
    let report = coord.gc_artifacts(false).unwrap();
    assert_eq!((report.scanned, report.live, report.kept_pinned), (5, 4, 0));
    assert_eq!(report.deleted, 1, "the superseded object is collected once unpinned");
    assert!(report.bytes_freed > 0);

    // The swept tree still serves (bit-identically: only a dead clause
    // changed) and still verifies clean.
    for x in &xs {
        assert_eq!(coord.infer_blocking(mid, x).unwrap().pred, m.predict(x));
    }
    let v = artifact::verify(&root).unwrap();
    assert_eq!((v.objects_verified, v.unreferenced), (4, 0));
    coord.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// v1 → v2 migration round-trip through a live pool: a bare-directory
/// tree converted in place by `pack_from_v1` serves bit-identically to
/// the original model, and the converted tree verifies clean.
#[test]
fn migrated_v1_tree_serves_bit_identically() {
    let root = tmp_root("fromv1");
    std::fs::remove_dir_all(&root).ok();
    let a = TmModel::synthetic("tenant_a", 3, 7, 33, 0.2, 23);
    let b = TmModel::synthetic("tenant_b", 2, 5, 65, 0.3, 29);
    Manifest::write_synthetic(&root, &[&a, &b]).unwrap();

    let report = artifact::pack_from_v1(&root, 3).unwrap();
    assert_eq!(report.models, 2);
    assert_eq!(report.generation, 1);
    let v = artifact::verify(&root).unwrap();
    assert_eq!(v.models, 2);

    let coord =
        Coordinator::start_multi(root.clone(), &["tenant_a", "tenant_b"], pool_config(2)).unwrap();
    for (model, name) in [(&a, "tenant_a"), (&b, "tenant_b")] {
        let mid = coord.model_id(name).unwrap();
        for x in &inputs(10, model.n_features, 31) {
            let resp = coord.infer_blocking(mid, x).unwrap();
            assert_eq!(resp.pred, model.predict(x), "migrated {name} diverged");
            assert_eq!(resp.sums, model.class_sums(x), "migrated {name} diverged");
        }
    }
    coord.shutdown();
    std::fs::remove_dir_all(&root).ok();
}
