//! Backend-seam correctness: the bit-packed `NativeBackend` must agree
//! with the naive bool-wise reference evaluator (`TmModel::forward_reference`)
//! on randomized models, and with the Python-emitted golden vectors when
//! artifacts are present.
//!
//! The word-boundary suite pins the packed data path at literal and
//! clause counts that straddle `u64` word edges (63/64/65/127 bits) —
//! the widths where shift/mask bugs in `tm::bits` would hide.

mod common;

use std::sync::Arc;

use common::load_golden;
use tdpc::runtime::{BackendSpec, InferenceBackend, NativeBackend};
use tdpc::tm::{Manifest, PackedBatch, TmModel};
use tdpc::util::prop;

/// Build a random model from the property generator (shapes and include
/// density vary per case; `nonempty` derives from the include masks like
/// trained artifacts).
fn random_model(g: &mut prop::Gen) -> TmModel {
    let k = g.int(1, 5) as usize;
    let cpc = g.int(1, 12) as usize;
    let f = g.int(1, 80) as usize;
    let density = g.float(0.0, 0.4);
    random_model_shaped(g, k, cpc, f, density)
}

fn random_model_shaped(
    g: &mut prop::Gen,
    k: usize,
    cpc: usize,
    f: usize,
    density: f64,
) -> TmModel {
    let c_total = k * cpc;
    let include: Vec<Vec<bool>> = (0..c_total).map(|_| g.bits(2 * f, density)).collect();
    let polarity: Vec<i8> =
        (0..c_total).map(|_| if g.boolean(0.5) { 1 } else { -1 }).collect();
    TmModel::assemble_derived("prop".into(), k, f, cpc, include, polarity, 0.0)
}

/// Assert the packed forward pass reproduces the bool-wise reference on
/// every row of a batch: sums, argmax, and every fired clause bit.
fn assert_packed_matches_reference(model: &TmModel, rows: &[Vec<bool>], ctx: &str) {
    let backend = NativeBackend::new(Arc::new(model.clone()));
    let batch = PackedBatch::from_rows(rows).unwrap();
    let out = backend.forward(&batch).unwrap();
    assert_eq!(out.batch, rows.len(), "{ctx}: batch size");
    for (i, row) in rows.iter().enumerate() {
        let (fired, sums, pred) = model.forward_reference(row);
        assert_eq!(out.sums_row(i), &sums[..], "{ctx}: sums, row {i}");
        assert_eq!(out.pred[i] as usize, pred, "{ctx}: argmax, row {i}");
        assert_eq!(out.fired_row(i), fired, "{ctx}: clause bits, row {i}");
    }
}

#[test]
fn prop_native_backend_matches_reference_forward() {
    prop::check("native backend vs reference forward", 120, |g| {
        let model = random_model(g);
        let n_rows = g.int(1, 6) as usize;
        let rows: Vec<Vec<bool>> =
            (0..n_rows).map(|_| g.bits(model.n_features, 0.5)).collect();
        assert_packed_matches_reference(&model, &rows, "random shape");
    });
}

#[test]
fn prop_packed_forward_at_word_boundary_widths() {
    // Feature counts straddling 32/64-bit literal-word edges (the literal
    // vector is 2 × f bits: f = 31..33 → 62/64/66 literals, f = 63..65 →
    // 126/128/130) crossed with clause totals straddling fired-word edges
    // (63/64/65/127 clause bits, class boundaries word-unaligned).
    let features = [31usize, 32, 33, 63, 64, 65];
    let shapes = [(1usize, 63usize), (2, 32), (5, 13), (1, 127), (3, 21)];
    prop::check("packed forward at word-boundary widths", 60, |g| {
        let f = *g.choose(&features);
        let &(k, cpc) = g.choose(&shapes);
        let density = g.float(0.0, 0.4);
        let model = random_model_shaped(g, k, cpc, f, density);
        assert_eq!(model.c_total(), k * cpc);
        let n_rows = g.int(1, 5) as usize;
        let rows: Vec<Vec<bool>> = (0..n_rows).map(|_| g.bits(f, 0.5)).collect();
        assert_packed_matches_reference(&model, &rows, &format!("k={k} cpc={cpc} f={f}"));
    });
}

#[test]
fn prop_popcount_voter_matches_per_clause_voter() {
    // The polarity-mask popcount sums vs the per-clause signed loop, on
    // the packed fired words the forward pass actually emits.
    prop::check("popcount voter vs per-clause voter", 80, |g| {
        let model = random_model(g);
        let row = g.bits(model.n_features, 0.5);
        let out = model.forward_packed(&PackedBatch::single(&row)).unwrap();
        let fired = out.fired_words_row(0);
        assert_eq!(
            model.class_sums_from_fired(fired),
            model.class_sums_per_clause(fired)
        );
    });
}

#[test]
fn prop_argmax_ties_resolve_to_lowest_index() {
    // The cross-language contract: ties break like jnp.argmax.
    prop::check("argmax tie convention", 60, |g| {
        let model = random_model(g);
        let row = g.bits(model.n_features, 0.5);
        let backend = NativeBackend::new(Arc::new(model));
        let out = backend.forward(&PackedBatch::single(&row)).unwrap();
        let sums = out.sums_row(0);
        let top = *sums.iter().max().unwrap();
        let first_top = sums.iter().position(|&s| s == top).unwrap();
        assert_eq!(out.pred[0] as usize, first_top);
    });
}

#[test]
fn native_backend_matches_golden_vectors() {
    // The same proof-of-composition the PJRT path runs (L1 Pallas kernel →
    // jnp oracle → goldens), executed on the native backend. Skips when
    // artifacts are not built.
    let Ok(manifest) = Manifest::load_default() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    for entry in &manifest.models {
        let golden = load_golden(&entry.golden_path);
        let spec = BackendSpec::Native;
        let backend = spec.open(&manifest.root, &entry.name).unwrap();
        let batch = PackedBatch::from_rows(&golden.inputs).unwrap();
        let out = backend.forward(&batch).unwrap();
        for i in 0..golden.inputs.len() {
            assert_eq!(out.sums_row(i), &golden.sums[i][..], "{} sample {i} sums", entry.name);
            assert_eq!(out.pred[i], golden.pred[i], "{} sample {i} pred", entry.name);
            assert_eq!(out.fired_row(i), golden.fired[i], "{} sample {i} clause bits", entry.name);
        }
    }
}
