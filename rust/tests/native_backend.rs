//! Backend-seam correctness: the bit-packed `NativeBackend` must agree
//! with the naive bool-wise reference evaluator (`TmModel::forward_reference`)
//! on randomized models, and with the Python-emitted golden vectors when
//! artifacts are present.

mod common;

use std::sync::Arc;

use common::load_golden;
use tdpc::runtime::{BackendSpec, InferenceBackend, NativeBackend};
use tdpc::tm::{Manifest, TmModel};
use tdpc::util::prop;

/// Build a random model from the property generator (shapes and include
/// density vary per case; `nonempty` derives from the include masks like
/// trained artifacts).
fn random_model(g: &mut prop::Gen) -> TmModel {
    let k = g.int(1, 5) as usize;
    let cpc = g.int(1, 12) as usize;
    let f = g.int(1, 80) as usize;
    let density = g.float(0.0, 0.4);
    let c_total = k * cpc;
    let include: Vec<Vec<bool>> = (0..c_total).map(|_| g.bits(2 * f, density)).collect();
    let polarity: Vec<i8> =
        (0..c_total).map(|_| if g.boolean(0.5) { 1 } else { -1 }).collect();
    TmModel::assemble_derived("prop".into(), k, f, cpc, include, polarity, 0.0)
}

#[test]
fn prop_native_backend_matches_reference_forward() {
    prop::check("native backend vs reference forward", 120, |g| {
        let model = random_model(g);
        let n_rows = g.int(1, 6) as usize;
        let rows: Vec<Vec<bool>> =
            (0..n_rows).map(|_| g.bits(model.n_features, 0.5)).collect();
        let backend = NativeBackend::new(Arc::new(model));
        let out = backend.forward(&rows).unwrap();
        assert_eq!(out.batch, n_rows);
        for (i, row) in rows.iter().enumerate() {
            let (fired, sums, pred) = backend.model().forward_reference(row);
            assert_eq!(out.sums_row(i), &sums[..], "sums, row {i}");
            assert_eq!(out.pred[i] as usize, pred, "argmax, row {i}");
            let got_fired: Vec<bool> =
                out.fired[i * out.c_total..(i + 1) * out.c_total].iter().map(|&v| v != 0).collect();
            assert_eq!(got_fired, fired, "clause bits, row {i}");
        }
    });
}

#[test]
fn prop_argmax_ties_resolve_to_lowest_index() {
    // The cross-language contract: ties break like jnp.argmax.
    prop::check("argmax tie convention", 60, |g| {
        let model = random_model(g);
        let row = g.bits(model.n_features, 0.5);
        let backend = NativeBackend::new(Arc::new(model));
        let out = backend.forward(std::slice::from_ref(&row)).unwrap();
        let sums = out.sums_row(0);
        let top = *sums.iter().max().unwrap();
        let first_top = sums.iter().position(|&s| s == top).unwrap();
        assert_eq!(out.pred[0] as usize, first_top);
    });
}

#[test]
fn native_backend_matches_golden_vectors() {
    // The same proof-of-composition the PJRT path runs (L1 Pallas kernel →
    // jnp oracle → goldens), executed on the native backend. Skips when
    // artifacts are not built.
    let Ok(manifest) = Manifest::load_default() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    for entry in &manifest.models {
        let golden = load_golden(&entry.golden_path);
        let spec = BackendSpec::Native;
        let backend = spec.open(&manifest.root, &entry.name).unwrap();
        let out = backend.forward(&golden.inputs).unwrap();
        for i in 0..golden.inputs.len() {
            assert_eq!(out.sums_row(i), &golden.sums[i][..], "{} sample {i} sums", entry.name);
            assert_eq!(out.pred[i], golden.pred[i], "{} sample {i} pred", entry.name);
            let fired: Vec<bool> = out.fired
                [i * out.c_total..(i + 1) * out.c_total]
                .iter()
                .map(|&v| v != 0)
                .collect();
            assert_eq!(fired, golden.fired[i], "{} sample {i} clause bits", entry.name);
        }
    }
}
