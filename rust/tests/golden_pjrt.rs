//! Cross-layer golden test: the AOT-compiled HLO executed on PJRT must
//! reproduce the Python reference path bit-exactly.
//!
//! `python/compile/aot.py` stores golden vectors (inputs, class sums,
//! clause bits, predictions) computed through the pure-jnp oracle; this
//! test loads each model's HLO text, compiles it on the PJRT CPU client,
//! executes the same inputs, and compares everything. This is the
//! proof-of-composition for L1 (Pallas kernel) → L2 (jax graph) → AOT →
//! L3 (Rust runtime).
//!
//! Requires `make artifacts`; tests skip (pass with a notice) otherwise.

use tdpc::runtime::{bools_to_f32, ModelRegistry};
use tdpc::tm::{parse_bits, Manifest, TmModel};
use tdpc::util::json;

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load_default() {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

struct Golden {
    inputs: Vec<Vec<bool>>,
    sums: Vec<Vec<i32>>,
    fired: Vec<Vec<bool>>,
    pred: Vec<i32>,
}

fn load_golden(path: &std::path::Path) -> Golden {
    let doc = json::parse_file(path).unwrap();
    let inputs = doc
        .get("inputs").unwrap().as_arr().unwrap()
        .iter().map(|v| parse_bits(v.as_str().unwrap()).unwrap()).collect();
    let sums = doc
        .get("sums").unwrap().as_arr().unwrap()
        .iter()
        .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_i64().unwrap() as i32).collect())
        .collect();
    let fired = doc
        .get("fired").unwrap().as_arr().unwrap()
        .iter().map(|v| parse_bits(v.as_str().unwrap()).unwrap()).collect();
    let pred = doc
        .get("pred").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_i64().unwrap() as i32).collect();
    Golden { inputs, sums, fired, pred }
}

#[test]
fn pjrt_matches_golden_vectors_batch1() {
    let Some(manifest) = manifest_or_skip() else { return };
    let registry = ModelRegistry::new(manifest).unwrap();
    for entry in registry.manifest().models.clone() {
        let golden = load_golden(&entry.golden_path);
        let runner = registry.runner(&entry.name, 1).unwrap();
        for i in 0..golden.inputs.len() {
            let out = runner
                .run(&bools_to_f32(std::slice::from_ref(&golden.inputs[i])))
                .unwrap();
            assert_eq!(out.sums_row(0), &golden.sums[i][..], "{} sample {i} sums", entry.name);
            assert_eq!(out.pred[0], golden.pred[i], "{} sample {i} pred", entry.name);
            let fired: Vec<bool> = out.fired.iter().map(|&v| v != 0).collect();
            assert_eq!(fired, golden.fired[i], "{} sample {i} clause bits", entry.name);
        }
    }
}

#[test]
fn pjrt_batch32_consistent_with_batch1() {
    let Some(manifest) = manifest_or_skip() else { return };
    let registry = ModelRegistry::new(manifest).unwrap();
    for entry in registry.manifest().models.clone() {
        let golden = load_golden(&entry.golden_path);
        let r32 = registry.runner(&entry.name, 32).unwrap();
        // Tile the 8 golden inputs to a full batch of 32.
        let rows: Vec<Vec<bool>> =
            (0..32).map(|i| golden.inputs[i % golden.inputs.len()].clone()).collect();
        let out = r32.run(&bools_to_f32(&rows)).unwrap();
        for i in 0..32 {
            let g = i % golden.inputs.len();
            assert_eq!(out.sums_row(i), &golden.sums[g][..], "{} lane {i}", entry.name);
            assert_eq!(out.pred[i], golden.pred[g], "{} lane {i}", entry.name);
        }
    }
}

#[test]
fn pjrt_matches_rust_clause_evaluator() {
    // Third implementation agreement: PJRT-executed HLO vs the independent
    // Rust TmModel evaluator, on fresh test-set samples (not the goldens).
    let Some(manifest) = manifest_or_skip() else { return };
    let registry = ModelRegistry::new(manifest).unwrap();
    for entry in registry.manifest().models.clone() {
        let model = TmModel::load(&entry.model_path).unwrap();
        let test = tdpc::tm::TestSet::load(&entry.test_data_path).unwrap();
        let runner = registry.runner(&entry.name, 1).unwrap();
        for i in (0..test.len().min(40)).step_by(5) {
            let out = runner
                .run(&bools_to_f32(std::slice::from_ref(&test.x[i])))
                .unwrap();
            let sums = model.class_sums(&test.x[i]);
            assert_eq!(out.sums_row(0), &sums[..], "{} sample {i}", entry.name);
            assert_eq!(out.pred[0] as usize, model.predict(&test.x[i]), "{} sample {i}", entry.name);
        }
    }
}

#[test]
fn padded_partial_batches_truncate_correctly() {
    let Some(manifest) = manifest_or_skip() else { return };
    let registry = ModelRegistry::new(manifest).unwrap();
    let entry = registry.manifest().entry("iris_c10").unwrap().clone();
    let golden = load_golden(&entry.golden_path);
    let runner = registry.runner("iris_c10", 32).unwrap();
    let rows: Vec<Vec<bool>> = golden.inputs[..5].to_vec();
    let out = runner.run_padded(&bools_to_f32(&rows), 5).unwrap();
    assert_eq!(out.batch, 5);
    assert_eq!(out.pred.len(), 5);
    for i in 0..5 {
        assert_eq!(out.pred[i], golden.pred[i]);
        assert_eq!(out.sums_row(i), &golden.sums[i][..]);
    }
}
