//! Cross-layer golden test (`--features pjrt` only): the AOT-compiled HLO
//! executed on PJRT must reproduce the Python reference path bit-exactly.
//!
//! `python/compile/aot.py` stores golden vectors (inputs, class sums,
//! clause bits, predictions) computed through the pure-jnp oracle; this
//! test opens each model on the `PjrtBackend`, executes the same inputs,
//! and compares everything. This is the proof-of-composition for L1
//! (Pallas kernel) → L2 (jax graph) → AOT → L3 (Rust runtime). The same
//! goldens run against the `NativeBackend` in `tests/native_backend.rs`
//! on every build.
//!
//! Requires `make artifacts` *and* real xla bindings (the default build
//! links the compile-only stub — see rust/README.md); tests skip (pass
//! with a notice) otherwise.

#![cfg(feature = "pjrt")]

mod common;

use common::load_golden;
use tdpc::runtime::{InferenceBackend, PjrtBackend};
use tdpc::tm::{Manifest, PackedBatch, TmModel};

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load_default() {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

/// One backend (and so one PJRT client) per model; `None` skips the test
/// when the bindings are the compile-only stub.
fn backend_or_skip(manifest: &Manifest, model: &str) -> Option<PjrtBackend> {
    match PjrtBackend::new(manifest.clone(), model) {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("SKIP: PJRT unavailable ({e:#})");
            None
        }
    }
}

#[test]
fn pjrt_matches_golden_vectors_sample_by_sample() {
    let Some(manifest) = manifest_or_skip() else { return };
    for entry in &manifest.models {
        let Some(backend) = backend_or_skip(&manifest, &entry.name) else { return };
        let golden = load_golden(&entry.golden_path);
        for i in 0..golden.inputs.len() {
            let out = backend
                .forward(&PackedBatch::single(&golden.inputs[i]))
                .unwrap();
            assert_eq!(out.sums_row(0), &golden.sums[i][..], "{} sample {i} sums", entry.name);
            assert_eq!(out.pred[0], golden.pred[i], "{} sample {i} pred", entry.name);
            assert_eq!(out.fired_row(0), golden.fired[i], "{} sample {i} clause bits", entry.name);
        }
    }
}

#[test]
fn pjrt_full_batch_consistent_with_single_samples() {
    let Some(manifest) = manifest_or_skip() else { return };
    for entry in &manifest.models {
        let Some(backend) = backend_or_skip(&manifest, &entry.name) else { return };
        let golden = load_golden(&entry.golden_path);
        // Tile the golden inputs to a full batch of 32; the backend picks
        // the 32-wide artifact internally.
        let rows: Vec<Vec<bool>> =
            (0..32).map(|i| golden.inputs[i % golden.inputs.len()].clone()).collect();
        let out = backend.forward(&PackedBatch::from_rows(&rows).unwrap()).unwrap();
        assert_eq!(out.batch, 32);
        for i in 0..32 {
            let g = i % golden.inputs.len();
            assert_eq!(out.sums_row(i), &golden.sums[g][..], "{} lane {i}", entry.name);
            assert_eq!(out.pred[i], golden.pred[g], "{} lane {i}", entry.name);
        }
    }
}

#[test]
fn pjrt_matches_rust_clause_evaluator() {
    // Third implementation agreement: PJRT-executed HLO vs the independent
    // Rust TmModel evaluator, on fresh test-set samples (not the goldens).
    let Some(manifest) = manifest_or_skip() else { return };
    for entry in &manifest.models {
        let Some(backend) = backend_or_skip(&manifest, &entry.name) else { return };
        let model = TmModel::load(&entry.model_path).unwrap();
        let test = tdpc::tm::TestSet::load(&entry.test_data_path).unwrap();
        for i in (0..test.len().min(40)).step_by(5) {
            let out = backend.forward(&PackedBatch::single(&test.x[i])).unwrap();
            let sums = model.class_sums(&test.x[i]);
            assert_eq!(out.sums_row(0), &sums[..], "{} sample {i}", entry.name);
            let want = model.predict(&test.x[i]);
            assert_eq!(out.pred[0] as usize, want, "{} sample {i}", entry.name);
        }
    }
}

#[test]
fn padded_partial_batches_truncate_correctly() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(backend) = backend_or_skip(&manifest, "iris_c10") else { return };
    let entry = manifest.entry("iris_c10").unwrap().clone();
    let golden = load_golden(&entry.golden_path);
    // 5 rows force the 32-wide artifact with zero-padding + truncation.
    let rows: Vec<Vec<bool>> = golden.inputs[..5].to_vec();
    let out = backend.forward(&PackedBatch::from_rows(&rows).unwrap()).unwrap();
    assert_eq!(out.batch, 5);
    assert_eq!(out.pred.len(), 5);
    for i in 0..5 {
        assert_eq!(out.pred[i], golden.pred[i]);
        assert_eq!(out.sums_row(i), &golden.sums[i][..]);
    }
}
