//! Bit-sliced engine property suites: the 64×64 transpose, the
//! plane-major `TransposedBatch`, and `forward_sliced_with` /
//! `partial_sliced_into` must be bit-exact with the row-major reference
//! paths (`forward_reference`, `forward_indexed_with`,
//! `partial_indexed_into`) — across word-boundary shapes, ragged tail
//! groups, lying-`nonempty` authority cases, and cross-class ties.
//!
//! Shapes mirror `tests/hotpath_forward.rs` and deliberately straddle
//! `u64` edges: f ∈ {31, 63, 64, 65} crossed with clause totals
//! c_total ∈ {63, 64, 65, 127}, at row counts that leave the last
//! 64-row group full, singleton, and partially filled.

use std::sync::Arc;

use tdpc::tm::{
    merge_partials, ClauseShard, ForwardScratch, PackedBatch, PartialOutput, TmModel,
    TransposedBatch, SLICED_MIN_ROWS,
};
use tdpc::util::{prop, SplitMix64};

const CLAUSE_SHAPES: [(usize, usize); 4] = [(3, 21), (4, 16), (5, 13), (1, 127)];
const FEATURES: [usize; 4] = [31, 63, 64, 65];
/// Row counts hitting a lone partial group, exact group boundaries, one
/// bit past a boundary, and a multi-group batch with a ragged tail.
const ROW_COUNTS: [usize; 5] = [1, 63, 64, 65, 130];

fn random_model_shaped(g: &mut prop::Gen, k: usize, cpc: usize, f: usize, dens: f64) -> TmModel {
    let c_total = k * cpc;
    let include: Vec<Vec<bool>> = (0..c_total).map(|_| g.bits(2 * f, dens)).collect();
    let polarity: Vec<i8> = (0..c_total).map(|_| if g.boolean(0.5) { 1 } else { -1 }).collect();
    TmModel::assemble_derived("prop".into(), k, f, cpc, include, polarity, 0.0)
}

fn random_rows(rng: &mut SplitMix64, n: usize, f: usize) -> Vec<Vec<bool>> {
    (0..n).map(|_| (0..f).map(|_| rng.next_bool(0.5)).collect()).collect()
}

#[test]
fn transpose_roundtrips_and_agrees_with_rows_across_the_grid() {
    let mut rng = SplitMix64::new(0x51ce);
    for &f in &FEATURES {
        for &rows in &ROW_COUNTS {
            let data = random_rows(&mut rng, rows, f);
            let batch = PackedBatch::from_rows(&data).unwrap();
            let t = TransposedBatch::from_packed(&batch);
            let ctx = format!("f={f} rows={rows}");
            assert_eq!((t.rows(), t.bits()), (rows, f), "{ctx}");
            assert_eq!(t.groups(), rows.div_ceil(64), "{ctx}");
            // Bit definition: bit r of plane word g == row 64g+r's bit i.
            for (r, row) in data.iter().enumerate() {
                for (i, &bit) in row.iter().enumerate() {
                    assert_eq!(t.get(r, i), bit, "{ctx}: bit ({r},{i})");
                    assert_eq!(
                        (t.plane(i)[r / 64] >> (r % 64)) & 1 == 1,
                        bit,
                        "{ctx}: plane word ({r},{i})"
                    );
                }
            }
            // Ragged lanes beyond the last row stay zero in every plane
            // (the invariant the sliced evaluator's `valid` mask rests on).
            if rows % 64 != 0 {
                let tail = t.groups() - 1;
                let live = tdpc::tm::bits::tail_mask(rows);
                for i in 0..f {
                    assert_eq!(t.plane(i)[tail] & !live, 0, "{ctx}: ragged lanes, plane {i}");
                }
            }
            // transpose(transpose(b)) == b, exactly.
            assert_eq!(t.untranspose(), batch, "{ctx}: roundtrip");
        }
    }
}

#[test]
fn prop_sliced_forward_matches_reference_at_word_boundaries() {
    // The tentpole cross-check: sliced ≡ indexed ≡ reference on sums,
    // preds, and fired words — forced through the sliced engine directly
    // (no dispatch threshold), so 1-row batches exercise its ragged
    // single-lane path too.
    prop::check("sliced forward at word-boundary shapes", 40, |g| {
        let f = *g.choose(&FEATURES);
        let &(k, cpc) = g.choose(&CLAUSE_SHAPES);
        let density = g.float(0.0, 0.4);
        let model = random_model_shaped(g, k, cpc, f, density);
        let n_rows = *g.choose(&ROW_COUNTS);
        let rows: Vec<Vec<bool>> = (0..n_rows).map(|_| g.bits(f, 0.5)).collect();
        let ctx = format!("k={k} cpc={cpc} f={f} rows={n_rows}");
        let batch = PackedBatch::from_rows(&rows).unwrap();
        let mut s_sliced = ForwardScratch::new();
        let mut s_indexed = ForwardScratch::new();
        let sliced = model.forward_sliced_with(&batch, &mut s_sliced).unwrap();
        let indexed = model.forward_indexed_with(&batch, &mut s_indexed).unwrap();
        assert_eq!(sliced, indexed, "{ctx}: sliced vs indexed");
        for (i, row) in rows.iter().enumerate() {
            let (fired_ref, sums_ref, pred_ref) = model.forward_reference(row);
            assert_eq!(sliced.fired_row(i), fired_ref, "{ctx}: fired, row {i}");
            assert_eq!(sliced.sums_row(i), &sums_ref[..], "{ctx}: sums, row {i}");
            assert_eq!(sliced.pred[i] as usize, pred_ref, "{ctx}: pred, row {i}");
        }
        // Telemetry parity: both engines account for every eligible slot.
        assert_eq!(s_sliced.rows, n_rows as u64, "{ctx}: rows telemetry");
        assert_eq!(
            s_sliced.clauses_eligible,
            (n_rows * model.c_total()) as u64,
            "{ctx}: eligible telemetry"
        );
        assert_eq!(s_sliced.sliced_groups, n_rows.div_ceil(64) as u64, "{ctx}: groups");
        assert_eq!(s_sliced.sliced_rows, n_rows as u64, "{ctx}: sliced rows");
        assert_eq!(s_indexed.sliced_groups, 0, "{ctx}: indexed engine never slices");
    });
}

#[test]
fn dispatch_is_transparent_and_observable_only_through_telemetry() {
    let mut rng = SplitMix64::new(0xd15b);
    let model = TmModel::synthetic("dispatch", 4, 16, 65, 0.2, 11);
    for &n_rows in &[SLICED_MIN_ROWS - 1, SLICED_MIN_ROWS, 3 * SLICED_MIN_ROWS + 7] {
        let rows = random_rows(&mut rng, n_rows, model.n_features);
        let batch = PackedBatch::from_rows(&rows).unwrap();
        let mut scratch = ForwardScratch::new();
        let dispatched = model.forward_packed_with(&batch, &mut scratch).unwrap();
        let indexed = model.forward_indexed_with(&batch, &mut ForwardScratch::new()).unwrap();
        assert_eq!(dispatched, indexed, "rows={n_rows}");
        let expect_sliced = n_rows >= SLICED_MIN_ROWS;
        assert_eq!(
            scratch.sliced_rows,
            if expect_sliced { n_rows as u64 } else { 0 },
            "rows={n_rows}: sliced row telemetry"
        );
        assert_eq!(
            scratch.sliced_groups,
            if expect_sliced { n_rows.div_ceil(64) as u64 } else { 0 },
            "rows={n_rows}: sliced group telemetry"
        );
    }
}

#[test]
fn vacuous_nonempty_flag_is_authoritative_through_the_sliced_engine() {
    // Same lying-flag fixture as the hotpath suite: a flagged clause
    // with an all-false mask fires on every sample, an unflagged clause
    // with a real mask never fires. The sliced engine must honor both
    // through its plane pipeline — across full and ragged groups.
    let f = 64usize;
    let include = vec![
        vec![false; 2 * f],                                // vacuous, flagged
        (0..2 * f).map(|i| i == 0).collect::<Vec<bool>>(), // real, flagged
        (0..2 * f).map(|i| i == 1).collect::<Vec<bool>>(), // real, UNflagged
        vec![false; 2 * f],                                // dead
    ];
    let m = TmModel::assemble(
        "vacuous".into(),
        2,
        f,
        2,
        include,
        vec![1, -1, 1, -1],
        vec![true, true, false, false],
        0.0,
    );
    let mut rng = SplitMix64::new(0xface);
    for &n_rows in &[65usize, 128] {
        let rows = random_rows(&mut rng, n_rows, f);
        let batch = PackedBatch::from_rows(&rows).unwrap();
        let mut scratch = ForwardScratch::new();
        let out = m.forward_sliced_with(&batch, &mut scratch).unwrap();
        let reference = m.forward_indexed_with(&batch, &mut ForwardScratch::new()).unwrap();
        assert_eq!(out, reference, "rows={n_rows}");
        for r in 0..n_rows {
            let fired = out.fired_row(r);
            assert!(fired[0], "vacuous clause fires on row {r}");
            assert!(!fired[2], "unflagged clause never fires on row {r}");
            assert!(!fired[3], "dead clause never fires on row {r}");
        }
    }
}

#[test]
fn prop_sliced_ties_resolve_to_the_lowest_class_index() {
    // Duplicated class blocks guarantee cross-class ties; the sliced
    // argmax (expanded from the CSA counters per lane) must break them
    // exactly like jnp.argmax — lowest index wins.
    prop::check("sliced argmax tie convention", 60, |g| {
        let f = g.int(1, 40) as usize;
        let cpc = g.int(1, 10) as usize;
        let k = g.int(1, 4) as usize;
        let base = random_model_shaped(g, k, cpc, f, g.float(0.0, 0.4));
        let include: Vec<Vec<bool>> =
            base.include.iter().chain(base.include.iter()).cloned().collect();
        let polarity: Vec<i8> =
            base.polarity.iter().chain(base.polarity.iter()).copied().collect();
        let tied = TmModel::assemble_derived("tied".into(), 2 * k, f, cpc, include, polarity, 0.0);
        let rows: Vec<Vec<bool>> = (0..70).map(|_| g.bits(f, 0.5)).collect();
        let batch = PackedBatch::from_rows(&rows).unwrap();
        let out = tied.forward_sliced_with(&batch, &mut ForwardScratch::new()).unwrap();
        for r in 0..rows.len() {
            let sums = out.sums_row(r);
            let top = *sums.iter().max().unwrap();
            let first_top = sums.iter().position(|&s| s == top).unwrap();
            assert_eq!(out.pred[r] as usize, first_top, "row {r} broke the tie convention");
            assert_eq!(
                sums[out.pred[r] as usize],
                sums[out.pred[r] as usize + k],
                "row {r}: duplicated blocks must actually tie"
            );
        }
    });
}

#[test]
fn sharded_partials_slice_cleanly_and_merge_to_the_unsharded_forward() {
    // Per-shard slot ranges through the sliced engine: each shard's
    // sliced partial must equal its indexed partial bit for bit, and the
    // merged sliced partials must equal the unsharded forward.
    let mut rng = SplitMix64::new(0x5a4d);
    for &(k, cpc) in &[(3usize, 21usize), (1, 127)] {
        for &n_shards in &[2usize, 3, 7] {
            let model = Arc::new(TmModel::synthetic(
                &format!("shard_k{k}x{cpc}_s{n_shards}"),
                k,
                cpc,
                65,
                0.25,
                (k * cpc * n_shards) as u64,
            ));
            let rows = random_rows(&mut rng, 100, model.n_features);
            let batch = PackedBatch::from_rows(&rows).unwrap();
            let full = model.forward_packed(&batch).unwrap();
            let shards = ClauseShard::split(&model, n_shards).unwrap();
            let mut sliced_parts = Vec::new();
            for shard in &shards {
                let mut sliced = PartialOutput::empty(
                    model.n_classes,
                    model.c_total(),
                    shard.index(),
                    shard.n_shards(),
                );
                let mut indexed = PartialOutput::empty(
                    model.n_classes,
                    model.c_total(),
                    shard.index(),
                    shard.n_shards(),
                );
                let mut scratch = ForwardScratch::new();
                shard.partial_sliced_into(&batch, &mut scratch, &mut sliced).unwrap();
                shard
                    .partial_indexed_into(&batch, &mut ForwardScratch::new(), &mut indexed)
                    .unwrap();
                assert_eq!(
                    sliced, indexed,
                    "k={k} cpc={cpc} n_shards={n_shards} shard={}",
                    shard.index()
                );
                assert_eq!(scratch.sliced_groups, 2, "100 rows = 2 groups per shard");
                sliced_parts.push(sliced);
            }
            let merged = merge_partials(&sliced_parts).unwrap();
            assert_eq!(merged, full, "k={k} cpc={cpc} n_shards={n_shards}: merged");
        }
    }
}

#[test]
fn scratch_reuse_across_engines_and_shapes_is_equivalent_to_fresh() {
    // One long-lived scratch alternating between sliced and indexed
    // batches of different model shapes — the worker lifecycle once the
    // dispatcher starts flipping engines per batch size.
    let m1 = TmModel::synthetic("mix1", 3, 21, 31, 0.2, 1);
    let m2 = TmModel::synthetic("mix2", 5, 13, 65, 0.1, 2);
    let mut shared = ForwardScratch::new();
    let mut rng = SplitMix64::new(0x5eed);
    let mut sliced_rows = 0u64;
    for round in 0..8 {
        let m = if round % 2 == 0 { &m1 } else { &m2 };
        let n_rows = if round % 3 == 0 { 80 } else { 5 };
        let rows = random_rows(&mut rng, n_rows, m.n_features);
        let batch = PackedBatch::from_rows(&rows).unwrap();
        let reused = m.forward_packed_with(&batch, &mut shared).unwrap();
        let fresh = m.forward_packed(&batch).unwrap();
        assert_eq!(reused, fresh, "round {round}");
        if n_rows >= SLICED_MIN_ROWS {
            sliced_rows += n_rows as u64;
        }
    }
    assert_eq!(shared.sliced_rows, sliced_rows, "sliced telemetry across reuse");
}
