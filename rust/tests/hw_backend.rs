//! HwBackend functional-agreement property suite.
//!
//! The contract (documented in `crate::hw`): `HwBackend` predictions are
//! the native packed forward pass, so `pred` is bit-identical to
//! `NativeBackend` on every input; the *hardware winner* additionally
//! matches except where the contract says it may not —
//!
//! * synchronous engines (adder, fpt18) resolve argmax ties to the lowest
//!   class index, exactly like the functional path: bit-exact agreement
//!   on every row, ties included;
//! * the async engine resolves ties by an arbiter race, so it may
//!   disagree on exact class-sum ties; and its PDL arrival physically
//!   encodes `neg_count + sum`, so an *odd* clauses/class (which leaves
//!   classes with ±1 different negative-clause counts under the
//!   alternating convention) may additionally bias a margin-1 decision by
//!   one vote. At margin ≥ 2 — and everywhere, for balanced shapes — the
//!   async winner must equal the functional argmax.
//!
//! The engines run on an *ideal* (zero-variation) flow so the contract is
//! deterministic; variation robustness is table1's delay-tuning concern,
//! not this suite's. Exercised across word-boundary shapes: features
//! f ∈ {63, 64, 65} and total clause counts c_total ∈ {63, 64, 65, 127}.

use std::path::Path;
use std::sync::Arc;

use tdpc::flow::FlowConfig;
use tdpc::hw::HwArch;
use tdpc::runtime::{BackendSpec, InferenceBackend, NativeBackend};
use tdpc::tm::{PackedBatch, TmModel};
use tdpc::util::{Ps, SplitMix64};

/// (n_classes, clauses_per_class, n_features): c_total ∈ {63, 64, 65, 127},
/// f ∈ {63, 64, 65} — every shape straddles a u64 word edge somewhere.
const SHAPES: [(usize, usize, usize); 4] =
    [(3, 21, 63), (2, 32, 64), (5, 13, 65), (1, 127, 64)];

fn rows(n: usize, f: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..f).map(|_| rng.next_bool(0.5)).collect()).collect()
}

fn hw_backend(arch: HwArch, model: Arc<TmModel>) -> Box<dyn InferenceBackend> {
    let name = model.name.clone();
    // Ideal flow: Table-I nominal delays, zero process variation — the
    // margin contract below is then exact rather than statistical.
    BackendSpec::TimeDomain { arch, flow: FlowConfig::ideal(Ps(380), Ps(618)), model: Some(model) }
        .open(Path::new("/nonexistent"), &name)
        .unwrap()
}

#[test]
fn hw_backend_agrees_with_native_across_word_boundary_shapes() {
    for (k, cpc, f) in SHAPES {
        let model = Arc::new(TmModel::synthetic(
            "agree",
            k,
            cpc,
            f,
            0.12,
            (k * 1000 + cpc * 10 + f) as u64,
        ));
        let native = NativeBackend::new(model.clone());
        let inputs = rows(24, f, 97);
        let batch = PackedBatch::from_rows(&inputs).unwrap();
        let reference = native.forward(&batch).unwrap();

        for arch in HwArch::ALL {
            let hw = hw_backend(arch, model.clone());
            let out = hw.forward(&batch).unwrap();
            // Functional results: the same packed forward pass, bit-exact
            // (sums, fired bits, and predictions all identical).
            assert_eq!(out, reference, "k={k} cpc={cpc} f={f} {arch:?}");

            for i in 0..out.batch {
                let o = hw.replay(&out, i).expect("hw backend always replays");
                let sums = out.sums_row(i);
                let top = *sums.iter().max().unwrap();
                let tied = sums.iter().filter(|&&s| s == top).count() > 1;
                let pred = out.pred[i] as usize;
                match arch {
                    // Sync engines: lowest-index tie-break = functional
                    // argmax, so agreement is unconditional.
                    HwArch::Adder | HwArch::Fpt18 => assert_eq!(
                        o.winner, pred,
                        "k={k} cpc={cpc} f={f} {arch:?} row {i} sums {sums:?}"
                    ),
                    // Async engine: exact except ties for balanced
                    // polarity; odd clauses/class (unequal negative
                    // counts) may bias a margin-1 race by one vote.
                    HwArch::Async => {
                        let balanced = cpc % 2 == 0 || k == 1;
                        let margin2 =
                            sums.iter().filter(|&&s| s >= top - 1).count() == 1;
                        if balanced && !tied {
                            assert_eq!(
                                o.winner, pred,
                                "k={k} cpc={cpc} f={f} row {i} sums {sums:?}"
                            );
                        } else if !balanced && margin2 {
                            assert_eq!(
                                o.winner, pred,
                                "k={k} cpc={cpc} f={f} row {i} sums {sums:?} (margin ≥ 2)"
                            );
                        } else {
                            assert!(
                                sums[o.winner] >= top - 1,
                                "k={k} cpc={cpc} f={f} row {i}: winner within one \
                                 vote of the maximum, sums {sums:?}"
                            );
                        }
                    }
                }
                assert!(
                    o.decision_latency <= o.cycle_latency,
                    "k={k} cpc={cpc} f={f} {arch:?} row {i}"
                );
            }
        }
    }
}

#[test]
fn replay_is_deterministic_for_sync_engines() {
    // Replaying the same forward output twice through a fresh sync
    // backend yields identical outcomes (no hidden RNG on the sync path).
    let (k, cpc, f) = SHAPES[1];
    let model = Arc::new(TmModel::synthetic("agree", k, cpc, f, 0.12, 5));
    let batch = PackedBatch::from_rows(&rows(8, f, 3)).unwrap();
    for arch in [HwArch::Adder, HwArch::Fpt18] {
        let a = hw_backend(arch, model.clone());
        let b = hw_backend(arch, model.clone());
        let out = a.forward(&batch).unwrap();
        for i in 0..out.batch {
            assert_eq!(a.replay(&out, i), b.replay(&out, i), "{arch:?} row {i}");
        }
    }
}
