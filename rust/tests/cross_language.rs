//! Cross-language determinism: the Rust SplitMix64 must emit the same
//! stream as `python/compile/tm/datasets.py::SplitMix64` (pinned in
//! `python/tests/test_cross_language.py` against the same constants),
//! and the native backend must honour the jnp conventions the Python
//! oracle bakes into the golden vectors (argmax ties → lowest index,
//! empty clauses never fire).

use std::sync::Arc;

use tdpc::runtime::{InferenceBackend, NativeBackend};
use tdpc::tm::{PackedBatch, TmModel};
use tdpc::util::SplitMix64;

#[test]
fn pinned_u64_stream() {
    let mut r = SplitMix64::new(1234567);
    assert_eq!(
        [r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64()],
        [
            6457827717110365317,
            3203168211198807973,
            9817491932198370423,
            4593380528125082431,
        ]
    );
}

#[test]
fn pinned_f64_stream() {
    let mut r = SplitMix64::new(0xDEAD);
    assert_eq!(r.next_f64(), 0.13048625271529091);
    assert_eq!(r.next_f64(), 0.65448148162553266);
    assert_eq!(r.next_f64(), 0.017882184589982808);
}

#[test]
fn pinned_gauss_stream() {
    let mut r = SplitMix64::new(42);
    let g = [r.next_gauss(), r.next_gauss(), r.next_gauss()];
    let expect = [0.41471975043153059, -0.89188621362775633, 1.7295930879374024];
    for (a, b) in g.iter().zip(expect) {
        assert!((a - b).abs() < 1e-14, "{a} vs {b}");
    }
}

#[test]
fn native_backend_honours_jnp_conventions() {
    // 2 classes × 2 clauses over 2 features. Class 0: +x0, −x1;
    // class 1: +~x0, and one empty clause (never fires, like the oracle).
    let model = Arc::new(TmModel::assemble(
        "conv".into(),
        2,
        2,
        2,
        vec![
            vec![true, false, false, false],  // x0
            vec![false, true, false, false],  // x1
            vec![false, false, true, false],  // ~x0
            vec![false, false, false, false], // empty
        ],
        vec![1, -1, 1, -1],
        vec![true, true, true, false],
        100.0,
    ));
    let backend = NativeBackend::new(model);
    // x = [1, 1]: sums tie at (0, 0) → jnp.argmax picks class 0.
    let out = backend.forward(&PackedBatch::single(&[true, true])).unwrap();
    assert_eq!(out.sums_row(0), &[0, 0]);
    assert_eq!(out.pred[0], 0, "tie must resolve to the lowest index (jnp.argmax)");
    // x = [0, 0]: only ~x0 fires → class 1 wins; the empty clause stayed
    // silent even though all of its (zero) literals are satisfied.
    let out = backend.forward(&PackedBatch::single(&[false, false])).unwrap();
    assert_eq!(out.sums_row(0), &[0, 1]);
    assert_eq!(out.pred[0], 1);
    assert_eq!(out.fired_row(0), vec![false, false, true, false]);
}
