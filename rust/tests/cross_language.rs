//! Cross-language determinism: the Rust SplitMix64 must emit the same
//! stream as `python/compile/tm/datasets.py::SplitMix64` (pinned in
//! `python/tests/test_cross_language.py` against the same constants).

use tdpc::util::SplitMix64;

#[test]
fn pinned_u64_stream() {
    let mut r = SplitMix64::new(1234567);
    assert_eq!(
        [r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64()],
        [
            6457827717110365317,
            3203168211198807973,
            9817491932198370423,
            4593380528125082431,
        ]
    );
}

#[test]
fn pinned_f64_stream() {
    let mut r = SplitMix64::new(0xDEAD);
    assert_eq!(r.next_f64(), 0.13048625271529091);
    assert_eq!(r.next_f64(), 0.65448148162553266);
    assert_eq!(r.next_f64(), 0.017882184589982808);
}

#[test]
fn pinned_gauss_stream() {
    let mut r = SplitMix64::new(42);
    let g = [r.next_gauss(), r.next_gauss(), r.next_gauss()];
    let expect = [0.41471975043153059, -0.89188621362775633, 1.7295930879374024];
    for (a, b) in g.iter().zip(expect) {
        assert!((a - b).abs() < 1e-14, "{a} vs {b}");
    }
}
