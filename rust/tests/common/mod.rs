//! Shared integration-test helpers: the golden-vector loader for the
//! artifacts `python/compile/aot.py` emits (used by both the native and
//! PJRT backend golden suites).

use tdpc::tm::parse_bits;
use tdpc::util::json;

pub struct Golden {
    pub inputs: Vec<Vec<bool>>,
    pub sums: Vec<Vec<i32>>,
    pub fired: Vec<Vec<bool>>,
    pub pred: Vec<i32>,
}

pub fn load_golden(path: &std::path::Path) -> Golden {
    let doc = json::parse_file(path).unwrap();
    let inputs = doc
        .get("inputs").unwrap().as_arr().unwrap()
        .iter().map(|v| parse_bits(v.as_str().unwrap()).unwrap()).collect();
    let sums = doc
        .get("sums").unwrap().as_arr().unwrap()
        .iter()
        .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_i64().unwrap() as i32).collect())
        .collect();
    let fired = doc
        .get("fired").unwrap().as_arr().unwrap()
        .iter().map(|v| parse_bits(v.as_str().unwrap()).unwrap()).collect();
    let pred = doc
        .get("pred").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_i64().unwrap() as i32).collect();
    Golden { inputs, sums, fired, pred }
}
