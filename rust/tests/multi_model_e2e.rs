//! Multi-model serving e2e: one pool routing, batching, and hot-swapping
//! several TM models.
//!
//! Covers the model-keyed refactor's acceptance invariants:
//! * a mixed pool serving two models of different feature widths / class
//!   counts produces **bit-identical** predictions to two dedicated
//!   single-model pools, with zero mixed-width batches ever formed;
//! * per-model metrics (`metrics_for`) sum exactly to the pool totals;
//! * `reload` under live traffic loses zero requests — every reply is
//!   the old or the new generation's prediction, never an error — and a
//!   failed reload leaves the previous generation serving;
//! * unregistered models are answered with a typed `UnknownModel`.
//!
//! The artifact-free pools run on `BackendSpec::InMemorySet`; the
//! hot-swap tests write real artifacts (`Manifest::write_synthetic`) to
//! a temp dir so the registry's invalidate + re-open path reads a
//! genuinely rewritten file.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, InferError, ReplayPolicy,
    ShedPolicy,
};
use tdpc::runtime::BackendSpec;
use tdpc::tm::{Manifest, TmModel};
use tdpc::util::SplitMix64;

/// Two tenants whose widths straddle a u64 word boundary (63 vs 65
/// features) and whose class counts differ — any batch that mixed them
/// would fail loudly.
fn model_a() -> Arc<TmModel> {
    Arc::new(TmModel::synthetic("tenant_a", 3, 11, 63, 0.2, 101))
}

fn model_b() -> Arc<TmModel> {
    Arc::new(TmModel::synthetic("tenant_b", 2, 9, 65, 0.25, 202))
}

fn inputs_for(model: &TmModel, n: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..model.n_features).map(|_| rng.next_bool(0.5)).collect()).collect()
}

fn unused_root() -> PathBuf {
    PathBuf::from("/nonexistent-artifacts-root")
}

fn set_spec() -> BackendSpec {
    BackendSpec::InMemorySet(Arc::new(vec![model_a(), model_b()]))
}

fn pool_config(n_workers: usize, backend: BackendSpec) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(300) },
        n_workers,
        dispatch: DispatchPolicy::RoundRobin,
        backend,
        replay: ReplayPolicy::Off,
        queue_limit: None,
        shed: ShedPolicy::RejectNew,
        ..CoordinatorConfig::default()
    }
}

fn tmp_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tdpc-mm-{tag}-{}", std::process::id()))
}

/// The tentpole acceptance path: a 4-worker pool serving two models of
/// different widths under interleaved burst load. Every response must
/// match that model's own golden — and be bit-identical to what two
/// dedicated single-model pools produce — with `failed_batches == 0`
/// and `rejected_requests == 0` (a mixed-width batch would surface as
/// one or the other), and the per-model metrics must sum to the pool
/// totals.
#[test]
fn mixed_pool_matches_dedicated_single_model_pools() {
    let (a, b) = (model_a(), model_b());
    let n_each = 150;
    let xa = inputs_for(&a, n_each, 1);
    let xb = inputs_for(&b, n_each, 2);

    let names = ["tenant_a", "tenant_b"];
    let coord =
        Coordinator::start_multi(unused_root(), &names, pool_config(4, set_spec())).unwrap();
    let mid_a = coord.model_id("tenant_a").unwrap();
    let mid_b = coord.model_id("tenant_b").unwrap();
    assert_ne!(mid_a, mid_b);
    assert_eq!(coord.n_features_for(mid_a), Some(63));
    assert_eq!(coord.n_features_for(mid_b), Some(65));
    assert_eq!(
        coord.served_models().map(|(_, n)| n.to_string()).collect::<Vec<_>>(),
        vec!["tenant_a", "tenant_b"]
    );

    // Interleaved open-loop burst: submissions alternate models, so both
    // tenants are pending in every worker at once.
    let (tx, rx) = mpsc::channel();
    for i in 0..n_each {
        coord.submit(mid_a, &xa[i], tx.clone());
        coord.submit(mid_b, &xb[i], tx.clone());
    }
    drop(tx);
    let responses: Vec<_> =
        rx.iter().map(|r| r.expect("no request may fail in a healthy mixed pool")).collect();
    assert_eq!(responses.len(), 2 * n_each);

    // Dedicated single-model pools over the same inputs, for the
    // bit-identical comparison.
    let solo_a = Coordinator::start(
        unused_root(),
        "tenant_a",
        pool_config(4, BackendSpec::InMemory(a.clone())),
    )
    .unwrap();
    let solo_b = Coordinator::start(
        unused_root(),
        "tenant_b",
        pool_config(4, BackendSpec::InMemory(b.clone())),
    )
    .unwrap();
    let sid_a = solo_a.model_id("tenant_a").unwrap();
    let sid_b = solo_b.model_id("tenant_b").unwrap();

    for r in &responses {
        // Ids alternate a,b in submission order: even → a, odd → b.
        let round = (r.request_id / 2) as usize;
        let (x, solo, sid, model) = if r.model == mid_a {
            (&xa[round], &solo_a, sid_a, &a)
        } else {
            assert_eq!(r.model, mid_b);
            (&xb[round], &solo_b, sid_b, &b)
        };
        assert_eq!(r.pred, model.predict(x), "request {}", r.request_id);
        assert_eq!(r.sums, model.class_sums(x), "request {}", r.request_id);
        let solo_resp = solo.infer_blocking(sid, x).unwrap();
        assert_eq!(r.pred, solo_resp.pred, "mixed pool diverged from dedicated pool");
        assert_eq!(r.sums, solo_resp.sums, "mixed pool diverged from dedicated pool");
        assert_eq!(r.generation, 0, "no reload happened");
    }
    solo_a.shutdown();
    solo_b.shutdown();

    // No mixed-width batch can have formed: assembly rejections or
    // forward failures would have counted it.
    let pool = coord.metrics();
    assert_eq!(pool.failed_batches, 0, "a mixed-width batch would fail its forward pass");
    assert_eq!(pool.rejected_requests, 0, "a mixed-width batch would reject at assembly");
    assert_eq!(pool.requests, 2 * n_each as u64);

    // Per-model metrics sum exactly to the pool totals.
    let ma = coord.metrics_for(mid_a).unwrap();
    let mb = coord.metrics_for(mid_b).unwrap();
    assert_eq!(ma.requests, n_each as u64);
    assert_eq!(mb.requests, n_each as u64);
    assert_eq!(ma.requests + mb.requests, pool.requests);
    assert_eq!(ma.batches + mb.batches, pool.batches);
    assert_eq!(ma.shed_requests + mb.shed_requests, pool.shed_requests);
    assert_eq!(ma.failed_batches + mb.failed_batches, pool.failed_batches);
    assert!(ma.batches >= 1 && mb.batches >= 1);
    assert!(
        ma.service_p50_us > 0.0 && mb.service_p50_us > 0.0,
        "per-model latency percentiles are populated"
    );
    // Per-worker snapshots cover the same traffic along the other axis.
    let per_worker = coord.worker_metrics();
    assert_eq!(per_worker.iter().map(|w| w.requests).sum::<u64>(), pool.requests);
    assert_eq!(per_worker.iter().map(|w| w.batches).sum::<u64>(), pool.batches);
    coord.shutdown();
}

/// Per-model admission: the width gate checks the *request's* model, so
/// a row of the other tenant's width is rejected with that model's
/// expected width, and the rejection is attributed to the right tenant.
#[test]
fn width_gate_is_per_model() {
    let names = ["tenant_a", "tenant_b"];
    let coord =
        Coordinator::start_multi(unused_root(), &names, pool_config(1, set_spec())).unwrap();
    let mid_a = coord.model_id("tenant_a").unwrap();
    let mid_b = coord.model_id("tenant_b").unwrap();

    // A 65-wide row is valid for B but not for A.
    let row = vec![true; 65];
    let err = coord.infer_blocking(mid_a, &row).unwrap_err();
    assert_eq!(
        err.downcast_ref::<InferError>(),
        Some(&InferError::WidthMismatch { got: 65, expected: 63 })
    );
    let resp = coord.infer_blocking(mid_b, &row).unwrap();
    assert_eq!(resp.pred, model_b().predict(&row));

    assert_eq!(coord.metrics_for(mid_a).unwrap().rejected_requests, 1);
    assert_eq!(coord.metrics_for(mid_b).unwrap().rejected_requests, 0);
    assert_eq!(coord.metrics().rejected_requests, 1);
    coord.shutdown();
}

/// Unregistered names and foreign/stale ids are answered with a typed
/// `UnknownModel` — exactly one reply, never a dead channel.
#[test]
fn unknown_model_is_a_typed_error() {
    let names = ["tenant_a", "tenant_b"];
    let coord =
        Coordinator::start_multi(unused_root(), &names, pool_config(1, set_spec())).unwrap();
    assert_eq!(coord.model_id("ghost"), None);

    let (tx, rx) = mpsc::channel();
    coord.submit_named("ghost", &[true; 63], tx);
    match rx.recv().unwrap() {
        Err(InferError::UnknownModel { name }) => assert_eq!(name, "ghost"),
        other => panic!("expected UnknownModel, got {other:?}"),
    }

    // A ModelId minted by a *different* pool does not resolve here even
    // when its index is in range for this pool: ids are pool-tagged, so
    // a cross-pool mixup is a typed UnknownModel, never a silent
    // misroute to whatever model occupies that index.
    let other = Coordinator::start_multi(
        unused_root(),
        &["tenant_a", "tenant_b"],
        pool_config(1, set_spec()),
    )
    .unwrap();
    let foreign = other.model_id("tenant_a").unwrap();
    assert_eq!(foreign.index(), 0, "in range for `coord`, yet still foreign");
    assert_ne!(foreign, coord.model_id("tenant_a").unwrap());
    assert_eq!(coord.n_features_for(foreign), None);
    let err = coord.infer_blocking(foreign, &[true; 63]).unwrap_err();
    match err.downcast_ref::<InferError>() {
        Some(InferError::UnknownModel { .. }) => {}
        otherwise => panic!("expected UnknownModel, got {otherwise:?}"),
    }
    // submit_named still resolves real tenants.
    let mid_a = coord.model_id("tenant_a").unwrap();
    let (tx, rx) = mpsc::channel();
    let id = coord.submit_named("tenant_a", &inputs_for(&model_a(), 1, 9)[0], tx);
    let resp = rx.recv().unwrap().unwrap();
    assert_eq!((resp.request_id, resp.model), (id, mid_a));
    other.shutdown();
    coord.shutdown();
}

/// The hot-swap acceptance path, against *real* on-disk artifacts:
/// a retrained artifact replaces the served one under concurrent
/// submits, and zero requests are lost — every reply is the old or the
/// new generation's prediction (both goldens computed in-test), never
/// an error. Rows submitted before the reload are served by generation
/// 0; rows submitted after `reload` returns by generation 1; rows
/// racing the reload by whichever generation computed them.
#[test]
fn hot_swap_reload_loses_zero_requests() {
    let root = tmp_root("hotswap");
    let v1 = TmModel::synthetic("swap", 3, 8, 20, 0.2, 1);
    let v2 = TmModel::synthetic("swap", 3, 8, 20, 0.2, 2);
    Manifest::write_synthetic(&root, &[&v1]).unwrap();

    let n_phase = 150;
    let inputs = inputs_for(&v1, 3 * n_phase, 5);
    // The swap must be observable: at least one input where the
    // generations disagree.
    assert!(
        inputs.iter().any(|x| v1.predict(x) != v2.predict(x)),
        "seeded models must disagree somewhere"
    );

    let coord =
        Coordinator::start_multi(root.clone(), &["swap"], pool_config(4, BackendSpec::Native))
            .unwrap();
    let mid = coord.model_id("swap").unwrap();

    let (tx, rx) = mpsc::channel();
    // Phase 1: submitted (and therefore enqueued) before the reload —
    // every worker flushes these against generation 0 before swapping.
    for x in &inputs[..n_phase] {
        coord.submit(mid, x, tx.clone());
    }
    // Rewrite the artifact on disk, then hot-swap while phase-1 rows are
    // still in flight and a concurrent submitter keeps the traffic
    // continuous through the swap window.
    Manifest::write_synthetic(&root, &[&v2]).unwrap();
    std::thread::scope(|s| {
        let coord = &coord;
        let racing = &inputs[n_phase..2 * n_phase];
        let tx2 = tx.clone();
        s.spawn(move || {
            for x in racing {
                coord.submit(mid, x, tx2.clone());
            }
        });
        coord.reload(mid).unwrap();
    });
    // Phase 3: strictly after the reload returned — all workers have
    // swapped, so these must be generation 1.
    for x in &inputs[2 * n_phase..] {
        coord.submit(mid, x, tx.clone());
    }
    drop(tx);

    let replies: Vec<_> = rx.iter().collect();
    assert_eq!(replies.len(), 3 * n_phase, "zero requests lost across the swap");
    let mut gen0 = 0usize;
    let mut gen1 = 0usize;
    for reply in replies {
        let resp = reply.expect("every reply is a prediction, never an error");
        let i = resp.request_id as usize;
        let want = match resp.generation {
            0 => {
                gen0 += 1;
                v1.predict(&inputs[i])
            }
            1 => {
                gen1 += 1;
                v2.predict(&inputs[i])
            }
            g => panic!("impossible generation {g}"),
        };
        assert_eq!(resp.pred, want, "request {i} (generation {})", resp.generation);
        if i < n_phase {
            assert_eq!(resp.generation, 0, "pre-reload rows drain against the old backend");
        }
        if i >= 2 * n_phase {
            assert_eq!(resp.generation, 1, "post-reload rows meet the new backend");
        }
    }
    assert!(gen0 >= n_phase && gen1 >= n_phase, "both generations actually served");
    assert_eq!(coord.metrics().requests, 3 * n_phase as u64);
    assert_eq!(coord.metrics().failed_batches, 0);

    // The pool stays on the new generation afterwards.
    let resp = coord.infer_blocking(mid, &inputs[0]).unwrap();
    assert_eq!((resp.generation, resp.pred), (1, v2.predict(&inputs[0])));
    coord.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// Reload is fail-soft: if the rewritten artifact is corrupt, `reload`
/// returns the error and every worker keeps serving the previous
/// generation; fixing the artifact and retrying converges.
#[test]
fn failed_reload_keeps_previous_generation_serving() {
    let root = tmp_root("badswap");
    let v1 = TmModel::synthetic("swap", 2, 6, 16, 0.25, 3);
    let v3 = TmModel::synthetic("swap", 2, 6, 16, 0.25, 4);
    Manifest::write_synthetic(&root, &[&v1]).unwrap();

    let coord =
        Coordinator::start_multi(root.clone(), &["swap"], pool_config(2, BackendSpec::Native))
            .unwrap();
    let mid = coord.model_id("swap").unwrap();
    let xs = inputs_for(&v1, 8, 6);

    // Corrupt the artifact: the swap must fail and change nothing.
    std::fs::write(root.join("models").join("swap.json"), "{ this is not json").unwrap();
    let err = coord.reload(mid).unwrap_err().to_string();
    assert!(err.contains("swap"), "actionable reload error, got {err}");
    for x in &xs {
        let resp = coord.infer_blocking(mid, x).unwrap();
        assert_eq!(
            (resp.generation, resp.pred),
            (0, v1.predict(x)),
            "previous generation keeps serving after a failed reload"
        );
    }

    // Fix the artifact: the retry converges onto the newest generation
    // (the failed attempt consumed generation 1).
    Manifest::write_synthetic(&root, &[&v3]).unwrap();
    coord.reload(mid).unwrap();
    for x in &xs {
        let resp = coord.infer_blocking(mid, x).unwrap();
        assert_eq!((resp.generation, resp.pred), (2, v3.predict(x)));
    }
    coord.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// Reload also works on artifact-free in-memory pools (the set spec
/// rebuilds the same model): generations advance, predictions stay.
#[test]
fn reload_on_in_memory_pool_bumps_generation() {
    let names = ["tenant_a", "tenant_b"];
    let coord =
        Coordinator::start_multi(unused_root(), &names, pool_config(2, set_spec())).unwrap();
    let mid_a = coord.model_id("tenant_a").unwrap();
    let mid_b = coord.model_id("tenant_b").unwrap();
    let (a, b) = (model_a(), model_b());
    let xa = inputs_for(&a, 4, 11);
    let xb = inputs_for(&b, 4, 12);

    coord.reload(mid_a).unwrap();
    for x in &xa {
        let resp = coord.infer_blocking(mid_a, x).unwrap();
        assert_eq!((resp.generation, resp.pred), (1, a.predict(x)));
    }
    // Tenant B is untouched by A's reload.
    for x in &xb {
        let resp = coord.infer_blocking(mid_b, x).unwrap();
        assert_eq!((resp.generation, resp.pred), (0, b.predict(x)));
    }
    coord.shutdown();
}
