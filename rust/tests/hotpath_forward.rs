//! Hot-loop property suites: the clause-indexed scan, the chunked full
//! scan, and the early-exit argmax must all be bit-exact with the naive
//! bool-wise reference (`TmModel::forward_reference`) — across word-
//! boundary shapes, degenerate models, and reindexing.
//!
//! Shapes deliberately straddle `u64` word edges: feature counts
//! f ∈ {31, 63, 64, 65} (literal vectors of 62/126/128/130 bits) crossed
//! with clause totals c_total ∈ {63, 64, 65, 127} (fired words with
//! partial tails and word-unaligned class boundaries).

use tdpc::tm::{bits, ForwardScratch, PackedBatch, TmModel};
use tdpc::util::prop;

/// (n_classes, clauses_per_class) pairs hitting the mandated word-edge
/// clause totals 63 / 64 / 65 / 127.
const CLAUSE_SHAPES: [(usize, usize); 4] = [(3, 21), (4, 16), (5, 13), (1, 127)];
const FEATURES: [usize; 4] = [31, 63, 64, 65];

fn random_model_shaped(g: &mut prop::Gen, k: usize, cpc: usize, f: usize, dens: f64) -> TmModel {
    let c_total = k * cpc;
    let include: Vec<Vec<bool>> = (0..c_total).map(|_| g.bits(2 * f, dens)).collect();
    let polarity: Vec<i8> = (0..c_total).map(|_| if g.boolean(0.5) { 1 } else { -1 }).collect();
    TmModel::assemble_derived("prop".into(), k, f, cpc, include, polarity, 0.0)
}

/// Every evaluation path vs the reference, on one model + rows: the
/// scalar scan, the chunked scan, the indexed scan, the batched forward,
/// and the early-exit argmax.
fn assert_all_paths_match(model: &TmModel, rows: &[Vec<bool>], ctx: &str) {
    let batch = PackedBatch::from_rows(rows).unwrap();
    let mut scratch = ForwardScratch::new();
    let out = model.forward_packed_with(&batch, &mut scratch).unwrap();
    let preds = model.predict_packed(&batch).unwrap();
    let n_words = bits::words_for(model.c_total());
    let (mut scalar, mut chunked, mut indexed) =
        (vec![0u64; n_words], vec![0u64; n_words], vec![0u64; n_words]);
    for (i, row) in rows.iter().enumerate() {
        let (fired_ref, sums_ref, pred_ref) = model.forward_reference(row);
        assert_eq!(out.fired_row(i), fired_ref, "{ctx}: fired, row {i}");
        assert_eq!(out.sums_row(i), &sums_ref[..], "{ctx}: sums, row {i}");
        assert_eq!(out.pred[i] as usize, pred_ref, "{ctx}: pred, row {i}");
        assert_eq!(preds[i], out.pred[i], "{ctx}: early-exit pred, row {i}");
        let lits = model.packed_literals(batch.row(i));
        model.fired_words_into_scalar(lits.words(), &mut scalar);
        model.fired_words_into(lits.words(), &mut chunked);
        model.fired_words_into_indexed(lits.words(), &mut indexed);
        assert_eq!(scalar, chunked, "{ctx}: scalar vs chunked, row {i}");
        assert_eq!(scalar, indexed, "{ctx}: scalar vs indexed, row {i}");
        assert_eq!(out.fired_words_row(i), &scalar[..], "{ctx}: forward fired, row {i}");
    }
    assert_eq!(scratch.rows as usize, rows.len(), "{ctx}: scratch row telemetry");
    assert_eq!(
        scratch.clauses_eligible as usize,
        rows.len() * model.c_total(),
        "{ctx}: scratch eligible telemetry"
    );
}

#[test]
fn prop_all_paths_match_reference_at_word_boundaries() {
    prop::check("hot-loop paths at word-boundary shapes", 80, |g| {
        let f = *g.choose(&FEATURES);
        let &(k, cpc) = g.choose(&CLAUSE_SHAPES);
        let density = g.float(0.0, 0.4);
        let model = random_model_shaped(g, k, cpc, f, density);
        let n_rows = g.int(1, 5) as usize;
        let rows: Vec<Vec<bool>> = (0..n_rows).map(|_| g.bits(f, 0.5)).collect();
        assert_all_paths_match(&model, &rows, &format!("k={k} cpc={cpc} f={f}"));
    });
}

#[test]
fn degenerate_all_empty_and_all_include_models() {
    for &(k, cpc) in &CLAUSE_SHAPES {
        for &f in &FEATURES {
            let c_total = k * cpc;
            // All-empty: every clause is dead (derived nonempty=false),
            // nothing ever fires, every class sums to 0, pred = 0.
            let empty = TmModel::assemble_derived(
                "empty".into(),
                k,
                f,
                cpc,
                vec![vec![false; 2 * f]; c_total],
                vec![1; c_total],
                0.0,
            );
            let stats = empty.index_stats();
            assert_eq!((stats.indexed, stats.fallback), (0, 0), "dead clauses get no slots");
            // All-include: a clause fires only when every literal is 1 —
            // impossible for f ≥ 1 (x and ~x can't both be 1).
            let full = TmModel::assemble_derived(
                "full".into(),
                k,
                f,
                cpc,
                vec![vec![true; 2 * f]; c_total],
                vec![1; c_total],
                0.0,
            );
            assert_eq!(full.index_stats().indexed, c_total);
            let rows = vec![vec![false; f], vec![true; f]];
            assert_all_paths_match(&empty, &rows, &format!("all-empty k={k} cpc={cpc} f={f}"));
            assert_all_paths_match(&full, &rows, &format!("all-include k={k} cpc={cpc} f={f}"));
            let out = empty.forward_packed(&PackedBatch::from_rows(&rows).unwrap()).unwrap();
            assert!(out.sums.iter().all(|&s| s == 0));
            assert!(out.pred.iter().all(|&p| p == 0));
        }
    }
}

#[test]
fn vacuous_nonempty_flag_is_authoritative_through_every_path() {
    // Direct `assemble` with a lying-but-authoritative nonempty flag: a
    // flagged clause with an all-false mask fires on every sample (it
    // must live in the index's fallback bucket), and an unflagged clause
    // with a real mask never fires (it gets no scan slot at all).
    let f = 64usize; // literal vector exactly 2 words
    let include = vec![
        vec![false; 2 * f],                                // vacuous, flagged
        (0..2 * f).map(|i| i == 0).collect::<Vec<bool>>(), // real, flagged
        (0..2 * f).map(|i| i == 1).collect::<Vec<bool>>(), // real, UNflagged
        vec![false; 2 * f],                                // dead
    ];
    let m = TmModel::assemble(
        "vacuous".into(),
        2,
        f,
        2,
        include,
        vec![1, -1, 1, -1],
        vec![true, true, false, false],
        0.0,
    );
    let stats = m.index_stats();
    assert_eq!(stats.fallback, 1, "vacuous clause scanned every sample");
    assert_eq!(stats.indexed, 1, "only the live masked clause is indexed");
    let rows = vec![vec![false; f], vec![true; f]];
    assert_all_paths_match(&m, &rows, "vacuous flags");
    let out = m.forward_packed(&PackedBatch::from_rows(&rows).unwrap()).unwrap();
    for r in 0..rows.len() {
        let fired = out.fired_row(r);
        assert!(fired[0], "vacuous clause fires on row {r}");
        assert!(!fired[2], "unflagged clause never fires on row {r}");
        assert!(!fired[3], "dead clause never fires on row {r}");
    }
}

#[test]
fn prop_predict_packed_agrees_with_full_argmax_1000_cases() {
    // 1000 random (model, row) pairs; half the cases duplicate the class
    // block so cross-class ties are guaranteed, pinning the early exit
    // to the lowest-index tie convention.
    prop::check("early-exit argmax vs full argmax", 1000, |g| {
        let f = g.int(1, 40) as usize;
        let cpc = g.int(1, 10) as usize;
        let k = g.int(1, 5) as usize;
        let model = if g.boolean(0.5) {
            random_model_shaped(g, k, cpc, f, g.float(0.0, 0.4))
        } else {
            // Duplicate every class's clauses: class i and class i+k are
            // identical, so the top sum is always tied across classes.
            let base = random_model_shaped(g, k, cpc, f, g.float(0.0, 0.4));
            let include: Vec<Vec<bool>> =
                base.include.iter().chain(base.include.iter()).cloned().collect();
            let polarity: Vec<i8> =
                base.polarity.iter().chain(base.polarity.iter()).copied().collect();
            TmModel::assemble_derived("tied".into(), 2 * k, f, cpc, include, polarity, 0.0)
        };
        let row = g.bits(f, 0.5);
        let batch = PackedBatch::single(&row);
        let out = model.forward_packed(&batch).unwrap();
        let sums = out.sums_row(0);
        let top = *sums.iter().max().unwrap();
        let first_top = sums.iter().position(|&s| s == top).unwrap();
        let pred = model.predict_packed(&batch).unwrap();
        assert_eq!(pred[0] as usize, first_top, "early exit broke the tie convention");
        assert_eq!(pred[0], out.pred[0]);
    });
}

#[test]
fn prop_reindexing_with_stats_never_changes_results() {
    prop::check("reindex_with_stats is bit-exact", 60, |g| {
        let f = *g.choose(&FEATURES);
        let &(k, cpc) = g.choose(&CLAUSE_SHAPES);
        let mut model = random_model_shaped(g, k, cpc, f, g.float(0.05, 0.4));
        let rows: Vec<Vec<bool>> = (0..4).map(|_| g.bits(f, 0.5)).collect();
        let batch = PackedBatch::from_rows(&rows).unwrap();
        let before = model.forward_packed(&batch).unwrap();
        let probs: Vec<f64> = (0..2 * f).map(|_| g.float(0.0, 1.0)).collect();
        model.reindex_with_stats(&probs).unwrap();
        let after = model.forward_packed(&batch).unwrap();
        assert_eq!(before, after);
        assert_all_paths_match(&model, &rows, "post-reindex");
    });
}

#[test]
fn scratch_reuse_across_batches_is_equivalent_to_fresh_scratch() {
    // One long-lived scratch (the worker shape) vs a fresh scratch per
    // batch, across models of different shapes sharing nothing.
    let m1 = TmModel::synthetic("reuse1", 3, 21, 31, 0.2, 1);
    let m2 = TmModel::synthetic("reuse2", 5, 13, 65, 0.1, 2);
    let mut shared = ForwardScratch::new();
    let mut rng = tdpc::util::SplitMix64::new(9);
    for round in 0..6 {
        let m = if round % 2 == 0 { &m1 } else { &m2 };
        let rows: Vec<Vec<bool>> = (0..3)
            .map(|_| (0..m.n_features).map(|_| rng.next_bool(0.5)).collect())
            .collect();
        let batch = PackedBatch::from_rows(&rows).unwrap();
        let reused = m.forward_packed_with(&batch, &mut shared).unwrap();
        let fresh = m.forward_packed(&batch).unwrap();
        assert_eq!(reused, fresh, "round {round}");
        let p_reused = m.predict_packed_with(&batch, &mut shared).unwrap();
        assert_eq!(p_reused, fresh.pred, "round {round}: predict");
    }
    assert_eq!(shared.rows, 6 * 2 * 3, "forward + predict each count 3 rows per round");
}
