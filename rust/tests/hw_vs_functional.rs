//! Hardware-vs-functional agreement on real trained models: the simulated
//! time-domain argmax must match the software argmax on every sample with
//! a unique maximum (ties are genuinely ambiguous — paper footnote 1).

use tdpc::asynctm::AsyncTmEngine;
use tdpc::baselines::DesignParams;
use tdpc::fabric::Device;
use tdpc::flow::FlowConfig;
use tdpc::tm::{Manifest, TestSet, TmModel};
use tdpc::util::Ps;

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load_default() {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn tuned_engine_is_lossless_on_all_models() {
    let Some(manifest) = manifest_or_skip() else { return };
    let device = Device::xc7z020();
    for entry in &manifest.models {
        let model = TmModel::load(&entry.model_path).unwrap();
        let test = TestSet::load(&entry.test_data_path).unwrap();
        let d = DesignParams::from_model(&model);
        let mut engine =
            AsyncTmEngine::build(&device, &d, &FlowConfig::table1_default(), 11).unwrap();
        let mut checked = 0;
        for x in test.x.iter().take(120) {
            let sums = model.class_sums(x);
            let top = *sums.iter().max().unwrap();
            if sums.iter().filter(|&&s| s == top).count() > 1 {
                continue; // tie: either answer is defensible
            }
            let bits = model.clause_bits(x);
            let hw = engine.infer(&bits).winner;
            assert_eq!(hw, model.predict(x), "{} sample sums {sums:?}", entry.name);
            checked += 1;
        }
        let expect_min = (test.len().min(120) / 2).min(50);
        assert!(checked >= expect_min, "{}: too few non-tied samples ({checked})", entry.name);
    }
}

#[test]
fn decision_latency_anticorrelates_with_winner_margin() {
    // The core time-domain law at system level: bigger winning class sums
    // finish faster.
    let Some(manifest) = manifest_or_skip() else { return };
    let entry = manifest.entry("mnist_c50").unwrap();
    let model = TmModel::load(&entry.model_path).unwrap();
    let test = TestSet::load(&entry.test_data_path).unwrap();
    let d = DesignParams::from_model(&model);
    let mut engine = AsyncTmEngine::build(
        &Device::xc7z020(),
        &d,
        &FlowConfig::table1_default(),
        13,
    )
    .unwrap();
    let mut margins = Vec::new();
    let mut lats = Vec::new();
    for x in test.x.iter().take(150) {
        let sums = model.class_sums(x);
        let top = *sums.iter().max().unwrap();
        let bits = model.clause_bits(x);
        let out = engine.infer(&bits);
        margins.push(top as f64);
        lats.push(out.decision_latency.as_ns());
    }
    let rho = tdpc::util::stats::spearman(&margins, &lats);
    assert!(rho < -0.8, "winner sum vs latency must be strongly negative, ρ = {rho}");
}

#[test]
fn cycle_latency_bounded_by_worst_case_plus_control() {
    let Some(manifest) = manifest_or_skip() else { return };
    let entry = manifest.entry("iris_c50").unwrap();
    let model = TmModel::load(&entry.model_path).unwrap();
    let test = TestSet::load(&entry.test_data_path).unwrap();
    let d = DesignParams::from_model(&model);
    let mut engine =
        AsyncTmEngine::build(&Device::xc7z020(), &d, &FlowConfig::table1_default(), 17).unwrap();
    let bound = engine.worst_case_latency() + Ps(2_000);
    for x in test.x.iter().take(30) {
        let out = engine.infer(&model.clause_bits(x));
        assert!(out.cycle_latency <= bound, "{} > {bound}", out.cycle_latency);
        assert!(out.decision_latency <= out.cycle_latency);
    }
}
