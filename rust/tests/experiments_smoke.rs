//! Artifact-free smoke tests for the figure/table drivers, so the paper
//! experiment code cannot silently rot: tiny-config `table1` tuning and a
//! full `fig9` run (through the unified hardware-engine seam) execute on
//! every `cargo test`, with no manifest and no trained artifacts.

use tdpc::experiments::{fig9, table1};
use tdpc::tm::{TestSet, TmModel};

/// 2 classes × 4 clauses over 3 features, hand-wired so that class 0 wins
/// iff x0 ∧ x1 and class 1 wins iff ¬x0 (same construction as the table1
/// unit suite: labels = model predictions ⇒ "lossless" is achievable).
fn tiny_model() -> TmModel {
    TmModel::assemble(
        "tiny".into(),
        2,
        3,
        4,
        vec![
            vec![true, false, false, false, false, false], // +: x0
            vec![false, false, false, false, false, true], // −: ~x2
            vec![false, true, false, false, false, false], // +: x1
            vec![false, false, false, false, false, false],
            vec![false, false, false, true, false, false], // +: ~x0
            vec![false, false, false, false, false, false],
            vec![false, false, false, true, false, false], // +: ~x0
            vec![false, false, true, false, false, false], // −: x2
        ],
        vec![1, -1, 1, -1, 1, -1, 1, -1],
        vec![true, true, true, false, true, false, true, true],
        100.0,
    )
}

fn tiny_testset(model: &TmModel) -> TestSet {
    let xs: Vec<Vec<bool>> = (0..8)
        .map(|i| vec![i & 1 != 0, i & 2 != 0, i & 4 != 0])
        .collect();
    let ys: Vec<usize> = xs.iter().map(|x| model.predict(x)).collect();
    TestSet { name: "tiny".into(), n_features: 3, x: xs, y: ys }
}

#[test]
fn table1_tuning_smoke() {
    let model = tiny_model();
    let test = tiny_testset(&model);
    let (hi, hw_acc, sw_acc) = table1::tune_hi_delay(&model, &test, 8, 5).unwrap();
    assert_eq!(sw_acc, 1.0);
    assert_eq!(hw_acc, 1.0, "tiny config must tune lossless");
    assert!(hi.as_ps() >= 440);
}

#[test]
fn fig9_runs_on_a_synthetic_model() {
    // A synthetic iris-scale model exercises the whole fig9 path: engine
    // list construction (flow + PDLs + arbiter for the async design),
    // per-request replay of every architecture, analytic latency /
    // resource / power rows, and table rendering.
    let model = TmModel::synthetic("smoke", 3, 10, 16, 0.15, 41);
    let mut rng = tdpc::util::SplitMix64::new(7);
    let xs: Vec<Vec<bool>> =
        (0..12).map(|_| (0..16).map(|_| rng.next_bool(0.5)).collect()).collect();
    let ys: Vec<usize> = xs.iter().map(|x| model.predict(x)).collect();
    let test = TestSet { name: "smoke".into(), n_features: 16, x: xs, y: ys };

    let cfg = fig9::run_model("smoke", &model, &test, 10, 1).unwrap();
    assert_eq!(cfg.measured.len(), 3, "one measured entry per architecture");
    for (arch, mean, _std) in &cfg.measured {
        assert!(*mean > 0.0, "{arch}: measured decision latency must be positive");
    }
    assert!(cfg.td_measured_mean_ns > 0.0);
    assert!(cfg.td_worst_ns >= cfg.td_decision_mean_ns);
    assert!(cfg.latency_reduction().is_finite());
    assert!(cfg.power_reduction().is_finite());

    // Rendering: three tables (9a/9b/9c), each with one row per arch for
    // the single config, and the engine-seam note present.
    let tables = fig9::Fig9Result { configs: vec![cfg] }.tables();
    assert_eq!(tables.len(), 3);
    assert_eq!(tables[0].rows.len(), 3, "latency rows: generic, fpt18, td-async");
    assert_eq!(tables[1].rows.len(), 4, "resource rows include async21");
    let md = tables[0].to_markdown();
    assert!(md.contains("unified engine seam"), "{md}");
}
