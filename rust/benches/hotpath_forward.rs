//! The clause-evaluation hot-loop bench: seed-shaped scalar scan vs the
//! chunked full scan vs the clause-indexed scan, plus the end-to-end
//! production paths — the other half of the perf trajectory next to
//! `BENCH_serving.json`.
//!
//! Every variant is cross-checked bit-for-bit against
//! `TmModel::forward_reference` *before* anything is timed, and the
//! result is written as `BENCH_hotpath.json` (schema
//! `tdpc-bench-hotpath/v1`):
//!
//! ```text
//! {
//!   "schema": "tdpc-bench-hotpath/v1",
//!   "config": { "batch", "clauses_per_class", "density",
//!               "n_classes", "n_features", "smoke" },
//!   "cross_check": "pass",
//!   "index": { "buckets", "fallback", "indexed" },
//!   "skip_rate": 0.87,
//!   "variants": [ { "mean_us_per_iter", "name", "rows_per_s" }, … ],
//!   "best_speedup_vs_baseline": 2.3
//! }
//! ```
//!
//! Variants (each iterates one batch, reporting rows/s):
//! - `baseline`      — the seed `forward_packed` inner shape: word-serial
//!   scalar clause scan, bit-at-a-time fired stores, per-row sums `Vec`;
//! - `simd`          — chunked 4×u64-lane full scan + caller-scratch sums;
//! - `indexed_simd`  — the production kernel: clause-indexed scan +
//!   chunked lanes + caller-scratch sums;
//! - `forward_packed` — the public end-to-end entry (builds `ForwardOutput`);
//! - `predict_packed` — argmax-only with the exact class-sum early exit.
//!
//! Usage: `cargo bench --bench hotpath_forward -- [--smoke] [--out PATH]`

use std::time::Duration;

use tdpc::tm::{bits, ForwardScratch, PackedBatch, TmModel};
use tdpc::util::{benchkit, json, SplitMix64};

struct Config {
    n_classes: usize,
    clauses_per_class: usize,
    n_features: usize,
    density: f64,
    batch: usize,
    smoke: bool,
    warmup: Duration,
    budget: Duration,
}

fn config(smoke: bool) -> Config {
    if smoke {
        Config {
            n_classes: 4,
            clauses_per_class: 20,
            n_features: 128,
            density: 0.05,
            batch: 16,
            smoke,
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(80),
        }
    } else {
        Config {
            n_classes: 10,
            clauses_per_class: 100,
            n_features: 784,
            density: 0.05,
            batch: 64,
            smoke,
            warmup: Duration::from_millis(200),
            budget: Duration::from_millis(900),
        }
    }
}

/// Argmax with ties → lowest index (jnp.argmax), shared by the kernels.
fn argmax(sums: &[i32]) -> usize {
    let mut best = 0usize;
    for (k, &s) in sums.iter().enumerate() {
        if s > sums[best] {
            best = k;
        }
    }
    best
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_hotpath.json".to_string());
    let cfg = config(smoke);

    let model = TmModel::synthetic(
        "hotpath",
        cfg.n_classes,
        cfg.clauses_per_class,
        cfg.n_features,
        cfg.density,
        7,
    );
    let mut rng = SplitMix64::new(13);
    let rows: Vec<Vec<bool>> = (0..cfg.batch)
        .map(|_| (0..cfg.n_features).map(|_| rng.next_bool(0.5)).collect())
        .collect();
    let batch = PackedBatch::from_rows(&rows).unwrap();

    let lit_words = bits::words_for(2 * model.n_features);
    let fired_words = bits::words_for(model.c_total());

    // -- bit-exact cross-check (every variant vs forward_reference) ------
    // Runs before any timing: a fast wrong kernel must never get a number.
    let out = model.forward_packed(&batch).unwrap();
    let preds_early = model.predict_packed(&batch).unwrap();
    {
        let mut lits = vec![0u64; lit_words];
        let mut negated = Vec::new();
        let (mut scalar, mut chunked, mut indexed) =
            (vec![0u64; fired_words], vec![0u64; fired_words], vec![0u64; fired_words]);
        for (r, row) in rows.iter().enumerate() {
            let (fired_ref, sums_ref, pred_ref) = model.forward_reference(row);
            model.packed_literals_into(batch.row(r), &mut negated, &mut lits);
            model.fired_words_into_scalar(&lits, &mut scalar);
            model.fired_words_into(&lits, &mut chunked);
            model.fired_words_into_indexed(&lits, &mut indexed);
            assert_eq!(scalar, chunked, "row {r}: scalar vs chunked scan");
            assert_eq!(scalar, indexed, "row {r}: scalar vs indexed scan");
            assert_eq!(out.fired_words_row(r), &scalar[..], "row {r}: forward_packed fired");
            assert_eq!(out.fired_row(r), fired_ref, "row {r}: fired vs reference");
            assert_eq!(out.sums_row(r), &sums_ref[..], "row {r}: sums vs reference");
            assert_eq!(out.pred[r] as usize, pred_ref, "row {r}: pred vs reference");
            assert_eq!(preds_early[r], out.pred[r], "row {r}: early-exit pred");
            assert_eq!(model.class_sums_from_fired(&scalar), sums_ref, "row {r}: voter");
        }
    }
    println!("cross-check PASS: scalar == chunked == indexed == reference ({} rows)", cfg.batch);

    // Skip rate on this workload (CI gates on > 0: the index must be
    // doing real work on the synthetic model, not falling back).
    let mut telemetry = ForwardScratch::new();
    model.forward_packed_with(&batch, &mut telemetry).unwrap();
    let skip_rate = telemetry.skip_rate();
    let stats = model.index_stats();
    println!(
        "index: {} clauses in {} buckets, {} fallback; skip rate {:.1}%",
        stats.indexed,
        stats.buckets,
        stats.fallback,
        100.0 * skip_rate
    );
    assert!(skip_rate > 0.0, "clause index skipped nothing on the synthetic workload");

    // -- timed variants ---------------------------------------------------
    let mut variants: Vec<(String, f64, f64)> = Vec::new(); // (name, mean_us, rows/s)
    let mut run = |name: &str, warmup: Duration, budget: Duration, f: &mut dyn FnMut()| {
        let mean = benchkit::bench_with(&format!("hotpath/{name}"), warmup, budget, f);
        let rate = benchkit::report_rows_per_s(&format!("hotpath/{name}"), mean, cfg.batch);
        (name.to_string(), mean, rate)
    };

    // baseline: the seed forward_packed body — scalar scan, bit-at-a-time
    // stores, per-row sums Vec allocation.
    let mut lits = vec![0u64; lit_words];
    let mut negated: Vec<u64> = Vec::new();
    let mut fired = vec![0u64; fired_words];
    let v = run("baseline", cfg.warmup, cfg.budget, &mut || {
        for r in 0..batch.rows() {
            model.packed_literals_into(batch.row(r), &mut negated, &mut lits);
            model.fired_words_into_scalar(&lits, &mut fired);
            let sums = model.class_sums_from_fired(&fired);
            std::hint::black_box(argmax(&sums));
        }
    });
    variants.push(v);

    // simd: chunked 4×u64-lane full scan, caller-scratch sums.
    let mut sums = vec![0i32; model.n_classes];
    let v = run("simd", cfg.warmup, cfg.budget, &mut || {
        for r in 0..batch.rows() {
            model.packed_literals_into(batch.row(r), &mut negated, &mut lits);
            model.fired_words_into(&lits, &mut fired);
            model.class_sums_into(&fired, &mut sums);
            std::hint::black_box(argmax(&sums));
        }
    });
    variants.push(v);

    // indexed_simd: the production kernel.
    let v = run("indexed_simd", cfg.warmup, cfg.budget, &mut || {
        for r in 0..batch.rows() {
            model.packed_literals_into(batch.row(r), &mut negated, &mut lits);
            model.fired_words_into_indexed(&lits, &mut fired);
            model.class_sums_into(&fired, &mut sums);
            std::hint::black_box(argmax(&sums));
        }
    });
    variants.push(v);

    // End-to-end public entries (include ForwardOutput assembly / the
    // early-exit argmax) for the trajectory record.
    let mut scratch = ForwardScratch::new();
    let v = run("forward_packed", cfg.warmup, cfg.budget, &mut || {
        std::hint::black_box(model.forward_packed_with(&batch, &mut scratch).unwrap());
    });
    variants.push(v);
    let v = run("predict_packed", cfg.warmup, cfg.budget, &mut || {
        std::hint::black_box(model.predict_packed_with(&batch, &mut scratch).unwrap());
    });
    variants.push(v);

    let baseline_rate = variants[0].2;
    let best = variants.iter().skip(1).map(|v| v.2).fold(0.0f64, f64::max);
    let best_speedup = best / baseline_rate;
    println!("best variant over baseline: ×{best_speedup:.2}");

    // -- artifact ---------------------------------------------------------
    let doc = json::obj(vec![
        ("schema", json::s("tdpc-bench-hotpath/v1")),
        (
            "config",
            json::obj(vec![
                ("n_classes", json::num(cfg.n_classes as f64)),
                ("clauses_per_class", json::num(cfg.clauses_per_class as f64)),
                ("n_features", json::num(cfg.n_features as f64)),
                ("density", json::num(cfg.density)),
                ("batch", json::num(cfg.batch as f64)),
                ("smoke", json::num(cfg.smoke as u8 as f64)),
            ]),
        ),
        ("cross_check", json::s("pass")),
        (
            "index",
            json::obj(vec![
                ("indexed", json::num(stats.indexed as f64)),
                ("fallback", json::num(stats.fallback as f64)),
                ("buckets", json::num(stats.buckets as f64)),
            ]),
        ),
        ("skip_rate", json::num(skip_rate)),
        (
            "variants",
            json::Value::Arr(
                variants
                    .iter()
                    .map(|(name, mean, rate)| benchkit::variant_json(name, *mean, *rate))
                    .collect(),
            ),
        ),
        ("best_speedup_vs_baseline", json::num(best_speedup)),
    ]);
    std::fs::write(&out_path, json::emit(&doc) + "\n").unwrap();
    println!("wrote {out_path}");
}
