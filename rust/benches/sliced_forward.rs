//! The bit-sliced forward engine bench: row-major clause-indexed scan vs
//! the plane-major carry-save engine (`tm::slice`), plus the dispatched
//! public entry — the trajectory record for the batch-transposed path
//! next to `BENCH_hotpath.json`.
//!
//! Every variant is cross-checked bit-for-bit against
//! `TmModel::forward_reference` *and* the row-major indexed kernel
//! *before* anything is timed, and the result is written as
//! `BENCH_slice.json` (schema `tdpc-bench-slice/v1`):
//!
//! ```text
//! {
//!   "schema": "tdpc-bench-slice/v1",
//!   "config": { "batch", "clauses_per_class", "density",
//!               "n_classes", "n_features", "smoke" },
//!   "cross_check": "pass",
//!   "sliced": { "groups", "rows" },
//!   "variants": [ { "mean_us_per_iter", "name", "rows_per_s" }, … ],
//!   "sliced_speedup_vs_indexed": 2.1
//! }
//! ```
//!
//! Variants (each iterates one batch, reporting rows/s):
//! - `indexed`        — the row-major production kernel: per-row
//!   clause-indexed scan + chunked lanes (`forward_indexed_with`);
//! - `sliced`         — the plane-major engine: 64×64 batch transpose,
//!   bucket-skipped plane ANDs, CSA vertical counters
//!   (`forward_sliced_with`);
//! - `forward_packed` — the public dispatched entry (routes this batch
//!   to the sliced engine: batch ≥ `SLICED_MIN_ROWS`).
//!
//! Usage: `cargo bench --bench sliced_forward -- [--smoke] [--out PATH]`

use std::time::Duration;

use tdpc::tm::{ForwardScratch, PackedBatch, TmModel, SLICED_MIN_ROWS};
use tdpc::util::{benchkit, json, SplitMix64};

struct Config {
    n_classes: usize,
    clauses_per_class: usize,
    n_features: usize,
    density: f64,
    batch: usize,
    smoke: bool,
    warmup: Duration,
    budget: Duration,
}

fn config(smoke: bool) -> Config {
    if smoke {
        Config {
            n_classes: 4,
            clauses_per_class: 20,
            n_features: 128,
            density: 0.05,
            // Must stay ≥ SLICED_MIN_ROWS so the smoke run still
            // exercises the sliced engine through the dispatcher.
            batch: 128,
            smoke,
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(80),
        }
    } else {
        // Seed-shaped model (MNIST-sized: 10 × 100 × 784) at the batch
        // the CI gate measures.
        Config {
            n_classes: 10,
            clauses_per_class: 100,
            n_features: 784,
            density: 0.05,
            batch: 512,
            smoke,
            warmup: Duration::from_millis(200),
            budget: Duration::from_millis(900),
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_slice.json".to_string());
    let cfg = config(smoke);
    assert!(cfg.batch >= SLICED_MIN_ROWS, "bench batch must take the sliced path");

    let model = TmModel::synthetic(
        "sliced",
        cfg.n_classes,
        cfg.clauses_per_class,
        cfg.n_features,
        cfg.density,
        7,
    );
    let mut rng = SplitMix64::new(13);
    let rows: Vec<Vec<bool>> = (0..cfg.batch)
        .map(|_| (0..cfg.n_features).map(|_| rng.next_bool(0.5)).collect())
        .collect();
    let batch = PackedBatch::from_rows(&rows).unwrap();

    // -- bit-exact cross-check (sliced vs indexed vs reference) ----------
    // Runs before any timing: a fast wrong kernel must never get a number.
    let mut scratch = ForwardScratch::new();
    let sliced = model.forward_sliced_with(&batch, &mut scratch).unwrap();
    let mut scratch_idx = ForwardScratch::new();
    let indexed = model.forward_indexed_with(&batch, &mut scratch_idx).unwrap();
    assert_eq!(sliced, indexed, "sliced ForwardOutput vs indexed ForwardOutput");
    let dispatched = model.forward_packed(&batch).unwrap();
    assert_eq!(sliced, dispatched, "sliced ForwardOutput vs dispatched forward_packed");
    for (r, row) in rows.iter().enumerate() {
        let (fired_ref, sums_ref, pred_ref) = model.forward_reference(row);
        assert_eq!(sliced.fired_row(r), fired_ref, "row {r}: fired vs reference");
        assert_eq!(sliced.sums_row(r), &sums_ref[..], "row {r}: sums vs reference");
        assert_eq!(sliced.pred[r] as usize, pred_ref, "row {r}: pred vs reference");
    }
    println!("cross-check PASS: sliced == indexed == dispatched == reference ({} rows)", cfg.batch);

    // The dispatcher must actually have taken the sliced engine, and the
    // group accounting must cover every row (CI reads these numbers).
    let sliced_groups = scratch.sliced_groups;
    let sliced_rows = scratch.sliced_rows;
    assert!(sliced_groups > 0, "sliced engine reported no groups");
    assert_eq!(sliced_rows as usize, cfg.batch, "sliced engine must cover every row");
    println!("sliced: {} rows in {} groups of 64", sliced_rows, sliced_groups);

    // -- timed variants ---------------------------------------------------
    let mut variants: Vec<(String, f64, f64)> = Vec::new(); // (name, mean_us, rows/s)
    let mut run = |name: &str, warmup: Duration, budget: Duration, f: &mut dyn FnMut()| {
        let mean = benchkit::bench_with(&format!("sliced/{name}"), warmup, budget, f);
        let rate = benchkit::report_rows_per_s(&format!("sliced/{name}"), mean, cfg.batch);
        (name.to_string(), mean, rate)
    };

    // indexed: the row-major production kernel, forced past the dispatcher.
    let v = run("indexed", cfg.warmup, cfg.budget, &mut || {
        std::hint::black_box(model.forward_indexed_with(&batch, &mut scratch_idx).unwrap());
    });
    variants.push(v);

    // sliced: the plane-major engine, forced past the dispatcher.
    let v = run("sliced", cfg.warmup, cfg.budget, &mut || {
        std::hint::black_box(model.forward_sliced_with(&batch, &mut scratch).unwrap());
    });
    variants.push(v);

    // forward_packed: the public dispatched entry — at this batch size it
    // routes to the sliced engine, so its rate should track `sliced`.
    let mut scratch_dispatch = ForwardScratch::new();
    let v = run("forward_packed", cfg.warmup, cfg.budget, &mut || {
        std::hint::black_box(model.forward_packed_with(&batch, &mut scratch_dispatch).unwrap());
    });
    variants.push(v);

    let indexed_rate = variants[0].2;
    let sliced_rate = variants[1].2;
    let speedup = sliced_rate / indexed_rate;
    println!("sliced over indexed: ×{speedup:.2}");

    // -- artifact ---------------------------------------------------------
    let doc = json::obj(vec![
        ("schema", json::s("tdpc-bench-slice/v1")),
        (
            "config",
            json::obj(vec![
                ("n_classes", json::num(cfg.n_classes as f64)),
                ("clauses_per_class", json::num(cfg.clauses_per_class as f64)),
                ("n_features", json::num(cfg.n_features as f64)),
                ("density", json::num(cfg.density)),
                ("batch", json::num(cfg.batch as f64)),
                ("smoke", json::num(cfg.smoke as u8 as f64)),
            ]),
        ),
        ("cross_check", json::s("pass")),
        (
            "sliced",
            json::obj(vec![
                ("groups", json::num(sliced_groups as f64)),
                ("rows", json::num(sliced_rows as f64)),
            ]),
        ),
        (
            "variants",
            json::Value::Arr(
                variants
                    .iter()
                    .map(|(name, mean, rate)| benchkit::variant_json(name, *mean, *rate))
                    .collect(),
            ),
        ),
        ("sliced_speedup_vs_indexed", json::num(speedup)),
    ]);
    std::fs::write(&out_path, json::emit(&doc) + "\n").unwrap();
    println!("wrote {out_path}");
}
