//! Bench/regenerator for Fig. 11 (resource scaling sweeps).
use tdpc::experiments::fig11;

fn main() {
    let r = fig11::run();
    for t in r.tables() {
        println!("{}", t.to_markdown());
    }
    let [g, f, a, t] = fig11::Fig11Result::slopes(&r.vs_clauses);
    println!("slopes vs clauses: generic {g:.1}, fpt18 {f:.1}, async21 {a:.1}, td {t:.1} (LUT+FF per clause)");
    assert!(r.shape_holds(), "TD must have the smallest resource slope");
}
