//! §Perf L3 bench: the simulation hot paths — PDL propagation, arbiter
//! trees, full engine inference, event-driven simulator events/s, and the
//! flow (place+route) cost.
use tdpc::arbiter::{ArbiterConfig, ArbiterTree};
use tdpc::asynctm::AsyncTmEngine;
use tdpc::baselines::DesignParams;
use tdpc::fabric::Device;
use tdpc::flow::FlowConfig;
use tdpc::timing::{Circuit, Simulator};
use tdpc::tm::datasets::synthetic_clause_bits;
use tdpc::tm::WorkloadSpec;
use tdpc::util::{benchkit, Ps, SplitMix64};

fn main() {
    let device = Device::xc7z020();

    // Flow: place + route 10 × 100-element PDLs.
    benchkit::bench("hotpath/flow_10x100", || {
        let _ = tdpc::flow::run(&device, 10, 100, &FlowConfig::table1_default()).unwrap();
    });

    // Engine inference (10 classes × 100 clauses, the biggest config).
    let d = DesignParams::synthetic(10, 100, 784);
    let mut engine =
        AsyncTmEngine::build(&device, &d, &FlowConfig::table1_default(), 1).unwrap();
    let spec = WorkloadSpec { n_classes: 10, clauses_per_class: 100, n_features: 784, fire_rate: 0.5 };
    let mut rng = SplitMix64::new(5);
    let samples: Vec<_> = (0..64).map(|i| synthetic_clause_bits(&spec, i % 10, &mut rng)).collect();
    let mut i = 0;
    let mean = benchkit::bench("hotpath/engine_infer_10x100", || {
        let s = &samples[i % samples.len()];
        i += 1;
        std::hint::black_box(engine.infer(s));
    });
    println!("  engine inference rate: {:.0}/s", benchkit::throughput(mean, 1));

    // Arbiter tree alone (32-way).
    let tree = ArbiterTree::new(32, ArbiterConfig::default());
    let arrivals: Vec<Ps> = (0..32).map(|k| Ps(50_000 + 311 * k as u64)).collect();
    let mut rng2 = SplitMix64::new(9);
    benchkit::bench("hotpath/arbiter_tree_32way", || {
        std::hint::black_box(tree.decide(&arrivals, &mut rng2));
    });

    // Event-driven simulator: 2000-buffer chain, measure events/s.
    let mut c = Circuit::new();
    let start = c.net();
    let mut n = start;
    for _ in 0..2000 {
        n = c.delay_net(n, Ps(100));
    }
    let mean = benchkit::bench("hotpath/event_sim_2000gate_chain", || {
        let mut sim = Simulator::new(&c);
        sim.schedule(start, true, Ps(0));
        sim.schedule(start, false, Ps(50_000_000));
        std::hint::black_box(sim.run_until(Ps(u64::MAX / 2)));
    });
    println!(
        "  event rate: {:.2} M events/s",
        4000.0 / mean // 2 edges × 2000 gates per iteration
    );
}
