//! Bench/regenerator for Fig. 12 (power scaling at α ∈ {0.1, 0.5}).
use tdpc::experiments::fig12;

fn main() {
    let r = fig12::run();
    for t in r.tables() {
        println!("{}", t.to_markdown());
    }
    assert!(r.shape_holds(), "Fig. 12 crossover + TD stability must hold");
    println!("fig12 shape: adder wins at α=0.1, TD wins at α=0.5, TD activity-insensitive");
}
