//! Clause-sharded forward-pass bench: the scatter/reduce perf story next
//! to `BENCH_hotpath.json`'s single-scan one.
//!
//! For each shard count the batch is cross-checked bit-for-bit against the
//! unsharded `forward_packed` (merged partials must reproduce sums, fired
//! bits, and argmax ties exactly) *before* anything is timed, then each
//! shard's partial pass is timed on its own. A sharded pool runs shards on
//! parallel workers, so the modeled per-batch latency is the *critical
//! path* — the slowest shard plus the reduce — not the sum of shard times
//! (summing would re-serialize the plan and, on the single-core CI box,
//! report ≤ 1/N efficiency for any N by construction):
//!
//! ```text
//! rows/s(n) = batch / (max over shards of mean partial time + mean merge time)
//! ```
//!
//! The result is written as `BENCH_shard.json` (schema
//! `tdpc-bench-shard/v1`):
//!
//! ```text
//! {
//!   "schema": "tdpc-bench-shard/v1",
//!   "config": { "batch", "clauses_per_class", "density",
//!               "n_classes", "n_features", "smoke" },
//!   "cross_check": "pass",
//!   "variants": [ { "name": "shards_4", "n_shards": 4,
//!                   "critical_path_us", "merge_us",
//!                   "mean_us_per_iter", "rows_per_s" }, … ],
//!   "scaling_efficiency": 0.9   // (rate@4 / rate@1) / 4
//! }
//! ```
//!
//! Usage: `cargo bench --bench sharded_forward -- [--smoke] [--out PATH]`

use std::sync::Arc;
use std::time::Duration;

use tdpc::tm::{merge_partials, ClauseShard, ForwardScratch, PackedBatch, PartialOutput, TmModel};
use tdpc::util::{benchkit, json, SplitMix64};

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

struct Config {
    n_classes: usize,
    clauses_per_class: usize,
    n_features: usize,
    density: f64,
    batch: usize,
    smoke: bool,
    warmup: Duration,
    budget: Duration,
}

fn config(smoke: bool) -> Config {
    if smoke {
        Config {
            n_classes: 4,
            clauses_per_class: 40,
            n_features: 128,
            density: 0.05,
            batch: 16,
            smoke,
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(60),
        }
    } else {
        // Big enough in c_total (4000 clauses) that one shard's slice of
        // the scan dominates the per-batch fixed costs (literal packing,
        // merge) — the regime sharding exists for.
        Config {
            n_classes: 10,
            clauses_per_class: 400,
            n_features: 784,
            density: 0.05,
            batch: 64,
            smoke,
            warmup: Duration::from_millis(150),
            budget: Duration::from_millis(600),
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_shard.json".to_string());
    let cfg = config(smoke);

    let model = Arc::new(TmModel::synthetic(
        "shard_bench",
        cfg.n_classes,
        cfg.clauses_per_class,
        cfg.n_features,
        cfg.density,
        7,
    ));
    let mut rng = SplitMix64::new(13);
    let rows: Vec<Vec<bool>> = (0..cfg.batch)
        .map(|_| (0..cfg.n_features).map(|_| rng.next_bool(0.5)).collect())
        .collect();
    let batch = PackedBatch::from_rows(&rows).unwrap();
    let full = model.forward_packed(&batch).unwrap();

    // -- bit-exact cross-check (every shard count vs forward_packed) -----
    // Runs before any timing: a fast wrong shard split must never get a
    // number. merge_partials re-argmaxes with the same lowest-index tie
    // rule, so `pred` equality covers tie handling too.
    for &n_shards in &SHARD_COUNTS {
        let shards = ClauseShard::split(&model, n_shards).unwrap();
        let parts: Vec<PartialOutput> =
            shards.iter().map(|s| s.partial(&batch).unwrap()).collect();
        let merged = merge_partials(&parts).unwrap();
        assert_eq!(merged, full, "n_shards={n_shards}: merged != unsharded forward_packed");
    }
    println!(
        "cross-check PASS: merged partials == forward_packed for shards {SHARD_COUNTS:?} \
         ({} rows)",
        cfg.batch
    );

    // -- timed variants ---------------------------------------------------
    let mut variants: Vec<(usize, f64, f64, f64, f64)> = Vec::new();
    for &n_shards in &SHARD_COUNTS {
        let shards = ClauseShard::split(&model, n_shards).unwrap();
        // Time each shard's partial pass separately (its own scratch and
        // output, as on a real worker); the critical path is the max.
        let mut critical_us = 0.0f64;
        let mut parts = Vec::with_capacity(n_shards);
        for shard in &shards {
            let mut scratch = ForwardScratch::new();
            let mut out = PartialOutput::empty(
                cfg.n_classes,
                model.c_total(),
                shard.index(),
                n_shards,
            );
            let mean = benchkit::bench_with(
                &format!("shard/{n_shards}way/part{}", shard.index()),
                cfg.warmup,
                cfg.budget,
                || {
                    shard.partial_class_sums_into(&batch, &mut scratch, &mut out).unwrap();
                    std::hint::black_box(&out);
                },
            );
            critical_us = critical_us.max(mean);
            parts.push(out);
        }
        let merge_us = benchkit::bench_with(
            &format!("shard/{n_shards}way/merge"),
            cfg.warmup,
            cfg.budget,
            || {
                std::hint::black_box(merge_partials(&parts).unwrap());
            },
        );
        let iter_us = critical_us + merge_us;
        let rate = benchkit::report_rows_per_s(
            &format!("shard/{n_shards}way/critical_path"),
            iter_us,
            cfg.batch,
        );
        variants.push((n_shards, critical_us, merge_us, iter_us, rate));
    }

    let rate_at = |n: usize| {
        variants
            .iter()
            .find(|v| v.0 == n)
            .map(|v| v.4)
            .expect("shard count timed")
    };
    let scaling_efficiency = rate_at(4) / rate_at(1) / 4.0;
    println!("scaling efficiency at 4 shards: {scaling_efficiency:.2} (1.0 = perfect)");

    // -- artifact ---------------------------------------------------------
    let doc = json::obj(vec![
        ("schema", json::s("tdpc-bench-shard/v1")),
        (
            "config",
            json::obj(vec![
                ("n_classes", json::num(cfg.n_classes as f64)),
                ("clauses_per_class", json::num(cfg.clauses_per_class as f64)),
                ("n_features", json::num(cfg.n_features as f64)),
                ("density", json::num(cfg.density)),
                ("batch", json::num(cfg.batch as f64)),
                ("smoke", json::num(cfg.smoke as u8 as f64)),
            ]),
        ),
        ("cross_check", json::s("pass")),
        (
            "variants",
            json::Value::Arr(
                variants
                    .iter()
                    .map(|&(n_shards, critical_us, merge_us, iter_us, rate)| {
                        json::obj(vec![
                            ("name", json::s(&format!("shards_{n_shards}"))),
                            ("n_shards", json::num(n_shards as f64)),
                            ("critical_path_us", json::num(critical_us)),
                            ("merge_us", json::num(merge_us)),
                            ("mean_us_per_iter", json::num(iter_us)),
                            ("rows_per_s", json::num(rate)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("scaling_efficiency", json::num(scaling_efficiency)),
    ]);
    std::fs::write(&out_path, json::emit(&doc) + "\n").unwrap();
    println!("wrote {out_path}");
}
