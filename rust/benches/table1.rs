//! Bench/regenerator for Table I: high-latency delay tuning to lossless
//! accuracy per configuration. Prints the table, then times the tuning
//! inner loop (engine build + replay).
use tdpc::experiments::table1;
use tdpc::tm::Manifest;
use tdpc::util::benchkit;

fn main() {
    let Ok(manifest) = Manifest::load_default() else {
        eprintln!("SKIP table1: artifacts not built");
        return;
    };
    let r = table1::run(&manifest, 120).expect("table1");
    println!("{}", r.table().to_markdown());

    // Hot-loop timing: one engine rebuild + 120-sample replay (iris_c50).
    let entry = manifest.entry("iris_c50").unwrap();
    let model = tdpc::tm::TmModel::load(&entry.model_path).unwrap();
    let test = tdpc::tm::TestSet::load(&entry.test_data_path).unwrap();
    benchkit::bench_with(
        "table1/tune_iris_c50_120samples",
        std::time::Duration::from_millis(200),
        std::time::Duration::from_secs(2),
        || {
            let _ = table1::tune_hi_delay(&model, &test, 120, 3).unwrap();
        },
    );
}
