//! Bench/regenerator for Fig. 6: PDL Hamming-weight response.
use tdpc::experiments::fig6;
use tdpc::util::benchkit;

fn main() {
    let r = fig6::run(150, 8, 42);
    println!("{}", r.table().to_markdown());
    assert!(r.shape_holds(), "Fig. 6 shape must hold");
    benchkit::bench_with(
        "fig6/150el_8samples_per_weight",
        std::time::Duration::from_millis(200),
        std::time::Duration::from_secs(2),
        || {
            let _ = fig6::run(150, 8, 7);
        },
    );
}
