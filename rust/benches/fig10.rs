//! Bench/regenerator for Fig. 10 (latency scaling sweeps, 1000 samples per
//! point like the paper).
use tdpc::experiments::fig10;

fn main() {
    let t0 = std::time::Instant::now();
    let r = fig10::run(1000);
    for t in r.tables() {
        println!("{}", t.to_markdown());
    }
    let (a, b, c, d) = r.shape_holds();
    println!("shape: generic-sublinear={a} td-linear={b} generic-linear-classes={c} td-constant-classes={d}");
    assert!(a && b && c && d, "Fig. 10 shapes must hold");
    assert!(r.worst_case_improbable());
    println!("fig10 total wall: {:.2}s", t0.elapsed().as_secs_f64());
}
