//! §Perf L3 bench: cost of the hardware replay seam on the serving path.
//!
//! Artifact-free (synthetic in-memory model): one coordinator per replay
//! configuration — native-only serving, `ReplayPolicy::Sample(8)`, and
//! `ReplayPolicy::Full` over the async time-domain backend — so the
//! overhead of per-request hardware timing is directly measurable as a
//! throughput delta. Registered in CI as a compile target
//! (`cargo bench --bench hw_backend --no-run`).

use std::num::NonZeroU32;
use std::sync::Arc;
use std::time::Duration;

use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, ReplayPolicy,
};
use tdpc::flow::FlowConfig;
use tdpc::hw::HwArch;
use tdpc::runtime::BackendSpec;
use tdpc::tm::TmModel;
use tdpc::util::{benchkit, SplitMix64};

fn main() {
    // MNIST-shaped but flow-buildable quickly: 8 classes × 64 clauses
    // over 128 Boolean features.
    let model = Arc::new(TmModel::synthetic("hw_bench", 8, 64, 128, 0.10, 7));
    let mut rng = SplitMix64::new(11);
    let inputs: Vec<Vec<bool>> = (0..256)
        .map(|_| (0..model.n_features).map(|_| rng.next_bool(0.5)).collect())
        .collect();

    let cases: [(&str, BackendSpec, ReplayPolicy); 3] = [
        ("native", BackendSpec::InMemory(model.clone()), ReplayPolicy::Off),
        (
            "hw_sample8",
            BackendSpec::TimeDomain {
                arch: HwArch::Async,
                flow: FlowConfig::table1_default(),
                model: Some(model.clone()),
            },
            ReplayPolicy::Sample(NonZeroU32::new(8).unwrap()),
        ),
        (
            "hw_full",
            BackendSpec::TimeDomain {
                arch: HwArch::Async,
                flow: FlowConfig::table1_default(),
                model: Some(model.clone()),
            },
            ReplayPolicy::Full,
        ),
    ];

    let mut throughputs: Vec<(&str, f64)> = Vec::new();
    for (tag, backend, replay) in cases {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 32, max_wait: Duration::from_micros(300) },
            n_workers: 2,
            dispatch: DispatchPolicy::LeastLoaded,
            backend,
            replay,
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start(std::path::PathBuf::from("/unused"), "hw_bench", cfg)
            .unwrap();
        let mid = coord.model_id("hw_bench").unwrap();

        let n = inputs.len();
        let mean = benchkit::bench_with(
            &format!("hw_backend/{tag}_burst{n}"),
            Duration::from_millis(200),
            Duration::from_secs(2),
            || {
                let (tx, rx) = std::sync::mpsc::channel();
                for x in &inputs {
                    coord.submit(mid, x, tx.clone());
                }
                drop(tx);
                let got = rx.iter().take(n).filter(|r| r.is_ok()).count();
                assert_eq!(got, n);
            },
        );
        let rps = benchkit::throughput(mean, n);
        println!("  burst throughput: {rps:.0} req/s");
        let m = coord.metrics();
        if m.hw_mean_ns > 0.0 {
            println!("  hw decision latency: p50 {} p99 {}", m.hw_p50, m.hw_p99);
        }
        throughputs.push((tag, rps));
        coord.shutdown();
    }

    // The headline: replay overhead as a fraction of native throughput.
    if let Some((_, native)) = throughputs.iter().find(|(t, _)| *t == "native") {
        for (tag, rps) in &throughputs {
            println!("  {tag}: {:.1}% of native throughput", 100.0 * rps / native);
        }
    }
}
