//! Artifact-store bench: what the content-addressed v2 tree costs and
//! what delta-aware reload buys.
//!
//! Before anything is timed, a cross-check asserts that a model opened
//! through a warmed payload cache after a one-shard rewrite is
//! bit-identical (clause arrays and all) to a cold full open of the same
//! generation — a fast-but-wrong cache must never get a number. Then:
//!
//! * `pack` — publish a fresh multi-model tree (objects + manifest),
//!   timed over fresh directories;
//! * `verify` — full-tree integrity sweep (read + re-hash + parse +
//!   assemble every object);
//! * `open_cold` — full model load with an empty payload cache (every
//!   object read from disk): the cost a full reload pays per worker;
//! * `open_cached` — the same load with every hash already cached: the
//!   floor delta reload converges to as the changed fraction → 0;
//! * `delta_open` — single-shot: one shard rewritten, load through the
//!   warmed cache (1 object from disk, N−1 from cache), with the
//!   payload-stat delta asserted, not assumed.
//!
//! The result is written as `BENCH_artifact.json` (schema
//! `tdpc-bench-artifact/v1`):
//!
//! ```text
//! {
//!   "schema": "tdpc-bench-artifact/v1",
//!   "config": { "n_models", "n_shards", "n_classes", "clauses_per_class",
//!               "n_features", "density", "smoke" },
//!   "cross_check": "pass",
//!   "pack_us", "verify_us", "open_cold_us", "open_cached_us",
//!   "delta_open_us", "delta_opened_objects", "delta_reused_objects",
//!   "cached_speedup": open_cold_us / open_cached_us
//! }
//! ```
//!
//! Usage: `cargo bench --bench artifact_store -- [--smoke] [--out PATH]`

use std::time::{Duration, Instant};

use tdpc::tm::artifact::{self, PackOptions, PayloadCache, Store};
use tdpc::tm::TmModel;
use tdpc::util::benchkit;
use tdpc::util::json;

struct Config {
    n_models: usize,
    n_shards: usize,
    n_classes: usize,
    clauses_per_class: usize,
    n_features: usize,
    density: f64,
    smoke: bool,
    warmup: Duration,
    budget: Duration,
}

fn config(smoke: bool) -> Config {
    if smoke {
        Config {
            n_models: 2,
            n_shards: 4,
            n_classes: 3,
            clauses_per_class: 24,
            n_features: 64,
            density: 0.2,
            smoke,
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(60),
        }
    } else {
        // Big enough that payload IO + hashing dominates the per-open
        // fixed costs (manifest parse, model assembly).
        Config {
            n_models: 4,
            n_shards: 8,
            n_classes: 10,
            clauses_per_class: 200,
            n_features: 784,
            density: 0.1,
            smoke,
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(400),
        }
    }
}

fn models(cfg: &Config) -> Vec<TmModel> {
    (0..cfg.n_models)
        .map(|i| {
            TmModel::synthetic(
                &format!("bench_{i}"),
                cfg.n_classes,
                cfg.clauses_per_class,
                cfg.n_features,
                cfg.density,
                100 + i as u64,
            )
        })
        .collect()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_artifact.json".to_string());
    let cfg = config(smoke);
    let ms = models(&cfg);
    let refs: Vec<&TmModel> = ms.iter().collect();
    let opts = PackOptions { n_shards: cfg.n_shards, ..Default::default() };
    let root = std::env::temp_dir().join(format!("tdpc-bench-art-{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();

    artifact::pack(&root, &refs, &opts).unwrap();

    // -- cross-check: delta-cached open == cold open, bit for bit --------
    // Warm a cache on generation 1, rewrite one shard of bench_0, then
    // compare the cache-assisted open against a cold open of the same
    // (new) generation.
    {
        let cache = PayloadCache::new();
        let store = Store::open(&root).unwrap();
        store.load_model("bench_0", Some(&cache)).unwrap();
        artifact::rewrite_shard(&root, "bench_0", 0, |b| b.polarity[0] = -b.polarity[0]).unwrap();
        let store = Store::open(&root).unwrap();
        let via_cache = store.load_model("bench_0", Some(&cache)).unwrap();
        let cold = store.load_model("bench_0", None).unwrap();
        assert_eq!(via_cache.include, cold.include, "cached open diverged from cold open");
        assert_eq!(via_cache.polarity, cold.polarity, "cached open diverged from cold open");
        assert_eq!(via_cache.nonempty, cold.nonempty, "cached open diverged from cold open");
        // Put generation 2's first shard back so later phases see a
        // settled tree.
        artifact::rewrite_shard(&root, "bench_0", 0, |b| b.polarity[0] = -b.polarity[0]).unwrap();
    }
    println!("cross-check PASS: delta-cached open == cold open for bench_0");

    // -- pack (fresh tree per iteration) ---------------------------------
    let pack_root = std::env::temp_dir().join(format!("tdpc-bench-artp-{}", std::process::id()));
    let pack_us = benchkit::bench_with("artifact/pack", cfg.warmup, cfg.budget, || {
        std::fs::remove_dir_all(&pack_root).ok();
        std::hint::black_box(artifact::pack(&pack_root, &refs, &opts).unwrap());
    });
    std::fs::remove_dir_all(&pack_root).ok();

    // -- verify -----------------------------------------------------------
    let verify_us = benchkit::bench_with("artifact/verify", cfg.warmup, cfg.budget, || {
        std::hint::black_box(artifact::verify(&root).unwrap());
    });

    // -- open: cold vs fully cached ---------------------------------------
    let open_cold_us = benchkit::bench_with("artifact/open_cold", cfg.warmup, cfg.budget, || {
        let store = Store::open(&root).unwrap();
        let cache = PayloadCache::new();
        std::hint::black_box(store.load_model("bench_0", Some(&cache)).unwrap());
    });
    let warm = PayloadCache::new();
    Store::open(&root).unwrap().load_model("bench_0", Some(&warm)).unwrap();
    let open_cached_us = benchkit::bench_with("artifact/open_cached", cfg.warmup, cfg.budget, || {
        let store = Store::open(&root).unwrap();
        std::hint::black_box(store.load_model("bench_0", Some(&warm)).unwrap());
    });

    // -- delta open: 1 of n_shards objects changed, single-shot -----------
    let delta_cache = PayloadCache::new();
    Store::open(&root).unwrap().load_model("bench_0", Some(&delta_cache)).unwrap();
    let (o0, r0) = delta_cache.stats();
    artifact::rewrite_shard(&root, "bench_0", 0, |b| b.polarity[0] = -b.polarity[0]).unwrap();
    let t = Instant::now();
    let store = Store::open(&root).unwrap();
    store.load_model("bench_0", Some(&delta_cache)).unwrap();
    let delta_open_us = t.elapsed().as_secs_f64() * 1e6;
    let (o1, r1) = delta_cache.stats();
    let (delta_opened, delta_reused) = (o1 - o0, r1 - r0);
    assert_eq!(delta_opened, 1, "a one-shard rewrite must re-read exactly one object");
    assert_eq!(delta_reused, (cfg.n_shards - 1) as u64);
    println!(
        "bench artifact/delta_open ({} of {} objects from disk): {delta_open_us:.2} µs",
        delta_opened, cfg.n_shards
    );

    let cached_speedup = open_cold_us / open_cached_us.max(1e-9);
    println!("cached open speedup over cold: {cached_speedup:.2}x");

    // -- artifact ----------------------------------------------------------
    let doc = json::obj(vec![
        ("schema", json::s("tdpc-bench-artifact/v1")),
        (
            "config",
            json::obj(vec![
                ("n_models", json::num(cfg.n_models as f64)),
                ("n_shards", json::num(cfg.n_shards as f64)),
                ("n_classes", json::num(cfg.n_classes as f64)),
                ("clauses_per_class", json::num(cfg.clauses_per_class as f64)),
                ("n_features", json::num(cfg.n_features as f64)),
                ("density", json::num(cfg.density)),
                ("smoke", json::num(cfg.smoke as u8 as f64)),
            ]),
        ),
        ("cross_check", json::s("pass")),
        ("pack_us", json::num(pack_us)),
        ("verify_us", json::num(verify_us)),
        ("open_cold_us", json::num(open_cold_us)),
        ("open_cached_us", json::num(open_cached_us)),
        ("delta_open_us", json::num(delta_open_us)),
        ("delta_opened_objects", json::num(delta_opened as f64)),
        ("delta_reused_objects", json::num(delta_reused as f64)),
        ("cached_speedup", json::num(cached_speedup)),
    ]);
    std::fs::write(&out_path, json::emit(&doc) + "\n").unwrap();
    println!("wrote {out_path}");
    std::fs::remove_dir_all(&root).ok();
}
