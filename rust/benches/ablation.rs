//! Bench/regenerator for the flow-ingredient ablation (DESIGN.md §4).
use tdpc::experiments::ablation;

fn main() {
    let r = ablation::run(150, 7);
    println!("{}", r.table().to_markdown());
    assert!(r.shape_holds(), "ablation shape must hold");
}
