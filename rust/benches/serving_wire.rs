//! §Perf L4 bench: the network serving wire itself.
//!
//! Artifact-free, three measurements:
//!
//! 1. **Frame codec, in memory** — `InferRequestMsg` encode+decode
//!    throughput through `write_frame`/`read_frame` over a byte buffer
//!    (the pure CPU cost of the protocol, no sockets).
//! 2. **TCP loopback round-trip** — a pipelined window of requests over a
//!    real socket against a live synthetic-model server.
//! 3. **Direct submission baseline** — the same burst through
//!    `Coordinator::submit_packed` on the same pool, so the wire's
//!    overhead above the coordinator is a directly-reported delta.
//!
//! Registered in CI as a compile target (`cargo bench --no-run`).

use std::io::Cursor;
use std::sync::Arc;
use std::time::Duration;

use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, ReplayPolicy,
};
use tdpc::runtime::BackendSpec;
use tdpc::server::{
    read_frame, write_frame, Client, InferRequestMsg, Kind, Server, ServerConfig,
};
use tdpc::tm::{BitVec64, TmModel};
use tdpc::util::{benchkit, SplitMix64};

const N_FEATURES: usize = 128;
const BURST: usize = 256;

fn random_rows(n: usize, seed: u64) -> Vec<BitVec64> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|_| {
            BitVec64::from_bools(
                &(0..N_FEATURES).map(|_| rng.next_bool(0.5)).collect::<Vec<bool>>(),
            )
        })
        .collect()
}

/// Measurement 1: pure codec throughput, no sockets.
fn bench_codec(rows: &[BitVec64]) {
    let frames: Vec<Vec<u8>> = rows
        .iter()
        .enumerate()
        .map(|(i, row)| {
            InferRequestMsg {
                corr: i as u64,
                model: "wire_bench".to_string(),
                n_features: row.len() as u32,
                words: row.words().to_vec(),
            }
            .encode()
        })
        .collect();

    let mut buf = Vec::with_capacity(frames.iter().map(|f| f.len() + 16).sum());
    let mean = benchkit::bench_with(
        &format!("serving_wire/codec_roundtrip_x{}", frames.len()),
        Duration::from_millis(200),
        Duration::from_secs(2),
        || {
            buf.clear();
            for payload in &frames {
                write_frame(&mut buf, Kind::InferRequest.as_u8(), payload).unwrap();
            }
            let mut rd = Cursor::new(buf.as_slice());
            let mut decoded = 0usize;
            while let Some((kind, payload)) = read_frame(&mut rd).unwrap() {
                assert_eq!(kind, Kind::InferRequest.as_u8());
                let req = InferRequestMsg::decode(&payload).unwrap();
                decoded += req.words.len();
            }
            assert_eq!(decoded, frames.len() * N_FEATURES.div_ceil(64));
        },
    );
    println!("  codec: {:.0} frames/s", benchkit::throughput(mean, frames.len()));
}

fn main() {
    let rows = random_rows(BURST, 31);
    bench_codec(&rows);

    // One pool behind both the TCP and the direct measurements, so the
    // wire overhead is the only difference.
    let model = Arc::new(TmModel::synthetic("wire_bench", 4, 16, N_FEATURES, 0.15, 17));
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 32, max_wait: Duration::from_micros(300) },
        n_workers: 2,
        dispatch: DispatchPolicy::RoundRobin,
        backend: BackendSpec::InMemorySet(Arc::new(vec![model])),
        replay: ReplayPolicy::Off,
        ..CoordinatorConfig::default()
    };
    let coord = Arc::new(
        Coordinator::start_multi(std::path::PathBuf::from("/unused"), &["wire_bench"], cfg)
            .unwrap(),
    );
    let server = Server::start(coord.clone(), "127.0.0.1:0", ServerConfig::default()).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();

    // Measurement 2: pipelined TCP round-trips (one connection; the
    // blocking client serializes request/reply, so this is per-request
    // wire latency, not peak pool throughput).
    let mean_tcp = benchkit::bench_with(
        &format!("serving_wire/tcp_roundtrip_x{BURST}"),
        Duration::from_millis(200),
        Duration::from_secs(2),
        || {
            for row in &rows {
                let resp =
                    client.infer_packed("wire_bench", row.len(), row.words().to_vec()).unwrap();
                assert!((resp.pred as usize) < 4);
            }
        },
    );
    let tcp_rps = benchkit::throughput(mean_tcp, BURST);
    println!("  tcp round-trip: {tcp_rps:.0} req/s");

    // Measurement 3: the same burst submitted directly to the pool.
    let mid = coord.model_id("wire_bench").unwrap();
    let mean_direct = benchkit::bench_with(
        &format!("serving_wire/direct_submit_x{BURST}"),
        Duration::from_millis(200),
        Duration::from_secs(2),
        || {
            let (tx, rx) = std::sync::mpsc::channel();
            for row in &rows {
                coord.submit_packed(mid, row.clone(), tx.clone());
            }
            drop(tx);
            let got = rx.iter().take(BURST).filter(|r| r.is_ok()).count();
            assert_eq!(got, BURST);
        },
    );
    // Rows/s through the shared reporting helper so this number lines up
    // with the `BENCH_hotpath.json` variants.
    let direct_rps = benchkit::report_rows_per_s("serving_wire/direct_submit", mean_direct, BURST);
    println!(
        "  wire overhead: tcp at {:.1}% of direct-submission throughput",
        100.0 * tcp_rps / direct_rps
    );

    server.shutdown();
    drop(client);
    drop(coord); // last Arc: the pool drains and joins via Drop
}
