//! §Perf L3 bench: coordinator serving path — round-trip latency and
//! closed-loop throughput across pool sizes, with and without the
//! time-domain hardware backend (replay policy: full).
//!
//! Needs `make artifacts`; `benches/hw_backend.rs` is the artifact-free
//! native-vs-replay sweep.

use std::time::Duration;

use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, ReplayPolicy,
};
use tdpc::flow::FlowConfig;
use tdpc::hw::HwArch;
use tdpc::runtime::BackendSpec;
use tdpc::tm::{Manifest, TestSet};
use tdpc::util::benchkit;

fn main() {
    let root = Manifest::default_root();
    let Ok(manifest) = Manifest::load(&root) else {
        eprintln!("SKIP coordinator: artifacts not built");
        return;
    };
    let cases = [
        ("iris_c10", 1usize, false),
        ("mnist_c100", 1, false),
        ("mnist_c100", 4, false),
        ("mnist_c100", 1, true),
    ];
    for (model_name, n_workers, hw) in cases {
        let entry = manifest.entry(model_name).unwrap().clone();
        let test = TestSet::load(&entry.test_data_path).unwrap();
        let (backend, replay) = if hw {
            (
                BackendSpec::TimeDomain {
                    arch: HwArch::Async,
                    flow: FlowConfig::table1_default(),
                    model: None,
                },
                ReplayPolicy::Full,
            )
        } else {
            (BackendSpec::Native, ReplayPolicy::Off)
        };
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 32, max_wait: Duration::from_micros(300) },
            n_workers,
            dispatch: DispatchPolicy::LeastLoaded,
            backend,
            replay,
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start(root.clone(), model_name, cfg).unwrap();
        let tag = format!("{model_name}_w{n_workers}{}", if hw { "+hw" } else { "" });

        // Round-trip latency (single in-flight request).
        benchkit::bench(&format!("coordinator/{tag}_roundtrip"), || {
            let _ = coord.infer_blocking(&test.x[0]).unwrap();
        });

        // Closed-loop burst throughput.
        let n = 512;
        let mean = benchkit::bench_with(
            &format!("coordinator/{tag}_burst512"),
            Duration::from_millis(200),
            Duration::from_secs(2),
            || {
                let (tx, rx) = std::sync::mpsc::channel();
                for i in 0..n {
                    coord.submit(&test.x[i % test.len()], tx.clone());
                }
                drop(tx);
                let got = rx.iter().take(n).filter(|r| r.is_ok()).count();
                assert_eq!(got, n);
            },
        );
        println!("  burst throughput: {:.0} req/s", benchkit::throughput(mean, n));
        let m = coord.metrics();
        println!(
            "  mean batch {:.1}, mean exec {:.0} µs",
            m.mean_batch_size, m.mean_batch_exec_us
        );
        if m.hw_mean_ns > 0.0 {
            println!("  hw decision latency: p50 {} p99 {}", m.hw_p50, m.hw_p99);
        }
        coord.shutdown();
    }
}
