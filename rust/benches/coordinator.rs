//! §Perf L3 bench: coordinator serving path — round-trip latency and
//! closed-loop throughput across pool sizes, with and without the
//! hardware replay engine.

use std::time::Duration;

use tdpc::asynctm::AsyncTmEngine;
use tdpc::baselines::DesignParams;
use tdpc::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy};
use tdpc::fabric::Device;
use tdpc::flow::FlowConfig;
use tdpc::tm::{Manifest, TestSet, TmModel};
use tdpc::util::benchkit;

fn main() {
    let root = Manifest::default_root();
    let Ok(manifest) = Manifest::load(&root) else {
        eprintln!("SKIP coordinator: artifacts not built");
        return;
    };
    let cases = [
        ("iris_c10", 1usize, false),
        ("mnist_c100", 1, false),
        ("mnist_c100", 4, false),
        ("mnist_c100", 1, true),
    ];
    for (model_name, n_workers, hw) in cases {
        let entry = manifest.entry(model_name).unwrap().clone();
        let test = TestSet::load(&entry.test_data_path).unwrap();
        let engines = if hw {
            let model = TmModel::load(&entry.model_path).unwrap();
            let d = DesignParams::from_model(&model);
            (0..n_workers)
                .map(|i| {
                    AsyncTmEngine::build(
                        &Device::xc7z020(),
                        &d,
                        &FlowConfig::table1_default(),
                        1 + i as u64,
                    )
                    .unwrap()
                })
                .collect()
        } else {
            Vec::new()
        };
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 32, max_wait: Duration::from_micros(300) },
            n_workers,
            dispatch: DispatchPolicy::LeastLoaded,
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start(root.clone(), model_name, cfg, engines).unwrap();
        let tag = format!("{model_name}_w{n_workers}{}", if hw { "+hw" } else { "" });

        // Round-trip latency (single in-flight request).
        benchkit::bench(&format!("coordinator/{tag}_roundtrip"), || {
            let _ = coord.infer_blocking(&test.x[0]).unwrap();
        });

        // Closed-loop burst throughput.
        let n = 512;
        let mean = benchkit::bench_with(
            &format!("coordinator/{tag}_burst512"),
            Duration::from_millis(200),
            Duration::from_secs(2),
            || {
                let (tx, rx) = std::sync::mpsc::channel();
                for i in 0..n {
                    coord.submit(&test.x[i % test.len()], tx.clone()).unwrap();
                }
                drop(tx);
                let got = rx.iter().take(n).count();
                assert_eq!(got, n);
            },
        );
        println!("  burst throughput: {:.0} req/s", benchkit::throughput(mean, n));
        let m = coord.metrics();
        println!(
            "  mean batch {:.1}, mean exec {:.0} µs",
            m.mean_batch_size, m.mean_batch_exec_us
        );
        coord.shutdown();
    }
}
