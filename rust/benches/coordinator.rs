//! §Perf L3 bench: coordinator serving path — round-trip latency and
//! closed-loop throughput across pool sizes, with and without the
//! time-domain hardware backend (replay policy: full), plus the cost of
//! model-keyed batching: a two-model interleaved burst vs the same
//! traffic through a single-model pool.
//!
//! The multi-model section is artifact-free (synthetic in-memory
//! models) and always runs; the per-artifact sweep needs
//! `make artifacts`. `benches/hw_backend.rs` is the artifact-free
//! native-vs-replay sweep.

use std::sync::Arc;
use std::time::Duration;

use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, ModelId, ReplayPolicy,
};
use tdpc::flow::FlowConfig;
use tdpc::hw::HwArch;
use tdpc::runtime::BackendSpec;
use tdpc::tm::{Manifest, TestSet, TmModel};
use tdpc::util::{benchkit, SplitMix64};

/// Burst `batches` of pre-built (model, row) submissions through the
/// pool and wait for every reply; returns requests served per second.
fn burst_throughput(
    name: &str,
    coord: &Coordinator,
    work: &[(ModelId, Vec<bool>)],
) -> f64 {
    let n = work.len();
    let mean = benchkit::bench_with(
        name,
        Duration::from_millis(200),
        Duration::from_secs(2),
        || {
            let (tx, rx) = std::sync::mpsc::channel();
            for (mid, x) in work {
                coord.submit(*mid, x, tx.clone());
            }
            drop(tx);
            let got = rx.iter().take(n).filter(|r| r.is_ok()).count();
            assert_eq!(got, n);
        },
    );
    benchkit::throughput(mean, n)
}

/// Model-keyed batching overhead, measured not assumed: the same 512-row
/// burst served (a) by a single-model pool, (b) as a two-model
/// interleaved stream through one multi-model pool — identical total
/// work per forward pass, but (b) pays the per-model pending map and
/// splits each worker's stream into two batch queues.
fn multi_model_overhead() {
    let a = Arc::new(TmModel::synthetic("mm_a", 8, 64, 128, 0.10, 7));
    let b = Arc::new(TmModel::synthetic("mm_b", 8, 64, 128, 0.10, 8));
    let mut rng = SplitMix64::new(11);
    let mut row = |f: usize| -> Vec<bool> { (0..f).map(|_| rng.next_bool(0.5)).collect() };
    let n = 512;

    let cfg = |backend: BackendSpec| CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 32, max_wait: Duration::from_micros(300) },
        n_workers: 2,
        dispatch: DispatchPolicy::LeastLoaded,
        backend,
        replay: ReplayPolicy::Off,
        ..CoordinatorConfig::default()
    };
    let root = std::path::PathBuf::from("/unused");

    // Baseline: one model, 512 rows.
    let solo = Coordinator::start(root.clone(), "mm_a", cfg(BackendSpec::InMemory(a.clone())))
        .unwrap();
    let sid = solo.model_id("mm_a").unwrap();
    let solo_work: Vec<(ModelId, Vec<bool>)> = (0..n).map(|_| (sid, row(128))).collect();
    let solo_rps = burst_throughput("coordinator/single_model_burst512", &solo, &solo_work);
    println!("  single-model burst: {solo_rps:.0} req/s");
    solo.shutdown();

    // Two models, alternating submissions, same total row count and the
    // same per-row compute shape.
    let set = BackendSpec::InMemorySet(Arc::new(vec![a, b]));
    let duo = Coordinator::start_multi(root, &["mm_a", "mm_b"], cfg(set)).unwrap();
    let mid_a = duo.model_id("mm_a").unwrap();
    let mid_b = duo.model_id("mm_b").unwrap();
    let duo_work: Vec<(ModelId, Vec<bool>)> = (0..n)
        .map(|i| (if i % 2 == 0 { mid_a } else { mid_b }, row(128)))
        .collect();
    let duo_rps = burst_throughput("coordinator/two_model_interleaved_burst512", &duo, &duo_work);
    println!("  two-model interleaved burst: {duo_rps:.0} req/s");
    let m = duo.metrics();
    println!(
        "  two-model mean batch {:.1} ({} batches); {:.1}% of single-model throughput",
        m.mean_batch_size,
        m.batches,
        100.0 * duo_rps / solo_rps
    );
    duo.shutdown();
}

fn main() {
    multi_model_overhead();

    let root = Manifest::default_root();
    let Ok(manifest) = Manifest::load(&root) else {
        eprintln!("SKIP coordinator artifact sweep: artifacts not built");
        return;
    };
    let cases = [
        ("iris_c10", 1usize, false),
        ("mnist_c100", 1, false),
        ("mnist_c100", 4, false),
        ("mnist_c100", 1, true),
    ];
    for (model_name, n_workers, hw) in cases {
        let entry = manifest.entry(model_name).unwrap().clone();
        let test = TestSet::load(&entry.test_data_path).unwrap();
        let (backend, replay) = if hw {
            (
                BackendSpec::TimeDomain {
                    arch: HwArch::Async,
                    flow: FlowConfig::table1_default(),
                    model: None,
                },
                ReplayPolicy::Full,
            )
        } else {
            (BackendSpec::Native, ReplayPolicy::Off)
        };
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch: 32, max_wait: Duration::from_micros(300) },
            n_workers,
            dispatch: DispatchPolicy::LeastLoaded,
            backend,
            replay,
            ..CoordinatorConfig::default()
        };
        let coord = Coordinator::start(root.clone(), model_name, cfg).unwrap();
        let mid = coord.model_id(model_name).unwrap();
        let tag = format!("{model_name}_w{n_workers}{}", if hw { "+hw" } else { "" });

        // Round-trip latency (single in-flight request).
        benchkit::bench(&format!("coordinator/{tag}_roundtrip"), || {
            let _ = coord.infer_blocking(mid, &test.x[0]).unwrap();
        });

        // Closed-loop burst throughput.
        let n = 512;
        let work: Vec<(ModelId, Vec<bool>)> =
            (0..n).map(|i| (mid, test.x[i % test.len()].clone())).collect();
        let rps = burst_throughput(&format!("coordinator/{tag}_burst512"), &coord, &work);
        println!("  burst throughput: {rps:.0} req/s");
        let m = coord.metrics();
        println!(
            "  mean batch {:.1}, mean exec {:.0} µs",
            m.mean_batch_size, m.mean_batch_exec_us
        );
        if m.hw_mean_ns > 0.0 {
            println!("  hw decision latency: p50 {} p99 {}", m.hw_p50, m.hw_p99);
        }
        coord.shutdown();
    }
}
