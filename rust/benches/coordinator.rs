//! §Perf L3 bench: coordinator serving path — round-trip latency and
//! closed-loop throughput, with and without the hardware replay engine.
use std::time::Duration;

use tdpc::asynctm::AsyncTmEngine;
use tdpc::baselines::DesignParams;
use tdpc::coordinator::{BatcherConfig, Coordinator};
use tdpc::fabric::Device;
use tdpc::flow::FlowConfig;
use tdpc::tm::{Manifest, TestSet, TmModel};
use tdpc::util::benchkit;

fn main() {
    let root = Manifest::default_root();
    let Ok(manifest) = Manifest::load(&root) else {
        eprintln!("SKIP coordinator: artifacts not built");
        return;
    };
    for (model_name, hw) in [("iris_c10", false), ("mnist_c100", false), ("mnist_c100", true)] {
        let entry = manifest.entry(model_name).unwrap().clone();
        let test = TestSet::load(&entry.test_data_path).unwrap();
        let engine = if hw {
            let model = TmModel::load(&entry.model_path).unwrap();
            let d = DesignParams::from_model(&model);
            Some(AsyncTmEngine::build(&Device::xc7z020(), &d, &FlowConfig::table1_default(), 1).unwrap())
        } else {
            None
        };
        let cfg = BatcherConfig { max_batch: 32, max_wait: Duration::from_micros(300) };
        let coord = Coordinator::start(root.clone(), model_name, cfg, engine).unwrap();
        let tag = if hw { "+hw" } else { "" };

        // Round-trip latency (single in-flight request).
        benchkit::bench(&format!("coordinator/{model_name}{tag}_roundtrip"), || {
            let _ = coord.infer_blocking(test.x[0].clone()).unwrap();
        });

        // Closed-loop burst throughput.
        let n = 512;
        let mean = benchkit::bench_with(
            &format!("coordinator/{model_name}{tag}_burst512"),
            Duration::from_millis(200),
            Duration::from_secs(2),
            || {
                let (tx, rx) = std::sync::mpsc::channel();
                for i in 0..n {
                    coord.submit(test.x[i % test.len()].clone(), tx.clone()).unwrap();
                }
                drop(tx);
                let got = rx.iter().take(n).count();
                assert_eq!(got, n);
            },
        );
        println!("  burst throughput: {:.0} req/s", benchkit::throughput(mean, n));
        let m = coord.metrics();
        println!("  mean batch {:.1}, mean exec {:.0} µs", m.mean_batch_size, m.mean_batch_exec_us);
        coord.shutdown();
    }
}
