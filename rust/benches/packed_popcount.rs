//! §Perf L3 bench: the packed popcount voter vs the legacy per-clause
//! summation — the speedup this repo's packed-bit-plane data path exists
//! to deliver, recorded in CI-compilable bench code.
//!
//! Three comparisons on an MNIST-c100-shaped synthetic model (hermetic,
//! no artifacts needed):
//!
//! 1. *summation only*: `class_sums_from_fired` (word-level
//!    `popcount(fired & pos) − popcount(fired & neg)` over polarity
//!    masks) vs `class_sums_per_clause` (test-and-add per clause bit) on
//!    identical fired words;
//! 2. *end-to-end packed*: `forward_packed` over a pre-packed batch —
//!    the production request path;
//! 3. *end-to-end legacy*: per-row bool clause bits + per-clause signed
//!    summation — the shape of the pre-packed-data-path backend loop.

use tdpc::tm::{bits, PackedBatch, TmModel};
use tdpc::util::{benchkit, SplitMix64};

const BATCH: usize = 32;

/// The old NativeBackend inner loop: bool clause bits per class, signed
/// per-clause accumulation, `Vec<i32>` fired lanes.
fn forward_legacy(model: &TmModel, rows: &[Vec<bool>]) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    let cpc = model.clauses_per_class;
    let mut sums = Vec::with_capacity(rows.len() * model.n_classes);
    let mut fired_lanes = Vec::with_capacity(rows.len() * model.c_total());
    let mut pred = Vec::with_capacity(rows.len());
    for row in rows {
        let bits = model.clause_bits(row);
        let mut best = 0usize;
        let mut best_sum = i32::MIN;
        for (ki, class_bits) in bits.iter().enumerate() {
            let mut s = 0i32;
            for (j, &f) in class_bits.iter().enumerate() {
                fired_lanes.push(f as i32);
                if f {
                    s += model.polarity[ki * cpc + j] as i32;
                }
            }
            if s > best_sum {
                best_sum = s;
                best = ki;
            }
            sums.push(s);
        }
        pred.push(best as i32);
    }
    (sums, fired_lanes, pred)
}

fn main() {
    let model = TmModel::synthetic("packed_vs_legacy", 10, 100, 784, 0.05, 7);
    let mut rng = SplitMix64::new(13);
    let rows: Vec<Vec<bool>> = (0..BATCH)
        .map(|_| (0..model.n_features).map(|_| rng.next_bool(0.5)).collect())
        .collect();
    let batch = PackedBatch::from_rows(&rows).unwrap();

    // -- 1. summation only, on identical fired words ----------------------
    let fired_rows: Vec<Vec<u64>> = (0..batch.rows())
        .map(|r| {
            let out = model.forward_packed(&PackedBatch::from_rows(&rows[r..r + 1]).unwrap());
            out.unwrap().fired_words_row(0).to_vec()
        })
        .collect();
    let mut i = 0usize;
    let m_pop = benchkit::bench("packed_popcount/sums_popcount_masks", || {
        let f = &fired_rows[i % fired_rows.len()];
        i += 1;
        std::hint::black_box(model.class_sums_from_fired(f));
    });
    let mut j = 0usize;
    let m_clause = benchkit::bench("packed_popcount/sums_per_clause", || {
        let f = &fired_rows[j % fired_rows.len()];
        j += 1;
        std::hint::black_box(model.class_sums_per_clause(f));
    });
    println!(
        "  summation speedup: ×{:.1} (popcount masks over per-clause loop)",
        m_clause / m_pop
    );

    // Cross-check before timing the end-to-end paths: both voters and
    // both forward passes must agree bit-for-bit.
    let packed_out = model.forward_packed(&batch).unwrap();
    let (legacy_sums, legacy_fired, legacy_pred) = forward_legacy(&model, &rows);
    assert_eq!(packed_out.sums, legacy_sums, "sums diverge");
    assert_eq!(packed_out.pred, legacy_pred, "preds diverge");
    for r in 0..BATCH {
        let unpacked: Vec<i32> =
            packed_out.fired_row(r).iter().map(|&b| b as i32).collect();
        assert_eq!(
            unpacked,
            legacy_fired[r * model.c_total()..(r + 1) * model.c_total()],
            "fired bits diverge at row {r}"
        );
        assert_eq!(
            model.class_sums_from_fired(&fired_rows[r]),
            model.class_sums_per_clause(&fired_rows[r]),
            "voters diverge at row {r}"
        );
    }

    // The hot-loop rework (clause index, chunked subset scan, early-exit
    // argmax) must also agree bit-for-bit with all of the above.
    assert_eq!(
        model.predict_packed(&batch).unwrap(),
        packed_out.pred,
        "early-exit argmax diverges"
    );
    let n_words = bits::words_for(model.c_total());
    let (mut full, mut scalar, mut indexed) =
        (vec![0u64; n_words], vec![0u64; n_words], vec![0u64; n_words]);
    for r in 0..BATCH {
        let lits = model.packed_literals(batch.row(r));
        model.fired_words_into(lits.words(), &mut full);
        model.fired_words_into_scalar(lits.words(), &mut scalar);
        model.fired_words_into_indexed(lits.words(), &mut indexed);
        assert_eq!(full, scalar, "chunked vs scalar scan diverge at row {r}");
        assert_eq!(full, indexed, "indexed scan diverges at row {r}");
        assert_eq!(&full[..], packed_out.fired_words_row(r), "scan vs forward at row {r}");
    }

    // -- 2 & 3. end-to-end forward passes ---------------------------------
    let m_packed = benchkit::bench("packed_popcount/forward_packed_b32", || {
        std::hint::black_box(model.forward_packed(&batch).unwrap());
    });
    let m_legacy = benchkit::bench("packed_popcount/forward_legacy_b32", || {
        std::hint::black_box(forward_legacy(&model, &rows));
    });
    println!(
        "  end-to-end: packed {:.0}/s vs legacy {:.0}/s (×{:.1})",
        benchkit::throughput(m_packed, BATCH),
        benchkit::throughput(m_legacy, BATCH),
        m_legacy / m_packed
    );
    println!(
        "  fired-row memory: {} B packed vs {} B as i32 lanes (×{:.0} smaller)",
        bits::words_for(model.c_total()) * 8,
        model.c_total() * 4,
        (model.c_total() * 4) as f64 / (bits::words_for(model.c_total()) * 8) as f64
    );
}
