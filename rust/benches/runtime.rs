//! §Perf L3 bench: the inference request path through the backend seam —
//! single-sample and batched execution, items/s throughput.
//!
//! Always benches a synthetic MNIST-scale model on the native backend (no
//! artifacts needed, so this runs in any checkout); additionally benches
//! every trained artifact model when `make artifacts` has been run.

use std::sync::Arc;

use tdpc::runtime::{InferenceBackend, ModelRegistry, NativeBackend};
use tdpc::tm::{Manifest, PackedBatch, TestSet, TmModel};
use tdpc::util::{benchkit, SplitMix64};

/// MNIST-c100-shaped synthetic model (10 classes × 100 clauses × 784
/// Boolean features) with a realistic include density.
fn synthetic_model() -> TmModel {
    TmModel::synthetic("synthetic_mnist", 10, 100, 784, 0.05, 7)
}

fn bench_backend(tag: &str, backend: &dyn InferenceBackend, rows: &[Vec<bool>]) {
    // Batches are packed once up front, as the coordinator does at
    // ingestion; the forward pass consumes words.
    let one = PackedBatch::from_rows(&rows[..1]).unwrap();
    let full = PackedBatch::from_rows(rows).unwrap();
    let m1 = benchkit::bench(&format!("runtime/{tag}_b1"), || {
        let _ = backend.forward(&one).unwrap();
    });
    let m32 = benchkit::bench(&format!("runtime/{tag}_b32"), || {
        let _ = backend.forward(&full).unwrap();
    });
    println!(
        "  throughput: b1 {:.0}/s, b32 {:.0}/s (batching gain ×{:.1})",
        benchkit::throughput(m1, 1),
        benchkit::throughput(m32, 32),
        benchkit::throughput(m32, 32) / benchkit::throughput(m1, 1)
    );
}

fn main() {
    // Hermetic part: synthetic model, runs everywhere.
    let model = synthetic_model();
    let mut rng = SplitMix64::new(11);
    let rows: Vec<Vec<bool>> =
        (0..32).map(|_| (0..model.n_features).map(|_| rng.next_bool(0.5)).collect()).collect();
    let backend = NativeBackend::new(Arc::new(model));
    bench_backend("synthetic_native", &backend, &rows);

    // Artifact part: every trained model, when artifacts exist.
    let Ok(manifest) = Manifest::load_default() else {
        eprintln!("SKIP runtime artifact models: artifacts not built");
        return;
    };
    let root = manifest.root.clone();
    let registry = ModelRegistry::open(&root).unwrap();
    println!("backend: {}", registry.platform());
    for entry in manifest.models {
        let test = TestSet::load(&entry.test_data_path).unwrap();
        let t0 = std::time::Instant::now();
        let backend = registry.backend(&entry.name).unwrap();
        println!("open {}: {:.1} ms (cold)", entry.name, t0.elapsed().as_secs_f64() * 1e3);
        let rows: Vec<Vec<bool>> = (0..32).map(|i| test.x[i % test.len()].clone()).collect();
        bench_backend(&entry.name, backend.as_ref(), &rows);
    }
}
