//! §Perf L3 bench: the PJRT request path — compile cost, single-sample and
//! batched execution per model, and items/s throughput.
use tdpc::runtime::{bools_to_f32, ModelRegistry};
use tdpc::tm::{Manifest, TestSet};
use tdpc::util::benchkit;

fn main() {
    let Ok(manifest) = Manifest::load_default() else {
        eprintln!("SKIP runtime: artifacts not built");
        return;
    };
    let registry = ModelRegistry::new(manifest).unwrap();
    println!("platform: {}", registry.platform());

    for entry in registry.manifest().models.clone() {
        let test = TestSet::load(&entry.test_data_path).unwrap();
        // Compile cost (fresh registry each iteration would re-create the
        // client too; measure the runner() path on a cold key instead).
        let t0 = std::time::Instant::now();
        let r1 = registry.runner(&entry.name, 1).unwrap();
        let r32 = registry.runner(&entry.name, 32).unwrap();
        println!("compile {}: {:.1} ms (both batch sizes, cold)", entry.name,
            t0.elapsed().as_secs_f64() * 1e3);

        let x1 = bools_to_f32(std::slice::from_ref(&test.x[0]));
        let rows: Vec<Vec<bool>> = (0..32).map(|i| test.x[i % test.len()].clone()).collect();
        let x32 = bools_to_f32(&rows);

        let m1 = benchkit::bench(&format!("runtime/{}_b1", entry.name), || {
            let _ = r1.run(&x1).unwrap();
        });
        let m32 = benchkit::bench(&format!("runtime/{}_b32", entry.name), || {
            let _ = r32.run(&x32).unwrap();
        });
        println!(
            "  throughput: b1 {:.0}/s, b32 {:.0}/s (batching gain ×{:.1})",
            benchkit::throughput(m1, 1),
            benchkit::throughput(m32, 32),
            benchkit::throughput(m32, 32) / benchkit::throughput(m1, 1)
        );
    }
}
