//! Bench/regenerator for Fig. 9 (latency / resources / power across the
//! four Table-I configurations).
use tdpc::experiments::fig9;
use tdpc::tm::Manifest;
use tdpc::util::benchkit;

fn main() {
    let Ok(manifest) = Manifest::load_default() else {
        eprintln!("SKIP fig9: artifacts not built");
        return;
    };
    let r = fig9::run(&manifest, 100).expect("fig9");
    for t in r.tables() {
        println!("{}", t.to_markdown());
    }
    for c in &r.configs {
        println!(
            "headline {}: latency reduction {:+.1}%, resources {:+.1}%, power {:+.1}%",
            c.name,
            100.0 * c.latency_reduction(),
            100.0 * c.resource_reduction(),
            100.0 * c.power_reduction()
        );
    }
    benchkit::bench_with(
        "fig9/mnist_c50_100samples",
        std::time::Duration::from_millis(100),
        std::time::Duration::from_secs(2),
        || {
            let _ = fig9::run_config(&manifest, "mnist_c50", 100, 1).unwrap();
        },
    );
}
