//! End-to-end driver (DESIGN.md §5): serve the MNIST-100 TM through the
//! full stack — multi-worker coordinator (dispatch + per-worker dynamic
//! batching) → time-domain hardware backend (`BackendSpec::TimeDomain`:
//! native bit-packed forward pass for functional results, one
//! independently-seeded simulated async die per worker) → full-replay
//! hardware timing on every response.
//!
//! Reports functional accuracy, service latency percentiles, throughput,
//! per-worker load, and the simulated on-chip async-vs-sync latency
//! ratio — the numbers recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example mnist_serving
//! ```
//!
//! `--smoke` runs the artifact-free **multi-model** exercise instead
//! (what CI drives as a binary): synthetic artifacts for two models of
//! different shapes are written to a temp dir, served through one pool,
//! and one of them is hot-swapped mid-traffic — asserting zero lost
//! requests and per-generation golden predictions throughout.
//!
//! ```sh
//! cargo run --release --example mnist_serving -- --smoke
//! ```

use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use tdpc::baselines::{Architecture, DesignParams, GenericAdder};
use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, ReplayPolicy, ShedPolicy,
};
use tdpc::flow::FlowConfig;
use tdpc::hw::HwArch;
use tdpc::runtime::BackendSpec;
use tdpc::tm::{Manifest, TestSet, TmModel};
use tdpc::util::SplitMix64;

const MODEL: &str = "mnist_c100";
const N_REQUESTS: usize = 2000;
const N_WORKERS: usize = 2;

fn main() -> Result<()> {
    if std::env::args().any(|a| a == "--smoke") {
        return smoke();
    }
    let root = Manifest::default_root();
    let manifest = Manifest::load(&root)?;
    let entry = manifest.entry(MODEL)?.clone();
    let test = TestSet::load(&entry.test_data_path)?;
    let model = TmModel::load(&entry.model_path)?;
    let d = DesignParams::from_model(&model);

    // Simulated hardware is just another backend: every worker builds its
    // own die from the spec, and the Full replay policy tags each response
    // with the on-chip decision latency of the paper's architecture.
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 32, max_wait: Duration::from_micros(400) },
        n_workers: N_WORKERS,
        dispatch: DispatchPolicy::LeastLoaded,
        backend: BackendSpec::TimeDomain {
            arch: HwArch::Async,
            flow: FlowConfig::table1_default(),
            model: None,
        },
        replay: ReplayPolicy::Full,
        // Fail-soft admission: bound each worker's in-flight load. The
        // open-loop burst below (all N_REQUESTS submitted before any
        // reply is read) peaks near N_REQUESTS / N_WORKERS ≈ 1000 per
        // worker, under the bound, so nothing is shed; raise N_REQUESTS
        // past ~8k and the overflow would see typed QueueFull errors
        // instead of unbounded queueing.
        queue_limit: Some(4096),
        shed: ShedPolicy::RejectNew,
        ..CoordinatorConfig::default()
    };
    println!(
        "starting {N_WORKERS}-worker coordinator for {MODEL} (backend {}, batch ≤ {}, deadline {:?})",
        cfg.backend.name(),
        cfg.batcher.max_batch,
        cfg.batcher.max_wait
    );
    let coord = Coordinator::start(root, MODEL, cfg)?;
    let mid = coord.model_id(MODEL).expect("started model resolves");
    assert_eq!(coord.n_features_for(mid), Some(model.n_features));

    // Open-loop burst load: every request submitted before any reply is
    // read, from the test set.
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    for i in 0..N_REQUESTS {
        coord.submit(mid, &test.x[i % test.len()], tx.clone());
    }
    drop(tx);
    // Every submit is answered exactly once — a response or a typed
    // InferError — so this loop can never hang on a dropped channel.
    let mut correct = 0usize;
    let mut hw_agree = 0usize;
    let mut served = 0usize;
    let mut failed = 0usize;
    let mut got = 0usize;
    for reply in rx.iter() {
        got += 1;
        match reply {
            Ok(resp) => {
                let idx = resp.request_id as usize % test.len();
                correct += (resp.pred == test.y[idx]) as usize;
                hw_agree += (resp.hw_winner == Some(resp.pred)) as usize;
                served += 1;
            }
            Err(e) => {
                eprintln!("request failed: {e}");
                failed += 1;
            }
        }
        if got == N_REQUESTS {
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics();

    println!("\n== end-to-end results ({served} served, {failed} failed) ==");
    println!("throughput:          {:.0} req/s ({wall:.2}s wall)", got as f64 / wall);
    println!("functional accuracy: {:.1}%", 100.0 * correct as f64 / served.max(1) as f64);
    println!("hw/functional agreement: {:.2}% ({} mismatches, ties only)",
        100.0 * hw_agree as f64 / served.max(1) as f64, m.hw_functional_mismatches);
    println!(
        "service latency:     p50 {:.0} µs, p99 {:.0} µs, mean {:.0} µs",
        m.service_p50_us, m.service_p99_us, m.service_mean_us
    );
    println!(
        "batching:            mean batch {:.1}, mean exec {:.0} µs/batch",
        m.mean_batch_size, m.mean_batch_exec_us
    );
    for (i, wm) in coord.worker_metrics().iter().enumerate() {
        println!(
            "  worker {i}:          {} requests, {} batches",
            wm.requests, wm.batches
        );
    }

    // The paper's comparison: simulated async hardware vs the synchronous
    // adder-based min clock period for the same model.
    let sync_ns = GenericAdder.latency(&d).total().as_ns();
    println!("\n== simulated on-chip latency (paper Fig. 9a) ==");
    println!(
        "async time-domain:   mean {:.1} ns, p50 {}, p99 {}",
        m.hw_mean_ns, m.hw_p50, m.hw_p99
    );
    println!("sync adder baseline: {sync_ns:.1} ns (min clock period)");
    println!(
        "async/sync ratio:    {:.2} ({}{:.1}% latency)",
        m.hw_mean_ns / sync_ns,
        if m.hw_mean_ns < sync_ns { "-" } else { "+" },
        (m.hw_mean_ns - sync_ns).abs() / sync_ns * 100.0
    );

    coord.shutdown();
    Ok(())
}

/// The artifact-free multi-model + hot-swap exercise CI runs as a
/// binary: two models of different shapes behind one pool, interleaved
/// traffic, one mid-run reload, everything asserted against in-process
/// goldens. Exits non-zero on any violated invariant.
fn smoke() -> Result<()> {
    let root = std::env::temp_dir().join(format!("tdpc-smoke-{}", std::process::id()));
    let result = smoke_in(&root);
    std::fs::remove_dir_all(&root).ok();
    result
}

fn smoke_in(root: &std::path::Path) -> Result<()> {
    // Two tenants with different widths and class counts, plus the
    // retrained v2 of tenant A that the reload swaps in.
    let a_v1 = TmModel::synthetic("smoke_a", 3, 12, 24, 0.2, 11);
    let a_v2 = TmModel::synthetic("smoke_a", 3, 12, 24, 0.2, 12);
    let b = TmModel::synthetic("smoke_b", 2, 10, 40, 0.25, 21);
    Manifest::write_synthetic(root, &[&a_v1, &b])?;

    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_micros(300) },
        n_workers: 2,
        dispatch: DispatchPolicy::RoundRobin,
        backend: BackendSpec::Native,
        replay: ReplayPolicy::Off,
        queue_limit: None,
        shed: ShedPolicy::RejectNew,
        ..CoordinatorConfig::default()
    };
    println!("smoke: 2-worker pool over synthetic artifacts at {}", root.display());
    let coord = Coordinator::start_multi(root.to_path_buf(), &["smoke_a", "smoke_b"], cfg)?;
    let mid_a = coord.model_id("smoke_a").expect("smoke_a served");
    let mid_b = coord.model_id("smoke_b").expect("smoke_b served");
    ensure!(coord.n_features_for(mid_a) == Some(24), "width table entry for smoke_a");
    ensure!(coord.n_features_for(mid_b) == Some(40), "width table entry for smoke_b");

    let mut rng = SplitMix64::new(7);
    let mut row = |f: usize| -> Vec<bool> { (0..f).map(|_| rng.next_bool(0.5)).collect() };
    let phase = 300usize; // interleaved submits per phase, per model

    let (tx, rx) = std::sync::mpsc::channel();
    let mut inputs_a = Vec::new();
    let mut inputs_b = Vec::new();
    let mut submit_round = |inputs_a: &mut Vec<Vec<bool>>, inputs_b: &mut Vec<Vec<bool>>| {
        for _ in 0..phase {
            let xa = row(24);
            let xb = row(40);
            coord.submit(mid_a, &xa, tx.clone());
            coord.submit(mid_b, &xb, tx.clone());
            inputs_a.push(xa);
            inputs_b.push(xb);
        }
    };

    // Phase 1 against generation 0, then hot-swap A while phase-1 rows
    // may still be in flight, then phase 2 against generation 1.
    submit_round(&mut inputs_a, &mut inputs_b);
    Manifest::write_synthetic(root, &[&a_v2, &b])?;
    coord.reload(mid_a)?;
    println!("smoke: reloaded smoke_a (generation 1) under live traffic");
    submit_round(&mut inputs_a, &mut inputs_b);
    drop(tx);

    let mut served = 0usize;
    for reply in rx.iter() {
        let resp = reply.map_err(|e| anyhow::anyhow!("request failed: {e}"))?;
        served += 1;
        // Ids are issued in submission order: even slots → A, odd → B,
        // alternating within each phase round.
        let round = resp.request_id as usize / 2;
        if resp.model == mid_a {
            let x = &inputs_a[round];
            let want = match resp.generation {
                0 => a_v1.predict(x),
                1 => a_v2.predict(x),
                g => anyhow::bail!("impossible generation {g} for smoke_a"),
            };
            ensure!(
                resp.pred == want,
                "smoke_a row {round}: pred {} != generation-{} golden {want}",
                resp.pred,
                resp.generation
            );
            // Phase 2 rows were submitted after reload() returned, so
            // they must all be served by the new generation.
            ensure!(
                round < phase || resp.generation == 1,
                "smoke_a row {round} served by generation {} after the swap",
                resp.generation
            );
        } else {
            ensure!(resp.model == mid_b && resp.generation == 0, "smoke_b untouched");
            ensure!(resp.pred == b.predict(&inputs_b[round]), "smoke_b row {round}");
        }
    }
    ensure!(served == 4 * phase, "zero-loss: {served} of {} replies", 4 * phase);

    let pool = coord.metrics();
    ensure!(pool.failed_batches == 0, "no forward call may fail");
    ensure!(pool.rejected_requests == 0, "no width rejections");
    let mut per_model_requests = 0;
    for (mid, name) in coord.served_models() {
        let pm = coord.metrics_for(mid).expect("served model has metrics");
        println!(
            "smoke: model {name}: {} requests in {} batches, p50 {:.0} µs p99 {:.0} µs",
            pm.requests, pm.batches, pm.service_p50_us, pm.service_p99_us
        );
        per_model_requests += pm.requests;
    }
    ensure!(
        per_model_requests == pool.requests,
        "per-model requests ({per_model_requests}) must sum to the pool total ({})",
        pool.requests
    );
    coord.shutdown();
    println!("smoke: OK ({served} served, zero lost, hot-swap verified)");
    Ok(())
}
