//! End-to-end driver (DESIGN.md §5): serve the MNIST-100 TM through the
//! full stack — multi-worker coordinator (dispatch + per-worker dynamic
//! batching) → time-domain hardware backend (`BackendSpec::TimeDomain`:
//! native bit-packed forward pass for functional results, one
//! independently-seeded simulated async die per worker) → full-replay
//! hardware timing on every response.
//!
//! Reports functional accuracy, service latency percentiles, throughput,
//! per-worker load, and the simulated on-chip async-vs-sync latency
//! ratio — the numbers recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example mnist_serving
//! ```

use std::time::{Duration, Instant};

use anyhow::Result;

use tdpc::baselines::{Architecture, DesignParams, GenericAdder};
use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, ReplayPolicy, ShedPolicy,
};
use tdpc::flow::FlowConfig;
use tdpc::hw::HwArch;
use tdpc::runtime::BackendSpec;
use tdpc::tm::{Manifest, TestSet, TmModel};

const MODEL: &str = "mnist_c100";
const N_REQUESTS: usize = 2000;
const N_WORKERS: usize = 2;

fn main() -> Result<()> {
    let root = Manifest::default_root();
    let manifest = Manifest::load(&root)?;
    let entry = manifest.entry(MODEL)?.clone();
    let test = TestSet::load(&entry.test_data_path)?;
    let model = TmModel::load(&entry.model_path)?;
    let d = DesignParams::from_model(&model);

    // Simulated hardware is just another backend: every worker builds its
    // own die from the spec, and the Full replay policy tags each response
    // with the on-chip decision latency of the paper's architecture.
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 32, max_wait: Duration::from_micros(400) },
        n_workers: N_WORKERS,
        dispatch: DispatchPolicy::LeastLoaded,
        backend: BackendSpec::TimeDomain {
            arch: HwArch::Async,
            flow: FlowConfig::table1_default(),
            model: None,
        },
        replay: ReplayPolicy::Full,
        // Fail-soft admission: bound each worker's in-flight load. The
        // open-loop burst below (all N_REQUESTS submitted before any
        // reply is read) peaks near N_REQUESTS / N_WORKERS ≈ 1000 per
        // worker, under the bound, so nothing is shed; raise N_REQUESTS
        // past ~8k and the overflow would see typed QueueFull errors
        // instead of unbounded queueing.
        queue_limit: Some(4096),
        shed: ShedPolicy::RejectNew,
    };
    println!(
        "starting {N_WORKERS}-worker coordinator for {MODEL} (backend {}, batch ≤ {}, deadline {:?})",
        cfg.backend.name(),
        cfg.batcher.max_batch,
        cfg.batcher.max_wait
    );
    let coord = Coordinator::start(root, MODEL, cfg)?;

    // Open-loop burst load: every request submitted before any reply is
    // read, from the test set.
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    for i in 0..N_REQUESTS {
        coord.submit(&test.x[i % test.len()], tx.clone());
    }
    drop(tx);
    // Every submit is answered exactly once — a response or a typed
    // InferError — so this loop can never hang on a dropped channel.
    let mut correct = 0usize;
    let mut hw_agree = 0usize;
    let mut served = 0usize;
    let mut failed = 0usize;
    let mut got = 0usize;
    for reply in rx.iter() {
        got += 1;
        match reply {
            Ok(resp) => {
                let idx = resp.request_id as usize % test.len();
                correct += (resp.pred == test.y[idx]) as usize;
                hw_agree += (resp.hw_winner == Some(resp.pred)) as usize;
                served += 1;
            }
            Err(e) => {
                eprintln!("request failed: {e}");
                failed += 1;
            }
        }
        if got == N_REQUESTS {
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics();

    println!("\n== end-to-end results ({served} served, {failed} failed) ==");
    println!("throughput:          {:.0} req/s ({wall:.2}s wall)", got as f64 / wall);
    println!("functional accuracy: {:.1}%", 100.0 * correct as f64 / served.max(1) as f64);
    println!("hw/functional agreement: {:.2}% ({} mismatches, ties only)",
        100.0 * hw_agree as f64 / served.max(1) as f64, m.hw_functional_mismatches);
    println!(
        "service latency:     p50 {:.0} µs, p99 {:.0} µs, mean {:.0} µs",
        m.service_p50_us, m.service_p99_us, m.service_mean_us
    );
    println!(
        "batching:            mean batch {:.1}, mean exec {:.0} µs/batch",
        m.mean_batch_size, m.mean_batch_exec_us
    );
    for (i, wm) in coord.worker_metrics().iter().enumerate() {
        println!(
            "  worker {i}:          {} requests, {} batches",
            wm.requests, wm.batches
        );
    }

    // The paper's comparison: simulated async hardware vs the synchronous
    // adder-based min clock period for the same model.
    let sync_ns = GenericAdder.latency(&d).total().as_ns();
    println!("\n== simulated on-chip latency (paper Fig. 9a) ==");
    println!(
        "async time-domain:   mean {:.1} ns, p50 {}, p99 {}",
        m.hw_mean_ns, m.hw_p50, m.hw_p99
    );
    println!("sync adder baseline: {sync_ns:.1} ns (min clock period)");
    println!(
        "async/sync ratio:    {:.2} ({}{:.1}% latency)",
        m.hw_mean_ns / sync_ns,
        if m.hw_mean_ns < sync_ns { "-" } else { "+" },
        (m.hw_mean_ns - sync_ns).abs() / sync_ns * 100.0
    );

    coord.shutdown();
    Ok(())
}
