//! FPGA design-flow walkthrough (paper §III-B, Figs. 3–6): place, pin-
//! assign and route a 150-element PDL, audit symmetry/skew, and
//! characterize the Hamming-weight response on several simulated dies.
//!
//! ```sh
//! cargo run --release --example design_flow
//! ```

use anyhow::Result;

use tdpc::fabric::{Device, VariationParams};
use tdpc::flow::{self, hamming_response, pins, skew_report, FlowConfig};
use tdpc::util::Ps;

fn main() -> Result<()> {
    let device = Device::xc7z020();
    println!(
        "device: {} — {} CLBs, {} LUTs, {} FFs",
        device.name,
        device.total_clbs(),
        device.total_luts(),
        device.total_ffs()
    );

    // Step 1/2 — placement + pin assignment audit (paper Fig. 2 inset).
    println!("\npin audit (minimal net delay per physical LUT pin):");
    for (pin, d) in pins::pin_audit() {
        println!("  {pin:?}: {d}");
    }
    let pa = pins::PinAssignment::fastest_pair();
    println!("assignment: low → {:?}, high → {:?}", pa.lo_pin, pa.hi_pin);

    // Step 3 — route 4 PDLs × 150 elements under Table-I delay windows.
    let cfg = FlowConfig::table1_default();
    let pdls = flow::run(&device, 4, 150, &cfg)?;
    let rep = skew_report(&pdls);
    println!("\nrouted 4 × 150-element PDLs (lo {} / hi {}):", cfg.lo_target, cfg.hi_target);
    println!("  mean per-stage Δ:        {}", rep.mean_delta);
    println!("  max stage skew (lo/hi):  {} / {}", rep.max_stage_skew_lo, rep.max_stage_skew_hi);
    println!("  max cumulative skew:     {} / {}", rep.max_cumulative_skew_lo, rep.max_cumulative_skew_hi);
    println!("  uniformity criterion:    {}", if rep.is_safe() { "PASS" } else { "FAIL" });

    // Step 4 — Hamming-weight response (paper Fig. 6) on three dies, for
    // the paper's two delay-difference settings.
    println!("\nHamming-weight response (150 elements, 8 vectors/weight):");
    for (label, hi) in [("Δ≈60 ps", 440u64), ("Δ≈600 ps", 980)] {
        for die in [1u64, 2, 3] {
            let cfg = FlowConfig {
                hi_target: Ps(hi),
                die_seed: die,
                variation: VariationParams { sigma_random: 0.035, ..VariationParams::default() },
                ..FlowConfig::table1_default()
            };
            let pdl = flow::run(&device, 1, 150, &cfg)?.remove(0);
            let resp = hamming_response(&pdl, 8, die);
            println!(
                "  {label} die {die}: Spearman ρ = {:+.5}, strictly monotonic: {}, delay {:.1} → {:.1} ns",
                resp.spearman_rho,
                resp.strictly_monotonic,
                resp.mean_delay_ns.first().unwrap(),
                resp.mean_delay_ns.last().unwrap(),
            );
        }
    }
    println!("\n(paper Fig. 6: ρ ≈ −1 for both, stronger at the larger Δ)");
    Ok(())
}
