//! MOUSETRAP protocol walkthrough (paper Fig. 7/8): run the event-driven
//! gate-level stage, then trace one asynchronous TM inference through the
//! STG and validate the causal order.
//!
//! ```sh
//! cargo run --release --example async_pipeline
//! ```

use anyhow::Result;

use tdpc::asynctm::stg::{trace_from_outcome, Stg};
use tdpc::asynctm::{mousetrap, AsyncTmEngine, MousetrapStage};
use tdpc::baselines::DesignParams;
use tdpc::fabric::Device;
use tdpc::flow::FlowConfig;
use tdpc::timing::{Circuit, Simulator};
use tdpc::tm::datasets::synthetic_clause_bits;
use tdpc::tm::WorkloadSpec;
use tdpc::util::{Ps, SplitMix64};

fn main() -> Result<()> {
    // Part 1 — gate-level MOUSETRAP stage on the event-driven simulator.
    println!("== gate-level MOUSETRAP stage (event-driven) ==");
    let stage = MousetrapStage::default();
    let mut c = Circuit::new();
    let nets = mousetrap::build_event_circuit(&mut c, &stage);
    let mut sim = Simulator::new(&c);
    for net in [nets.req_out, nets.enable, nets.data_out] {
        sim.watch(net);
    }
    sim.schedule(nets.data_in, true, Ps(100));
    sim.schedule(nets.req_in, true, Ps(300)); // bundled request
    sim.schedule(nets.ack_in, true, Ps(2_000)); // downstream consumes
    sim.run_until(Ps(100_000));
    println!("req_out transitions: {:?}", sim.trace(nets.req_out));
    println!("enable transitions:  {:?}", sim.trace(nets.enable));
    println!("(latch closes after accepting the token, reopens on ack)");
    println!("events processed: {}", sim.stats.events_processed);

    // Part 2 — one full asynchronous TM inference, traced through the STG.
    println!("\n== asynchronous TM inference (STG of Fig. 8) ==");
    let params = DesignParams::synthetic(4, 20, 64);
    let mut engine = AsyncTmEngine::build(
        &Device::xc7z020(),
        &params,
        &FlowConfig::table1_default(),
        7,
    )?;
    let spec = WorkloadSpec { n_classes: 4, clauses_per_class: 20, n_features: 64, fire_rate: 0.5 };
    let mut rng = SplitMix64::new(99);
    let bits = synthetic_clause_bits(&spec, 2, &mut rng);
    let out = engine.infer(&bits);
    let launch = engine.stage.latch_delay + engine.clause_bundle;
    let trace = trace_from_outcome(launch, &out);
    for ev in &trace {
        println!("  t={:>12} {:?}", ev.at.to_string(), ev.signal);
    }
    let stg = Stg::new(4);
    stg.validate(&trace)?;
    println!("STG validation: PASS");
    println!(
        "\nwinner class {} — decision at {} (Completion), cycle closes at {}",
        out.winner, out.decision_latency, out.cycle_latency
    );
    println!(
        "note: Completion precedes the slowest PDL output — the async win the paper exploits."
    );
    Ok(())
}
