//! Quickstart: load the trained Iris TM artifact, execute it on the
//! native (pure-Rust) backend, and replay each sample through the
//! simulated asynchronous time-domain hardware.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::{Context, Result};

use tdpc::asynctm::AsyncTmEngine;
use tdpc::baselines::DesignParams;
use tdpc::fabric::Device;
use tdpc::flow::FlowConfig;
use tdpc::runtime::{InferenceBackend, ModelRegistry};
use tdpc::tm::{Manifest, PackedBatch, TestSet, TmModel};

fn main() -> Result<()> {
    let root = Manifest::default_root();
    let registry = ModelRegistry::open(&root)?;

    // 1. Functional path: bit-packed clause evaluation + signed popcount +
    //    argmax straight from the trained weights (the same semantics the
    //    AOT-lowered HLO executes under `--features pjrt`).
    let manifest = registry.manifest().context("artifact manifest missing")?;
    let entry = manifest.entry("iris_c10")?.clone();
    let backend = registry.backend("iris_c10")?;
    println!("backend: {} (platform {})", backend.kind(), backend.platform());
    let test = TestSet::load(&entry.test_data_path)?;

    // 2. Hardware path: place & route 3 PDLs + arbiter tree on the
    //    XC7Z020 model and replay the clause bits per sample.
    let model = TmModel::load(&entry.model_path)?;
    let params = DesignParams::from_model(&model);
    let mut engine = AsyncTmEngine::build(
        &Device::xc7z020(),
        &params,
        &FlowConfig::table1_default(),
        1,
    )?;

    println!(
        "\niris_c10: {} classes × {} clauses, trained accuracy {:.1}%\n",
        model.n_classes, model.clauses_per_class, model.accuracy
    );

    let mut correct = 0;
    let n = test.len().min(10);
    for i in 0..n {
        let out = backend.forward(&PackedBatch::single(&test.x[i]))?;
        let hw = engine.infer(&out.clause_bits_row(0));
        let ok = out.pred[0] as usize == test.y[i];
        correct += ok as usize;
        println!(
            "sample {i}: sums {:?} → pred {} (label {}), hw winner {} in {} {}",
            out.sums_row(0),
            out.pred[0],
            test.y[i],
            hw.winner,
            hw.decision_latency,
            if ok { "✓" } else { "✗" },
        );
    }
    println!("\naccuracy on shown samples: {correct}/{n}");
    println!("hardware worst-case decision latency: {}", engine.worst_case_latency());
    Ok(())
}
