//! Time-domain BNN (paper §V future work): hidden layers as PDL-vs-neutral
//! sign races, output layer as the arbiter-tree argmax.
//!
//! ```sh
//! cargo run --release --example bnn_inference
//! ```
use anyhow::Result;
use tdpc::asynctm::bnn::TimeDomainBnn;
use tdpc::fabric::Device;
use tdpc::flow::FlowConfig;
use tdpc::util::SplitMix64;

fn main() -> Result<()> {
    let device = Device::xc7z020();
    let dims = [64, 16, 8, 4];
    let mut net = TimeDomainBnn::build(&device, &dims, &FlowConfig::table1_default(), 42)?;
    println!("time-domain BNN {dims:?} on {}", device.name);
    let mut agree = 0;
    let n = 50;
    let mut rng = SplitMix64::new(1);
    let mut lat_sum = 0.0;
    for s in 0..n {
        let inputs: Vec<bool> = (0..dims[0]).map(|_| rng.next_bool(0.5)).collect();
        let (hw, t) = net.forward(&inputs);
        let sw = net.reference_forward(&inputs, s as u64);
        agree += (hw == sw) as usize;
        lat_sum += t.as_ns();
        if s < 5 {
            println!("sample {s}: hw class {hw}, reference {sw}, completion {t}");
        }
    }
    println!("\nagreement {agree}/{n} (disagreements are sign-threshold races — the BNN analogue of the paper's classification metastability)");
    println!("mean completion latency {:.1} ns", lat_sum / n as f64);
    Ok(())
}
