//! Compile-only stub of the `xla` PJRT binding surface the `tdpc` crate
//! uses.
//!
//! The real `xla` crate links the XLA/PJRT C++ toolchain, which is not
//! available in hermetic build environments (CI, developer laptops without
//! the toolchain). This stub implements the exact API shape the `pjrt`
//! feature of `tdpc` compiles against, so `cargo build --features pjrt`
//! and `cargo clippy --features pjrt` work everywhere; every entry point
//! fails at *runtime* with a clear message.
//!
//! To execute HLO for real, replace this path dependency with a checkout
//! of the actual bindings (edit the `xla` entry in `rust/Cargo.toml`, or
//! add a `[patch]` section pointing at your xla-rs checkout). The types
//! here are deliberately `!Send`/`!Sync` — the real bindings wrap raw
//! PJRT pointers — so code written against the stub carries the same
//! threading constraints as code written against the real thing.

use std::fmt;
use std::marker::PhantomData;

/// Marker making stub types `!Send`/`!Sync`, like the real raw-pointer
/// wrappers.
type NotThreadSafe = PhantomData<*const ()>;

const STUB_MSG: &str = "xla stub: the real PJRT bindings are not linked into this build \
     (see rust/README.md — patch the `xla` dependency to enable execution)";

/// Error type mirroring the real binding's error enum.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub() -> Error {
        Error(STUB_MSG.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _marker: NotThreadSafe,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

/// A parsed HLO module proto (stub: parsing always fails).
pub struct HloModuleProto {
    _marker: NotThreadSafe,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _marker: NotThreadSafe,
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _marker: PhantomData }
    }
}

/// A compiled executable (stub: never constructible, execution fails).
pub struct PjRtLoadedExecutable {
    _marker: NotThreadSafe,
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _marker: NotThreadSafe,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

/// A host literal (stub: constructible, but conversions fail).
pub struct Literal {
    _marker: NotThreadSafe,
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _marker: PhantomData }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(Error::stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }
}
