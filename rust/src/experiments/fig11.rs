//! Fig. 11: resource utilization scaling — (a) vs clauses (6 classes),
//! (b) vs classes (100 clauses).
//!
//! Paper claim: every implementation grows linearly with model size, but
//! the time-domain popcount has the smallest increment, so its savings
//! persist at scale.

use crate::asynctm::TdAsync;
use crate::baselines::{Architecture, Async21, DesignParams, Fpt18, GenericAdder};

use super::Table;

#[derive(Debug, Clone)]
pub struct ResourcePoint {
    pub x: usize,
    pub generic: u32,
    pub fpt18: u32,
    pub async21: u32,
    pub td: u32,
}

pub struct Fig11Result {
    pub vs_clauses: Vec<ResourcePoint>,
    pub vs_classes: Vec<ResourcePoint>,
}

fn point(n_classes: usize, clauses: usize, x: usize) -> ResourcePoint {
    let d = DesignParams::synthetic(n_classes, clauses, 200);
    ResourcePoint {
        x,
        generic: GenericAdder.resources(&d).total(),
        fpt18: Fpt18.resources(&d).total(),
        async21: Async21.resources(&d).total(),
        td: TdAsync::default().resources(&d).total(),
    }
}

pub fn run() -> Fig11Result {
    Fig11Result {
        vs_clauses: super::fig10::CLAUSE_SWEEP
            .iter()
            .map(|&c| point(6, c, c))
            .collect(),
        vs_classes: super::fig10::CLASS_SWEEP
            .iter()
            .map(|&k| point(k, 100, k))
            .collect(),
    }
}

/// Least-squares slope of y over x (for the "smallest increment" claim).
fn slope(pts: &[(f64, f64)]) -> f64 {
    let n = pts.len() as f64;
    let (sx, sy): (f64, f64) = pts.iter().fold((0.0, 0.0), |a, p| (a.0 + p.0, a.1 + p.1));
    let (mx, my) = (sx / n, sy / n);
    let num: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let den: f64 = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    num / den
}

impl Fig11Result {
    pub fn tables(&self) -> Vec<Table> {
        let render = |title: &str, xlabel: &str, pts: &[ResourcePoint]| {
            let mut t = Table::new(
                title,
                &[xlabel, "generic", "fpt18", "async21", "td-async"],
            );
            for p in pts {
                t.row(vec![
                    p.x.to_string(),
                    p.generic.to_string(),
                    p.fpt18.to_string(),
                    p.async21.to_string(),
                    p.td.to_string(),
                ]);
            }
            t
        };
        vec![
            render("Fig. 11a — resources vs clauses (6 classes)", "clauses", &self.vs_clauses),
            render("Fig. 11b — resources vs classes (100 clauses)", "classes", &self.vs_classes),
        ]
    }

    /// Slopes of each architecture along a sweep.
    pub fn slopes(pts: &[ResourcePoint]) -> [f64; 4] {
        let xs: Vec<f64> = pts.iter().map(|p| p.x as f64).collect();
        let mk = |f: &dyn Fn(&ResourcePoint) -> u32| {
            slope(&xs.iter().copied().zip(pts.iter().map(|p| f(p) as f64)).collect::<Vec<_>>())
        };
        [
            mk(&|p| p.generic),
            mk(&|p| p.fpt18),
            mk(&|p| p.async21),
            mk(&|p| p.td),
        ]
    }

    /// Paper claims: all linear; TD has the smallest increment.
    pub fn shape_holds(&self) -> bool {
        for pts in [&self.vs_clauses, &self.vs_classes] {
            let [g, f, a, t] = Self::slopes(pts);
            if !(t < g && t < f && t < a) {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn td_has_smallest_resource_slope() {
        assert!(run().shape_holds());
    }

    #[test]
    fn async21_is_heaviest() {
        let r = run();
        for p in r.vs_clauses.iter().chain(&r.vs_classes) {
            assert!(p.async21 > p.generic, "dual-rail must cost most at x={}", p.x);
        }
    }

    #[test]
    fn growth_is_linear() {
        // Doubling clauses roughly doubles the clause-dependent part:
        // check R²-style sanity via endpoint ratio vs slope prediction.
        let r = run();
        let pts = &r.vs_clauses;
        let [g, ..] = Fig11Result::slopes(pts);
        let predicted = pts[0].generic as f64 + g * (pts.last().unwrap().x - pts[0].x) as f64;
        let actual = pts.last().unwrap().generic as f64;
        assert!((predicted / actual - 1.0).abs() < 0.15, "linear fit holds");
    }
}
