//! Table I: dataset / TM / PDL details — including the paper's trial-and-
//! error tuning of the high-latency net delay to the minimum that achieves
//! *lossless accuracy* (§IV-B).
//!
//! For each trained configuration we: evaluate the software model on its
//! test set; then sweep the high-latency routing target upward, rebuilding
//! the flow + PDLs + arbiter tree each time, until the simulated hardware's
//! classification accuracy matches the software accuracy (ties at the
//! arbiter may legitimately break either way, so the criterion is equal
//! accuracy, not per-sample agreement — exactly the paper's "lossless
//! accuracy" notion).

use anyhow::Result;

use crate::asynctm::AsyncTmEngine;
use crate::baselines::DesignParams;
use crate::fabric::Device;
use crate::flow::FlowConfig;
use crate::hw::HwEngine;
use crate::tm::{Manifest, TestSet, TmModel};
use crate::util::Ps;

use super::Table;

/// Tuning outcome for one configuration.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub name: String,
    pub dataset: String,
    pub n_classes: usize,
    pub n_features: usize,
    pub clauses_per_class: usize,
    pub t_param: f64,
    pub s_param: f64,
    pub sw_accuracy: f64,
    pub paper_accuracy: f64,
    /// Tuned net delays (Table I semantics).
    pub lo_net: Ps,
    pub hi_net: Ps,
    pub hw_accuracy: f64,
}

pub struct Table1Result {
    pub rows: Vec<Table1Row>,
}

/// Hardware accuracy of one engine over precomputed clause bits + sums —
/// engine-generic: works against any [`HwEngine`], not just the async
/// design (the tuning loop below drives the async engine through this
/// same seam the serving replay uses).
fn hw_accuracy(
    engine: &mut dyn HwEngine,
    clause_bits: &[Vec<Vec<bool>>],
    sums: &[Vec<i32>],
    labels: &[usize],
) -> f64 {
    let mut correct = 0usize;
    for ((bits, s), &y) in clause_bits.iter().zip(sums).zip(labels) {
        if engine.replay_row(bits, s).winner == y {
            correct += 1;
        }
    }
    correct as f64 / clause_bits.len() as f64
}

/// Tune the high-latency target for one model; returns (hi, hw_accuracy).
pub fn tune_hi_delay(
    model: &TmModel,
    test: &TestSet,
    max_samples: usize,
    die_seed: u64,
) -> Result<(Ps, f64, f64)> {
    // Samples whose top class sum is *tied* are excluded: argmax on a tie
    // is a coin flip in hardware (arbiter metastability) and an arbitrary
    // convention in software (paper footnote 1's "classification
    // metastability") — no delay tuning can make them agree.
    let mut xs: Vec<&Vec<bool>> = Vec::new();
    let mut ys: Vec<usize> = Vec::new();
    let mut kept_sums: Vec<Vec<i32>> = Vec::new();
    for (x, &y) in test.x.iter().zip(&test.y) {
        if xs.len() >= max_samples {
            break;
        }
        let sums = model.class_sums(x);
        let top = *sums.iter().max().unwrap();
        if sums.iter().filter(|&&s| s == top).count() == 1 {
            xs.push(x);
            ys.push(y);
            kept_sums.push(sums);
        }
    }
    let n = xs.len();
    anyhow::ensure!(n > 0, "every test sample is argmax-tied");
    // Software reference accuracy on the same subset.
    let sw_correct = xs
        .iter()
        .zip(&ys)
        .filter(|(x, &y)| model.predict(x) == y)
        .count();
    let sw_acc = sw_correct as f64 / n as f64;
    // Clause bits are delay-independent: compute once.
    let clause_bits: Vec<Vec<Vec<bool>>> = xs.iter().map(|x| model.clause_bits(x)).collect();

    let device = Device::xc7z020();
    let params = DesignParams::from_model(model);
    // The paper's sweep: smallest-possible low net, grow the high net until
    // lossless. Candidates step by 40 ps from just above the pin floor.
    for hi in (440..=1100).step_by(40) {
        let cfg = FlowConfig {
            lo_target: Ps(380),
            hi_target: Ps(hi),
            granularity: Ps(5),
            variation: crate::fabric::VariationParams::default(),
            die_seed,
        };
        let mut engine = AsyncTmEngine::build(&device, &params, &cfg, die_seed)?;
        let acc = hw_accuracy(&mut engine, &clause_bits, &kept_sums, &ys);
        if acc >= sw_acc {
            return Ok((Ps(hi), acc, sw_acc));
        }
    }
    anyhow::bail!("no lossless hi delay found up to 1100 ps for {}", model.name)
}

/// Run Table I for every model in the manifest.
pub fn run(manifest: &Manifest, max_samples: usize) -> Result<Table1Result> {
    let mut rows = Vec::new();
    for entry in &manifest.models {
        let model = TmModel::load(&entry.model_path)?;
        let test = TestSet::load(&entry.test_data_path)?;
        let (hi, hw_acc, sw_acc) = tune_hi_delay(&model, &test, max_samples, 1)?;
        rows.push(Table1Row {
            name: entry.name.clone(),
            dataset: entry.dataset.clone(),
            n_classes: entry.n_classes,
            n_features: entry.n_features,
            clauses_per_class: entry.clauses_per_class,
            t_param: entry.t,
            s_param: entry.s,
            sw_accuracy: sw_acc * 100.0,
            paper_accuracy: entry.paper_accuracy,
            lo_net: Ps(380),
            hi_net: hi,
            hw_accuracy: hw_acc * 100.0,
        });
    }
    Ok(Table1Result { rows })
}

impl Table1Result {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Table I — dataset, TM model and PDL details",
            &[
                "config", "dataset", "classes", "bool features", "clauses/class",
                "(T,s)", "sw acc %", "paper acc %", "low net (ps)", "high net (ps)",
                "hw acc %",
            ],
        );
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                r.dataset.clone(),
                r.n_classes.to_string(),
                r.n_features.to_string(),
                r.clauses_per_class.to_string(),
                format!("({},{})", r.t_param, r.s_param),
                format!("{:.1}", r.sw_accuracy),
                format!("{:.1}", r.paper_accuracy),
                r.lo_net.as_ps().to_string(),
                r.hi_net.as_ps().to_string(),
                format!("{:.1}", r.hw_accuracy),
            ]);
        }
        let mean_hi =
            self.rows.iter().map(|r| r.hi_net.as_ps_f64()).sum::<f64>() / self.rows.len().max(1) as f64;
        t.note(format!(
            "Mean tuned delays: low 380 ps / high {mean_hi:.1} ps (paper averages: 384.5 / 617.6 ps). \
             Hardware argmax is lossless at the tuned delta for every configuration."
        ));
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::model::TmModel;

    fn toy_model() -> TmModel {
        // 2 classes × 4 clauses over 3 features, hand-wired so that class 0
        // wins iff x0 ∧ x1, class 1 wins iff ¬x0.
        TmModel::assemble(
            "toy".into(),
            2,
            3,
            4,
            vec![
                vec![true, false, false, false, false, false], // +: x0
                vec![false, false, false, false, false, true], // −: ~x2
                vec![false, true, false, false, false, false], // +: x1
                vec![false, false, false, false, false, false],
                vec![false, false, false, true, false, false], // +: ~x0
                vec![false, false, false, false, false, false],
                vec![false, false, false, true, false, false], // +: ~x0
                vec![false, false, true, false, false, false], // −: x2
            ],
            vec![1, -1, 1, -1, 1, -1, 1, -1],
            vec![true, true, true, false, true, false, true, true],
            100.0,
        )
    }

    fn toy_testset(model: &TmModel) -> TestSet {
        // Labels = the model's own predictions ⇒ sw accuracy is 100 % and
        // "lossless" means the hardware matches the model exactly.
        let xs: Vec<Vec<bool>> = (0..8)
            .map(|i| vec![i & 1 != 0, i & 2 != 0, i & 4 != 0])
            .collect();
        let ys: Vec<usize> = xs.iter().map(|x| model.predict(x)).collect();
        TestSet { name: "toy".into(), n_features: 3, x: xs, y: ys }
    }

    #[test]
    fn tuning_finds_lossless_delta() {
        let model = toy_model();
        let test = toy_testset(&model);
        let (hi, hw_acc, sw_acc) = tune_hi_delay(&model, &test, 8, 5).unwrap();
        assert_eq!(sw_acc, 1.0);
        assert_eq!(hw_acc, 1.0, "tuned delta must be lossless");
        assert!(hi >= Ps(440));
    }

    #[test]
    fn tuned_delta_consistent_across_dies() {
        let model = toy_model();
        let test = toy_testset(&model);
        for die in [2u64, 9, 77] {
            let (_, hw_acc, _) = tune_hi_delay(&model, &test, 8, die).unwrap();
            assert_eq!(hw_acc, 1.0, "die {die}");
        }
    }
}
