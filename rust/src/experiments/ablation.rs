//! Ablation: is the paper's implementation flow actually necessary?
//!
//! §II-B argues PDLs "cannot be directly applied" without structural *and*
//! physical uniformity, and §III-B builds the placement/pin/routing flow to
//! provide it. This experiment removes the flow's ingredients one at a
//! time and re-measures the Fig. 6 monotonicity:
//!
//! * **full flow** — symmetric placement, A6/A5 pins, delay-range routing;
//! * **naive pins** — low/high nets on the *slowest* pin pair (A1/A2):
//!   same delta window but ~3× the per-stage latency (the latency cost the
//!   pin-assignment step avoids);
//! * **unconstrained routing** — no delay windows: every arc lands wherever
//!   general routing puts it (modeled as a per-arc uniform spread much
//!   wider than the window), destroying the weight→delay law.

use crate::fabric::{Device, VariationModel, VariationParams, LUT_LOGIC_DELAY};
use crate::flow::{hamming_response, place_pdls, route_pdl, FlowConfig, PinAssignment, RoutedElement, RoutedPdl};
use crate::util::{Ps, SplitMix64};

use super::Table;

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub variant: &'static str,
    pub spearman_rho: f64,
    pub strictly_monotonic: bool,
    pub mean_stage_ps: f64,
    /// Mean within-weight delay spread (ps) — per-sample count resolution.
    pub within_weight_sigma_ps: f64,
}

pub struct AblationResult {
    pub rows: Vec<AblationRow>,
}

fn response_of(pdl: &RoutedPdl, seed: u64) -> (f64, bool, f64, f64) {
    let r = hamming_response(pdl, 6, seed);
    let mean_stage = pdl
        .elements
        .iter()
        .map(|e| (e.lo_total.as_ps_f64() + e.hi_total.as_ps_f64()) / 2.0)
        .sum::<f64>()
        / pdl.len() as f64;
    // Mean within-weight spread: the popcount's per-sample resolution.
    // If two inputs of the same Hamming weight differ by more than one
    // stage delta, the PDL no longer encodes the count — regardless of how
    // monotone the *averages* look.
    let mean_sigma_ps =
        1000.0 * r.std_delay_ns.iter().sum::<f64>() / r.std_delay_ns.len() as f64;
    (r.spearman_rho, r.strictly_monotonic, mean_stage, mean_sigma_ps)
}

/// Unconstrained general routing: per-arc delays drawn uniformly from the
/// spread general routing exhibits (±40 % around a 500 ps mean — far wider
/// than the hi−lo window), i.e. what you get without the Fig. 3 flow.
fn unconstrained_pdl(n: usize, seed: u64) -> RoutedPdl {
    let device = Device::xc7z020();
    let placement = place_pdls(&device, 1, n).unwrap().remove(0);
    let mut rng = SplitMix64::new(seed ^ 0xAB1A);
    let elements = placement
        .sites
        .iter()
        .map(|&site| {
            let a = Ps::from_ps_f64(rng.next_range_f64(300.0, 700.0)) + LUT_LOGIC_DELAY;
            let b = Ps::from_ps_f64(rng.next_range_f64(300.0, 700.0)) + LUT_LOGIC_DELAY;
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            RoutedElement { site, lo_net: lo, hi_net: hi, lo_total: lo, hi_total: hi }
        })
        .collect();
    RoutedPdl { index: 0, elements }
}

pub fn run(n_elements: usize, die_seed: u64) -> AblationResult {
    let device = Device::xc7z020();
    let variation = VariationParams { sigma_random: 0.035, ..VariationParams::default() };
    let var = VariationModel::new(die_seed, variation);
    let placement = place_pdls(&device, 1, n_elements).unwrap().remove(0);
    let cfg = FlowConfig {
        lo_target: Ps(380),
        hi_target: Ps(618),
        granularity: Ps(5),
        variation,
        die_seed,
    };

    let mut rows = Vec::new();

    // Full flow.
    let full = route_pdl(&device, &placement, &PinAssignment::fastest_pair(), &cfg, &var).unwrap();
    let (rho, mono, stage, sigma) = response_of(&full, die_seed);
    rows.push(AblationRow { variant: "full flow (A6/A5 + windows)", spearman_rho: rho, strictly_monotonic: mono, mean_stage_ps: stage, within_weight_sigma_ps: sigma });

    // Naive pins: slowest pair, same windows (targets shifted up to the
    // slower pins' floor).
    let naive_pins = PinAssignment {
        lo_pin: crate::fabric::LutPin::A2,
        hi_pin: crate::fabric::LutPin::A1,
    };
    let slow_cfg = FlowConfig {
        lo_target: Ps(560),
        hi_target: Ps(798), // same 238 ps window at the slow pins' floor
        ..cfg
    };
    let slow = route_pdl(&device, &placement, &naive_pins, &slow_cfg, &var).unwrap();
    let (rho, mono, stage, sigma) = response_of(&slow, die_seed);
    rows.push(AblationRow { variant: "naive pins (A1/A2)", spearman_rho: rho, strictly_monotonic: mono, mean_stage_ps: stage, within_weight_sigma_ps: sigma });

    // Unconstrained routing.
    let un = unconstrained_pdl(n_elements, die_seed);
    let (rho, mono, stage, sigma) = response_of(&un, die_seed);
    rows.push(AblationRow { variant: "unconstrained routing", spearman_rho: rho, strictly_monotonic: mono, mean_stage_ps: stage, within_weight_sigma_ps: sigma });

    AblationResult { rows }
}

impl AblationResult {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Ablation — flow ingredients vs Fig. 6 monotonicity (150-element PDL)",
            &["variant", "Spearman ρ", "strictly monotonic", "mean stage (ps)", "within-weight σ (ps)"],
        );
        for r in &self.rows {
            t.row(vec![
                r.variant.to_string(),
                format!("{:.5}", r.spearman_rho),
                r.strictly_monotonic.to_string(),
                format!("{:.0}", r.mean_stage_ps),
                format!("{:.0}", r.within_weight_sigma_ps),
            ]);
        }
        t.note(
            "The paper's claim (§II-B): without the implementation flow, the \
             weight→delay relationship degrades. Naive pins keep monotonicity \
             but pay per-stage latency; unconstrained routing keeps only a \
             statistical trend (per-element deltas vary wildly), so per-weight \
             delay overlaps and ρ degrades — exact popcount is lost.",
        );
        t
    }

    /// Predicates the test suite asserts.
    pub fn shape_holds(&self) -> bool {
        let full = &self.rows[0];
        let naive = &self.rows[1];
        let unc = &self.rows[2];
        full.spearman_rho < -0.999
            && naive.spearman_rho < -0.999
            // Naive pins: same monotonicity, ≥25 % more per-stage latency.
            && naive.mean_stage_ps > full.mean_stage_ps * 1.25
            // Unconstrained routing: within-weight spread explodes past the
            // ~238 ps stage delta — per-sample popcount resolution is gone.
            && unc.within_weight_sigma_ps > 3.0 * full.within_weight_sigma_ps
            && unc.within_weight_sigma_ps > 238.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flow_ingredients_matter() {
        let r = run(150, 7);
        assert!(r.shape_holds(), "{:#?}", r.rows);
    }

    #[test]
    fn naive_pins_cost_latency_not_monotonicity() {
        let r = run(100, 3);
        assert!(r.rows[1].spearman_rho < -0.99);
        assert!(r.rows[1].mean_stage_ps > r.rows[0].mean_stage_ps + 150.0);
    }

    #[test]
    fn unconstrained_routing_destroys_count_resolution() {
        for die in [1u64, 5, 9] {
            let r = run(150, die);
            assert!(
                r.rows[2].within_weight_sigma_ps > 3.0 * r.rows[0].within_weight_sigma_ps,
                "die {die}: {:?}",
                r.rows
            );
        }
    }
}
