//! Fig. 6: PDL propagation delay vs input Hamming weight.
//!
//! A 150-element PDL is implemented through the full flow on a varied die
//! and characterized over every Hamming weight, for two hi−lo settings
//! (≈60 ps and ≈600 ps as in the paper). The paper's claims, asserted here
//! and recorded in EXPERIMENTS.md: Spearman's ρ ≈ −1 for both, stronger
//! (and strictly monotonic) for the larger delta.

use crate::fabric::{Device, VariationParams};
use crate::flow::{self, hamming_response, FlowConfig, HammingResponse};
use crate::util::Ps;

use super::Table;

/// One Fig. 6 series.
#[derive(Debug, Clone)]
pub struct Fig6Series {
    pub delta_label: String,
    pub hi_target: Ps,
    pub response: HammingResponse,
}

pub struct Fig6Result {
    pub series: Vec<Fig6Series>,
    pub n_elements: usize,
}

/// Run the experiment. `samples_per_weight` random bit placements average
/// out placement effects per weight (paper's characterization method [19]).
pub fn run(n_elements: usize, samples_per_weight: usize, die_seed: u64) -> Fig6Result {
    let device = Device::xc7z020();
    // σ chosen at the high end of intra-die variation so the 60 ps case is
    // visibly stressed, like the paper's measured board.
    let variation = VariationParams { sigma_random: 0.035, ..VariationParams::default() };
    let mut series = Vec::new();
    for (label, hi) in [("60 ps", Ps(440)), ("600 ps", Ps(980))] {
        let cfg = FlowConfig {
            lo_target: Ps(380),
            hi_target: hi,
            granularity: Ps(5),
            variation,
            die_seed,
        };
        let pdl = flow::run(&device, 1, n_elements, &cfg)
            .expect("flow must succeed for the Fig. 6 geometry")
            .remove(0);
        let response = hamming_response(&pdl, samples_per_weight, die_seed ^ 0xF16);
        series.push(Fig6Series { delta_label: label.to_string(), hi_target: hi, response });
    }
    Fig6Result { series, n_elements }
}

impl Fig6Result {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 6 — PDL propagation delay vs input Hamming weight",
            &["series", "hamming weight", "mean delay (ns)", "σ (ns)"],
        );
        for s in &self.series {
            // Sample every 10th weight for the record; the CSV keeps all.
            for (i, &w) in s.response.weights.iter().enumerate() {
                if w % 25 == 0 || w == self.n_elements {
                    t.row(vec![
                        s.delta_label.clone(),
                        w.to_string(),
                        format!("{:.3}", s.response.mean_delay_ns[i]),
                        format!("{:.4}", s.response.std_delay_ns[i]),
                    ]);
                }
            }
        }
        for s in &self.series {
            t.note(format!(
                "Δ={}: Spearman ρ = {:.5} (paper: ≈ −1), strictly monotonic: {}",
                s.delta_label, s.response.spearman_rho, s.response.strictly_monotonic
            ));
        }
        t
    }

    /// The paper's two claims as predicates (asserted by tests/benches).
    pub fn shape_holds(&self) -> bool {
        let rho60 = self.series[0].response.spearman_rho;
        let rho600 = self.series[1].response.spearman_rho;
        rho60 < -0.99 && rho600 <= rho60 && self.series[1].response.strictly_monotonic
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_reproduces_paper_shape() {
        let r = run(150, 6, 42);
        assert!(r.shape_holds(), "ρ60={}, ρ600={}",
            r.series[0].response.spearman_rho, r.series[1].response.spearman_rho);
    }

    #[test]
    fn fig6_shape_robust_across_dies() {
        for die in [1u64, 7, 1234] {
            let r = run(150, 4, die);
            assert!(r.shape_holds(), "die {die} breaks the Fig. 6 shape");
        }
    }

    #[test]
    fn table_has_both_series() {
        let t = run(100, 2, 3).table();
        assert!(t.rows.iter().any(|r| r[0] == "60 ps"));
        assert!(t.rows.iter().any(|r| r[0] == "600 ps"));
        assert_eq!(t.notes.len(), 2);
    }
}
