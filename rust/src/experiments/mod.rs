//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (DESIGN.md §4 maps each to its modules).
//!
//! Each experiment returns a [`Table`] (headers + rows) that renders to
//! markdown (for EXPERIMENTS.md) or CSV (for plotting); the benches, the
//! CLI (`tdpc table1|fig6|…`) and the examples all call the same functions,
//! so the recorded numbers always come from one code path.

pub mod ablation;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig6;
pub mod fig9;
pub mod table1;

use std::fmt::Write as _;

/// A rendered experiment result.
#[derive(Debug, Clone, PartialEq)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (observed shape vs the paper's claim).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        for n in &self.notes {
            let _ = writeln!(out, "\n> {n}");
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.join(","));
        }
        out
    }
}

/// Format helpers shared by the experiment modules.
pub fn ns(p: crate::util::Ps) -> String {
    format!("{:.2}", p.as_ns())
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown_and_csv() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.note("shape holds");
        let md = t.to_markdown();
        assert!(md.contains("### demo"));
        assert!(md.contains("| 1 | 2 |"));
        assert!(md.contains("> shape holds"));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
