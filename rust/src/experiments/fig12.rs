//! Fig. 12: dynamic power scaling at switching activity α ∈ {0.1, 0.5} —
//! (a) vs clauses (6 classes), (b) vs classes (100 clauses), all designs
//! compared at the same inference rate.
//!
//! Paper claims: at α = 0.1 the adder-based popcount consumes less power
//! (little switching); at α = 0.5 it degrades steeply while the
//! time-domain popcount barely moves (every delay element transitions once
//! per cycle regardless of data), making TD the most power-efficient and
//! the most *predictable* option.

use crate::asynctm::TdAsync;
use crate::baselines::{DesignParams, Fpt18, GenericAdder};
use crate::power::power_at_rate;

use super::Table;

/// Comparison rate: 1 M inferences/s for every design.
pub const RATE_HZ: f64 = 1e6;

#[derive(Debug, Clone)]
pub struct PowerPoint {
    pub x: usize,
    pub activity: f64,
    /// Popcount-stage power (mW) — the implementation Fig. 12 isolates.
    pub generic_mw: f64,
    pub fpt18_mw: f64,
    pub td_mw: f64,
}

pub struct Fig12Result {
    pub vs_clauses: Vec<PowerPoint>,
    pub vs_classes: Vec<PowerPoint>,
}

pub const ACTIVITIES: [f64; 2] = [0.1, 0.5];

fn point(n_classes: usize, clauses: usize, x: usize, alpha: f64) -> PowerPoint {
    let d = DesignParams::synthetic(n_classes, clauses, 200);
    // Fig. 12 compares the *popcount implementations*: popcount stage only.
    let pc = |p: crate::power::PowerBreakdown| p.popcount_mw;
    PowerPoint {
        x,
        activity: alpha,
        generic_mw: pc(power_at_rate(&GenericAdder, &d, alpha, RATE_HZ)),
        fpt18_mw: pc(power_at_rate(&Fpt18, &d, alpha, RATE_HZ)),
        td_mw: pc(power_at_rate(&TdAsync::default(), &d, alpha, RATE_HZ)),
    }
}

pub fn run() -> Fig12Result {
    let mut vs_clauses = Vec::new();
    let mut vs_classes = Vec::new();
    for &alpha in &ACTIVITIES {
        for &c in &super::fig10::CLAUSE_SWEEP {
            vs_clauses.push(point(6, c, c, alpha));
        }
        for &k in &super::fig10::CLASS_SWEEP {
            vs_classes.push(point(k, 100, k, alpha));
        }
    }
    Fig12Result { vs_clauses, vs_classes }
}

impl Fig12Result {
    pub fn tables(&self) -> Vec<Table> {
        let render = |title: &str, xlabel: &str, pts: &[PowerPoint]| {
            let mut t = Table::new(
                title,
                &[xlabel, "α", "generic (mW)", "fpt18 (mW)", "td-async (mW)"],
            );
            for p in pts {
                t.row(vec![
                    p.x.to_string(),
                    format!("{:.1}", p.activity),
                    format!("{:.3}", p.generic_mw),
                    format!("{:.3}", p.fpt18_mw),
                    format!("{:.3}", p.td_mw),
                ]);
            }
            t
        };
        vec![
            render("Fig. 12a — power vs clauses (6 classes, 1 M inf/s)", "clauses", &self.vs_clauses),
            render("Fig. 12b — power vs classes (100 clauses, 1 M inf/s)", "classes", &self.vs_classes),
        ]
    }

    /// Paper claims as predicates.
    pub fn shape_holds(&self) -> bool {
        let lo: Vec<&PowerPoint> =
            self.vs_clauses.iter().filter(|p| p.activity == 0.1).collect();
        let hi: Vec<&PowerPoint> =
            self.vs_clauses.iter().filter(|p| p.activity == 0.5).collect();
        // α=0.1: adder popcount cheaper at every size.
        let adder_wins_low = lo.iter().all(|p| p.generic_mw < p.td_mw);
        // α=0.5: TD cheaper at every size.
        let td_wins_high = hi.iter().all(|p| p.td_mw < p.generic_mw);
        // TD is activity-insensitive: ≤5 % change across α.
        let td_stable = lo.iter().zip(&hi).all(|(l, h)| {
            (l.td_mw - h.td_mw).abs() / l.td_mw.max(1e-12) < 0.05
        });
        // Adder is activity-sensitive: ≥2.5× change.
        let adder_sensitive = lo.iter().zip(&hi).all(|(l, h)| h.generic_mw > 2.5 * l.generic_mw);
        adder_wins_low && td_wins_high && td_stable && adder_sensitive
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_crossover_and_stability() {
        assert!(run().shape_holds());
    }

    #[test]
    fn power_grows_with_model_size() {
        let r = run();
        let lo: Vec<_> = r.vs_clauses.iter().filter(|p| p.activity == 0.1).collect();
        assert!(lo.last().unwrap().td_mw > lo.first().unwrap().td_mw);
        assert!(lo.last().unwrap().generic_mw > lo.first().unwrap().generic_mw);
    }
}
