//! Fig. 9: inference latency (a), resource utilization (b) and dynamic
//! power (c) for the four Table-I TM configurations across implementations,
//! with the popcount+comparison share of each metric (the paper's
//! bottleneck claim).
//!
//! Synchronous baselines report their minimum clock period (worst-case
//! critical path); the proposed async design reports the *measured mean*
//! decision latency over real test samples replayed through the built
//! engine (the paper averages over 100 samples), alongside its worst case.
//! Every architecture is additionally replayed per-request through the
//! unified [`crate::hw::HwEngine`] seam — the same executable engines the
//! serving path's `ReplayPolicy` drives — so the figure and the
//! coordinator benches share one code path.

use anyhow::Result;

use crate::asynctm::TdAsync;
use crate::baselines::{Architecture, Async21, DesignParams, Fpt18, GenericAdder};
use crate::flow::FlowConfig;
use crate::hw::{self, HwArch, HwEngine};
use crate::power::{power_at_rate, PowerBreakdown};
use crate::tm::{Manifest, TestSet, TmModel};
use crate::util::{stats, Ps};

use super::{ns, pct, Table};

/// All Fig. 9 numbers for one configuration.
#[derive(Debug, Clone)]
pub struct Fig9Config {
    pub name: String,
    /// (arch, total latency, popcount+compare share) — sync: min period.
    pub latency: Vec<(String, Ps, f64)>,
    /// Per-request decision latency measured through the unified engine
    /// seam: (arch label, mean ns, std ns), one entry per [`HwArch`].
    pub measured: Vec<(String, f64, f64)>,
    /// Measured async cycle-latency statistics (ns) over the sample set.
    pub td_measured_mean_ns: f64,
    pub td_measured_std_ns: f64,
    /// Mean Completion (decision-available) latency (ns).
    pub td_decision_mean_ns: f64,
    pub td_worst_ns: f64,
    /// (arch, LUTs+FFs, popcount+compare share).
    pub resources: Vec<(String, u32, f64)>,
    /// (arch, total mW, popcount+compare share, clock mW).
    pub power: Vec<(String, PowerBreakdown)>,
    /// Dataset-derived input switching activity.
    pub activity: f64,
}

pub struct Fig9Result {
    pub configs: Vec<Fig9Config>,
}

/// Mean fraction of Boolean features that toggle between consecutive
/// samples — the dataset-dependent activity factor Fig. 9c depends on.
pub fn dataset_activity(test: &TestSet) -> f64 {
    if test.len() < 2 {
        return 0.5;
    }
    let mut toggles = 0usize;
    let mut total = 0usize;
    for w in test.x.windows(2) {
        toggles += w[0].iter().zip(&w[1]).filter(|(a, b)| a != b).count();
        total += w[0].len();
    }
    toggles as f64 / total as f64
}

/// Run one manifest configuration (loads the model + test set).
pub fn run_config(
    manifest: &Manifest,
    name: &str,
    n_samples: usize,
    die_seed: u64,
) -> Result<Fig9Config> {
    let entry = manifest.entry(name)?;
    let model = TmModel::load(&entry.model_path)?;
    let test = TestSet::load(&entry.test_data_path)?;
    run_model(name, &model, &test, n_samples, die_seed)
}

/// Manifest-free core: all Fig. 9 numbers for one in-memory model + test
/// set (the experiments smoke test runs this on a synthetic pair).
pub fn run_model(
    name: &str,
    model: &TmModel,
    test: &TestSet,
    n_samples: usize,
    die_seed: u64,
) -> Result<Fig9Config> {
    let d = DesignParams::from_model(model);
    let activity = dataset_activity(test);

    // --- Per-request replay over real samples through the unified engine
    // seam (paper: 100 samples for the async measurement). The paper
    // reports the async design's full handshake *cycle* (bundling → PDLs
    // → join → ack) — what batch-mode throughput exposes; the
    // Completion-based decision latency goes in the notes.
    let n = test.len().min(n_samples);
    let rows: Vec<(Vec<Vec<bool>>, Vec<i32>)> = test
        .x
        .iter()
        .take(n)
        .map(|x| (model.clause_bits(x), model.class_sums(x)))
        .collect();
    // Engines wired from the model's true clause polarities, exactly like
    // the serving path's `HwBackend` (the alternating default de-phases
    // from a trained model whenever clauses/class is odd).
    let mut engines = hw::engine_list_for_model(model, &FlowConfig::table1_default(), die_seed)?;
    let mut measured = Vec::new();
    let mut td_cycle_ns = Vec::new();
    let mut td_decision_ns = Vec::new();
    let mut td_worst = 0.0;
    for eng in engines.iter_mut() {
        let mut decision = Vec::with_capacity(n);
        let mut cycle = Vec::with_capacity(n);
        for (bits, sums) in &rows {
            let o = eng.replay_row(bits, sums);
            decision.push(o.decision_latency.as_ns());
            cycle.push(o.cycle_latency.as_ns());
        }
        measured.push((
            eng.arch().arch_label().to_string(),
            stats::mean(&decision),
            stats::std_dev(&decision),
        ));
        if eng.arch() == HwArch::Async {
            td_worst = eng.worst_case().as_ns();
            td_cycle_ns = cycle;
            td_decision_ns = decision;
        }
    }
    let td_mean = stats::mean(&td_cycle_ns);
    let td_std = stats::std_dev(&td_cycle_ns);
    let td_decision_mean = stats::mean(&td_decision_ns);

    // --- Architecture handles.
    let td = TdAsync::default();
    let archs: Vec<(&str, &dyn Architecture)> = vec![
        ("generic", &GenericAdder),
        ("fpt18", &Fpt18),
        ("td-async", &td),
    ];

    let mut latency = Vec::new();
    for (nm, a) in &archs {
        let lb = a.latency(&d);
        let total = if *nm == "td-async" {
            // Report the measured mean for the async design.
            Ps::from_ps_f64(td_mean * 1000.0)
        } else {
            lb.total()
        };
        latency.push((nm.to_string(), total, lb.popcount_compare_share()));
    }

    let mut resources = Vec::new();
    for (nm, a) in archs
        .iter()
        .map(|(n, a)| (*n, *a))
        .chain(std::iter::once(("async21", &Async21 as &dyn Architecture)))
    {
        let rb = a.resources(&d);
        resources.push((nm.to_string(), rb.total(), rb.popcount_compare_share()));
    }

    // Iso-throughput power comparison (Fig. 9c): every design at the rate
    // the slowest one can sustain, so the clock-elimination and glitching
    // effects are isolated from throughput differences.
    let slowest = archs
        .iter()
        .map(|(_, a)| a.latency(&d).total().as_ps_f64())
        .fold(0.0f64, f64::max);
    let rate_hz = 1e12 / slowest.max(1.0);
    let mut power = Vec::new();
    for (nm, a) in &archs {
        power.push((nm.to_string(), power_at_rate(*a, &d, activity, rate_hz)));
    }

    Ok(Fig9Config {
        name: name.to_string(),
        latency,
        measured,
        td_measured_mean_ns: td_mean,
        td_measured_std_ns: td_std,
        td_decision_mean_ns: td_decision_mean,
        td_worst_ns: td_worst,
        resources,
        power,
        activity,
    })
}

pub fn run(manifest: &Manifest, n_samples: usize) -> Result<Fig9Result> {
    let mut configs = Vec::new();
    for entry in &manifest.models {
        configs.push(run_config(manifest, &entry.name, n_samples, 1)?);
    }
    Ok(Fig9Result { configs })
}

impl Fig9Config {
    fn latency_of(&self, arch: &str) -> Ps {
        self.latency.iter().find(|(n, _, _)| n == arch).unwrap().1
    }

    fn resources_of(&self, arch: &str) -> u32 {
        self.resources.iter().find(|(n, _, _)| n == arch).unwrap().1
    }

    fn power_of(&self, arch: &str) -> f64 {
        self.power.iter().find(|(n, _)| n == arch).unwrap().1.total()
    }

    /// Latency reduction of td-async vs the best adder-based sync design
    /// (positive = async wins; the paper's headline is +38 % at MNIST-50).
    pub fn latency_reduction(&self) -> f64 {
        let sync_best = self
            .latency_of("generic")
            .min(self.latency_of("fpt18"))
            .as_ps_f64();
        1.0 - self.latency_of("td-async").as_ps_f64() / sync_best
    }

    pub fn resource_reduction(&self) -> f64 {
        let best = ["generic", "fpt18", "async21"]
            .iter()
            .map(|a| self.resources_of(a))
            .min()
            .unwrap() as f64;
        1.0 - self.resources_of("td-async") as f64 / best
    }

    pub fn power_reduction(&self) -> f64 {
        let best = self.power_of("generic").min(self.power_of("fpt18"));
        1.0 - self.power_of("td-async") / best
    }
}

impl Fig9Result {
    pub fn tables(&self) -> Vec<Table> {
        let mut lat = Table::new(
            "Fig. 9a — inference latency",
            &["config", "arch", "latency (ns)", "pop+cmp share", "td reduction"],
        );
        for c in &self.configs {
            for (arch, t, share) in &c.latency {
                let red = if arch == "td-async" {
                    pct(c.latency_reduction())
                } else {
                    String::new()
                };
                lat.row(vec![c.name.clone(), arch.clone(), ns(*t), pct(*share), red]);
            }
            lat.note(format!(
                "{}: td-async measured cycle {:.1} ± {:.1} ns, decision (Completion) {:.1} ns, worst case {:.1} ns",
                c.name, c.td_measured_mean_ns, c.td_measured_std_ns,
                c.td_decision_mean_ns, c.td_worst_ns
            ));
            let per_arch: Vec<String> = c
                .measured
                .iter()
                .map(|(a, mean, std)| format!("{a} {mean:.1} ± {std:.1} ns"))
                .collect();
            lat.note(format!(
                "{}: per-request decision settle via the unified engine seam: {}",
                c.name,
                per_arch.join(", ")
            ));
        }

        let mut res = Table::new(
            "Fig. 9b — resource utilization (LUTs + FFs)",
            &["config", "arch", "LUT+FF", "pop+cmp share", "td reduction"],
        );
        for c in &self.configs {
            for (arch, total, share) in &c.resources {
                let red = if arch == "td-async" {
                    pct(c.resource_reduction())
                } else {
                    String::new()
                };
                res.row(vec![c.name.clone(), arch.clone(), total.to_string(), pct(*share), red]);
            }
        }

        let mut pow = Table::new(
            "Fig. 9c — dynamic power",
            &["config", "arch", "total (mW)", "pop+cmp share", "clock (mW)", "td reduction"],
        );
        for c in &self.configs {
            for (arch, p) in &c.power {
                let red = if arch == "td-async" {
                    pct(c.power_reduction())
                } else {
                    String::new()
                };
                pow.row(vec![
                    c.name.clone(),
                    arch.clone(),
                    format!("{:.3}", p.total()),
                    pct(p.popcount_compare_share()),
                    format!("{:.3}", p.clock_mw),
                    red,
                ]);
            }
            pow.note(format!("{}: dataset activity α = {:.3}", c.name, c.activity));
        }
        vec![lat, res, pow]
    }
}
