//! Fig. 10: latency scaling — (a) vs clause count at 6 classes, (b) vs
//! class count at 100 clauses.
//!
//! The paper's claims, which the shape predicates below assert:
//! * (a) generic grows ~logarithmically in clauses, FPT'18 and the
//!   time-domain design linearly (FPT'18's slope slightly below the TD
//!   average), so adder trees win for very long input vectors;
//! * (b) adder-based designs grow linearly in classes (sequential
//!   comparison) while the TD design is near-constant (arbiter levels);
//! * the TD average (±3σ, measured over 1000 synthetic samples as in the
//!   paper) sits far below the TD worst case, and the gap widens with
//!   model size.
//!
//! Every sweep point runs through the unified [`crate::hw::HwEngine`]
//! seam — the same executable engines the serving path replays against.

use crate::baselines::DesignParams;
use crate::flow::FlowConfig;
use crate::hw::{self, HwArch, HwEngine};
use crate::tm::datasets::{signed_sum, synthetic_clause_bits};
use crate::tm::WorkloadSpec;
use crate::util::{stats, SplitMix64};

use super::Table;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub x: usize,
    pub generic_ns: f64,
    pub fpt18_ns: f64,
    pub td_worst_ns: f64,
    pub td_mean_ns: f64,
    pub td_std_ns: f64,
}

pub struct Fig10Result {
    /// (a): x = clauses per class, 6 classes.
    pub vs_clauses: Vec<SweepPoint>,
    /// (b): x = classes, 100 clauses per class.
    pub vs_classes: Vec<SweepPoint>,
}

pub const CLAUSE_SWEEP: [usize; 5] = [25, 50, 100, 200, 400];
pub const CLASS_SWEEP: [usize; 5] = [2, 4, 8, 16, 32];

fn measure_point(n_classes: usize, clauses: usize, samples: usize, seed: u64) -> SweepPoint {
    let d = DesignParams::synthetic(n_classes, clauses, 200);
    let spec = WorkloadSpec {
        n_classes,
        clauses_per_class: clauses,
        n_features: 200,
        fire_rate: 0.5,
    };

    // All three architectures run through the unified engine seam
    // (`hw::engine_list`): the synchronous engines report their cycle
    // latency — the minimum clock period, i.e. the analytic bound — while
    // the async design measures per-sample decision latencies over
    // synthetic clause vectors (the paper: 1000 MNIST samples).
    let mut engines = hw::engine_list(&d, &FlowConfig::table1_default(), seed)
        .expect("sweep geometry must place");
    let mut rng = SplitMix64::new(seed ^ 0x10a);
    let mut generic_ns = 0.0;
    let mut fpt18_ns = 0.0;
    let (mut td_worst, mut td_mean, mut td_std) = (0.0, 0.0, 0.0);
    for eng in engines.iter_mut() {
        match eng.arch() {
            HwArch::Adder | HwArch::Fpt18 => {
                // Sync cycle latency is the data-independent minimum
                // clock period — no sample replay needed to read it.
                let cycle = eng.worst_case().as_ns();
                if eng.arch() == HwArch::Adder {
                    generic_ns = cycle;
                } else {
                    fpt18_ns = cycle;
                }
            }
            HwArch::Async => {
                let mut lat = Vec::with_capacity(samples);
                for i in 0..samples {
                    let bits = synthetic_clause_bits(&spec, i % n_classes, &mut rng);
                    let sums: Vec<i32> = bits.iter().map(|b| signed_sum(b)).collect();
                    lat.push(eng.replay_row(&bits, &sums).decision_latency.as_ns());
                }
                td_worst = eng.worst_case().as_ns();
                td_mean = stats::mean(&lat);
                td_std = stats::std_dev(&lat);
            }
        }
    }
    SweepPoint {
        x: if n_classes == 6 { clauses } else { n_classes },
        generic_ns,
        fpt18_ns,
        td_worst_ns: td_worst,
        td_mean_ns: td_mean,
        td_std_ns: td_std,
    }
}

pub fn run(samples_per_point: usize) -> Fig10Result {
    let vs_clauses = CLAUSE_SWEEP
        .iter()
        .map(|&c| measure_point(6, c, samples_per_point, 17))
        .collect();
    let vs_classes = CLASS_SWEEP
        .iter()
        .map(|&k| measure_point(k, 100, samples_per_point, 29))
        .collect();
    Fig10Result { vs_clauses, vs_classes }
}

impl Fig10Result {
    pub fn tables(&self) -> Vec<Table> {
        let render = |title: &str, xlabel: &str, pts: &[SweepPoint]| {
            let mut t = Table::new(
                title,
                &[xlabel, "generic (ns)", "fpt18 (ns)", "td mean (ns)", "td ±3σ", "td worst (ns)"],
            );
            for p in pts {
                t.row(vec![
                    p.x.to_string(),
                    format!("{:.1}", p.generic_ns),
                    format!("{:.1}", p.fpt18_ns),
                    format!("{:.1}", p.td_mean_ns),
                    format!("{:.1}", 3.0 * p.td_std_ns),
                    format!("{:.1}", p.td_worst_ns),
                ]);
            }
            t
        };
        vec![
            render("Fig. 10a — latency vs clauses (6 classes)", "clauses", &self.vs_clauses),
            render("Fig. 10b — latency vs classes (100 clauses)", "classes", &self.vs_classes),
        ]
    }

    /// Shape predicates (paper claims).
    pub fn shape_holds(&self) -> (bool, bool, bool, bool) {
        // (a) generic sublinear: 16× clauses < 4× latency.
        let g = &self.vs_clauses;
        let generic_sublinear =
            g.last().unwrap().generic_ns / g.first().unwrap().generic_ns < 4.0;
        // (a) td linear-ish in clauses: 16× clauses ⇒ >8× mean latency.
        let td_linear = g.last().unwrap().td_mean_ns / g.first().unwrap().td_mean_ns > 8.0;
        // (b) generic roughly linear in classes.
        let k = &self.vs_classes;
        let generic_linear_classes =
            k.last().unwrap().generic_ns / k.first().unwrap().generic_ns > 6.0;
        // (b) td near-constant in classes.
        let td_constant_classes =
            k.last().unwrap().td_mean_ns / k.first().unwrap().td_mean_ns < 1.5;
        (generic_sublinear, td_linear, generic_linear_classes, td_constant_classes)
    }

    /// The ±3σ claim: worst case sits far outside the measured band, and
    /// increasingly so for larger models.
    pub fn worst_case_improbable(&self) -> bool {
        self.vs_clauses
            .iter()
            .all(|p| p.td_worst_ns > p.td_mean_ns + 3.0 * p.td_std_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_shapes_match_paper() {
        let r = run(60);
        let (g_sub, td_lin, g_lin_k, td_const_k) = r.shape_holds();
        assert!(g_sub, "generic must scale sub-linearly with clauses (Fig. 10a)");
        assert!(td_lin, "TD must scale linearly with clauses (Fig. 10a)");
        assert!(g_lin_k, "adder designs must scale linearly with classes (Fig. 10b)");
        assert!(td_const_k, "TD must be near-constant in classes (Fig. 10b)");
        assert!(r.worst_case_improbable(), "±3σ band must exclude the worst case");
    }

    #[test]
    fn adder_wins_at_large_clause_counts() {
        // Paper: "for large input vectors, adder-based designs may have a
        // latency advantage over the time-domain popcount."
        let r = run(30);
        let last = r.vs_clauses.last().unwrap();
        assert!(last.generic_ns < last.td_mean_ns, "crossover at 400 clauses");
    }

    #[test]
    fn td_wins_at_many_classes() {
        let r = run(30);
        let last = r.vs_classes.last().unwrap();
        assert!(last.td_mean_ns < last.generic_ns, "TD must win at 32 classes");
        assert!(last.td_mean_ns < last.fpt18_ns);
    }
}
