//! The unified hardware-engine seam: every architecture of the paper's
//! comparison is *executable* on the request path.
//!
//! [`HwEngine`] is the per-request timing contract: it consumes one
//! sample's clause bits (the PDL select inputs, as produced by
//! [`ForwardOutput::clause_bits_row`]) plus the signed class sums, and
//! returns the hardware's argmax, its decision/cycle latency, and the
//! switching inventory of that inference. Three implementations exist:
//!
//! * [`crate::asynctm::AsyncTmEngine`] — the proposed asynchronous
//!   time-domain design: winner from the arbiter race, decision latency =
//!   the *winning* PDL traversal (bigger class sums finish **faster**).
//! * [`SyncReplayEngine`] over [`GenericAdder`] — the synchronous adder
//!   tree: winner from a sequential argmax, cycle latency = the minimum
//!   clock period, and a per-request combinational *settle* model in
//!   which wider actual class sums ripple **longer** carry chains — the
//!   inverse of the time-domain law.
//! * [`SyncReplayEngine`] over [`Fpt18`] — the ripple-chain popcount:
//!   settle tracks the furthest fired clause position in any class.
//!
//! Experiments ([`crate::experiments::fig9`], `fig10`), the serving path
//! ([`crate::runtime`]'s `HwBackend` + the coordinator's `ReplayPolicy`),
//! and the benches all iterate the same [`engine_list`], so paper figures
//! and production replay share one code path.
//!
//! Tie-break contract: the synchronous engines resolve argmax ties to the
//! *lowest* class index, exactly like `jnp.argmax` and the native
//! functional path — their winner is bit-identical to the functional
//! prediction on every input. The asynchronous engine resolves ties by an
//! arbiter race (paper footnote 1's "classification metastability"), so
//! it may legitimately disagree on exact class-sum ties and only there —
//! with one physical caveat: a class-k PDL's arrival encodes
//! `neg_count(k) + sum(k)` (a non-firing negative clause takes the short
//! arc), so classes with *unequal negative-clause counts* shift the race
//! by the difference. Balanced polarity (every trained artifact; any even
//! `clauses_per_class` under the alternating convention) makes the offset
//! uniform and the contract exact; odd clauses/class biases margin-1
//! decisions by one vote. [`HwArch::build_for_model`] wires the model's
//! true signs so this is the *only* residual divergence.

use anyhow::Result;

use crate::asynctm::{AsyncTmEngine, TdAsync};
use crate::baselines::{
    calib, Architecture, DesignParams, Fpt18, GenericAdder, LatencyBreakdown, ToggleInventory,
};
use crate::fabric::Device;
use crate::flow::FlowConfig;
use crate::pdl::Polarity;
use crate::tm::model::ForwardOutput;
use crate::tm::TmModel;
use crate::util::Ps;

/// Which hardware architecture an engine (or backend) simulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HwArch {
    /// The paper's proposed asynchronous time-domain design.
    Async,
    /// "Generic implementation": synchronous compressor/adder tree.
    Adder,
    /// Kim et al. FPT'18 ripple-chain popcount.
    Fpt18,
}

impl HwArch {
    /// Every architecture, in the order the paper's tables list them
    /// (synchronous baselines first, the proposed design last).
    pub const ALL: [HwArch; 3] = [HwArch::Adder, HwArch::Fpt18, HwArch::Async];

    /// Parse a CLI-style architecture name (`hw:<name>` backend syntax).
    pub fn from_name(name: &str) -> Result<HwArch> {
        match name {
            "async" => Ok(HwArch::Async),
            "adder" => Ok(HwArch::Adder),
            "fpt18" => Ok(HwArch::Fpt18),
            other => anyhow::bail!(
                "unknown hardware architecture {other:?} (expected: async, adder, fpt18)"
            ),
        }
    }

    /// CLI / backend-spec name.
    pub fn name(self) -> &'static str {
        match self {
            HwArch::Async => "async",
            HwArch::Adder => "adder",
            HwArch::Fpt18 => "fpt18",
        }
    }

    /// Row label used by the experiment tables (Fig. 9/10 conventions).
    pub fn arch_label(self) -> &'static str {
        match self {
            HwArch::Async => "td-async",
            HwArch::Adder => "generic",
            HwArch::Fpt18 => "fpt18",
        }
    }

    /// Build the executable engine for this architecture. The async design
    /// runs the full implementation flow (placement → pins → routing) on
    /// the canonical device; the synchronous designs need no flow.
    pub fn build(
        self,
        d: &DesignParams,
        flow: &FlowConfig,
        seed: u64,
    ) -> Result<Box<dyn HwEngine>> {
        match self {
            HwArch::Async => {
                let eng = AsyncTmEngine::build(&Device::xc7z020(), d, flow, seed)
                    .map_err(anyhow::Error::from)?;
                Ok(Box::new(eng))
            }
            HwArch::Adder | HwArch::Fpt18 => Ok(Box::new(SyncReplayEngine::new(self, d))),
        }
    }

    /// [`HwArch::build`] for a trained model: the async design wires each
    /// PDL element's polarity from the model's class-major clause
    /// polarities (via [`AsyncTmEngine::build_with_polarities`]), so the
    /// replayed clause bits race with exactly the vote signs the
    /// functional argmax counts — the alternating default de-phases from
    /// the model whenever `clauses_per_class` is odd. The synchronous
    /// engines take their argmax from the class sums directly and need
    /// only the design parameters.
    pub fn build_for_model(
        self,
        model: &TmModel,
        flow: &FlowConfig,
        seed: u64,
    ) -> Result<Box<dyn HwEngine>> {
        let d = DesignParams::from_model(model);
        match self {
            HwArch::Async => {
                let cpc = model.clauses_per_class;
                let pols: Vec<Vec<Polarity>> = (0..model.n_classes)
                    .map(|k| {
                        (0..cpc)
                            .map(|j| {
                                if model.polarity[k * cpc + j] > 0 {
                                    Polarity::Positive
                                } else {
                                    Polarity::Negative
                                }
                            })
                            .collect()
                    })
                    .collect();
                let eng = AsyncTmEngine::build_with_polarities(
                    &Device::xc7z020(),
                    &d,
                    flow,
                    seed,
                    &pols,
                )
                .map_err(anyhow::Error::from)?;
                Ok(Box::new(eng))
            }
            HwArch::Adder | HwArch::Fpt18 => Ok(Box::new(SyncReplayEngine::new(self, &d))),
        }
    }
}

/// Result of replaying one sample through a hardware engine.
#[derive(Debug, Clone, PartialEq)]
pub struct HwOutcome {
    /// The hardware's argmax class (see the module-level tie contract).
    pub winner: usize,
    /// Request → classification available. Per-request: a function of the
    /// actual class sums, not an analytic worst-case bound.
    pub decision_latency: Ps,
    /// Request → ready for the next sample (async: the handshake join;
    /// sync: the minimum clock period).
    pub cycle_latency: Ps,
    /// Switching inventory of this inference (feeds [`crate::power`]).
    pub toggles: ToggleInventory,
}

/// One executable hardware architecture: batched per-request replay of
/// clause bits + class sums into winner / latency / toggles.
///
/// Engines are stateful (`&mut self`): the async engine owns the arbiter
/// metastability RNG, the synchronous engines track the previous fired
/// vector for their data-dependent toggle model.
pub trait HwEngine: Send {
    fn arch(&self) -> HwArch;

    /// Replay one sample: `clause_bits[k]` are class k's clause outputs
    /// (as from [`ForwardOutput::clause_bits_row`]), `sums` the signed
    /// class sums of the same sample.
    fn replay_row(&mut self, clause_bits: &[Vec<bool>], sums: &[i32]) -> HwOutcome;

    /// Replay every row of a forward output, in order.
    fn replay(&mut self, out: &ForwardOutput) -> Vec<HwOutcome> {
        let mut v = Vec::with_capacity(out.batch);
        for b in 0..out.batch {
            v.push(self.replay_row(&out.clause_bits_row(b), out.sums_row(b)));
        }
        v
    }

    /// Worst-case decision latency (async: all-high-arc traversal; sync:
    /// the minimum clock period).
    fn worst_case(&self) -> Ps;
}

/// Build one engine per architecture in [`HwArch::ALL`] order — the list
/// the experiments, benches, and serving replay all iterate. Use this
/// form for *synthetic* workloads (whose clause bits follow the per-class
/// alternating convention); replaying a trained model's clause bits goes
/// through [`engine_list_for_model`] so the async PDLs carry the model's
/// true vote signs.
pub fn engine_list(
    d: &DesignParams,
    flow: &FlowConfig,
    seed: u64,
) -> Result<Vec<Box<dyn HwEngine>>> {
    HwArch::ALL.iter().map(|a| a.build(d, flow, seed)).collect()
}

/// [`engine_list`] wired for a trained model ([`HwArch::build_for_model`]
/// per architecture) — what fig9/table-style replays of real clause bits
/// and the serving path's `HwBackend` both build from, so figures and
/// production replay share one code path.
pub fn engine_list_for_model(
    model: &TmModel,
    flow: &FlowConfig,
    seed: u64,
) -> Result<Vec<Box<dyn HwEngine>>> {
    HwArch::ALL.iter().map(|a| a.build_for_model(model, flow, seed)).collect()
}

/// Per-request activity shared by every engine's toggle model: the
/// fraction of clause outputs that changed since the previous replayed
/// sample (first sample: the fired density). `prev` is the engine's
/// history slot, updated in place — the same definition
/// [`crate::experiments::fig9::dataset_activity`] uses at the input.
fn replay_activity(prev: &mut Option<Vec<bool>>, clause_bits: &[Vec<bool>]) -> f64 {
    let flat: Vec<bool> = clause_bits.concat();
    let total = flat.len().max(1) as f64;
    let act = match prev {
        Some(p) if p.len() == flat.len() => {
            p.iter().zip(&flat).filter(|(a, b)| a != b).count() as f64 / total
        }
        _ => flat.iter().filter(|&&b| b).count() as f64 / total,
    };
    *prev = Some(flat);
    act
}

impl HwEngine for AsyncTmEngine {
    fn arch(&self) -> HwArch {
        HwArch::Async
    }

    fn replay_row(&mut self, clause_bits: &[Vec<bool>], _sums: &[i32]) -> HwOutcome {
        let d = *self.params();
        let act = replay_activity(&mut self.replay_fired, clause_bits);
        let out = self.infer(clause_bits);
        HwOutcome {
            winner: out.winner,
            decision_latency: out.decision_latency,
            cycle_latency: out.cycle_latency,
            // One analytic source of truth ([`TdAsync::toggles`], Fig. 12):
            // the time-domain popcount propagates exactly one transition
            // per delay element per inference, whatever the data; only the
            // clause logic scales with this sample's activity.
            toggles: TdAsync::default().toggles(&d, act),
        }
    }

    fn worst_case(&self) -> Ps {
        self.worst_case_latency()
    }
}

/// Executable synchronous baseline ([`GenericAdder`] or [`Fpt18`]): the
/// cycle latency is the analytic minimum clock period, but the *decision*
/// latency is a per-request combinational settle time driven by the
/// actual class sums — the adder tree's carry chains only ripple as far
/// as the widest real sum, the FPT'18 chain only as far as the furthest
/// fired clause.
pub struct SyncReplayEngine {
    arch: HwArch,
    d: DesignParams,
    /// Congestion multiplier at this design size.
    m: f64,
    /// Analytic worst-case decomposition (the minimum clock period).
    worst: LatencyBreakdown,
    /// Previous flat fired vector, for the data-dependent toggle model.
    prev_fired: Option<Vec<bool>>,
}

impl SyncReplayEngine {
    pub fn new(arch: HwArch, d: &DesignParams) -> SyncReplayEngine {
        let (m, worst) = match arch {
            HwArch::Adder => (
                calib::congestion(GenericAdder.resources(d).luts()),
                GenericAdder.latency(d),
            ),
            HwArch::Fpt18 => (calib::congestion(Fpt18.resources(d).luts()), Fpt18.latency(d)),
            HwArch::Async => panic!("SyncReplayEngine models synchronous architectures only"),
        };
        SyncReplayEngine { arch, d: *d, m, worst, prev_fired: None }
    }

    /// Per-request popcount settle time (≤ the worst-case stage delay).
    fn popcount_settle(&self, clause_bits: &[Vec<bool>], sums: &[i32]) -> Ps {
        match self.arch {
            HwArch::Adder => {
                // Carry chains stop rippling at the top active bit of the
                // widest actual sum: small sums settle early.
                let max_abs = sums.iter().map(|s| s.unsigned_abs()).max().unwrap_or(0);
                GenericAdder::popcount_settle(&self.d, self.m, calib::sum_width(max_abs as usize))
            }
            HwArch::Fpt18 => {
                // The ripple chain settles once the increment injected by
                // the furthest fired clause has propagated out.
                let active = clause_bits
                    .iter()
                    .map(|b| b.iter().rposition(|&x| x).map_or(0, |p| p + 1))
                    .max()
                    .unwrap_or(0);
                Fpt18::popcount_settle(&self.d, self.m, active.max(1))
            }
            HwArch::Async => unreachable!(),
        }
    }
}

impl HwEngine for SyncReplayEngine {
    fn arch(&self) -> HwArch {
        self.arch
    }

    fn replay_row(&mut self, clause_bits: &[Vec<bool>], sums: &[i32]) -> HwOutcome {
        // Sequential argmax: ties resolve to the lowest class index,
        // matching jnp.argmax and the native functional path bit-exactly.
        let mut winner = 0usize;
        for (k, &s) in sums.iter().enumerate() {
            if s > sums[winner] {
                winner = k;
            }
        }
        let decision = self.worst.clause + self.popcount_settle(clause_bits, sums) + self.worst.compare;
        let cycle = self.worst.total();
        let act = replay_activity(&mut self.prev_fired, clause_bits);
        let toggles = match self.arch {
            HwArch::Adder => GenericAdder.toggles(&self.d, act),
            HwArch::Fpt18 => Fpt18.toggles(&self.d, act),
            HwArch::Async => unreachable!(),
        };
        HwOutcome {
            winner,
            decision_latency: decision.min(cycle),
            cycle_latency: cycle,
            toggles,
        }
    }

    fn worst_case(&self) -> Ps {
        self.worst.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::datasets::{signed_sum, synthetic_clause_bits};
    use crate::tm::WorkloadSpec;
    use crate::util::SplitMix64;

    fn sample(k: usize, c: usize, winner: usize, seed: u64) -> (Vec<Vec<bool>>, Vec<i32>) {
        let spec = WorkloadSpec {
            n_classes: k,
            clauses_per_class: c,
            n_features: 96,
            fire_rate: 0.5,
        };
        let mut rng = SplitMix64::new(seed);
        let bits = synthetic_clause_bits(&spec, winner, &mut rng);
        let sums: Vec<i32> = bits.iter().map(|b| signed_sum(b)).collect();
        (bits, sums)
    }

    #[test]
    fn arch_names_round_trip() {
        for a in HwArch::ALL {
            assert_eq!(HwArch::from_name(a.name()).unwrap(), a);
        }
        let err = HwArch::from_name("systolic").unwrap_err().to_string();
        assert!(err.contains("async") && err.contains("adder") && err.contains("fpt18"));
    }

    #[test]
    fn engine_list_covers_every_arch_in_table_order() {
        let d = DesignParams::synthetic(3, 20, 96);
        let engines = engine_list(&d, &FlowConfig::table1_default(), 7).unwrap();
        let archs: Vec<HwArch> = engines.iter().map(|e| e.arch()).collect();
        assert_eq!(archs, HwArch::ALL.to_vec());
    }

    #[test]
    fn sync_winner_matches_functional_argmax_even_on_ties() {
        let d = DesignParams::synthetic(4, 10, 96);
        let mut eng = SyncReplayEngine::new(HwArch::Adder, &d);
        let bits = vec![vec![false; 10]; 4];
        // Tie between classes 1 and 3 → lowest index wins.
        let out = eng.replay_row(&bits, &[-1, 5, 0, 5]);
        assert_eq!(out.winner, 1);
    }

    #[test]
    fn sync_decision_bounded_by_cycle_and_monotone_in_sum_width() {
        let d = DesignParams::synthetic(3, 60, 96);
        for arch in [HwArch::Adder, HwArch::Fpt18] {
            let mut eng = SyncReplayEngine::new(arch, &d);
            let (bits, sums) = sample(3, 60, 0, 5);
            let out = eng.replay_row(&bits, &sums);
            assert!(out.decision_latency <= out.cycle_latency, "{arch:?}");
            assert_eq!(out.cycle_latency, eng.worst_case(), "{arch:?}");
            assert!(out.decision_latency > Ps::ZERO, "{arch:?}");
        }
        // Adder tree: a wider actual sum ripples a longer carry chain.
        let mut eng = SyncReplayEngine::new(HwArch::Adder, &d);
        let quiet = vec![vec![false; 60]; 3];
        let narrow = eng.replay_row(&quiet, &[1, 0, 0]).decision_latency;
        let wide = eng.replay_row(&quiet, &[29, 0, 0]).decision_latency;
        assert!(wide > narrow, "bigger sums must settle later on the adder tree");
    }

    #[test]
    fn fpt18_settle_tracks_furthest_fired_clause() {
        let d = DesignParams::synthetic(2, 80, 96);
        let mut eng = SyncReplayEngine::new(HwArch::Fpt18, &d);
        let mut early = vec![vec![false; 80]; 2];
        early[0][2] = true;
        let mut late = vec![vec![false; 80]; 2];
        late[0][78] = true;
        let t_early = eng.replay_row(&early, &[1, 0]).decision_latency;
        let t_late = eng.replay_row(&late, &[1, 0]).decision_latency;
        assert!(t_late > t_early);
    }

    #[test]
    fn sync_toggles_are_data_dependent_async_popcount_is_not() {
        let d = DesignParams::synthetic(3, 40, 96);
        let mut eng = SyncReplayEngine::new(HwArch::Adder, &d);
        let (bits, sums) = sample(3, 40, 1, 9);
        let first = eng.replay_row(&bits, &sums);
        // Identical consecutive sample → zero switching in the datapath.
        let repeat = eng.replay_row(&bits, &sums);
        assert!(repeat.toggles.popcount_toggles_per_inference
            < first.toggles.popcount_toggles_per_inference);
        assert_eq!(repeat.toggles.popcount_toggles_per_inference, 0.0);

        let mut engines = engine_list(&d, &FlowConfig::table1_default(), 3).unwrap();
        let td = engines.iter_mut().find(|e| e.arch() == HwArch::Async).unwrap();
        let a = td.replay_row(&bits, &sums);
        let b = td.replay_row(&bits, &sums);
        assert_eq!(
            a.toggles.popcount_toggles_per_inference,
            b.toggles.popcount_toggles_per_inference
        );
        assert_eq!(a.toggles.popcount_toggles_per_inference, d.c_total() as f64);
        assert_eq!(a.toggles.clocked_ffs, 0);
        // Clause-stage activity uses the same hamming-vs-previous
        // definition as the sync engines: an identical repeat is quiet.
        assert_eq!(b.toggles.clause_toggles_per_inference, 0.0);
        assert!(a.toggles.clause_toggles_per_inference > 0.0);
    }

    #[test]
    fn async_replay_matches_inherent_infer_semantics() {
        let d = DesignParams::synthetic(4, 30, 96);
        let mut eng = HwArch::Async.build(&d, &FlowConfig::table1_default(), 11).unwrap();
        let (bits, sums) = sample(4, 30, 2, 13);
        let out = eng.replay_row(&bits, &sums);
        assert!(out.decision_latency <= out.cycle_latency);
        assert!(out.decision_latency <= eng.worst_case());
        assert!(out.winner < 4);
    }

    #[test]
    fn batched_replay_is_rowwise() {
        let d = DesignParams::synthetic(2, 8, 4);
        let mut eng = SyncReplayEngine::new(HwArch::Adder, &d);
        let model = crate::tm::TmModel::synthetic("hw", 2, 8, 4, 0.3, 5);
        let rows: Vec<Vec<bool>> =
            (0..3).map(|i| (0..4).map(|j| (i + j) % 2 == 0).collect()).collect();
        let out = model
            .forward_packed(&crate::tm::PackedBatch::from_rows(&rows).unwrap())
            .unwrap();
        let outcomes = eng.replay(&out);
        assert_eq!(outcomes.len(), 3);
        for (b, o) in outcomes.iter().enumerate() {
            assert_eq!(o.winner, out.pred[b] as usize, "row {b}");
        }
    }
}
