//! One clause shard as a first-class inference backend.
//!
//! [`ShardBackend`] is how the scatter half of the scatter/reduce plan
//! reaches the [`super::InferenceBackend`] seam: each coordinator worker
//! of a sharded pool (`Coordinator::start_sharded`) opens a
//! `BackendSpec::Sharded` spec pinned to its own shard, evaluates only
//! that contiguous slice of the clause-index arena
//! ([`crate::tm::ClauseShard`]), and answers with *partial* class sums
//! plus shard-local fired words. The coordinator's reduce slot adds the
//! partials and re-argmaxes; `tm::merge_partials` is the pure, tested
//! statement of that merge.
//!
//! With `hw: Some(arch)` the shard carries its own simulated engine —
//! one die per shard, built for the full model geometry but replayed
//! with only the shard's fired bits, modeling a voter slice whose
//! decision latency is the time *this shard's* votes take to race. The
//! reduce takes the max of the per-shard decision latencies as the
//! plan's critical-path estimate (votes merge after the slowest slice).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::flow::FlowConfig;
use crate::hw::{HwArch, HwEngine, HwOutcome};
use crate::tm::{ClauseShard, ForwardScratch, HotLoopStats, PackedBatch, PartialOutput, TmModel};

use super::backend::{InferenceBackend, ShardSpec};
use super::ForwardOutput;

/// Partial (one-shard) evaluation behind the whole-model backend seam.
pub struct ShardBackend {
    shard: ClauseShard,
    arch: Option<HwArch>,
    engine: Option<Mutex<Box<dyn HwEngine>>>,
    /// Same per-worker uncontended mutex shape as `NativeBackend`.
    scratch: Mutex<ForwardScratch>,
}

impl ShardBackend {
    /// Carve the shard view out of `model` and optionally attach a
    /// simulated engine. Each shard gets a distinct die
    /// (`die_seed + index`), mirroring how `BackendSpec::for_worker`
    /// seeds time-domain workers.
    pub fn build(model: Arc<TmModel>, spec: ShardSpec, hw: Option<HwArch>) -> Result<ShardBackend> {
        let shard = ClauseShard::new(model, spec.index, spec.n_shards)?;
        let engine = match hw {
            Some(arch) => {
                let mut flow = FlowConfig::table1_default();
                flow.die_seed = flow.die_seed.wrapping_add(spec.index as u64);
                Some(Mutex::new(arch.build_for_model(shard.model(), &flow, flow.die_seed)?))
            }
            None => None,
        };
        Ok(ShardBackend { shard, arch: hw, engine, scratch: Mutex::new(ForwardScratch::new()) })
    }

    /// [`ShardBackend::build`] over a **subset model**: `model` already
    /// holds only this worker's live clause range (every other clause is
    /// dead — the shape `Store::load_model_subset` produces from a v2
    /// artifact tree), so the backend scans *all* of it
    /// (`ClauseShard::new(model, 0, 1)`) and then claims its true plan
    /// position via [`ClauseShard::with_plan_coords`] so the reduce sees
    /// an exact `(index, n_shards)` cover. Engine seeding matches
    /// [`ShardBackend::build`]: one die per shard index.
    pub fn build_subset(
        model: Arc<TmModel>,
        spec: ShardSpec,
        hw: Option<HwArch>,
    ) -> Result<ShardBackend> {
        let shard =
            ClauseShard::new(model, 0, 1)?.with_plan_coords(spec.index, spec.n_shards)?;
        let engine = match hw {
            Some(arch) => {
                let mut flow = FlowConfig::table1_default();
                flow.die_seed = flow.die_seed.wrapping_add(spec.index as u64);
                Some(Mutex::new(arch.build_for_model(shard.model(), &flow, flow.die_seed)?))
            }
            None => None,
        };
        Ok(ShardBackend { shard, arch: hw, engine, scratch: Mutex::new(ForwardScratch::new()) })
    }

    pub fn shard_view(&self) -> &ClauseShard {
        &self.shard
    }
}

impl InferenceBackend for ShardBackend {
    fn kind(&self) -> &'static str {
        "sharded"
    }

    fn platform(&self) -> String {
        let base = match self.arch {
            Some(arch) => format!("hw:{} (simulated)", arch.name()),
            None => "native".to_string(),
        };
        format!("shard {}/{} over {base}", self.shard.index() + 1, self.shard.n_shards())
    }

    fn model_name(&self) -> &str {
        &self.shard.model().name
    }

    // Shape accessors report the *whole model*: admission control gates
    // request width against them, and every shard of a plan must accept
    // exactly the rows the unsharded pool would.
    fn n_features(&self) -> usize {
        self.shard.model().n_features
    }

    fn n_classes(&self) -> usize {
        self.shard.model().n_classes
    }

    fn c_total(&self) -> usize {
        self.shard.model().c_total()
    }

    /// Whole-model contract satisfied with shard-local data: sums are
    /// this shard's partial sums, fired rows carry only shard-owned
    /// bits, and `pred` is the shard-local argmax — meaningful only
    /// through a reduce that re-argmaxes over merged sums.
    fn forward(&self, batch: &PackedBatch) -> Result<ForwardOutput> {
        Ok(self.forward_partial(batch)?.into_forward_output())
    }

    fn forward_partial(&self, batch: &PackedBatch) -> Result<PartialOutput> {
        let mut out = PartialOutput::empty(
            self.n_classes(),
            self.c_total(),
            self.shard.index(),
            self.shard.n_shards(),
        );
        let mut scratch = self.scratch.lock().unwrap_or_else(|e| e.into_inner());
        self.shard.partial_class_sums_into(batch, &mut scratch, &mut out)?;
        Ok(out)
    }

    /// Replay this shard's fired bits through its own die. The outcome's
    /// decision latency is the shard's slice of the vote race; the
    /// reduce takes the max over shards as the critical path.
    fn replay(&self, out: &ForwardOutput, row: usize) -> Option<HwOutcome> {
        let engine = self.engine.as_ref()?;
        let mut engine = engine.lock().unwrap_or_else(|e| e.into_inner());
        Some(engine.replay_row(&out.clause_bits_row(row), out.sums_row(row)))
    }

    fn hw_arch(&self) -> Option<HwArch> {
        self.arch
    }

    fn shard(&self) -> Option<(usize, usize)> {
        Some((self.shard.index(), self.shard.n_shards()))
    }

    fn hot_loop_stats(&self) -> Option<HotLoopStats> {
        Some(self.scratch.lock().unwrap_or_else(|e| e.into_inner()).stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{BackendSpec, NativeBackend};
    use crate::tm::merge_partials;

    fn model() -> Arc<TmModel> {
        Arc::new(TmModel::synthetic("shardb", 3, 22, 17, 0.15, 13))
    }

    fn rows(n: usize, f: usize, seed: u64) -> Vec<Vec<bool>> {
        let mut rng = crate::util::SplitMix64::new(seed);
        (0..n).map(|_| (0..f).map(|_| rng.next_bool(0.5)).collect()).collect()
    }

    #[test]
    fn shard_backends_merge_to_the_native_answer() {
        let m = model();
        let native = NativeBackend::new(m.clone());
        let batch = PackedBatch::from_rows(&rows(6, 17, 9)).unwrap();
        let full = native.forward(&batch).unwrap();
        for n_shards in [1usize, 2, 4] {
            let backends: Vec<ShardBackend> = (0..n_shards)
                .map(|i| {
                    ShardBackend::build(m.clone(), ShardSpec { index: i, n_shards }, None).unwrap()
                })
                .collect();
            let parts: Vec<PartialOutput> =
                backends.iter().map(|b| b.forward_partial(&batch).unwrap()).collect();
            assert_eq!(merge_partials(&parts).unwrap(), full, "n_shards={n_shards}");
            for b in &backends {
                assert_eq!(b.n_features(), m.n_features, "width contract is whole-model");
                assert_eq!(b.shard().unwrap().1, n_shards);
                assert!(b.hot_loop_stats().unwrap().rows > 0);
            }
        }
    }

    /// Subset-model shards (each built from only its own v2 artifact
    /// objects) must merge to the exact native answer — the bit-exactness
    /// contract of the "a shard worker opens only its own bytes" path.
    #[test]
    fn subset_shard_backends_merge_to_the_native_answer() {
        use crate::tm::artifact::{pack, PackOptions};
        let root =
            std::env::temp_dir().join(format!("tdpc-subset-shard-{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        let m = model();
        pack(&root, &[&m], &PackOptions { n_shards: 5, ..Default::default() }).unwrap();
        let store = crate::tm::Store::open(&root).unwrap();
        let native = NativeBackend::new(m.clone());
        let batch = PackedBatch::from_rows(&rows(5, 17, 21)).unwrap();
        let full = native.forward(&batch).unwrap();
        for n_shards in [1usize, 2, 4] {
            let parts: Vec<PartialOutput> = (0..n_shards)
                .map(|i| {
                    let sub = store.load_model_subset("shardb", i, n_shards, None).unwrap();
                    let b = ShardBackend::build_subset(
                        Arc::new(sub),
                        ShardSpec { index: i, n_shards },
                        None,
                    )
                    .unwrap();
                    assert_eq!(b.shard(), Some((i, n_shards)));
                    b.forward_partial(&batch).unwrap()
                })
                .collect();
            assert_eq!(merge_partials(&parts).unwrap(), full, "n_shards={n_shards}");
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn default_forward_partial_is_the_one_shard_view() {
        let m = model();
        let native = NativeBackend::new(m.clone());
        let batch = PackedBatch::from_rows(&rows(3, 17, 2)).unwrap();
        let p = native.forward_partial(&batch).unwrap();
        assert_eq!((p.shard, p.n_shards), (0, 1));
        assert_eq!(merge_partials(&[p]).unwrap(), native.forward(&batch).unwrap());
        assert_eq!(native.shard(), None);
    }

    #[test]
    fn sharded_spec_opens_pins_and_replays() {
        let m = model();
        let spec = BackendSpec::Sharded {
            model: Some(m.clone()),
            shard: ShardSpec::first_of(4),
            hw: Some(HwArch::Adder),
        };
        assert_eq!(spec.name(), "sharded");
        assert!(!spec.needs_manifest());
        // for_worker pins worker w to shard w % n_shards.
        let spec3 = spec.clone().for_worker(3);
        let b = spec3.open(std::path::Path::new("/nonexistent"), "shardb").unwrap();
        assert_eq!(b.kind(), "sharded");
        assert_eq!(b.shard(), Some((3, 4)));
        assert!(b.platform().contains("shard 4/4"), "{}", b.platform());
        assert_eq!(b.hw_arch(), Some(HwArch::Adder));
        let batch = PackedBatch::from_rows(&rows(2, 17, 5)).unwrap();
        let out = b.forward(&batch).unwrap();
        let o = b.replay(&out, 0).expect("hw-attached shard replays");
        assert!(o.decision_latency > crate::util::Ps::ZERO);
        // Wrong model name fails at open, like every in-memory spec.
        assert!(spec.open(std::path::Path::new("/nonexistent"), "other").is_err());
    }
}
