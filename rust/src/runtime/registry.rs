//! Model registry: one backend spec, many constructed backends.
//!
//! A registry is owned by whoever executes models — each coordinator
//! worker constructs its own inside its thread (backends are not
//! necessarily `Send`), the CLI constructs one per invocation. It caches
//! one [`InferenceBackend`] per model name, constructing each at most
//! once via [`OnceMap`]: the cache mutex is held only around map access,
//! never across backend construction (which for PJRT includes executable
//! compilation), so two different models open concurrently while a second
//! request for the *same* model waits instead of duplicating the work.
//!
//! Below the backend cache sits a **hash-keyed payload cache**
//! ([`PayloadCache`]): on a v2 (content-addressed) artifact tree, every
//! clause-block object a backend opens is cached under its sha256, so an
//! [`ModelRegistry::invalidate`] → re-open cycle re-reads from disk only
//! the objects whose hash actually changed — the registry half of the
//! coordinator's delta-aware reload ([`ModelRegistry::payload_stats`]
//! exposes the opened/reused counters the coordinator diffs).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::tm::{Manifest, PayloadCache, Store};
use crate::util::sync::OnceMap;

use super::backend::{BackendSpec, InferenceBackend};

/// Registry of constructed backends for one artifact root.
pub struct ModelRegistry {
    root: PathBuf,
    spec: BackendSpec,
    /// `None` for in-memory specs, which need no artifacts at all.
    store: Option<Store>,
    backends: OnceMap<String, Arc<dyn InferenceBackend>>,
    /// Content-addressed payloads shared by every backend this registry
    /// opens (hits on v2 trees only; v1 model files are not objects).
    payloads: Arc<PayloadCache>,
}

impl ModelRegistry {
    /// Open with the default (native) backend spec.
    pub fn open(root: &Path) -> Result<ModelRegistry> {
        Self::open_with(root, BackendSpec::Native)
    }

    /// Open with an explicit backend spec. Opens the artifact tree (v1
    /// directory or v2 content-addressed store — [`Store::open`]) unless
    /// the spec carries its own in-memory model.
    pub fn open_with(root: &Path, spec: BackendSpec) -> Result<ModelRegistry> {
        let store = if spec.needs_manifest() {
            Some(Store::open(root).context("opening artifact tree")?)
        } else {
            None
        };
        Ok(ModelRegistry {
            root: root.to_path_buf(),
            spec,
            store,
            backends: OnceMap::new(),
            payloads: Arc::new(PayloadCache::new()),
        })
    }

    /// The v1 manifest view, when this registry opened a v1 tree (HLO
    /// paths, batch sizes, test data — fields v2 trees do not carry).
    pub fn manifest(&self) -> Option<&Manifest> {
        self.store.as_ref().and_then(|s| s.v1())
    }

    /// The artifact tree this registry opened (`None` for in-memory
    /// specs).
    pub fn store(&self) -> Option<&Store> {
        self.store.as_ref()
    }

    pub fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    /// Execution platform label, for operator-facing output.
    pub fn platform(&self) -> String {
        self.spec.name().to_string()
    }

    /// `(opened, reused)` payload-object counters of this registry's
    /// cache: `opened` counts objects read + hash-verified + parsed from
    /// disk, `reused` counts content-hash hits that touched nothing. The
    /// coordinator diffs these around [`ModelRegistry::invalidate`] →
    /// re-open to report how much of a swap was delta.
    pub fn payload_stats(&self) -> (u64, u64) {
        self.payloads.stats()
    }

    /// Get (constructing on first use) the backend for `model`. The
    /// construction — model load, PJRT compilation — runs outside the
    /// cache lock, so unrelated models never serialize behind it.
    pub fn backend(&self, model: &str) -> Result<Arc<dyn InferenceBackend>> {
        let b = self.backends.get_or_try_insert(model.to_string(), || {
            self.spec
                .open_cached(&self.root, model, Some(&self.payloads))
                .map(|b| -> Arc<dyn InferenceBackend> { Arc::from(b) })
        })?;
        // A successful (re)open may have superseded payloads cached by a
        // previous generation of this model; dropping them releases
        // their GC pins.
        self.payloads.evict_stale();
        Ok(b)
    }

    /// Drop the cached backend for `model`, forcing the next
    /// [`ModelRegistry::backend`] call to re-open it from the artifacts
    /// on disk — the registry-level primitive behind coordinator
    /// hot-swap (`Coordinator::reload`). [`BackendSpec::open`] re-reads
    /// the manifest itself, so a rewritten artifact is picked up even
    /// though this registry cached the manifest at open time (the
    /// cached [`ModelRegistry::manifest`] view keeps describing the
    /// models as first opened). On a v2 tree the re-open goes through
    /// the payload cache, so only changed-hash objects touch disk.
    ///
    /// Safe against a concurrent in-flight construction of the same
    /// model: the in-flight backend is delivered to its own caller but
    /// not re-cached (see [`OnceMap::remove`]). Returns whether a
    /// cached or in-flight entry existed.
    pub fn invalidate(&self, model: &str) -> bool {
        self.backends.remove(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::model::tests::toy;

    #[test]
    fn in_memory_registry_needs_no_artifacts() {
        let spec = BackendSpec::InMemory(std::sync::Arc::new(toy()));
        let reg = ModelRegistry::open_with(Path::new("/nonexistent"), spec).unwrap();
        assert!(reg.manifest().is_none());
        assert!(reg.store().is_none());
        assert_eq!(reg.platform(), "native(in-memory)");
        let b = reg.backend("toy").unwrap();
        assert_eq!(b.model_name(), "toy");
        // Second lookup hits the cache (same Arc).
        let b2 = reg.backend("toy").unwrap();
        assert!(Arc::ptr_eq(&b, &b2));
        // In-memory specs never touch the payload cache.
        assert_eq!(reg.payload_stats(), (0, 0));
    }

    #[test]
    fn native_registry_fails_cleanly_without_manifest() {
        assert!(ModelRegistry::open(Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn invalidate_forces_reopen() {
        let spec = BackendSpec::InMemory(std::sync::Arc::new(toy()));
        let reg = ModelRegistry::open_with(Path::new("/nonexistent"), spec).unwrap();
        let b = reg.backend("toy").unwrap();
        assert!(reg.invalidate("toy"), "cached entry existed");
        assert!(!reg.invalidate("toy"), "already invalidated");
        assert!(!reg.invalidate("never-opened"));
        // The next lookup re-constructs instead of hitting the cache.
        let b2 = reg.backend("toy").unwrap();
        assert!(!Arc::ptr_eq(&b, &b2), "invalidate must force a fresh construction");
    }

    /// On a v2 tree, invalidate → re-open after a one-shard rewrite
    /// re-reads exactly one object — the registry half of delta reload.
    #[test]
    fn v2_reopen_is_delta_aware() {
        use crate::tm::artifact::{pack, rewrite_shard, PackOptions};
        use crate::tm::TmModel;
        let root =
            std::env::temp_dir().join(format!("tdpc-reg-delta-{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        let m = TmModel::synthetic("regd", 2, 8, 19, 0.25, 41);
        pack(&root, &[&m], &PackOptions { n_shards: 4, ..Default::default() }).unwrap();
        let reg = ModelRegistry::open(&root).unwrap();
        assert!(reg.store().unwrap().is_v2());
        reg.backend("regd").unwrap();
        assert_eq!(reg.payload_stats(), (4, 0));
        rewrite_shard(&root, "regd", 3, |b| b.polarity[0] = -b.polarity[0]).unwrap();
        assert!(reg.invalidate("regd"));
        reg.backend("regd").unwrap();
        let (opened, reused) = reg.payload_stats();
        assert_eq!((opened, reused), (5, 3), "one changed shard → one disk read");
        std::fs::remove_dir_all(&root).ok();
    }
}
