//! Model registry: one PJRT client, many compiled executables.
//!
//! The coordinator routes requests by model name and batch size; the
//! registry owns the client and compiles each (model, batch) artifact at
//! most once (compilation is the expensive step — the §Perf bench
//! quantifies it).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::tm::Manifest;

use super::ModelRunner;

/// Thread-safe registry of compiled model runners.
pub struct ModelRegistry {
    client: xla::PjRtClient,
    manifest: Manifest,
    runners: Mutex<BTreeMap<(String, usize), std::sync::Arc<ModelRunner>>>,
}

impl ModelRegistry {
    /// Create with the default (CPU) PJRT client.
    pub fn new(manifest: Manifest) -> Result<ModelRegistry> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(ModelRegistry { client, manifest, runners: Mutex::new(BTreeMap::new()) })
    }

    pub fn open(artifacts_root: &Path) -> Result<ModelRegistry> {
        Self::new(Manifest::load(artifacts_root)?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) the runner for a model/batch pair.
    pub fn runner(&self, model: &str, batch: usize) -> Result<std::sync::Arc<ModelRunner>> {
        let key = (model.to_string(), batch);
        {
            let cache = self.runners.lock().unwrap();
            if let Some(r) = cache.get(&key) {
                return Ok(r.clone());
            }
        }
        // Compile outside the lock: compilation takes ~100 ms and other
        // batch sizes shouldn't stall behind it.
        let entry = self.manifest.entry(model)?;
        let hlo = self.manifest.hlo_path(model, batch)?;
        let runner = std::sync::Arc::new(ModelRunner::load(
            &self.client,
            &hlo,
            model,
            batch,
            entry.n_features,
            entry.n_classes,
            entry.n_classes * entry.clauses_per_class,
        )?);
        let mut cache = self.runners.lock().unwrap();
        Ok(cache.entry(key).or_insert(runner).clone())
    }

    /// Largest artifact batch size ≤ `n`, for batch planning.
    pub fn best_batch(&self, n: usize) -> usize {
        self.manifest
            .batch_sizes
            .iter()
            .copied()
            .filter(|&b| b <= n.max(1))
            .max()
            .unwrap_or_else(|| self.manifest.batch_sizes.iter().copied().min().unwrap_or(1))
    }

    /// Execution batch for `n` queued requests: the *smallest* artifact
    /// batch that fits all of them (padding beats splitting into many
    /// small executions — §Perf L3), else the largest available.
    pub fn exec_batch(&self, n: usize) -> usize {
        self.manifest
            .batch_sizes
            .iter()
            .copied()
            .filter(|&b| b >= n.max(1))
            .min()
            .unwrap_or_else(|| self.manifest.batch_sizes.iter().copied().max().unwrap_or(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_batch_picks_largest_fitting() {
        // Manifest stub with batch sizes {1, 32}.
        let manifest = Manifest {
            root: std::path::PathBuf::from("/nonexistent"),
            batch_sizes: vec![1, 32],
            models: vec![],
        };
        let reg = ModelRegistry::new(manifest);
        // PJRT client may be unavailable in odd environments; skip then.
        let Ok(reg) = reg else { return };
        assert_eq!(reg.best_batch(100), 32);
        assert_eq!(reg.best_batch(32), 32);
        assert_eq!(reg.best_batch(31), 1);
        assert_eq!(reg.best_batch(0), 1);
        // exec_batch: smallest artifact batch that fits everything.
        assert_eq!(reg.exec_batch(1), 1);
        assert_eq!(reg.exec_batch(2), 32);
        assert_eq!(reg.exec_batch(32), 32);
        assert_eq!(reg.exec_batch(100), 32);
    }
}
