//! Model registry: one backend spec, many constructed backends.
//!
//! A registry is owned by whoever executes models — each coordinator
//! worker constructs its own inside its thread (backends are not
//! necessarily `Send`), the CLI constructs one per invocation. It caches
//! one [`InferenceBackend`] per model name, constructing each at most
//! once via [`OnceMap`]: the cache mutex is held only around map access,
//! never across backend construction (which for PJRT includes executable
//! compilation), so two different models open concurrently while a second
//! request for the *same* model waits instead of duplicating the work.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::tm::Manifest;
use crate::util::sync::OnceMap;

use super::backend::{BackendSpec, InferenceBackend};

/// Registry of constructed backends for one artifact root.
pub struct ModelRegistry {
    root: PathBuf,
    spec: BackendSpec,
    /// `None` for in-memory specs, which need no artifacts at all.
    manifest: Option<Manifest>,
    backends: OnceMap<String, Arc<dyn InferenceBackend>>,
}

impl ModelRegistry {
    /// Open with the default (native) backend spec.
    pub fn open(root: &Path) -> Result<ModelRegistry> {
        Self::open_with(root, BackendSpec::Native)
    }

    /// Open with an explicit backend spec. Loads the artifact manifest
    /// unless the spec carries its own in-memory model.
    pub fn open_with(root: &Path, spec: BackendSpec) -> Result<ModelRegistry> {
        let manifest = if spec.needs_manifest() {
            Some(Manifest::load(root).context("loading artifact manifest")?)
        } else {
            None
        };
        Ok(ModelRegistry {
            root: root.to_path_buf(),
            spec,
            manifest,
            backends: OnceMap::new(),
        })
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    pub fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    /// Execution platform label, for operator-facing output.
    pub fn platform(&self) -> String {
        self.spec.name().to_string()
    }

    /// Get (constructing on first use) the backend for `model`. The
    /// construction — model load, PJRT compilation — runs outside the
    /// cache lock, so unrelated models never serialize behind it.
    pub fn backend(&self, model: &str) -> Result<Arc<dyn InferenceBackend>> {
        self.backends.get_or_try_insert(model.to_string(), || {
            self.spec
                .open(&self.root, model)
                .map(|b| -> Arc<dyn InferenceBackend> { Arc::from(b) })
        })
    }

    /// Drop the cached backend for `model`, forcing the next
    /// [`ModelRegistry::backend`] call to re-open it from the artifacts
    /// on disk — the registry-level primitive behind coordinator
    /// hot-swap (`Coordinator::reload`). [`BackendSpec::open`] re-reads
    /// the manifest itself, so a rewritten artifact is picked up even
    /// though this registry cached the manifest at open time (the
    /// cached [`ModelRegistry::manifest`] view keeps describing the
    /// models as first opened).
    ///
    /// Safe against a concurrent in-flight construction of the same
    /// model: the in-flight backend is delivered to its own caller but
    /// not re-cached (see [`OnceMap::remove`]). Returns whether a
    /// cached or in-flight entry existed.
    pub fn invalidate(&self, model: &str) -> bool {
        self.backends.remove(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::model::tests::toy;

    #[test]
    fn in_memory_registry_needs_no_artifacts() {
        let spec = BackendSpec::InMemory(std::sync::Arc::new(toy()));
        let reg = ModelRegistry::open_with(Path::new("/nonexistent"), spec).unwrap();
        assert!(reg.manifest().is_none());
        assert_eq!(reg.platform(), "native(in-memory)");
        let b = reg.backend("toy").unwrap();
        assert_eq!(b.model_name(), "toy");
        // Second lookup hits the cache (same Arc).
        let b2 = reg.backend("toy").unwrap();
        assert!(Arc::ptr_eq(&b, &b2));
    }

    #[test]
    fn native_registry_fails_cleanly_without_manifest() {
        assert!(ModelRegistry::open(Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn invalidate_forces_reopen() {
        let spec = BackendSpec::InMemory(std::sync::Arc::new(toy()));
        let reg = ModelRegistry::open_with(Path::new("/nonexistent"), spec).unwrap();
        let b = reg.backend("toy").unwrap();
        assert!(reg.invalidate("toy"), "cached entry existed");
        assert!(!reg.invalidate("toy"), "already invalidated");
        assert!(!reg.invalidate("never-opened"));
        // The next lookup re-constructs instead of hitting the cache.
        let b2 = reg.backend("toy").unwrap();
        assert!(!Arc::ptr_eq(&b, &b2), "invalidate must force a fresh construction");
    }
}
