//! Simulated hardware as a first-class inference backend.
//!
//! [`HwBackend`] makes the paper's architectures peers of
//! [`super::NativeBackend`]/`PjrtBackend` on the request path: functional
//! results come from the same packed native forward pass (so predictions
//! are bit-identical to the native backend), while per-request on-chip
//! timing comes from the attached [`crate::hw::HwEngine`] via
//! [`super::InferenceBackend::replay`]. The engine is stateful (arbiter
//! RNG, toggle history) and sits behind a mutex; each coordinator worker
//! owns its own backend — and therefore its own simulated die — so the
//! lock is uncontended on the serving path.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::flow::FlowConfig;
use crate::hw::{HwArch, HwEngine, HwOutcome};
use crate::tm::{ForwardScratch, HotLoopStats, PackedBatch, TmModel};

use super::backend::InferenceBackend;
use super::ForwardOutput;

/// Native functional forward pass + simulated hardware timing engine.
pub struct HwBackend {
    model: Arc<TmModel>,
    arch: HwArch,
    engine: Mutex<Box<dyn HwEngine>>,
    /// Hot-loop buffers + skip telemetry; same per-worker uncontended
    /// mutex shape as `engine`.
    scratch: Mutex<ForwardScratch>,
}

impl HwBackend {
    /// Build the engine for `model` and wrap both. For the async
    /// architecture this runs the full implementation flow and wires the
    /// PDL polarities from the model's trained clause signs
    /// ([`HwArch::build_for_model`]); `flow.die_seed` selects the
    /// simulated die (the coordinator gives every worker a distinct one
    /// via `BackendSpec::for_worker`).
    pub fn build(model: Arc<TmModel>, arch: HwArch, flow: &FlowConfig) -> Result<HwBackend> {
        let engine = arch.build_for_model(&model, flow, flow.die_seed)?;
        Ok(HwBackend {
            model,
            arch,
            engine: Mutex::new(engine),
            scratch: Mutex::new(ForwardScratch::new()),
        })
    }

    pub fn arch(&self) -> HwArch {
        self.arch
    }
}

impl InferenceBackend for HwBackend {
    fn kind(&self) -> &'static str {
        "hw"
    }

    fn platform(&self) -> String {
        format!("hw:{} (simulated)", self.arch.name())
    }

    fn model_name(&self) -> &str {
        &self.model.name
    }

    fn n_features(&self) -> usize {
        self.model.n_features
    }

    fn n_classes(&self) -> usize {
        self.model.n_classes
    }

    fn c_total(&self) -> usize {
        self.model.c_total()
    }

    fn forward(&self, batch: &PackedBatch) -> Result<ForwardOutput> {
        let mut scratch = self.scratch.lock().unwrap_or_else(|e| e.into_inner());
        self.model.forward_packed_with(batch, &mut scratch)
    }

    fn replay(&self, out: &ForwardOutput, row: usize) -> Option<HwOutcome> {
        // Recover a poisoned lock: a replay panic (contained by the
        // coordinator's catch_unwind) must not permanently disable this
        // die's telemetry. The engine holds only simulation state
        // (arbiter RNG, toggle history), so continuing after a
        // mid-update unwind is safe.
        let mut engine = self.engine.lock().unwrap_or_else(|e| e.into_inner());
        Some(engine.replay_row(&out.clause_bits_row(row), out.sums_row(row)))
    }

    fn hw_arch(&self) -> Option<HwArch> {
        Some(self.arch)
    }

    fn hot_loop_stats(&self) -> Option<HotLoopStats> {
        Some(self.scratch.lock().unwrap_or_else(|e| e.into_inner()).stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::BackendSpec;

    fn model() -> Arc<TmModel> {
        Arc::new(TmModel::synthetic("hwb", 3, 10, 16, 0.15, 21))
    }

    fn rows(n: usize, f: usize, seed: u64) -> Vec<Vec<bool>> {
        let mut rng = crate::util::SplitMix64::new(seed);
        (0..n).map(|_| (0..f).map(|_| rng.next_bool(0.5)).collect()).collect()
    }

    #[test]
    fn time_domain_spec_opens_without_artifacts_and_replays() {
        let m = model();
        for arch in HwArch::ALL {
            let spec = BackendSpec::TimeDomain {
                arch,
                flow: FlowConfig::table1_default(),
                model: Some(m.clone()),
            };
            let b = spec.open(std::path::Path::new("/nonexistent"), "hwb").unwrap();
            assert_eq!(b.kind(), "hw");
            assert_eq!(b.hw_arch(), Some(arch));
            assert!(b.platform().contains(arch.name()));
            let batch = PackedBatch::from_rows(&rows(4, 16, 3)).unwrap();
            let out = b.forward(&batch).unwrap();
            for i in 0..out.batch {
                let o = b.replay(&out, i).expect("hw backend always replays");
                assert!(o.decision_latency <= o.cycle_latency, "{arch:?} row {i}");
                assert!(o.decision_latency > crate::util::Ps::ZERO, "{arch:?} row {i}");
            }
        }
    }

    #[test]
    fn functional_results_match_native_backend_exactly() {
        let m = model();
        let native = super::super::NativeBackend::new(m.clone());
        let hw = HwBackend::build(m, HwArch::Adder, &FlowConfig::table1_default()).unwrap();
        let batch = PackedBatch::from_rows(&rows(8, 16, 5)).unwrap();
        let a = native.forward(&batch).unwrap();
        let b = hw.forward(&batch).unwrap();
        assert_eq!(a, b, "functional path is the same packed forward pass");
    }

    #[test]
    fn time_domain_spec_rejects_wrong_model_name() {
        let spec = BackendSpec::TimeDomain {
            arch: HwArch::Adder,
            flow: FlowConfig::table1_default(),
            model: Some(model()),
        };
        assert!(spec.open(std::path::Path::new("/nonexistent"), "other").is_err());
    }
}
