//! PJRT runtime: load AOT-compiled HLO artifacts and execute them on the
//! request path.
//!
//! The Python build path (`python/compile/aot.py`) lowers each TM
//! configuration to HLO *text* (the interchange format xla_extension 0.5.1
//! accepts — jax ≥ 0.5's serialized protos carry 64-bit instruction ids it
//! rejects). This module compiles those artifacts once on the PJRT CPU
//! client and executes them for the coordinator; Python never runs here.

pub mod registry;

pub use registry::ModelRegistry;

use std::path::Path;

use anyhow::{ensure, Context, Result};

/// Output of one batched TM forward pass (mirrors `model.tm_forward`).
#[derive(Debug, Clone, PartialEq)]
pub struct ForwardOutput {
    pub batch: usize,
    pub n_classes: usize,
    pub c_total: usize,
    /// (batch × n_classes) row-major signed class sums.
    pub sums: Vec<i32>,
    /// (batch × c_total) row-major clause bits.
    pub fired: Vec<i32>,
    /// (batch) argmax predictions.
    pub pred: Vec<i32>,
}

impl ForwardOutput {
    pub fn sums_row(&self, b: usize) -> &[i32] {
        &self.sums[b * self.n_classes..(b + 1) * self.n_classes]
    }

    /// Clause bits of sample `b`, grouped per class (PDL select inputs).
    pub fn clause_bits_row(&self, b: usize) -> Vec<Vec<bool>> {
        let row = &self.fired[b * self.c_total..(b + 1) * self.c_total];
        let per = self.c_total / self.n_classes;
        (0..self.n_classes)
            .map(|k| row[k * per..(k + 1) * per].iter().map(|&v| v != 0).collect())
            .collect()
    }
}

/// A compiled executable for one (model, batch-size) pair.
pub struct ModelRunner {
    pub name: String,
    pub batch: usize,
    pub n_features: usize,
    pub n_classes: usize,
    pub c_total: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl ModelRunner {
    /// Compile the HLO text at `path` on `client`.
    pub fn load(
        client: &xla::PjRtClient,
        path: &Path,
        name: &str,
        batch: usize,
        n_features: usize,
        n_classes: usize,
        c_total: usize,
    ) -> Result<ModelRunner> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF-8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {}", path.display()))?;
        Ok(ModelRunner {
            name: name.to_string(),
            batch,
            n_features,
            n_classes,
            c_total,
            exe,
        })
    }

    /// Execute one batch. `x` is (batch × n_features) row-major 0.0/1.0.
    pub fn run(&self, x: &[f32]) -> Result<ForwardOutput> {
        ensure!(
            x.len() == self.batch * self.n_features,
            "input length {} != batch {} × features {}",
            x.len(),
            self.batch,
            self.n_features
        );
        let input = xla::Literal::vec1(x)
            .reshape(&[self.batch as i64, self.n_features as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (sums, fired, pred).
        let (sums_l, fired_l, pred_l) = result.to_tuple3()?;
        let sums = sums_l.to_vec::<i32>()?;
        let fired = fired_l.to_vec::<i32>()?;
        let pred = pred_l.to_vec::<i32>()?;
        ensure!(sums.len() == self.batch * self.n_classes, "sums shape mismatch");
        ensure!(fired.len() == self.batch * self.c_total, "fired shape mismatch");
        ensure!(pred.len() == self.batch, "pred shape mismatch");
        Ok(ForwardOutput {
            batch: self.batch,
            n_classes: self.n_classes,
            c_total: self.c_total,
            sums,
            fired,
            pred,
        })
    }

    /// Run a partial batch by padding with zeros and truncating the output.
    pub fn run_padded(&self, x: &[f32], n_valid: usize) -> Result<ForwardOutput> {
        ensure!(n_valid <= self.batch);
        let mut padded = vec![0.0f32; self.batch * self.n_features];
        padded[..x.len()].copy_from_slice(x);
        let mut out = self.run(&padded)?;
        out.batch = n_valid;
        out.sums.truncate(n_valid * self.n_classes);
        out.fired.truncate(n_valid * self.c_total);
        out.pred.truncate(n_valid);
        Ok(out)
    }
}

/// Convert Boolean features to the f32 layout the HLO expects.
pub fn bools_to_f32(rows: &[Vec<bool>]) -> Vec<f32> {
    rows.iter()
        .flat_map(|r| r.iter().map(|&b| if b { 1.0 } else { 0.0 }))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_output_row_access() {
        let out = ForwardOutput {
            batch: 2,
            n_classes: 2,
            c_total: 4,
            sums: vec![1, -1, 3, 0],
            fired: vec![1, 0, 0, 1, 1, 1, 0, 0],
            pred: vec![0, 0],
        };
        assert_eq!(out.sums_row(1), &[3, 0]);
        let bits = out.clause_bits_row(0);
        assert_eq!(bits, vec![vec![true, false], vec![false, true]]);
    }

    #[test]
    fn bools_layout() {
        let rows = vec![vec![true, false], vec![false, true]];
        assert_eq!(bools_to_f32(&rows), vec![1.0, 0.0, 0.0, 1.0]);
    }
}
