//! Inference runtime: pluggable execution backends behind one seam.
//!
//! The request path executes TM forward passes through the
//! [`InferenceBackend`] trait. Three implementations exist:
//!
//! * [`NativeBackend`] (default) — pure-Rust bit-packed clause evaluation
//!   straight from the trained [`crate::tm::TmModel`]. Hermetic: no XLA
//!   toolchain, deterministic, and what CI builds and tests.
//! * [`HwBackend`] (`BackendSpec::TimeDomain`, CLI `hw:<arch>`) — the same
//!   packed native forward pass for functional results, plus a simulated
//!   hardware engine ([`crate::hw::HwEngine`]: the async time-domain
//!   design, the generic adder tree, or FPT'18) reachable through
//!   [`InferenceBackend::replay`] for per-request on-chip timing.
//! * [`ShardBackend`] (`BackendSpec::Sharded`) — *partial* evaluation of
//!   one clause shard ([`crate::tm::ClauseShard`]): per-class partial
//!   sums + shard-local fired words through
//!   [`InferenceBackend::forward_partial`], merged by the coordinator's
//!   scatter/reduce plan (`Coordinator::start_sharded`) into answers
//!   bit-exact with the unsharded forward pass.
//! * `PjrtBackend` (`--features pjrt`) — compiles the AOT-lowered HLO text
//!   emitted by `python/compile/aot.py` on the PJRT CPU client and executes
//!   it. PJRT clients wrap raw pointers and are not `Send`, so PJRT
//!   backends must be constructed inside the thread that uses them — the
//!   coordinator's worker pool does exactly that via [`BackendSpec`].
//!
//! [`BackendSpec`] is the `Send + Clone` factory that crosses thread
//! boundaries; [`ModelRegistry`] caches constructed backends per model.
//! [`FaultInjectingBackend`] (`BackendSpec::FaultInjecting`) wraps the
//! native backend with a deterministic failure mode (the all-true poison
//! row) so chaos drills and the coordinator's fail-soft tests exercise
//! per-row retry through the real seam.
//!
//! Manifest-backed specs open their artifact tree through
//! [`crate::tm::Store`] — v1 bare directories and v2 content-addressed
//! trees (`tm::artifact`) both work, and v2 opens verify every payload
//! object's sha256. The registry shares one hash-keyed
//! [`crate::tm::PayloadCache`] across all backends it opens, so an
//! invalidate → re-open cycle touches disk only for objects whose hash
//! changed (delta-aware reload; `ModelRegistry::payload_stats` is the
//! counter pair the coordinator reports as `reload_shards_reused`), and
//! on a v2 tree a `BackendSpec::Sharded` worker loads only the objects
//! overlapping its own clause range
//! (`Store::load_model_subset` → `ShardBackend::build_subset`).
//!
//! The data plane is *packed end-to-end*: [`InferenceBackend::forward`]
//! consumes a [`crate::tm::PackedBatch`] of bit-packed feature rows (the
//! coordinator packs each request once at ingestion) and produces a
//! [`ForwardOutput`] whose clause bits are bit-packed words. The native
//! backend never unpacks; the PJRT backend unpacks only at the HLO
//! boundary, where the AOT artifact demands f32 lanes.

pub mod backend;
pub mod hw_backend;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod registry;
pub mod shard_backend;

pub use backend::{BackendSpec, FaultInjectingBackend, InferenceBackend, NativeBackend, ShardSpec};
pub use hw_backend::HwBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::{ModelRunner, PjrtBackend};
pub use registry::ModelRegistry;
pub use shard_backend::ShardBackend;

/// The forward-pass output every backend returns. Defined next to
/// [`crate::tm::TmModel::forward_packed`] in the model layer (so `tm`
/// has no dependency on the serving runtime) and re-exported here as the
/// seam's interchange type.
pub use crate::tm::model::ForwardOutput;

/// One shard's partial view of a batch — what
/// [`InferenceBackend::forward_partial`] returns (partial class sums +
/// shard-local fired words). Defined in the model layer next to
/// `tm::merge_partials`, the pure reduce.
pub use crate::tm::model::PartialOutput;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::PackedBatch;

    fn packed(rows: &[Vec<bool>]) -> PackedBatch {
        PackedBatch::from_rows(rows).unwrap()
    }

    #[test]
    fn forward_output_row_access() {
        let out = ForwardOutput {
            batch: 2,
            n_classes: 2,
            c_total: 4,
            sums: vec![1, -1, 3, 0],
            fired: packed(&[
                vec![true, false, false, true],
                vec![true, true, false, false],
            ]),
            pred: vec![0, 0],
        };
        assert_eq!(out.sums_row(1), &[3, 0]);
        let bits = out.clause_bits_row(0);
        assert_eq!(bits, vec![vec![true, false], vec![false, true]]);
        assert_eq!(out.fired_row(1), vec![true, true, false, false]);
        assert_eq!(out.fired_words_row(0), &[0b1001u64]);
    }

    #[test]
    fn append_concatenates_rows() {
        let mut a = ForwardOutput::empty(2, 4);
        let b = ForwardOutput {
            batch: 1,
            n_classes: 2,
            c_total: 4,
            sums: vec![1, -1],
            fired: packed(&[vec![true, false, false, true]]),
            pred: vec![0],
        };
        a.append(b.clone()).unwrap();
        a.append(b).unwrap();
        assert_eq!(a.batch, 2);
        assert_eq!(a.fired.rows(), 2);
        assert_eq!(a.sums, vec![1, -1, 1, -1]);
        assert_eq!(a.pred, vec![0, 0]);
        // Shape mismatch is rejected.
        let mut c = ForwardOutput::empty(3, 6);
        assert!(c.append(a).is_err());
    }
}
