//! Inference runtime: pluggable execution backends behind one seam.
//!
//! The request path executes TM forward passes through the
//! [`InferenceBackend`] trait. Two implementations exist:
//!
//! * [`NativeBackend`] (default) — pure-Rust bit-packed clause evaluation
//!   straight from the trained [`crate::tm::TmModel`]. Hermetic: no XLA
//!   toolchain, deterministic, and what CI builds and tests.
//! * `PjrtBackend` (`--features pjrt`) — compiles the AOT-lowered HLO text
//!   emitted by `python/compile/aot.py` on the PJRT CPU client and executes
//!   it. PJRT clients wrap raw pointers and are not `Send`, so PJRT
//!   backends must be constructed inside the thread that uses them — the
//!   coordinator's worker pool does exactly that via [`BackendSpec`].
//!
//! [`BackendSpec`] is the `Send + Clone` factory that crosses thread
//! boundaries; [`ModelRegistry`] caches constructed backends per model.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod registry;

pub use backend::{BackendSpec, InferenceBackend, NativeBackend};
#[cfg(feature = "pjrt")]
pub use pjrt::{ModelRunner, PjrtBackend};
pub use registry::ModelRegistry;

use anyhow::{ensure, Result};

/// Output of one batched TM forward pass (mirrors `model.tm_forward` on the
/// Python side; identical layout across every backend).
#[derive(Debug, Clone, PartialEq)]
pub struct ForwardOutput {
    pub batch: usize,
    pub n_classes: usize,
    pub c_total: usize,
    /// (batch × n_classes) row-major signed class sums.
    pub sums: Vec<i32>,
    /// (batch × c_total) row-major clause bits.
    pub fired: Vec<i32>,
    /// (batch) argmax predictions.
    pub pred: Vec<i32>,
}

impl ForwardOutput {
    /// An output with zero rows (identity for [`ForwardOutput::append`]).
    pub fn empty(n_classes: usize, c_total: usize) -> ForwardOutput {
        ForwardOutput {
            batch: 0,
            n_classes,
            c_total,
            sums: Vec::new(),
            fired: Vec::new(),
            pred: Vec::new(),
        }
    }

    /// Concatenate another output's rows onto this one (used by backends
    /// that execute a logical batch as several fixed-size chunks).
    pub fn append(&mut self, other: ForwardOutput) -> Result<()> {
        ensure!(
            self.n_classes == other.n_classes && self.c_total == other.c_total,
            "cannot append outputs of different shapes ({}/{} vs {}/{})",
            self.n_classes,
            self.c_total,
            other.n_classes,
            other.c_total
        );
        self.batch += other.batch;
        self.sums.extend(other.sums);
        self.fired.extend(other.fired);
        self.pred.extend(other.pred);
        Ok(())
    }

    pub fn sums_row(&self, b: usize) -> &[i32] {
        &self.sums[b * self.n_classes..(b + 1) * self.n_classes]
    }

    /// Clause bits of sample `b`, grouped per class (PDL select inputs).
    pub fn clause_bits_row(&self, b: usize) -> Vec<Vec<bool>> {
        let row = &self.fired[b * self.c_total..(b + 1) * self.c_total];
        let per = self.c_total / self.n_classes;
        (0..self.n_classes)
            .map(|k| row[k * per..(k + 1) * per].iter().map(|&v| v != 0).collect())
            .collect()
    }
}

/// Convert Boolean features to the f32 layout the HLO expects.
pub fn bools_to_f32(rows: &[Vec<bool>]) -> Vec<f32> {
    rows.iter()
        .flat_map(|r| r.iter().map(|&b| if b { 1.0 } else { 0.0 }))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_output_row_access() {
        let out = ForwardOutput {
            batch: 2,
            n_classes: 2,
            c_total: 4,
            sums: vec![1, -1, 3, 0],
            fired: vec![1, 0, 0, 1, 1, 1, 0, 0],
            pred: vec![0, 0],
        };
        assert_eq!(out.sums_row(1), &[3, 0]);
        let bits = out.clause_bits_row(0);
        assert_eq!(bits, vec![vec![true, false], vec![false, true]]);
    }

    #[test]
    fn bools_layout() {
        let rows = vec![vec![true, false], vec![false, true]];
        assert_eq!(bools_to_f32(&rows), vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn append_concatenates_rows() {
        let mut a = ForwardOutput::empty(2, 4);
        let b = ForwardOutput {
            batch: 1,
            n_classes: 2,
            c_total: 4,
            sums: vec![1, -1],
            fired: vec![1, 0, 0, 1],
            pred: vec![0],
        };
        a.append(b.clone()).unwrap();
        a.append(b).unwrap();
        assert_eq!(a.batch, 2);
        assert_eq!(a.sums, vec![1, -1, 1, -1]);
        assert_eq!(a.pred, vec![0, 0]);
        // Shape mismatch is rejected.
        let mut c = ForwardOutput::empty(3, 6);
        assert!(c.append(a).is_err());
    }
}
