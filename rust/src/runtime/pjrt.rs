//! PJRT execution backend (`--features pjrt`): load AOT-compiled HLO
//! artifacts and execute them on the request path.
//!
//! The Python build path (`python/compile/aot.py`) lowers each TM
//! configuration to HLO *text* (the interchange format xla_extension 0.5.1
//! accepts — jax ≥ 0.5's serialized protos carry 64-bit instruction ids it
//! rejects). [`PjrtBackend`] compiles those artifacts once per batch size
//! on the PJRT CPU client and executes them; Python never runs here.
//!
//! PJRT clients wrap raw pointers and are not `Send`: construct the
//! backend inside the thread that uses it (the coordinator's worker pool
//! does this through `BackendSpec::Pjrt`).

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Context, Result};

use crate::tm::{bits::BitVec64, Manifest, ManifestEntry, PackedBatch};
use crate::util::sync::OnceMap;

use super::{ForwardOutput, InferenceBackend};

/// Unpack rows `[lo, hi)` of a packed batch to the f32 layout the HLO
/// expects (1.0/0.0 lanes, row-major). This is the *only* place the
/// request path unpacks: everything upstream of the PJRT boundary is
/// `u64` words.
fn packed_to_f32(batch: &PackedBatch, lo: usize, hi: usize) -> Vec<f32> {
    let bits = batch.bits();
    let mut out = Vec::with_capacity((hi - lo) * bits);
    for r in lo..hi {
        for i in 0..bits {
            out.push(if batch.bit(r, i) { 1.0 } else { 0.0 });
        }
    }
    out
}

/// Pack the i32 clause-bit lanes an HLO execution returns (batch ×
/// c_total, row-major) into the bit-packed interchange form.
fn pack_fired_lanes(fired: &[i32], batch: usize, c_total: usize) -> PackedBatch {
    let mut out = PackedBatch::new(c_total);
    for b in 0..batch {
        let row = &fired[b * c_total..(b + 1) * c_total];
        let mut v = BitVec64::zeros(c_total);
        for (i, &lane) in row.iter().enumerate() {
            if lane != 0 {
                v.set(i, true);
            }
        }
        out.push_bitvec(&v).expect("row width is c_total by construction");
    }
    out
}

/// A compiled executable for one (model, batch-size) pair.
pub struct ModelRunner {
    pub name: String,
    pub batch: usize,
    pub n_features: usize,
    pub n_classes: usize,
    pub c_total: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl ModelRunner {
    /// Compile the HLO text at `path` on `client`.
    pub fn load(
        client: &xla::PjRtClient,
        path: &Path,
        name: &str,
        batch: usize,
        n_features: usize,
        n_classes: usize,
        c_total: usize,
    ) -> Result<ModelRunner> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF-8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {}", path.display()))?;
        Ok(ModelRunner {
            name: name.to_string(),
            batch,
            n_features,
            n_classes,
            c_total,
            exe,
        })
    }

    /// Execute one batch. `x` is (batch × n_features) row-major 0.0/1.0.
    pub fn run(&self, x: &[f32]) -> Result<ForwardOutput> {
        ensure!(
            x.len() == self.batch * self.n_features,
            "input length {} != batch {} × features {}",
            x.len(),
            self.batch,
            self.n_features
        );
        let input = xla::Literal::vec1(x)
            .reshape(&[self.batch as i64, self.n_features as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (sums, fired, pred).
        let (sums_l, fired_l, pred_l) = result.to_tuple3()?;
        let sums = sums_l.to_vec::<i32>()?;
        let fired = fired_l.to_vec::<i32>()?;
        let pred = pred_l.to_vec::<i32>()?;
        ensure!(sums.len() == self.batch * self.n_classes, "sums shape mismatch");
        ensure!(fired.len() == self.batch * self.c_total, "fired shape mismatch");
        ensure!(pred.len() == self.batch, "pred shape mismatch");
        Ok(ForwardOutput {
            batch: self.batch,
            n_classes: self.n_classes,
            c_total: self.c_total,
            sums,
            fired: pack_fired_lanes(&fired, self.batch, self.c_total),
            pred,
        })
    }

    /// Run a partial batch by padding with zeros and truncating the output.
    pub fn run_padded(&self, x: &[f32], n_valid: usize) -> Result<ForwardOutput> {
        ensure!(n_valid <= self.batch);
        let mut padded = vec![0.0f32; self.batch * self.n_features];
        padded[..x.len()].copy_from_slice(x);
        let mut out = self.run(&padded)?;
        out.batch = n_valid;
        out.sums.truncate(n_valid * self.n_classes);
        out.fired.truncate_rows(n_valid);
        out.pred.truncate(n_valid);
        Ok(out)
    }
}

/// PJRT backend for one model: a client plus compiled executables per
/// artifact batch size, compiled at most once each.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    manifest: Manifest,
    entry: ManifestEntry,
    /// Compile-once cache. The [`OnceMap`] holds its mutex only around
    /// map access, never across PJRT compilation — compilation of two
    /// *different* batch sizes proceeds concurrently, while a second
    /// request for the *same* batch size waits instead of compiling a
    /// duplicate (the double-lock hazard the old registry design
    /// invited).
    runners: OnceMap<usize, Arc<ModelRunner>>,
}

impl PjrtBackend {
    /// Open `model` from the artifact manifest at `root`.
    pub fn open(root: &Path, model: &str) -> Result<PjrtBackend> {
        Self::new(Manifest::load(root)?, model)
    }

    pub fn new(manifest: Manifest, model: &str) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let entry = manifest.entry(model)?.clone();
        Ok(PjrtBackend { client, manifest, entry, runners: OnceMap::new() })
    }

    /// Pre-compile every artifact batch size (startup warm-up, so errors
    /// surface before the first request).
    pub fn warm(&self) -> Result<()> {
        for &b in &self.manifest.batch_sizes {
            self.runner(b).context("pre-compiling model")?;
        }
        Ok(())
    }

    /// Get (compiling on first use) the runner for one batch size. The
    /// ~100 ms compilation runs outside the cache lock, so other batch
    /// sizes never stall behind it.
    pub fn runner(&self, batch: usize) -> Result<Arc<ModelRunner>> {
        self.runners
            .get_or_try_insert(batch, || self.compile(batch).map(Arc::new))
    }

    fn compile(&self, batch: usize) -> Result<ModelRunner> {
        let hlo = self.manifest.hlo_path(&self.entry.name, batch)?;
        ModelRunner::load(
            &self.client,
            &hlo,
            &self.entry.name,
            batch,
            self.entry.n_features,
            self.entry.n_classes,
            self.entry.n_classes * self.entry.clauses_per_class,
        )
    }
}

impl InferenceBackend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    /// The PJRT client's actual platform name (e.g. `cpu`), not just the
    /// backend kind.
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn model_name(&self) -> &str {
        &self.entry.name
    }

    fn n_features(&self) -> usize {
        self.entry.n_features
    }

    fn n_classes(&self) -> usize {
        self.entry.n_classes
    }

    fn c_total(&self) -> usize {
        self.entry.n_classes * self.entry.clauses_per_class
    }

    /// Execute a logical batch of any size by slicing it into artifact-
    /// sized chunks (padding the tail — §Perf L3: padding beats splitting
    /// into many small executions). The packed batch is unpacked to f32
    /// lanes here, chunk by chunk, because that is the layout the AOT
    /// artifact was lowered against — nothing upstream unpacks.
    fn forward(&self, batch: &PackedBatch) -> Result<ForwardOutput> {
        ensure!(
            batch.is_empty() || batch.bits() == self.entry.n_features,
            "batch feature width {} != model features {}",
            batch.bits(),
            self.entry.n_features
        );
        let mut out = ForwardOutput::empty(self.n_classes(), self.c_total());
        let mut i = 0;
        while i < batch.rows() {
            let remaining = batch.rows() - i;
            let exec = self
                .manifest
                .exec_batch(remaining)
                .ok_or_else(|| anyhow!("manifest lists no artifact batch sizes"))?;
            let take = exec.min(remaining);
            let runner = self.runner(exec)?;
            let x = packed_to_f32(batch, i, i + take);
            let o = if take == runner.batch {
                runner.run(&x)?
            } else {
                runner.run_padded(&x, take)?
            };
            out.append(o)?;
            i += take;
        }
        Ok(out)
    }
}
