//! The pluggable inference-backend seam.
//!
//! [`InferenceBackend`] is the execution contract the coordinator and CLI
//! program against; [`BackendSpec`] is the thread-crossing factory (PJRT
//! backends are not `Send`, so every worker constructs its own backend
//! from the spec inside its own thread); [`NativeBackend`] is the
//! default pure-Rust implementation.

use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Result};

use crate::flow::FlowConfig;
use crate::hw::{HwArch, HwOutcome};
use crate::tm::{
    ForwardScratch, HotLoopStats, PackedBatch, PartialOutput, PayloadCache, Store, TmModel,
};

use super::ForwardOutput;

/// One inference execution engine for a single model.
///
/// Implementations accept a logical batch of any size (chunking and
/// padding to fixed artifact batch sizes, where needed, is the backend's
/// concern, not the caller's). The batch arrives *bit-packed* — the
/// coordinator packs each request once at ingestion, so backends never
/// see a `Vec<bool>` on the request path.
pub trait InferenceBackend {
    /// Short backend identifier (`"native"`, `"pjrt"`).
    fn kind(&self) -> &'static str;
    /// Execution platform label for operator-facing output (e.g. the
    /// PJRT client's device name); defaults to the backend kind.
    fn platform(&self) -> String {
        self.kind().to_string()
    }
    /// Name of the model this backend executes.
    fn model_name(&self) -> &str;
    /// Feature width of the served model. This is the admission-control
    /// contract: the coordinator caches it at pool startup and refuses
    /// width-mismatched rows at ingestion (typed `WidthMismatch`), so
    /// `forward` normally sees width-matched batches from the pool — the
    /// `Result` stays for defense in depth and non-pool callers.
    fn n_features(&self) -> usize;
    fn n_classes(&self) -> usize;
    /// Total clause count (`n_classes × clauses_per_class`).
    fn c_total(&self) -> usize;
    /// Run the forward pass over a packed batch of feature rows
    /// (`batch.bits()` must equal [`InferenceBackend::n_features`] unless
    /// the batch is empty).
    fn forward(&self, batch: &PackedBatch) -> Result<ForwardOutput>;
    /// Replay row `row` of a forward output through the attached simulated
    /// hardware engine, if this backend carries one (see
    /// [`crate::hw::HwEngine`]). Backends without hardware return `None`,
    /// so callers (the coordinator's `ReplayPolicy`) need no
    /// special-casing per backend kind.
    fn replay(&self, out: &ForwardOutput, row: usize) -> Option<HwOutcome> {
        let _ = (out, row);
        None
    }
    /// The simulated hardware architecture attached to this backend, if
    /// any.
    fn hw_arch(&self) -> Option<HwArch> {
        None
    }
    /// Run the forward pass and return this backend's *partial* view of
    /// the batch (per-class i32 partial sums + shard-local fired words —
    /// see `tm::PartialOutput`). An unsharded backend is, definitionally,
    /// shard 0 of a 1-shard plan, so the default wraps [`InferenceBackend::forward`];
    /// a shard-serving backend ([`super::ShardBackend`]) overrides this
    /// with its genuine partial evaluation. The reduce side
    /// (`tm::merge_partials`, the coordinator's scatter/reduce plan)
    /// accepts either.
    fn forward_partial(&self, batch: &PackedBatch) -> Result<PartialOutput> {
        Ok(PartialOutput::from_full(self.forward(batch)?))
    }
    /// `(shard index, shard count)` when this backend serves one clause
    /// shard of its model; `None` for whole-model backends.
    fn shard(&self) -> Option<(usize, usize)> {
        None
    }
    /// Cumulative hot-loop telemetry (rows / skipped / eligible /
    /// pruned) for backends that run the clause-indexed scan; `None`
    /// where no such loop exists (e.g. PJRT). The coordinator diffs
    /// successive snapshots into per-batch metric deltas, which is how
    /// `ForwardScratch`'s counters reach `MetricsSnapshot` and the
    /// `serve` per-tenant breakdown.
    fn hot_loop_stats(&self) -> Option<HotLoopStats> {
        None
    }
}

/// A `Send + Clone` recipe for constructing a backend inside a worker
/// thread. This is the only backend handle that crosses threads.
#[derive(Debug, Clone, Default)]
pub enum BackendSpec {
    /// Pure-Rust evaluation of a model loaded from the artifact manifest.
    #[default]
    Native,
    /// Pure-Rust evaluation of an in-memory model — no artifacts required
    /// (synthetic workloads, tests, CI).
    InMemory(Arc<TmModel>),
    /// Pure-Rust evaluation over a *set* of in-memory models, looked up
    /// by name at open time — the artifact-free way to drive a
    /// multi-model coordinator pool (`Coordinator::start_multi`) from
    /// tests and benches. Unknown names fail at open, like the
    /// manifest-backed specs.
    InMemorySet(Arc<Vec<Arc<TmModel>>>),
    /// [`FaultInjectingBackend`] over an in-memory model: native
    /// evaluation whose `forward` fails whenever the batch contains the
    /// all-true poison row. Chaos drills and the coordinator's fail-soft
    /// tests; not reachable from the CLI.
    FaultInjecting(Arc<TmModel>),
    /// Native functional results plus a simulated hardware engine
    /// ([`crate::hw::HwEngine`]) of the chosen architecture for per-request
    /// on-chip timing (`--backend hw:<async|adder|fpt18>`). `model: None`
    /// loads from the artifact manifest; `Some` serves an in-memory model
    /// (tests, synthetic workloads).
    TimeDomain {
        arch: HwArch,
        flow: FlowConfig,
        model: Option<Arc<TmModel>>,
    },
    /// Serve one clause shard of a model ([`super::ShardBackend`] over a
    /// `tm::ClauseShard` view): `forward_partial` returns the shard's
    /// partial class sums + shard-local fired words, and `forward`
    /// satisfies the whole-model contract with shard-local argmax (only
    /// meaningful behind the coordinator's scatter/reduce plan, which
    /// re-argmaxes over merged sums). `model: None` loads from the
    /// artifact manifest; `hw: Some(arch)` attaches a per-shard
    /// simulated engine so `ReplayPolicy` replay yields per-shard
    /// decision latencies the reduce maxes into a critical-path
    /// estimate. `for_worker` assigns worker `w` shard `w % n_shards`,
    /// which is how `Coordinator::start_sharded` pins one shard per
    /// worker.
    Sharded {
        model: Option<Arc<TmModel>>,
        shard: ShardSpec,
        hw: Option<HwArch>,
    },
    /// Execute the AOT-compiled HLO on a PJRT client (requires artifacts
    /// and real xla bindings; see rust/README.md).
    #[cfg(feature = "pjrt")]
    Pjrt,
}

/// Which clause shard of a model a [`BackendSpec::Sharded`] spec serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Shard ordinal, `0..n_shards`.
    pub index: usize,
    /// Total shards in the plan.
    pub n_shards: usize,
}

impl ShardSpec {
    /// Shard 0 of an `n_shards` plan — the placeholder
    /// `Coordinator::start_sharded` hands to `for_worker`, which picks
    /// the real per-worker index.
    pub fn first_of(n_shards: usize) -> ShardSpec {
        ShardSpec { index: 0, n_shards }
    }
}

impl BackendSpec {
    /// Parse a CLI-style backend name.
    pub fn from_name(name: &str) -> Result<BackendSpec> {
        if let Some(arch) = name.strip_prefix("hw:") {
            return Ok(BackendSpec::TimeDomain {
                arch: HwArch::from_name(arch)?,
                flow: FlowConfig::table1_default(),
                model: None,
            });
        }
        match name {
            "native" => Ok(BackendSpec::Native),
            "hw" => bail!(
                "backend `hw` needs an architecture: hw:async, hw:adder, hw:fpt18"
            ),
            #[cfg(feature = "pjrt")]
            "pjrt" => Ok(BackendSpec::Pjrt),
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => bail!("this binary was built without the `pjrt` feature"),
            other => bail!(
                "unknown backend {other:?} (expected: native, pjrt, hw:<async|adder|fpt18>)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendSpec::Native => "native",
            BackendSpec::InMemory(_) => "native(in-memory)",
            BackendSpec::InMemorySet(_) => "native(in-memory-set)",
            BackendSpec::FaultInjecting(_) => "native+faults",
            BackendSpec::TimeDomain { arch: HwArch::Async, .. } => "hw:async",
            BackendSpec::TimeDomain { arch: HwArch::Adder, .. } => "hw:adder",
            BackendSpec::TimeDomain { arch: HwArch::Fpt18, .. } => "hw:fpt18",
            BackendSpec::Sharded { .. } => "sharded",
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt => "pjrt",
        }
    }

    /// Whether this spec needs the artifact manifest at `root` to open.
    pub fn needs_manifest(&self) -> bool {
        !matches!(
            self,
            BackendSpec::InMemory(_)
                | BackendSpec::InMemorySet(_)
                | BackendSpec::FaultInjecting(_)
                | BackendSpec::TimeDomain { model: Some(_), .. }
                | BackendSpec::Sharded { model: Some(_), .. }
        )
    }

    /// Derive the spec worker `w` should open: time-domain specs get a
    /// distinct die seed per worker (independent simulated chips, like a
    /// rack of boards), sharded specs pin worker `w` to shard
    /// `w % n_shards` (the coordinator's scatter plan: one shard per
    /// worker), every other spec is unchanged.
    pub fn for_worker(mut self, w: usize) -> BackendSpec {
        match &mut self {
            BackendSpec::TimeDomain { flow, .. } => {
                flow.die_seed = flow.die_seed.wrapping_add(w as u64);
            }
            BackendSpec::Sharded { shard, .. } => {
                shard.index = w % shard.n_shards.max(1);
            }
            _ => {}
        }
        self
    }

    /// Construct the backend for `model` from the artifacts at `root`.
    ///
    /// Called from the thread that will own the backend; performs all
    /// expensive startup work (model load, PJRT pre-compilation) so
    /// failures surface at startup rather than on the first request.
    pub fn open(&self, root: &Path, model: &str) -> Result<Box<dyn InferenceBackend>> {
        self.open_cached(root, model, None)
    }

    /// [`BackendSpec::open`] with a shared payload cache. Manifest-backed
    /// specs open the tree through [`Store::open`] (v1 directories and v2
    /// content-addressed trees both work; v2 opens verify object hashes),
    /// and a `cache` turns unchanged-hash payloads into no-disk-touch
    /// hits — the mechanism behind the coordinator's delta-aware reload.
    /// On a v2 tree a [`BackendSpec::Sharded`] spec loads **only the
    /// objects overlapping its own clause range** instead of the whole
    /// model.
    pub fn open_cached(
        &self,
        root: &Path,
        model: &str,
        cache: Option<&PayloadCache>,
    ) -> Result<Box<dyn InferenceBackend>> {
        match self {
            BackendSpec::Native => {
                let store = Store::open(root)?;
                let m = Arc::new(store.load_model(model, cache)?);
                Ok(Box::new(NativeBackend::new(m)))
            }
            BackendSpec::InMemory(m) => {
                // Keep the "unknown model fails at startup" guarantee the
                // manifest-backed specs get from `Manifest::entry`.
                ensure!(
                    m.name == model,
                    "in-memory spec holds model {:?}, not {model:?}",
                    m.name
                );
                Ok(Box::new(NativeBackend::new(m.clone())))
            }
            BackendSpec::InMemorySet(models) => {
                let m = models.iter().find(|m| m.name == model).ok_or_else(|| {
                    let held: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
                    anyhow::anyhow!("in-memory set holds models {held:?}, not {model:?}")
                })?;
                Ok(Box::new(NativeBackend::new(m.clone())))
            }
            BackendSpec::FaultInjecting(m) => {
                ensure!(
                    m.name == model,
                    "in-memory spec holds model {:?}, not {model:?}",
                    m.name
                );
                Ok(Box::new(FaultInjectingBackend::new(m.clone())))
            }
            BackendSpec::TimeDomain { arch, flow, model: mem } => {
                let m = match mem {
                    Some(m) => {
                        ensure!(
                            m.name == model,
                            "in-memory spec holds model {:?}, not {model:?}",
                            m.name
                        );
                        m.clone()
                    }
                    None => {
                        let store = Store::open(root)?;
                        Arc::new(store.load_model(model, cache)?)
                    }
                };
                Ok(Box::new(super::hw_backend::HwBackend::build(m, *arch, flow)?))
            }
            BackendSpec::Sharded { model: mem, shard, hw } => {
                let m = match mem {
                    Some(m) => {
                        ensure!(
                            m.name == model,
                            "in-memory spec holds model {:?}, not {model:?}",
                            m.name
                        );
                        m.clone()
                    }
                    None => {
                        let store = Store::open(root)?;
                        if store.is_v2() {
                            // Content-addressed tree: this worker loads
                            // only the objects overlapping its own clause
                            // range; every other clause comes back dead.
                            let sub = store.load_model_subset(
                                model,
                                shard.index,
                                shard.n_shards,
                                cache,
                            )?;
                            return Ok(Box::new(
                                super::shard_backend::ShardBackend::build_subset(
                                    Arc::new(sub),
                                    *shard,
                                    *hw,
                                )?,
                            ));
                        }
                        Arc::new(store.load_model(model, cache)?)
                    }
                };
                Ok(Box::new(super::shard_backend::ShardBackend::build(m, *shard, *hw)?))
            }
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt => {
                let b = super::pjrt::PjrtBackend::open(root, model)?;
                b.warm()?;
                Ok(Box::new(b))
            }
        }
    }
}

/// Pure-Rust execution of the TM forward pass, fully packed: clause
/// evaluation over bit-packed `u64` literal words (through the
/// adaptive `TmModel::forward_packed_with` dispatch — row-major
/// clause-indexed scan for small batches, the bit-sliced transposed
/// engine of `tm::slice` for batches of `tm::SLICED_MIN_ROWS` rows or
/// more), class sums via word-level popcount or CSA vertical counters,
/// argmax — directly from the trained model weights, with no bool/int
/// materialization anywhere.
/// `Send + Sync`: the model is immutable shared data, and the per-batch
/// scratch (buffer reuse + skip telemetry) sits behind a `Mutex` that
/// is uncontended in practice — each pool worker constructs its own
/// backend from the spec (same ownership shape as the hw engine mutex
/// in `HwBackend`).
pub struct NativeBackend {
    model: Arc<TmModel>,
    scratch: Mutex<ForwardScratch>,
}

impl NativeBackend {
    pub fn new(model: Arc<TmModel>) -> NativeBackend {
        NativeBackend { model, scratch: Mutex::new(ForwardScratch::new()) }
    }

    /// Load `model` from the artifact tree at `root` (v1 or v2 — see
    /// [`Store::open`]).
    pub fn open(root: &Path, model: &str) -> Result<NativeBackend> {
        Ok(NativeBackend::new(Arc::new(Store::open(root)?.load_model(model, None)?)))
    }

    pub fn model(&self) -> &TmModel {
        &self.model
    }

    /// Fraction of clause evaluations the clause index skipped over the
    /// backend's lifetime (telemetry; 0.0 before any batch).
    pub fn skip_rate(&self) -> f64 {
        // A poisoned scratch only means a panicking thread died mid-
        // forward; the counters are still coherent enough for telemetry.
        self.scratch.lock().unwrap_or_else(|e| e.into_inner()).skip_rate()
    }
}

impl InferenceBackend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn model_name(&self) -> &str {
        &self.model.name
    }

    fn n_features(&self) -> usize {
        self.model.n_features
    }

    fn n_classes(&self) -> usize {
        self.model.n_classes
    }

    fn c_total(&self) -> usize {
        self.model.c_total()
    }

    fn forward(&self, batch: &PackedBatch) -> Result<ForwardOutput> {
        let mut scratch = self.scratch.lock().unwrap_or_else(|e| e.into_inner());
        self.model.forward_packed_with(batch, &mut scratch)
    }

    fn hot_loop_stats(&self) -> Option<HotLoopStats> {
        Some(self.scratch.lock().unwrap_or_else(|e| e.into_inner()).stats())
    }
}

/// Fault-injection wrapper around [`NativeBackend`] (chaos drills and
/// the coordinator's fail-soft tests): `forward` fails whenever the
/// batch contains a *poison row* — every feature bit set — **panics**
/// on a *panic row* — every bit set except the first — and behaves
/// exactly like the native backend otherwise. This exercises the
/// coordinator's split-and-retry and panic-containment paths through
/// the real backend seam instead of a mock: a marked row submitted
/// alongside healthy neighbors fails its batch, the coordinator retries
/// per-row, the neighbors are served, and only the marked caller sees a
/// typed `BackendFailed`.
pub struct FaultInjectingBackend {
    inner: NativeBackend,
}

impl FaultInjectingBackend {
    pub fn new(model: Arc<TmModel>) -> FaultInjectingBackend {
        FaultInjectingBackend { inner: NativeBackend::new(model) }
    }

    /// The input that makes `forward` fail: a row of all-true features.
    pub fn poison_row(n_features: usize) -> Vec<bool> {
        vec![true; n_features]
    }

    /// The input that makes `forward` *panic* (needs ≥ 2 features): all
    /// bits set except the first.
    pub fn panic_row(n_features: usize) -> Vec<bool> {
        let mut row = vec![true; n_features];
        row[0] = false;
        row
    }

    fn is_poison(batch: &PackedBatch, row: usize) -> bool {
        batch.bits() > 0 && (0..batch.bits()).all(|i| batch.bit(row, i))
    }

    fn is_panic(batch: &PackedBatch, row: usize) -> bool {
        batch.bits() > 1
            && !batch.bit(row, 0)
            && (1..batch.bits()).all(|i| batch.bit(row, i))
    }
}

impl InferenceBackend for FaultInjectingBackend {
    fn kind(&self) -> &'static str {
        "native+faults"
    }

    fn model_name(&self) -> &str {
        self.inner.model_name()
    }

    fn n_features(&self) -> usize {
        self.inner.n_features()
    }

    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }

    fn c_total(&self) -> usize {
        self.inner.c_total()
    }

    fn forward(&self, batch: &PackedBatch) -> Result<ForwardOutput> {
        for r in 0..batch.rows() {
            if Self::is_poison(batch, r) {
                bail!("injected fault: row {r} of {} is the poison row", batch.rows());
            }
            if Self::is_panic(batch, r) {
                panic!("injected panic: row {r} of {} is the panic row", batch.rows());
            }
        }
        self.inner.forward(batch)
    }

    fn hot_loop_stats(&self) -> Option<HotLoopStats> {
        self.inner.hot_loop_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::model::tests::toy;

    fn backend() -> NativeBackend {
        NativeBackend::new(Arc::new(toy()))
    }

    #[test]
    fn forward_matches_model_methods() {
        let b = backend();
        let rows = vec![
            vec![true, false],
            vec![true, true],
            vec![false, false],
        ];
        let out = b.forward(&PackedBatch::from_rows(&rows).unwrap()).unwrap();
        assert_eq!(out.batch, 3);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(out.sums_row(i), &b.model().class_sums(row)[..], "row {i}");
            assert_eq!(out.pred[i] as usize, b.model().predict(row), "row {i}");
            let per_class: Vec<Vec<bool>> = out.clause_bits_row(i);
            assert_eq!(per_class, b.model().clause_bits(row), "row {i}");
        }
    }

    #[test]
    fn large_batches_take_the_sliced_engine_and_report_it() {
        let b = backend();
        let rows: Vec<Vec<bool>> =
            (0..100).map(|i| vec![i % 2 == 0, i % 3 == 0]).collect();
        let batch = PackedBatch::from_rows(&rows).unwrap();
        let out = b.forward(&batch).unwrap();
        assert_eq!(out.batch, 100);
        // Dispatch is observable only through the telemetry: a 100-row
        // batch runs as two sliced groups, and predictions still match
        // the scalar reference.
        let stats = b.hot_loop_stats().unwrap();
        assert_eq!(stats.sliced_groups, 2);
        assert_eq!(stats.sliced_rows, 100);
        assert_eq!(stats.rows, 100);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(out.pred[i] as usize, b.model().predict(row), "row {i}");
        }
    }

    #[test]
    fn forward_rejects_wrong_feature_width() {
        let b = backend();
        assert!(b.forward(&PackedBatch::single(&[true; 3])).is_err());
    }

    #[test]
    fn forward_empty_batch() {
        let b = backend();
        let out = b.forward(&PackedBatch::from_rows(&[]).unwrap()).unwrap();
        assert_eq!(out.batch, 0);
        assert!(out.pred.is_empty());
    }

    #[test]
    fn spec_parsing() {
        assert!(matches!(BackendSpec::from_name("native"), Ok(BackendSpec::Native)));
        assert!(BackendSpec::from_name("hls").is_err());
        assert_eq!(BackendSpec::default().name(), "native");
        assert!(!BackendSpec::InMemory(Arc::new(toy())).needs_manifest());
    }

    #[test]
    fn hw_spec_parsing() {
        let spec = BackendSpec::from_name("hw:adder").unwrap();
        assert!(matches!(spec, BackendSpec::TimeDomain { arch: HwArch::Adder, .. }));
        assert_eq!(spec.name(), "hw:adder");
        assert!(spec.needs_manifest(), "manifest-backed until a model is attached");
        // Bad architecture names fail with the valid set listed.
        let err = BackendSpec::from_name("hw:systolic").unwrap_err().to_string();
        assert!(err.contains("adder") && err.contains("fpt18"), "{err}");
        assert!(BackendSpec::from_name("hw").is_err());
        // In-memory time-domain specs need no artifacts, and each worker
        // gets its own die.
        let spec = BackendSpec::TimeDomain {
            arch: HwArch::Async,
            flow: FlowConfig::table1_default(),
            model: Some(Arc::new(toy())),
        };
        assert!(!spec.needs_manifest());
        let reseeded = spec.clone().for_worker(3);
        match (&spec, &reseeded) {
            (
                BackendSpec::TimeDomain { flow: f0, .. },
                BackendSpec::TimeDomain { flow: f3, .. },
            ) => assert_eq!(f3.die_seed, f0.die_seed + 3),
            _ => unreachable!(),
        }
    }

    #[test]
    fn fault_injecting_backend_fails_only_on_poison_rows() {
        let model = Arc::new(toy());
        let faulty = FaultInjectingBackend::new(model.clone());
        let native = NativeBackend::new(model.clone());
        let clean = vec![vec![true, false], vec![false, false]];
        let batch = PackedBatch::from_rows(&clean).unwrap();
        assert_eq!(
            faulty.forward(&batch).unwrap(),
            native.forward(&batch).unwrap(),
            "clean batches are served exactly like the native backend"
        );

        // Any batch containing the poison row fails, with the row named.
        let poison = FaultInjectingBackend::poison_row(model.n_features);
        let rows = vec![clean[0].clone(), poison, clean[1].clone()];
        let err = faulty.forward(&PackedBatch::from_rows(&rows).unwrap()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("injected fault") && msg.contains("row 1"), "{msg}");

        // The panic row panics (callers contain it with catch_unwind).
        let panic_rows = vec![FaultInjectingBackend::panic_row(model.n_features)];
        let batch = PackedBatch::from_rows(&panic_rows).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = faulty.forward(&batch);
        }));
        assert!(caught.is_err(), "panic row must panic");

        // The spec opens artifact-free and enforces the model name.
        let spec = BackendSpec::FaultInjecting(model);
        assert_eq!(spec.name(), "native+faults");
        assert!(!spec.needs_manifest());
        let b = spec.open(std::path::Path::new("/nonexistent"), "toy").unwrap();
        assert_eq!(b.kind(), "native+faults");
        assert!(spec.open(std::path::Path::new("/nonexistent"), "other").is_err());
    }

    #[test]
    fn in_memory_spec_opens_without_artifacts() {
        let spec = BackendSpec::InMemory(Arc::new(toy()));
        let b = spec.open(std::path::Path::new("/nonexistent"), "toy").unwrap();
        assert_eq!(b.kind(), "native");
        assert_eq!(b.model_name(), "toy");
        assert_eq!(b.n_classes(), 2);
    }

    #[test]
    fn in_memory_set_opens_each_model_by_name() {
        let other = Arc::new(crate::tm::TmModel::synthetic("other", 3, 4, 7, 0.2, 1));
        let spec = BackendSpec::InMemorySet(Arc::new(vec![Arc::new(toy()), other]));
        assert!(!spec.needs_manifest());
        assert_eq!(spec.name(), "native(in-memory-set)");
        let root = std::path::Path::new("/nonexistent");
        let a = spec.open(root, "toy").unwrap();
        assert_eq!((a.model_name(), a.n_features()), ("toy", 2));
        let b = spec.open(root, "other").unwrap();
        assert_eq!((b.model_name(), b.n_features()), ("other", 7));
        // Unknown names fail at open with the held set listed.
        let err = spec.open(root, "missing").unwrap_err().to_string();
        assert!(err.contains("toy") && err.contains("other"), "{err}");
    }
}
