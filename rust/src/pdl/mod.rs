//! Programmable delay line (paper §III-A).
//!
//! A PDL converts a binary code into a cumulative propagation delay that is
//! *inversely* proportional to the code's Hamming weight: every delay
//! element is a LUT configured as a 2:1 mux whose select bit picks either a
//! low-latency or a high-latency input net. For the TM case study one PDL
//! per class receives that class's clause outputs; clause polarity is
//! handled by swapping the net connections at the element inputs
//! (§III-A.1): a positive clause's `1` takes the short arc, a negative
//! clause's `1` takes the long arc (a firing negative clause must *slow*
//! its class down).
//!
//! The start transition is synchronized through a D-FF per PDL (§III-A.2)
//! so fanout skew on the request signal cannot bias the race.

use crate::flow::RoutedPdl;
use crate::util::Ps;

pub mod resources;

pub use resources::PdlResources;

/// Clause polarity: whether a `1` on this element's select input represents
/// a vote *for* (positive) or *against* (negative) the class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Polarity {
    Positive,
    Negative,
}

/// One delay element: the two routed arc delays plus the polarity wiring of
/// its select input.
#[derive(Debug, Clone, Copy)]
pub struct DelayElement {
    /// Stage traversal delay via the low-latency arc.
    pub lo: Ps,
    /// Stage traversal delay via the high-latency arc.
    pub hi: Ps,
    pub polarity: Polarity,
}

impl DelayElement {
    /// Stage delay for a select bit, honoring polarity (paper §III-A.1:
    /// positive clause 1→short/0→long; negative clause wiring swapped).
    #[inline]
    pub fn stage_delay(&self, bit: bool) -> Ps {
        let take_short = match self.polarity {
            Polarity::Positive => bit,
            Polarity::Negative => !bit,
        };
        if take_short {
            self.lo
        } else {
            self.hi
        }
    }

    /// Timing resolution of this stage.
    pub fn delta(&self) -> Ps {
        self.hi.saturating_sub(self.lo)
    }
}

/// A programmable delay line: the start-sync FF plus the element chain.
#[derive(Debug, Clone)]
pub struct Pdl {
    /// Class (or neuron) index this PDL serves.
    pub index: usize,
    pub elements: Vec<DelayElement>,
    /// Clock-to-Q of the start-synchronization FF.
    pub start_sync: Ps,
}

impl Pdl {
    /// Build from a routed PDL and the per-element polarities (length must
    /// match; TM wiring alternates +,−,+,− per the training convention).
    pub fn from_routed(routed: &RoutedPdl, polarities: &[Polarity]) -> Pdl {
        assert_eq!(routed.len(), polarities.len(), "one polarity per element");
        let elements = routed
            .elements
            .iter()
            .zip(polarities)
            .map(|(e, &p)| DelayElement { lo: e.lo_total, hi: e.hi_total, polarity: p })
            .collect();
        Pdl { index: routed.index, elements, start_sync: crate::fabric::FF_CLK_TO_Q }
    }

    /// Standard TM polarity pattern: even element index positive.
    pub fn tm_polarities(n: usize) -> Vec<Polarity> {
        (0..n)
            .map(|i| if i % 2 == 0 { Polarity::Positive } else { Polarity::Negative })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.elements.len()
    }

    pub fn is_empty(&self) -> bool {
        self.elements.is_empty()
    }

    /// Behavioral propagation: time from the start clock edge until the
    /// transition exits the chain, for the given select bits.
    ///
    /// This is the hot path of every experiment sweep; the event-driven
    /// simulator (crate::timing) validates it on small circuits.
    #[inline]
    pub fn propagate(&self, bits: &[bool]) -> Ps {
        debug_assert_eq!(bits.len(), self.elements.len());
        let mut t = self.start_sync.0;
        for (e, &b) in self.elements.iter().zip(bits) {
            t += e.stage_delay(b).0;
        }
        Ps(t)
    }

    /// The *class-sum → delay* law: with per-stage delta δ and vote count v
    /// (signed popcount), traversal ≈ max_traversal − δ·(v_offset + v).
    /// Used by analyses; `propagate` is the ground truth.
    pub fn max_traversal(&self) -> Ps {
        Ps(self.start_sync.0 + self.elements.iter().map(|e| e.hi.0).sum::<u64>())
    }

    pub fn min_traversal(&self) -> Ps {
        Ps(self.start_sync.0 + self.elements.iter().map(|e| e.lo.0).sum::<u64>())
    }

    pub fn mean_delta(&self) -> Ps {
        if self.elements.is_empty() {
            return Ps::ZERO;
        }
        Ps(self.elements.iter().map(|e| e.delta().0).sum::<u64>() / self.elements.len() as u64)
    }

    /// Number of stages that take the short arc for this input — the
    /// quantity the PDL physically popcounts.
    pub fn effective_weight(&self, bits: &[bool]) -> usize {
        self.elements
            .iter()
            .zip(bits)
            .filter(|(e, &b)| match e.polarity {
                Polarity::Positive => b,
                Polarity::Negative => !b,
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Device, VariationModel, VariationParams};
    use crate::flow::{place_pdls, route_pdl, FlowConfig, PinAssignment};
    use crate::util::prop;

    fn ideal_pdl(n: usize, lo: u64, hi: u64, pol: Vec<Polarity>) -> Pdl {
        let d = Device::xc7z020();
        let p = place_pdls(&d, 1, n).unwrap().remove(0);
        let var = VariationModel::new(0, VariationParams::none());
        let cfg = FlowConfig::ideal(Ps(lo), Ps(hi));
        let routed = route_pdl(&d, &p, &PinAssignment::fastest_pair(), &cfg, &var).unwrap();
        Pdl::from_routed(&routed, &pol)
    }

    #[test]
    fn positive_polarity_one_is_fast() {
        let pdl = ideal_pdl(4, 400, 600, vec![Polarity::Positive; 4]);
        let fast = pdl.propagate(&[true; 4]);
        let slow = pdl.propagate(&[false; 4]);
        assert_eq!(fast, pdl.min_traversal());
        assert_eq!(slow, pdl.max_traversal());
    }

    #[test]
    fn negative_polarity_swaps_arcs() {
        let pdl = ideal_pdl(4, 400, 600, vec![Polarity::Negative; 4]);
        assert_eq!(pdl.propagate(&[true; 4]), pdl.max_traversal());
        assert_eq!(pdl.propagate(&[false; 4]), pdl.min_traversal());
    }

    #[test]
    fn delay_decreases_linearly_with_weight() {
        let n = 20;
        let pdl = ideal_pdl(n, 380, 620, vec![Polarity::Positive; n]);
        let delta = pdl.elements[0].delta();
        let mut prev = pdl.propagate(&vec![false; n]);
        for w in 1..=n {
            let mut bits = vec![false; n];
            bits[..w].iter_mut().for_each(|b| *b = true);
            let t = pdl.propagate(&bits);
            assert_eq!(prev - t, delta, "each extra 1 removes exactly one delta");
            prev = t;
        }
    }

    #[test]
    fn mixed_polarity_counts_signed_votes() {
        // +,− alternating: input [1,0] = one supporting vote + one
        // non-firing negative clause ⇒ both take the short arc.
        let pdl = ideal_pdl(2, 400, 600, Pdl::tm_polarities(2));
        assert_eq!(pdl.propagate(&[true, false]), pdl.min_traversal());
        // [0,1]: no support, firing negative clause ⇒ both long.
        assert_eq!(pdl.propagate(&[false, true]), pdl.max_traversal());
    }

    #[test]
    fn prop_delay_is_monotone_in_effective_weight() {
        prop::check("pdl delay monotone in effective weight", 60, |g| {
            let n = g.int(2, 120) as usize;
            let pdl = ideal_pdl(n, 380, 620, Pdl::tm_polarities(n));
            let a: Vec<bool> = g.bits(n, 0.5);
            let b: Vec<bool> = g.bits(n, 0.5);
            let (wa, wb) = (pdl.effective_weight(&a), pdl.effective_weight(&b));
            let (ta, tb) = (pdl.propagate(&a), pdl.propagate(&b));
            if wa > wb {
                assert!(ta < tb, "higher weight must be strictly faster (ideal PDL)");
            } else if wa == wb {
                assert_eq!(ta, tb);
            }
        });
    }

    #[test]
    fn prop_variation_preserves_monotonicity_with_wide_window() {
        prop::check("variation-safe monotonicity", 20, |g| {
            let d = Device::xc7z020();
            let n = g.int(10, 150) as usize;
            let p = place_pdls(&d, 1, n).unwrap().remove(0);
            let params = VariationParams::default();
            let var = VariationModel::new(g.int(0, 10_000) as u64, params);
            let cfg = FlowConfig::table1_default();
            let routed = route_pdl(&d, &p, &PinAssignment::fastest_pair(), &cfg, &var).unwrap();
            let pdl = Pdl::from_routed(&routed, &vec![Polarity::Positive; n]);
            // Weight w vs w+2: ≥2·δ_min margin ⇒ must order correctly even
            // under the default 2 % variation.
            let w = g.int(0, (n - 2) as i64) as usize;
            let mut lo_bits = vec![false; n];
            lo_bits[..w].iter_mut().for_each(|b| *b = true);
            let mut hi_bits = vec![false; n];
            hi_bits[..w + 2].iter_mut().for_each(|b| *b = true);
            assert!(pdl.propagate(&hi_bits) < pdl.propagate(&lo_bits));
        });
    }
}
