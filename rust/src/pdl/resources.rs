//! Resource accounting for the time-domain popcount (paper Fig. 9b/11).
//!
//! One delay element = one LUT (the 2:1 mux). Each PDL adds a start-sync FF
//! and each input bit needs its polarity wiring (free: it is just net
//! permutation). Arbiter costs live in [`crate::arbiter::resources`].

/// LUT/FF cost of a set of PDLs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PdlResources {
    pub luts: u32,
    pub ffs: u32,
}

impl PdlResources {
    /// `n_pdls` PDLs of `n_elements` each.
    ///
    /// * 1 LUT per delay element (paper §III-A.2);
    /// * 1 start-sync FF per PDL (§III-A.2's fanout-skew mitigation);
    /// * 1 FF per PDL output capture at the arbiter boundary.
    pub fn for_pdls(n_pdls: usize, n_elements: usize) -> PdlResources {
        PdlResources {
            luts: (n_pdls * n_elements) as u32,
            ffs: (2 * n_pdls) as u32,
        }
    }

    pub fn total(&self) -> u32 {
        self.luts + self.ffs
    }

    pub fn add(self, other: PdlResources) -> PdlResources {
        PdlResources { luts: self.luts + other.luts, ffs: self.ffs + other.ffs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_lut_per_element() {
        let r = PdlResources::for_pdls(3, 100);
        assert_eq!(r.luts, 300);
        assert_eq!(r.ffs, 6);
        assert_eq!(r.total(), 306);
    }

    #[test]
    fn scales_linearly() {
        let a = PdlResources::for_pdls(1, 50);
        let b = PdlResources::for_pdls(2, 50);
        assert_eq!(b.luts, 2 * a.luts);
    }
}
