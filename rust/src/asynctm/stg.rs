//! Signal transition graph (STG) of the asynchronous controller
//! (paper Fig. 8).
//!
//! The controller's causal contract, as the paper draws it:
//!
//! ```text
//!   req±  →  start±  →  (PDL outputs ±…)  →  Completion±
//!   Completion±  →  wait±           (merge fragment, via arbiters)
//!   all PDL outputs±  →  wait-release  (join fragment)
//!   wait-release  →  ack±  →  done±  →  req∓ (next token)
//! ```
//!
//! plus the dotted-arc timing assumption: the bundling delay exceeds the
//! clause-block settling time. [`Stg`] encodes the partial order;
//! [`Stg::validate`] checks a recorded trace against it — used both by the
//! engine's self-checks and by the event-driven MOUSETRAP tests.

use std::collections::BTreeMap;

use crate::util::Ps;

/// Signals of the Fig. 8 STG.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum StgSignal {
    Req,
    Start,
    /// Output of PDL k arrived.
    PdlOut(usize),
    Completion,
    Wait,
    Ack,
    Done,
}

/// One recorded transition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StgEvent {
    pub signal: StgSignal,
    pub at: Ps,
}

/// The STG as a set of precedence constraints over one inference cycle.
#[derive(Debug, Clone)]
pub struct Stg {
    pub n_pdls: usize,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum StgViolation {
    #[error("signal {0:?} transitioned more than once in a cycle")]
    Duplicate(StgSignal),
    #[error("missing transition of {0:?}")]
    Missing(StgSignal),
    #[error("{before:?} (t={t_before}) must precede {after:?} (t={t_after})")]
    Order { before: StgSignal, after: StgSignal, t_before: Ps, t_after: Ps },
}

impl Stg {
    pub fn new(n_pdls: usize) -> Self {
        Self { n_pdls }
    }

    /// All signals that must transition exactly once per cycle.
    fn required(&self) -> Vec<StgSignal> {
        let mut v = vec![StgSignal::Req, StgSignal::Start, StgSignal::Completion,
            StgSignal::Wait, StgSignal::Ack, StgSignal::Done];
        for k in 0..self.n_pdls {
            v.push(StgSignal::PdlOut(k));
        }
        v
    }

    /// Precedence pairs (before, after).
    fn edges(&self) -> Vec<(StgSignal, StgSignal)> {
        let mut e = vec![
            (StgSignal::Req, StgSignal::Start),
            (StgSignal::Completion, StgSignal::Wait),
            (StgSignal::Wait, StgSignal::Ack),
            (StgSignal::Ack, StgSignal::Done),
        ];
        for k in 0..self.n_pdls {
            e.push((StgSignal::Start, StgSignal::PdlOut(k)));
            // First PDL output suffices for Completion (the merge), but
            // *every* PDL output must precede Ack (the join): the wait
            // fragment holds the controller until the slowest arrives.
            e.push((StgSignal::PdlOut(k), StgSignal::Ack));
        }
        e
    }

    /// Check one cycle's trace. The merge (Completion after the *first*
    /// PdlOut) is validated separately from the ordered pairs.
    pub fn validate(&self, trace: &[StgEvent]) -> Result<(), StgViolation> {
        let mut times: BTreeMap<StgSignal, Ps> = BTreeMap::new();
        for ev in trace {
            if times.insert(ev.signal, ev.at).is_some() {
                return Err(StgViolation::Duplicate(ev.signal));
            }
        }
        for sig in self.required() {
            if !times.contains_key(&sig) {
                return Err(StgViolation::Missing(sig));
            }
        }
        for (a, b) in self.edges() {
            let (ta, tb) = (times[&a], times[&b]);
            if ta > tb {
                return Err(StgViolation::Order { before: a, after: b, t_before: ta, t_after: tb });
            }
        }
        // Merge fragment: Completion no earlier than the first PDL output.
        let first_pdl = (0..self.n_pdls)
            .map(|k| times[&StgSignal::PdlOut(k)])
            .min()
            .unwrap();
        let tc = times[&StgSignal::Completion];
        if tc < first_pdl {
            return Err(StgViolation::Order {
                before: StgSignal::PdlOut(0),
                after: StgSignal::Completion,
                t_before: first_pdl,
                t_after: tc,
            });
        }
        Ok(())
    }
}

/// Produce the canonical trace of one engine inference (used by tests and
/// the `async_pipeline` example to visualize the protocol).
pub fn trace_from_outcome(
    launch: Ps,
    outcome: &crate::asynctm::InferOutcome,
) -> Vec<StgEvent> {
    let mut tr = vec![
        StgEvent { signal: StgSignal::Req, at: Ps::ZERO },
        StgEvent { signal: StgSignal::Start, at: launch },
    ];
    for (k, &d) in outcome.pdl_delays.iter().enumerate() {
        tr.push(StgEvent { signal: StgSignal::PdlOut(k), at: launch + d });
    }
    let slowest = outcome.pdl_delays.iter().map(|&d| launch + d).max().unwrap();
    tr.push(StgEvent { signal: StgSignal::Completion, at: outcome.decision_latency });
    tr.push(StgEvent { signal: StgSignal::Wait, at: outcome.decision_latency });
    let ack = slowest.max(outcome.decision_latency) + Ps(124);
    tr.push(StgEvent { signal: StgSignal::Ack, at: ack });
    tr.push(StgEvent { signal: StgSignal::Done, at: outcome.cycle_latency });
    tr.sort_by_key(|e| e.at);
    tr
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(signal: StgSignal, at: u64) -> StgEvent {
        StgEvent { signal, at: Ps(at) }
    }

    fn good_trace() -> Vec<StgEvent> {
        vec![
            ev(StgSignal::Req, 0),
            ev(StgSignal::Start, 100),
            ev(StgSignal::PdlOut(0), 600),
            ev(StgSignal::Completion, 900),
            ev(StgSignal::Wait, 950),
            ev(StgSignal::PdlOut(1), 1200),
            ev(StgSignal::Ack, 1400),
            ev(StgSignal::Done, 1500),
        ]
    }

    #[test]
    fn valid_trace_passes() {
        Stg::new(2).validate(&good_trace()).unwrap();
    }

    #[test]
    fn completion_may_precede_slow_pdls() {
        // The merge fires on the first arrival — Completion at 900 before
        // PdlOut(1) at 1200 is legal (that's the async win).
        assert!(Stg::new(2).validate(&good_trace()).is_ok());
    }

    #[test]
    fn ack_before_all_pdls_is_a_violation() {
        // The join: ack before the slowest PDL output breaks the STG.
        let mut tr = good_trace();
        for e in &mut tr {
            if e.signal == StgSignal::Ack {
                e.at = Ps(1000);
            }
        }
        let err = Stg::new(2).validate(&tr).unwrap_err();
        assert!(matches!(err, StgViolation::Order { .. }), "{err}");
    }

    #[test]
    fn missing_signal_detected() {
        let tr: Vec<StgEvent> =
            good_trace().into_iter().filter(|e| e.signal != StgSignal::Wait).collect();
        assert_eq!(Stg::new(2).validate(&tr).unwrap_err(), StgViolation::Missing(StgSignal::Wait));
    }

    #[test]
    fn duplicate_signal_detected() {
        let mut tr = good_trace();
        tr.push(ev(StgSignal::Req, 1600));
        assert_eq!(Stg::new(2).validate(&tr).unwrap_err(), StgViolation::Duplicate(StgSignal::Req));
    }

    #[test]
    fn engine_traces_satisfy_stg() {
        use crate::asynctm::AsyncTmEngine;
        use crate::baselines::DesignParams;
        use crate::fabric::Device;
        use crate::flow::FlowConfig;
        use crate::tm::datasets::synthetic_clause_bits;
        use crate::tm::WorkloadSpec;
        use crate::util::SplitMix64;

        let d = Device::xc7z020();
        let params = DesignParams::synthetic(4, 30, 64);
        let mut eng = AsyncTmEngine::build(&d, &params, &FlowConfig::table1_default(), 3).unwrap();
        let launch = eng.stage.latch_delay + eng.clause_bundle;
        let spec = WorkloadSpec { n_classes: 4, clauses_per_class: 30, n_features: 64, fire_rate: 0.5 };
        let mut rng = SplitMix64::new(21);
        let stg = Stg::new(4);
        for i in 0..40 {
            let bits = synthetic_clause_bits(&spec, i % 4, &mut rng);
            let out = eng.infer(&bits);
            let tr = trace_from_outcome(launch, &out);
            stg.validate(&tr).unwrap();
        }
    }
}
