//! Time-domain BNN extension (paper §V, future work).
//!
//! The paper sketches how the time-domain popcount extends beyond TMs to
//! binarized neural networks: *"for hidden layers, each neuron can be
//! assigned a dedicated PDL, with inputs derived from synapse outputs
//! computed via XNOR. Sign activation can be performed using a shared PDL
//! with an equal number of ones and zeros as a neutral latency reference,
//! with an arbiter determining neuron activation based on the timing
//! relative to the neutral PDL."* This module implements exactly that
//! scheme on the same substrates (flow-routed PDLs + arbiters):
//!
//! * a hidden [`BnnLayer`] holds one PDL per neuron plus one shared
//!   *neutral* PDL driven by a fixed half-ones pattern; a neuron activates
//!   (+1) iff its PDL beats the neutral reference at its arbiter — the
//!   time-domain sign( popcount(xnor) − n/2 ) function;
//! * the output layer reuses [`crate::arbiter::ArbiterTree`] as the
//!   time-domain argmax, identical to the TM case.

use crate::arbiter::{Arbiter2, ArbiterConfig, ArbiterTree};
use crate::fabric::Device;
use crate::flow::{self, FlowConfig, FlowError};
use crate::pdl::{Pdl, Polarity};
use crate::util::{Ps, SplitMix64};

/// Binarized weights of one layer: `weights[n][i]` ∈ {−1, +1} encoded as
/// bool (true = +1), for neuron n and input i.
#[derive(Debug, Clone)]
pub struct BnnLayerWeights {
    pub weights: Vec<Vec<bool>>,
}

impl BnnLayerWeights {
    pub fn random(n_neurons: usize, n_inputs: usize, rng: &mut SplitMix64) -> Self {
        let weights = (0..n_neurons)
            .map(|_| (0..n_inputs).map(|_| rng.next_bool(0.5)).collect())
            .collect();
        Self { weights }
    }

    pub fn n_neurons(&self) -> usize {
        self.weights.len()
    }

    pub fn n_inputs(&self) -> usize {
        self.weights.first().map_or(0, Vec::len)
    }
}

/// One hidden layer in the time domain.
pub struct BnnLayer {
    pub weights: BnnLayerWeights,
    /// One PDL per neuron (all positive polarity: a 1 from the XNOR takes
    /// the short arc, so more matching synapses ⇒ earlier arrival).
    neuron_pdls: Vec<Pdl>,
    /// The shared neutral reference: same geometry, driven by a fixed
    /// pattern with ⌈n/2⌉ ones.
    neutral_pdl: Pdl,
    neutral_bits: Vec<bool>,
    arbiter: Arbiter2,
}

/// Outcome of one layer evaluation.
#[derive(Debug, Clone)]
pub struct LayerOutcome {
    /// Binarized activations (+1 = true).
    pub activations: Vec<bool>,
    /// When the slowest neuron's sign decision resolved (layer latency).
    pub latency: Ps,
    /// Arbiter races that entered the metastability window (popcount
    /// exactly at the sign threshold).
    pub metastable: u32,
}

impl BnnLayer {
    /// Build: places and routes `n_neurons + 1` PDLs of `n_inputs` elements
    /// (the +1 is the shared neutral line).
    pub fn build(
        device: &Device,
        weights: BnnLayerWeights,
        flow_cfg: &FlowConfig,
    ) -> Result<BnnLayer, FlowError> {
        let n = weights.n_neurons();
        let n_in = weights.n_inputs();
        let routed = flow::run(device, n + 1, n_in, flow_cfg)?;
        let pols = vec![Polarity::Positive; n_in];
        let mut pdls: Vec<Pdl> = routed.iter().map(|r| Pdl::from_routed(r, &pols)).collect();
        let neutral_pdl = pdls.pop().expect("n+1 PDLs routed");
        // Neutral reference: alternating ones/zeros, ⌈n/2⌉ ones (paper §V:
        // "an equal number of ones and zeros").
        let neutral_bits: Vec<bool> = (0..n_in).map(|i| i % 2 == 0).collect();
        Ok(BnnLayer {
            weights,
            neuron_pdls: pdls,
            neutral_pdl,
            neutral_bits,
            arbiter: Arbiter2::new(ArbiterConfig::default()),
        })
    }

    /// XNOR synapse outputs for one neuron: 1 where input matches weight.
    fn synapses(&self, neuron: usize, inputs: &[bool]) -> Vec<bool> {
        self.weights.weights[neuron]
            .iter()
            .zip(inputs)
            .map(|(&w, &x)| !(w ^ x))
            .collect()
    }

    /// Functional reference: sign(popcount(xnor) − n/2), ties → +1 here
    /// (the hardware coin-flips them; tests exclude exact ties).
    pub fn reference_activation(&self, neuron: usize, inputs: &[bool]) -> bool {
        let pop = self.synapses(neuron, inputs).iter().filter(|&&b| b).count();
        2 * pop >= self.weights.n_inputs() + self.neutral_margin()
    }

    /// Popcount of the neutral pattern × 2 − n (its signed offset). Zero
    /// for even n; +1 for odd n (⌈n/2⌉ ones).
    fn neutral_margin(&self) -> usize {
        let ones = self.neutral_bits.iter().filter(|&&b| b).count();
        2 * ones - self.weights.n_inputs()
    }

    /// Evaluate the layer in the time domain.
    pub fn forward(&self, inputs: &[bool], rng: &mut SplitMix64) -> LayerOutcome {
        assert_eq!(inputs.len(), self.weights.n_inputs());
        let t_neutral = self.neutral_pdl.propagate(&self.neutral_bits);
        let mut activations = Vec::with_capacity(self.neuron_pdls.len());
        let mut latency = Ps::ZERO;
        let mut metastable = 0;
        for (n, pdl) in self.neuron_pdls.iter().enumerate() {
            let syn = self.synapses(n, inputs);
            let t_neuron = pdl.propagate(&syn);
            // Race: neuron beats neutral ⇒ popcount above half ⇒ +1.
            let d = self.arbiter.decide(t_neuron, t_neutral, rng);
            activations.push(d.winner == 0);
            latency = latency.max(d.completion);
            metastable += d.metastable as u32;
        }
        LayerOutcome { activations, latency, metastable }
    }
}

/// A small time-domain BNN: hidden layers + a class-vote output layer
/// resolved by the arbiter tree (the paper's Fig. 7 output structure).
pub struct TimeDomainBnn {
    pub layers: Vec<BnnLayer>,
    /// Output layer: one PDL per class over the last hidden activations.
    output_weights: BnnLayerWeights,
    output_pdls: Vec<Pdl>,
    tree: ArbiterTree,
    rng: SplitMix64,
}

impl TimeDomainBnn {
    /// Random-weight network (the substrate study; training BNNs is out of
    /// scope of the paper's sketch): `dims` = [input, hidden..., classes].
    pub fn build(
        device: &Device,
        dims: &[usize],
        flow_cfg: &FlowConfig,
        seed: u64,
    ) -> Result<TimeDomainBnn, FlowError> {
        assert!(dims.len() >= 2);
        let mut rng = SplitMix64::new(seed);
        let mut layers = Vec::new();
        for w in dims[..dims.len() - 1].windows(2) {
            let weights = BnnLayerWeights::random(w[1], w[0], &mut rng);
            layers.push(BnnLayer::build(device, weights, flow_cfg)?);
        }
        // Output: one PDL per class over the last hidden width.
        let (n_classes, n_hidden) = (dims[dims.len() - 1], dims[dims.len() - 2]);
        let output_weights = BnnLayerWeights::random(n_classes, n_hidden, &mut rng);
        let routed = flow::run(device, n_classes, n_hidden, flow_cfg)?;
        let pols = vec![Polarity::Positive; n_hidden];
        let output_pdls = routed.iter().map(|r| Pdl::from_routed(r, &pols)).collect();
        Ok(TimeDomainBnn {
            layers,
            output_weights,
            output_pdls,
            tree: ArbiterTree::new(n_classes, ArbiterConfig::default()),
            rng,
        })
    }

    /// Full forward pass: hidden layers sequentially (each gated by its
    /// sign-arbiter completion), then the output-layer argmax race.
    /// Returns (predicted class, completion time).
    pub fn forward(&mut self, inputs: &[bool]) -> (usize, Ps) {
        let mut acts = inputs.to_vec();
        let mut t_total = Ps::ZERO;
        for layer in &self.layers {
            let out = layer.forward(&acts, &mut self.rng);
            acts = out.activations;
            t_total += out.latency;
        }
        // Output layer: class PDLs race through the arbiter tree (argmax).
        let arrivals: Vec<Ps> = self
            .output_pdls
            .iter()
            .enumerate()
            .map(|(k, pdl)| {
                let syn: Vec<bool> = self.output_weights.weights[k]
                    .iter()
                    .zip(&acts)
                    .map(|(&w, &x)| !(w ^ x))
                    .collect();
                t_total + pdl.propagate(&syn)
            })
            .collect();
        let d = self.tree.decide(&arrivals, &mut self.rng);
        (d.winner, d.completion)
    }

    /// Functional reference argmax over output-layer popcounts.
    pub fn reference_forward(&self, inputs: &[bool], rng_seed: u64) -> usize {
        // Hidden layers evaluated functionally (ties resolved as +1):
        let mut rng = SplitMix64::new(rng_seed);
        let _ = &mut rng;
        let mut acts = inputs.to_vec();
        for layer in &self.layers {
            acts = (0..layer.weights.n_neurons())
                .map(|n| layer.reference_activation(n, &acts))
                .collect();
        }
        let pops: Vec<usize> = (0..self.output_weights.n_neurons())
            .map(|k| {
                self.output_weights.weights[k]
                    .iter()
                    .zip(&acts)
                    .filter(|(&w, &x)| !(w ^ x))
                    .count()
            })
            .collect();
        let mut best = 0;
        for (k, &p) in pops.iter().enumerate() {
            if p > pops[best] {
                best = k;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FlowConfig {
        FlowConfig::table1_default()
    }

    #[test]
    fn neuron_activation_matches_sign_function() {
        let device = Device::xc7z020();
        let mut rng = SplitMix64::new(3);
        let weights = BnnLayerWeights::random(8, 32, &mut rng);
        let layer = BnnLayer::build(&device, weights, &cfg()).unwrap();
        let mut mism = 0;
        let mut checked = 0;
        for s in 0..40u64 {
            let mut srng = SplitMix64::new(s);
            let inputs: Vec<bool> = (0..32).map(|_| srng.next_bool(0.5)).collect();
            let out = layer.forward(&inputs, &mut rng);
            for n in 0..8 {
                let pop = layer.synapses(n, &inputs).iter().filter(|&&b| b).count();
                if 2 * pop == 32 + layer.neutral_margin() {
                    continue; // exact threshold: hardware coin-flip
                }
                checked += 1;
                if out.activations[n] != layer.reference_activation(n, &inputs) {
                    mism += 1;
                }
            }
        }
        assert_eq!(mism, 0, "sign activation must match on non-threshold neurons");
        assert!(checked > 200);
    }

    #[test]
    fn stronger_match_resolves_faster() {
        let device = Device::xc7z020();
        let mut rng = SplitMix64::new(5);
        let weights = BnnLayerWeights::random(1, 64, &mut rng);
        let layer = BnnLayer::build(&device, weights.clone(), &cfg()).unwrap();
        // Input equal to the weights: all 64 synapses match → fastest.
        let perfect: Vec<bool> = weights.weights[0].clone();
        let t_perfect = layer.neuron_pdls[0].propagate(&layer.synapses(0, &perfect));
        // Input inverted: zero matches → slowest.
        let inverted: Vec<bool> = perfect.iter().map(|&b| !b).collect();
        let t_inverted = layer.neuron_pdls[0].propagate(&layer.synapses(0, &inverted));
        assert!(t_perfect < t_inverted);
        let t_neutral = layer.neutral_pdl.propagate(&layer.neutral_bits);
        assert!(t_perfect < t_neutral && t_neutral < t_inverted);
    }

    /// A sample is "decisive" when no hidden neuron sits at the sign
    /// threshold and the output argmax is unique — the cases where the
    /// time-domain result is well-defined (threshold neurons are coin
    /// flips at the arbiter, the BNN analogue of the TM's classification
    /// metastability).
    fn is_decisive(net: &TimeDomainBnn, inputs: &[bool]) -> bool {
        let mut acts = inputs.to_vec();
        for layer in &net.layers {
            let n_in = layer.weights.n_inputs();
            for n in 0..layer.weights.n_neurons() {
                let pop = layer.synapses(n, &acts).iter().filter(|&&b| b).count();
                let margin = 2 * pop as i64 - n_in as i64 - layer.neutral_margin() as i64;
                if margin.abs() < 2 {
                    return false;
                }
            }
            acts = (0..layer.weights.n_neurons())
                .map(|n| layer.reference_activation(n, &acts))
                .collect();
        }
        let pops: Vec<usize> = (0..net.output_weights.n_neurons())
            .map(|k| {
                net.output_weights.weights[k]
                    .iter()
                    .zip(&acts)
                    .filter(|(&w, &x)| !(w ^ x))
                    .count()
            })
            .collect();
        let top = *pops.iter().max().unwrap();
        pops.iter().filter(|&&p| p == top).count() == 1
    }

    #[test]
    fn network_forward_matches_reference_on_decisive_samples() {
        let device = Device::xc7z020();
        let mut net = TimeDomainBnn::build(&device, &[24, 12, 4], &cfg(), 11).unwrap();
        let mut agree = 0;
        let mut total = 0;
        for s in 0..600u64 {
            let mut srng = SplitMix64::new(s * 7 + 1);
            let inputs: Vec<bool> = (0..24).map(|_| srng.next_bool(0.5)).collect();
            if !is_decisive(&net, &inputs) {
                continue;
            }
            let (hw, _t) = net.forward(&inputs);
            let sw = net.reference_forward(&inputs, s);
            total += 1;
            agree += (hw == sw) as usize;
        }
        assert!(total >= 15, "need decisive samples, got {total}");
        assert_eq!(agree, total, "decisive samples must agree exactly");
    }

    #[test]
    fn layer_latency_bounded_by_slowest_pdl() {
        let device = Device::xc7z020();
        let mut rng = SplitMix64::new(9);
        let weights = BnnLayerWeights::random(4, 16, &mut rng);
        let layer = BnnLayer::build(&device, weights, &cfg()).unwrap();
        let inputs: Vec<bool> = (0..16).map(|i| i % 3 == 0).collect();
        let out = layer.forward(&inputs, &mut rng);
        let worst = layer.neuron_pdls.iter().map(Pdl::max_traversal).max().unwrap();
        assert!(out.latency <= worst + Ps(2_000));
    }
}
