//! The proposed architecture: asynchronous TM with time-domain popcount
//! (paper §IV-A, Fig. 7).
//!
//! A single MOUSETRAP stage fronts the datapath: transparent latches admit
//! a new sample, the clause blocks evaluate under a bundled-data delay, the
//! bundling signal launches the per-class PDLs, and the arbiter tree
//! resolves the time-domain argmax. The asynchronous controller (STG of
//! Fig. 8) waits for all PDL outputs (join) before re-opening the latches,
//! so an unarrived slow transition can never corrupt the next inference.
//!
//! Latency semantics (reported by [`AsyncTmEngine::infer`]):
//! * `decision_latency` — request edge → `Completion` (classification
//!   available): bundled clause delay + *winning* PDL traversal + arbiter
//!   tree. This is the per-inference latency of Fig. 9a: the winner (the
//!   largest class sum) is by construction the *fastest* PDL, which is why
//!   the async design's latency tracks the average case rather than the
//!   worst case.
//! * `cycle_latency` — request edge → controller ready for the next sample:
//!   bounded by the *slowest* PDL (smallest class sum; the join in the
//!   STG). This is the batch-mode throughput bound ("the overall latency is
//!   determined by the TM producing the smallest class sum").

pub mod bnn;
pub mod mousetrap;
pub mod stg;

pub use mousetrap::MousetrapStage;
pub use stg::{Stg, StgEvent, StgSignal};

use crate::arbiter::{ArbiterConfig, ArbiterResources, ArbiterTree};
use crate::baselines::{
    calib, clause_block, Architecture, DesignParams, LatencyBreakdown, ResourceBreakdown,
    ToggleInventory,
};
use crate::fabric::Device;
use crate::flow::{self, FlowConfig};
use crate::pdl::{Pdl, PdlResources, Polarity};
use crate::util::{Ps, SplitMix64};

/// Result of one asynchronous inference.
#[derive(Debug, Clone, PartialEq)]
pub struct InferOutcome {
    /// Winning class from the arbiter tree (the hardware's argmax).
    pub winner: usize,
    /// Request edge → Completion (classification available).
    pub decision_latency: Ps,
    /// Request edge → all PDL outputs arrived (next-cycle gate).
    pub cycle_latency: Ps,
    /// Per-PDL traversal delays (diagnostics / Fig. 10 data).
    pub pdl_delays: Vec<Ps>,
    /// Metastable arbiter nodes in this decision.
    pub metastable_nodes: u32,
}

/// The assembled asynchronous TM: placed + routed PDLs, arbiter tree,
/// MOUSETRAP stage timing.
pub struct AsyncTmEngine {
    pub pdls: Vec<Pdl>,
    pub tree: ArbiterTree,
    pub stage: MousetrapStage,
    /// Bundled clause-block delay (launches the PDL start FFs).
    pub clause_bundle: Ps,
    params: DesignParams,
    rng: SplitMix64,
    /// Previous replayed fired vector — state for the hardware-seam
    /// toggle model (`crate::hw`), which defines per-request activity as
    /// the clause-output hamming change between consecutive samples.
    pub(crate) replay_fired: Option<Vec<bool>>,
}

impl AsyncTmEngine {
    /// Build from a workload: runs the full implementation flow (placement
    /// → pins → routing) for `n_classes` PDLs of `clauses_per_class`
    /// elements on the device, then assembles the arbiter tree and stage.
    /// Every PDL gets the standard alternating TM polarity wiring
    /// (element 0 positive); use [`AsyncTmEngine::build_with_polarities`]
    /// to wire a trained model's actual clause polarities.
    pub fn build(
        device: &Device,
        params: &DesignParams,
        flow_cfg: &FlowConfig,
        seed: u64,
    ) -> Result<AsyncTmEngine, flow::FlowError> {
        let pols = Pdl::tm_polarities(params.clauses_per_class);
        let per_class = vec![pols; params.n_classes];
        Self::build_with_polarities(device, params, flow_cfg, seed, &per_class)
    }

    /// [`AsyncTmEngine::build`] with explicit per-class element polarities
    /// (`polarities[k][j]` wires class k's element j). Trained models
    /// order clause polarity over the *global* class-major clause index,
    /// which de-phases from the per-PDL alternating pattern whenever
    /// `clauses_per_class` is odd — the hardware backend wires the model's
    /// true signs through here so the PDL race counts the same votes the
    /// functional argmax does.
    pub fn build_with_polarities(
        device: &Device,
        params: &DesignParams,
        flow_cfg: &FlowConfig,
        seed: u64,
        polarities: &[Vec<Polarity>],
    ) -> Result<AsyncTmEngine, flow::FlowError> {
        assert_eq!(polarities.len(), params.n_classes, "one polarity vector per class");
        let routed = flow::run(device, params.n_classes, params.clauses_per_class, flow_cfg)?;
        let pdls: Vec<Pdl> = routed
            .iter()
            .zip(polarities)
            .map(|(r, pols)| Pdl::from_routed(r, pols))
            .collect();
        let m = calib::congestion(Self::static_resources(params).luts());
        let clause_bundle =
            clause_block::clause_delay(params, m).scale(calib::BUNDLE_MARGIN);
        Ok(AsyncTmEngine {
            pdls,
            tree: ArbiterTree::new(params.n_classes, ArbiterConfig::default()),
            stage: MousetrapStage::default(),
            clause_bundle,
            params: *params,
            rng: SplitMix64::new(seed ^ 0xA5_1C_7000),
            replay_fired: None,
        })
    }

    pub fn params(&self) -> &DesignParams {
        &self.params
    }

    /// One inference: `clause_bits[k]` are class k's clause outputs.
    pub fn infer(&mut self, clause_bits: &[Vec<bool>]) -> InferOutcome {
        assert_eq!(clause_bits.len(), self.pdls.len(), "one bit vector per class");
        // Request edge → latch transparent → clause logic settles under the
        // bundling delay → start FFs launch all PDLs simultaneously.
        let launch = self.stage.latch_delay + self.clause_bundle;
        let pdl_delays: Vec<Ps> = self
            .pdls
            .iter()
            .zip(clause_bits)
            .map(|(pdl, bits)| pdl.propagate(bits))
            .collect();
        let arrivals: Vec<Ps> = pdl_delays.iter().map(|&d| launch + d).collect();
        let decision = self.tree.decide(&arrivals, &mut self.rng);
        // The join (wait fragment, Fig. 8) releases once every PDL output
        // has arrived; then the controller toggles ack/done.
        let slowest = arrivals.iter().copied().max().unwrap_or(Ps::ZERO);
        let cycle = slowest.max(decision.completion) + calib::ASYNC_CTL;
        InferOutcome {
            winner: decision.winner,
            decision_latency: decision.completion,
            cycle_latency: cycle,
            pdl_delays,
            metastable_nodes: decision.metastable_nodes,
        }
    }

    /// Worst-case decision latency: every element takes the high arc.
    pub fn worst_case_latency(&self) -> Ps {
        let launch = self.stage.latch_delay + self.clause_bundle;
        let slowest = self
            .pdls
            .iter()
            .map(Pdl::max_traversal)
            .max()
            .unwrap_or(Ps::ZERO);
        let mut rng = SplitMix64::new(0);
        let arrivals = vec![launch + slowest; self.pdls.len()];
        self.tree
            .decide(&arrivals, &mut rng)
            .completion
            .max(launch + slowest)
    }

    /// Static resource inventory (shared with the [`TdAsync`] architecture
    /// handle so sweeps don't need a built engine).
    pub fn static_resources(d: &DesignParams) -> ResourceBreakdown {
        let pdl = PdlResources::for_pdls(d.n_classes, d.clauses_per_class);
        let arb = ArbiterResources::for_tree(d.n_classes);
        ResourceBreakdown {
            clause_luts: clause_block::clause_luts(d),
            popcount_luts: pdl.luts,
            compare_luts: arb.luts,
            // MOUSETRAP latch control (XNOR per stage), wait/join fragments,
            // request/done toggling — small but not free.
            control_luts: 60,
            // Input latches + PDL start-sync FFs + handshake state.
            ffs: (d.n_features) as u32 + pdl.ffs + 8,
        }
    }
}

/// [`Architecture`] handle for the proposed design: closed-form model used
/// by the sweep experiments (the engine gives exact per-sample numbers).
#[derive(Debug, Clone, Copy)]
pub struct TdAsync {
    /// Per-stage low/high traversal delays (flow-calibrated).
    pub lo_stage: Ps,
    pub hi_stage: Ps,
    /// Expected winner class-sum margin as a fraction of clauses/class
    /// (drives the average-case winner PDL delay).
    pub winner_margin: f64,
}

impl Default for TdAsync {
    fn default() -> Self {
        // Table I defaults: net 380/618 + LUT logic 124.
        Self { lo_stage: Ps(504), hi_stage: Ps(742), winner_margin: 0.18 }
    }
}

impl TdAsync {
    /// Average-case winner PDL traversal: shorts = C/2 · (1 + margin).
    pub fn winner_pdl_delay(&self, d: &DesignParams) -> Ps {
        let c = d.clauses_per_class as f64;
        let shorts = (c / 2.0 * (1.0 + self.winner_margin)).min(c);
        let longs = c - shorts;
        Ps((shorts * self.lo_stage.as_ps_f64() + longs * self.hi_stage.as_ps_f64()) as u64)
    }

    fn arbiter_delay(&self, d: &DesignParams) -> Ps {
        let cfg = ArbiterConfig::default();
        let levels = (d.n_classes.max(2) as f64).log2().ceil() as u64;
        cfg.latch_delay * levels + cfg.completion_gate_delay
    }
}

impl Architecture for TdAsync {
    fn name(&self) -> &'static str {
        "td-async"
    }

    fn latency(&self, d: &DesignParams) -> LatencyBreakdown {
        let m = calib::congestion(AsyncTmEngine::static_resources(d).luts());
        LatencyBreakdown {
            clause: clause_block::clause_delay(d, m).scale(calib::BUNDLE_MARGIN),
            popcount: self.winner_pdl_delay(d),
            compare: self.arbiter_delay(d),
            control: crate::fabric::FF_CLK_TO_Q + Ps(80), // latch + launch
        }
    }

    fn resources(&self, d: &DesignParams) -> ResourceBreakdown {
        AsyncTmEngine::static_resources(d)
    }

    fn toggles(&self, d: &DesignParams, activity: f64) -> ToggleInventory {
        ToggleInventory {
            clause_toggles_per_inference: clause_block::clause_toggles(d, activity),
            // The defining power property (Fig. 12): every delay element
            // propagates exactly one transition per inference, data- and
            // activity-independent.
            popcount_toggles_per_inference: d.c_total() as f64,
            compare_toggles_per_inference: (2 * d.n_classes) as f64,
            clocked_ffs: 0,
            control_toggles_per_inference: 12.0 + d.n_classes as f64,
        }
    }

    fn is_synchronous(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::datasets::synthetic_clause_bits;
    use crate::tm::WorkloadSpec;

    fn engine(k: usize, c: usize) -> AsyncTmEngine {
        let d = Device::xc7z020();
        let params = DesignParams::synthetic(k, c, 96);
        AsyncTmEngine::build(&d, &params, &FlowConfig::table1_default(), 7).unwrap()
    }

    #[test]
    fn winner_matches_argmax_with_margin() {
        let mut eng = engine(4, 60);
        // Class 2 fires far more supporting clauses: its PDL must win.
        let mut bits = vec![vec![false; 60]; 4];
        for j in (0..40).step_by(2) {
            bits[2][j] = true; // 20 positive votes
        }
        for j in (0..8).step_by(2) {
            bits[0][j] = true; // 4 positive votes
        }
        let out = eng.infer(&bits);
        assert_eq!(out.winner, 2);
        assert!(out.decision_latency < out.cycle_latency);
    }

    #[test]
    fn decision_latency_below_worst_case() {
        let mut eng = engine(3, 50);
        let spec = WorkloadSpec {
            n_classes: 3,
            clauses_per_class: 50,
            n_features: 96,
            fire_rate: 0.5,
        };
        let mut rng = SplitMix64::new(11);
        let wc = eng.worst_case_latency();
        for i in 0..50 {
            let bits = synthetic_clause_bits(&spec, i % 3, &mut rng);
            let out = eng.infer(&bits);
            assert!(out.decision_latency <= wc, "avg case bounded by worst case");
        }
    }

    #[test]
    fn cycle_latency_tracks_slowest_pdl() {
        let mut eng = engine(3, 40);
        let bits = vec![vec![true; 40], vec![false; 40], vec![true; 40]];
        let out = eng.infer(&bits);
        // Class 1 fires nothing on positives and nothing on negatives ⇒
        // negatives not firing take the SHORT arc... so compute directly:
        let launch = eng.stage.latch_delay + eng.clause_bundle;
        let slowest = out.pdl_delays.iter().copied().max().unwrap();
        assert!(out.cycle_latency >= launch + slowest);
    }

    #[test]
    fn td_arch_latency_near_constant_in_classes() {
        // Fig. 10b: classes 2 → 32 adds only arbiter levels.
        let td = TdAsync::default();
        let t2 = td.latency(&DesignParams::synthetic(2, 100, 200)).total();
        let t32 = td.latency(&DesignParams::synthetic(32, 100, 200)).total();
        let growth = t32.as_ps_f64() / t2.as_ps_f64();
        assert!(growth < 1.25, "near-constant in classes, got ×{growth:.2}");
    }

    #[test]
    fn td_arch_latency_linear_in_clauses() {
        // Fig. 10a: PDL length grows with clauses.
        let td = TdAsync::default();
        let t100 = td.winner_pdl_delay(&DesignParams::synthetic(6, 100, 200));
        let t200 = td.winner_pdl_delay(&DesignParams::synthetic(6, 200, 200));
        let r = t200.as_ps_f64() / t100.as_ps_f64();
        assert!((1.95..2.05).contains(&r), "linear, got ×{r:.2}");
    }

    #[test]
    fn toggles_independent_of_activity() {
        let td = TdAsync::default();
        let d = DesignParams::synthetic(10, 50, 784);
        let a = td.toggles(&d, 0.1);
        let b = td.toggles(&d, 0.5);
        assert_eq!(a.popcount_toggles_per_inference, b.popcount_toggles_per_inference);
        assert_eq!(a.clocked_ffs, 0);
    }

    #[test]
    fn engine_resources_match_arch_handle() {
        let d = DesignParams::synthetic(10, 50, 784);
        assert_eq!(
            AsyncTmEngine::static_resources(&d).total(),
            TdAsync::default().resources(&d).total()
        );
    }
}
