//! MOUSETRAP pipeline stage (Singh & Nowick [8]; paper Fig. 7).
//!
//! A MOUSETRAP stage is a bank of transparent latches whose enable is
//! `XNOR(req_out, ack_in)`: the latch is transparent while waiting for new
//! data and snaps opaque the moment the stage accepts a token, giving a
//! 2-phase (transition-signalling) handshake with only one gate of control
//! overhead. The paper pairs one stage with the TM datapath and generates
//! the bundling signal from a matched net delay.
//!
//! [`MousetrapStage`] is the behavioral timing model used by the engine;
//! [`build_event_circuit`] instantiates the same stage as real gates on the
//! event-driven simulator, and the equivalence test in
//! `rust/tests/timing_equivalence.rs` holds the two together.

use crate::timing::{Circuit, GateKind, NetId};
use crate::util::Ps;

/// Behavioral timing of one MOUSETRAP stage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MousetrapStage {
    /// Transparent-latch D→Q delay.
    pub latch_delay: Ps,
    /// XNOR enable-control delay (hidden from the forward path in steady
    /// state — the latch is already transparent when data arrives).
    pub xnor_delay: Ps,
}

impl Default for MousetrapStage {
    fn default() -> Self {
        Self { latch_delay: Ps(124), xnor_delay: Ps(124) }
    }
}

impl MousetrapStage {
    /// Forward latency seen by a token entering an idle (transparent)
    /// stage.
    pub fn forward_latency(&self) -> Ps {
        self.latch_delay
    }

    /// Minimum cycle time of a MOUSETRAP ring with this stage and a
    /// datapath of delay `datapath`: req toggles → data out → ack back →
    /// enable reopens.
    pub fn cycle_time(&self, datapath: Ps) -> Ps {
        self.latch_delay + datapath + self.xnor_delay
    }
}

/// Nets exposed by an event-driven MOUSETRAP stage instance.
#[derive(Debug, Clone, Copy)]
pub struct MousetrapNets {
    pub req_in: NetId,
    pub ack_in: NetId,
    /// Latched request (= req_out toward the next stage).
    pub req_out: NetId,
    /// Latch enable (XNOR of req_out and ack_in).
    pub enable: NetId,
    /// Latched data bit (single representative datapath bit).
    pub data_in: NetId,
    pub data_out: NetId,
}

/// Instantiate one MOUSETRAP stage (control + a representative data latch)
/// on the gate-level simulator.
pub fn build_event_circuit(c: &mut Circuit, stage: &MousetrapStage) -> MousetrapNets {
    let req_in = c.net();
    let ack_in = c.net();
    let data_in = c.net();
    // Enable net with feedback: en = XNOR(req_out, ack_in). Allocate
    // req_out/en first, then wire gates onto them.
    let req_out = c.net();
    let enable = c.net_init(true); // idle stage is transparent
    c.gate_onto(GateKind::LatchT, &[enable, req_in], req_out, stage.latch_delay);
    c.gate_onto(GateKind::Xnor2, &[req_out, ack_in], enable, stage.xnor_delay);
    let data_out = c.gate(GateKind::LatchT, &[enable, data_in], stage.latch_delay);
    MousetrapNets { req_in, ack_in, req_out, enable, data_in, data_out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::Simulator;

    #[test]
    fn behavioral_cycle_time() {
        let s = MousetrapStage::default();
        assert_eq!(s.forward_latency(), Ps(124));
        assert_eq!(s.cycle_time(Ps(1000)), Ps(1248));
    }

    #[test]
    fn event_stage_latches_and_closes() {
        let stage = MousetrapStage::default();
        let mut c = Circuit::new();
        let nets = build_event_circuit(&mut c, &stage);
        let mut sim = Simulator::new(&c);
        sim.watch(nets.req_out);
        sim.watch(nets.enable);
        sim.watch(nets.data_out);

        // Token arrives: data then req (bundled).
        sim.schedule(nets.data_in, true, Ps(100));
        sim.schedule(nets.req_in, true, Ps(300));
        sim.run_until(Ps(100_000));

        // Transparent stage passes both after one latch delay.
        assert_eq!(sim.first_edge(nets.data_out, true), Some(Ps(224)));
        assert_eq!(sim.first_edge(nets.req_out, true), Some(Ps(424)));
        // req_out toggled with ack still low ⇒ enable must have closed.
        assert_eq!(sim.first_edge(nets.enable, false), Some(Ps(548)));
    }

    #[test]
    fn ack_reopens_latch() {
        let stage = MousetrapStage::default();
        let mut c = Circuit::new();
        let nets = build_event_circuit(&mut c, &stage);
        let mut sim = Simulator::new(&c);
        sim.watch(nets.enable);
        sim.schedule(nets.req_in, true, Ps(0));
        sim.run_until(Ps(10_000));
        assert!(!sim.level(nets.enable), "closed after accepting the token");
        // 2-phase: the matching ack transition reopens.
        sim.schedule(nets.ack_in, true, Ps(20_000));
        sim.run_until(Ps(40_000));
        assert!(sim.level(nets.enable), "ack must reopen the latch");
    }

    #[test]
    fn two_phase_second_token() {
        // Full 2-phase cycle: falling req transition is the next token.
        let stage = MousetrapStage::default();
        let mut c = Circuit::new();
        let nets = build_event_circuit(&mut c, &stage);
        let mut sim = Simulator::new(&c);
        sim.watch(nets.req_out);
        sim.schedule(nets.req_in, true, Ps(0));
        sim.run_until(Ps(5_000));
        sim.schedule(nets.ack_in, true, Ps(6_000)); // consume token 1
        sim.run_until(Ps(8_000));
        sim.schedule(nets.req_in, false, Ps(9_000)); // token 2 (falling)
        sim.run_until(Ps(20_000));
        let tr = sim.trace(nets.req_out);
        assert_eq!(tr.len(), 2, "both tokens must pass: {tr:?}");
        assert!(!tr[1].1, "second token is the falling transition");
    }
}
