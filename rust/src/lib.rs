//! `tdpc` — Time-Domain Popcount for Low-Complexity Machine Learning.
//!
//! Reproduction of Duan et al., *"Efficient FPGA Implementation of
//! Time-Domain Popcount for Low-Complexity Machine Learning"* (2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 1/2 (build-time Python, `python/`)** — Tsetlin Machine
//!   training and the fused clause-evaluation + signed-popcount Pallas
//!   kernel, AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 3 (this crate)** — the paper's hardware contribution and
//!   every substrate it depends on: an XC7Z020-class fabric model
//!   ([`fabric`]), the paper's implementation flow ([`flow`]), PDLs
//!   ([`pdl`]), arbiter trees ([`arbiter`]), an event-driven timing
//!   simulator ([`timing`]), the asynchronous MOUSETRAP TM engine
//!   ([`asynctm`]), all adder-based baselines ([`baselines`]), power and
//!   resource models ([`power`]), the unified executable hardware-engine
//!   seam ([`hw`]), the pluggable inference runtime ([`runtime`]), a
//!   multi-worker batch-serving coordinator ([`coordinator`]), and a
//!   dependency-free TCP serving front end + load harness ([`server`]).
//!
//! # Execution backends
//!
//! The request path runs through the [`runtime::InferenceBackend`] seam:
//!
//! | feature set | backend | needs | use |
//! |---|---|---|---|
//! | `default` | [`runtime::NativeBackend`] | nothing (hermetic) | CI, tests, serving |
//! | `default` | [`runtime::HwBackend`] (`hw:<async\|adder\|fpt18>`) | nothing (hermetic) | serving with simulated on-chip timing |
//! | `--features pjrt` | `runtime::PjrtBackend` | XLA/PJRT bindings + `make artifacts` | HLO cross-checks |
//!
//! The default build is pure Rust and is what CI builds, tests, lints and
//! benches on every change (`.github/workflows/ci.yml`). The
//! [`coordinator`] runs a pool of `n_workers ≥ 1` worker threads serving
//! **one or many models** at once, each worker owning one backend per
//! model (PJRT clients are not `Send`), with round-robin or least-loaded
//! dispatch, **model-keyed** per-worker dynamic batching (requests
//! intern to a [`coordinator::ModelId`]; one pending queue per model, so
//! a batch never mixes widths or backends), per-model width-gated
//! admission over bounded queues with typed fail-soft errors
//! ([`coordinator::InferError`]: unknown model / reject / shed /
//! per-row-retried backend failure, never a silently dropped reply
//! channel), live hot-swap ([`coordinator::Coordinator::reload`]:
//! generation-stamped, zero lost requests, built on
//! `ModelRegistry::invalidate` → `util::sync::OnceMap::remove`), and
//! metrics that aggregate across the pool — per tenant via
//! [`coordinator::Coordinator::metrics_for`], per worker via
//! `worker_metrics`. The pool also runs **clause-sharded
//! scatter/reduce** ([`coordinator::Coordinator::start_sharded`]): one
//! model's clause arena is carved into contiguous shards
//! ([`tm::ClauseShard`]), one worker per shard serves partial class
//! sums through [`runtime::ShardBackend`], and a reduce collector
//! merges them ([`tm::merge_partials`]) bit-exactly with the unsharded
//! forward pass — per-batch latency scales with `c_total / n_shards`,
//! near-constant-time in clause count.
//!
//! On top of the coordinator sits the **network serving layer**
//! ([`server`]): a length-prefixed binary protocol over TCP (magic +
//! version + model name + packed feature words — rows never unpack on
//! the wire path), a multi-threaded accept/connection loop that decodes
//! frames into [`coordinator::Coordinator::submit_packed_named`] and
//! streams replies back in submission order, typed
//! [`coordinator::InferError`]s mapped to protocol error codes
//! ([`server::protocol::error_code`]), accept-time overload refusal tied
//! to the pool's admission state
//! ([`coordinator::Coordinator::is_saturated`]), and an open/closed-loop
//! load generator ([`server::loadgen`]) that writes `BENCH_serving.json`
//! — CI's per-run perf datapoint.
//!
//! # The artifact store
//!
//! Models reach the pool through the **content-addressed artifact
//! store** ([`tm::artifact`]): a model is published as clause-block
//! shards under `objects/<sha256>` plus a generation-versioned
//! `manifest.json` recording every shard hash and its provenance
//! (schema `tdpc-artifact/v2`; [`tm::artifact::pack`] /
//! [`tm::artifact::pack_from_v1`] write it atomically, and the legacy
//! v1 bare-directory layout still opens read-only through the same
//! [`tm::artifact::Store`]). Every object read re-hashes the bytes and
//! fails with a typed [`tm::artifact::ArtifactError`] — hash mismatch,
//! missing object, malformed manifest — which
//! [`coordinator::Coordinator::reload`] turns into fail-soft behaviour:
//! a worker that cannot open the new generation keeps serving the old
//! one. Because shards are keyed by content, reload is **delta-aware**:
//! workers share a hash-keyed [`tm::artifact::PayloadCache`], so a
//! 1-of-N-shard change re-reads exactly one object (`shards_reused` is
//! counted per swap and surfaced as `reload_shards_reused` in
//! [`coordinator::MetricsSnapshot`]), and sharded workers open only the
//! objects overlapping their own clause range. Superseded objects are
//! swept by [`tm::artifact::gc`] (CLI `tdpc gc`, or
//! [`coordinator::Coordinator::gc_artifacts`] under the reload lock),
//! which never deletes anything referenced by a live manifest or pinned
//! by an in-flight open (§Artifact store, rust/README.md).
//!
//! # The hardware-engine seam
//!
//! Every architecture of the paper's comparison is *executable* behind
//! the [`hw::HwEngine`] trait: the asynchronous time-domain design (built
//! through the real implementation flow), the generic adder tree, and the
//! FPT'18 ripple chain each replay a sample's clause bits + class sums
//! into a winner, per-request decision/cycle latency, and a switching
//! inventory. [`runtime::HwBackend`] attaches one engine per worker on
//! the serving path; the coordinator's `ReplayPolicy` (`Off` /
//! `Sample(1-in-N)` / `Full`) decides which requests pay for timing
//! replay and feeds hardware decision-latency p50/p99 into the pool
//! metrics. The experiments ([`experiments::table1`], `fig9`, `fig10`)
//! iterate the same [`hw::engine_list`], so paper figures and serving
//! benches share one code path.
//!
//! # The packed data plane
//!
//! The request path's native currency is the bit plane of [`tm::bits`]:
//! `u64` words, LSB-first (bit `i` → word `i / 64`, position `i % 64`),
//! tail bits zero, batches row-major ([`tm::PackedBatch`]). The
//! [`coordinator`] packs each request's Boolean features **once at
//! ingestion**; dispatch, batching, and [`runtime::InferenceBackend::forward`]
//! all consume packed rows, and [`runtime::ForwardOutput`] returns the
//! fired-clause bits packed the same way (32× smaller than i32 lanes at
//! MNIST clause counts). Inside [`tm::TmModel::forward_packed`], literal
//! vectors `[x, ~x]` are assembled word-wise, clauses evaluate as
//! `include & !literals == 0` per word, and class sums are
//! `popcount(fired & pos) − popcount(fired & neg)` over precomputed
//! class-major polarity masks — the software mirror of the paper's
//! time-domain popcount voter, where votes are never materialized as
//! integers either. Clause evaluation itself runs the **clause-indexed
//! hot loop**: include masks live in one flat arena scanned through
//! chunked 4×`u64`-lane subset tests, and an index built at model
//! construction buckets each clause under a rarely-set included literal
//! so whole buckets are skipped when a sample leaves that literal 0 —
//! bit-exact with the full scan, with per-worker scratch and skip
//! telemetry in [`tm::ForwardScratch`] and an exact early-exit argmax
//! behind [`tm::TmModel::predict_packed`] (§Data plane, "The hot loop",
//! rust/README.md). Batches of [`tm::SLICED_MIN_ROWS`] (64) rows or more
//! dispatch to the **bit-sliced engine** ([`tm::slice`]): the batch is
//! flipped plane-major by a word-level 64×64 bit-matrix transpose
//! ([`tm::TransposedBatch`]), clauses evaluate 64 rows per word as ANDs
//! of literal planes (reusing the same arena and bucket skips,
//! group-wide), and class sums accumulate in carry-save vertical
//! counters ([`tm::CsaAccumulator`], 3:2 compressors over fired planes)
//! — bit-exact with the row-major path, observable only through
//! `sliced_groups`/`sliced_rows` telemetry (§Data plane, "The sliced
//! loop", rust/README.md). Only the PJRT backend unpacks, at the HLO
//! boundary, because the AOT artifact was lowered against f32 lanes.
//!
//! See rust/README.md for the feature matrix and local verify commands,
//! DESIGN.md for the system inventory and the experiment index, and
//! EXPERIMENTS.md for the paper-vs-measured record.

pub mod arbiter;
pub mod asynctm;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod fabric;
pub mod flow;
pub mod hw;
pub mod pdl;
pub mod power;
pub mod runtime;
pub mod server;
pub mod timing;
pub mod tm;
pub mod util;
