//! L3 serving coordinator: request routing, dynamic batching, worker pool,
//! metrics.
//!
//! The coordinator is the deployment shell around the paper's hardware:
//! clients submit Booleanized samples; a per-model dynamic batcher groups
//! them (size- and deadline-bounded, vLLM-router style); worker threads
//! execute the AOT-compiled HLO on the PJRT runtime; and, when a hardware
//! engine is attached, each sample's clause bits are replayed through the
//! asynchronous time-domain TM to report the on-chip decision latency next
//! to the functional result. Everything is std-threads + channels (tokio is
//! not in the offline crate set — DESIGN.md §7).

pub mod batcher;
pub mod metrics;

pub use batcher::{BatchPlan, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::asynctm::AsyncTmEngine;
use crate::runtime::{bools_to_f32, ModelRegistry};
use crate::util::Ps;

/// One inference request.
#[derive(Debug)]
pub struct InferRequest {
    pub features: Vec<bool>,
    /// Where to deliver the response.
    pub reply: mpsc::Sender<InferResponse>,
    submitted: Instant,
}

/// One inference response.
#[derive(Debug, Clone, PartialEq)]
pub struct InferResponse {
    pub request_id: u64,
    /// Functional argmax class from the PJRT-executed model.
    pub pred: usize,
    /// Signed class sums.
    pub sums: Vec<i32>,
    /// Simulated on-chip decision latency of the async time-domain TM
    /// (None when no hardware engine is attached).
    pub hw_decision_latency: Option<Ps>,
    /// Hardware argmax (may disagree with `pred` only on exact ties).
    pub hw_winner: Option<usize>,
    /// End-to-end service latency through the coordinator (µs).
    pub service_latency_us: f64,
    /// Batch this request was served in.
    pub batch_size: usize,
}

/// Handle to a running coordinator for one model.
pub struct Coordinator {
    tx: mpsc::Sender<WorkItem>,
    next_id: AtomicU64,
    metrics: Arc<Mutex<Metrics>>,
    shutdown: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub model: String,
}

struct WorkItem {
    id: u64,
    req: InferRequest,
}

impl Coordinator {
    /// Start a coordinator for `model` over the artifacts at `root`.
    ///
    /// The PJRT client and its compiled executables are not `Send` (the
    /// `xla` crate wraps raw PJRT pointers), so the worker thread *owns*
    /// its [`ModelRegistry`]: the registry is constructed and both batch
    /// sizes pre-compiled inside the worker, and startup errors are
    /// reported back through a ready-channel before `start` returns.
    /// If `engine` is provided, every sample is additionally replayed
    /// through the simulated async TM.
    pub fn start(
        root: PathBuf,
        model: &str,
        cfg: BatcherConfig,
        engine: Option<AsyncTmEngine>,
    ) -> Result<Coordinator> {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let worker = {
            let model = model.to_string();
            let metrics = metrics.clone();
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name(format!("tdpc-batcher-{model}"))
                .spawn(move || {
                    // Build + pre-compile inside the owning thread.
                    let registry = match ModelRegistry::open(&root) {
                        Ok(r) => r,
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    for &b in &registry.manifest().batch_sizes.clone() {
                        if let Err(e) =
                            registry.runner(&model, b).context("pre-compiling model")
                        {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    }
                    let _ = ready_tx.send(Ok(()));
                    worker_loop(registry, model, cfg, engine, rx, metrics, shutdown)
                })?
        };
        ready_rx
            .recv()
            .context("coordinator worker died during startup")??;
        Ok(Coordinator {
            tx,
            next_id: AtomicU64::new(0),
            metrics,
            shutdown,
            worker: Some(worker),
            model: model.to_string(),
        })
    }

    /// Submit asynchronously; the response arrives on `reply`.
    pub fn submit(&self, features: Vec<bool>, reply: mpsc::Sender<InferResponse>) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(WorkItem { id, req: InferRequest { features, reply, submitted: Instant::now() } })
            .map_err(|_| anyhow::anyhow!("coordinator worker has shut down"))?;
        Ok(id)
    }

    /// Convenience blocking call.
    pub fn infer_blocking(&self, features: Vec<bool>) -> Result<InferResponse> {
        let (tx, rx) = mpsc::channel();
        self.submit(features, tx)?;
        rx.recv().context("coordinator dropped the reply channel")
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.lock().unwrap().snapshot()
    }

    /// Stop the worker after draining queued requests.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        drop(self.tx.clone()); // worker exits when all senders drop + flag set
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    registry: ModelRegistry,
    model: String,
    cfg: BatcherConfig,
    mut engine: Option<AsyncTmEngine>,
    rx: mpsc::Receiver<WorkItem>,
    metrics: Arc<Mutex<Metrics>>,
    shutdown: Arc<AtomicBool>,
) {
    let mut pending: Vec<WorkItem> = Vec::new();
    loop {
        // Collect until the batch plan says flush. The channel is drained
        // greedily before each planning decision: the deadline is measured
        // from *submission*, so leaving ready work in the channel would
        // make every item individually overdue and collapse batching.
        let plan = loop {
            while let Ok(item) = rx.try_recv() {
                pending.push(item);
                if pending.len() >= cfg.max_batch {
                    break;
                }
            }
            if let Some(plan) = cfg.plan(pending.len(), pending.first().map(|w| w.req.submitted)) {
                break plan;
            }
            let timeout = cfg.poll_interval();
            match rx.recv_timeout(timeout) {
                Ok(item) => pending.push(item),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if pending.is_empty() && shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    if pending.is_empty() {
                        return;
                    }
                    // Flush whatever is left.
                    break BatchPlan { take: pending.len() };
                }
            }
        };

        let batch: Vec<WorkItem> = pending.drain(..plan.take.min(pending.len())).collect();
        if batch.is_empty() {
            continue;
        }
        if let Err(e) = execute_batch(&registry, &model, &batch, engine.as_mut(), &metrics) {
            log::error!("batch execution failed: {e:#}");
            // Drop the batch; reply channels close and callers see an error.
        }
    }
}

fn execute_batch(
    registry: &ModelRegistry,
    model: &str,
    batch: &[WorkItem],
    mut engine: Option<&mut AsyncTmEngine>,
    metrics: &Arc<Mutex<Metrics>>,
) -> Result<()> {
    let exec_size = registry.exec_batch(batch.len());
    let runner = registry.runner(model, exec_size)?;
    let t0 = Instant::now();
    // Slice the logical batch into runner-sized chunks.
    for chunk in batch.chunks(exec_size) {
        let rows: Vec<Vec<bool>> = chunk.iter().map(|w| w.req.features.clone()).collect();
        let x = bools_to_f32(&rows);
        let out = if chunk.len() == runner.batch {
            runner.run(&x)?
        } else {
            runner.run_padded(&x, chunk.len())?
        };
        for (i, item) in chunk.iter().enumerate() {
            let (hw_latency, hw_winner) = match engine.as_deref_mut() {
                Some(eng) => {
                    let bits = out.clause_bits_row(i);
                    let o = eng.infer(&bits);
                    (Some(o.decision_latency), Some(o.winner))
                }
                None => (None, None),
            };
            let service_us = item.req.submitted.elapsed().as_secs_f64() * 1e6;
            let resp = InferResponse {
                request_id: item.id,
                pred: out.pred[i] as usize,
                sums: out.sums_row(i).to_vec(),
                hw_decision_latency: hw_latency,
                hw_winner,
                service_latency_us: service_us,
                batch_size: chunk.len(),
            };
            metrics.lock().unwrap().record(&resp);
            let _ = item.req.reply.send(resp); // receiver may have gone away
        }
    }
    metrics
        .lock()
        .unwrap()
        .record_batch(batch.len(), t0.elapsed().as_secs_f64() * 1e6);
    Ok(())
}
