//! L3 serving coordinator: admission control, request routing, dynamic
//! batching, a multi-worker execution pool, fail-soft error delivery,
//! metrics.
//!
//! The coordinator is the deployment shell around the paper's hardware:
//! clients submit Booleanized samples, which are width-validated against
//! the served model and bit-packed once at ingestion (the packed words
//! are the native currency of the whole request path — see `tm::bits`);
//! a dispatcher routes each request to one of `n_workers` worker threads
//! (round-robin or least-loaded); each worker runs its own dynamic
//! batcher (size- and deadline-bounded, vLLM-router style) and *owns*
//! its execution backend — constructed inside the worker thread from a
//! [`BackendSpec`], because PJRT clients are not `Send` while native
//! backends are. Simulated hardware is just another backend
//! (`BackendSpec::TimeDomain` → `runtime::HwBackend`, one
//! independently-seeded die per worker): the worker-side
//! [`ReplayPolicy`] decides which served rows are additionally replayed
//! through the backend's hardware engine for on-chip decision latency,
//! with no backend-specific plumbing anywhere in the pool.
//!
//! **The fail-soft contract.** Every call to [`Coordinator::submit`]
//! delivers exactly one [`Reply`] — `Ok(InferResponse)` or a typed
//! [`InferError`] — so callers never diagnose a bare closed channel.
//! Malformed rows are refused at ingestion (`WidthMismatch`) before they
//! can join a batch, overload is shed against a bounded per-worker queue
//! (`QueueFull`, policy [`ShedPolicy`]), and a backend failure on a
//! batch falls back to per-row retry so one bad row cannot poison its
//! `max_batch − 1` neighbors (`BackendFailed` goes only to the rows that
//! actually fail). Dropped work is visible: see the
//! `rejected_requests` / `shed_requests` / `failed_batches` counters in
//! [`MetricsSnapshot`]. Everything is std-threads + channels (tokio is
//! not in the offline crate set — DESIGN.md §7).

pub mod batcher;
pub mod metrics;

pub use batcher::{BatchPlan, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot};

use std::num::NonZeroU32;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

use crate::runtime::{BackendSpec, ForwardOutput, InferenceBackend, ModelRegistry};
use crate::tm::{BitVec64, PackedBatch};
use crate::util::Ps;

/// One inference request. Features are bit-packed at ingestion
/// ([`Coordinator::submit`] validates the width and packs the caller's
/// bools exactly once), so the batcher, workers, and backends all
/// consume the packed form — batch assembly is a word memcpy per
/// request.
#[derive(Debug)]
pub struct InferRequest {
    pub features: BitVec64,
    /// Where to deliver the response (or the typed error).
    pub reply: mpsc::Sender<Reply>,
    submitted: Instant,
}

/// One inference response.
#[derive(Debug, Clone, PartialEq)]
pub struct InferResponse {
    pub request_id: u64,
    /// Functional argmax class from the executing backend.
    pub pred: usize,
    /// Signed class sums.
    pub sums: Vec<i32>,
    /// Simulated on-chip decision latency of the backend's hardware
    /// engine (None when the backend has no engine, or the [`ReplayPolicy`]
    /// skipped this row).
    pub hw_decision_latency: Option<Ps>,
    /// Hardware argmax (may disagree with `pred` only on exact class-sum
    /// ties, and only for the async architecture — see `crate::hw`).
    pub hw_winner: Option<usize>,
    /// End-to-end service latency through the coordinator (µs).
    pub service_latency_us: f64,
    /// Logical batch this request was served in (1 when the row was
    /// isolated by a per-row retry after its batch failed).
    pub batch_size: usize,
    /// Index of the worker that served this request.
    pub worker: usize,
}

/// Typed per-request failure, delivered on the caller's reply channel.
///
/// The serving contract is fail-soft: a request that cannot be served is
/// answered with one of these instead of a silently dropped channel.
/// [`Coordinator::infer_blocking`] converts them into `anyhow::Error`;
/// the original variant stays recoverable via
/// `err.downcast_ref::<InferError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InferError {
    /// The feature row's width does not match the served model. Rejected
    /// at admission, before the row can join (and poison) a batch.
    WidthMismatch { got: usize, expected: usize },
    /// The chosen worker's bounded queue was full and the shed policy
    /// dropped this request. `depth` is the worker's in-flight load when
    /// the decision was made.
    QueueFull { depth: usize, limit: usize },
    /// The backend's forward pass failed for this row — even after the
    /// batch it arrived in was split and retried row-by-row.
    BackendFailed(String),
    /// The pool (or its worker) went away before the request could be
    /// queued.
    ShuttingDown,
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::WidthMismatch { got, expected } => {
                write!(f, "feature width {got} does not match model width {expected}")
            }
            InferError::QueueFull { depth, limit } => {
                write!(f, "worker queue full ({depth} in flight, limit {limit}); request shed")
            }
            InferError::BackendFailed(msg) => write!(f, "backend forward pass failed: {msg}"),
            InferError::ShuttingDown => write!(f, "coordinator is shutting down"),
        }
    }
}

impl std::error::Error for InferError {}

/// What a caller receives on its reply channel: exactly one per
/// submitted request.
pub type Reply = Result<InferResponse, InferError>;

/// How the dispatcher assigns incoming requests to workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Cycle through workers in submission order.
    RoundRobin,
    /// Send to the worker with the fewest in-flight requests
    /// (ties → lowest index).
    LeastLoaded,
}

impl DispatchPolicy {
    pub fn from_name(name: &str) -> Result<DispatchPolicy> {
        match name {
            "round-robin" => Ok(DispatchPolicy::RoundRobin),
            "least-loaded" => Ok(DispatchPolicy::LeastLoaded),
            other => anyhow::bail!(
                "unknown dispatch policy {other:?} (expected: round-robin, least-loaded)"
            ),
        }
    }
}

/// What happens when a worker is at [`CoordinatorConfig::queue_limit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedPolicy {
    /// Refuse the incoming request at admission: the new caller gets
    /// [`InferError::QueueFull`]; queued work is untouched. When the
    /// dispatcher's pick is full, the request first spills to the
    /// least-loaded worker with room — only a fully saturated pool
    /// rejects.
    #[default]
    RejectNew,
    /// Admit the incoming request and have the worker shed its *stalest*
    /// queued request instead, so the freshest work survives —
    /// event-driven clients usually prefer a current answer over a stale
    /// one. A drop-oldest queue at its limit also flushes immediately
    /// (eviction keeps replacing the queue head, which would otherwise
    /// reset the batcher's age deadline forever under sustained
    /// overload).
    DropOldest,
}

impl ShedPolicy {
    /// Parse a CLI-style policy name: `reject-new`, `drop-oldest`.
    pub fn from_name(name: &str) -> Result<ShedPolicy> {
        match name {
            "reject-new" => Ok(ShedPolicy::RejectNew),
            "drop-oldest" => Ok(ShedPolicy::DropOldest),
            other => anyhow::bail!(
                "unknown shed policy {other:?} (expected: reject-new, drop-oldest)"
            ),
        }
    }
}

/// Which served rows are replayed through the backend's hardware engine
/// ([`InferenceBackend::replay`]) for on-chip timing. Works against any
/// engine-carrying backend; backends without an engine simply report no
/// hardware fields whatever the policy says.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplayPolicy {
    /// Never replay (pure functional serving).
    #[default]
    Off,
    /// Replay one row in N (per worker), amortizing the simulation cost
    /// while keeping the latency histograms populated. `NonZeroU32`
    /// makes the divide-by-zero degenerate unrepresentable.
    Sample(NonZeroU32),
    /// Replay every row (full per-request hardware telemetry).
    Full,
}

impl ReplayPolicy {
    /// Parse a CLI-style policy name: `off`, `sample:<N>`, `full`.
    pub fn from_name(name: &str) -> Result<ReplayPolicy> {
        match name {
            "off" => Ok(ReplayPolicy::Off),
            "full" => Ok(ReplayPolicy::Full),
            other => {
                if let Some(n) = other.strip_prefix("sample:") {
                    let n: u32 = n.parse().with_context(|| {
                        format!("replay policy sample:<N> expects an integer, got {n:?}")
                    })?;
                    let n = NonZeroU32::new(n)
                        .ok_or_else(|| anyhow!("replay policy sample:<N> needs N ≥ 1"))?;
                    Ok(ReplayPolicy::Sample(n))
                } else {
                    anyhow::bail!(
                        "unknown replay policy {other:?} (expected: off, sample:<N>, full)"
                    )
                }
            }
        }
    }

    /// Whether the `seq`-th row a worker serves (0-based) gets replayed.
    pub fn take(self, seq: u64) -> bool {
        match self {
            ReplayPolicy::Off => false,
            ReplayPolicy::Full => true,
            ReplayPolicy::Sample(n) => seq % u64::from(n.get()) == 0,
        }
    }
}

/// Pool-level configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Per-worker dynamic batching policy.
    pub batcher: BatcherConfig,
    /// Number of worker threads (≥ 1), each owning its own backend.
    pub n_workers: usize,
    pub dispatch: DispatchPolicy,
    /// How each worker constructs its execution backend.
    pub backend: BackendSpec,
    /// Which served rows replay through the backend's hardware engine.
    pub replay: ReplayPolicy,
    /// Bound on each worker's in-flight load (requests dispatched to it
    /// but not yet answered — the same `depth` gauge least-loaded
    /// dispatch reads). `None` accepts without bound. With multiple
    /// concurrent submitters the bound is approximate: admission reads
    /// the gauge without a lock.
    pub queue_limit: Option<usize>,
    /// What to shed when a worker is at `queue_limit`.
    pub shed: ShedPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig::default(),
            n_workers: 1,
            dispatch: DispatchPolicy::RoundRobin,
            backend: BackendSpec::default(),
            replay: ReplayPolicy::default(),
            queue_limit: None,
            shed: ShedPolicy::default(),
        }
    }
}

struct WorkItem {
    id: u64,
    req: InferRequest,
}

/// One worker thread's handle: its queue, load gauge, metrics, and join
/// handle.
struct WorkerHandle {
    tx: Option<mpsc::Sender<WorkItem>>,
    /// Requests dispatched but not yet answered (least-loaded gauge and
    /// admission-control bound).
    depth: Arc<AtomicUsize>,
    metrics: Arc<Mutex<Metrics>>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Handle to a running coordinator pool for one model.
pub struct Coordinator {
    workers: Vec<WorkerHandle>,
    next_id: AtomicU64,
    rr: AtomicUsize,
    dispatch: DispatchPolicy,
    /// Feature width of the served model, cached at startup so
    /// [`Coordinator::submit`] can gate admission without a backend
    /// round-trip.
    n_features: usize,
    queue_limit: Option<usize>,
    shed: ShedPolicy,
    /// Admission-time counters (width rejections, reject-new sheds).
    /// Lock-free on purpose: the fast-reject path must not serialize
    /// overloaded client threads on a mutex. Folded into
    /// [`Coordinator::metrics`] at snapshot time.
    admission_rejected: AtomicU64,
    admission_shed: AtomicU64,
    shutdown: Arc<AtomicBool>,
    pub model: String,
}

impl Coordinator {
    /// Start a worker pool for `model` over the artifacts at `root`.
    ///
    /// Each worker thread constructs its own [`ModelRegistry`] and backend
    /// from `cfg.backend` (PJRT backends are not `Send`; native backends
    /// are, but per-worker ownership keeps the paths uniform — and gives
    /// time-domain backends one independently-seeded simulated die per
    /// worker via [`BackendSpec::for_worker`]). Startup errors from every
    /// worker are reported back before `start` returns; on success each
    /// worker also reports the model's feature width, which `start`
    /// caches for the admission-control width gate in
    /// [`Coordinator::submit`].
    pub fn start(root: PathBuf, model: &str, cfg: CoordinatorConfig) -> Result<Coordinator> {
        ensure!(cfg.n_workers >= 1, "coordinator needs at least one worker");
        let shutdown = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize>>();
        let mut workers = Vec::with_capacity(cfg.n_workers);
        for w in 0..cfg.n_workers {
            let (tx, rx) = mpsc::channel::<WorkItem>();
            let depth = Arc::new(AtomicUsize::new(0));
            let metrics = Arc::new(Mutex::new(Metrics::default()));
            let join = {
                let root = root.clone();
                let model = model.to_string();
                let spec = cfg.backend.clone().for_worker(w);
                let batcher = cfg.batcher;
                let queue_limit = cfg.queue_limit;
                let shed = cfg.shed;
                let replay = cfg.replay;
                let depth = depth.clone();
                let metrics = metrics.clone();
                let shutdown = shutdown.clone();
                let ready_tx = ready_tx.clone();
                std::thread::Builder::new()
                    .name(format!("tdpc-worker-{model}-{w}"))
                    .spawn(move || {
                        // Build the backend inside the owning thread.
                        let backend = match ModelRegistry::open_with(&root, spec)
                            .and_then(|reg| reg.backend(&model))
                        {
                            Ok(b) => b,
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        };
                        let _ = ready_tx.send(Ok(backend.n_features()));
                        drop(ready_tx);
                        worker_loop(
                            w,
                            backend.as_ref(),
                            batcher,
                            queue_limit,
                            shed,
                            replay,
                            rx,
                            metrics,
                            shutdown,
                            depth,
                        )
                    })?
            };
            workers.push(WorkerHandle { tx: Some(tx), depth, metrics, join: Some(join) });
        }
        drop(ready_tx);

        // Collect one readiness report per worker before declaring the
        // pool up.
        let mut startup_err: Option<anyhow::Error> = None;
        let mut n_features: Option<usize> = None;
        for _ in 0..cfg.n_workers {
            let report = ready_rx
                .recv()
                .unwrap_or_else(|_| Err(anyhow!("coordinator worker died during startup")));
            match report {
                Ok(width) => {
                    n_features.get_or_insert(width);
                }
                Err(e) => {
                    startup_err.get_or_insert(e);
                }
            }
        }
        let n_features = match (startup_err, n_features) {
            (None, Some(width)) => width,
            (err, _) => {
                shutdown.store(true, Ordering::SeqCst);
                for h in &mut workers {
                    h.tx = None;
                }
                for h in &mut workers {
                    if let Some(j) = h.join.take() {
                        let _ = j.join();
                    }
                }
                let e = err.unwrap_or_else(|| anyhow!("no coordinator worker reported ready"));
                return Err(e).context("coordinator startup failed");
            }
        };

        Ok(Coordinator {
            workers,
            next_id: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            dispatch: cfg.dispatch,
            n_features,
            queue_limit: cfg.queue_limit,
            shed: cfg.shed,
            admission_rejected: AtomicU64::new(0),
            admission_shed: AtomicU64::new(0),
            shutdown,
            model: model.to_string(),
        })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Feature width of the served model — the width
    /// [`Coordinator::submit`] admits against.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    fn pick_worker(&self) -> usize {
        match self.dispatch {
            DispatchPolicy::RoundRobin => {
                self.rr.fetch_add(1, Ordering::Relaxed) % self.workers.len()
            }
            DispatchPolicy::LeastLoaded => self
                .workers
                .iter()
                .enumerate()
                .min_by_key(|(_, w)| w.depth.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    /// Submit asynchronously. Exactly one [`Reply`] is delivered on
    /// `reply` for every call: a response, or a typed [`InferError`]
    /// when the request is refused at admission (width gate, bounded
    /// queue), shed, or fails in the backend. Returns the request id.
    ///
    /// The Boolean feature row is validated against the served model's
    /// width *here*, at ingestion — a malformed row is answered with
    /// [`InferError::WidthMismatch`] before it can join (and poison) a
    /// batch — then bit-packed once, so everything downstream (dispatch,
    /// batching, the backend forward pass) works on `u64` words.
    pub fn submit(&self, features: &[bool], reply: mpsc::Sender<Reply>) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if features.len() != self.n_features {
            self.admission_rejected.fetch_add(1, Ordering::Relaxed);
            let _ = reply.send(Err(InferError::WidthMismatch {
                got: features.len(),
                expected: self.n_features,
            }));
            return id;
        }
        let mut w = self.pick_worker();
        if let (ShedPolicy::RejectNew, Some(limit)) = (self.shed, self.queue_limit) {
            if self.workers[w].depth.load(Ordering::Relaxed) >= limit {
                // The dispatcher's pick is full. Spill to the least-loaded
                // worker with room before shedding, so a pool with idle
                // capacity never rejects (round-robin can land on a full
                // worker while its neighbors sit empty).
                let depths = self.workers.iter().map(|h| h.depth.load(Ordering::Relaxed));
                match spill_target(depths, limit) {
                    Some(alt) => w = alt,
                    None => {
                        // An admission-time event: counted lock-free on
                        // the coordinator, keeping overloaded client
                        // threads off every metrics mutex.
                        let depth = self.workers[w].depth.load(Ordering::Relaxed);
                        self.admission_shed.fetch_add(1, Ordering::Relaxed);
                        let _ = reply.send(Err(InferError::QueueFull { depth, limit }));
                        return id;
                    }
                }
            }
        }
        let worker = &self.workers[w];
        let Some(tx) = worker.tx.as_ref() else {
            let _ = reply.send(Err(InferError::ShuttingDown));
            return id;
        };
        worker.depth.fetch_add(1, Ordering::Relaxed);
        let item = WorkItem {
            id,
            req: InferRequest {
                features: BitVec64::from_bools(features),
                reply,
                submitted: Instant::now(),
            },
        };
        if let Err(mpsc::SendError(item)) = tx.send(item) {
            // The worker died; the item comes back, so its caller still
            // gets a typed answer instead of a dead channel.
            worker.depth.fetch_sub(1, Ordering::Relaxed);
            let _ = item.req.reply.send(Err(InferError::ShuttingDown));
        }
        id
    }

    /// Convenience blocking call. Rejected, shed, and backend-failed
    /// requests surface as a typed [`InferError`] (recoverable via
    /// `err.downcast_ref::<InferError>()`), never a bare closed-channel
    /// error.
    pub fn infer_blocking(&self, features: &[bool]) -> Result<InferResponse> {
        let (tx, rx) = mpsc::channel();
        self.submit(features, tx);
        let reply = rx.recv().context("coordinator dropped the reply channel")?;
        reply.map_err(anyhow::Error::from)
    }

    /// Aggregated metrics across all workers plus admission-time events
    /// (latency histograms merge, counters sum). Admission-time events —
    /// width rejections and reject-new sheds — happen before any worker
    /// is involved and are counted lock-free on the coordinator, so they
    /// appear in this aggregate but not in
    /// [`Coordinator::worker_metrics`]; drop-oldest sheds and batch
    /// failures are worker-side and appear in both. (The worker-side
    /// assembly guard in `execute_batch` — unreachable through the
    /// public API — attributes its rejection to the worker that caught
    /// it.)
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut agg = Metrics::default();
        for w in &self.workers {
            agg.merge(&w.metrics.lock().unwrap());
        }
        agg.record_rejected(self.admission_rejected.load(Ordering::Relaxed));
        agg.record_shed(self.admission_shed.load(Ordering::Relaxed));
        agg.snapshot()
    }

    /// Per-worker metrics snapshots, in worker-index order.
    pub fn worker_metrics(&self) -> Vec<MetricsSnapshot> {
        self.workers
            .iter()
            .map(|w| w.metrics.lock().unwrap().snapshot())
            .collect()
    }

    /// Stop every worker after draining all queued requests.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Drop all senders first so every worker sees Disconnected and
        // flushes its pending queue, then join.
        for w in &mut self.workers {
            w.tx = None;
        }
        for w in &mut self.workers {
            if let Some(h) = w.join.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Reject-new admission spill: when the dispatcher's pick is at the
/// queue limit, the least-loaded worker with room (ties → lowest index)
/// should take the request instead; `None` means the whole pool is
/// saturated and the request must be shed. Pure decision logic.
fn spill_target<I: Iterator<Item = usize>>(depths: I, limit: usize) -> Option<usize> {
    depths
        .enumerate()
        .filter(|&(_, d)| d < limit)
        .min_by_key(|&(_, d)| d)
        .map(|(i, _)| i)
}

/// Greedily drain ready channel items into `pending`, never growing it
/// past `max_batch`. Regression guard: the old loop pushed *before*
/// checking the bound, so a queue the `recv_timeout` arm had already
/// filled to `max_batch` could over-fill on the next pass.
fn drain_ready(rx: &mpsc::Receiver<WorkItem>, pending: &mut Vec<WorkItem>, max_batch: usize) {
    while pending.len() < max_batch {
        match rx.try_recv() {
            Ok(item) => pending.push(item),
            Err(_) => break,
        }
    }
}

/// Drop-oldest shedding: trim `pending` to its freshest `limit` rows,
/// answering each evicted (stalest-first) request with
/// [`InferError::QueueFull`] and releasing its load. Trims by the
/// *local* queue length, never the global gauge: the gauge counts
/// channel backlog too, and shedding against it would evict rows the
/// very flush that follows is about to serve.
fn shed_to_limit(
    limit: usize,
    pending: &mut Vec<WorkItem>,
    depth: &AtomicUsize,
    metrics: &Mutex<Metrics>,
) {
    let overflow = pending.len().saturating_sub(limit);
    if overflow == 0 {
        return;
    }
    // One O(n) drain of the stalest prefix, not per-item remove(0) —
    // this runs on the overload hot path against a just-drained backlog.
    let mut shed: Vec<(WorkItem, usize)> = Vec::with_capacity(overflow);
    for item in pending.drain(..overflow) {
        let observed = depth.fetch_sub(1, Ordering::Relaxed);
        shed.push((item, observed));
    }
    // Count before replying (metrics are complete the moment a caller
    // sees its answer), then deliver the typed sheds.
    metrics.lock().unwrap().record_shed(shed.len() as u64);
    for (item, observed) in shed {
        let _ = item.req.reply.send(Err(InferError::QueueFull { depth: observed, limit }));
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker: usize,
    backend: &dyn InferenceBackend,
    cfg: BatcherConfig,
    queue_limit: Option<usize>,
    shed: ShedPolicy,
    replay: ReplayPolicy,
    rx: mpsc::Receiver<WorkItem>,
    metrics: Arc<Mutex<Metrics>>,
    shutdown: Arc<AtomicBool>,
    depth: Arc<AtomicUsize>,
) {
    let mut pending: Vec<WorkItem> = Vec::new();
    // Rows this worker has served, for 1-in-N replay sampling.
    let mut replay_seq: u64 = 0;
    loop {
        // Collect until the batch plan says flush. The channel is drained
        // greedily before each planning decision: the deadline is measured
        // from *submission*, so leaving ready work in the channel would
        // make every item individually overdue and collapse batching.
        let plan = loop {
            drain_ready(&rx, &mut pending, cfg.max_batch);
            if let (ShedPolicy::DropOldest, Some(limit)) = (shed, queue_limit) {
                if depth.load(Ordering::Relaxed) > limit {
                    // Over the bound. The channel backlog has to come out
                    // either way — to be shed or served — so drain it
                    // all, keep the freshest `limit` rows, shed the rest,
                    // and flush *now*: eviction keeps replacing the head,
                    // so waiting on the head-age deadline would starve
                    // serving under sustained overload, and at the limit
                    // there is nothing to gain by batching longer.
                    drain_ready(&rx, &mut pending, usize::MAX);
                    shed_to_limit(limit, &mut pending, &depth, &metrics);
                    if !pending.is_empty() {
                        break BatchPlan { take: pending.len().min(cfg.max_batch) };
                    }
                }
            }
            if let Some(plan) = cfg.plan(pending.len(), pending.first().map(|w| w.req.submitted)) {
                break plan;
            }
            match rx.recv_timeout(cfg.poll_interval()) {
                // `plan` returned None, so pending is below max_batch and
                // this push cannot over-fill it.
                Ok(item) => pending.push(item),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if pending.is_empty() && shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    if pending.is_empty() {
                        return;
                    }
                    // Flush whatever is left (graceful drain).
                    break BatchPlan { take: pending.len() };
                }
            }
        };

        let batch: Vec<WorkItem> = pending.drain(..plan.take.min(pending.len())).collect();
        if batch.is_empty() {
            continue;
        }
        execute_batch(worker, backend, batch, replay, &mut replay_seq, &metrics, &depth);
    }
}

/// Execute one batch fail-soft, delivering exactly one [`Reply`] per
/// item. Failure isolation, in order:
///
/// 1. a row that fails packed assembly (unreachable through the public
///    API — [`Coordinator::submit`] gates width at ingestion) is
///    answered with [`InferError::WidthMismatch`] and excluded instead
///    of poisoning its neighbors;
/// 2. a failed multi-row forward pass falls back to per-row retry, so
///    one bad row costs only itself — every healthy neighbor is still
///    served — and each caller whose row really cannot be served gets a
///    typed [`InferError::BackendFailed`];
/// 3. metrics accumulate into a local delta and fold into the worker's
///    [`Metrics`] under one lock per batch (not one per row), before any
///    reply goes out so aggregate counters are complete the moment a
///    client has seen the last response (no settle race).
fn execute_batch(
    worker: usize,
    backend: &dyn InferenceBackend,
    batch: Vec<WorkItem>,
    replay: ReplayPolicy,
    replay_seq: &mut u64,
    metrics: &Mutex<Metrics>,
    depth: &AtomicUsize,
) {
    let expected = backend.n_features();
    let mut rows = PackedBatch::new(expected);
    let mut items: Vec<WorkItem> = Vec::with_capacity(batch.len());
    let mut delta = Metrics::default();
    let mut outbox: Vec<(WorkItem, Reply)> = Vec::with_capacity(batch.len());
    for mut item in batch {
        let features = std::mem::take(&mut item.req.features);
        let got = features.len();
        if rows.push_bitvec(&features).is_ok() {
            items.push(item);
        } else {
            delta.record_rejected(1);
            outbox.push((item, Err(InferError::WidthMismatch { got, expected })));
        }
    }

    if !items.is_empty() {
        let n = items.len();
        let t0 = Instant::now();
        match forward_caught(backend, &rows) {
            Ok(out) => {
                delta.record_batch(n, t0.elapsed().as_secs_f64() * 1e6);
                for (i, item) in items.into_iter().enumerate() {
                    let resp =
                        make_response(worker, backend, &out, i, n, replay, replay_seq, &item);
                    delta.record(&resp);
                    outbox.push((item, Ok(resp)));
                }
            }
            Err(e) if n == 1 => {
                delta.record_failed_batch();
                log::warn!("worker {worker}: forward failed for a single-row batch: {e:#}");
                let item = items.pop().expect("n == 1");
                outbox.push((item, Err(InferError::BackendFailed(format!("{e:#}")))));
            }
            Err(e) => {
                // Fail-soft: split the batch and retry each row alone, so
                // one poisonous row costs only itself.
                delta.record_failed_batch();
                log::warn!(
                    "worker {worker}: forward failed for a {n}-row batch ({e:#}); \
                     retrying rows individually"
                );
                for (i, item) in items.into_iter().enumerate() {
                    let mut single = PackedBatch::new(expected);
                    single.push_words(rows.row(i));
                    let t1 = Instant::now();
                    match forward_caught(backend, &single) {
                        Ok(out) => {
                            delta.record_batch(1, t1.elapsed().as_secs_f64() * 1e6);
                            let resp = make_response(
                                worker,
                                backend,
                                &out,
                                0,
                                1,
                                replay,
                                replay_seq,
                                &item,
                            );
                            delta.record(&resp);
                            outbox.push((item, Ok(resp)));
                        }
                        Err(re) => {
                            delta.record_failed_batch();
                            let err = InferError::BackendFailed(format!("{re:#}"));
                            outbox.push((item, Err(err)));
                        }
                    }
                }
            }
        }
    }

    // One metrics lock per batch, taken before any reply goes out so
    // aggregate counters are complete the moment a client has seen the
    // last response.
    metrics.lock().unwrap().merge(&delta);
    for (item, reply) in outbox {
        // Release the load gauge *before* replying so a blocking caller's
        // next submit observes the decrement (least-loaded determinism).
        depth.fetch_sub(1, Ordering::Relaxed);
        let _ = item.req.reply.send(reply); // receiver may have gone away
    }
}

/// Run the backend forward pass with panic containment: a panicking
/// backend becomes an ordinary error instead of an unwinding worker
/// thread. An unwind here would drop the reply sender of every queued
/// request — exactly the bare closed-channel failure the typed
/// [`Reply`] contract forbids.
fn forward_caught(backend: &dyn InferenceBackend, rows: &PackedBatch) -> Result<ForwardOutput> {
    match catch_unwind(AssertUnwindSafe(|| backend.forward(rows))) {
        Ok(res) => res,
        Err(panic) => Err(anyhow!("backend forward panicked: {}", panic_message(&panic))),
    }
}

/// Best-effort human-readable payload of a caught panic.
fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Build the reply for `row` of a forward output: replay-policy-driven
/// hardware timing, service latency stamped at delivery time.
#[allow(clippy::too_many_arguments)]
fn make_response(
    worker: usize,
    backend: &dyn InferenceBackend,
    out: &ForwardOutput,
    row: usize,
    batch_size: usize,
    replay: ReplayPolicy,
    replay_seq: &mut u64,
    item: &WorkItem,
) -> InferResponse {
    // The replay policy is engine-agnostic: any backend carrying a
    // hardware engine answers `replay`; all others return None. Replay
    // is telemetry, so a panicking engine degrades to "no hardware
    // fields" rather than killing the worker (and every queued reply
    // sender) mid-batch.
    let seq = *replay_seq;
    *replay_seq += 1;
    let (hw_latency, hw_winner) = if replay.take(seq) {
        match catch_unwind(AssertUnwindSafe(|| backend.replay(out, row))) {
            Ok(Some(o)) => (Some(o.decision_latency), Some(o.winner)),
            Ok(None) => (None, None),
            Err(panic) => {
                log::warn!(
                    "worker {worker}: hardware replay panicked: {}",
                    panic_message(&panic)
                );
                (None, None)
            }
        }
    } else {
        (None, None)
    };
    InferResponse {
        request_id: item.id,
        pred: out.pred[row] as usize,
        sums: out.sums_row(row).to_vec(),
        hw_decision_latency: hw_latency,
        hw_winner,
        service_latency_us: item.req.submitted.elapsed().as_secs_f64() * 1e6,
        batch_size,
        worker,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nz(n: u32) -> NonZeroU32 {
        NonZeroU32::new(n).unwrap()
    }

    #[test]
    fn replay_policy_parsing() {
        assert_eq!(ReplayPolicy::from_name("off").unwrap(), ReplayPolicy::Off);
        assert_eq!(ReplayPolicy::from_name("full").unwrap(), ReplayPolicy::Full);
        assert_eq!(
            ReplayPolicy::from_name("sample:8").unwrap(),
            ReplayPolicy::Sample(nz(8))
        );
        for bad in ["sample:0", "sample:x", "some", "sample"] {
            let err = ReplayPolicy::from_name(bad);
            assert!(err.is_err(), "{bad} must be rejected");
        }
        let msg = ReplayPolicy::from_name("everything").unwrap_err().to_string();
        assert!(msg.contains("off") && msg.contains("sample:<N>") && msg.contains("full"));
    }

    #[test]
    fn replay_policy_take_schedule() {
        assert!(!ReplayPolicy::Off.take(0));
        assert!(ReplayPolicy::Full.take(17));
        let s = ReplayPolicy::Sample(nz(4));
        let taken: Vec<u64> = (0..12).filter(|&i| s.take(i)).collect();
        assert_eq!(taken, vec![0, 4, 8]);
        // `Sample(NonZeroU32)` makes the old divide-by-zero degenerate
        // unrepresentable; a 1-in-1 sample is simply every row.
        assert!(ReplayPolicy::Sample(nz(1)).take(5));
    }

    #[test]
    fn shed_policy_parsing() {
        assert_eq!(ShedPolicy::from_name("reject-new").unwrap(), ShedPolicy::RejectNew);
        assert_eq!(ShedPolicy::from_name("drop-oldest").unwrap(), ShedPolicy::DropOldest);
        let msg = ShedPolicy::from_name("newest").unwrap_err().to_string();
        assert!(msg.contains("reject-new") && msg.contains("drop-oldest"));
        assert_eq!(ShedPolicy::default(), ShedPolicy::RejectNew);
    }

    #[test]
    fn spill_target_picks_least_loaded_with_room() {
        assert_eq!(spill_target([4, 2, 3].into_iter(), 4), Some(1));
        assert_eq!(spill_target([4, 4, 1].into_iter(), 4), Some(2));
        // Ties break to the lowest index (min_by_key returns the first
        // minimum).
        assert_eq!(spill_target([2, 0, 0].into_iter(), 4), Some(1));
        // Saturated pool: nobody has room, the request must be shed.
        assert_eq!(spill_target([4, 5, 4].into_iter(), 4), None);
        assert_eq!(spill_target([0].into_iter(), 0), None);
    }

    #[test]
    fn infer_error_messages_are_actionable() {
        fn is_error<E: std::error::Error>(_: &E) {}
        let e = InferError::WidthMismatch { got: 17, expected: 16 };
        is_error(&e);
        assert!(e.to_string().contains("17") && e.to_string().contains("16"));
        let e = InferError::QueueFull { depth: 9, limit: 8 };
        assert!(e.to_string().contains('9') && e.to_string().contains('8'));
        assert!(InferError::BackendFailed("boom".into()).to_string().contains("boom"));
        assert!(InferError::ShuttingDown.to_string().contains("shutting down"));
    }

    /// Regression for the worker drain over-fill: `pending` already at
    /// `max_batch` (the `recv_timeout` arm filled it) plus a non-empty
    /// channel used to grow `pending` to `max_batch + 1`, because the old
    /// loop pushed before checking the bound.
    #[test]
    fn drain_ready_never_grows_pending_past_max_batch() {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (reply_tx, _reply_rx) = mpsc::channel::<Reply>();
        let item = |id: u64| WorkItem {
            id,
            req: InferRequest {
                features: BitVec64::from_bools(&[true, false, true, false]),
                reply: reply_tx.clone(),
                submitted: Instant::now(),
            },
        };
        let max_batch = 4;
        let mut pending: Vec<WorkItem> = (0..max_batch as u64).map(item).collect();
        for id in 10..13 {
            tx.send(item(id)).unwrap();
        }
        drain_ready(&rx, &mut pending, max_batch);
        assert_eq!(pending.len(), max_batch, "pending must never exceed max_batch");

        // The queued items stayed in the channel and drain on the next
        // pass, oldest first.
        pending.clear();
        drain_ready(&rx, &mut pending, max_batch);
        assert_eq!(pending.len(), 3);
        assert_eq!(pending[0].id, 10);

        // A partial queue fills up to the bound and no further.
        for id in 20..30 {
            tx.send(item(id)).unwrap();
        }
        drain_ready(&rx, &mut pending, max_batch);
        assert_eq!(pending.len(), max_batch);
        assert_eq!(pending[3].id, 20);
    }

    /// Drop-oldest shedding trims the *local* queue to its freshest
    /// `limit` rows — it must not consult the global gauge, which also
    /// counts channel backlog (shedding against that starves serving
    /// under sustained overload).
    #[test]
    fn shed_to_limit_evicts_stalest_keeps_freshest() {
        let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
        // Gauge above pending.len(): two more requests still in the
        // channel backlog. Only the local overflow (5 − 2 = 3) sheds.
        let depth = AtomicUsize::new(7);
        let metrics = Mutex::new(Metrics::default());
        let mut pending: Vec<WorkItem> = (0..5u64)
            .map(|id| WorkItem {
                id,
                req: InferRequest {
                    features: BitVec64::from_bools(&[true; 4]),
                    reply: reply_tx.clone(),
                    submitted: Instant::now(),
                },
            })
            .collect();
        shed_to_limit(2, &mut pending, &depth, &metrics);
        assert_eq!(pending.len(), 2, "freshest work survives");
        assert_eq!(pending[0].id, 3);
        assert_eq!(depth.load(Ordering::Relaxed), 4, "3 shed, backlog untouched");
        assert_eq!(metrics.lock().unwrap().snapshot().shed_requests, 3);
        for _ in 0..3 {
            match reply_rx.try_recv().unwrap() {
                Err(InferError::QueueFull { limit: 2, .. }) => {}
                other => panic!("expected QueueFull, got {other:?}"),
            }
        }
        assert!(reply_rx.try_recv().is_err(), "survivors must not be answered");

        // At or under the limit nothing sheds.
        shed_to_limit(2, &mut pending, &depth, &metrics);
        assert_eq!(pending.len(), 2);
        assert_eq!(metrics.lock().unwrap().snapshot().shed_requests, 3);
    }
}
