//! L3 serving coordinator: request routing, dynamic batching, a
//! multi-worker execution pool, metrics.
//!
//! The coordinator is the deployment shell around the paper's hardware:
//! clients submit Booleanized samples, which are bit-packed once at
//! ingestion (the packed words are the native currency of the whole
//! request path — see `tm::bits`); a dispatcher routes each request to
//! one of `n_workers` worker threads (round-robin or least-loaded); each
//! worker runs its own dynamic batcher (size- and deadline-bounded,
//! vLLM-router style) and *owns* its execution backend — constructed
//! inside the worker thread from a [`BackendSpec`], because PJRT clients
//! are not `Send` while native backends are. Simulated hardware is just
//! another backend (`BackendSpec::TimeDomain` → `runtime::HwBackend`,
//! one independently-seeded die per worker): the worker-side
//! [`ReplayPolicy`] decides which served rows are additionally replayed
//! through the backend's hardware engine for on-chip decision latency,
//! with no backend-specific plumbing anywhere in the pool. Everything is
//! std-threads + channels (tokio is not in the offline crate set —
//! DESIGN.md §7).

pub mod batcher;
pub mod metrics;

pub use batcher::{BatchPlan, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

use crate::runtime::{BackendSpec, InferenceBackend, ModelRegistry};
use crate::tm::{BitVec64, PackedBatch};
use crate::util::Ps;

/// One inference request. Features are bit-packed at ingestion
/// ([`Coordinator::submit`] packs the caller's bools exactly once), so
/// the batcher, workers, and backends all consume the packed form — batch
/// assembly is a word memcpy per request.
#[derive(Debug)]
pub struct InferRequest {
    pub features: BitVec64,
    /// Where to deliver the response.
    pub reply: mpsc::Sender<InferResponse>,
    submitted: Instant,
}

/// One inference response.
#[derive(Debug, Clone, PartialEq)]
pub struct InferResponse {
    pub request_id: u64,
    /// Functional argmax class from the executing backend.
    pub pred: usize,
    /// Signed class sums.
    pub sums: Vec<i32>,
    /// Simulated on-chip decision latency of the backend's hardware
    /// engine (None when the backend has no engine, or the [`ReplayPolicy`]
    /// skipped this row).
    pub hw_decision_latency: Option<Ps>,
    /// Hardware argmax (may disagree with `pred` only on exact class-sum
    /// ties, and only for the async architecture — see `crate::hw`).
    pub hw_winner: Option<usize>,
    /// End-to-end service latency through the coordinator (µs).
    pub service_latency_us: f64,
    /// Logical batch this request was served in.
    pub batch_size: usize,
    /// Index of the worker that served this request.
    pub worker: usize,
}

/// How the dispatcher assigns incoming requests to workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Cycle through workers in submission order.
    RoundRobin,
    /// Send to the worker with the fewest in-flight requests
    /// (ties → lowest index).
    LeastLoaded,
}

impl DispatchPolicy {
    pub fn from_name(name: &str) -> Result<DispatchPolicy> {
        match name {
            "round-robin" => Ok(DispatchPolicy::RoundRobin),
            "least-loaded" => Ok(DispatchPolicy::LeastLoaded),
            other => anyhow::bail!(
                "unknown dispatch policy {other:?} (expected: round-robin, least-loaded)"
            ),
        }
    }
}

/// Which served rows are replayed through the backend's hardware engine
/// ([`InferenceBackend::replay`]) for on-chip timing. Works against any
/// engine-carrying backend; backends without an engine simply report no
/// hardware fields whatever the policy says.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplayPolicy {
    /// Never replay (pure functional serving).
    #[default]
    Off,
    /// Replay one row in N (per worker), amortizing the simulation cost
    /// while keeping the latency histograms populated.
    Sample(u32),
    /// Replay every row (full per-request hardware telemetry).
    Full,
}

impl ReplayPolicy {
    /// Parse a CLI-style policy name: `off`, `sample:<N>`, `full`.
    pub fn from_name(name: &str) -> Result<ReplayPolicy> {
        match name {
            "off" => Ok(ReplayPolicy::Off),
            "full" => Ok(ReplayPolicy::Full),
            other => {
                if let Some(n) = other.strip_prefix("sample:") {
                    let n: u32 = n.parse().with_context(|| {
                        format!("replay policy sample:<N> expects an integer, got {n:?}")
                    })?;
                    ensure!(n >= 1, "replay policy sample:<N> needs N ≥ 1");
                    Ok(ReplayPolicy::Sample(n))
                } else {
                    anyhow::bail!(
                        "unknown replay policy {other:?} (expected: off, sample:<N>, full)"
                    )
                }
            }
        }
    }

    /// Whether the `seq`-th row a worker serves (0-based) gets replayed.
    pub fn take(self, seq: u64) -> bool {
        match self {
            ReplayPolicy::Off => false,
            ReplayPolicy::Full => true,
            ReplayPolicy::Sample(n) => seq % u64::from(n.max(1)) == 0,
        }
    }
}

/// Pool-level configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Per-worker dynamic batching policy.
    pub batcher: BatcherConfig,
    /// Number of worker threads (≥ 1), each owning its own backend.
    pub n_workers: usize,
    pub dispatch: DispatchPolicy,
    /// How each worker constructs its execution backend.
    pub backend: BackendSpec,
    /// Which served rows replay through the backend's hardware engine.
    pub replay: ReplayPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig::default(),
            n_workers: 1,
            dispatch: DispatchPolicy::RoundRobin,
            backend: BackendSpec::default(),
            replay: ReplayPolicy::default(),
        }
    }
}

struct WorkItem {
    id: u64,
    req: InferRequest,
}

/// One worker thread's handle: its queue, load gauge, metrics, and join
/// handle.
struct WorkerHandle {
    tx: Option<mpsc::Sender<WorkItem>>,
    /// Requests dispatched but not yet answered (least-loaded gauge).
    depth: Arc<AtomicUsize>,
    metrics: Arc<Mutex<Metrics>>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Handle to a running coordinator pool for one model.
pub struct Coordinator {
    workers: Vec<WorkerHandle>,
    next_id: AtomicU64,
    rr: AtomicUsize,
    dispatch: DispatchPolicy,
    shutdown: Arc<AtomicBool>,
    pub model: String,
}

impl Coordinator {
    /// Start a worker pool for `model` over the artifacts at `root`.
    ///
    /// Each worker thread constructs its own [`ModelRegistry`] and backend
    /// from `cfg.backend` (PJRT backends are not `Send`; native backends
    /// are, but per-worker ownership keeps the paths uniform — and gives
    /// time-domain backends one independently-seeded simulated die per
    /// worker via [`BackendSpec::for_worker`]). Startup errors from every
    /// worker are reported back before `start` returns.
    pub fn start(root: PathBuf, model: &str, cfg: CoordinatorConfig) -> Result<Coordinator> {
        ensure!(cfg.n_workers >= 1, "coordinator needs at least one worker");
        let shutdown = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut workers = Vec::with_capacity(cfg.n_workers);
        for w in 0..cfg.n_workers {
            let (tx, rx) = mpsc::channel::<WorkItem>();
            let depth = Arc::new(AtomicUsize::new(0));
            let metrics = Arc::new(Mutex::new(Metrics::default()));
            let join = {
                let root = root.clone();
                let model = model.to_string();
                let spec = cfg.backend.clone().for_worker(w);
                let batcher = cfg.batcher;
                let replay = cfg.replay;
                let depth = depth.clone();
                let metrics = metrics.clone();
                let shutdown = shutdown.clone();
                let ready_tx = ready_tx.clone();
                std::thread::Builder::new()
                    .name(format!("tdpc-worker-{model}-{w}"))
                    .spawn(move || {
                        // Build the backend inside the owning thread.
                        let backend = match ModelRegistry::open_with(&root, spec)
                            .and_then(|reg| reg.backend(&model))
                        {
                            Ok(b) => b,
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        };
                        let _ = ready_tx.send(Ok(()));
                        drop(ready_tx);
                        worker_loop(
                            w,
                            backend.as_ref(),
                            batcher,
                            replay,
                            rx,
                            metrics,
                            shutdown,
                            depth,
                        )
                    })?
            };
            workers.push(WorkerHandle { tx: Some(tx), depth, metrics, join: Some(join) });
        }
        drop(ready_tx);

        // Collect one readiness report per worker before declaring the
        // pool up.
        let mut startup_err: Option<anyhow::Error> = None;
        for _ in 0..cfg.n_workers {
            let report = ready_rx
                .recv()
                .unwrap_or_else(|_| Err(anyhow!("coordinator worker died during startup")));
            if let Err(e) = report {
                startup_err.get_or_insert(e);
            }
        }
        if let Some(e) = startup_err {
            shutdown.store(true, Ordering::SeqCst);
            for w in &mut workers {
                w.tx = None;
            }
            for w in &mut workers {
                if let Some(h) = w.join.take() {
                    let _ = h.join();
                }
            }
            return Err(e).context("coordinator startup failed");
        }

        Ok(Coordinator {
            workers,
            next_id: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            dispatch: cfg.dispatch,
            shutdown,
            model: model.to_string(),
        })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn pick_worker(&self) -> usize {
        match self.dispatch {
            DispatchPolicy::RoundRobin => {
                self.rr.fetch_add(1, Ordering::Relaxed) % self.workers.len()
            }
            DispatchPolicy::LeastLoaded => self
                .workers
                .iter()
                .enumerate()
                .min_by_key(|(_, w)| w.depth.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    /// Submit asynchronously; the response arrives on `reply`.
    ///
    /// The Boolean feature row is bit-packed here, once, at ingestion —
    /// everything downstream (dispatch, batching, the backend forward
    /// pass) works on `u64` words.
    pub fn submit(&self, features: &[bool], reply: mpsc::Sender<InferResponse>) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let w = self.pick_worker();
        let worker = &self.workers[w];
        let tx = worker
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("coordinator is shutting down"))?;
        worker.depth.fetch_add(1, Ordering::Relaxed);
        let item = WorkItem {
            id,
            req: InferRequest {
                features: BitVec64::from_bools(features),
                reply,
                submitted: Instant::now(),
            },
        };
        if tx.send(item).is_err() {
            worker.depth.fetch_sub(1, Ordering::Relaxed);
            return Err(anyhow!("coordinator worker {w} has shut down"));
        }
        Ok(id)
    }

    /// Convenience blocking call.
    pub fn infer_blocking(&self, features: &[bool]) -> Result<InferResponse> {
        let (tx, rx) = mpsc::channel();
        self.submit(features, tx)?;
        rx.recv().context("coordinator dropped the reply channel")
    }

    /// Aggregated metrics across all workers (latency histograms merge,
    /// counters sum).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut agg = Metrics::default();
        for w in &self.workers {
            agg.merge(&w.metrics.lock().unwrap());
        }
        agg.snapshot()
    }

    /// Per-worker metrics snapshots, in worker-index order.
    pub fn worker_metrics(&self) -> Vec<MetricsSnapshot> {
        self.workers
            .iter()
            .map(|w| w.metrics.lock().unwrap().snapshot())
            .collect()
    }

    /// Stop every worker after draining all queued requests.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Drop all senders first so every worker sees Disconnected and
        // flushes its pending queue, then join.
        for w in &mut self.workers {
            w.tx = None;
        }
        for w in &mut self.workers {
            if let Some(h) = w.join.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker: usize,
    backend: &dyn InferenceBackend,
    cfg: BatcherConfig,
    replay: ReplayPolicy,
    rx: mpsc::Receiver<WorkItem>,
    metrics: Arc<Mutex<Metrics>>,
    shutdown: Arc<AtomicBool>,
    depth: Arc<AtomicUsize>,
) {
    let mut pending: Vec<WorkItem> = Vec::new();
    // Rows this worker has served, for 1-in-N replay sampling.
    let mut replay_seq: u64 = 0;
    loop {
        // Collect until the batch plan says flush. The channel is drained
        // greedily before each planning decision: the deadline is measured
        // from *submission*, so leaving ready work in the channel would
        // make every item individually overdue and collapse batching.
        let plan = loop {
            while let Ok(item) = rx.try_recv() {
                pending.push(item);
                if pending.len() >= cfg.max_batch {
                    break;
                }
            }
            if let Some(plan) = cfg.plan(pending.len(), pending.first().map(|w| w.req.submitted)) {
                break plan;
            }
            let timeout = cfg.poll_interval();
            match rx.recv_timeout(timeout) {
                Ok(item) => pending.push(item),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if pending.is_empty() && shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    if pending.is_empty() {
                        return;
                    }
                    // Flush whatever is left (graceful drain).
                    break BatchPlan { take: pending.len() };
                }
            }
        };

        let mut batch: Vec<WorkItem> = pending.drain(..plan.take.min(pending.len())).collect();
        if batch.is_empty() {
            continue;
        }
        if let Err(e) = execute_batch(
            worker,
            backend,
            &mut batch,
            replay,
            &mut replay_seq,
            &metrics,
            &depth,
        ) {
            log::error!("worker {worker}: batch execution failed: {e:#}");
            // Drop the batch; reply channels close and callers see an error.
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn execute_batch(
    worker: usize,
    backend: &dyn InferenceBackend,
    batch: &mut [WorkItem],
    replay: ReplayPolicy,
    replay_seq: &mut u64,
    metrics: &Arc<Mutex<Metrics>>,
    depth: &AtomicUsize,
) -> Result<()> {
    // Assemble the packed execution batch: requests were packed at
    // ingestion, so each row is a word memcpy. A width-mismatched request
    // fails assembly and drops the whole batch, exactly like a forward
    // error (reply channels close and callers see the disconnect).
    let rows = (|| -> Result<PackedBatch> {
        let mut rows = PackedBatch::new(backend.n_features());
        for w in batch.iter_mut() {
            rows.push_bitvec(&std::mem::take(&mut w.req.features))?;
        }
        Ok(rows)
    })();
    let t0 = Instant::now();
    let out = match rows.and_then(|rows| backend.forward(&rows)) {
        Ok(out) => out,
        Err(e) => {
            // The whole batch is dropped: release its load in one go.
            depth.fetch_sub(batch.len(), Ordering::Relaxed);
            return Err(e);
        }
    };
    // Record the batch before any reply goes out, so metrics are complete
    // the moment a client has seen the last response (no settle race).
    metrics
        .lock()
        .unwrap()
        .record_batch(batch.len(), t0.elapsed().as_secs_f64() * 1e6);
    for (i, item) in batch.iter().enumerate() {
        // The replay policy is engine-agnostic: any backend carrying a
        // hardware engine answers `replay`; all others return None.
        let seq = *replay_seq;
        *replay_seq += 1;
        let (hw_latency, hw_winner) = if replay.take(seq) {
            match backend.replay(&out, i) {
                Some(o) => (Some(o.decision_latency), Some(o.winner)),
                None => (None, None),
            }
        } else {
            (None, None)
        };
        let service_us = item.req.submitted.elapsed().as_secs_f64() * 1e6;
        let resp = InferResponse {
            request_id: item.id,
            pred: out.pred[i] as usize,
            sums: out.sums_row(i).to_vec(),
            hw_decision_latency: hw_latency,
            hw_winner,
            service_latency_us: service_us,
            batch_size: batch.len(),
            worker,
        };
        metrics.lock().unwrap().record(&resp);
        // Release the load gauge *before* replying so a blocking caller's
        // next submit observes the decrement (least-loaded determinism).
        depth.fetch_sub(1, Ordering::Relaxed);
        let _ = item.req.reply.send(resp); // receiver may have gone away
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_policy_parsing() {
        assert_eq!(ReplayPolicy::from_name("off").unwrap(), ReplayPolicy::Off);
        assert_eq!(ReplayPolicy::from_name("full").unwrap(), ReplayPolicy::Full);
        assert_eq!(
            ReplayPolicy::from_name("sample:8").unwrap(),
            ReplayPolicy::Sample(8)
        );
        for bad in ["sample:0", "sample:x", "some", "sample"] {
            let err = ReplayPolicy::from_name(bad);
            assert!(err.is_err(), "{bad} must be rejected");
        }
        let msg = ReplayPolicy::from_name("everything").unwrap_err().to_string();
        assert!(msg.contains("off") && msg.contains("sample:<N>") && msg.contains("full"));
    }

    #[test]
    fn replay_policy_take_schedule() {
        assert!(!ReplayPolicy::Off.take(0));
        assert!(ReplayPolicy::Full.take(17));
        let s = ReplayPolicy::Sample(4);
        let taken: Vec<u64> = (0..12).filter(|&i| s.take(i)).collect();
        assert_eq!(taken, vec![0, 4, 8]);
        // A zero sample rate (only constructible directly) degrades to
        // every-row rather than dividing by zero.
        assert!(ReplayPolicy::Sample(0).take(5));
    }
}
