//! L3 serving coordinator: admission control, model-keyed request
//! routing, dynamic batching, a multi-worker execution pool serving
//! *many models at once*, live model hot-swap, fail-soft error delivery,
//! metrics.
//!
//! The coordinator is the deployment shell around the paper's hardware:
//! clients submit Booleanized samples *for a named model* (interned to a
//! [`ModelId`] at pool startup), which are width-validated against that
//! model's width table entry and bit-packed once at ingestion (the
//! packed words are the native currency of the whole request path — see
//! `tm::bits`); a dispatcher routes each request to one of `n_workers`
//! worker threads (round-robin or least-loaded); each worker runs its
//! own dynamic batcher with **one pending queue per model** — a batch
//! never mixes feature widths or backends; full queues drain oldest-head
//! first and the shared deadline is measured on the globally oldest head
//! (see [`BatcherConfig::plan_multi`]) — and *owns* one backend per
//! served model, constructed inside the worker thread through its own
//! [`ModelRegistry`], because PJRT clients are not `Send` while native
//! backends are. Simulated hardware is just another backend
//! (`BackendSpec::TimeDomain` → `runtime::HwBackend`, one
//! independently-seeded die per worker): the worker-side
//! [`ReplayPolicy`] decides which served rows are additionally replayed
//! through the backend's hardware engine for on-chip decision latency,
//! with no backend-specific plumbing anywhere in the pool.
//!
//! **Hot-swap.** [`Coordinator::reload`] replaces one model's backend in
//! every worker while the pool keeps serving: the model's generation
//! counter is bumped, each worker first drains the rows it already holds
//! for that model against the old backend (rows and control messages
//! share one ordered channel, so "submitted before the reload" ⇒
//! "served by the old generation"), then re-opens the artifact through
//! `ModelRegistry::invalidate` + re-construction and serves subsequent
//! rows from the new backend. Every [`InferResponse`] carries the
//! generation that served it. Zero requests are lost across a swap; a
//! worker whose re-open fails keeps serving the previous generation and
//! the error is returned to the reloader.
//!
//! **The fail-soft contract.** Every call to [`Coordinator::submit`]
//! delivers exactly one [`Reply`] — `Ok(InferResponse)` or a typed
//! [`InferError`] — so callers never diagnose a bare closed channel.
//! Unregistered models are refused at ingestion (`UnknownModel`), as are
//! malformed rows (`WidthMismatch`, against the *per-model* width), so
//! neither can join a batch; overload is shed against a bounded
//! per-worker queue (`QueueFull`, policy [`ShedPolicy`]), and a backend
//! failure on a batch falls back to per-row retry so one bad row cannot
//! poison its `max_batch − 1` neighbors (`BackendFailed` goes only to
//! the rows that actually fail). Dropped work is visible: see the
//! `rejected_requests` / `shed_requests` / `failed_batches` counters in
//! [`MetricsSnapshot`] — pool-wide via [`Coordinator::metrics`], per
//! tenant via [`Coordinator::metrics_for`]. Everything is std-threads +
//! channels (tokio is not in the offline crate set — DESIGN.md §7).
//!
//! **Scatter/reduce (clause sharding).** Alongside route-to-one-worker,
//! [`Coordinator::start_sharded`] serves *one model across all workers*:
//! worker `w` opens a `BackendSpec::Sharded` backend pinned to clause
//! shard `w` (a contiguous slice of the clause-index arena — see
//! `tm::ClauseShard`), every admitted request is scattered to all
//! shards, and a reduce collector accumulates the per-shard partial
//! class sums in a reduce slot keyed by request id: sums add, the
//! merged argmax is re-taken (ties → lowest class, bit-exact with the
//! unsharded forward pass — `tm::merge_partials` is the pure statement
//! of the merge), per-shard replay latencies max into a critical-path
//! estimate, and generations must agree (a mid-reload mix is answered
//! with a typed error, never a Frankenstein prediction). Admission
//! control, typed errors, per-row retry, and shedding all apply per
//! shard group, and a straggler deadline
//! ([`CoordinatorConfig::straggler_deadline`]) converts one slow shard
//! into a typed `BackendFailed` for the affected requests instead of a
//! wedged pool.

pub mod batcher;
pub mod metrics;

pub use batcher::{BatchPlan, BatcherConfig, QueueState};
pub use metrics::{Metrics, MetricsSnapshot};

use std::collections::HashMap;
use std::num::NonZeroU32;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::runtime::{BackendSpec, ForwardOutput, InferenceBackend, ModelRegistry, ShardSpec};
use crate::tm::{BitVec64, HotLoopStats, PackedBatch};
use crate::util::Ps;

/// Interned identity of one served model: a dense index into the pool's
/// model table, assigned by [`Coordinator::start_multi`] in serve-list
/// order. Requests carry this `Copy` id, never a per-request `String` —
/// resolve a name once with [`Coordinator::model_id`] (or use
/// [`Coordinator::submit_named`], which resolves per call). Ids are only
/// meaningful on the pool that issued them: each carries its pool's
/// process-unique tag, so a foreign or stale id — even one whose index
/// happens to be in range — is answered with
/// [`InferError::UnknownModel`], never silently routed to whatever
/// model occupies that index.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId {
    /// Process-unique tag of the issuing pool.
    pool: u32,
    index: u32,
}

impl ModelId {
    pub(crate) fn new(pool: u32, index: u32) -> ModelId {
        ModelId { pool, index }
    }

    /// Dense index into the issuing pool's model table (serve-list
    /// order).
    pub fn index(self) -> usize {
        self.index as usize
    }
}

impl std::fmt::Display for ModelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "model#{}@pool{}", self.index, self.pool)
    }
}

/// One inference request. Features are bit-packed at ingestion
/// ([`Coordinator::submit`] validates the width against the request's
/// model and packs the caller's bools exactly once), so the batcher,
/// workers, and backends all consume the packed form — batch assembly is
/// a word memcpy per request.
#[derive(Debug)]
pub struct InferRequest {
    /// Which model this row is for — the batching key: a worker groups
    /// pending rows by model, so a batch never mixes widths or backends.
    pub model: ModelId,
    pub features: BitVec64,
    /// Where to deliver the response (or the typed error): straight to
    /// the caller, or into a sharded pool's reduce collector.
    pub reply: ReplySink,
    submitted: Instant,
}

/// One inference response.
#[derive(Debug, Clone, PartialEq)]
pub struct InferResponse {
    pub request_id: u64,
    /// The model that served this request.
    pub model: ModelId,
    /// Hot-swap generation of the backend that served it: 0 until the
    /// first successful [`Coordinator::reload`] of this model, then the
    /// reload's generation. Under a concurrent reload, a reply carries
    /// whichever generation actually computed it.
    pub generation: u64,
    /// Functional argmax class from the executing backend.
    pub pred: usize,
    /// Signed class sums.
    pub sums: Vec<i32>,
    /// Simulated on-chip decision latency of the backend's hardware
    /// engine (None when the backend has no engine, or the [`ReplayPolicy`]
    /// skipped this row).
    pub hw_decision_latency: Option<Ps>,
    /// Hardware argmax (may disagree with `pred` only on exact class-sum
    /// ties, and only for the async architecture — see `crate::hw`).
    pub hw_winner: Option<usize>,
    /// End-to-end service latency through the coordinator (µs).
    pub service_latency_us: f64,
    /// Logical batch this request was served in (1 when the row was
    /// isolated by a per-row retry after its batch failed).
    pub batch_size: usize,
    /// Index of the worker that served this request.
    pub worker: usize,
}

/// Typed per-request failure, delivered on the caller's reply channel.
///
/// The serving contract is fail-soft: a request that cannot be served is
/// answered with one of these instead of a silently dropped channel.
/// [`Coordinator::infer_blocking`] converts them into `anyhow::Error`;
/// the original variant stays recoverable via
/// `err.downcast_ref::<InferError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InferError {
    /// The request named a model this pool does not serve (or carried a
    /// foreign/stale [`ModelId`]). Rejected at admission.
    UnknownModel { name: String },
    /// The feature row's width does not match its model. Rejected at
    /// admission, before the row can join (and poison) a batch.
    WidthMismatch { got: usize, expected: usize },
    /// The chosen worker's bounded queue was full and the shed policy
    /// dropped this request. `depth` is the worker's in-flight load when
    /// the decision was made.
    QueueFull { depth: usize, limit: usize },
    /// The backend's forward pass failed for this row — even after the
    /// batch it arrived in was split and retried row-by-row.
    BackendFailed(String),
    /// The pool (or its worker) went away before the request could be
    /// queued.
    ShuttingDown,
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::UnknownModel { name } => {
                write!(f, "model {name:?} is not served by this pool")
            }
            InferError::WidthMismatch { got, expected } => {
                write!(f, "feature width {got} does not match model width {expected}")
            }
            InferError::QueueFull { depth, limit } => {
                write!(f, "worker queue full ({depth} in flight, limit {limit}); request shed")
            }
            InferError::BackendFailed(msg) => write!(f, "backend forward pass failed: {msg}"),
            InferError::ShuttingDown => write!(f, "coordinator is shutting down"),
        }
    }
}

impl std::error::Error for InferError {}

/// What a caller receives on its reply channel: exactly one per
/// submitted request.
pub type Reply = Result<InferResponse, InferError>;

/// Where a worker delivers a finished [`Reply`].
///
/// Route-to-one-worker requests answer the submitting caller directly.
/// A sharded pool's scatter path instead points every shard's copy of a
/// request at the reduce collector, with the request id riding outside
/// the [`Reply`] — [`InferError`] carries no id, so a bare error could
/// not be routed back to its reduce slot otherwise.
#[derive(Debug, Clone)]
pub enum ReplySink {
    /// Deliver straight to the submitting caller.
    Caller(mpsc::Sender<Reply>),
    /// Deliver to the sharded pool's reduce collector as one shard's
    /// partial answer for request `id`.
    Reduce(mpsc::Sender<ReduceMsg>),
}

impl ReplySink {
    /// Deliver one reply for request `id`. Send failures are ignored in
    /// both arms: a caller that hung up forfeits its answer, and a
    /// collector that is gone means the pool is tearing down.
    fn deliver(&self, id: u64, reply: Reply) {
        match self {
            ReplySink::Caller(tx) => {
                let _ = tx.send(reply);
            }
            ReplySink::Reduce(tx) => {
                let _ = tx.send(ReduceMsg::Partial { id, reply });
            }
        }
    }
}

/// One message into a sharded pool's reduce collector.
#[derive(Debug)]
pub enum ReduceMsg {
    /// Open the reduce slot for a scattered request. Sent by
    /// [`Coordinator::submit_packed`] *before* any shard copy is
    /// enqueued, so the slot exists before the first partial can arrive
    /// (worker sends happen-after the scatter, and the channel is
    /// causally ordered).
    Register {
        id: u64,
        model: ModelId,
        caller: mpsc::Sender<Reply>,
        submitted: Instant,
    },
    /// One shard's answer for request `id`: a partial [`InferResponse`]
    /// (partial class sums, shard-local replay latency, `worker` ==
    /// shard index) or that shard's typed error.
    Partial { id: u64, reply: Reply },
}

/// How the dispatcher assigns incoming requests to workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Cycle through workers in submission order.
    RoundRobin,
    /// Send to the worker with the fewest in-flight requests
    /// (ties → lowest index).
    LeastLoaded,
}

impl DispatchPolicy {
    pub fn from_name(name: &str) -> Result<DispatchPolicy> {
        match name {
            "round-robin" => Ok(DispatchPolicy::RoundRobin),
            "least-loaded" => Ok(DispatchPolicy::LeastLoaded),
            other => anyhow::bail!(
                "unknown dispatch policy {other:?} (expected: round-robin, least-loaded)"
            ),
        }
    }
}

/// What happens when a worker is at [`CoordinatorConfig::queue_limit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedPolicy {
    /// Refuse the incoming request at admission: the new caller gets
    /// [`InferError::QueueFull`]; queued work is untouched. When the
    /// dispatcher's pick is full, the request first spills to the
    /// least-loaded worker with room — only a fully saturated pool
    /// rejects.
    #[default]
    RejectNew,
    /// Admit the incoming request and have the worker shed its *stalest*
    /// queued request instead, so the freshest work survives —
    /// event-driven clients usually prefer a current answer over a stale
    /// one. Staleness is global across the worker's per-model queues
    /// (request ids are issued monotonically at submit). A drop-oldest
    /// queue at its limit also flushes immediately (eviction keeps
    /// replacing the queue head, which would otherwise reset the
    /// batcher's age deadline forever under sustained overload).
    DropOldest,
}

impl ShedPolicy {
    /// Parse a CLI-style policy name: `reject-new`, `drop-oldest`.
    pub fn from_name(name: &str) -> Result<ShedPolicy> {
        match name {
            "reject-new" => Ok(ShedPolicy::RejectNew),
            "drop-oldest" => Ok(ShedPolicy::DropOldest),
            other => anyhow::bail!(
                "unknown shed policy {other:?} (expected: reject-new, drop-oldest)"
            ),
        }
    }
}

/// Which served rows are replayed through the backend's hardware engine
/// ([`InferenceBackend::replay`]) for on-chip timing. Works against any
/// engine-carrying backend; backends without an engine simply report no
/// hardware fields whatever the policy says.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplayPolicy {
    /// Never replay (pure functional serving).
    #[default]
    Off,
    /// Replay one row in N (per worker), amortizing the simulation cost
    /// while keeping the latency histograms populated. `NonZeroU32`
    /// makes the divide-by-zero degenerate unrepresentable.
    Sample(NonZeroU32),
    /// Replay every row (full per-request hardware telemetry).
    Full,
}

impl ReplayPolicy {
    /// Parse a CLI-style policy name: `off`, `sample:<N>`, `full`.
    pub fn from_name(name: &str) -> Result<ReplayPolicy> {
        match name {
            "off" => Ok(ReplayPolicy::Off),
            "full" => Ok(ReplayPolicy::Full),
            other => {
                if let Some(n) = other.strip_prefix("sample:") {
                    let n: u32 = n.parse().with_context(|| {
                        format!("replay policy sample:<N> expects an integer, got {n:?}")
                    })?;
                    let n = NonZeroU32::new(n)
                        .ok_or_else(|| anyhow!("replay policy sample:<N> needs N ≥ 1"))?;
                    Ok(ReplayPolicy::Sample(n))
                } else {
                    anyhow::bail!(
                        "unknown replay policy {other:?} (expected: off, sample:<N>, full)"
                    )
                }
            }
        }
    }

    /// Whether the `seq`-th row a worker serves (0-based) gets replayed.
    pub fn take(self, seq: u64) -> bool {
        match self {
            ReplayPolicy::Off => false,
            ReplayPolicy::Full => true,
            ReplayPolicy::Sample(n) => seq % u64::from(n.get()) == 0,
        }
    }
}

/// Pool-level configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Per-worker dynamic batching policy (shared by every served
    /// model's pending queue).
    pub batcher: BatcherConfig,
    /// Number of worker threads (≥ 1), each owning one backend per
    /// served model.
    pub n_workers: usize,
    pub dispatch: DispatchPolicy,
    /// How each worker constructs its execution backends.
    pub backend: BackendSpec,
    /// Which served rows replay through the backend's hardware engine.
    pub replay: ReplayPolicy,
    /// Bound on each worker's in-flight load (requests dispatched to it
    /// but not yet answered — the same `depth` gauge least-loaded
    /// dispatch reads), across all models. `None` accepts without
    /// bound. With multiple concurrent submitters the bound is
    /// approximate: admission reads the gauge without a lock.
    pub queue_limit: Option<usize>,
    /// What to shed when a worker is at `queue_limit`.
    pub shed: ShedPolicy,
    /// Sharded pools only ([`Coordinator::start_sharded`]): how long the
    /// reduce collector waits, from submission, for all shard partials
    /// of a request before failing it with a typed
    /// [`InferError::BackendFailed`] naming the missing shards — one
    /// slow or wedged shard degrades its requests instead of wedging
    /// the pool. Ignored by route-to-one-worker pools.
    pub straggler_deadline: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig::default(),
            n_workers: 1,
            dispatch: DispatchPolicy::RoundRobin,
            backend: BackendSpec::default(),
            replay: ReplayPolicy::default(),
            queue_limit: None,
            shed: ShedPolicy::default(),
            straggler_deadline: Duration::from_secs(2),
        }
    }
}

struct WorkItem {
    id: u64,
    req: InferRequest,
}

/// What travels down a worker's channel: inference rows interleaved, in
/// order, with hot-swap control messages. The shared ordered channel is
/// what makes reload zero-loss: a row enqueued before the `Reload`
/// control is flushed against the old backend, a row after it meets the
/// new one.
enum WorkMsg {
    Infer(WorkItem),
    Reload {
        /// Index into the worker's model slots (== [`ModelId::index`]).
        model_ix: usize,
        generation: u64,
        ack: mpsc::Sender<ReloadReport>,
    },
}

/// One worker's answer to a `Reload` control: what the swap did, or why
/// it failed (in which case the worker keeps serving the previous
/// generation).
struct ReloadReport {
    worker: usize,
    result: Result<SwapReport>,
}

/// What one worker's successful swap did: the new backend's shape plus
/// the payload delta its registry observed — on a v2 (content-addressed)
/// artifact tree, `shards_reused` counts clause-block objects served
/// from the hash-keyed cache (unchanged hash → no disk touch) and
/// `shards_opened` the objects actually re-read, so a reload that
/// changed 1 of N objects reports `(reused, opened) = (N−1, 1)`.
/// Non-content-addressed paths (v1 trees, in-memory specs) report
/// `(0, 0)`: nothing is hash-tracked, everything is rebuilt.
#[derive(Debug, Clone, Copy)]
struct SwapReport {
    n_features: usize,
    n_classes: usize,
    shards_reused: u64,
    shards_opened: u64,
}

/// One worker thread's handle: its queue, load gauge, per-model metrics,
/// and join handle.
struct WorkerHandle {
    tx: Option<mpsc::Sender<WorkMsg>>,
    /// Requests dispatched but not yet answered (least-loaded gauge and
    /// admission-control bound), across all models.
    depth: Arc<AtomicUsize>,
    /// One [`Metrics`] per served model (serve-list order), under a
    /// single lock per worker.
    metrics: Arc<Mutex<Vec<Metrics>>>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Shape-and-version record of one served model — the **single home**
/// for the metadata admission control, the network front end, and the
/// sharded scatter plan all read. Populated from worker ready-reports at
/// pool startup, updated under one `RwLock` write by
/// [`Coordinator::reload`] acks (and, for `n_shards`, fixed at
/// [`Coordinator::start_sharded`]), so a width/class/generation triple
/// can never be observed half-updated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelShape {
    /// Feature width the admission gate validates rows against.
    pub n_features: usize,
    /// Class count of the served backend. Read by the network front end
    /// to answer model-shape queries without touching a worker.
    pub n_classes: usize,
    /// Hot-swap generation: 0 until the first successful
    /// [`Coordinator::reload`]; each reload *attempt* consumes the next
    /// value.
    pub generation: u64,
    /// Clause shards this model is served over — 1 in a
    /// route-to-one-worker pool, the shard count of the scatter plan in
    /// a sharded pool.
    pub n_shards: usize,
}

/// Coordinator-side state for one served model.
struct ModelEntry {
    name: String,
    /// The shape table entry (see [`ModelShape`]); reads on the submit
    /// hot path take the read lock, reloads the write lock.
    shape: RwLock<ModelShape>,
    /// Admission-time counters (width rejections, unknown-model hits
    /// resolved to this entry never happen — unknown models have no
    /// entry — and reject-new sheds). Lock-free on purpose: the
    /// fast-reject path must not serialize overloaded client threads on
    /// a mutex. Folded into [`Coordinator::metrics`] /
    /// [`Coordinator::metrics_for`] at snapshot time.
    admission_rejected: AtomicU64,
    admission_shed: AtomicU64,
    /// Reload observability (same lock-free fold-at-snapshot pattern as
    /// the admission counters): attempts started, attempts that returned
    /// an error, and payload shard-objects served from the hash cache
    /// across all workers' swaps (the delta-reload signal — see
    /// [`SwapReport`]).
    reload_attempts: AtomicU64,
    reload_failures: AtomicU64,
    reload_shards_reused: AtomicU64,
}

impl ModelEntry {
    /// Point-in-time copy of the shape entry (poisoning is impossible:
    /// no panic can happen under the shape lock, but recover anyway).
    fn shape(&self) -> ModelShape {
        *self.shape.read().unwrap_or_else(|e| e.into_inner())
    }
}

/// Process-wide pool-instance counter behind [`ModelId`]'s pool tag.
static POOL_TAG: AtomicU64 = AtomicU64::new(0);

/// Handle to a running multi-model coordinator pool.
pub struct Coordinator {
    workers: Vec<WorkerHandle>,
    next_id: AtomicU64,
    rr: AtomicUsize,
    dispatch: DispatchPolicy,
    /// This pool's [`ModelId`] tag: ids from other pools never resolve
    /// here, whatever their index.
    pool_tag: u32,
    /// Artifact root the workers opened — the target of
    /// [`Coordinator::gc_artifacts`].
    root: PathBuf,
    /// Per-model table, indexed by [`ModelId`] (serve-list order).
    models: Vec<ModelEntry>,
    queue_limit: Option<usize>,
    shed: ShedPolicy,
    /// Serializes [`Coordinator::reload`] calls: two racing reloads
    /// would interleave their per-worker control messages and could
    /// leave workers on different final backends.
    reload_lock: Mutex<()>,
    /// `Some` when this pool scatters each request across clause shards
    /// ([`Coordinator::start_sharded`]): the reduce collector's inbox
    /// and thread handle.
    sharded: Option<ShardedPlan>,
    shutdown: Arc<AtomicBool>,
}

/// Reduce side of a sharded pool: worker `w` serves clause shard `w`,
/// `submit_packed` scatters each admitted request to every worker, and
/// the collector thread merges the partials (see [`ReduceSlot`]).
struct ShardedPlan {
    n_shards: usize,
    /// The collector's inbox; dropped (set `None`) at shutdown so the
    /// collector drains and exits.
    reduce_tx: Option<mpsc::Sender<ReduceMsg>>,
    collector: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start a worker pool serving the single model `model` — the
    /// one-model convenience over [`Coordinator::start_multi`].
    pub fn start(root: PathBuf, model: &str, cfg: CoordinatorConfig) -> Result<Coordinator> {
        Self::start_multi(root, &[model], cfg)
    }

    /// Start a worker pool serving every model in `models` over the
    /// artifacts at `root`.
    ///
    /// Each worker thread constructs its own [`ModelRegistry`] and one
    /// backend per model from `cfg.backend` (PJRT backends are not
    /// `Send`; native backends are, but per-worker ownership keeps the
    /// paths uniform — and gives time-domain backends one
    /// independently-seeded simulated die per worker via
    /// [`BackendSpec::for_worker`]). Startup errors from every worker
    /// are reported back before `start_multi` returns — an unknown model
    /// name fails here, not at first request; on success each worker
    /// also reports the models' feature widths, which populate the
    /// per-model width table behind the admission gate in
    /// [`Coordinator::submit`].
    pub fn start_multi(
        root: PathBuf,
        models: &[&str],
        cfg: CoordinatorConfig,
    ) -> Result<Coordinator> {
        Self::start_inner(root, models, cfg, None)
    }

    /// Start a scatter/reduce pool serving `model` across `n_shards`
    /// clause shards — **one model, many workers**: worker `w` opens a
    /// `BackendSpec::Sharded` backend pinned to shard `w` (a contiguous
    /// slice of the model's clause-index arena, see `tm::ClauseShard`),
    /// every admitted request is scattered to all workers, and a reduce
    /// collector merges the partial class sums into one reply per
    /// request, bit-exact with the unsharded forward pass (merged
    /// argmax, ties → lowest class). Latency scales with the *largest
    /// shard* instead of the whole clause count, which is the point.
    ///
    /// `cfg.backend` chooses the substrate: `Native` (manifest) and
    /// `InMemory`/`InMemorySet` shard the native evaluator;
    /// `TimeDomain { arch, .. }` gives every shard its own simulated die
    /// of `arch`, so `ReplayPolicy` replay yields per-shard decision
    /// latencies the reduce maxes into a critical-path estimate. An
    /// explicit `Sharded` spec is re-pinned to `n_shards`.
    /// `cfg.n_workers` is overridden to `n_shards` (one worker per
    /// shard); `cfg.dispatch` is moot (every request visits every
    /// worker). The fail-soft contract is unchanged: exactly one
    /// [`Reply`] per submit, with shard errors, mixed mid-reload
    /// generations, and straggler-deadline expiries
    /// ([`CoordinatorConfig::straggler_deadline`]) all surfacing as
    /// typed errors.
    pub fn start_sharded(
        root: PathBuf,
        model: &str,
        n_shards: usize,
        mut cfg: CoordinatorConfig,
    ) -> Result<Coordinator> {
        ensure!(n_shards >= 1, "sharded pool needs at least one shard");
        cfg.n_workers = n_shards;
        cfg.backend = match cfg.backend {
            BackendSpec::Sharded { model, hw, .. } => {
                BackendSpec::Sharded { model, shard: ShardSpec::first_of(n_shards), hw }
            }
            BackendSpec::Native => {
                BackendSpec::Sharded { model: None, shard: ShardSpec::first_of(n_shards), hw: None }
            }
            BackendSpec::InMemory(m) => BackendSpec::Sharded {
                model: Some(m),
                shard: ShardSpec::first_of(n_shards),
                hw: None,
            },
            BackendSpec::InMemorySet(set) => {
                let m = set.iter().find(|m| m.name == model).cloned().ok_or_else(|| {
                    anyhow!("in-memory set does not hold model {model:?}")
                })?;
                BackendSpec::Sharded {
                    model: Some(m),
                    shard: ShardSpec::first_of(n_shards),
                    hw: None,
                }
            }
            BackendSpec::TimeDomain { arch, model, .. } => BackendSpec::Sharded {
                model,
                shard: ShardSpec::first_of(n_shards),
                hw: Some(arch),
            },
            other => anyhow::bail!("backend {:?} cannot serve clause shards", other.name()),
        };
        Self::start_inner(root, &[model], cfg, Some(n_shards))
    }

    fn start_inner(
        root: PathBuf,
        models: &[&str],
        cfg: CoordinatorConfig,
        sharded: Option<usize>,
    ) -> Result<Coordinator> {
        ensure!(cfg.n_workers >= 1, "coordinator needs at least one worker");
        ensure!(!models.is_empty(), "coordinator needs at least one model");
        ensure!(cfg.batcher.max_batch >= 1, "batcher max_batch must be ≥ 1");
        for (i, m) in models.iter().enumerate() {
            ensure!(!models[..i].contains(m), "duplicate model {m:?} in the serve list");
        }
        let names: Arc<Vec<String>> = Arc::new(models.iter().map(|s| s.to_string()).collect());
        let shutdown = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Vec<(usize, usize)>>>();
        let mut workers = Vec::with_capacity(cfg.n_workers);
        for w in 0..cfg.n_workers {
            let (tx, rx) = mpsc::channel::<WorkMsg>();
            let depth = Arc::new(AtomicUsize::new(0));
            let metrics = Arc::new(Mutex::new(vec![Metrics::default(); names.len()]));
            let join = {
                let root = root.clone();
                let names = names.clone();
                let spec = cfg.backend.clone().for_worker(w);
                let batcher = cfg.batcher;
                let queue_limit = cfg.queue_limit;
                let shed = cfg.shed;
                let replay = cfg.replay;
                let depth = depth.clone();
                let metrics = metrics.clone();
                let shutdown = shutdown.clone();
                let ready_tx = ready_tx.clone();
                std::thread::Builder::new()
                    .name(format!("tdpc-worker-{w}"))
                    .spawn(move || {
                        // Build the registry and every model's backend
                        // inside the owning thread.
                        let (registry, slots, shapes) =
                            match open_worker_models(&root, spec, &names) {
                                Ok(opened) => opened,
                                Err(e) => {
                                    let _ = ready_tx.send(Err(e));
                                    return;
                                }
                            };
                        let _ = ready_tx.send(Ok(shapes));
                        drop(ready_tx);
                        Worker {
                            index: w,
                            registry,
                            slots,
                            pending: names.iter().map(|_| Vec::new()).collect(),
                            states: Vec::with_capacity(names.len()),
                            cfg: batcher,
                            queue_limit,
                            shed,
                            replay,
                            metrics,
                            depth,
                            shutdown,
                            replay_seq: 0,
                        }
                        .run(rx)
                    })?
            };
            workers.push(WorkerHandle { tx: Some(tx), depth, metrics, join: Some(join) });
        }
        drop(ready_tx);

        // Collect one readiness report per worker before declaring the
        // pool up; the first successful report populates the shape table.
        let mut startup_err: Option<anyhow::Error> = None;
        let mut shapes: Option<Vec<(usize, usize)>> = None;
        for _ in 0..cfg.n_workers {
            let report = ready_rx
                .recv()
                .unwrap_or_else(|_| Err(anyhow!("coordinator worker died during startup")));
            match report {
                Ok(ws) => {
                    shapes.get_or_insert(ws);
                }
                Err(e) => {
                    startup_err.get_or_insert(e);
                }
            }
        }
        let shapes = match (startup_err, shapes) {
            (None, Some(ws)) => ws,
            (err, _) => {
                shutdown.store(true, Ordering::SeqCst);
                for h in &mut workers {
                    h.tx = None;
                }
                for h in &mut workers {
                    if let Some(j) = h.join.take() {
                        let _ = j.join();
                    }
                }
                let e = err.unwrap_or_else(|| anyhow!("no coordinator worker reported ready"));
                return Err(e).context("coordinator startup failed");
            }
        };

        let entries = names
            .iter()
            .zip(&shapes)
            .map(|(name, &(width, classes))| ModelEntry {
                name: name.clone(),
                shape: RwLock::new(ModelShape {
                    n_features: width,
                    n_classes: classes,
                    generation: 0,
                    n_shards: sharded.unwrap_or(1),
                }),
                admission_rejected: AtomicU64::new(0),
                admission_shed: AtomicU64::new(0),
                reload_attempts: AtomicU64::new(0),
                reload_failures: AtomicU64::new(0),
                reload_shards_reused: AtomicU64::new(0),
            })
            .collect();

        let plan = match sharded {
            None => None,
            Some(n_shards) => {
                let (reduce_tx, reduce_rx) = mpsc::channel::<ReduceMsg>();
                let deadline = cfg.straggler_deadline;
                let collector = std::thread::Builder::new()
                    .name("tdpc-reduce".to_string())
                    .spawn(move || run_reduce(reduce_rx, n_shards, deadline))?;
                Some(ShardedPlan {
                    n_shards,
                    reduce_tx: Some(reduce_tx),
                    collector: Some(collector),
                })
            }
        };

        Ok(Coordinator {
            workers,
            next_id: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            dispatch: cfg.dispatch,
            pool_tag: POOL_TAG.fetch_add(1, Ordering::Relaxed) as u32,
            root,
            models: entries,
            queue_limit: cfg.queue_limit,
            shed: cfg.shed,
            reload_lock: Mutex::new(()),
            sharded: plan,
            shutdown,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Resolve a model name to this pool's interned [`ModelId`] (`None`
    /// if the pool does not serve it). Resolve once, submit many.
    pub fn model_id(&self, name: &str) -> Option<ModelId> {
        self.models
            .iter()
            .position(|m| m.name == name)
            .map(|i| ModelId::new(self.pool_tag, i as u32))
    }

    /// The served models, in [`ModelId`] order.
    pub fn served_models(&self) -> impl Iterator<Item = (ModelId, &str)> + '_ {
        self.models
            .iter()
            .enumerate()
            .map(|(i, e)| (ModelId::new(self.pool_tag, i as u32), e.name.as_str()))
    }

    /// This pool's entry for `model`, `None` for a foreign or
    /// out-of-range id — the single resolution point every model-keyed
    /// API goes through.
    fn entry(&self, model: ModelId) -> Option<&ModelEntry> {
        if model.pool != self.pool_tag {
            return None;
        }
        self.models.get(model.index())
    }

    /// The full shape table entry of one served model — width, class
    /// count, hot-swap generation, and shard count in one atomically-
    /// consistent [`ModelShape`]. `None` for a foreign or unknown id.
    /// The thin accessors below are views of this.
    pub fn shape_for(&self, model: ModelId) -> Option<ModelShape> {
        Some(self.entry(model)?.shape())
    }

    /// Feature width of one served model — the width
    /// [`Coordinator::submit`] admits that model's rows against. `None`
    /// for a foreign or unknown id.
    pub fn n_features_for(&self, model: ModelId) -> Option<usize> {
        Some(self.shape_for(model)?.n_features)
    }

    /// Class count of one served model (`None` for a foreign or unknown
    /// id). Tracked alongside the width table, so model-shape queries —
    /// e.g. the network front end's `ModelQuery` — never touch a worker.
    pub fn n_classes_for(&self, model: ModelId) -> Option<usize> {
        Some(self.shape_for(model)?.n_classes)
    }

    /// Current hot-swap generation of one served model: 0 until its
    /// first successful [`Coordinator::reload`]. `None` for a foreign or
    /// unknown id.
    pub fn generation_for(&self, model: ModelId) -> Option<u64> {
        Some(self.shape_for(model)?.generation)
    }

    /// Clause shards this pool serves each model over: 1 for a
    /// route-to-one-worker pool, the scatter width for a
    /// [`Coordinator::start_sharded`] pool.
    pub fn n_shards(&self) -> usize {
        self.sharded.as_ref().map_or(1, |p| p.n_shards)
    }

    /// The pool's per-worker queue bound, if one is configured.
    pub fn queue_limit(&self) -> Option<usize> {
        self.queue_limit
    }

    /// Total in-flight load across all workers (dispatched but not yet
    /// answered) — a point-in-time gauge, approximate under concurrency.
    pub fn total_depth(&self) -> usize {
        self.workers.iter().map(|w| w.depth.load(Ordering::Relaxed)).sum()
    }

    /// Whether every worker is at (or over) the configured queue limit —
    /// the condition under which a reject-new submit would shed. Always
    /// `false` without a queue limit. The network listener reads this at
    /// accept time to refuse whole connections while the pool is
    /// saturated, shedding overload at the socket instead of
    /// accumulating per-request errors in RAM.
    pub fn is_saturated(&self) -> bool {
        match self.queue_limit {
            None => false,
            // A scatter needs room on *every* shard, so one full shard
            // queue already sheds — `any`, not `all`.
            Some(limit) if self.sharded.is_some() => self
                .workers
                .iter()
                .any(|w| w.depth.load(Ordering::Relaxed) >= limit),
            Some(limit) => self
                .workers
                .iter()
                .all(|w| w.depth.load(Ordering::Relaxed) >= limit),
        }
    }

    fn pick_worker(&self) -> usize {
        match self.dispatch {
            DispatchPolicy::RoundRobin => {
                self.rr.fetch_add(1, Ordering::Relaxed) % self.workers.len()
            }
            DispatchPolicy::LeastLoaded => self
                .workers
                .iter()
                .enumerate()
                .min_by_key(|(_, w)| w.depth.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    /// Submit asynchronously for one model. Exactly one [`Reply`] is
    /// delivered on `reply` for every call: a response, or a typed
    /// [`InferError`] when the request is refused at admission (unknown
    /// model, width gate, bounded queue), shed, or fails in the backend.
    /// Returns the request id.
    ///
    /// The Boolean feature row is validated against *its model's* width
    /// *here*, at ingestion — a malformed row is answered with
    /// [`InferError::WidthMismatch`] before it can join (and poison) a
    /// batch — then bit-packed once, so everything downstream (dispatch,
    /// per-model batching, the backend forward pass) works on `u64`
    /// words.
    pub fn submit(&self, model: ModelId, features: &[bool], reply: mpsc::Sender<Reply>) -> u64 {
        self.submit_packed(model, BitVec64::from_bools(features), reply)
    }

    /// [`Coordinator::submit`] for callers that already hold the packed
    /// form — the network front end decodes wire frames straight into
    /// [`BitVec64`] words, so this path never materializes a bool slice.
    /// Same admission gates and fail-soft contract; the width check runs
    /// against the packed row's logical length.
    pub fn submit_packed(
        &self,
        model: ModelId,
        features: BitVec64,
        reply: mpsc::Sender<Reply>,
    ) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let Some(entry) = self.entry(model) else {
            let _ = reply.send(Err(InferError::UnknownModel { name: model.to_string() }));
            return id;
        };
        let expected = entry.shape().n_features;
        if features.len() != expected {
            entry.admission_rejected.fetch_add(1, Ordering::Relaxed);
            let _ = reply.send(Err(InferError::WidthMismatch {
                got: features.len(),
                expected,
            }));
            return id;
        }
        if let Some(plan) = &self.sharded {
            return self.scatter(plan, entry, id, model, features, reply);
        }
        let mut w = self.pick_worker();
        if let (ShedPolicy::RejectNew, Some(limit)) = (self.shed, self.queue_limit) {
            if self.workers[w].depth.load(Ordering::Relaxed) >= limit {
                // The dispatcher's pick is full. Spill to the least-loaded
                // worker with room before shedding, so a pool with idle
                // capacity never rejects (round-robin can land on a full
                // worker while its neighbors sit empty).
                let depths = self.workers.iter().map(|h| h.depth.load(Ordering::Relaxed));
                match spill_target(depths, limit) {
                    Some(alt) => w = alt,
                    None => {
                        // An admission-time event: counted lock-free on
                        // the coordinator (per model), keeping overloaded
                        // client threads off every metrics mutex.
                        let depth = self.workers[w].depth.load(Ordering::Relaxed);
                        entry.admission_shed.fetch_add(1, Ordering::Relaxed);
                        let _ = reply.send(Err(InferError::QueueFull { depth, limit }));
                        return id;
                    }
                }
            }
        }
        let worker = &self.workers[w];
        let Some(tx) = worker.tx.as_ref() else {
            let _ = reply.send(Err(InferError::ShuttingDown));
            return id;
        };
        worker.depth.fetch_add(1, Ordering::Relaxed);
        let item = WorkItem {
            id,
            req: InferRequest {
                model,
                features,
                reply: ReplySink::Caller(reply),
                submitted: Instant::now(),
            },
        };
        if let Err(mpsc::SendError(msg)) = tx.send(WorkMsg::Infer(item)) {
            // The worker died; the item comes back, so its caller still
            // gets a typed answer instead of a dead channel.
            worker.depth.fetch_sub(1, Ordering::Relaxed);
            if let WorkMsg::Infer(item) = msg {
                item.req.reply.deliver(item.id, Err(InferError::ShuttingDown));
            }
        }
        id
    }

    /// Scatter one admitted request to every shard worker and point the
    /// shards' answers at the reduce collector.
    ///
    /// Admission against the bounded queue is all-or-nothing: a scatter
    /// must land on every shard, so under reject-new *any* full shard
    /// queue sheds the request — there is no other worker to spill to,
    /// because each worker is a distinct shard, not spare capacity.
    /// Once the reduce slot is registered it owns the exactly-one-reply
    /// contract: every failure below is delivered as a partial error,
    /// which finalizes the slot.
    fn scatter(
        &self,
        plan: &ShardedPlan,
        entry: &ModelEntry,
        id: u64,
        model: ModelId,
        features: BitVec64,
        reply: mpsc::Sender<Reply>,
    ) -> u64 {
        if let (ShedPolicy::RejectNew, Some(limit)) = (self.shed, self.queue_limit) {
            let full = self
                .workers
                .iter()
                .map(|h| h.depth.load(Ordering::Relaxed))
                .find(|&d| d >= limit);
            if let Some(depth) = full {
                entry.admission_shed.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Err(InferError::QueueFull { depth, limit }));
                return id;
            }
        }
        let Some(reduce_tx) = plan.reduce_tx.as_ref() else {
            let _ = reply.send(Err(InferError::ShuttingDown));
            return id;
        };
        let submitted = Instant::now();
        let register = ReduceMsg::Register { id, model, caller: reply.clone(), submitted };
        if reduce_tx.send(register).is_err() {
            let _ = reply.send(Err(InferError::ShuttingDown));
            return id;
        }
        for worker in &self.workers {
            let Some(tx) = worker.tx.as_ref() else {
                let _ = reduce_tx
                    .send(ReduceMsg::Partial { id, reply: Err(InferError::ShuttingDown) });
                continue;
            };
            worker.depth.fetch_add(1, Ordering::Relaxed);
            let item = WorkItem {
                id,
                req: InferRequest {
                    model,
                    features: features.clone(),
                    reply: ReplySink::Reduce(reduce_tx.clone()),
                    submitted,
                },
            };
            if let Err(mpsc::SendError(msg)) = tx.send(WorkMsg::Infer(item)) {
                worker.depth.fetch_sub(1, Ordering::Relaxed);
                if let WorkMsg::Infer(item) = msg {
                    item.req.reply.deliver(item.id, Err(InferError::ShuttingDown));
                }
            }
        }
        id
    }

    /// [`Coordinator::submit`] with per-call name resolution: an
    /// unregistered name is answered with a typed
    /// [`InferError::UnknownModel`] on the reply channel (still exactly
    /// one [`Reply`] per call). Hot paths should resolve once via
    /// [`Coordinator::model_id`] and use `submit`.
    pub fn submit_named(&self, model: &str, features: &[bool], reply: mpsc::Sender<Reply>) -> u64 {
        match self.model_id(model) {
            Some(mid) => self.submit(mid, features, reply),
            None => {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Err(InferError::UnknownModel { name: model.to_string() }));
                id
            }
        }
    }

    /// [`Coordinator::submit_packed`] with per-call name resolution —
    /// the network request path: an unregistered name is answered with a
    /// typed [`InferError::UnknownModel`] on the reply channel (still
    /// exactly one [`Reply`] per call).
    pub fn submit_packed_named(
        &self,
        model: &str,
        features: BitVec64,
        reply: mpsc::Sender<Reply>,
    ) -> u64 {
        match self.model_id(model) {
            Some(mid) => self.submit_packed(mid, features, reply),
            None => {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Err(InferError::UnknownModel { name: model.to_string() }));
                id
            }
        }
    }

    /// Convenience blocking call. Rejected, shed, and backend-failed
    /// requests surface as a typed [`InferError`] (recoverable via
    /// `err.downcast_ref::<InferError>()`), never a bare closed-channel
    /// error.
    pub fn infer_blocking(&self, model: ModelId, features: &[bool]) -> Result<InferResponse> {
        let (tx, rx) = mpsc::channel();
        self.submit(model, features, tx);
        await_reply(&rx).map_err(anyhow::Error::from)
    }

    /// Hot-swap one model: re-open its artifact in every worker while
    /// the pool keeps serving, losing zero requests.
    ///
    /// The model's generation counter is bumped, then a generation-
    /// stamped control message is enqueued on every worker's ordered
    /// channel. Each worker, on reaching it, (1) flushes the rows it
    /// already holds for that model against the old backend — rows
    /// submitted before `reload` drain against the generation they saw —
    /// then (2) invalidates the model in its [`ModelRegistry`] and
    /// re-opens it, so the artifact (and its manifest) are re-read from
    /// disk, and (3) serves every subsequent row from the new backend,
    /// stamping replies with the new generation. Blocks until every
    /// worker has swapped (or failed).
    ///
    /// Fail-soft: a worker whose re-open fails (missing/corrupt new
    /// artifact) keeps serving the previous generation and this call
    /// returns its error — no worker ever serves from a half-loaded
    /// model, and no prediction is ever wrong. On a *partial* failure
    /// (some workers swapped, some refused) the pool serves mixed
    /// generations until a retry succeeds — observable per reply via
    /// [`InferResponse::generation`]; if the retrain also changed the
    /// feature width, rows meeting the wrong-width side are answered
    /// with a typed `WidthMismatch` by the worker-side assembly guard
    /// (the admission width table commits only on full success), so a
    /// failed width-changing swap degrades to typed errors, not silent
    /// misprediction — retry `reload` to converge. A failed attempt
    /// still consumes a generation number. Reloads are serialized
    /// internally.
    pub fn reload(&self, model: ModelId) -> Result<()> {
        let entry = self
            .entry(model)
            .ok_or_else(|| anyhow!("{model} is not served by this pool"))?;
        let _swap = self.reload_lock.lock().unwrap();
        entry.reload_attempts.fetch_add(1, Ordering::Relaxed);
        let generation = {
            let mut shape = entry.shape.write().unwrap_or_else(|e| e.into_inner());
            shape.generation += 1;
            shape.generation
        };
        let (ack_tx, ack_rx) = mpsc::channel::<ReloadReport>();
        let mut sent = 0usize;
        for wk in &self.workers {
            if let Some(tx) = wk.tx.as_ref() {
                let msg =
                    WorkMsg::Reload { model_ix: model.index(), generation, ack: ack_tx.clone() };
                if tx.send(msg).is_ok() {
                    sent += 1;
                }
            }
        }
        drop(ack_tx);
        ensure!(sent == self.workers.len(), "coordinator is shutting down");
        let mut new_shape: Option<(usize, usize)> = None;
        let mut first_err: Option<anyhow::Error> = None;
        let mut shards_reused = 0u64;
        let mut shards_opened = 0u64;
        for _ in 0..sent {
            match ack_rx.recv() {
                Ok(ReloadReport { result: Ok(rep), .. }) => {
                    new_shape.get_or_insert((rep.n_features, rep.n_classes));
                    shards_reused += rep.shards_reused;
                    shards_opened += rep.shards_opened;
                }
                Ok(ReloadReport { worker, result: Err(e) }) => {
                    first_err
                        .get_or_insert(e.context(format!("worker {worker} failed to swap")));
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow!("a worker died during the reload"));
                }
            }
        }
        // Workers that *did* swap reused what they reused even when a
        // sibling failed — record the delta before deciding the outcome.
        entry.reload_shards_reused.fetch_add(shards_reused, Ordering::Relaxed);
        log::debug!(
            "reload {:?} gen {generation}: {shards_opened} payload objects opened, \
             {shards_reused} reused across {sent} workers",
            entry.name
        );
        if let Some(e) = first_err {
            entry.reload_failures.fetch_add(1, Ordering::Relaxed);
            return Err(e).with_context(|| {
                format!(
                    "reloading model {:?} (failed workers keep serving the previous generation)",
                    entry.name
                )
            });
        }
        if let Some((width, classes)) = new_shape {
            // One write commits the whole shape: a reader can never see
            // the new width with the old class count.
            let mut shape = entry.shape.write().unwrap_or_else(|e| e.into_inner());
            shape.n_features = width;
            shape.n_classes = classes;
        }
        Ok(())
    }

    /// Garbage-collect the artifact tree this pool serves from: delete
    /// (or with `dry_run`, just count) payload objects referenced by
    /// neither the current manifest nor any object still pinned by a
    /// live payload cache — i.e. objects only superseded generations
    /// point at. Holding [`Coordinator::reload`]'s lock for the duration
    /// means no worker can be mid-swap while the sweep runs, so an
    /// object a worker is about to open is either manifest-referenced
    /// (kept as live) or cache-pinned (kept as pinned) — never deleted
    /// out from under an in-flight open. v1 trees have no object store
    /// and return an error, as does [`crate::tm::artifact::gc`] itself.
    pub fn gc_artifacts(&self, dry_run: bool) -> Result<crate::tm::artifact::GcReport> {
        let _swap = self.reload_lock.lock().unwrap();
        crate::tm::artifact::gc(&self.root, dry_run)
    }

    /// Aggregated metrics across all workers and models plus
    /// admission-time events (latency histograms merge, counters sum).
    /// Admission-time events — unknown-model/width rejections and
    /// reject-new sheds — happen before any worker is involved and are
    /// counted lock-free on the coordinator, so they appear in this
    /// aggregate (and in [`Coordinator::metrics_for`]) but not in
    /// [`Coordinator::worker_metrics`]; drop-oldest sheds and batch
    /// failures are worker-side and appear in both. Per-model snapshots
    /// sum exactly to this aggregate: every event is recorded under the
    /// model it belongs to, and histogram merges are bucket-wise.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut agg = Metrics::default();
        for w in &self.workers {
            for m in w.metrics.lock().unwrap().iter() {
                agg.merge(m);
            }
        }
        for e in &self.models {
            agg.record_rejected(e.admission_rejected.load(Ordering::Relaxed));
            agg.record_shed(e.admission_shed.load(Ordering::Relaxed));
            agg.record_reload(
                e.reload_attempts.load(Ordering::Relaxed),
                e.reload_failures.load(Ordering::Relaxed),
                e.reload_shards_reused.load(Ordering::Relaxed),
            );
        }
        agg.snapshot()
    }

    /// One model's metrics, merged across every worker (its share of the
    /// pool aggregate: same histograms and counters, restricted to this
    /// tenant — so per-model p50/p99 and fail-soft counters are
    /// observable independently). `None` for an unknown id.
    pub fn metrics_for(&self, model: ModelId) -> Option<MetricsSnapshot> {
        let entry = self.entry(model)?;
        let mut agg = Metrics::default();
        for w in &self.workers {
            agg.merge(&w.metrics.lock().unwrap()[model.index()]);
        }
        agg.record_rejected(entry.admission_rejected.load(Ordering::Relaxed));
        agg.record_shed(entry.admission_shed.load(Ordering::Relaxed));
        agg.record_reload(
            entry.reload_attempts.load(Ordering::Relaxed),
            entry.reload_failures.load(Ordering::Relaxed),
            entry.reload_shards_reused.load(Ordering::Relaxed),
        );
        Some(agg.snapshot())
    }

    /// Per-worker metrics snapshots (each worker's models merged), in
    /// worker-index order.
    pub fn worker_metrics(&self) -> Vec<MetricsSnapshot> {
        self.workers
            .iter()
            .map(|w| {
                let per_model = w.metrics.lock().unwrap();
                let mut agg = Metrics::default();
                for m in per_model.iter() {
                    agg.merge(m);
                }
                agg.snapshot()
            })
            .collect()
    }

    /// Stop every worker after draining all queued requests.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Drop all senders first so every worker sees Disconnected and
        // flushes its pending queues, then join.
        for w in &mut self.workers {
            w.tx = None;
        }
        for w in &mut self.workers {
            if let Some(h) = w.join.take() {
                let _ = h.join();
            }
        }
        // Workers are drained: every shard partial they will ever
        // produce is already in the reduce channel. Dropping the
        // coordinator's sender disconnects the collector *after* it
        // drains that backlog; slots still incomplete then can never
        // complete and are answered with a typed shutdown error.
        if let Some(plan) = &mut self.sharded {
            plan.reduce_tx = None;
            if let Some(h) = plan.collector.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Wait for the single [`Reply`] a submit guarantees. A closed channel —
/// possible only if the pool is torn down around the caller — degrades
/// to a typed [`InferError::ShuttingDown`] instead of a panic or a bare
/// `RecvError`, keeping the fail-soft contract airtight for every
/// consumer. This is the one reply-wait implementation shared by
/// [`Coordinator::infer_blocking`] and the network connection handler
/// (`server::conn`).
pub fn await_reply(rx: &mpsc::Receiver<Reply>) -> Reply {
    rx.recv().unwrap_or(Err(InferError::ShuttingDown))
}

/// Accumulator for one scattered request, keyed by request id in the
/// reduce collector's map. Absorbs shard partials until the request is
/// *decided*: all `n_shards` partials in (→ merged success), any shard's
/// typed error (→ that error, first wins), shard generations disagreeing
/// mid-reload (→ typed `BackendFailed`: merging sums computed by two
/// different models would be a silent misprediction), or the straggler
/// deadline passing (→ typed `BackendFailed` naming the missing shards).
/// Pure accumulation logic — unit-tested directly, below.
struct ReduceSlot {
    model: ModelId,
    caller: mpsc::Sender<Reply>,
    submitted: Instant,
    /// Which shards have answered (index == worker == shard).
    seen: Vec<bool>,
    parts: usize,
    /// Element-wise sum of the shards' partial class sums.
    sums: Vec<i32>,
    /// Generation of the first partial; all others must match.
    generation: Option<u64>,
    /// Max per-shard replay decision latency — the plan's critical-path
    /// estimate (votes merge after the slowest shard's race) — and the
    /// shard that set it.
    hw_max: Option<(Ps, usize)>,
    /// Largest per-shard batch this request rode in.
    batch_max: usize,
    /// Shard of the most recent partial (the wall-clock critical path
    /// when no shard replayed hardware).
    last_worker: usize,
}

impl ReduceSlot {
    fn new(model: ModelId, caller: mpsc::Sender<Reply>, submitted: Instant, n_shards: usize) -> ReduceSlot {
        ReduceSlot {
            model,
            caller,
            submitted,
            seen: vec![false; n_shards],
            parts: 0,
            sums: Vec::new(),
            generation: None,
            hw_max: None,
            batch_max: 0,
            last_worker: 0,
        }
    }

    /// Absorb one shard's reply. `Some(reply)` means the request is
    /// decided: deliver it and drop the slot. `None` means more shards
    /// are still owed.
    fn absorb(&mut self, id: u64, reply: Reply) -> Option<Reply> {
        let resp = match reply {
            Ok(resp) => resp,
            // Fail fast on the first shard error: the merged answer is
            // already unreachable, and waiting for the rest only delays
            // the caller.
            Err(e) => return Some(Err(e)),
        };
        let shard = resp.worker;
        if shard >= self.seen.len() || self.seen[shard] {
            return Some(Err(InferError::BackendFailed(format!(
                "reduce protocol violation: duplicate or out-of-range shard {shard}"
            ))));
        }
        match self.generation {
            None => self.generation = Some(resp.generation),
            Some(g) if g != resp.generation => {
                return Some(Err(InferError::BackendFailed(format!(
                    "shards answered from mixed hot-swap generations ({g} and {}) \
                     mid-reload; retry",
                    resp.generation
                ))));
            }
            Some(_) => {}
        }
        if self.sums.is_empty() {
            self.sums = resp.sums;
        } else if self.sums.len() != resp.sums.len() {
            return Some(Err(InferError::BackendFailed(format!(
                "shard {shard} answered {} class sums where {} were expected",
                resp.sums.len(),
                self.sums.len()
            ))));
        } else {
            for (acc, part) in self.sums.iter_mut().zip(&resp.sums) {
                *acc += part;
            }
        }
        if let Some(ps) = resp.hw_decision_latency {
            if self.hw_max.map_or(true, |(m, _)| ps > m) {
                self.hw_max = Some((ps, shard));
            }
        }
        self.batch_max = self.batch_max.max(resp.batch_size);
        self.last_worker = shard;
        self.seen[shard] = true;
        self.parts += 1;
        (self.parts == self.seen.len()).then(|| Ok(self.finish(id)))
    }

    /// Merge the complete set of partials into the final response:
    /// re-argmax over the summed class sums (ties → lowest class,
    /// matching the unsharded forward pass), max replay latency as the
    /// critical path, `worker` = the critical shard.
    fn finish(&self, id: u64) -> InferResponse {
        let mut pred = 0usize;
        for (k, &s) in self.sums.iter().enumerate() {
            if s > self.sums[pred] {
                pred = k;
            }
        }
        InferResponse {
            request_id: id,
            model: self.model,
            generation: self.generation.unwrap_or(0),
            pred,
            sums: self.sums.clone(),
            hw_decision_latency: self.hw_max.map(|(ps, _)| ps),
            // Per-shard hardware winners are shard-local argmaxes; they
            // do not compose into a whole-model winner, so the merged
            // reply reports none.
            hw_winner: None,
            service_latency_us: self.submitted.elapsed().as_secs_f64() * 1e6,
            batch_size: self.batch_max,
            worker: self.hw_max.map_or(self.last_worker, |(_, w)| w),
        }
    }

    fn expired(&self, deadline: Duration) -> bool {
        self.submitted.elapsed() >= deadline
    }

    /// The typed answer for a slot whose deadline passed with shards
    /// still owed.
    fn straggler_error(&self, deadline: Duration) -> Reply {
        let missing: Vec<usize> = self
            .seen
            .iter()
            .enumerate()
            .filter(|(_, seen)| !**seen)
            .map(|(i, _)| i)
            .collect();
        Err(InferError::BackendFailed(format!(
            "straggler deadline ({deadline:?}) passed with shard(s) {missing:?} unanswered \
             ({}/{} partials in)",
            self.parts,
            self.seen.len()
        )))
    }
}

/// The reduce collector of a sharded pool: owns the request-id → slot
/// map, finalizes each scattered request exactly once (all partials in /
/// first shard error / mixed generations / straggler deadline), and
/// sweeps for stragglers every 50 ms even when the channel is quiet.
/// When every sender is gone (workers joined, coordinator handle
/// dropped) it drains the backlog, answers the undecidable remainder
/// with a typed shutdown error, and exits.
fn run_reduce(rx: mpsc::Receiver<ReduceMsg>, n_shards: usize, deadline: Duration) {
    const SWEEP_EVERY: Duration = Duration::from_millis(50);
    let mut slots: HashMap<u64, ReduceSlot> = HashMap::new();
    let mut last_sweep = Instant::now();
    loop {
        match rx.recv_timeout(SWEEP_EVERY) {
            Ok(ReduceMsg::Register { id, model, caller, submitted }) => {
                slots.insert(id, ReduceSlot::new(model, caller, submitted, n_shards));
            }
            Ok(ReduceMsg::Partial { id, reply }) => {
                // A partial for an already-decided request (post-error
                // shard, late straggler) finds no slot and is dropped:
                // its caller was answered long ago.
                let decided = slots.get_mut(&id).and_then(|slot| slot.absorb(id, reply));
                if let Some(final_reply) = decided {
                    let slot = slots.remove(&id).expect("slot just absorbed");
                    let _ = slot.caller.send(final_reply);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        if last_sweep.elapsed() >= SWEEP_EVERY {
            let expired: Vec<u64> = slots
                .iter()
                .filter(|(_, slot)| slot.expired(deadline))
                .map(|(&id, _)| id)
                .collect();
            for id in expired {
                let slot = slots.remove(&id).expect("expired id came from the map");
                let _ = slot.caller.send(slot.straggler_error(deadline));
            }
            last_sweep = Instant::now();
        }
    }
    for (_, slot) in slots {
        let _ = slot.caller.send(Err(InferError::ShuttingDown));
    }
}

/// Open one worker's registry and a backend per served model, reporting
/// the models' shapes (feature width, class count) in serve-list order.
/// Runs inside the worker thread; any failure (missing artifact, unknown
/// model name) aborts pool startup.
fn open_worker_models(
    root: &Path,
    spec: BackendSpec,
    names: &[String],
) -> Result<(ModelRegistry, Vec<ModelSlot>, Vec<(usize, usize)>)> {
    let registry = ModelRegistry::open_with(root, spec)?;
    let mut slots = Vec::with_capacity(names.len());
    let mut shapes = Vec::with_capacity(names.len());
    for name in names {
        let backend = registry
            .backend(name)
            .with_context(|| format!("opening model {name:?}"))?;
        shapes.push((backend.n_features(), backend.n_classes()));
        slots.push(ModelSlot {
            name: name.clone(),
            generation: 0,
            backend,
            last_hot: HotLoopStats::default(),
        });
    }
    Ok((registry, slots, shapes))
}

/// Reject-new admission spill: when the dispatcher's pick is at the
/// queue limit, the least-loaded worker with room (ties → lowest index)
/// should take the request instead; `None` means the whole pool is
/// saturated and the request must be shed. Pure decision logic.
fn spill_target<I: Iterator<Item = usize>>(depths: I, limit: usize) -> Option<usize> {
    depths
        .enumerate()
        .filter(|&(_, d)| d < limit)
        .min_by_key(|&(_, d)| d)
        .map(|(i, _)| i)
}

/// The model (by slot index) with the oldest head request (ties →
/// lowest index) and a plan to flush up to `max_batch` of it — the
/// forced-flush decision used on graceful drain and post-shed overload,
/// where waiting on the age deadline would be wrong. `None` ⇔ every
/// queue is empty.
fn force_flush(pending: &[Vec<WorkItem>], max_batch: usize) -> Option<(usize, BatchPlan)> {
    pending
        .iter()
        .enumerate()
        .filter(|(_, q)| !q.is_empty())
        .min_by_key(|&(i, q)| (q[0].req.submitted, i))
        .map(|(i, q)| (i, BatchPlan { take: q.len().min(max_batch) }))
}

/// Drop-oldest shedding across a worker's per-model queues: trim the
/// *total* pending load to its freshest `limit` rows, answering each
/// evicted request with [`InferError::QueueFull`] and releasing its
/// load. Staleness is global: request ids are issued monotonically at
/// submit and each per-model queue is FIFO, so the globally stalest
/// rows are found by a heads-first merge on id. Trims by the *local*
/// queue lengths, never the global gauge: the gauge counts channel
/// backlog too, and shedding against it would evict rows the very
/// flush that follows is about to serve.
fn shed_to_limit(
    limit: usize,
    pending: &mut [Vec<WorkItem>],
    depth: &AtomicUsize,
    metrics: &Mutex<Vec<Metrics>>,
) {
    let total: usize = pending.iter().map(Vec::len).sum();
    let overflow = total.saturating_sub(limit);
    if overflow == 0 {
        return;
    }
    // Count how many to evict from each queue's stalest prefix: repeat
    // "take the smallest head id" `overflow` times (queues are FIFO in
    // id order, so prefixes are exactly the globally stalest rows).
    let mut evict = vec![0usize; pending.len()];
    for _ in 0..overflow {
        let qi = (0..pending.len())
            .filter(|&q| evict[q] < pending[q].len())
            .min_by_key(|&q| pending[q][evict[q]].id)
            .expect("overflow < total pending");
        evict[qi] += 1;
    }
    // One O(n) drain per queue, not per-item remove(0) — this runs on
    // the overload hot path against a just-drained backlog.
    let mut shed: Vec<(WorkItem, usize)> = Vec::with_capacity(overflow);
    {
        // Count before replying (metrics are complete the moment a
        // caller sees its answer), under one lock for all models.
        let mut per_model = metrics.lock().unwrap();
        for (qi, q) in pending.iter_mut().enumerate() {
            if evict[qi] == 0 {
                continue;
            }
            per_model[qi].record_shed(evict[qi] as u64);
            for item in q.drain(..evict[qi]) {
                let observed = depth.fetch_sub(1, Ordering::Relaxed);
                shed.push((item, observed));
            }
        }
    }
    for (item, observed) in shed {
        item.req.reply.deliver(item.id, Err(InferError::QueueFull { depth: observed, limit }));
    }
}

/// One worker's view of one served model: the name it re-opens under,
/// the hot-swap generation it is currently serving, and the backend
/// itself.
struct ModelSlot {
    name: String,
    generation: u64,
    backend: Arc<dyn InferenceBackend>,
    /// The backend's cumulative hot-loop counters as of the last batch —
    /// `execute_batch` diffs the backend's running totals against this
    /// to fold a per-batch telemetry delta into the worker's [`Metrics`]
    /// slot. Reset on hot-swap (a fresh backend starts its counters at
    /// zero).
    last_hot: HotLoopStats,
}

/// A worker thread: one backend per model (via its own registry), one
/// pending queue per model, one metrics slot per model, one load gauge.
struct Worker {
    index: usize,
    registry: ModelRegistry,
    slots: Vec<ModelSlot>,
    /// Pending rows, one FIFO per model (the batching key).
    pending: Vec<Vec<WorkItem>>,
    /// Scratch for [`BatcherConfig::plan_multi`] (hoisted out of the
    /// poll loop).
    states: Vec<QueueState>,
    cfg: BatcherConfig,
    queue_limit: Option<usize>,
    shed: ShedPolicy,
    replay: ReplayPolicy,
    metrics: Arc<Mutex<Vec<Metrics>>>,
    depth: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
    /// Rows this worker has served, for 1-in-N replay sampling (shared
    /// across models: sampling amortizes the *worker's* simulation
    /// budget).
    replay_seq: u64,
}

impl Worker {
    fn run(mut self, rx: mpsc::Receiver<WorkMsg>) {
        loop {
            // Collect until the batch plan says flush. The channel is
            // drained greedily before each planning decision — grouping
            // rows by model as they come out — because the deadline is
            // measured from *submission*: leaving ready work in the
            // channel would make every item individually overdue and
            // collapse batching. Control messages are handled inline, in
            // channel order (the zero-loss reload contract).
            let (model_ix, plan) = loop {
                // Bounded per planning round so a firehose of producers
                // cannot livelock the drain: once every model could fill
                // a batch, stop pulling and go plan (the channel keeps
                // the rest).
                let drain_cap = self.cfg.max_batch.saturating_mul(self.slots.len()).max(64);
                for _ in 0..drain_cap {
                    match rx.try_recv() {
                        Ok(msg) => self.handle(msg),
                        Err(_) => break,
                    }
                }
                if let (ShedPolicy::DropOldest, Some(limit)) = (self.shed, self.queue_limit) {
                    if self.depth.load(Ordering::Relaxed) > limit {
                        // Over the bound. The channel backlog has to come
                        // out either way — to be shed or served — so pull
                        // it *all* local (past the drain cap), keep the
                        // freshest `limit` rows across all models, shed
                        // the rest, and flush *now*: eviction keeps
                        // replacing the heads, so waiting on the head-age
                        // deadline would starve serving under sustained
                        // overload, and at the limit there is nothing to
                        // gain by batching longer.
                        while let Ok(msg) = rx.try_recv() {
                            self.handle(msg);
                        }
                        shed_to_limit(limit, &mut self.pending, &self.depth, &self.metrics);
                        if let Some(flush) = force_flush(&self.pending, self.cfg.max_batch) {
                            break flush;
                        }
                    }
                }
                if let Some(planned) = self.replan() {
                    break planned;
                }
                match rx.recv_timeout(self.cfg.poll_interval()) {
                    Ok(msg) => self.handle(msg),
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if self.all_empty() && self.shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        // Flush whatever is left, oldest-head model
                        // first (graceful drain; the disconnected
                        // channel returns instantly, so the remaining
                        // queues drain in consecutive iterations).
                        match force_flush(&self.pending, self.cfg.max_batch) {
                            Some(flush) => break flush,
                            None => return,
                        }
                    }
                }
            };
            self.flush(model_ix, plan.take);
        }
    }

    fn handle(&mut self, msg: WorkMsg) {
        match msg {
            WorkMsg::Infer(item) => self.pending[item.req.model.index()].push(item),
            WorkMsg::Reload { model_ix, generation, ack } => {
                let result = self.swap(model_ix, generation);
                let _ = ack.send(ReloadReport { worker: self.index, result });
            }
        }
    }

    /// Hot-swap one model slot: drain its pending rows against the old
    /// backend (they were submitted before the reload), then invalidate
    /// and re-open through the registry. On failure the slot is left
    /// untouched — the worker keeps serving the previous generation.
    ///
    /// The returned [`SwapReport`] carries the registry's payload-cache
    /// delta across the re-open: on a v2 tree, `shards_reused` counts
    /// clause-block objects served from the hash-keyed cache (unchanged
    /// content) and `shards_opened` the objects actually re-read from
    /// disk. v1 trees and in-memory specs report `(0, 0)`.
    fn swap(&mut self, ix: usize, generation: u64) -> Result<SwapReport> {
        while !self.pending[ix].is_empty() {
            let take = self.pending[ix].len().min(self.cfg.max_batch);
            self.flush(ix, take);
        }
        let name = self.slots[ix].name.clone();
        let (opened_before, reused_before) = self.registry.payload_stats();
        self.registry.invalidate(&name);
        let backend = self
            .registry
            .backend(&name)
            .with_context(|| format!("re-opening model {name:?}"))?;
        let (opened_after, reused_after) = self.registry.payload_stats();
        let report = SwapReport {
            n_features: backend.n_features(),
            n_classes: backend.n_classes(),
            shards_opened: opened_after - opened_before,
            shards_reused: reused_after - reused_before,
        };
        let slot = &mut self.slots[ix];
        slot.backend = backend;
        slot.generation = generation;
        slot.last_hot = HotLoopStats::default();
        Ok(report)
    }

    fn replan(&mut self) -> Option<(usize, BatchPlan)> {
        self.states.clear();
        self.states.extend(self.pending.iter().map(|q| QueueState {
            queued: q.len(),
            oldest: q.first().map(|w| w.req.submitted),
        }));
        self.cfg.plan_multi(&self.states)
    }

    fn all_empty(&self) -> bool {
        self.pending.iter().all(Vec::is_empty)
    }

    /// Drain up to `take` rows of one model's queue and execute them as
    /// a batch.
    fn flush(&mut self, ix: usize, take: usize) {
        let batch: Vec<WorkItem> = {
            let queue = &mut self.pending[ix];
            let n = take.min(queue.len());
            if n == 0 {
                return;
            }
            queue.drain(..n).collect()
        };
        execute_batch(
            self.index,
            ix,
            &mut self.slots[ix],
            batch,
            self.replay,
            &mut self.replay_seq,
            &self.metrics,
            &self.depth,
        );
    }
}

/// Execute one single-model batch fail-soft, delivering exactly one
/// [`Reply`] per item. Failure isolation, in order:
///
/// 1. a row that fails packed assembly (unreachable through the public
///    API — [`Coordinator::submit`] gates width per model at ingestion;
///    reachable transiently when a reload changes a model's width) is
///    answered with [`InferError::WidthMismatch`] and excluded instead
///    of poisoning its neighbors;
/// 2. a failed multi-row forward pass falls back to per-row retry, so
///    one bad row costs only itself — every healthy neighbor is still
///    served — and each caller whose row really cannot be served gets a
///    typed [`InferError::BackendFailed`];
/// 3. metrics accumulate into a local delta and fold into the worker's
///    per-model [`Metrics`] slot under one lock per batch (not one per
///    row), before any reply goes out so aggregate counters are complete
///    the moment a client has seen the last response (no settle race).
#[allow(clippy::too_many_arguments)]
fn execute_batch(
    worker: usize,
    model_ix: usize,
    slot: &mut ModelSlot,
    batch: Vec<WorkItem>,
    replay: ReplayPolicy,
    replay_seq: &mut u64,
    metrics: &Mutex<Vec<Metrics>>,
    depth: &AtomicUsize,
) {
    let backend = slot.backend.clone();
    let backend = backend.as_ref();
    let expected = backend.n_features();
    let mut rows = PackedBatch::new(expected);
    let mut items: Vec<WorkItem> = Vec::with_capacity(batch.len());
    let mut delta = Metrics::default();
    let mut outbox: Vec<(WorkItem, Reply)> = Vec::with_capacity(batch.len());
    for mut item in batch {
        let features = std::mem::take(&mut item.req.features);
        let got = features.len();
        if rows.push_bitvec(&features).is_ok() {
            items.push(item);
        } else {
            delta.record_rejected(1);
            outbox.push((item, Err(InferError::WidthMismatch { got, expected })));
        }
    }

    if !items.is_empty() {
        let n = items.len();
        let t0 = Instant::now();
        match forward_caught(backend, &rows) {
            Ok(out) => {
                delta.record_batch(n, t0.elapsed().as_secs_f64() * 1e6);
                for (i, item) in items.into_iter().enumerate() {
                    let resp =
                        make_response(worker, slot, &out, i, n, replay, replay_seq, &item);
                    delta.record(&resp);
                    outbox.push((item, Ok(resp)));
                }
            }
            Err(e) if n == 1 => {
                delta.record_failed_batch();
                log::warn!("worker {worker}: forward failed for a single-row batch: {e:#}");
                let item = items.pop().expect("n == 1");
                outbox.push((item, Err(InferError::BackendFailed(format!("{e:#}")))));
            }
            Err(e) => {
                // Fail-soft: split the batch and retry each row alone, so
                // one poisonous row costs only itself.
                delta.record_failed_batch();
                log::warn!(
                    "worker {worker}: forward failed for a {n}-row batch ({e:#}); \
                     retrying rows individually"
                );
                for (i, item) in items.into_iter().enumerate() {
                    let mut single = PackedBatch::new(expected);
                    single.push_words(rows.row(i));
                    let t1 = Instant::now();
                    match forward_caught(backend, &single) {
                        Ok(out) => {
                            delta.record_batch(1, t1.elapsed().as_secs_f64() * 1e6);
                            let resp = make_response(
                                worker, slot, &out, 0, 1, replay, replay_seq, &item,
                            );
                            delta.record(&resp);
                            outbox.push((item, Ok(resp)));
                        }
                        Err(re) => {
                            delta.record_failed_batch();
                            let err = InferError::BackendFailed(format!("{re:#}"));
                            outbox.push((item, Err(err)));
                        }
                    }
                }
            }
        }
    }

    // Hot-loop telemetry: the backend's counters run cumulatively, so
    // the per-batch contribution is the delta since the last batch this
    // slot executed.
    if let Some(now) = backend.hot_loop_stats() {
        delta.record_hot(now.delta_since(&slot.last_hot));
        slot.last_hot = now;
    }

    // One metrics lock per batch, taken before any reply goes out so
    // aggregate counters are complete the moment a client has seen the
    // last response. The delta folds into this model's slot, keeping the
    // per-model breakdown exact.
    metrics.lock().unwrap()[model_ix].merge(&delta);
    for (item, reply) in outbox {
        // Release the load gauge *before* replying so a blocking caller's
        // next submit observes the decrement (least-loaded determinism).
        depth.fetch_sub(1, Ordering::Relaxed);
        item.req.reply.deliver(item.id, reply); // receiver may have gone away
    }
}

/// Run the backend forward pass with panic containment: a panicking
/// backend becomes an ordinary error instead of an unwinding worker
/// thread. An unwind here would drop the reply sender of every queued
/// request — exactly the bare closed-channel failure the typed
/// [`Reply`] contract forbids.
fn forward_caught(backend: &dyn InferenceBackend, rows: &PackedBatch) -> Result<ForwardOutput> {
    match catch_unwind(AssertUnwindSafe(|| backend.forward(rows))) {
        Ok(res) => res,
        Err(panic) => Err(anyhow!("backend forward panicked: {}", panic_message(&panic))),
    }
}

/// Best-effort human-readable payload of a caught panic.
fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Build the reply for `row` of a forward output: replay-policy-driven
/// hardware timing, model identity and hot-swap generation from the
/// serving slot, service latency stamped at delivery time.
#[allow(clippy::too_many_arguments)]
fn make_response(
    worker: usize,
    slot: &ModelSlot,
    out: &ForwardOutput,
    row: usize,
    batch_size: usize,
    replay: ReplayPolicy,
    replay_seq: &mut u64,
    item: &WorkItem,
) -> InferResponse {
    // The replay policy is engine-agnostic: any backend carrying a
    // hardware engine answers `replay`; all others return None. Replay
    // is telemetry, so a panicking engine degrades to "no hardware
    // fields" rather than killing the worker (and every queued reply
    // sender) mid-batch.
    let backend = slot.backend.as_ref();
    let seq = *replay_seq;
    *replay_seq += 1;
    let (hw_latency, hw_winner) = if replay.take(seq) {
        match catch_unwind(AssertUnwindSafe(|| backend.replay(out, row))) {
            Ok(Some(o)) => (Some(o.decision_latency), Some(o.winner)),
            Ok(None) => (None, None),
            Err(panic) => {
                log::warn!(
                    "worker {worker}: hardware replay panicked: {}",
                    panic_message(&panic)
                );
                (None, None)
            }
        }
    } else {
        (None, None)
    };
    InferResponse {
        request_id: item.id,
        model: item.req.model,
        generation: slot.generation,
        pred: out.pred[row] as usize,
        sums: out.sums_row(row).to_vec(),
        hw_decision_latency: hw_latency,
        hw_winner,
        service_latency_us: item.req.submitted.elapsed().as_secs_f64() * 1e6,
        batch_size,
        worker,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nz(n: u32) -> NonZeroU32 {
        NonZeroU32::new(n).unwrap()
    }

    #[test]
    fn replay_policy_parsing() {
        assert_eq!(ReplayPolicy::from_name("off").unwrap(), ReplayPolicy::Off);
        assert_eq!(ReplayPolicy::from_name("full").unwrap(), ReplayPolicy::Full);
        assert_eq!(
            ReplayPolicy::from_name("sample:8").unwrap(),
            ReplayPolicy::Sample(nz(8))
        );
        for bad in ["sample:0", "sample:x", "some", "sample"] {
            let err = ReplayPolicy::from_name(bad);
            assert!(err.is_err(), "{bad} must be rejected");
        }
        let msg = ReplayPolicy::from_name("everything").unwrap_err().to_string();
        assert!(msg.contains("off") && msg.contains("sample:<N>") && msg.contains("full"));
    }

    #[test]
    fn replay_policy_take_schedule() {
        assert!(!ReplayPolicy::Off.take(0));
        assert!(ReplayPolicy::Full.take(17));
        let s = ReplayPolicy::Sample(nz(4));
        let taken: Vec<u64> = (0..12).filter(|&i| s.take(i)).collect();
        assert_eq!(taken, vec![0, 4, 8]);
        // `Sample(NonZeroU32)` makes the old divide-by-zero degenerate
        // unrepresentable; a 1-in-1 sample is simply every row.
        assert!(ReplayPolicy::Sample(nz(1)).take(5));
    }

    #[test]
    fn shed_policy_parsing() {
        assert_eq!(ShedPolicy::from_name("reject-new").unwrap(), ShedPolicy::RejectNew);
        assert_eq!(ShedPolicy::from_name("drop-oldest").unwrap(), ShedPolicy::DropOldest);
        let msg = ShedPolicy::from_name("newest").unwrap_err().to_string();
        assert!(msg.contains("reject-new") && msg.contains("drop-oldest"));
        assert_eq!(ShedPolicy::default(), ShedPolicy::RejectNew);
    }

    #[test]
    fn spill_target_picks_least_loaded_with_room() {
        assert_eq!(spill_target([4, 2, 3].into_iter(), 4), Some(1));
        assert_eq!(spill_target([4, 4, 1].into_iter(), 4), Some(2));
        // Ties break to the lowest index (min_by_key returns the first
        // minimum).
        assert_eq!(spill_target([2, 0, 0].into_iter(), 4), Some(1));
        // Saturated pool: nobody has room, the request must be shed.
        assert_eq!(spill_target([4, 5, 4].into_iter(), 4), None);
        assert_eq!(spill_target([0].into_iter(), 0), None);
    }

    #[test]
    fn infer_error_messages_are_actionable() {
        fn is_error<E: std::error::Error>(_: &E) {}
        let e = InferError::UnknownModel { name: "ghost".into() };
        is_error(&e);
        assert!(e.to_string().contains("ghost") && e.to_string().contains("not served"));
        let e = InferError::WidthMismatch { got: 17, expected: 16 };
        assert!(e.to_string().contains("17") && e.to_string().contains("16"));
        let e = InferError::QueueFull { depth: 9, limit: 8 };
        assert!(e.to_string().contains('9') && e.to_string().contains('8'));
        assert!(InferError::BackendFailed("boom".into()).to_string().contains("boom"));
        assert!(InferError::ShuttingDown.to_string().contains("shutting down"));
    }

    #[test]
    fn model_id_display_index_and_pool_tag() {
        let mid = ModelId::new(7, 3);
        assert_eq!(mid.index(), 3);
        assert_eq!(mid.to_string(), "model#3@pool7");
        // Same index, different pool: distinct identities.
        assert_ne!(mid, ModelId::new(8, 3));
    }

    fn item_for(model: u32, id: u64, reply: &mpsc::Sender<Reply>) -> WorkItem {
        WorkItem {
            id,
            req: InferRequest {
                model: ModelId::new(0, model),
                features: BitVec64::from_bools(&[true, false, true, false]),
                reply: ReplySink::Caller(reply.clone()),
                submitted: Instant::now(),
            },
        }
    }

    /// Forced flush picks the model whose *head* is oldest, regardless
    /// of queue lengths, and never takes more than `max_batch`.
    #[test]
    fn force_flush_picks_oldest_head_model() {
        let (reply_tx, _reply_rx) = mpsc::channel::<Reply>();
        assert!(force_flush(&[Vec::new(), Vec::new()], 8).is_none());
        // Queue 0 filled first (older heads), queue 1 longer but newer.
        let mut pending = vec![Vec::new(), Vec::new()];
        for id in 0..3u64 {
            pending[0].push(item_for(0, id, &reply_tx));
        }
        for id in 10..20u64 {
            pending[1].push(item_for(1, id, &reply_tx));
        }
        let (ix, plan) = force_flush(&pending, 8).unwrap();
        assert_eq!((ix, plan.take), (0, 3));
        // With queue 0 drained, queue 1 flushes in max_batch chunks.
        pending[0].clear();
        let (ix, plan) = force_flush(&pending, 8).unwrap();
        assert_eq!((ix, plan.take), (1, 8));
    }

    /// Drop-oldest shedding trims the worker's *total* pending load to
    /// its freshest `limit` rows, evicting globally stalest-first across
    /// the per-model queues (id order == submission order), and records
    /// each eviction under its own model.
    #[test]
    fn shed_to_limit_evicts_globally_stalest_across_models() {
        let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
        // Gauge above the local total: two more requests still in the
        // channel backlog. Only the local overflow (6 − 2 = 4) sheds.
        let depth = AtomicUsize::new(8);
        let metrics = Mutex::new(vec![Metrics::default(), Metrics::default()]);
        // Interleaved submission order: ids 0,2,4 → model 0; 1,3,5 → model 1.
        let mut pending = vec![Vec::new(), Vec::new()];
        for id in 0..6u64 {
            pending[(id % 2) as usize].push(item_for((id % 2) as u32, id, &reply_tx));
        }
        shed_to_limit(2, &mut pending, &depth, &metrics);
        assert_eq!(pending[0].len() + pending[1].len(), 2, "freshest work survives");
        // The survivors are exactly the freshest ids, one per model here.
        assert_eq!(pending[0][0].id, 4);
        assert_eq!(pending[1][0].id, 5);
        assert_eq!(depth.load(Ordering::Relaxed), 4, "4 shed, backlog untouched");
        let shed: Vec<u64> = {
            let guard = metrics.lock().unwrap();
            guard.iter().map(|m| m.snapshot().shed_requests).collect()
        };
        assert_eq!(shed, vec![2, 2], "evictions recorded under their own model");
        for _ in 0..4 {
            match reply_rx.try_recv().unwrap() {
                Err(InferError::QueueFull { limit: 2, .. }) => {}
                other => panic!("expected QueueFull, got {other:?}"),
            }
        }
        assert!(reply_rx.try_recv().is_err(), "survivors must not be answered");

        // At or under the limit nothing sheds.
        shed_to_limit(2, &mut pending, &depth, &metrics);
        assert_eq!(pending[0].len() + pending[1].len(), 2);

        // Zero limit sheds everything that is local.
        shed_to_limit(0, &mut pending, &depth, &metrics);
        assert!(pending.iter().all(Vec::is_empty));
        assert!(reply_rx.try_recv().is_ok());
    }

    /// One shard's partial reply, as a worker would produce it: partial
    /// class sums, shard index in `worker`, shard-local replay latency.
    fn partial(shard: usize, generation: u64, sums: Vec<i32>, hw: Option<Ps>, batch: usize) -> Reply {
        Ok(InferResponse {
            request_id: 7,
            model: ModelId::new(0, 0),
            generation,
            pred: 0,
            sums,
            hw_decision_latency: hw,
            hw_winner: None,
            service_latency_us: 1.0,
            batch_size: batch,
            worker: shard,
        })
    }

    fn slot(n_shards: usize) -> (ReduceSlot, mpsc::Receiver<Reply>) {
        let (tx, rx) = mpsc::channel();
        (ReduceSlot::new(ModelId::new(0, 0), tx, Instant::now(), n_shards), rx)
    }

    #[test]
    fn reduce_slot_merges_partials_and_reargmaxes() {
        let (mut s, _rx) = slot(3);
        assert!(s.absorb(7, partial(0, 4, vec![1, 0, 0], None, 2)).is_none());
        assert!(s.absorb(7, partial(2, 4, vec![0, 0, 1], Some(Ps(500)), 1)).is_none());
        let decided = s.absorb(7, partial(1, 4, vec![0, 5, 0], Some(Ps(900)), 4)).unwrap();
        let resp = decided.unwrap();
        assert_eq!(resp.sums, vec![1, 5, 1]);
        assert_eq!(resp.pred, 1, "argmax over MERGED sums, not any shard's local argmax");
        assert_eq!(resp.generation, 4);
        assert_eq!(resp.hw_decision_latency, Some(Ps(900)), "critical path = max over shards");
        assert_eq!(resp.worker, 1, "the critical shard");
        assert_eq!(resp.hw_winner, None, "shard-local hw winners do not compose");
        assert_eq!(resp.batch_size, 4);
        assert_eq!(resp.request_id, 7);
    }

    #[test]
    fn reduce_slot_breaks_merged_ties_to_the_lowest_class() {
        let (mut s, _rx) = slot(2);
        assert!(s.absorb(1, partial(0, 0, vec![-1, 2, 4], None, 1)).is_none());
        let resp = s.absorb(1, partial(1, 0, vec![5, 2, 0], None, 1)).unwrap().unwrap();
        assert_eq!(resp.sums, vec![4, 4, 4]);
        assert_eq!(resp.pred, 0, "ties go to the lowest class, like the unsharded argmax");
    }

    #[test]
    fn reduce_slot_fails_fast_on_error_mixed_generations_and_duplicates() {
        // First shard error decides the request immediately.
        let (mut s, _rx) = slot(2);
        let e = s.absorb(1, Err(InferError::QueueFull { depth: 9, limit: 8 })).unwrap();
        assert_eq!(e.unwrap_err(), InferError::QueueFull { depth: 9, limit: 8 });

        // Mixed hot-swap generations mid-reload: typed error, never a
        // Frankenstein merge.
        let (mut s, _rx) = slot(2);
        assert!(s.absorb(1, partial(0, 1, vec![1], None, 1)).is_none());
        let e = s.absorb(1, partial(1, 2, vec![1], None, 1)).unwrap().unwrap_err();
        assert!(
            matches!(&e, InferError::BackendFailed(m) if m.contains("generations")),
            "{e}"
        );

        // A duplicate shard is a protocol violation, not a silent
        // double-count.
        let (mut s, _rx) = slot(2);
        assert!(s.absorb(1, partial(0, 0, vec![1], None, 1)).is_none());
        let e = s.absorb(1, partial(0, 0, vec![1], None, 1)).unwrap().unwrap_err();
        assert!(
            matches!(&e, InferError::BackendFailed(m) if m.contains("duplicate")),
            "{e}"
        );
    }

    #[test]
    fn reduce_slot_straggler_error_names_missing_shards() {
        let (mut s, _rx) = slot(3);
        assert!(s.absorb(1, partial(1, 0, vec![1], None, 1)).is_none());
        assert!(s.expired(Duration::ZERO));
        assert!(!s.expired(Duration::from_secs(3600)));
        let msg = s.straggler_error(Duration::from_millis(250)).unwrap_err().to_string();
        assert!(msg.contains("[0, 2]") && msg.contains("1/3"), "{msg}");
    }
}
