//! Dynamic batching policy.
//!
//! Pure decision logic (fully unit-testable without threads): flush a
//! pending queue when it reaches `max_batch`, or when the *oldest* queued
//! request has waited `max_wait` (deadline bound), mirroring the size/
//! deadline policy of production inference routers.

use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatcherConfig {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::from_micros(500) }
    }
}

/// A flush decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPlan {
    /// How many queued requests to take.
    pub take: usize,
}

impl BatcherConfig {
    /// Decide whether to flush now. `oldest` is the enqueue time of the
    /// head request (None ⇔ empty queue).
    pub fn plan(&self, queued: usize, oldest: Option<Instant>) -> Option<BatchPlan> {
        if queued == 0 {
            return None;
        }
        if queued >= self.max_batch {
            return Some(BatchPlan { take: self.max_batch });
        }
        match oldest {
            Some(t0) if t0.elapsed() >= self.max_wait => Some(BatchPlan { take: queued }),
            _ => None,
        }
    }

    /// Receive-poll granularity: a fraction of the deadline so a deadline
    /// flush is never late by more than ~25 %.
    pub fn poll_interval(&self) -> Duration {
        (self.max_wait / 4).max(Duration::from_micros(50))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn empty_queue_never_flushes() {
        let cfg = BatcherConfig::default();
        assert_eq!(cfg.plan(0, None), None);
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(10) };
        let now = Instant::now();
        assert_eq!(cfg.plan(8, Some(now)), Some(BatchPlan { take: 8 }));
        assert_eq!(cfg.plan(20, Some(now)), Some(BatchPlan { take: 8 }));
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let cfg = BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(0) };
        let t0 = Instant::now() - Duration::from_millis(5);
        assert_eq!(cfg.plan(3, Some(t0)), Some(BatchPlan { take: 3 }));
    }

    #[test]
    fn young_partial_batch_waits() {
        let cfg = BatcherConfig { max_batch: 32, max_wait: Duration::from_secs(60) };
        assert_eq!(cfg.plan(3, Some(Instant::now())), None);
    }

    #[test]
    fn poll_interval_bounded() {
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(100) };
        assert!(cfg.poll_interval() >= Duration::from_micros(50));
        let slow = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(40) };
        assert_eq!(slow.poll_interval(), Duration::from_millis(10));
    }

    #[test]
    fn max_batch_one_flushes_every_request_immediately() {
        // Degenerate pool: batching disabled, every queued request is its
        // own batch regardless of age.
        let cfg = BatcherConfig { max_batch: 1, max_wait: Duration::from_secs(60) };
        assert_eq!(cfg.plan(1, Some(Instant::now())), Some(BatchPlan { take: 1 }));
        assert_eq!(cfg.plan(7, Some(Instant::now())), Some(BatchPlan { take: 1 }));
        assert_eq!(cfg.plan(0, None), None);
    }

    #[test]
    fn deadline_exactly_elapsed_flushes() {
        // elapsed() >= max_wait must flush when the head request is
        // *exactly* max_wait old (the comparison is >=, not >).
        let cfg = BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now() - cfg.max_wait;
        assert_eq!(cfg.plan(3, Some(t0)), Some(BatchPlan { take: 3 }));
    }

    #[test]
    fn partial_take_then_empty_queue_stops_flushing() {
        // An over-full queue drains in max_batch-sized takes; once the
        // worker has drained it, an empty queue must plan None again.
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(0) };
        let old = Instant::now() - Duration::from_millis(1);
        let mut queued = 5usize;
        let p1 = cfg.plan(queued, Some(old)).unwrap();
        assert_eq!(p1.take, 4);
        queued -= p1.take;
        let p2 = cfg.plan(queued, Some(old)).unwrap();
        assert_eq!(p2.take, 1, "deadline-expired remainder flushes alone");
        queued -= p2.take;
        assert_eq!(queued, 0);
        assert_eq!(cfg.plan(queued, None), None, "empty queue after partial takes");
    }

    #[test]
    fn prop_plan_never_exceeds_queue_or_max() {
        prop::check("batch plan bounds", 200, |g| {
            let cfg = BatcherConfig {
                max_batch: g.int(1, 64) as usize,
                max_wait: Duration::from_micros(g.int(0, 1000) as u64),
            };
            let queued = g.int(0, 128) as usize;
            let aged = g.boolean(0.5);
            let oldest = if queued > 0 {
                Some(if aged {
                    Instant::now() - Duration::from_secs(1)
                } else {
                    Instant::now() + Duration::from_secs(1) // not yet due
                })
            } else {
                None
            };
            if let Some(plan) = cfg.plan(queued, oldest) {
                assert!(plan.take <= queued.max(cfg.max_batch));
                assert!(plan.take <= cfg.max_batch.max(queued));
                assert!(plan.take >= 1);
                assert!(plan.take <= queued, "cannot take more than queued");
            } else {
                // No flush ⇒ queue below max and (empty or not yet due).
                assert!(queued < cfg.max_batch);
            }
        });
    }
}
