//! Dynamic batching policy.
//!
//! Pure decision logic (fully unit-testable without threads): flush a
//! pending queue when it reaches `max_batch`, or when the *oldest* queued
//! request has waited `max_wait` (deadline bound), mirroring the size/
//! deadline policy of production inference routers.
//!
//! A multi-model worker keeps one pending queue *per model* (a batch
//! must never mix feature widths or backends); [`BatcherConfig::plan_multi`]
//! is the flush decision over that queue set: every queue shares the
//! same `max_batch`/`max_wait` knobs, full queues drain oldest-head
//! first, and the deadline is measured on the globally oldest head —
//! so one model's burst cannot starve another model's aging requests.
//! The single-queue [`BatcherConfig::plan`] is the degenerate one-model
//! case of the same decision.

use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatcherConfig {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::from_micros(500) }
    }
}

/// A flush decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPlan {
    /// How many queued requests to take.
    pub take: usize,
}

/// One model's pending-queue state, as seen by the multi-model planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueState {
    pub queued: usize,
    /// Enqueue time of the head (oldest) request; `None` ⇔ empty queue.
    pub oldest: Option<Instant>,
}

impl BatcherConfig {
    /// Decide whether to flush now. `oldest` is the enqueue time of the
    /// head request (None ⇔ empty queue).
    pub fn plan(&self, queued: usize, oldest: Option<Instant>) -> Option<BatchPlan> {
        self.plan_multi(&[QueueState { queued, oldest }]).map(|(_, plan)| plan)
    }

    /// Multi-model flush decision: which queue (by index) flushes now,
    /// and how much. At most one queue flushes per call — the worker
    /// executes the batch and re-plans, so several due models drain in
    /// consecutive rounds rather than one giant head-of-line batch.
    ///
    /// Order of precedence:
    /// 1. **Deadline bound** — if the globally oldest head has waited
    ///    `max_wait`, its queue flushes up to `max_batch` rows. Checked
    ///    *first* so one model's sustained full-queue burst can never
    ///    starve another model's overdue head (with a single queue the
    ///    order is unobservable: an overdue full queue takes `max_batch`
    ///    either way).
    /// 2. **Size bound** — otherwise any queue at/over `max_batch`
    ///    flushes a full `max_batch`; among several, the one whose
    ///    *head* has waited longest goes first (ties → lowest index).
    pub fn plan_multi(&self, queues: &[QueueState]) -> Option<(usize, BatchPlan)> {
        let (head_ix, head) = queues
            .iter()
            .enumerate()
            .filter(|(_, q)| q.queued > 0)
            .min_by_key(|&(i, q)| (q.oldest, i))?;
        if let Some(t0) = head.oldest {
            if t0.elapsed() >= self.max_wait {
                return Some((head_ix, BatchPlan { take: head.queued.min(self.max_batch) }));
            }
        }
        let full = queues
            .iter()
            .enumerate()
            .filter(|(_, q)| q.queued >= self.max_batch && q.queued > 0)
            .min_by_key(|&(i, q)| (q.oldest, i));
        full.map(|(i, _)| (i, BatchPlan { take: self.max_batch }))
    }

    /// Receive-poll granularity: a fraction of the deadline so a deadline
    /// flush is never late by more than ~25 %.
    pub fn poll_interval(&self) -> Duration {
        (self.max_wait / 4).max(Duration::from_micros(50))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn empty_queue_never_flushes() {
        let cfg = BatcherConfig::default();
        assert_eq!(cfg.plan(0, None), None);
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(10) };
        let now = Instant::now();
        assert_eq!(cfg.plan(8, Some(now)), Some(BatchPlan { take: 8 }));
        assert_eq!(cfg.plan(20, Some(now)), Some(BatchPlan { take: 8 }));
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let cfg = BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(0) };
        let t0 = Instant::now() - Duration::from_millis(5);
        assert_eq!(cfg.plan(3, Some(t0)), Some(BatchPlan { take: 3 }));
    }

    #[test]
    fn young_partial_batch_waits() {
        let cfg = BatcherConfig { max_batch: 32, max_wait: Duration::from_secs(60) };
        assert_eq!(cfg.plan(3, Some(Instant::now())), None);
    }

    #[test]
    fn poll_interval_bounded() {
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(100) };
        assert!(cfg.poll_interval() >= Duration::from_micros(50));
        let slow = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(40) };
        assert_eq!(slow.poll_interval(), Duration::from_millis(10));
    }

    #[test]
    fn max_batch_one_flushes_every_request_immediately() {
        // Degenerate pool: batching disabled, every queued request is its
        // own batch regardless of age.
        let cfg = BatcherConfig { max_batch: 1, max_wait: Duration::from_secs(60) };
        assert_eq!(cfg.plan(1, Some(Instant::now())), Some(BatchPlan { take: 1 }));
        assert_eq!(cfg.plan(7, Some(Instant::now())), Some(BatchPlan { take: 1 }));
        assert_eq!(cfg.plan(0, None), None);
    }

    #[test]
    fn deadline_exactly_elapsed_flushes() {
        // elapsed() >= max_wait must flush when the head request is
        // *exactly* max_wait old (the comparison is >=, not >).
        let cfg = BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now() - cfg.max_wait;
        assert_eq!(cfg.plan(3, Some(t0)), Some(BatchPlan { take: 3 }));
    }

    #[test]
    fn partial_take_then_empty_queue_stops_flushing() {
        // An over-full queue drains in max_batch-sized takes; once the
        // worker has drained it, an empty queue must plan None again.
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(0) };
        let old = Instant::now() - Duration::from_millis(1);
        let mut queued = 5usize;
        let p1 = cfg.plan(queued, Some(old)).unwrap();
        assert_eq!(p1.take, 4);
        queued -= p1.take;
        let p2 = cfg.plan(queued, Some(old)).unwrap();
        assert_eq!(p2.take, 1, "deadline-expired remainder flushes alone");
        queued -= p2.take;
        assert_eq!(queued, 0);
        assert_eq!(cfg.plan(queued, None), None, "empty queue after partial takes");
    }

    fn q(queued: usize, oldest: Option<Instant>) -> QueueState {
        QueueState { queued, oldest }
    }

    #[test]
    fn plan_multi_empty_or_young_queues_wait() {
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(60) };
        assert_eq!(cfg.plan_multi(&[]), None);
        assert_eq!(cfg.plan_multi(&[q(0, None), q(0, None)]), None);
        let now = Instant::now();
        assert_eq!(cfg.plan_multi(&[q(3, Some(now)), q(5, Some(now))]), None);
    }

    #[test]
    fn plan_multi_full_queue_flushes_oldest_head_first() {
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_secs(60) };
        let older = Instant::now() - Duration::from_millis(10);
        let newer = Instant::now();
        // Only one queue is full: it flushes even though its head is the
        // *younger* one (size beats deadline).
        let plan = cfg.plan_multi(&[q(2, Some(older)), q(6, Some(newer))]);
        assert_eq!(plan, Some((1, BatchPlan { take: 4 })));
        // Two full queues: the older head drains first.
        let plan = cfg.plan_multi(&[q(5, Some(newer)), q(4, Some(older))]);
        assert_eq!(plan, Some((1, BatchPlan { take: 4 })));
        // Equal heads tie-break to the lowest index.
        let t = Instant::now();
        let plan = cfg.plan_multi(&[q(0, None), q(4, Some(t)), q(9, Some(t))]);
        assert_eq!(plan, Some((1, BatchPlan { take: 4 })));
    }

    #[test]
    fn plan_multi_deadline_flushes_the_globally_oldest_model() {
        let cfg = BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(5) };
        let overdue = Instant::now() - Duration::from_millis(50);
        let fresh = Instant::now();
        // Model 2's head is overdue: it flushes everything it has, and
        // the fresher model 0 keeps batching.
        let plan = cfg.plan_multi(&[q(7, Some(fresh)), q(0, None), q(3, Some(overdue))]);
        assert_eq!(plan, Some((2, BatchPlan { take: 3 })));
        // The globally oldest head decides even when another queue is
        // longer.
        let older = Instant::now() - Duration::from_millis(80);
        let plan = cfg.plan_multi(&[q(12, Some(overdue)), q(2, Some(older))]);
        assert_eq!(plan, Some((1, BatchPlan { take: 2 })));
    }

    /// The anti-starvation guarantee: another model's full queue must
    /// not preempt an *overdue* head. Under a sustained burst on model
    /// 0 (its queue re-fills to `max_batch` before every replan), model
    /// 1's single aging row still flushes once it passes `max_wait`.
    #[test]
    fn plan_multi_overdue_head_beats_competing_full_queue() {
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) };
        let overdue = Instant::now() - Duration::from_millis(50);
        let fresh = Instant::now();
        let plan = cfg.plan_multi(&[q(400, Some(fresh)), q(1, Some(overdue))]);
        assert_eq!(plan, Some((1, BatchPlan { take: 1 })));
        // An overdue head on the full queue itself behaves like the old
        // size rule: take is still capped at max_batch.
        let plan = cfg.plan_multi(&[q(400, Some(overdue)), q(1, Some(fresh))]);
        assert_eq!(plan, Some((0, BatchPlan { take: 4 })));
    }

    #[test]
    fn plan_multi_single_queue_matches_plan() {
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(10) };
        for (queued, oldest) in [
            (0usize, None),
            (3, Some(Instant::now())),
            (3, Some(Instant::now() - Duration::from_secs(1))),
            (8, Some(Instant::now())),
            (20, Some(Instant::now())),
        ] {
            let single = cfg.plan(queued, oldest);
            let multi = cfg.plan_multi(&[q(queued, oldest)]);
            assert_eq!(single, multi.map(|(_, p)| p), "queued={queued}");
            if let Some((i, _)) = multi {
                assert_eq!(i, 0);
            }
        }
    }

    #[test]
    fn prop_plan_never_exceeds_queue_or_max() {
        prop::check("batch plan bounds", 200, |g| {
            let cfg = BatcherConfig {
                max_batch: g.int(1, 64) as usize,
                max_wait: Duration::from_micros(g.int(0, 1000) as u64),
            };
            let queued = g.int(0, 128) as usize;
            let aged = g.boolean(0.5);
            let oldest = if queued > 0 {
                Some(if aged {
                    Instant::now() - Duration::from_secs(1)
                } else {
                    Instant::now() + Duration::from_secs(1) // not yet due
                })
            } else {
                None
            };
            if let Some(plan) = cfg.plan(queued, oldest) {
                assert!(plan.take <= queued.max(cfg.max_batch));
                assert!(plan.take <= cfg.max_batch.max(queued));
                assert!(plan.take >= 1);
                assert!(plan.take <= queued, "cannot take more than queued");
            } else {
                // No flush ⇒ queue below max and (empty or not yet due).
                assert!(queued < cfg.max_batch);
            }
        });
    }
}
