//! Coordinator metrics: latency histograms, throughput, batch shapes.
//!
//! Each worker records into its own [`Metrics`] — one slot **per served
//! model** (batches never mix models, so every delta lands in exactly
//! one slot), all under a single per-worker lock, with no cross-worker
//! contention on the hot path. The coordinator aggregates the
//! (worker × model) matrix with [`Metrics::merge`] — histograms merge
//! bucket-wise, counters sum — along either axis: across everything for
//! the pool view (`Coordinator::metrics`), across workers for one
//! tenant's view (`Coordinator::metrics_for`), across models for one
//! worker's view (`Coordinator::worker_metrics`). Merging is exact and
//! order-independent (bucket-wise sums; percentile inputs are sorted at
//! snapshot), so the per-model snapshots always sum to the pool totals.

use crate::tm::HotLoopStats;
use crate::util::stats::Histogram;
use crate::util::Ps;

use super::InferResponse;

/// Live metrics, guarded by the coordinator's mutex.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    service_latency: Option<Histogram>,
    hw_latency_ns: Vec<f64>,
    requests: u64,
    batches: u64,
    batched_requests: u64,
    batch_exec_us_total: f64,
    hw_functional_mismatches: u64,
    rejected_requests: u64,
    shed_requests: u64,
    failed_batches: u64,
    reload_attempts: u64,
    reload_failures: u64,
    reload_shards_reused: u64,
    /// Clause-index hot-loop telemetry, accumulated from the per-batch
    /// deltas `execute_batch` diffs out of the backend's `ForwardScratch`
    /// counters (see `InferenceBackend::hot_loop_stats`).
    hot: HotLoopStats,
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    /// Mean requests per executed batch.
    pub mean_batch_size: f64,
    /// Mean PJRT execution time per batch (µs).
    pub mean_batch_exec_us: f64,
    /// Service latency stats (µs).
    pub service_p50_us: f64,
    pub service_p99_us: f64,
    pub service_mean_us: f64,
    /// Mean simulated hardware decision latency (ns), when an engine ran.
    pub hw_mean_ns: f64,
    /// Hardware decision-latency percentiles in simulated time, over every
    /// row the [`super::ReplayPolicy`] replayed (merged across workers
    /// like the wall-clock histograms; `Ps::ZERO` when nothing replayed).
    pub hw_p50: Ps,
    pub hw_p99: Ps,
    /// Samples where the hardware argmax disagreed with the functional
    /// argmax (possible only on class-sum ties / metastability).
    pub hw_functional_mismatches: u64,
    /// Requests refused at admission (the feature-width gate): each one
    /// was answered with a typed `WidthMismatch` instead of joining a
    /// batch.
    pub rejected_requests: u64,
    /// Requests shed by the bounded per-worker queue (typed `QueueFull`):
    /// refused at submit under reject-new, or dropped from the worker's
    /// pending queue under drop-oldest.
    pub shed_requests: u64,
    /// Backend forward calls that returned an error. A failed multi-row
    /// batch counts once for the batch, plus once per row whose solo
    /// retry also failed (those rows were answered with `BackendFailed`).
    pub failed_batches: u64,
    /// Rows that went through a backend's clause-indexed hot loop
    /// (backends without one — e.g. PJRT — contribute nothing here).
    pub hot_rows: u64,
    /// Clause-evaluation slots the clause index skipped outright.
    pub clauses_skipped: u64,
    /// Clause-evaluation slots the hot loop was responsible for
    /// (`skipped ≤ eligible`).
    pub clauses_eligible: u64,
    /// Classes whose popcount pass was pruned by the suffix upper bound.
    pub classes_pruned: u64,
    /// 64-row groups evaluated by the bit-sliced engine (`tm::slice`) —
    /// nonzero proves batching actually reached the sliced crossover.
    pub sliced_groups: u64,
    /// Rows those sliced groups covered (`sliced_rows ≤ hot_rows`; the
    /// remainder ran the row-major loop).
    pub sliced_rows: u64,
    /// `clauses_skipped / clauses_eligible` (0.0 before any hot-loop
    /// row) — the serving-time effectiveness of the clause index, now
    /// visible per tenant without touching a worker's backend.
    pub clause_skip_rate: f64,
    /// `Coordinator::reload` calls for this tenant (each consumes a
    /// generation number whether or not it succeeded).
    pub reload_attempts: u64,
    /// Reload attempts where at least one worker refused to swap (the
    /// pool kept serving — fully or mixed-generation — the old model).
    pub reload_failures: u64,
    /// Payload (clause-block) objects that reloads served from the
    /// hash-keyed cache instead of re-reading from disk, summed over all
    /// workers and attempts. On a v2 content-addressed tree, a reload
    /// that changed 1 of N objects adds `N − 1` per worker — the
    /// observable proof that reload cost is O(delta), not O(model). v1
    /// trees always add 0 (nothing is hash-tracked).
    pub reload_shards_reused: u64,
}

impl Metrics {
    pub fn record(&mut self, resp: &InferResponse) {
        self.requests += 1;
        self.service_latency
            .get_or_insert_with(Histogram::new)
            .record(resp.service_latency_us);
        if let Some(ps) = resp.hw_decision_latency {
            self.hw_latency_ns.push(ps.as_ns());
        }
        if let Some(w) = resp.hw_winner {
            if w != resp.pred {
                self.hw_functional_mismatches += 1;
            }
        }
    }

    pub fn record_batch(&mut self, n: usize, exec_us: f64) {
        self.batches += 1;
        self.batched_requests += n as u64;
        self.batch_exec_us_total += exec_us;
    }

    /// Count `n` requests refused at admission (feature-width gate).
    pub fn record_rejected(&mut self, n: u64) {
        self.rejected_requests += n;
    }

    /// Count `n` requests shed by the bounded-queue policy (`QueueFull`).
    pub fn record_shed(&mut self, n: u64) {
        self.shed_requests += n;
    }

    /// Count one backend forward call that returned an error.
    pub fn record_failed_batch(&mut self) {
        self.failed_batches += 1;
    }

    /// Fold in reload telemetry: attempts and failures of
    /// `Coordinator::reload`, plus the payload objects those reloads
    /// reused from the hash-keyed cache (delta-aware reload on v2
    /// artifact trees). Counters sum, so merging stays exact.
    pub fn record_reload(&mut self, attempts: u64, failures: u64, shards_reused: u64) {
        self.reload_attempts += attempts;
        self.reload_failures += failures;
        self.reload_shards_reused += shards_reused;
    }

    /// Fold one batch's hot-loop telemetry delta in (counters sum, like
    /// every other counter here, so merging stays exact).
    pub fn record_hot(&mut self, delta: HotLoopStats) {
        self.hot.rows += delta.rows;
        self.hot.clauses_skipped += delta.clauses_skipped;
        self.hot.clauses_eligible += delta.clauses_eligible;
        self.hot.classes_pruned += delta.classes_pruned;
        self.hot.sliced_groups += delta.sliced_groups;
        self.hot.sliced_rows += delta.sliced_rows;
    }

    /// Fold another worker's metrics into this one.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        if let Some(theirs) = &other.service_latency {
            self.service_latency
                .get_or_insert_with(Histogram::new)
                .merge(theirs);
        }
        self.hw_latency_ns.extend_from_slice(&other.hw_latency_ns);
        self.batches += other.batches;
        self.batched_requests += other.batched_requests;
        self.batch_exec_us_total += other.batch_exec_us_total;
        self.hw_functional_mismatches += other.hw_functional_mismatches;
        self.rejected_requests += other.rejected_requests;
        self.shed_requests += other.shed_requests;
        self.failed_batches += other.failed_batches;
        self.record_reload(
            other.reload_attempts,
            other.reload_failures,
            other.reload_shards_reused,
        );
        self.record_hot(other.hot);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let hist = self.service_latency.as_ref();
        let hw = &self.hw_latency_ns;
        MetricsSnapshot {
            requests: self.requests,
            batches: self.batches,
            mean_batch_size: if self.batches == 0 {
                0.0
            } else {
                self.batched_requests as f64 / self.batches as f64
            },
            mean_batch_exec_us: if self.batches == 0 {
                0.0
            } else {
                self.batch_exec_us_total / self.batches as f64
            },
            service_p50_us: hist.map(|h| h.quantile(0.5)).unwrap_or(0.0),
            service_p99_us: hist.map(|h| h.quantile(0.99)).unwrap_or(0.0),
            service_mean_us: hist.map(|h| h.mean()).unwrap_or(0.0),
            hw_mean_ns: crate::util::stats::mean(hw),
            hw_p50: Ps::from_ns(crate::util::stats::percentile(hw, 50.0)),
            hw_p99: Ps::from_ns(crate::util::stats::percentile(hw, 99.0)),
            hw_functional_mismatches: self.hw_functional_mismatches,
            rejected_requests: self.rejected_requests,
            shed_requests: self.shed_requests,
            failed_batches: self.failed_batches,
            hot_rows: self.hot.rows,
            clauses_skipped: self.hot.clauses_skipped,
            clauses_eligible: self.hot.clauses_eligible,
            classes_pruned: self.hot.classes_pruned,
            sliced_groups: self.hot.sliced_groups,
            sliced_rows: self.hot.sliced_rows,
            clause_skip_rate: self.hot.skip_rate(),
            reload_attempts: self.reload_attempts,
            reload_failures: self.reload_failures,
            reload_shards_reused: self.reload_shards_reused,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Ps;

    fn resp(latency_us: f64, hw: Option<(u64, usize)>, pred: usize) -> InferResponse {
        InferResponse {
            request_id: 0,
            model: crate::coordinator::ModelId::new(0, 0),
            generation: 0,
            pred,
            sums: vec![],
            hw_decision_latency: hw.map(|(ps, _)| Ps(ps)),
            hw_winner: hw.map(|(_, w)| w),
            service_latency_us: latency_us,
            batch_size: 1,
            worker: 0,
        }
    }

    #[test]
    fn records_and_snapshots() {
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.record(&resp(i as f64, Some((i * 1000, 0)), 0));
        }
        m.record_batch(32, 500.0);
        m.record_batch(8, 300.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 20.0).abs() < 1e-9);
        assert!((s.mean_batch_exec_us - 400.0).abs() < 1e-9);
        assert!(s.service_p50_us >= 50.0);
        assert!((s.hw_mean_ns - 50.5).abs() < 1e-9);
        // Simulated-time percentiles: latencies were 1..=100 ns.
        assert_eq!(s.hw_p50, Ps::from_ns(50.5));
        assert!(s.hw_p99 >= Ps(99_000) && s.hw_p99 <= Ps(100_000), "{:?}", s.hw_p99);
        assert_eq!(s.hw_functional_mismatches, 0);
        assert_eq!((s.rejected_requests, s.shed_requests, s.failed_batches), (0, 0, 0));
    }

    #[test]
    fn counts_hw_mismatches() {
        let mut m = Metrics::default();
        m.record(&resp(1.0, Some((100, 2)), 1)); // hw says 2, model says 1
        m.record(&resp(1.0, Some((100, 1)), 1));
        assert_eq!(m.snapshot().hw_functional_mismatches, 1);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.service_p50_us, 0.0);
        assert_eq!(s.hw_mean_ns, 0.0);
        assert_eq!(s.hw_p50, Ps::ZERO);
        assert_eq!(s.hw_p99, Ps::ZERO);
        assert_eq!(s.rejected_requests, 0);
        assert_eq!(s.shed_requests, 0);
        assert_eq!(s.failed_batches, 0);
    }

    #[test]
    fn fail_soft_counters_record_and_merge() {
        let mut w0 = Metrics::default();
        let mut w1 = Metrics::default();
        w0.record_rejected(1);
        w0.record_shed(3);
        w1.record_failed_batch();
        w1.record_failed_batch();
        w1.record_shed(2);
        let mut agg = Metrics::default();
        agg.merge(&w0);
        agg.merge(&w1);
        let s = agg.snapshot();
        assert_eq!(s.rejected_requests, 1);
        assert_eq!(s.shed_requests, 5);
        assert_eq!(s.failed_batches, 2);
        // Dropped work is visible without being double-counted as served.
        assert_eq!(s.requests, 0);
        assert_eq!(s.batches, 0);
    }

    #[test]
    fn merge_equals_combined_recording() {
        // Two workers recording disjoint halves must merge to the same
        // snapshot as one worker recording everything.
        let mut combined = Metrics::default();
        let mut w0 = Metrics::default();
        let mut w1 = Metrics::default();
        for i in 1..=100 {
            let r = resp(i as f64, Some((i * 1000, (i % 3) as usize)), 0);
            combined.record(&r);
            if i % 2 == 0 { w0.record(&r) } else { w1.record(&r) };
        }
        combined.record_batch(32, 500.0);
        combined.record_batch(8, 300.0);
        w0.record_batch(32, 500.0);
        w1.record_batch(8, 300.0);
        // Fail-soft counters split across workers the same way.
        combined.record_rejected(1);
        w0.record_rejected(1);
        combined.record_shed(4);
        w0.record_shed(1);
        w1.record_shed(3);
        combined.record_failed_batch();
        w1.record_failed_batch();
        // Reload telemetry splits across workers the same way (the
        // shards_reused sum is what a 2-worker delta reload would fold).
        combined.record_reload(2, 1, 6);
        w0.record_reload(1, 1, 3);
        w1.record_reload(1, 0, 3);

        let mut agg = Metrics::default();
        agg.merge(&w0);
        agg.merge(&w1);
        let (a, c) = (agg.snapshot(), combined.snapshot());
        assert_eq!(a.requests, c.requests);
        assert_eq!(a.batches, c.batches);
        assert!((a.mean_batch_size - c.mean_batch_size).abs() < 1e-9);
        assert!((a.mean_batch_exec_us - c.mean_batch_exec_us).abs() < 1e-9);
        assert_eq!(a.service_p50_us, c.service_p50_us);
        assert_eq!(a.service_p99_us, c.service_p99_us);
        assert!((a.hw_mean_ns - c.hw_mean_ns).abs() < 1e-9);
        assert_eq!(a.hw_p50, c.hw_p50, "hw p50 merges across workers");
        assert_eq!(a.hw_p99, c.hw_p99, "hw p99 merges across workers");
        assert_eq!(a.hw_functional_mismatches, c.hw_functional_mismatches);
        assert_eq!(a.rejected_requests, c.rejected_requests);
        assert_eq!(a.shed_requests, c.shed_requests);
        assert_eq!(a.failed_batches, c.failed_batches);
        assert_eq!(a.reload_attempts, c.reload_attempts);
        assert_eq!(a.reload_failures, c.reload_failures);
        assert_eq!(a.reload_shards_reused, c.reload_shards_reused);
    }

    /// The (worker × model) matrix merges to the same snapshot along
    /// either axis order — the property `metrics()` / `metrics_for()` /
    /// `worker_metrics()` consistency stands on.
    #[test]
    fn matrix_merge_is_axis_order_independent() {
        // 2 workers × 2 models, disjoint recordings.
        let mut cells = vec![vec![Metrics::default(), Metrics::default()]; 2];
        for (w, row) in cells.iter_mut().enumerate() {
            for (m, cell) in row.iter_mut().enumerate() {
                for i in 1..=20 {
                    let lat = (w * 100 + m * 10 + i) as f64;
                    cell.record(&resp(lat, Some((i as u64 * 500, 0)), 0));
                }
                cell.record_batch(20, 50.0);
                cell.record_shed((w + m) as u64);
            }
        }
        // Pool view: fold workers then models…
        let mut by_worker = Metrics::default();
        for row in &cells {
            for cell in row {
                by_worker.merge(cell);
            }
        }
        // …vs models then workers (the metrics_for axis).
        let mut by_model = Metrics::default();
        for m in 0..2 {
            for row in &cells {
                by_model.merge(&row[m]);
            }
        }
        assert_eq!(by_worker.snapshot(), by_model.snapshot());
        // And per-model partitions sum to the pool totals exactly.
        let pool = by_worker.snapshot();
        let per_model: Vec<MetricsSnapshot> = (0..2)
            .map(|m| {
                let mut agg = Metrics::default();
                for row in &cells {
                    agg.merge(&row[m]);
                }
                agg.snapshot()
            })
            .collect();
        assert_eq!(per_model.iter().map(|s| s.requests).sum::<u64>(), pool.requests);
        assert_eq!(per_model.iter().map(|s| s.batches).sum::<u64>(), pool.batches);
        assert_eq!(
            per_model.iter().map(|s| s.shed_requests).sum::<u64>(),
            pool.shed_requests
        );
    }

    #[test]
    fn hot_loop_telemetry_records_and_merges() {
        let mut w0 = Metrics::default();
        let mut w1 = Metrics::default();
        w0.record_hot(HotLoopStats {
            rows: 4,
            clauses_skipped: 30,
            clauses_eligible: 40,
            classes_pruned: 2,
            sliced_groups: 1,
            sliced_rows: 3,
        });
        w1.record_hot(HotLoopStats {
            rows: 1,
            clauses_skipped: 10,
            clauses_eligible: 40,
            classes_pruned: 0,
            sliced_groups: 0,
            sliced_rows: 0,
        });
        let mut agg = Metrics::default();
        agg.merge(&w0);
        agg.merge(&w1);
        let s = agg.snapshot();
        assert_eq!(s.hot_rows, 5);
        assert_eq!(s.clauses_skipped, 40);
        assert_eq!(s.clauses_eligible, 80);
        assert_eq!(s.classes_pruned, 2);
        assert_eq!(s.sliced_groups, 1);
        assert_eq!(s.sliced_rows, 3);
        assert!((s.clause_skip_rate - 0.5).abs() < 1e-12);
        // Empty metrics report a well-defined zero rate.
        assert_eq!(Metrics::default().snapshot().clause_skip_rate, 0.0);
        // Merge-equals-combined holds for the sliced counters too.
        let mut combined = Metrics::default();
        combined.record_hot(HotLoopStats {
            rows: 5,
            clauses_skipped: 40,
            clauses_eligible: 80,
            classes_pruned: 2,
            sliced_groups: 1,
            sliced_rows: 3,
        });
        assert_eq!(agg.snapshot(), combined.snapshot());
    }

    #[test]
    fn merge_into_empty_is_identity() {
        let mut m = Metrics::default();
        m.record(&resp(5.0, None, 1));
        m.record_batch(1, 10.0);
        let mut agg = Metrics::default();
        agg.merge(&m);
        assert_eq!(agg.snapshot(), m.snapshot());
        // And merging an empty set of workers leaves it empty.
        let mut empty = Metrics::default();
        empty.merge(&Metrics::default());
        assert_eq!(empty.snapshot().requests, 0);
    }
}
