//! Time-domain comparison: SR-latch arbiters and the arbiter tree
//! (paper §III-A.3).
//!
//! A NAND SR latch responds to the race between two PDL outputs: whichever
//! rising transition arrives first sets the latch, implementing a 2-way
//! argmax in time. An OR gate over the latch outputs produces the
//! completion signal. Comparisons over more than two PDLs cascade arbiter
//! levels, with each level's completion feeding the next; falling
//! transitions use the dual NOR-latch arbiter (the MOUSETRAP datapath
//! alternates transition phases), which doubles the per-node gate cost but
//! not the latency.
//!
//! Metastability: if two transitions arrive within the latch's resolution
//! window the output settles late — and may settle *wrong*. The paper
//! mitigates this by increasing the hi−lo delay gap of the PDL elements so
//! that distinct Hamming weights are separated by at least one delta;
//! genuinely equal weights remain a coin flip ("classification
//! metastability", paper footnote 1). [`Arbiter2::decide`] models exactly
//! that: deterministic for |Δt| ≥ window, probabilistic (seeded) inside it,
//! with an exponential settling-time penalty.

pub mod resources;
pub mod tree;

pub use resources::ArbiterResources;
pub use tree::{ArbiterTree, TreeDecision};

use crate::util::{Ps, SplitMix64};

/// Electrical parameters of one SR-latch arbiter node.
#[derive(Debug, Clone, Copy)]
pub struct ArbiterConfig {
    /// Propagation delay of the cross-coupled latch (set → Q).
    pub latch_delay: Ps,
    /// Delay of the completion gate (OR for rising / AND for falling).
    pub completion_gate_delay: Ps,
    /// Resolution window: |Δt| below this risks metastability.
    pub window: Ps,
    /// Regeneration time constant τ of the latch (settling penalty scale).
    pub tau_ps: f64,
}

impl Default for ArbiterConfig {
    fn default() -> Self {
        // 28 nm-class LUT-latch figures: one LUT delay per gate, ~25 ps
        // resolution window, τ ≈ 18 ps.
        Self {
            latch_delay: Ps(124),
            completion_gate_delay: Ps(124),
            window: Ps(25),
            tau_ps: 18.0,
        }
    }
}

/// Outcome of one 2-way arbitration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    /// 0 if input A won, 1 if input B won.
    pub winner: u8,
    /// When the winning transition is available at the latch output.
    pub grant_time: Ps,
    /// When the completion gate fires.
    pub completion: Ps,
    /// The race entered the metastability window.
    pub metastable: bool,
    /// The latch settled on the *later* input (possible only when
    /// metastable — the paper's "classification metastability").
    pub inverted: bool,
}

/// One NAND (rising) / NOR (falling) SR-latch arbiter.
#[derive(Debug, Clone)]
pub struct Arbiter2 {
    pub cfg: ArbiterConfig,
}

impl Arbiter2 {
    pub fn new(cfg: ArbiterConfig) -> Self {
        Self { cfg }
    }

    /// Resolve a race between arrivals `ta` (input A) and `tb` (input B).
    ///
    /// `rng` drives metastable resolution; passing the same seeded stream
    /// reproduces a run exactly.
    pub fn decide(&self, ta: Ps, tb: Ps, rng: &mut SplitMix64) -> Decision {
        let dt = ta.abs_diff(tb);
        let first_is_a = ta <= tb;
        let t_first = ta.min(tb);

        if dt >= self.cfg.window {
            // Clean race: the earlier transition wins deterministically.
            let grant = t_first + self.cfg.latch_delay;
            return Decision {
                winner: if first_is_a { 0 } else { 1 },
                grant_time: grant,
                completion: grant + self.cfg.completion_gate_delay,
                metastable: false,
                inverted: false,
            };
        }

        // Metastable race. Settling penalty grows as ln(window/Δt); the
        // probability the latch resolves toward the *later* input decays
        // linearly in Δt across the window (0.5 at Δt = 0).
        let dt_ps = dt.as_ps_f64().max(0.25); // quarter-ps floor avoids ln(∞)
        let window_ps = self.cfg.window.as_ps_f64();
        let settle_extra = Ps::from_ps_f64((self.cfg.tau_ps * (window_ps / dt_ps).ln()).min(self.cfg.tau_ps * 12.0));
        let p_invert = 0.5 * (1.0 - dt.as_ps_f64() / window_ps);
        let inverted = rng.next_bool(p_invert);

        let winner_is_a = first_is_a ^ inverted;
        let grant = t_first + self.cfg.latch_delay + settle_extra;
        Decision {
            winner: if winner_is_a { 0 } else { 1 },
            grant_time: grant,
            completion: grant + self.cfg.completion_gate_delay,
            metastable: true,
            inverted,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn arb() -> Arbiter2 {
        Arbiter2::new(ArbiterConfig::default())
    }

    #[test]
    fn clean_race_is_deterministic() {
        let mut rng = SplitMix64::new(1);
        let d = arb().decide(Ps(1000), Ps(1200), &mut rng);
        assert_eq!(d.winner, 0);
        assert!(!d.metastable && !d.inverted);
        assert_eq!(d.grant_time, Ps(1124));
        assert_eq!(d.completion, Ps(1248));
        let d2 = arb().decide(Ps(1200), Ps(1000), &mut rng);
        assert_eq!(d2.winner, 1);
    }

    #[test]
    fn exact_tie_is_coin_flip() {
        let a = arb();
        let mut wins_a = 0;
        for seed in 0..400 {
            let mut rng = SplitMix64::new(seed);
            let d = a.decide(Ps(5000), Ps(5000), &mut rng);
            assert!(d.metastable);
            if d.winner == 0 {
                wins_a += 1;
            }
        }
        assert!((150..=250).contains(&wins_a), "tie should be ≈50/50, got {wins_a}/400");
    }

    #[test]
    fn metastable_settling_is_slower() {
        let a = arb();
        let mut rng = SplitMix64::new(2);
        let clean = a.decide(Ps(1000), Ps(1100), &mut rng);
        let meta = a.decide(Ps(1000), Ps(1002), &mut rng);
        assert!(meta.metastable);
        assert!(meta.grant_time > clean.grant_time - Ps(100), "settling penalty applies");
        assert!(meta.grant_time > Ps(1000) + a.cfg.latch_delay);
    }

    #[test]
    fn inversion_probability_decays_across_window() {
        let a = arb();
        let count_inversions = |dt: u64| -> usize {
            (0..2000)
                .filter(|&seed| {
                    let mut rng = SplitMix64::new(seed);
                    a.decide(Ps(1000), Ps(1000 + dt), &mut rng).inverted
                })
                .count()
        };
        let at_0 = count_inversions(0);
        let at_12 = count_inversions(12);
        let at_24 = count_inversions(24);
        assert!(at_0 > at_12 && at_12 > at_24, "{at_0} > {at_12} > {at_24}");
        assert!(at_0 > 850 && at_0 < 1150); // ≈ p=0.5
        assert!(at_24 < 120); // ≈ p→0 at window edge
    }

    #[test]
    fn prop_widening_delta_prevents_inversion() {
        // The paper's mitigation: once |Δt| ≥ window, the decision is
        // always correct regardless of the rng stream.
        prop::check("no inversion outside window", 200, |g| {
            let a = arb();
            let base = g.int(0, 1_000_000) as u64;
            let dt = a.cfg.window.0 + g.int(0, 10_000) as u64;
            let mut rng = SplitMix64::new(g.int(0, i64::MAX - 1) as u64);
            let d = a.decide(Ps(base), Ps(base + dt), &mut rng);
            assert_eq!(d.winner, 0);
            assert!(!d.inverted);
        });
    }
}
