//! Resource accounting for arbiter trees (paper Fig. 9b/11).
//!
//! Each arbiter node comprises a rising-transition arbiter (2 cross-coupled
//! NAND LUTs + 1 OR completion LUT) and its falling-transition dual (2 NOR
//! LUTs + 1 AND LUT) — the MOUSETRAP datapath alternates phases, so both
//! are instantiated (paper §III-A.3). Padding nodes are kept for symmetry
//! and cost the same. Decoding the arbiter outputs to a class index costs
//! roughly one LUT per class.

/// LUT/FF cost of one N-way arbiter tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ArbiterResources {
    pub luts: u32,
    pub ffs: u32,
}

/// Gate cost of one arbiter node (both transition phases).
const LUTS_PER_NODE: u32 = 6; // 2 NAND + OR + 2 NOR + AND

impl ArbiterResources {
    pub fn for_tree(n_inputs: usize) -> ArbiterResources {
        if n_inputs <= 1 {
            return ArbiterResources { luts: 0, ffs: 0 };
        }
        let width = n_inputs.next_power_of_two() as u32;
        let nodes = width - 1; // full symmetric tree incl. padding nodes
        let decode = n_inputs as u32; // one-hot → index decode
        ArbiterResources { luts: nodes * LUTS_PER_NODE + decode, ffs: 0 }
    }

    pub fn total(&self) -> u32 {
        self.luts + self.ffs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_way_tree_is_one_node() {
        let r = ArbiterResources::for_tree(2);
        assert_eq!(r.luts, 6 + 2);
    }

    #[test]
    fn padding_counts_toward_cost() {
        // 3 classes pad to width 4 ⇒ 3 nodes, same as 4 classes.
        assert_eq!(
            ArbiterResources::for_tree(3).luts + 1,
            ArbiterResources::for_tree(4).luts
        );
    }

    #[test]
    fn single_input_free() {
        assert_eq!(ArbiterResources::for_tree(1).total(), 0);
    }

    #[test]
    fn grows_linearly_in_width() {
        // Tree nodes scale ~linearly with the (padded) class count —
        // the comparison cost the paper contrasts with adder comparators.
        let r8 = ArbiterResources::for_tree(8).luts;
        let r16 = ArbiterResources::for_tree(16).luts;
        assert!(r16 > r8 && r16 < 3 * r8);
    }
}
