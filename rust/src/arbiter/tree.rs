//! Multi-level arbiter tree: N-way time-domain argmax (paper §III-A.3,
//! Fig. 7).
//!
//! For more than two PDLs, arbiters cascade: level ℓ's winners race at
//! level ℓ+1, and the completion signal of the final level is the overall
//! `Completion`. When N is not a power of two, the tree is padded with
//! fixed-level inputs ("one input fixed at either 0 or 1 depending on the
//! transition phase", Fig. 7) that never win but keep the structure — and
//! therefore the per-level latency — symmetric.

use crate::util::{Ps, SplitMix64};

use super::{Arbiter2, ArbiterConfig, Decision};

/// Result of one N-way arbitration.
#[derive(Debug, Clone, PartialEq)]
pub struct TreeDecision {
    /// Index of the winning input (the argmax class).
    pub winner: usize,
    /// When the decoded one-hot winner is stable.
    pub grant_time: Ps,
    /// When the final-level completion gate fires.
    pub completion: Ps,
    /// Number of metastable node decisions along the way.
    pub metastable_nodes: u32,
    /// Number of nodes that resolved toward the later input.
    pub inverted_nodes: u32,
    /// Levels in the tree.
    pub levels: u32,
}

/// N-way arbiter tree.
#[derive(Debug, Clone)]
pub struct ArbiterTree {
    pub n_inputs: usize,
    pub node: Arbiter2,
}

impl ArbiterTree {
    pub fn new(n_inputs: usize, cfg: ArbiterConfig) -> Self {
        assert!(n_inputs >= 1);
        Self { n_inputs, node: Arbiter2::new(cfg) }
    }

    /// Number of cascade levels (0 for a single input).
    pub fn levels(&self) -> u32 {
        (self.n_inputs.max(1) as f64).log2().ceil() as u32
    }

    /// Race all inputs; `arrivals[i]` is when PDL `i`'s output transition
    /// reaches the first arbiter level.
    pub fn decide(&self, arrivals: &[Ps], rng: &mut SplitMix64) -> TreeDecision {
        assert_eq!(arrivals.len(), self.n_inputs);
        if self.n_inputs == 1 {
            let grant = arrivals[0] + self.node.cfg.latch_delay;
            return TreeDecision {
                winner: 0,
                grant_time: grant,
                completion: grant + self.node.cfg.completion_gate_delay,
                metastable_nodes: 0,
                inverted_nodes: 0,
                levels: 0,
            };
        }

        // Current round: (original input index, arrival time). Padding
        // slots are None — their latch input is tied off, so the real input
        // wins after the plain latch delay.
        let mut round: Vec<Option<(usize, Ps)>> =
            arrivals.iter().enumerate().map(|(i, &t)| Some((i, t))).collect();
        let width = self.n_inputs.next_power_of_two();
        round.resize(width, None);

        let mut metastable = 0u32;
        let mut inverted = 0u32;
        let mut levels = 0u32;

        while round.len() > 1 {
            levels += 1;
            let mut next = Vec::with_capacity(round.len() / 2);
            for pair in round.chunks(2) {
                let merged = match (pair[0], pair[1]) {
                    (Some((ia, ta)), Some((ib, tb))) => {
                        let d: Decision = self.node.decide(ta, tb, rng);
                        if d.metastable {
                            metastable += 1;
                        }
                        if d.inverted {
                            inverted += 1;
                        }
                        let (wi, _wt) = if d.winner == 0 { (ia, ta) } else { (ib, tb) };
                        Some((wi, d.grant_time))
                    }
                    // One real input + tied-off side: passes through after
                    // the latch delay.
                    (Some((i, t)), None) | (None, Some((i, t))) => {
                        Some((i, t + self.node.cfg.latch_delay))
                    }
                    (None, None) => None,
                };
                next.push(merged);
            }
            round = next;
        }

        let (winner, grant_time) = round[0].expect("at least one real input");
        // The system `Completion` is the *last-level* arbiter's completion
        // gate (paper §III-A.3 / Fig. 7): it fires as soon as the winning
        // transition has traversed the tree — the paper's async advantage.
        // Slow losers matter only to the controller's join, not here.
        TreeDecision {
            winner,
            grant_time,
            completion: grant_time + self.node.cfg.completion_gate_delay,
            metastable_nodes: metastable,
            inverted_nodes: inverted,
            levels,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn tree(n: usize) -> ArbiterTree {
        ArbiterTree::new(n, ArbiterConfig::default())
    }

    fn ps_vec(xs: &[u64]) -> Vec<Ps> {
        xs.iter().map(|&x| Ps(x)).collect()
    }

    #[test]
    fn two_way_picks_earliest() {
        let mut rng = SplitMix64::new(1);
        let d = tree(2).decide(&ps_vec(&[9000, 5000]), &mut rng);
        assert_eq!(d.winner, 1);
        assert_eq!(d.levels, 1);
    }

    #[test]
    fn three_way_uses_two_levels_with_padding() {
        let mut rng = SplitMix64::new(1);
        let t = tree(3);
        assert_eq!(t.levels(), 2);
        let d = t.decide(&ps_vec(&[70_000, 50_000, 90_000]), &mut rng);
        assert_eq!(d.winner, 1);
        assert_eq!(d.levels, 2);
        assert!(d.completion > Ps(50_000));
    }

    #[test]
    fn completion_tracks_last_level() {
        let mut rng = SplitMix64::new(3);
        let t = tree(4);
        let d = t.decide(&ps_vec(&[10_000, 20_000, 30_000, 40_000]), &mut rng);
        assert_eq!(d.winner, 0);
        // Grant passes 2 levels of latches; completion is one gate later
        // than the slowest node's grant.
        let cfg = ArbiterConfig::default();
        assert_eq!(d.grant_time, Ps(10_000) + cfg.latch_delay + cfg.latch_delay);
        assert!(d.completion >= d.grant_time + cfg.completion_gate_delay);
    }

    #[test]
    fn single_input_trivial() {
        let mut rng = SplitMix64::new(4);
        let d = tree(1).decide(&[Ps(500)], &mut rng);
        assert_eq!(d.winner, 0);
        assert_eq!(d.levels, 0);
    }

    #[test]
    fn near_constant_latency_in_class_count() {
        // The paper's Fig. 10b claim: comparison latency grows only by one
        // latch delay per doubling of classes.
        let mut rng = SplitMix64::new(5);
        let base = 100_000u64;
        let mut prev = None;
        for n in [2usize, 4, 8, 16, 32] {
            let arrivals: Vec<Ps> = (0..n).map(|i| Ps(base + 400 * i as u64)).collect();
            let d = tree(n).decide(&arrivals, &mut rng);
            assert_eq!(d.winner, 0);
            if let Some(p) = prev {
                let growth = d.grant_time.saturating_sub(p);
                assert_eq!(growth, ArbiterConfig::default().latch_delay,
                    "one extra level per doubling");
            }
            prev = Some(d.grant_time);
        }
    }

    #[test]
    fn prop_winner_is_argmin_with_margin() {
        prop::check("tree winner = argmin given margin", 100, |g| {
            let n = g.int(2, 24) as usize;
            let win = g.int(0, n as i64 - 1) as usize;
            let window = ArbiterConfig::default().window.0;
            // All arrivals ≥ window apart ⇒ deterministic argmin.
            let mut arrivals: Vec<Ps> = (0..n)
                .map(|i| Ps(500_000 + (i as u64 + 1) * (window + 30)))
                .collect();
            arrivals[win] = Ps(100_000);
            let mut rng = SplitMix64::new(g.int(0, i64::MAX - 1) as u64);
            let d = tree(n).decide(&arrivals, &mut rng);
            assert_eq!(d.winner, win);
            assert_eq!(d.metastable_nodes, 0);
        });
    }

    #[test]
    fn prop_completion_after_grant() {
        prop::check("completion after grant", 100, |g| {
            let n = g.int(1, 16) as usize;
            let arrivals: Vec<Ps> =
                (0..n).map(|_| Ps(g.int(0, 1_000_000) as u64)).collect();
            let mut rng = SplitMix64::new(9);
            let d = tree(n).decide(&arrivals, &mut rng);
            assert!(d.completion >= d.grant_time);
            assert!(d.winner < n);
        });
    }
}
