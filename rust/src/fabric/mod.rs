//! FPGA fabric substrate: an XC7Z020-class device model.
//!
//! The paper implements its PDLs on a Xilinx Zynq XC7Z020 (PYNQ-Z1): 53,200
//! LUTs / 106,400 FFs in 28 nm, organized as CLBs of two slices with four
//! 6-input LUTs each, tiled next to switchboxes (paper Fig. 4). This module
//! reproduces the *quantities the paper's claims depend on* (DESIGN.md §1):
//!
//! * geometric structure — CLB grid, slice/LUT positions, per-pin input
//!   delays (UG912: A6/A5 are the fast pins, used by the paper's pin
//!   assignment step),
//! * net delays between placed sites, with routing-detour control (the
//!   delay-range constraints of the paper's Fig. 3 routing step),
//! * process/voltage/temperature variation (see [`variation`]), which is
//!   what the paper's Fig. 6 monotonicity experiment stresses.

pub mod variation;

use crate::util::Ps;

pub use variation::{PvtCorner, VariationModel, VariationParams};

/// LUT physical input pins of a 7-series LUT6, ordered slowest → fastest.
/// UG912 (and the paper's Fig. 2 net-delay audit) identify A6 and A5 as the
/// two fastest physical pins; the paper's pin-assignment step maps the
/// low-latency net to the fastest pin and the high-latency net to the
/// second-fastest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LutPin {
    A1,
    A2,
    A3,
    A4,
    A5,
    A6,
}

impl LutPin {
    pub const ALL: [LutPin; 6] = [
        LutPin::A1,
        LutPin::A2,
        LutPin::A3,
        LutPin::A4,
        LutPin::A5,
        LutPin::A6,
    ];

    /// Minimal achievable net delay onto this pin (the quantity the paper
    /// evaluates in Vivado to pick the pinout, Fig. 2). Calibrated so the
    /// flow's minimum low-latency net lands in Table I's measured range
    /// (average low-latency net delay 384.5 ps on the adjacent-CLB route).
    pub fn base_net_delay(self) -> Ps {
        match self {
            LutPin::A6 => Ps(340),
            LutPin::A5 => Ps(362),
            LutPin::A4 => Ps(410),
            LutPin::A3 => Ps(455),
            LutPin::A2 => Ps(505),
            LutPin::A1 => Ps(560),
        }
    }

    /// Pins ranked fastest first.
    pub fn ranked() -> [LutPin; 6] {
        let mut pins = Self::ALL;
        pins.sort_by_key(|p| p.base_net_delay());
        pins
    }
}

/// Logic delay through a configured LUT6 (input pin → output), 28 nm class.
pub const LUT_LOGIC_DELAY: Ps = Ps(124);

/// Clock-to-Q of a slice FF (start-signal synchronization, §III-A.2).
pub const FF_CLK_TO_Q: Ps = Ps(141);

/// Routing delay contributed per switchbox hop on a general (non
/// delay-constrained) net.
pub const SWITCHBOX_HOP_DELAY: Ps = Ps(38);

/// Position of one LUT site on the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Site {
    /// CLB column.
    pub x: u16,
    /// CLB row.
    pub y: u16,
    /// Slice within the CLB (0..SLICES_PER_CLB).
    pub slice: u8,
    /// LUT within the slice (0..LUTS_PER_SLICE).
    pub lut: u8,
}

impl Site {
    /// Manhattan distance in CLB units (switchbox hops between CLBs).
    pub fn clb_distance(self, other: Site) -> u32 {
        (self.x.abs_diff(other.x) as u32) + (self.y.abs_diff(other.y) as u32)
    }

    /// Relative position inside the CLB — the paper's placement step
    /// requires every delay element to sit at the *same* relative position
    /// ("a designated LUT in a particular slice of each CLB", Fig. 4).
    pub fn rel(self) -> (u8, u8) {
        (self.slice, self.lut)
    }
}

pub const SLICES_PER_CLB: u8 = 2;
pub const LUTS_PER_SLICE: u8 = 4;
pub const LUTS_PER_CLB: u32 = (SLICES_PER_CLB as u32) * (LUTS_PER_SLICE as u32);
pub const FFS_PER_CLB: u32 = 2 * LUTS_PER_CLB; // 7-series: 2 FFs per LUT

/// The device model: a rectangular CLB grid.
#[derive(Debug, Clone)]
pub struct Device {
    pub name: &'static str,
    /// CLB grid width (columns).
    pub cols: u16,
    /// CLB grid height (rows).
    pub rows: u16,
    /// Technology node, informational.
    pub node_nm: u16,
}

impl Device {
    /// The paper's part: Zynq XC7Z020 — 53,200 LUTs / 106,400 FFs.
    /// 6,650 CLBs arranged here as 50 columns × 133 rows (tall-and-narrow,
    /// matching the vertical PDL placement of Fig. 4).
    pub fn xc7z020() -> Device {
        Device { name: "xc7z020", cols: 50, rows: 133, node_nm: 28 }
    }

    pub fn total_clbs(&self) -> u32 {
        self.cols as u32 * self.rows as u32
    }

    pub fn total_luts(&self) -> u32 {
        self.total_clbs() * LUTS_PER_CLB
    }

    pub fn total_ffs(&self) -> u32 {
        self.total_clbs() * FFS_PER_CLB
    }

    pub fn contains(&self, site: Site) -> bool {
        site.x < self.cols
            && site.y < self.rows
            && site.slice < SLICES_PER_CLB
            && site.lut < LUTS_PER_SLICE
    }

    /// Estimated routed delay for a general net between two sites with no
    /// delay constraint: hop count × switchbox delay, plus intra-CLB cost.
    pub fn net_delay(&self, from: Site, to: Site) -> Ps {
        let hops = from.clb_distance(to).max(1);
        Ps(SWITCHBOX_HOP_DELAY.0 * hops as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xc7z020_inventory_matches_datasheet() {
        let d = Device::xc7z020();
        assert_eq!(d.total_luts(), 53_200);
        assert_eq!(d.total_ffs(), 106_400);
    }

    #[test]
    fn pin_speed_order() {
        let ranked = LutPin::ranked();
        assert_eq!(ranked[0], LutPin::A6, "A6 must be the fastest pin (UG912)");
        assert_eq!(ranked[1], LutPin::A5, "A5 must be second-fastest");
        // Strictly increasing delays down the ranking.
        for w in ranked.windows(2) {
            assert!(w[0].base_net_delay() < w[1].base_net_delay());
        }
    }

    #[test]
    fn site_distance_and_bounds() {
        let d = Device::xc7z020();
        let a = Site { x: 0, y: 0, slice: 0, lut: 0 };
        let b = Site { x: 3, y: 4, slice: 1, lut: 3 };
        assert_eq!(a.clb_distance(b), 7);
        assert!(d.contains(b));
        assert!(!d.contains(Site { x: 50, y: 0, slice: 0, lut: 0 }));
        assert!(!d.contains(Site { x: 0, y: 0, slice: 2, lut: 0 }));
    }

    #[test]
    fn adjacent_net_faster_than_far_net() {
        let d = Device::xc7z020();
        let a = Site { x: 5, y: 5, slice: 0, lut: 1 };
        let near = Site { x: 5, y: 6, slice: 0, lut: 1 };
        let far = Site { x: 5, y: 20, slice: 0, lut: 1 };
        assert!(d.net_delay(a, near) < d.net_delay(a, far));
    }
}
