//! Process / voltage / temperature variation model.
//!
//! The paper's Fig. 6 experiment measures a physically implemented PDL on a
//! real board, where intra-die process variation, voltage and temperature
//! perturb every delay element differently; its §III-B.4 argues the PDL
//! stays monotonic in Hamming weight provided the hi−lo delay gap is large
//! enough relative to that noise. This module is the stand-in for the real
//! silicon (DESIGN.md §1): a deterministic, seedable variation field over
//! the device that multiplies nominal delays.
//!
//! Structure follows the standard intra-die decomposition:
//!   factor(site) = 1 + gradient(x, y) + random(site)
//! where `gradient` is a smooth across-die systematic component and
//! `random` is per-site white noise. PVT corners scale everything globally.

use crate::util::{Ps, SplitMix64};

use super::Site;

/// Global operating corner: scales all delays (slow corner > 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PvtCorner {
    /// Supply voltage scaling: delay ∝ ~1/(V/V_nom)^1.3 around nominal.
    pub v_scale: f64,
    /// Junction temperature in °C (delay grows mildly with T at 28 nm).
    pub temp_c: f64,
}

impl PvtCorner {
    pub fn nominal() -> Self {
        Self { v_scale: 1.0, temp_c: 25.0 }
    }

    pub fn slow() -> Self {
        Self { v_scale: 0.95, temp_c: 85.0 }
    }

    pub fn fast() -> Self {
        Self { v_scale: 1.05, temp_c: 0.0 }
    }

    /// Multiplicative delay factor of this corner.
    pub fn delay_factor(&self) -> f64 {
        let v = self.v_scale.max(0.5).powf(-1.3);
        let t = 1.0 + 0.0006 * (self.temp_c - 25.0);
        v * t
    }
}

/// Parameters of the intra-die variation field.
#[derive(Debug, Clone, Copy)]
pub struct VariationParams {
    /// σ of the per-site random component (fraction of nominal delay).
    /// 28 nm LUT+routing paths show a few percent; default 2 %.
    pub sigma_random: f64,
    /// Peak-to-peak amplitude of the smooth across-die gradient (fraction).
    pub gradient_amplitude: f64,
    /// PVT corner.
    pub corner: PvtCorner,
}

impl Default for VariationParams {
    fn default() -> Self {
        Self {
            sigma_random: 0.02,
            gradient_amplitude: 0.015,
            corner: PvtCorner::nominal(),
        }
    }
}

impl VariationParams {
    /// An idealized device with no variation (for unit tests and for
    /// isolating algorithmic behaviour from noise).
    pub fn none() -> Self {
        Self { sigma_random: 0.0, gradient_amplitude: 0.0, corner: PvtCorner::nominal() }
    }
}

/// A sampled variation field for one (simulated) die.
#[derive(Debug, Clone)]
pub struct VariationModel {
    params: VariationParams,
    seed: u64,
    /// Random phase of the systematic gradient, per die.
    phase_x: f64,
    phase_y: f64,
}

impl VariationModel {
    /// `seed` identifies the die: two models with different seeds behave
    /// like two different physical chips (device-to-device variation).
    pub fn new(seed: u64, params: VariationParams) -> Self {
        let mut r = SplitMix64::new(seed ^ 0xD1E_5EED);
        let phase_x = r.next_f64() * std::f64::consts::TAU;
        let phase_y = r.next_f64() * std::f64::consts::TAU;
        Self { params, seed, phase_x, phase_y }
    }

    pub fn params(&self) -> &VariationParams {
        &self.params
    }

    /// Smooth systematic component in [-amp/2, amp/2].
    fn gradient(&self, site: Site) -> f64 {
        let amp = self.params.gradient_amplitude;
        if amp == 0.0 {
            return 0.0;
        }
        // One-ish spatial period across the die in each axis.
        let fx = (site.x as f64 / 50.0) * std::f64::consts::TAU + self.phase_x;
        let fy = (site.y as f64 / 133.0) * std::f64::consts::TAU + self.phase_y;
        (fx.sin() + fy.cos()) * (amp / 4.0)
    }

    /// Per-site random component, deterministic in (die seed, site, tag).
    /// `tag` distinguishes multiple delay arcs at the same site (e.g. the
    /// low- and high-latency nets of one delay element vary independently).
    fn random(&self, site: Site, tag: u64) -> f64 {
        if self.params.sigma_random == 0.0 {
            return 0.0;
        }
        let key = (self.seed << 1)
            ^ ((site.x as u64) << 40)
            ^ ((site.y as u64) << 24)
            ^ ((site.slice as u64) << 16)
            ^ ((site.lut as u64) << 8)
            ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut r = SplitMix64::new(key);
        // Warm the stream so low-entropy keys decorrelate.
        r.next_u64();
        r.next_gauss() * self.params.sigma_random
    }

    /// Multiplicative delay factor for a delay arc at `site`.
    pub fn factor(&self, site: Site, tag: u64) -> f64 {
        let f = 1.0 + self.gradient(site) + self.random(site, tag);
        f.max(0.5) * self.params.corner.delay_factor()
    }

    /// Apply variation to a nominal delay.
    pub fn apply(&self, nominal: Ps, site: Site, tag: u64) -> Ps {
        nominal.scale(self.factor(site, tag))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn site(x: u16, y: u16) -> Site {
        Site { x, y, slice: 0, lut: 1 }
    }

    #[test]
    fn no_variation_is_identity_at_nominal() {
        let m = VariationModel::new(1, VariationParams::none());
        assert_eq!(m.apply(Ps(500), site(3, 7), 0), Ps(500));
    }

    #[test]
    fn deterministic_per_site_and_tag() {
        let m = VariationModel::new(42, VariationParams::default());
        let a = m.factor(site(10, 20), 0);
        let b = m.factor(site(10, 20), 0);
        assert_eq!(a, b);
        // Different tag ⇒ (almost surely) different factor.
        assert_ne!(m.factor(site(10, 20), 0), m.factor(site(10, 20), 1));
        // Different die ⇒ different field.
        let m2 = VariationModel::new(43, VariationParams::default());
        assert_ne!(m.factor(site(10, 20), 0), m2.factor(site(10, 20), 0));
    }

    #[test]
    fn random_component_has_requested_sigma() {
        let m = VariationModel::new(7, VariationParams {
            sigma_random: 0.03,
            gradient_amplitude: 0.0,
            corner: PvtCorner::nominal(),
        });
        let xs: Vec<f64> = (0..4000)
            .map(|i| m.factor(site((i % 50) as u16, (i / 50) as u16), i as u64) - 1.0)
            .collect();
        let sd = crate::util::stats::std_dev(&xs);
        assert!((sd - 0.03).abs() < 0.004, "σ={sd}");
        assert!(crate::util::stats::mean(&xs).abs() < 0.004);
    }

    #[test]
    fn corners_order_delays() {
        let slow = PvtCorner::slow().delay_factor();
        let nom = PvtCorner::nominal().delay_factor();
        let fast = PvtCorner::fast().delay_factor();
        assert!(fast < nom && nom < slow, "{fast} {nom} {slow}");
    }

    #[test]
    fn gradient_is_smooth() {
        // Neighbouring sites see nearly identical systematic components.
        let m = VariationModel::new(9, VariationParams {
            sigma_random: 0.0,
            gradient_amplitude: 0.02,
            corner: PvtCorner::nominal(),
        });
        let a = m.factor(site(10, 20), 0);
        let b = m.factor(site(10, 21), 0);
        assert!((a - b).abs() < 0.002);
    }
}
