//! Concurrency helpers for the runtime's compile-once caches.

use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};

enum Slot<V> {
    /// Some caller is running the builder for this key right now.
    Building,
    Ready(V),
}

/// A keyed build-at-most-once cache.
///
/// [`OnceMap::get_or_try_insert`] runs the builder *outside* the map
/// lock, so builds for two different keys proceed concurrently while a
/// second request for the *same* key waits on a condvar instead of
/// duplicating the work (the double-lock hazard a check-unlock-build
/// cache invites). A failed build releases its claim so a later caller
/// can retry.
///
/// Used by `ModelRegistry` (backend per model) and `PjrtBackend`
/// (compiled executable per batch size), where a build is an expensive
/// model load or PJRT compilation.
pub struct OnceMap<K, V> {
    slots: Mutex<BTreeMap<K, Slot<V>>>,
    ready: Condvar,
}

impl<K: Ord + Clone, V: Clone> OnceMap<K, V> {
    pub fn new() -> OnceMap<K, V> {
        OnceMap { slots: Mutex::new(BTreeMap::new()), ready: Condvar::new() }
    }

    /// Return the cached value for `key`, or claim the key and run
    /// `build` (outside the lock) to produce it.
    pub fn get_or_try_insert<E>(
        &self,
        key: K,
        build: impl FnOnce() -> Result<V, E>,
    ) -> Result<V, E> {
        {
            let mut slots = self.slots.lock().unwrap();
            loop {
                match slots.get(&key) {
                    Some(Slot::Ready(v)) => return Ok(v.clone()),
                    // Same key in flight elsewhere: wait, don't duplicate.
                    Some(Slot::Building) => {}
                    None => {
                        slots.insert(key.clone(), Slot::Building);
                        break;
                    }
                }
                slots = self.ready.wait(slots).unwrap();
            }
        }
        let result = build();
        let mut slots = self.slots.lock().unwrap();
        match result {
            Ok(v) => {
                slots.insert(key, Slot::Ready(v.clone()));
                self.ready.notify_all();
                Ok(v)
            }
            Err(e) => {
                // Clear the claim so a later caller can retry.
                slots.remove(&key);
                self.ready.notify_all();
                Err(e)
            }
        }
    }
}

impl<K: Ord + Clone, V: Clone> Default for OnceMap<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn builds_once_per_key_under_contention() {
        let map: Arc<OnceMap<usize, usize>> = Arc::new(OnceMap::new());
        let builds = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for t in 0..8 {
            let map = map.clone();
            let builds = builds.clone();
            handles.push(std::thread::spawn(move || {
                let key = t % 2;
                let v = map
                    .get_or_try_insert(key, || {
                        builds.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_millis(10));
                        Ok::<usize, ()>(key * 100)
                    })
                    .unwrap();
                assert_eq!(v, key * 100);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(builds.load(Ordering::SeqCst), 2, "one build per distinct key");
    }

    #[test]
    fn failed_build_releases_claim_for_retry() {
        let map: OnceMap<&'static str, i32> = OnceMap::new();
        let err = map.get_or_try_insert("k", || Err::<i32, &str>("boom"));
        assert_eq!(err.unwrap_err(), "boom");
        let ok = map.get_or_try_insert("k", || Ok::<i32, &str>(7));
        assert_eq!(ok.unwrap(), 7);
        // Cached now: builder must not run again.
        let cached = map.get_or_try_insert("k", || panic!("must not rebuild"));
        assert_eq!(cached.unwrap(), 7);
    }
}
