//! Concurrency helpers for the runtime's compile-once caches.

use std::borrow::Borrow;
use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};

enum Slot<V> {
    /// Some caller is running the builder for this key right now. The
    /// token identifies *which* claim: [`OnceMap::remove`] can release a
    /// claim mid-build, and the builder must not cache its (now stale)
    /// result over whatever claimed the key after it.
    Building { token: u64 },
    Ready(V),
}

struct MapState<K, V> {
    slots: BTreeMap<K, Slot<V>>,
    /// Monotone claim counter; every `Building` slot gets a fresh token.
    next_token: u64,
}

/// A keyed build-at-most-once cache with invalidation.
///
/// [`OnceMap::get_or_try_insert`] runs the builder *outside* the map
/// lock, so builds for two different keys proceed concurrently while a
/// second request for the *same* key waits on a condvar instead of
/// duplicating the work (the double-lock hazard a check-unlock-build
/// cache invites). A failed build releases its claim so a later caller
/// can retry.
///
/// [`OnceMap::remove`] invalidates a key — the primitive model hot-swap
/// stands on. It is safe against an in-flight build of the same key:
/// the claim is token-stamped, so a builder that finishes after its key
/// was removed returns its value to its own caller but does **not**
/// re-cache it, and condvar waiters re-check the slot state when woken
/// (they see the cleared slot and re-claim instead of waiting forever
/// on a build whose claim is gone).
///
/// Used by `ModelRegistry` (backend per model, invalidated on reload)
/// and `PjrtBackend` (compiled executable per batch size), where a
/// build is an expensive model load or PJRT compilation.
pub struct OnceMap<K, V> {
    state: Mutex<MapState<K, V>>,
    ready: Condvar,
}

impl<K: Ord + Clone, V: Clone> OnceMap<K, V> {
    pub fn new() -> OnceMap<K, V> {
        OnceMap {
            state: Mutex::new(MapState { slots: BTreeMap::new(), next_token: 0 }),
            ready: Condvar::new(),
        }
    }

    /// Return the cached value for `key`, or claim the key and run
    /// `build` (outside the lock) to produce it.
    pub fn get_or_try_insert<E>(
        &self,
        key: K,
        build: impl FnOnce() -> Result<V, E>,
    ) -> Result<V, E> {
        let my_token;
        {
            let mut st = self.state.lock().unwrap();
            loop {
                match st.slots.get(&key) {
                    Some(Slot::Ready(v)) => return Ok(v.clone()),
                    // Same key in flight elsewhere: wait, don't duplicate.
                    // The wait loop re-checks on every wake, so a claim
                    // released by `remove` is re-claimed, not waited on.
                    Some(Slot::Building { .. }) => {}
                    None => {
                        my_token = st.next_token;
                        st.next_token += 1;
                        st.slots.insert(key.clone(), Slot::Building { token: my_token });
                        break;
                    }
                }
                st = self.ready.wait(st).unwrap();
            }
        }
        let result = build();
        let mut st = self.state.lock().unwrap();
        // Cache (or clear) only if the claim is still ours. `remove` may
        // have released it mid-build — then the value we just built is
        // stale by definition (the remove *happened after* our build
        // began), so it goes to our caller but never into the cache,
        // and we must not clobber whoever claimed the key after us.
        let still_mine =
            matches!(st.slots.get(&key), Some(Slot::Building { token }) if *token == my_token);
        match result {
            Ok(v) => {
                if still_mine {
                    st.slots.insert(key, Slot::Ready(v.clone()));
                }
                self.ready.notify_all();
                Ok(v)
            }
            Err(e) => {
                if still_mine {
                    // Clear the claim so a later caller can retry.
                    st.slots.remove(&key);
                }
                self.ready.notify_all();
                Err(e)
            }
        }
    }

    /// Invalidate `key`: drop its cached value, or — if a build is in
    /// flight — release that build's claim so the next caller re-builds
    /// (the in-flight result will be returned to its own caller but not
    /// cached). Returns whether an entry (ready or in flight) existed.
    pub fn remove<Q>(&self, key: &Q) -> bool
    where
        K: Borrow<Q>,
        Q: Ord + ?Sized,
    {
        let mut st = self.state.lock().unwrap();
        let removed = st.slots.remove(key).is_some();
        if removed {
            // Wake condvar holders parked on a Building slot we just
            // released: they re-check, see the empty slot, and re-claim.
            self.ready.notify_all();
        }
        removed
    }
}

impl<K: Ord + Clone, V: Clone> Default for OnceMap<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{mpsc, Arc};

    #[test]
    fn builds_once_per_key_under_contention() {
        let map: Arc<OnceMap<usize, usize>> = Arc::new(OnceMap::new());
        let builds = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for t in 0..8 {
            let map = map.clone();
            let builds = builds.clone();
            handles.push(std::thread::spawn(move || {
                let key = t % 2;
                let v = map
                    .get_or_try_insert(key, || {
                        builds.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_millis(10));
                        Ok::<usize, ()>(key * 100)
                    })
                    .unwrap();
                assert_eq!(v, key * 100);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(builds.load(Ordering::SeqCst), 2, "one build per distinct key");
    }

    #[test]
    fn failed_build_releases_claim_for_retry() {
        let map: OnceMap<&'static str, i32> = OnceMap::new();
        let err = map.get_or_try_insert("k", || Err::<i32, &str>("boom"));
        assert_eq!(err.unwrap_err(), "boom");
        let ok = map.get_or_try_insert("k", || Ok::<i32, &str>(7));
        assert_eq!(ok.unwrap(), 7);
        // Cached now: builder must not run again.
        let cached = map.get_or_try_insert("k", || panic!("must not rebuild"));
        assert_eq!(cached.unwrap(), 7);
    }

    #[test]
    fn remove_ready_value_forces_rebuild() {
        let map: OnceMap<&'static str, i32> = OnceMap::new();
        assert!(!map.remove("k"), "removing an absent key reports false");
        assert_eq!(map.get_or_try_insert("k", || Ok::<i32, ()>(1)).unwrap(), 1);
        assert!(map.remove("k"));
        assert_eq!(map.get_or_try_insert("k", || Ok::<i32, ()>(2)).unwrap(), 2);
        assert_eq!(map.get_or_try_insert("k", || panic!("cached")).unwrap(), 2);
    }

    /// The hot-swap race: `remove` lands while a build for the same key
    /// is in flight. The in-flight builder must deliver its value to its
    /// own caller but *not* cache it (it is stale — the invalidation
    /// happened after that build began), and a post-invalidation caller
    /// must rebuild rather than inherit the stale value.
    #[test]
    fn remove_during_inflight_build_does_not_cache_stale_value() {
        let map: Arc<OnceMap<&'static str, i32>> = Arc::new(OnceMap::new());
        let (started_tx, started_rx) = mpsc::channel::<()>();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let stale_builder = {
            let map = map.clone();
            std::thread::spawn(move || {
                map.get_or_try_insert("k", || {
                    started_tx.send(()).unwrap();
                    // Park mid-build (outside the map lock) until the
                    // main thread has removed the key.
                    release_rx.recv().unwrap();
                    Ok::<i32, ()>(1)
                })
            })
        };
        started_rx.recv().unwrap();
        // A second caller that reaches the map while the stale build is
        // still claimed ends up in the condvar wait; give it a head
        // start so `remove`'s notify is what wakes it (the assertion
        // holds either way — a late arrival just sees the empty slot).
        let waiter = {
            let map = map.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                map.get_or_try_insert("k", || Ok::<i32, ()>(2))
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(60));
        assert!(map.remove("k"), "an in-flight claim is removable");
        // The waiter re-checks on wake, re-claims, and builds the fresh
        // value.
        assert_eq!(waiter.join().unwrap().unwrap(), 2);
        // Now let the stale build finish: its own caller gets 1, but the
        // cache must still hold the post-invalidation value.
        release_tx.send(()).unwrap();
        assert_eq!(stale_builder.join().unwrap().unwrap(), 1);
        assert_eq!(
            map.get_or_try_insert("k", || panic!("must not rebuild")).unwrap(),
            2,
            "stale in-flight build must not overwrite the rebuilt value"
        );
    }

    /// A failing stale build must not clear another thread's claim or
    /// cached value.
    #[test]
    fn stale_failed_build_leaves_fresh_value_cached() {
        let map: Arc<OnceMap<&'static str, i32>> = Arc::new(OnceMap::new());
        let (started_tx, started_rx) = mpsc::channel::<()>();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let stale = {
            let map = map.clone();
            std::thread::spawn(move || {
                map.get_or_try_insert("k", || {
                    started_tx.send(()).unwrap();
                    release_rx.recv().unwrap();
                    Err::<i32, &str>("stale boom")
                })
            })
        };
        started_rx.recv().unwrap();
        assert!(map.remove("k"));
        assert_eq!(map.get_or_try_insert("k", || Ok::<i32, &str>(9)).unwrap(), 9);
        release_tx.send(()).unwrap();
        assert_eq!(stale.join().unwrap().unwrap_err(), "stale boom");
        assert_eq!(map.get_or_try_insert("k", || panic!("cached")).unwrap(), 9);
    }
}
