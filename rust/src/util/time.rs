//! Picosecond-resolution simulated time.
//!
//! All hardware delays in the substrate (net delays, LUT delays, PDL
//! elements, clock periods) are integer picoseconds: the paper's measured
//! quantities are in the 60 ps – 650 ps range (Table I), and integer time
//! keeps the event-driven simulator exactly reproducible (no FP drift in
//! event ordering).

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub};

/// A span (or instant) of simulated time in picoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Ps(pub u64);

impl Ps {
    pub const ZERO: Ps = Ps(0);

    pub fn from_ns(ns: f64) -> Ps {
        Ps((ns * 1000.0).round() as u64)
    }

    pub fn from_ps_f64(ps: f64) -> Ps {
        Ps(ps.max(0.0).round() as u64)
    }

    pub fn as_ns(self) -> f64 {
        self.0 as f64 / 1000.0
    }

    pub fn as_ps(self) -> u64 {
        self.0
    }

    pub fn as_ps_f64(self) -> f64 {
        self.0 as f64
    }

    /// Saturating subtraction (useful for skew computations).
    pub fn saturating_sub(self, rhs: Ps) -> Ps {
        Ps(self.0.saturating_sub(rhs.0))
    }

    /// Absolute difference.
    pub fn abs_diff(self, rhs: Ps) -> Ps {
        Ps(self.0.abs_diff(rhs.0))
    }

    /// Scale by a dimensionless factor, rounding to the nearest ps.
    pub fn scale(self, k: f64) -> Ps {
        Ps((self.0 as f64 * k).round().max(0.0) as u64)
    }
}

impl Add for Ps {
    type Output = Ps;
    fn add(self, rhs: Ps) -> Ps {
        Ps(self.0 + rhs.0)
    }
}

impl AddAssign for Ps {
    fn add_assign(&mut self, rhs: Ps) {
        self.0 += rhs.0;
    }
}

impl Sub for Ps {
    type Output = Ps;
    fn sub(self, rhs: Ps) -> Ps {
        Ps(self.0 - rhs.0)
    }
}

impl Mul<u64> for Ps {
    type Output = Ps;
    fn mul(self, rhs: u64) -> Ps {
        Ps(self.0 * rhs)
    }
}

impl Div<u64> for Ps {
    type Output = Ps;
    fn div(self, rhs: u64) -> Ps {
        Ps(self.0 / rhs)
    }
}

impl Sum for Ps {
    fn sum<I: Iterator<Item = Ps>>(iter: I) -> Ps {
        Ps(iter.map(|p| p.0).sum())
    }
}

impl fmt::Display for Ps {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1_000_000 {
            write!(f, "{:.3} µs", self.0 as f64 / 1e6)
        } else if self.0 >= 1_000 {
            write!(f, "{:.3} ns", self.0 as f64 / 1e3)
        } else {
            write!(f, "{} ps", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        assert_eq!(Ps(100) + Ps(50), Ps(150));
        assert_eq!(Ps(100) - Ps(50), Ps(50));
        assert_eq!(Ps(100) * 3, Ps(300));
        assert_eq!(Ps(100) / 4, Ps(25));
        assert_eq!(Ps(100).abs_diff(Ps(130)), Ps(30));
        assert_eq!(Ps(50).saturating_sub(Ps(80)), Ps(0));
    }

    #[test]
    fn conversions_and_display() {
        assert_eq!(Ps::from_ns(1.5), Ps(1500));
        assert_eq!(Ps(1500).as_ns(), 1.5);
        assert_eq!(Ps(375).to_string(), "375 ps");
        assert_eq!(Ps(1500).to_string(), "1.500 ns");
        assert_eq!(Ps(2_500_000).to_string(), "2.500 µs");
    }

    #[test]
    fn scale_rounds() {
        assert_eq!(Ps(100).scale(0.5), Ps(50));
        assert_eq!(Ps(3).scale(0.5), Ps(2)); // round-half-up at .5
        assert_eq!(Ps(100).scale(0.0), Ps(0));
    }

    #[test]
    fn sum_iterator() {
        let total: Ps = [Ps(1), Ps(2), Ps(3)].into_iter().sum();
        assert_eq!(total, Ps(6));
    }
}
