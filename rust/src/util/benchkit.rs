//! Minimal benchmarking harness (criterion is not in the offline vendored
//! crate set — DESIGN.md §7).
//!
//! `cargo bench` targets use `harness = false` and drive this module: each
//! measurement warms up, then runs timed batches until a wall budget is
//! spent, reporting mean / p50 / p99 per iteration. Output format is one
//! line per benchmark, stable for EXPERIMENTS.md extraction:
//!
//! ```text
//! bench <name> ... mean 12.3 µs/iter  p50 11.8  p99 16.0  (n=4096)
//! ```

use std::time::{Duration, Instant};

/// Measure `f` repeatedly; returns per-iteration timings in µs.
pub fn measure(warmup: Duration, budget: Duration, mut f: impl FnMut()) -> Vec<f64> {
    let w0 = Instant::now();
    while w0.elapsed() < warmup {
        f();
    }
    let mut samples = Vec::new();
    let b0 = Instant::now();
    while b0.elapsed() < budget {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    samples
}

/// Run + report one benchmark. Returns the mean µs/iter.
pub fn bench(name: &str, mut f: impl FnMut()) -> f64 {
    let samples = measure(Duration::from_millis(150), Duration::from_millis(700), &mut f);
    report(name, &samples)
}

/// Run + report with custom budgets (for expensive iterations).
pub fn bench_with(name: &str, warmup: Duration, budget: Duration, mut f: impl FnMut()) -> f64 {
    let samples = measure(warmup, budget, &mut f);
    report(name, &samples)
}

fn report(name: &str, samples: &[f64]) -> f64 {
    let s = crate::util::stats::summarize(samples);
    println!(
        "bench {name:<44} mean {:>10.2} µs/iter  p50 {:>9.2}  p99 {:>9.2}  (n={})",
        s.mean, s.p50, s.p99, s.n
    );
    s.mean
}

/// Throughput helper: items/second given mean µs per iteration of `items`.
pub fn throughput(mean_us_per_iter: f64, items_per_iter: usize) -> f64 {
    items_per_iter as f64 / (mean_us_per_iter / 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let samples = measure(
            Duration::from_millis(1),
            Duration::from_millis(10),
            || {
                std::hint::black_box((0..100).sum::<u64>());
            },
        );
        assert!(!samples.is_empty());
        assert!(samples.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn throughput_math() {
        assert!((throughput(1000.0, 32) - 32_000.0).abs() < 1e-6);
    }
}
