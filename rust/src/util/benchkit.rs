//! Minimal benchmarking harness (criterion is not in the offline vendored
//! crate set — DESIGN.md §7).
//!
//! `cargo bench` targets use `harness = false` and drive this module: each
//! measurement warms up, then runs timed batches until a wall budget is
//! spent, reporting mean / p50 / p99 per iteration. Output format is one
//! line per benchmark, stable for EXPERIMENTS.md extraction:
//!
//! ```text
//! bench <name> ... mean 12.3 µs/iter  p50 11.8  p99 16.0  (n=4096)
//! ```

use std::time::{Duration, Instant};

use crate::util::json;

/// Measure `f` repeatedly; returns per-iteration timings in µs.
pub fn measure(warmup: Duration, budget: Duration, mut f: impl FnMut()) -> Vec<f64> {
    let w0 = Instant::now();
    while w0.elapsed() < warmup {
        f();
    }
    let mut samples = Vec::new();
    let b0 = Instant::now();
    while b0.elapsed() < budget {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    samples
}

/// Run + report one benchmark. Returns the mean µs/iter.
pub fn bench(name: &str, mut f: impl FnMut()) -> f64 {
    let samples = measure(Duration::from_millis(150), Duration::from_millis(700), &mut f);
    report(name, &samples)
}

/// Run + report with custom budgets (for expensive iterations).
pub fn bench_with(name: &str, warmup: Duration, budget: Duration, mut f: impl FnMut()) -> f64 {
    let samples = measure(warmup, budget, &mut f);
    report(name, &samples)
}

fn report(name: &str, samples: &[f64]) -> f64 {
    let s = crate::util::stats::summarize(samples);
    println!(
        "bench {name:<44} mean {:>10.2} µs/iter  p50 {:>9.2}  p99 {:>9.2}  (n={})",
        s.mean, s.p50, s.p99, s.n
    );
    s.mean
}

/// Throughput helper: items/second given mean µs per iteration of `items`.
pub fn throughput(mean_us_per_iter: f64, items_per_iter: usize) -> f64 {
    items_per_iter as f64 / (mean_us_per_iter / 1e6)
}

/// Rows/second reporting shared by the throughput benches
/// (`hotpath_forward`, `serving_wire`): one stable printed line per
/// variant plus the computed rate, so the two JSON artifacts
/// (`BENCH_hotpath.json`, `BENCH_serving.json`) stay comparable.
pub fn report_rows_per_s(name: &str, mean_us_per_iter: f64, rows_per_iter: usize) -> f64 {
    let rate = throughput(mean_us_per_iter, rows_per_iter);
    println!("bench {name:<44} {rate:>14.0} rows/s  ({rows_per_iter} rows/iter)");
    rate
}

/// One throughput variant as a JSON object for the bench artifacts:
/// `{"mean_us_per_iter": …, "name": …, "rows_per_s": …}`.
pub fn variant_json(name: &str, mean_us_per_iter: f64, rows_per_s: f64) -> json::Value {
    json::obj(vec![
        ("name", json::s(name)),
        ("mean_us_per_iter", json::num(mean_us_per_iter)),
        ("rows_per_s", json::num(rows_per_s)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let samples = measure(
            Duration::from_millis(1),
            Duration::from_millis(10),
            || {
                std::hint::black_box((0..100).sum::<u64>());
            },
        );
        assert!(!samples.is_empty());
        assert!(samples.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn throughput_math() {
        assert!((throughput(1000.0, 32) - 32_000.0).abs() < 1e-6);
        assert!((report_rows_per_s("t", 1000.0, 32) - 32_000.0).abs() < 1e-6);
    }

    #[test]
    fn variant_json_shape() {
        let v = variant_json("indexed_simd", 12.5, 5_120_000.0);
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "indexed_simd");
        assert_eq!(v.get("rows_per_s").unwrap().as_f64().unwrap(), 5_120_000.0);
        assert_eq!(v.get("mean_us_per_iter").unwrap().as_f64().unwrap(), 12.5);
    }
}
