//! Small statistics toolkit.
//!
//! The paper's evaluation leans on a few specific statistics: Spearman's
//! rank correlation ρ for the PDL monotonicity claim (Fig. 6), mean ± σ
//! bands for the average-case latency (Fig. 10's ±3σ interval), and
//! percentile summaries for the serving-path latency reports.

/// Arithmetic mean. Empty slices return 0.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Fractional ranks with ties averaged (midranks), as Spearman requires.
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        // Average rank for the tie group [i, j] (1-based ranks).
        let r = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = r;
        }
        i = j + 1;
    }
    out
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut dx2 = 0.0;
    let mut dy2 = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        num += dx * dy;
        dx2 += dx * dx;
        dy2 += dy * dy;
    }
    if dx2 == 0.0 || dy2 == 0.0 {
        return 0.0;
    }
    num / (dx2 * dy2).sqrt()
}

/// Spearman's rank correlation ρ — the paper's Fig. 6 monotonicity metric.
/// −1 is a perfectly decreasing monotonic relationship.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

/// Percentile via linear interpolation on the sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Summary of a sample: mean, σ, min, max, p50, p99.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p99: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: std_dev(xs),
        min: xs.iter().copied().fold(f64::INFINITY, f64::min),
        max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        p50: percentile(xs, 50.0),
        p99: percentile(xs, 99.0),
    }
}

/// Simple online latency histogram with fixed log-spaced buckets; used by
/// the coordinator's metrics without allocating per-request.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds in µs (log-spaced), plus +inf overflow.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // 1 µs .. ~16 s in ×2 steps.
        let bounds: Vec<f64> = (0..24).map(|i| 1.0_f64 * (1u64 << i) as f64).collect();
        let n = bounds.len();
        Self { bounds, counts: vec![0; n + 1], total: 0, sum: 0.0 }
    }

    pub fn record(&mut self, value_us: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| value_us <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += value_us;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Merge another histogram's observations into this one (used to
    /// aggregate per-worker latency histograms; both sides use the fixed
    /// bucket layout from [`Histogram::new`]).
    pub fn merge(&mut self, other: &Histogram) {
        debug_assert_eq!(self.bounds, other.bounds, "histogram bucket layouts differ");
        for (a, &b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-quantile observation).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    f64::INFINITY
                };
            }
        }
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn ranks_with_ties() {
        assert_eq!(ranks(&[10.0, 20.0, 20.0, 30.0]), vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn spearman_perfect_monotone() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys_inc: Vec<f64> = xs.iter().map(|x| x * x).collect();
        let ys_dec: Vec<f64> = xs.iter().map(|x| -x * 3.0 + 7.0).collect();
        assert!((spearman(&xs, &ys_inc) - 1.0).abs() < 1e-12);
        assert!((spearman(&xs, &ys_dec) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_noise_is_small() {
        let mut rng = crate::util::SplitMix64::new(5);
        let xs: Vec<f64> = (0..2000).map(|_| rng.next_f64()).collect();
        let ys: Vec<f64> = (0..2000).map(|_| rng.next_f64()).collect();
        assert!(spearman(&xs, &ys).abs() < 0.08);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile(0.5) >= 500.0);
        assert!(h.quantile(0.99) >= 990.0);
        assert!((h.mean() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge_equals_combined_recording() {
        let mut combined = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 1..=100 {
            combined.record(i as f64);
            if i % 2 == 0 {
                a.record(i as f64);
            } else {
                b.record(i as f64);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), combined.count());
        assert!((a.mean() - combined.mean()).abs() < 1e-9);
        assert_eq!(a.quantile(0.5), combined.quantile(0.5));
        assert_eq!(a.quantile(0.99), combined.quantile(0.99));
        // Merging an empty histogram is a no-op.
        a.merge(&Histogram::new());
        assert_eq!(a.count(), combined.count());
    }
}
