//! Miniature property-testing harness (proptest is not in the offline
//! vendored crate set — DESIGN.md §7).
//!
//! Usage mirrors the 80% of proptest this project needs: generate many
//! random cases from a seeded [`SplitMix64`], run the property, and on
//! failure report the case index + seed so the exact case replays
//! deterministically.
//!
//! ```no_run
//! # // no_run: doctest binaries miss the xla_extension rpath in this
//! # // offline image; the same pattern executes in unit tests below.
//! use tdpc::util::prop::check;
//! check("sum is commutative", 200, |g| {
//!     let a = g.int(0, 1000) as u64;
//!     let b = g.int(0, 1000) as u64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::SplitMix64;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: SplitMix64,
    /// Log of drawn values, printed on failure for diagnosis.
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), log: Vec::new() }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        let v = lo + (self.rng.next_u64() % span) as i64;
        self.log.push(format!("int({lo},{hi})={v}"));
        v
    }

    /// Uniform float in `[lo, hi)`.
    pub fn float(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.next_range_f64(lo, hi);
        self.log.push(format!("float({lo},{hi})={v:.6}"));
        v
    }

    /// Bernoulli draw.
    pub fn boolean(&mut self, p: f64) -> bool {
        let v = self.rng.next_bool(p);
        self.log.push(format!("bool({p})={v}"));
        v
    }

    /// Random bit vector of length `n` with ones-density `p`.
    pub fn bits(&mut self, n: usize, p: f64) -> Vec<bool> {
        let v: Vec<bool> = (0..n).map(|_| self.rng.next_bool(p)).collect();
        let ones = v.iter().filter(|&&b| b).count();
        self.log.push(format!("bits(n={n},p={p}) ones={ones}"));
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.next_below(xs.len());
        self.log.push(format!("choose idx={i}"));
        &xs[i]
    }

    /// Access the underlying PRNG (for bulk draws that shouldn't be logged).
    pub fn rng(&mut self) -> &mut SplitMix64 {
        &mut self.rng
    }
}

/// Environment knob so CI can turn case counts up: `TDPC_PROP_CASES`.
fn case_multiplier() -> usize {
    std::env::var("TDPC_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Run `cases` random cases of the property. Panics (with replay info) on
/// the first failing case.
pub fn check(name: &str, cases: usize, mut property: impl FnMut(&mut Gen)) {
    let cases = cases * case_multiplier();
    // Fixed base seed: failures replay without environment coordination.
    let base = 0x7D_C0DE ^ (name.len() as u64) << 32 ^ fnv(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut g);
        }));
        if let Err(payload) = result {
            eprintln!(
                "property {name:?} failed at case {case}/{cases} (seed {seed:#x})\n drawn: {}",
                g.log.join(", ")
            );
            std::panic::resume_unwind(payload);
        }
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add commutes", 100, |g| {
            let a = g.int(-1000, 1000);
            let b = g.int(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_failures() {
        let result = std::panic::catch_unwind(|| {
            check("always fails eventually", 50, |g| {
                let v = g.int(0, 100);
                assert!(v < 101, "ok");
                assert!(v < 5, "should fail for most draws");
            });
        });
        assert!(result.is_err());
    }

    #[test]
    fn bits_density() {
        check("bits density roughly p", 5, |g| {
            let v = g.bits(4000, 0.3);
            let ones = v.iter().filter(|&&b| b).count();
            assert!((ones as f64 / 4000.0 - 0.3).abs() < 0.06);
        });
    }
}
