//! Minimal JSON parser/emitter for the artifact interchange files.
//!
//! The Python build path (`python/compile/aot.py`) writes manifests, trained
//! models, golden vectors and test datasets as JSON; this module reads them
//! on the Rust side. It supports exactly the JSON subset those files use
//! (objects, arrays, strings with standard escapes, f64/i64 numbers, bools,
//! null) — `serde` is not in the offline vendored crate set (DESIGN.md §7).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()?.round() as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_i64()?;
        if v < 0 {
            bail!("expected non-negative integer, got {v}");
        }
        Ok(v as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {}", other.kind()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            other => bail!("expected array, got {}", other.kind()),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Ok(o),
            other => bail!("expected object, got {}", other.kind()),
        }
    }

    /// Object field access with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// `None` if the key is absent, `Some(value)` otherwise.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.get(key),
            _ => None,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at offset {}", p.pos);
    }
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at offset {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        Ok(Value::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume a run of plain bytes at once (bulk of bitstrings).
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                other => bail!("expected ',' or ']', got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                other => bail!("expected ',' or '}}', got {:?}", other.map(|c| c as char)),
            }
        }
    }
}

/// Serialize a [`Value`] to compact JSON text.
pub fn emit(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: object builder for emit paths.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(text: &str) -> Value {
    Value::Str(text.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": 1, "b": [true, null, -2.5e1], "c": "hi\nthere"}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap()[2], Value::Num(-25.0));
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "hi\nthere");
        let emitted = emit(&v);
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn parses_nested_and_empty() {
        let v = parse(r#"{"x": {"y": []}, "z": {}}"#).unwrap();
        assert!(v.get("x").unwrap().get("y").unwrap().as_arr().unwrap().is_empty());
        assert!(v.get("z").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("[1] trailing").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn long_bitstring_fast_path() {
        let bits: String = std::iter::repeat("10").take(10_000).collect();
        let text = format!("{{\"bits\": \"{bits}\"}}");
        let v = parse(&text).unwrap();
        assert_eq!(v.get("bits").unwrap().as_str().unwrap().len(), 20_000);
    }

    #[test]
    fn emit_integers_without_fraction() {
        assert_eq!(emit(&Value::Num(3.0)), "3");
        assert_eq!(emit(&Value::Num(3.25)), "3.25");
    }
}
