//! Deterministic PRNGs.
//!
//! [`SplitMix64`] mirrors `python/compile/tm/datasets.py::SplitMix64`
//! call-for-call (same constants, same Box-Muller branch, same modulo draw)
//! so the Rust substrate regenerates *bit-identical* datasets and noise
//! streams without a Python runtime. `python/tests/test_cross_language.py`
//! and `rust/tests/cross_language.rs` pin the shared stream.

/// splitmix64 (Steele et al.) — the project-wide seedable PRNG.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution (same ladder as Python).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller, cosine branch only — one fresh pair
    /// of uniforms per call, mirroring the Python generator exactly.
    pub fn next_gauss(&mut self) -> f64 {
        let mut u1 = self.next_f64();
        let mut u2 = self.next_f64();
        while u1 <= 1e-12 {
            u1 = self.next_f64();
            u2 = self.next_f64();
        }
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform draw in `0..n` (modulo; fine for `n << 2^64`).
    pub fn next_below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli draw.
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Uniform in `[lo, hi)`.
    pub fn next_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// In-place Fisher–Yates shuffle (same order as the Python helper).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_stream() {
        // First outputs for seed 1234567 — pinned against the Python
        // implementation (see python/tests/test_cross_language.py).
        let mut r = SplitMix64::new(1234567);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        let mut py = SplitMix64::new(1234567);
        assert_eq!(got[0], py.next_u64());
        // Determinism + full-period-ish sanity: no immediate repeats.
        assert_ne!(got[0], got[1]);
        assert_ne!(got[1], got[2]);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(42);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = SplitMix64::new(7);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gauss();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
