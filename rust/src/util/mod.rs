//! In-tree utilities.
//!
//! The offline vendored crate set has no PRNG / stats / JSON / property
//! testing crates, so the pieces this project needs are implemented here
//! (DESIGN.md §7): [`rng`] mirrors the Python build path's `splitmix64`
//! stream bit-for-bit so datasets regenerate identically across languages,
//! [`stats`] provides the Spearman rank correlation the paper's Fig. 6
//! reports, [`json`] is a minimal parser/emitter for the artifact
//! interchange files, and [`prop`] is a small property-testing harness used
//! by the coordinator/substrate invariant tests.

pub mod benchkit;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sha256;
pub mod stats;
pub mod sync;
pub mod time;

pub use rng::SplitMix64;
pub use time::Ps;
