//! SHA-256 (FIPS 180-4), implemented in-tree.
//!
//! The content-addressed artifact store (`tm::artifact`) keys every
//! clause-block object by its SHA-256 digest; the offline vendored crate
//! set has no hashing crate (DESIGN.md §7), so the compression function
//! lives here. Scalar, allocation-free, and fast enough for the store's
//! workload (model payloads are at most a few MB; packing hashes each
//! block once, opening re-hashes to verify).

/// Per-round constants (fractional parts of the cube roots of the first
/// 64 primes).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Incremental SHA-256 state. Feed bytes with [`Sha256::update`], close
/// with [`Sha256::finish`] / [`Sha256::finish_hex`].
pub struct Sha256 {
    /// Working hash state (initialized from the square-root constants).
    h: [u32; 8],
    /// Partial input block awaiting compression.
    block: [u8; 64],
    block_len: usize,
    /// Total message length in bytes (the padded trailer records bits).
    total_len: u64,
}

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 {
            h: [
                0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
                0x1f83d9ab, 0x5be0cd19,
            ],
            block: [0u8; 64],
            block_len: 0,
            total_len: 0,
        }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        // Top up a partial block first.
        if self.block_len > 0 {
            let take = data.len().min(64 - self.block_len);
            self.block[self.block_len..self.block_len + take].copy_from_slice(&data[..take]);
            self.block_len += take;
            data = &data[take..];
            if self.block_len == 64 {
                let block = self.block;
                self.compress(&block);
                self.block_len = 0;
            }
        }
        // Whole blocks straight from the input.
        while data.len() >= 64 {
            let (head, rest) = data.split_at(64);
            let mut block = [0u8; 64];
            block.copy_from_slice(head);
            self.compress(&block);
            data = rest;
        }
        if !data.is_empty() {
            self.block[..data.len()].copy_from_slice(data);
            self.block_len = data.len();
        }
    }

    /// Close the stream: pad (0x80, zeros, 64-bit big-endian bit length)
    /// and return the 32-byte digest.
    pub fn finish(mut self) -> [u8; 32] {
        // Capture the message bit length first: the padding bytes below
        // also go through `update`, but only the pre-padding length is
        // recorded in the trailer.
        let bit_len = self.total_len.wrapping_mul(8);
        self.update(&[0x80]);
        while self.block_len != 56 {
            self.update(&[0x00]);
        }
        // The 8-byte length trailer completes the final block exactly.
        self.update(&bit_len.to_be_bytes());
        debug_assert_eq!(self.block_len, 0);
        let mut out = [0u8; 32];
        for (i, word) in self.h.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    /// Close the stream and render the digest as lowercase hex — the
    /// object-file naming convention of the artifact store.
    pub fn finish_hex(self) -> String {
        let digest = self.finish();
        let mut out = String::with_capacity(64);
        for b in digest {
            out.push(char::from_digit((b >> 4) as u32, 16).unwrap());
            out.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.h[0] = self.h[0].wrapping_add(a);
        self.h[1] = self.h[1].wrapping_add(b);
        self.h[2] = self.h[2].wrapping_add(c);
        self.h[3] = self.h[3].wrapping_add(d);
        self.h[4] = self.h[4].wrapping_add(e);
        self.h[5] = self.h[5].wrapping_add(f);
        self.h[6] = self.h[6].wrapping_add(g);
        self.h[7] = self.h[7].wrapping_add(h);
    }
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot digest of `data`, as lowercase hex.
pub fn hex_digest(data: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(data);
    h.finish_hex()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// FIPS 180-4 / NIST CAVP known-answer vectors.
    #[test]
    fn known_answer_vectors() {
        assert_eq!(
            hex_digest(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex_digest(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex_digest(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // One million 'a's (streamed, exercising the block loop).
        let mut h = Sha256::new();
        let chunk = [b'a'; 10_000];
        for _ in 0..100 {
            h.update(&chunk);
        }
        assert_eq!(
            h.finish_hex(),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    /// Update granularity must not matter (boundary-straddling chunks).
    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0u32..1000).map(|i| (i * 31 % 251) as u8).collect();
        let whole = hex_digest(&data);
        // Anchor to hashlib so chunked-vs-whole agreement can't hide a
        // shared bug.
        assert_eq!(whole, "f3f55c45264850b8475533289ff43ab81fa1eb3bf781267db645e1ce0c193379");
        for chunk_size in [1usize, 7, 63, 64, 65, 129] {
            let mut h = Sha256::new();
            for c in data.chunks(chunk_size) {
                h.update(c);
            }
            assert_eq!(h.finish_hex(), whole, "chunk size {chunk_size}");
        }
    }

    /// Exact-block-length messages (55/56/64 bytes) hit every padding
    /// branch.
    #[test]
    fn padding_boundaries() {
        // Independently computed with Python's hashlib.
        assert_eq!(
            hex_digest(&[b'x'; 55]),
            "d5e285683cd4efc02d021a5c62014694958901005d6f71e89e0989fac77e4072"
        );
        assert_eq!(
            hex_digest(&[b'x'; 56]),
            "04c26261370ee7541549d16dee320c723e3fd14671e66a099afe0a377c16888e"
        );
        assert_eq!(
            hex_digest(&[b'x'; 64]),
            "7ce100971f64e7001e8fe5a51973ecdfe1ced42befe7ee8d5fd6219506b5393c"
        );
    }
}
