//! Dynamic power model (paper Fig. 9c / Fig. 12).
//!
//! Dynamic power is switching energy × switching rate. Each architecture
//! reports a [`ToggleInventory`](crate::baselines::ToggleInventory) — the
//! expected output transitions per inference of each stage plus the number
//! of clocked FFs — and this module converts it to milliwatts at a given
//! inference rate:
//!
//! ```text
//!   P = f_inf · Σ_stage (toggles_stage · E_node)  +  f_clk · N_FF · E_clk
//! ```
//!
//! `E_node` lumps a LUT output + its average routed net at 28 nm / V_nom;
//! `E_clk` is the per-FF clock-pin + amortized clock-tree energy. The
//! asynchronous designs have `N_FF = 0` (no clock tree) — the mechanism
//! behind the paper's "eliminating the clock contributes significantly to
//! dynamic power reduction" observation. Synchronous designs clock at
//! their minimum period regardless of data (f_clk = 1/T_clk), while every
//! design's *logic* switches per inference.

use crate::baselines::{Architecture, DesignParams, ToggleInventory};
use crate::util::Ps;

/// Switching energy of one LUT output transition incl. average net (pJ).
pub const E_NODE_PJ: f64 = 3.4;
/// Per-FF per-cycle clock energy incl. amortized clock tree (pJ).
pub const E_CLK_FF_PJ: f64 = 2.0;
/// PDL delay elements drive short, hand-routed nets: cheaper per toggle.
pub const E_PDL_NODE_PJ: f64 = 2.3;

/// Power decomposition in mW (the stacked bars of Fig. 9c).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PowerBreakdown {
    pub clause_mw: f64,
    pub popcount_mw: f64,
    pub compare_mw: f64,
    pub clock_mw: f64,
    pub control_mw: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.clause_mw + self.popcount_mw + self.compare_mw + self.clock_mw + self.control_mw
    }

    pub fn popcount_compare_share(&self) -> f64 {
        let t = self.total();
        if t == 0.0 {
            return 0.0;
        }
        (self.popcount_mw + self.compare_mw) / t
    }
}

/// Convert a toggle inventory to power at `inference_rate` inferences/s.
/// `clock_period` must be `Some(T_clk)` for synchronous designs (clock
/// runs at 1/T_clk even when data is idle) and `None` for self-timed ones.
pub fn power_from_toggles(
    inv: &ToggleInventory,
    inference_rate_hz: f64,
    clock_period: Option<Ps>,
    pdl_popcount: bool,
) -> PowerBreakdown {
    let f = inference_rate_hz;
    let pj_to_mw = 1e-9; // pJ × Hz = µW·1e-3 ⇒ pJ·Hz·1e-9 = mW
    let e_pop = if pdl_popcount { E_PDL_NODE_PJ } else { E_NODE_PJ };
    let clock_mw = match clock_period {
        Some(t) if t > Ps::ZERO => {
            let f_clk = 1e12 / t.as_ps_f64();
            inv.clocked_ffs as f64 * E_CLK_FF_PJ * f_clk * pj_to_mw
        }
        _ => 0.0,
    };
    PowerBreakdown {
        clause_mw: inv.clause_toggles_per_inference * E_NODE_PJ * f * pj_to_mw,
        popcount_mw: inv.popcount_toggles_per_inference * e_pop * f * pj_to_mw,
        compare_mw: inv.compare_toggles_per_inference * E_NODE_PJ * f * pj_to_mw,
        clock_mw,
        control_mw: inv.control_toggles_per_inference * E_NODE_PJ * f * pj_to_mw,
    }
}

/// Full-architecture power at its own operating point: synchronous designs
/// run at their minimum clock period (one inference per cycle); self-timed
/// ones at their per-inference latency.
pub fn architecture_power(
    arch: &dyn Architecture,
    d: &DesignParams,
    activity: f64,
) -> PowerBreakdown {
    let lat = arch.latency(d).total();
    let rate = if lat > Ps::ZERO { 1e12 / lat.as_ps_f64() } else { 0.0 };
    let inv = arch.toggles(d, activity);
    let clock = if arch.is_synchronous() { Some(lat) } else { None };
    power_from_toggles(&inv, rate, clock, arch.name() == "td-async")
}

/// Iso-throughput operating point (Fig. 9c / Fig. 12): all designs compared
/// at the *same* inference rate so the α-sensitivity of the logic is
/// isolated from throughput differences. Synchronous designs process one
/// inference per cycle, so their clock runs at the comparison rate.
pub fn power_at_rate(
    arch: &dyn Architecture,
    d: &DesignParams,
    activity: f64,
    rate_hz: f64,
) -> PowerBreakdown {
    let inv = arch.toggles(d, activity);
    let clock = if arch.is_synchronous() {
        Some(Ps::from_ps_f64(1e12 / rate_hz))
    } else {
        None
    };
    power_from_toggles(&inv, rate_hz, clock, arch.name() == "td-async")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asynctm::TdAsync;
    use crate::baselines::{Fpt18, GenericAdder};

    #[test]
    fn sync_design_pays_clock_power() {
        let d = DesignParams::synthetic(10, 50, 784);
        let p = architecture_power(&GenericAdder, &d, 0.2);
        assert!(p.clock_mw > 0.0);
        let q = architecture_power(&TdAsync::default(), &d, 0.2);
        assert_eq!(q.clock_mw, 0.0, "async designs have no clock tree");
    }

    #[test]
    fn adder_power_scales_with_activity_td_does_not() {
        // The paper's Fig. 12 mechanism.
        let d = DesignParams::synthetic(6, 100, 200);
        let rate = 1e6;
        let g_lo = power_at_rate(&GenericAdder, &d, 0.1, rate);
        let g_hi = power_at_rate(&GenericAdder, &d, 0.5, rate);
        let t_lo = power_at_rate(&TdAsync::default(), &d, 0.1, rate);
        let t_hi = power_at_rate(&TdAsync::default(), &d, 0.5, rate);
        assert!(g_hi.popcount_mw > 4.0 * g_lo.popcount_mw);
        assert_eq!(t_lo.popcount_mw, t_hi.popcount_mw);
    }

    #[test]
    fn fig12_crossover_exists() {
        // At α=0.1 the adder *popcount* is cheaper; at α=0.5 the TD
        // popcount must win (same inference rate — Fig. 12's comparison).
        let d = DesignParams::synthetic(6, 100, 200);
        let rate = 1e6;
        let pc = |p: PowerBreakdown| p.popcount_mw;
        let g01 = pc(power_at_rate(&GenericAdder, &d, 0.1, rate));
        let g05 = pc(power_at_rate(&GenericAdder, &d, 0.5, rate));
        let t01 = pc(power_at_rate(&TdAsync::default(), &d, 0.1, rate));
        let t05 = pc(power_at_rate(&TdAsync::default(), &d, 0.5, rate));
        assert!(g01 < t01, "adder wins at low activity: {g01:.3} vs {t01:.3}");
        assert!(g05 > t05, "TD wins at high activity: {g05:.3} vs {t05:.3}");
    }

    #[test]
    fn fpt18_popcount_power_below_td_but_arch_above() {
        // Fig. 9c's nuance: FPT'18's popcount alone is cheaper than the
        // TD popcount, yet the full synchronous architecture costs more
        // than the full async one (clock tree + comparator) at the same
        // throughput.
        let d = DesignParams::synthetic(10, 100, 784);
        let f = power_at_rate(&Fpt18, &d, 0.15, 1e6);
        let t = power_at_rate(&TdAsync::default(), &d, 0.15, 1e6);
        assert!(f.popcount_mw < t.popcount_mw, "{} vs {}", f.popcount_mw, t.popcount_mw);
        assert!(f.total() > t.total(), "{} vs {}", f.total(), t.total());
    }

    #[test]
    fn power_linear_in_rate() {
        let d = DesignParams::synthetic(6, 50, 200);
        let a = power_at_rate(&TdAsync::default(), &d, 0.3, 1e6).total();
        let b = power_at_rate(&TdAsync::default(), &d, 0.3, 2e6).total();
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
