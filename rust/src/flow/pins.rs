//! Pin-assignment pass (paper §III-B.2).
//!
//! Each delay element is a LUT configured as a 2:1 mux whose two data
//! inputs arrive over the low- and high-latency nets. The paper audits the
//! minimal net delay of every physical pin (its Fig. 2 inset) and maps the
//! low-latency net to the *fastest* pin and the high-latency net to the
//! *second-fastest* — minimizing overall latency while keeping the delta
//! between the two nets controllable by routing alone.

use crate::fabric::LutPin;
use crate::util::Ps;

/// The chosen physical pins for the two inputs of every delay element.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PinAssignment {
    /// Pin carrying the low-latency net.
    pub lo_pin: LutPin,
    /// Pin carrying the high-latency net.
    pub hi_pin: LutPin,
}

impl PinAssignment {
    /// The paper's assignment: fastest (A6) and second-fastest (A5) pins.
    pub fn fastest_pair() -> Self {
        let ranked = LutPin::ranked();
        Self { lo_pin: ranked[0], hi_pin: ranked[1] }
    }

    /// Minimum achievable net delays implied by the pin choice: routing can
    /// only *add* delay on top of the pin's base reach.
    pub fn min_net_delays(&self) -> (Ps, Ps) {
        (self.lo_pin.base_net_delay(), self.hi_pin.base_net_delay())
    }

    /// The structural delta floor between the nets if both were routed at
    /// their minimum (the granularity the routing pass must beat).
    pub fn min_delta(&self) -> Ps {
        self.hi_pin
            .base_net_delay()
            .saturating_sub(self.lo_pin.base_net_delay())
    }
}

/// Audit table of all pins ranked by minimal net delay — the data behind
/// the paper's pinout-selection figure.
pub fn pin_audit() -> Vec<(LutPin, Ps)> {
    LutPin::ranked()
        .into_iter()
        .map(|p| (p, p.base_net_delay()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fastest_pair_is_a6_a5() {
        let pa = PinAssignment::fastest_pair();
        assert_eq!(pa.lo_pin, LutPin::A6);
        assert_eq!(pa.hi_pin, LutPin::A5);
        assert!(pa.min_net_delays().0 < pa.min_net_delays().1);
    }

    #[test]
    fn audit_is_sorted_fastest_first() {
        let audit = pin_audit();
        assert_eq!(audit.len(), 6);
        for w in audit.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(audit[0].0, LutPin::A6);
    }

    #[test]
    fn min_delta_positive() {
        assert!(PinAssignment::fastest_pair().min_delta() > Ps::ZERO);
    }
}
