//! Routing pass (paper §III-B.3, Fig. 5).
//!
//! For every delay element the low- and high-latency nets are routed under
//! *delay-range constraints* (the paper's `set_property FIXED_ROUTE` /
//! delay-window Tcl idiom): the router detours the net until its delay
//! falls inside the requested window, in steps of the routing granularity.
//! Because the placement pass put every element at the same geometric
//! position relative to its switchbox, applying identical windows yields
//! symmetric routing across PDLs — *up to* intra-die variation, which this
//! model samples per arc from [`crate::fabric::VariationModel`] (that
//! residual asymmetry is exactly what Fig. 6 studies).

use crate::fabric::{Device, Site, VariationModel, LUT_LOGIC_DELAY};
use crate::util::Ps;

use super::pins::PinAssignment;
use super::placement::PdlPlacement;
use super::FlowConfig;

/// Routed delay arcs of one delay element.
#[derive(Debug, Clone, Copy)]
pub struct RoutedElement {
    pub site: Site,
    /// Achieved *net* delays (nominal, post-quantization, pre-variation).
    pub lo_net: Ps,
    pub hi_net: Ps,
    /// Total stage traversal delays (net + LUT logic, with variation):
    /// the per-stage delay the PDL adds when the mux selects each input.
    pub lo_total: Ps,
    pub hi_total: Ps,
}

impl RoutedElement {
    /// The usable timing resolution of this stage.
    pub fn delta(&self) -> Ps {
        self.hi_total.saturating_sub(self.lo_total)
    }
}

/// One fully routed PDL.
#[derive(Debug, Clone)]
pub struct RoutedPdl {
    pub index: usize,
    pub elements: Vec<RoutedElement>,
}

impl RoutedPdl {
    pub fn len(&self) -> usize {
        self.elements.len()
    }

    pub fn is_empty(&self) -> bool {
        self.elements.is_empty()
    }

    /// Cumulative delay if every stage selects the low-latency input
    /// (fastest possible traversal — all-ones input on a positive PDL).
    pub fn min_traversal(&self) -> Ps {
        self.elements.iter().map(|e| e.lo_total).sum()
    }

    /// Cumulative delay if every stage selects the high-latency input
    /// (the critical path the paper's §IV-A discusses).
    pub fn max_traversal(&self) -> Ps {
        self.elements.iter().map(|e| e.hi_total).sum()
    }

    /// Mean per-stage hi−lo delta (the PDL's timing resolution).
    pub fn mean_delta(&self) -> Ps {
        if self.elements.is_empty() {
            return Ps::ZERO;
        }
        let sum: u64 = self.elements.iter().map(|e| e.delta().0).sum();
        Ps(sum / self.elements.len() as u64)
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum RoutingError {
    #[error("low-latency target {target} below minimum achievable {min} for pin")]
    LoTargetTooFast { target: Ps, min: Ps },
    #[error("high-latency target {target} below minimum achievable {min} for pin")]
    HiTargetTooFast { target: Ps, min: Ps },
    #[error("high-latency target {hi} not above low-latency target {lo}")]
    InvertedTargets { lo: Ps, hi: Ps },
}

/// Quantize `target` up to the router granularity grid.
fn quantize_up(target: Ps, granularity: Ps) -> Ps {
    let g = granularity.0.max(1);
    Ps(target.0.div_ceil(g) * g)
}

/// Route one PDL under the config's delay windows.
///
/// Variation tags: arc `2*i` is element `i`'s low net, `2*i + 1` its high
/// net — each arc of each element varies independently, like distinct
/// physical wire segments.
pub fn route_pdl(
    device: &Device,
    placement: &PdlPlacement,
    pins: &PinAssignment,
    cfg: &FlowConfig,
    variation: &VariationModel,
) -> Result<RoutedPdl, RoutingError> {
    let (lo_min, hi_min) = pins.min_net_delays();
    // Inter-CLB reach: consecutive elements are in adjacent CLBs (placement
    // invariant), so the net must cross at least one switchbox.
    let lo_floor = lo_min + device.net_delay(placement.sites[0], placement.sites[1.min(placement.sites.len() - 1)]);
    let hi_floor = hi_min + device.net_delay(placement.sites[0], placement.sites[1.min(placement.sites.len() - 1)]);

    if cfg.lo_target < lo_floor {
        return Err(RoutingError::LoTargetTooFast { target: cfg.lo_target, min: lo_floor });
    }
    if cfg.hi_target < hi_floor {
        return Err(RoutingError::HiTargetTooFast { target: cfg.hi_target, min: hi_floor });
    }
    if cfg.hi_target <= cfg.lo_target {
        return Err(RoutingError::InvertedTargets { lo: cfg.lo_target, hi: cfg.hi_target });
    }

    let lo_net = quantize_up(cfg.lo_target, cfg.granularity);
    let hi_net = quantize_up(cfg.hi_target, cfg.granularity);

    let elements = placement
        .sites
        .iter()
        .enumerate()
        .map(|(i, &site)| {
            let lo_total = variation.apply(lo_net + LUT_LOGIC_DELAY, site, 2 * i as u64);
            let hi_total = variation.apply(hi_net + LUT_LOGIC_DELAY, site, 2 * i as u64 + 1);
            RoutedElement { site, lo_net, hi_net, lo_total, hi_total }
        })
        .collect();

    Ok(RoutedPdl { index: placement.index, elements })
}

/// Route the start-distribution and arbiter-side nets: the arbiter's two
/// NAND gates are placed symmetrically between the PDL end columns, so both
/// PDL→arbiter nets get the same window. Returns the (identical nominal)
/// net delay each PDL output sees to the arbiter, with per-arc variation.
pub fn route_arbiter_net(
    pdl_end: Site,
    arbiter_site: Site,
    device: &Device,
    variation: &VariationModel,
    tag: u64,
) -> Ps {
    let nominal = device.net_delay(pdl_end, arbiter_site) + Ps(60); // local fanin
    variation.apply(nominal, arbiter_site, tag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::VariationParams;
    use crate::flow::placement::place_pdls;
    use crate::util::prop;

    fn setup(n: usize) -> (Device, PdlPlacement) {
        let d = Device::xc7z020();
        let p = place_pdls(&d, 1, n).unwrap().remove(0);
        (d, p)
    }

    #[test]
    fn rejects_impossible_windows() {
        let (d, p) = setup(10);
        let pins = PinAssignment::fastest_pair();
        let var = VariationModel::new(0, VariationParams::none());
        let too_fast = FlowConfig::ideal(Ps(10), Ps(600));
        assert!(matches!(
            route_pdl(&d, &p, &pins, &too_fast, &var),
            Err(RoutingError::LoTargetTooFast { .. })
        ));
        let inverted = FlowConfig::ideal(Ps(600), Ps(500));
        assert!(matches!(
            route_pdl(&d, &p, &pins, &inverted, &var),
            Err(RoutingError::InvertedTargets { .. })
        ));
    }

    #[test]
    fn quantizes_to_granularity() {
        let (d, p) = setup(5);
        let pins = PinAssignment::fastest_pair();
        let var = VariationModel::new(0, VariationParams::none());
        let mut cfg = FlowConfig::ideal(Ps(401), Ps(633));
        cfg.granularity = Ps(10);
        let r = route_pdl(&d, &p, &pins, &cfg, &var).unwrap();
        assert_eq!(r.elements[0].lo_net, Ps(410));
        assert_eq!(r.elements[0].hi_net, Ps(640));
    }

    #[test]
    fn totals_include_lut_logic_delay() {
        let (d, p) = setup(5);
        let pins = PinAssignment::fastest_pair();
        let var = VariationModel::new(0, VariationParams::none());
        let cfg = FlowConfig::ideal(Ps(400), Ps(620));
        let r = route_pdl(&d, &p, &pins, &cfg, &var).unwrap();
        assert_eq!(r.elements[0].lo_total, Ps(400) + LUT_LOGIC_DELAY);
        assert_eq!(r.elements[0].hi_total, Ps(620) + LUT_LOGIC_DELAY);
        assert_eq!(r.min_traversal(), (Ps(400) + LUT_LOGIC_DELAY) * 5);
        assert_eq!(r.max_traversal(), (Ps(620) + LUT_LOGIC_DELAY) * 5);
    }

    #[test]
    fn variation_perturbs_but_preserves_scale() {
        let (d, p) = setup(150);
        let pins = PinAssignment::fastest_pair();
        let var = VariationModel::new(3, VariationParams::default());
        let cfg = FlowConfig::table1_default();
        let r = route_pdl(&d, &p, &pins, &cfg, &var).unwrap();
        let mean_lo = r.elements.iter().map(|e| e.lo_total.0 as f64).sum::<f64>() / 150.0;
        let nominal = (cfg.lo_target + LUT_LOGIC_DELAY).0 as f64;
        assert!((mean_lo / nominal - 1.0).abs() < 0.02, "mean {mean_lo} vs {nominal}");
        // Not all identical (variation active).
        let first = r.elements[0].lo_total;
        assert!(r.elements.iter().any(|e| e.lo_total != first));
    }

    #[test]
    fn prop_hi_always_above_lo_when_window_wide() {
        prop::check("hi window stays above lo under variation", 30, |g| {
            let (d, p) = setup(g.int(5, 150) as usize);
            let pins = PinAssignment::fastest_pair();
            let var = VariationModel::new(g.int(0, 1000) as u64, VariationParams::default());
            let hi = 600 + g.int(0, 400) as u64;
            let cfg = FlowConfig {
                lo_target: Ps(380),
                hi_target: Ps(hi),
                granularity: Ps(5),
                variation: VariationParams::default(),
                die_seed: 0,
            };
            let r = route_pdl(&d, &p, &pins, &cfg, &var).unwrap();
            // With a ≥220 ps window and σ=2 % of ~500 ps ≈ 10 ps, hi > lo
            // must hold for every stage (>>6σ margin).
            for e in &r.elements {
                assert!(e.hi_total > e.lo_total, "stage inversion: {e:?}");
            }
        });
    }
}
