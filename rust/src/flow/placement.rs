//! Placement pass (paper §III-B.1, Fig. 4).
//!
//! PDLs are aligned vertically: each delay element occupies the same
//! designated LUT of the same slice in its CLB, cascaded elements sit in
//! *adjacent* CLBs (minimizing inter-element net length), and every PDL is
//! mapped to CLB columns positioned identically relative to their
//! neighbouring switchboxes. When a PDL is longer than the device column,
//! the chain folds serpentine-style into the next column — the fold pattern
//! is identical across PDLs, preserving the symmetry the routing pass
//! relies on.

use crate::fabric::{Device, Site};

/// The designated relative position of every delay element (Fig. 4:
/// "a designated LUT in a particular slice of each CLB").
pub const ELEMENT_SLICE: u8 = 0;
pub const ELEMENT_LUT: u8 = 1;

/// Columns consumed per PDL (serpentine fold width): just wide enough for
/// the chain, so many short PDLs (large class counts) and few long PDLs
/// (large clause counts) both fit the device.
fn cols_per_pdl(n_elements: usize, rows: u16) -> u16 {
    (n_elements.div_ceil(rows.max(1) as usize)).max(1) as u16
}

/// One placed PDL: the ordered CLB sites of its delay elements.
#[derive(Debug, Clone)]
pub struct PdlPlacement {
    /// Index of this PDL (class index in the TM case study).
    pub index: usize,
    /// Base CLB column of this PDL's serpentine strip.
    pub base_col: u16,
    /// Site of each delay element, in chain order.
    pub sites: Vec<Site>,
}

impl PdlPlacement {
    /// Chain-order adjacency audit: max CLB distance between consecutive
    /// elements (1 everywhere except at serpentine folds, where it is also
    /// 1 because the fold moves one column sideways).
    pub fn max_hop(&self) -> u32 {
        self.sites
            .windows(2)
            .map(|w| w[0].clb_distance(w[1]))
            .max()
            .unwrap_or(0)
    }

    /// The fold pattern as (column offset, row) pairs — two placements are
    /// geometrically symmetric iff these are identical.
    pub fn pattern(&self) -> Vec<(u16, u16)> {
        self.sites
            .iter()
            .map(|s| (s.x - self.base_col, s.y))
            .collect()
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum PlacementError {
    #[error("{needed} PDLs × {cols_per} columns exceed device width {available}")]
    TooManyPdls { needed: usize, cols_per: u16, available: u16 },
    #[error("PDL of {elements} elements does not fit {capacity} sites in {cols} columns")]
    PdlTooLong { elements: usize, capacity: usize, cols: u16 },
    #[error("zero-length PDL")]
    Empty,
}

/// Place `n_pdls` PDLs of `n_elements` delay elements each.
///
/// Every PDL gets its own `COLS_PER_PDL`-column strip; within the strip the
/// chain walks up column 0, then down column 1 (serpentine). All PDLs share
/// the same fold pattern ⇒ identical geometry relative to their switchboxes.
pub fn place_pdls(
    device: &Device,
    n_pdls: usize,
    n_elements: usize,
) -> Result<Vec<PdlPlacement>, PlacementError> {
    if n_elements == 0 {
        return Err(PlacementError::Empty);
    }
    let cols_per = cols_per_pdl(n_elements, device.rows);
    let needed_cols = n_pdls as u16 * cols_per;
    if needed_cols > device.cols {
        return Err(PlacementError::TooManyPdls {
            needed: n_pdls,
            cols_per,
            available: device.cols,
        });
    }
    let capacity = (device.rows as usize) * (cols_per as usize);
    if n_elements > capacity {
        return Err(PlacementError::PdlTooLong {
            elements: n_elements,
            capacity,
            cols: cols_per,
        });
    }

    let mut out = Vec::with_capacity(n_pdls);
    for p in 0..n_pdls {
        let base_col = p as u16 * cols_per;
        let mut sites = Vec::with_capacity(n_elements);
        for i in 0..n_elements {
            let (dx, y) = serpentine(i, device.rows);
            sites.push(Site {
                x: base_col + dx,
                y,
                slice: ELEMENT_SLICE,
                lut: ELEMENT_LUT,
            });
        }
        out.push(PdlPlacement { index: p, base_col, sites });
    }
    Ok(out)
}

/// Serpentine coordinates: walk up column 0, fold, walk down column 1.
fn serpentine(i: usize, rows: u16) -> (u16, u16) {
    let rows = rows as usize;
    let col = i / rows;
    let pos = i % rows;
    let y = if col % 2 == 0 { pos } else { rows - 1 - pos };
    (col as u16, y as u16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn adjacent_elements_are_adjacent_clbs() {
        let d = Device::xc7z020();
        let pls = place_pdls(&d, 4, 150).unwrap();
        for p in &pls {
            assert_eq!(p.max_hop(), 1, "cascaded elements must sit in adjacent CLBs");
        }
    }

    #[test]
    fn placements_are_geometrically_symmetric() {
        let d = Device::xc7z020();
        let pls = place_pdls(&d, 6, 150).unwrap();
        let pattern = pls[0].pattern();
        for p in &pls[1..] {
            assert_eq!(p.pattern(), pattern, "all PDLs must share the fold pattern");
        }
    }

    #[test]
    fn all_elements_at_designated_lut() {
        let d = Device::xc7z020();
        for p in place_pdls(&d, 3, 140).unwrap() {
            for s in &p.sites {
                assert_eq!(s.rel(), (ELEMENT_SLICE, ELEMENT_LUT));
                assert!(d.contains(*s));
            }
        }
    }

    #[test]
    fn rejects_oversize_requests() {
        let d = Device::xc7z020();
        // 51 one-column PDLs exceed the 50-column device.
        assert!(matches!(
            place_pdls(&d, 51, 10),
            Err(PlacementError::TooManyPdls { .. })
        ));
        // 26 two-column PDLs exceed it as well.
        assert!(matches!(
            place_pdls(&d, 26, 150),
            Err(PlacementError::TooManyPdls { .. })
        ));
        assert!(matches!(place_pdls(&d, 1, 0), Err(PlacementError::Empty)));
    }

    #[test]
    fn wide_and_narrow_workloads_fit() {
        let d = Device::xc7z020();
        // Fig. 10a extreme: 6 classes × 400 clauses.
        let long = place_pdls(&d, 6, 400).unwrap();
        assert_eq!(long[0].sites.len(), 400);
        assert_eq!(long[0].max_hop(), 1);
        // Fig. 10b extreme: 32 classes × 100 clauses.
        let many = place_pdls(&d, 32, 100).unwrap();
        assert_eq!(many.len(), 32);
    }

    #[test]
    fn prop_no_site_shared_between_pdls() {
        prop::check("placement sites disjoint", 40, |g| {
            let d = Device::xc7z020();
            let n_pdls = g.int(1, 10) as usize;
            let n_el = g.int(1, 260) as usize;
            if let Ok(pls) = place_pdls(&d, n_pdls, n_el) {
                let mut seen = std::collections::HashSet::new();
                for p in &pls {
                    for s in &p.sites {
                        assert!(seen.insert(*s), "site {s:?} placed twice");
                    }
                }
            }
        });
    }

    #[test]
    fn prop_serpentine_is_injective_and_adjacent() {
        prop::check("serpentine adjacency", 30, |g| {
            let rows = g.int(2, 200) as u16;
            let n = g.int(2, 2 * rows as i64) as usize;
            let coords: Vec<_> = (0..n).map(|i| serpentine(i, rows)).collect();
            for w in coords.windows(2) {
                let dx = w[0].0.abs_diff(w[1].0);
                let dy = w[0].1.abs_diff(w[1].1);
                assert_eq!(dx + dy, 1, "chain must step one CLB at a time");
            }
        });
    }
}
