//! The paper's FPGA implementation flow (Fig. 3), as executable passes.
//!
//! ```text
//!   placement  →  pin assignment  →  routing  →  HW-response evaluation
//!   (Fig. 4)      (A6/A5, Fig. 2)    (delay ranges, Fig. 5)   (Fig. 6)
//! ```
//!
//! Each pass mirrors one step the paper performs with Vivado Tcl scripts:
//!
//! * [`placement`] — symmetric vertical PDL columns, one delay element per
//!   CLB at an identical relative (slice, LUT) position, cascaded elements
//!   in adjacent CLBs (paper §III-B.1, Fig. 4);
//! * [`pins`] — low-/high-latency nets onto the fastest / second-fastest
//!   physical LUT pins (paper §III-B.2, UG912);
//! * [`routing`] — delay-range-constrained routing of both nets of every
//!   element, identical constraints across all PDLs so routing is symmetric
//!   (paper §III-B.3, Fig. 5), on top of the [`crate::fabric`] variation
//!   model;
//! * [`skew`] — the audit the paper argues is mandatory: per-stage and
//!   cumulative skew between PDLs, and the Hamming-weight monotonicity
//!   check of §III-B.4 (Spearman ρ, Fig. 6).
//!
//! The flow's product is a [`routing::RoutedPdl`] per class, consumed by
//! [`crate::pdl::Pdl`].

pub mod placement;
pub mod pins;
pub mod routing;
pub mod skew;

use crate::fabric::{Device, VariationModel, VariationParams};
use crate::util::Ps;

pub use placement::{place_pdls, PdlPlacement, PlacementError};
pub use pins::PinAssignment;
pub use routing::{route_pdl, RoutedElement, RoutedPdl, RoutingError};
pub use skew::{hamming_response, skew_report, HammingResponse, SkewReport};

/// Full configuration of one flow run.
#[derive(Debug, Clone)]
pub struct FlowConfig {
    /// Target *net* delay of the low-latency input (the flow routes the
    /// low net as fast as it can and clamps to this if larger).
    pub lo_target: Ps,
    /// Target *net* delay of the high-latency input — the paper tunes this
    /// (trial and error, §IV-B) until accuracy is lossless.
    pub hi_target: Ps,
    /// Router delay granularity: achieved delays quantize to this step.
    pub granularity: Ps,
    /// Intra-die variation / PVT corner of the die being targeted.
    pub variation: VariationParams,
    /// Die seed (which simulated chip we are placing onto).
    pub die_seed: u64,
}

impl FlowConfig {
    /// Defaults matching Table I's averages: low 384.5 ps, high 617.6 ps.
    /// (380 ps is the fabric's minimum achievable low-latency net: A6 base
    /// reach + one switchbox hop, quantized.)
    pub fn table1_default() -> Self {
        Self {
            lo_target: Ps(380),
            hi_target: Ps(618),
            granularity: Ps(5),
            variation: VariationParams::default(),
            die_seed: 1,
        }
    }

    /// Idealized flow (no variation) for algorithm-level tests.
    pub fn ideal(lo: Ps, hi: Ps) -> Self {
        Self {
            lo_target: lo,
            hi_target: hi,
            granularity: Ps(1),
            variation: VariationParams::none(),
            die_seed: 0,
        }
    }

    pub fn with_hi_target(mut self, hi: Ps) -> Self {
        self.hi_target = hi;
        self
    }
}

/// Run the complete flow: place `n_pdls` PDLs of `n_elements` each, assign
/// pins, route under `cfg`, and return the routed PDLs.
pub fn run(
    device: &Device,
    n_pdls: usize,
    n_elements: usize,
    cfg: &FlowConfig,
) -> Result<Vec<RoutedPdl>, FlowError> {
    let placements = place_pdls(device, n_pdls, n_elements)?;
    let pins = PinAssignment::fastest_pair();
    let var = VariationModel::new(cfg.die_seed, cfg.variation);
    let mut out = Vec::with_capacity(n_pdls);
    for p in &placements {
        out.push(route_pdl(device, p, &pins, cfg, &var)?);
    }
    Ok(out)
}

/// Errors from any pass of the flow.
#[derive(Debug, thiserror::Error)]
pub enum FlowError {
    #[error("placement failed: {0}")]
    Placement(#[from] PlacementError),
    #[error("routing failed: {0}")]
    Routing(#[from] RoutingError),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_flow_produces_symmetric_pdls() {
        let device = Device::xc7z020();
        let cfg = FlowConfig::ideal(Ps(380), Ps(620));
        let pdls = run(&device, 3, 50, &cfg).unwrap();
        assert_eq!(pdls.len(), 3);
        // With no variation, all PDLs must be delay-identical stage by stage.
        for i in 0..50 {
            assert_eq!(pdls[0].elements[i].lo_total, pdls[1].elements[i].lo_total);
            assert_eq!(pdls[1].elements[i].hi_total, pdls[2].elements[i].hi_total);
        }
    }

    #[test]
    fn flow_respects_targets_in_ideal_conditions() {
        let device = Device::xc7z020();
        let cfg = FlowConfig::ideal(Ps(400), Ps(700));
        let pdls = run(&device, 1, 20, &cfg).unwrap();
        for e in &pdls[0].elements {
            assert_eq!(e.lo_net, Ps(400));
            assert_eq!(e.hi_net, Ps(700));
        }
    }
}
