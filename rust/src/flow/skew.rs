//! Skew audit + Hamming-weight response evaluation (paper §III-B.4, Fig. 6).
//!
//! The paper stresses that PDL popcount only works if placement/routing
//! keep the PDLs physically uniform: routing delays dominate logic delays
//! on FPGAs, so an unaudited implementation skews the Hamming-weight →
//! delay relationship. [`skew_report`] quantifies the residual per-stage
//! and cumulative mismatch between routed PDLs; [`hamming_response`]
//! reproduces the Fig. 6 measurement (mean traversal delay per input
//! Hamming weight + Spearman's ρ).

use crate::util::{stats, Ps, SplitMix64};

use super::routing::RoutedPdl;

/// Pairwise uniformity report across a set of routed PDLs.
#[derive(Debug, Clone)]
pub struct SkewReport {
    /// Max |lo_total(a,i) − lo_total(b,i)| over all stages i and PDL pairs.
    pub max_stage_skew_lo: Ps,
    /// Same for the high-latency arcs.
    pub max_stage_skew_hi: Ps,
    /// Max |Σlo(a) − Σlo(b)| — cumulative fast-path mismatch.
    pub max_cumulative_skew_lo: Ps,
    /// Max |Σhi(a) − Σhi(b)| — cumulative slow-path mismatch.
    pub max_cumulative_skew_hi: Ps,
    /// Mean per-stage hi−lo delta across all PDLs (timing resolution).
    pub mean_delta: Ps,
}

impl SkewReport {
    /// The paper's safety criterion: cumulative skew between PDLs must stay
    /// below one stage delta, otherwise two equal Hamming weights can order
    /// incorrectly at the arbiter.
    pub fn is_safe(&self) -> bool {
        self.max_cumulative_skew_lo < self.mean_delta
            && self.max_cumulative_skew_hi < self.mean_delta
    }
}

/// Compute the uniformity report for a set of routed PDLs (same length).
pub fn skew_report(pdls: &[RoutedPdl]) -> SkewReport {
    assert!(!pdls.is_empty());
    let n = pdls[0].len();
    assert!(pdls.iter().all(|p| p.len() == n), "PDLs must be equal length");

    let mut max_stage_lo = Ps::ZERO;
    let mut max_stage_hi = Ps::ZERO;
    let mut max_cum_lo = Ps::ZERO;
    let mut max_cum_hi = Ps::ZERO;
    for a in 0..pdls.len() {
        for b in a + 1..pdls.len() {
            for i in 0..n {
                let ea = &pdls[a].elements[i];
                let eb = &pdls[b].elements[i];
                max_stage_lo = max_stage_lo.max(ea.lo_total.abs_diff(eb.lo_total));
                max_stage_hi = max_stage_hi.max(ea.hi_total.abs_diff(eb.hi_total));
            }
            max_cum_lo = max_cum_lo.max(pdls[a].min_traversal().abs_diff(pdls[b].min_traversal()));
            max_cum_hi = max_cum_hi.max(pdls[a].max_traversal().abs_diff(pdls[b].max_traversal()));
        }
    }
    let mean_delta = {
        let total: u64 = pdls.iter().map(|p| p.mean_delta().0).sum();
        Ps(total / pdls.len() as u64)
    };
    SkewReport {
        max_stage_skew_lo: max_stage_lo,
        max_stage_skew_hi: max_stage_hi,
        max_cumulative_skew_lo: max_cum_lo,
        max_cumulative_skew_hi: max_cum_hi,
        mean_delta,
    }
}

/// Fig. 6 data: mean PDL traversal delay per input Hamming weight.
#[derive(Debug, Clone)]
pub struct HammingResponse {
    /// Hamming weights 0..=n.
    pub weights: Vec<usize>,
    /// Mean traversal delay per weight (ns for plotting parity with Fig. 6).
    pub mean_delay_ns: Vec<f64>,
    /// σ of the traversal delay per weight.
    pub std_delay_ns: Vec<f64>,
    /// Spearman's ρ between weight and mean delay (paper: ≈ −1).
    pub spearman_rho: f64,
    /// True iff mean delay is strictly decreasing in weight.
    pub strictly_monotonic: bool,
}

/// Traversal delay of a positive-polarity PDL for an input bit vector:
/// bit = 1 selects the low-latency arc, bit = 0 the high-latency arc
/// (paper §III-A.1).
pub fn traversal_delay(pdl: &RoutedPdl, bits: &[bool]) -> Ps {
    debug_assert_eq!(bits.len(), pdl.len());
    let mut t = 0u64;
    for (e, &b) in pdl.elements.iter().zip(bits) {
        t += if b { e.lo_total.0 } else { e.hi_total.0 };
    }
    Ps(t)
}

/// Measure the Hamming-weight response of one routed PDL: for every weight,
/// average the traversal delay over `samples_per_weight` random bit
/// placements of that weight (the paper's delay characterization sweeps
/// input vectors per weight the same way).
pub fn hamming_response(pdl: &RoutedPdl, samples_per_weight: usize, seed: u64) -> HammingResponse {
    let n = pdl.len();
    let mut rng = SplitMix64::new(seed);
    let mut weights = Vec::with_capacity(n + 1);
    let mut means = Vec::with_capacity(n + 1);
    let mut stds = Vec::with_capacity(n + 1);

    let mut idx: Vec<usize> = (0..n).collect();
    for w in 0..=n {
        let mut delays = Vec::with_capacity(samples_per_weight);
        for _ in 0..samples_per_weight {
            rng.shuffle(&mut idx);
            let mut bits = vec![false; n];
            for &i in idx.iter().take(w) {
                bits[i] = true;
            }
            delays.push(traversal_delay(pdl, &bits).as_ns());
        }
        weights.push(w);
        means.push(stats::mean(&delays));
        stds.push(stats::std_dev(&delays));
    }

    let w_f: Vec<f64> = weights.iter().map(|&w| w as f64).collect();
    let rho = stats::spearman(&w_f, &means);
    let strictly_monotonic = means.windows(2).all(|p| p[1] < p[0]);
    HammingResponse {
        weights,
        mean_delay_ns: means,
        std_delay_ns: stds,
        spearman_rho: rho,
        strictly_monotonic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Device, VariationModel, VariationParams};
    use crate::flow::{place_pdls, route_pdl, FlowConfig, PinAssignment};

    fn routed(n: usize, hi: u64, sigma: f64, die: u64) -> RoutedPdl {
        let d = Device::xc7z020();
        let p = place_pdls(&d, 1, n).unwrap().remove(0);
        let params = VariationParams { sigma_random: sigma, ..VariationParams::default() };
        let var = VariationModel::new(die, params);
        let cfg = FlowConfig {
            lo_target: Ps(380),
            hi_target: Ps(hi),
            granularity: Ps(5),
            variation: params,
            die_seed: die,
        };
        route_pdl(&d, &p, &PinAssignment::fastest_pair(), &cfg, &var).unwrap()
    }

    #[test]
    fn traversal_bounds() {
        let pdl = routed(50, 620, 0.02, 1);
        let all0 = traversal_delay(&pdl, &vec![false; 50]);
        let all1 = traversal_delay(&pdl, &vec![true; 50]);
        assert_eq!(all0, pdl.max_traversal());
        assert_eq!(all1, pdl.min_traversal());
        assert!(all1 < all0);
    }

    #[test]
    fn response_monotonic_with_large_delta() {
        // Fig. 6 bottom: ~600 ps delta ⇒ ρ ≈ −1 and strict monotonicity.
        let pdl = routed(150, 980, 0.02, 2);
        let r = hamming_response(&pdl, 8, 99);
        assert!(r.spearman_rho < -0.999, "ρ = {}", r.spearman_rho);
        assert!(r.strictly_monotonic);
    }

    #[test]
    fn small_delta_weakens_monotonicity() {
        // Fig. 6 top (60 ps delta) vs bottom (600 ps): ρ degrades (toward 0)
        // as delta shrinks relative to variation.
        let tight = hamming_response(&routed(150, 445, 0.06, 3), 4, 7); // ~60ps delta
        let wide = hamming_response(&routed(150, 980, 0.06, 3), 4, 7); // ~600ps
        assert!(wide.spearman_rho <= tight.spearman_rho,
            "wide {} should be ≤ tight {}", wide.spearman_rho, tight.spearman_rho);
        assert!(tight.spearman_rho < -0.9); // still strongly monotone, like the paper
    }

    #[test]
    fn skew_report_zero_without_variation() {
        let d = Device::xc7z020();
        let pls = place_pdls(&d, 3, 40).unwrap();
        let var = VariationModel::new(0, VariationParams::none());
        let cfg = FlowConfig::ideal(Ps(400), Ps(640));
        let routed: Vec<_> = pls
            .iter()
            .map(|p| route_pdl(&d, p, &PinAssignment::fastest_pair(), &cfg, &var).unwrap())
            .collect();
        let rep = skew_report(&routed);
        assert_eq!(rep.max_stage_skew_lo, Ps::ZERO);
        assert_eq!(rep.max_cumulative_skew_hi, Ps::ZERO);
        assert!(rep.is_safe());
    }

    #[test]
    fn skew_grows_with_variation() {
        let d = Device::xc7z020();
        let pls = place_pdls(&d, 3, 100).unwrap();
        let params = VariationParams::default();
        let var = VariationModel::new(11, params);
        let cfg = FlowConfig::table1_default();
        let routed: Vec<_> = pls
            .iter()
            .map(|p| route_pdl(&d, p, &PinAssignment::fastest_pair(), &cfg, &var).unwrap())
            .collect();
        let rep = skew_report(&routed);
        assert!(rep.max_stage_skew_lo > Ps::ZERO);
        assert!(rep.mean_delta > Ps(150)); // window preserved on average
    }
}
