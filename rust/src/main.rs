//! `tdpc` — CLI for the time-domain popcount reproduction.
//!
//! Subcommands:
//!   infer     — run samples through a model on the selected backend
//!               (--backend native|pjrt|hw:<async|adder|fpt18>; native is
//!               the default and needs no XLA toolchain)
//!   serve     — start the multi-worker batching coordinator and drive a
//!               load test (--workers N, --dispatch round-robin|least-loaded,
//!               --backend hw:<arch> for simulated-hardware serving with
//!               --hw-replay off|sample:N|full row replay; --queue-limit N
//!               bounds each worker's in-flight load, 0 = unbounded, with
//!               --shed reject-new|drop-oldest deciding what QueueFull drops;
//!               --models a,b,c serves several models through one pool,
//!               batched per model, and --reload <model> hot-swaps that
//!               model mid-burst with zero lost requests;
//!               --shards N scatters one model's clauses over N workers
//!               and reduces partial sums, with --straggler-ms bounding
//!               how long the reduce waits on a slow shard).
//!               With --listen ADDR the pool serves the binary wire
//!               protocol over TCP instead of a local burst: --synthetic N
//!               serves N in-memory synthetic models (no artifacts needed),
//!               --conn-limit caps concurrent connections, --port-file P
//!               writes the bound addr for scripts, --duration-s bounds the
//!               run (0 = forever)
//!   loadgen   — drive a serve --listen front end and write
//!               BENCH_serving.json (--addr or --port-file, --mode
//!               closed|open, --conns N, --rate RPS, --models a:3,b:1,
//!               --burst steady|square:<ms>:<pct>, --assert for CI gating)
//!   pack      — write a v2 content-addressed artifact tree: --synthetic N
//!               models to --out DIR (--shards B clause blocks per model,
//!               --seed S), or --from-v1 DIR to migrate a v1 bare
//!               directory in place
//!   verify    — full-tree integrity check of a v2 tree (every object
//!               re-hashed and parsed, every model assembled); corrupt or
//!               missing objects exit nonzero with a typed error
//!   gc        — delete objects no live generation references
//!               (--dry-run to count only)
//!   flow      — run the FPGA implementation flow and print the skew audit
//!   table1 / fig6 / fig9 / fig10 / fig11 / fig12 — regenerate the paper's
//!               tables/figures (markdown to stdout, CSV via --csv DIR)
//!   all       — every experiment in sequence
//!
//! `--artifacts DIR` (default ./artifacts or $TDPC_ARTIFACTS) points at the
//! output of `make artifacts`.

use std::path::PathBuf;
use anyhow::{bail, Context, Result};

use tdpc::config::Args;
use tdpc::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, DispatchPolicy, ReplayPolicy, ShedPolicy,
};
use tdpc::experiments::{ablation, fig10, fig11, fig12, fig6, fig9, table1, Table};
use tdpc::fabric::Device;
use tdpc::flow::{self, skew_report, FlowConfig};
use tdpc::runtime::{BackendSpec, InferenceBackend, ModelRegistry};
use tdpc::server::{loadgen, Server, ServerConfig};
use tdpc::tm::{artifact, Manifest, PackedBatch, Store, TestSet, TmModel};
use tdpc::util::{Ps, SplitMix64};

fn main() {
    env_logger_init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn env_logger_init() {
    // Minimal logger: honor TDPC_LOG=debug|info (log crate facade only).
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let _ = log::set_logger(&LOGGER);
    let lvl = match std::env::var("TDPC_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("info") => log::LevelFilter::Info,
        _ => log::LevelFilter::Warn,
    };
    log::set_max_level(lvl);
}

fn artifacts_root(args: &Args) -> PathBuf {
    args.opt("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(Manifest::default_root)
}

fn emit(tables: &[Table], args: &Args) -> Result<()> {
    for t in tables {
        println!("{}", t.to_markdown());
    }
    if let Some(dir) = args.opt("csv") {
        std::fs::create_dir_all(dir)?;
        for t in tables {
            let slug: String = t
                .title
                .chars()
                .map(|c| if c.is_alphanumeric() { c } else { '_' })
                .collect::<String>()
                .to_lowercase();
            let path = PathBuf::from(dir).join(format!("{}.csv", slug.trim_matches('_')));
            std::fs::write(&path, t.to_csv())?;
            eprintln!("wrote {}", path.display());
        }
    }
    Ok(())
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("infer") => cmd_infer(args),
        Some("serve") => cmd_serve(args),
        Some("loadgen") => cmd_loadgen(args),
        Some("pack") => cmd_pack(args),
        Some("verify") => cmd_verify(args),
        Some("gc") => cmd_gc(args),
        Some("flow") => cmd_flow(args),
        Some("table1") => cmd_table1(args),
        Some("fig6") => cmd_fig6(args),
        Some("fig9") => cmd_fig9(args),
        Some("fig10") => cmd_fig10(args),
        Some("fig11") => cmd_fig11(args),
        Some("fig12") => cmd_fig12(args),
        Some("ablation") => cmd_ablation(args),
        Some("all") => cmd_all(args),
        Some(other) => bail!("unknown subcommand {other:?}; try: infer serve loadgen pack verify gc flow table1 fig6 fig9 fig10 fig11 fig12 ablation all"),
        None => {
            println!("tdpc — time-domain popcount for low-complexity ML (paper reproduction)");
            println!("usage: tdpc <infer|serve|loadgen|pack|verify|gc|flow|table1|fig6|fig9|fig10|fig11|fig12|all> [--options]");
            Ok(())
        }
    }
}

fn cmd_infer(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "model", "samples", "backend", "csv"])?;
    let model = args.opt_or("model", "iris_c10");
    let n = args.opt_usize("samples", 8)?;
    let spec = BackendSpec::from_name(args.opt_or("backend", "native"))?;
    let registry = ModelRegistry::open_with(&artifacts_root(args), spec)?;
    let manifest = registry.manifest().context("infer needs the artifact manifest")?;
    let entry = manifest.entry(model)?.clone();
    let test = TestSet::load(&entry.test_data_path)?;
    let backend = registry.backend(model)?;
    println!("backend: {} (platform {})", backend.kind(), backend.platform());
    let mut correct = 0;
    for (i, x) in test.x.iter().take(n).enumerate() {
        let out = backend.forward(&PackedBatch::single(x))?;
        let ok = out.pred[0] as usize == test.y[i];
        correct += ok as usize;
        println!(
            "sample {i}: pred {} label {} sums {:?} {}",
            out.pred[0],
            test.y[i],
            out.sums_row(0),
            if ok { "OK" } else { "MISS" }
        );
    }
    println!("accuracy: {correct}/{n}");
    Ok(())
}

/// Rows the local serve burst submits for one model: real labeled test
/// rows on a v1 tree, deterministic synthetic rows (no labels, so no
/// accuracy) on a v2 content-addressed tree.
struct BurstData {
    rows: Vec<Vec<bool>>,
    labels: Option<Vec<usize>>,
}

impl BurstData {
    fn for_model(store: &Store, name: &str) -> Result<BurstData> {
        if let Some(manifest) = store.v1() {
            let entry = manifest.entry(name)?;
            let test = TestSet::load(&entry.test_data_path)?;
            return Ok(BurstData { labels: Some(test.y.clone()), rows: test.x });
        }
        let (_, n_features, _, _) = store.model_shape(name)?;
        let mut rng = SplitMix64::new(0xb065 ^ n_features as u64);
        let rows = (0..64)
            .map(|_| (0..n_features).map(|_| rng.next_bool(0.5)).collect())
            .collect();
        Ok(BurstData { rows, labels: None })
    }

    fn len(&self) -> usize {
        self.rows.len()
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_known(&[
        "artifacts", "model", "models", "requests", "batch", "max-batch", "deadline-us",
        "workers", "dispatch", "backend", "hw-replay", "queue-limit", "shed", "reload",
        "mutate-shard", "csv", "listen", "synthetic", "conn-limit", "port-file",
        "duration-s", "shards", "straggler-ms",
    ])?;
    // `--models a,b,c` serves several models through one pool (requests
    // alternate across them); `--model` remains the single-model form.
    let models_arg = args
        .opt("models")
        .map(str::to_string)
        .unwrap_or_else(|| args.opt_or("model", "mnist_c100").to_string());
    let names: Vec<String> = models_arg
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    anyhow::ensure!(!names.is_empty(), "--models needs at least one model name");
    let n_requests = args.opt_usize("requests", 500)?;
    let n_workers = args.opt_usize("workers", 1)?;
    // `--backend hw:<async|adder|fpt18>` serves through simulated hardware
    // (one independently-seeded die per worker); `--hw-replay` picks which
    // rows pay for timing replay. The default `full` is a no-op on
    // engine-less backends, so it only matters with hw:<arch>.
    // `--queue-limit 0` (the default) accepts without bound; any other N
    // bounds each worker's in-flight load, shedding per `--shed`.
    // `--max-batch N` is the explicit batch-size cap (alias of the older
    // `--batch`, which it overrides when both are given). Raising it past
    // `tm::SLICED_MIN_ROWS` (64) is what lets the batcher form groups big
    // enough for the bit-sliced forward engine; the default 32 keeps the
    // latency-oriented small-batch behavior.
    let max_batch = match args.opt("max-batch") {
        Some(_) => args.opt_usize("max-batch", 32)?,
        None => args.opt_usize("batch", 32)?,
    };
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch,
            max_wait: std::time::Duration::from_micros(args.opt_u64("deadline-us", 500)?),
        },
        n_workers,
        dispatch: DispatchPolicy::from_name(args.opt_or("dispatch", "round-robin"))?,
        backend: BackendSpec::from_name(args.opt_or("backend", "native"))?,
        replay: ReplayPolicy::from_name(args.opt_or("hw-replay", "full"))?,
        queue_limit: match args.opt_usize("queue-limit", 0)? {
            0 => None,
            n => Some(n),
        },
        shed: ShedPolicy::from_name(args.opt_or("shed", "reject-new"))?,
        straggler_deadline: std::time::Duration::from_millis(args.opt_u64("straggler-ms", 2000)?),
    };
    // `--shards N` (N > 1) serves ONE model through the scatter/reduce
    // plan: N workers each own a clause shard, every request fans out to
    // all of them, and a reduce slot re-argmaxes the merged partial sums
    // (bit-exact with the unsharded pool). `--straggler-ms` bounds how
    // long the reduce waits for a slow shard before failing the request.
    let n_shards = args.opt_usize("shards", 1)?;
    // `--listen ADDR` switches from the self-driving local burst to the
    // TCP front end: the pool serves the wire protocol until killed (or
    // for --duration-s seconds).
    if let Some(listen) = args.opt("listen") {
        return serve_network(args, cfg, names, listen, n_shards);
    }
    let root = artifacts_root(args);
    // v1 trees carry labeled test data the burst replays; v2
    // (content-addressed) trees carry only model payloads, so the burst
    // drives deterministic synthetic rows at each model's feature width
    // and reports accuracy as n/a.
    let store = Store::open(&root)?;
    let mut bursts = Vec::with_capacity(names.len());
    for name in &names {
        bursts.push(BurstData::for_model(&store, name)?);
    }

    let coord = if n_shards > 1 {
        anyhow::ensure!(
            names.len() == 1,
            "--shards serves exactly one model (got --models {names:?})"
        );
        Coordinator::start_sharded(root.clone(), &names[0], n_shards, cfg)?
    } else {
        let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
        Coordinator::start_multi(root.clone(), &name_refs, cfg)?
    };
    let mids: Vec<_> = names
        .iter()
        .map(|n| coord.model_id(n).expect("started models resolve"))
        .collect();
    // `--reload <model>`: hot-swap that model halfway through the burst,
    // demonstrating the zero-loss reload path under live traffic.
    let reload_mid = match args.opt("reload") {
        Some(name) => Some(coord.model_id(name).with_context(|| {
            format!("--reload {name:?} must name one of the served models {names:?}")
        })?),
        None => None,
    };
    // `--mutate-shard IX` (v2 trees, with --reload): rewrite clause block
    // IX of the reloaded model right before the mid-burst swap, so the
    // reload has a real one-object delta to pick up — the per-tenant
    // report's `shard_objects_reused` count is the proof the other
    // blocks never touched disk.
    let mutate_shard = match args.opt("mutate-shard") {
        Some(s) => {
            let ix: usize = s.parse().context("--mutate-shard expects a shard index")?;
            anyhow::ensure!(reload_mid.is_some(), "--mutate-shard needs --reload <model>");
            anyhow::ensure!(
                store.is_v2(),
                "--mutate-shard needs a v2 (content-addressed) artifact tree — see `tdpc pack`"
            );
            Some(ix)
        }
        None => None,
    };
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        if i == n_requests / 2 {
            if let Some(mid) = reload_mid {
                if let Some(ix) = mutate_shard {
                    artifact::rewrite_shard(&root, &names[mid.index()], ix, |b| {
                        // Prefer flipping an include bit of a dead clause:
                        // the object's hash changes but no answer does
                        // (dead clauses never fire). Fall back to a
                        // polarity flip when every clause is live.
                        match b.nonempty.iter().position(|&alive| !alive) {
                            Some(c) => b.include[c][0] = !b.include[c][0],
                            None => b.polarity[0] = -b.polarity[0],
                        }
                    })?;
                }
                coord.reload(mid)?;
            }
        }
        let m = i % names.len();
        let burst = &bursts[m];
        coord.submit(mids[m], &burst.rows[(i / names.len()) % burst.len()], tx.clone());
    }
    drop(tx);
    // Every submit is answered exactly once: a response, or a typed
    // InferError (QueueFull under --queue-limit saturation).
    let mut correct = vec![0usize; names.len()];
    let mut served = 0usize;
    let mut failed = 0usize;
    let mut got = 0usize;
    while let Ok(reply) = rx.recv() {
        got += 1;
        match reply {
            Ok(resp) => {
                let m = resp.model.index();
                let burst = &bursts[m];
                if let Some(labels) = &burst.labels {
                    let idx = (resp.request_id as usize / names.len()) % burst.len();
                    correct[m] += (resp.pred == labels[idx]) as usize;
                }
                served += 1;
            }
            Err(e) => {
                log::debug!("request failed: {e}");
                failed += 1;
            }
        }
        if got == n_requests {
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "pool [{}]: {served} served / {failed} failed of {got} replies in {wall:.3}s \
         = {:.0} req/s ({} workers)",
        names.join(", "),
        got as f64 / wall,
        coord.n_workers()
    );
    println!(
        "service latency: p50 {:.0} us p99 {:.0} us mean {:.0} us (mean batch {:.1}, exec {:.0} us)",
        m.service_p50_us, m.service_p99_us, m.service_mean_us, m.mean_batch_size, m.mean_batch_exec_us
    );
    // Per-tenant breakdown: each model's share of the pool, with its own
    // latency percentiles.
    for (mid, name) in coord.served_models() {
        let pm = coord.metrics_for(mid).expect("served model has metrics");
        let accuracy = match bursts[mid.index()].labels {
            Some(_) => {
                format!("{:.1}%", 100.0 * correct[mid.index()] as f64 / (pm.requests.max(1)) as f64)
            }
            None => "n/a".to_string(),
        };
        println!(
            "  model {name}: {} requests in {} batches, accuracy {accuracy}, \
             p50 {:.0} us p99 {:.0} us, clause skip {:.1}% ({} skipped / {} eligible), \
             sliced {} rows in {} groups",
            pm.requests,
            pm.batches,
            pm.service_p50_us,
            pm.service_p99_us,
            100.0 * pm.clause_skip_rate,
            pm.clauses_skipped,
            pm.clauses_eligible,
            pm.sliced_rows,
            pm.sliced_groups
        );
        if pm.reload_attempts > 0 {
            // One greppable line per reloaded tenant: on a v2 tree a
            // 1-of-N-object change across W workers reuses (objects each
            // worker holds − 1) · W from the hash-keyed cache.
            println!(
                "  model {name}: reloads {} ({} failed), shard_objects_reused {}",
                pm.reload_attempts, pm.reload_failures, pm.reload_shards_reused
            );
        }
    }
    for (i, wm) in coord.worker_metrics().iter().enumerate() {
        println!(
            "  worker {i}: {} requests in {} batches (mean batch {:.1})",
            wm.requests, wm.batches, wm.mean_batch_size
        );
    }
    if m.hw_mean_ns > 0.0 {
        println!(
            "simulated on-chip decision latency: mean {:.1} ns p50 {} p99 {} (mismatches {})",
            m.hw_mean_ns, m.hw_p50, m.hw_p99, m.hw_functional_mismatches
        );
    }
    if m.rejected_requests + m.shed_requests + m.failed_batches > 0 {
        println!(
            "fail-soft: {} rejected (width), {} shed (queue full), {} failed forward calls",
            m.rejected_requests, m.shed_requests, m.failed_batches
        );
    }
    coord.shutdown();
    Ok(())
}

/// `serve --listen ADDR`: expose the coordinator pool over TCP.
///
/// `--synthetic N` swaps the artifact-backed serve list for N in-memory
/// synthetic models (`synth_0..synth_{N-1}`, varied shapes straddling the
/// 64-bit word boundary) so CI and smoke tests need no artifacts on disk.
fn serve_network(
    args: &Args,
    mut cfg: CoordinatorConfig,
    mut names: Vec<String>,
    listen: &str,
    n_shards: usize,
) -> Result<()> {
    let root;
    if let Some(n) = args.opt("synthetic") {
        let n: usize = n.parse().context("--synthetic expects a model count")?;
        anyhow::ensure!(n >= 1, "--synthetic needs at least one model");
        const WIDTHS: [usize; 5] = [63, 65, 31, 128, 96];
        let models: Vec<std::sync::Arc<TmModel>> = (0..n)
            .map(|i| {
                std::sync::Arc::new(TmModel::synthetic(
                    &format!("synth_{i}"),
                    2 + i % 3,
                    8 + 4 * (i % 4),
                    WIDTHS[i % WIDTHS.len()],
                    0.2,
                    1000 + i as u64,
                ))
            })
            .collect();
        names = models.iter().map(|m| m.name.clone()).collect();
        cfg.backend = BackendSpec::InMemorySet(std::sync::Arc::new(models));
        root = PathBuf::from("/nonexistent-synthetic-root");
    } else {
        root = artifacts_root(args);
    }
    let coord = if n_shards > 1 {
        anyhow::ensure!(
            names.len() == 1,
            "--shards serves exactly one model (got --models {names:?})"
        );
        std::sync::Arc::new(Coordinator::start_sharded(root, &names[0], n_shards, cfg)?)
    } else {
        let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
        std::sync::Arc::new(Coordinator::start_multi(root, &name_refs, cfg)?)
    };
    let server_cfg = ServerConfig { max_conns: args.opt_usize("conn-limit", 256)? };
    let server = Server::start(coord.clone(), listen, server_cfg)?;
    let addr = server.local_addr();
    match coord.n_shards() {
        1 => println!(
            "serving [{}] on {addr} ({} workers)",
            names.join(", "),
            coord.n_workers()
        ),
        s => println!(
            "serving [{}] on {addr} (scatter/reduce over {s} clause shards)",
            names.join(", ")
        ),
    }
    // `--port-file P`: publish the bound address for scripts (written to
    // a temp file first, then renamed, so a poller never reads a partial
    // write).
    if let Some(path) = args.opt("port-file") {
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, format!("{addr}\n"))
            .with_context(|| format!("writing {tmp}"))?;
        std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp} -> {path}"))?;
    }
    let duration_s = args.opt_f64("duration-s", 0.0)?;
    if duration_s > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(duration_s));
    } else {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    server.shutdown();
    let m = coord.metrics();
    println!(
        "served {} requests in {} batches; {} rejected, {} shed, {} failed forward calls",
        m.requests, m.batches, m.rejected_requests, m.shed_requests, m.failed_batches
    );
    Ok(())
}

/// `loadgen`: drive a `serve --listen` front end and write
/// `BENCH_serving.json` (schema `tdpc-bench-serving/v1`).
fn cmd_loadgen(args: &Args) -> Result<()> {
    args.expect_known(&[
        "addr", "port-file", "mode", "conns", "rate", "duration-s", "requests", "models",
        "burst", "seed", "out", "assert",
    ])?;
    let addr = match (args.opt("addr"), args.opt("port-file")) {
        (Some(a), _) => a.to_string(),
        (None, Some(path)) => std::fs::read_to_string(path)
            .with_context(|| format!("reading --port-file {path}"))?
            .trim()
            .to_string(),
        (None, None) => bail!("loadgen needs --addr HOST:PORT or --port-file PATH"),
    };
    let conns = args.opt_usize("conns", 4)?;
    let mode = match args.opt_or("mode", "closed") {
        "closed" => loadgen::Mode::Closed { conns },
        "open" => loadgen::Mode::Open { rate_rps: args.opt_f64("rate", 1000.0)?, conns },
        other => bail!("unknown loadgen mode {other:?} (expected: closed, open)"),
    };
    let models = loadgen::parse_mix(
        args.opt("models")
            .context("loadgen needs --models name[:weight][,name[:weight]...]")?,
    )?;
    let cfg = loadgen::LoadgenConfig {
        addr,
        mode,
        duration: std::time::Duration::from_secs_f64(args.opt_f64("duration-s", 5.0)?),
        max_requests: match args.opt_u64("requests", 0)? {
            0 => None,
            n => Some(n),
        },
        models,
        burst: loadgen::BurstShape::from_name(args.opt_or("burst", "steady"))?,
        seed: args.opt_u64("seed", 42)?,
    };
    let report = loadgen::run(&cfg)?;
    println!("{}", report.summary());
    let out = PathBuf::from(args.opt_or("out", "BENCH_serving.json"));
    loadgen::write_report(&report, &out)?;
    eprintln!("wrote {}", out.display());
    // `--assert`: the CI gate — zero protocol/decode errors and nonzero
    // goodput, or a nonzero exit.
    if args.flag("assert") {
        anyhow::ensure!(
            report.protocol_errors == 0,
            "loadgen observed {} protocol errors (the wire must stay clean under load)",
            report.protocol_errors
        );
        anyhow::ensure!(report.ok > 0, "loadgen got zero successful replies");
    }
    Ok(())
}

/// `pack`: publish a v2 content-addressed artifact tree.
///
/// `--synthetic N --out DIR` packs N deterministic synthetic models
/// (`synth_0..`, the same shape family `serve --synthetic` uses) —
/// what CI smoke tests and the artifact bench build on. `--from-v1 DIR`
/// migrates a v1 bare-directory tree in place: every model is re-read
/// through the v1 loader and re-published as content-addressed clause
/// blocks (the v1 files stay; `Store::open` prefers the v2 manifest).
fn cmd_pack(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "out", "from-v1", "synthetic", "shards", "seed"])?;
    let n_shards = args.opt_usize("shards", 4)?;
    let report = if let Some(dir) = args.opt("from-v1") {
        artifact::pack_from_v1(&PathBuf::from(dir), n_shards)?
    } else {
        let n = args.opt_usize("synthetic", 2)?;
        anyhow::ensure!(n >= 1, "--synthetic needs at least one model");
        let out = PathBuf::from(
            args.opt("out").context("pack needs --out DIR (or --from-v1 DIR)")?,
        );
        let seed = args.opt_u64("seed", 42)?;
        const WIDTHS: [usize; 5] = [63, 65, 31, 128, 96];
        let models: Vec<TmModel> = (0..n)
            .map(|i| {
                TmModel::synthetic(
                    &format!("synth_{i}"),
                    2 + i % 3,
                    8 + 4 * (i % 4),
                    WIDTHS[i % WIDTHS.len()],
                    0.2,
                    seed + i as u64,
                )
            })
            .collect();
        let refs: Vec<&TmModel> = models.iter().collect();
        let opts = artifact::PackOptions {
            n_shards,
            profile: "synthetic".into(),
            source: format!("tdpc pack --synthetic {n} --seed {seed}"),
        };
        artifact::pack(&out, &refs, &opts)?
    };
    println!(
        "packed {} models: {} objects written ({} bytes), {} deduped, generation {}",
        report.models,
        report.objects_written,
        report.bytes_written,
        report.objects_deduped,
        report.generation
    );
    Ok(())
}

/// `verify`: full-tree integrity check of a v2 tree (`--artifacts DIR`).
/// A flipped byte, truncated object, or dangling hash exits nonzero with
/// the typed [`artifact::ArtifactError`] naming the object.
fn cmd_verify(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts"])?;
    let root = artifacts_root(args);
    let r = artifact::verify(&root)?;
    println!(
        "verified {} models: {} objects, {} bytes, {} unreferenced object(s)",
        r.models, r.objects_verified, r.bytes_verified, r.unreferenced
    );
    Ok(())
}

/// `gc`: sweep objects no live generation references (`--dry-run` counts
/// without deleting). Manifest-referenced and worker-pinned objects are
/// never touched.
fn cmd_gc(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "dry-run"])?;
    let root = artifacts_root(args);
    let dry = args.flag("dry-run");
    let r = artifact::gc(&root, dry)?;
    println!(
        "gc{}: {} objects scanned, {} live, {} kept (pinned), {} {} ({} bytes)",
        if dry { " (dry run)" } else { "" },
        r.scanned,
        r.live,
        r.kept_pinned,
        r.deleted,
        if dry { "would delete" } else { "deleted" },
        r.bytes_freed
    );
    Ok(())
}

fn cmd_flow(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "pdls", "elements", "hi", "csv", "seed"])?;
    let n_pdls = args.opt_usize("pdls", 3)?;
    let n_elements = args.opt_usize("elements", 150)?;
    let hi = args.opt_u64("hi", 618)?;
    let seed = args.opt_u64("seed", 1)?;
    let device = Device::xc7z020();
    let cfg = FlowConfig { hi_target: Ps(hi), die_seed: seed, ..FlowConfig::table1_default() };
    let pdls = flow::run(&device, n_pdls, n_elements, &cfg)?;
    let rep = skew_report(&pdls);
    println!("flow: {n_pdls} PDLs x {n_elements} elements on {}", device.name);
    println!("  mean per-stage delta (hi-lo): {}", rep.mean_delta);
    println!("  max stage skew lo/hi: {} / {}", rep.max_stage_skew_lo, rep.max_stage_skew_hi);
    println!(
        "  max cumulative skew lo/hi: {} / {}",
        rep.max_cumulative_skew_lo, rep.max_cumulative_skew_hi
    );
    println!("  safe (cumulative skew < delta): {}", rep.is_safe());
    let resp = flow::hamming_response(&pdls[0], 8, seed);
    println!("  Hamming response: Spearman rho = {:.5}, strictly monotonic: {}",
        resp.spearman_rho, resp.strictly_monotonic);
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "samples", "csv"])?;
    let manifest = Manifest::load(&artifacts_root(args))?;
    let r = table1::run(&manifest, args.opt_usize("samples", 150)?)?;
    emit(&[r.table()], args)
}

fn cmd_fig6(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "elements", "samples", "seed", "csv"])?;
    let r = fig6::run(
        args.opt_usize("elements", 150)?,
        args.opt_usize("samples", 8)?,
        args.opt_u64("seed", 42)?,
    );
    emit(&[r.table()], args)
}

fn cmd_fig9(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "samples", "csv"])?;
    let manifest = Manifest::load(&artifacts_root(args))?;
    let r = fig9::run(&manifest, args.opt_usize("samples", 100)?)?;
    emit(&r.tables(), args)
}

fn cmd_fig10(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "samples", "csv"])?;
    let r = fig10::run(args.opt_usize("samples", 1000)?);
    emit(&r.tables(), args)
}

fn cmd_fig11(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "csv"])?;
    emit(&fig11::run().tables(), args)
}

fn cmd_fig12(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "csv"])?;
    emit(&fig12::run().tables(), args)
}

fn cmd_ablation(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "elements", "seed", "csv"])?;
    let r = ablation::run(args.opt_usize("elements", 150)?, args.opt_u64("seed", 7)?);
    emit(&[r.table()], args)
}

fn cmd_all(args: &Args) -> Result<()> {
    cmd_table1(args).context("table1")?;
    cmd_fig6(args).context("fig6")?;
    cmd_fig9(args).context("fig9")?;
    cmd_fig10(args).context("fig10")?;
    cmd_fig11(args).context("fig11")?;
    cmd_fig12(args).context("fig12")?;
    cmd_ablation(args).context("ablation")?;
    Ok(())
}
