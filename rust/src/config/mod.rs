//! Configuration & CLI argument parsing (no external crates offline —
//! DESIGN.md §7).
//!
//! [`Args`] is a minimal clap-alike: positional subcommand + `--key value`
//! / `--flag` options, with typed accessors and an unknown-option check so
//! typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    /// Error if any provided option/flag is not in the allowed set.
    pub fn expect_known(&self, allowed: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown option --{k} (allowed: {allowed:?})");
            }
        }
        for f in &self.flags {
            if !allowed.contains(&f.as_str()) {
                bail!("unknown flag --{f} (allowed: {allowed:?})");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig9 --model mnist_c50 --samples 100 --verbose");
        assert_eq!(a.command.as_deref(), Some("fig9"));
        assert_eq!(a.opt("model"), Some("mnist_c50"));
        assert_eq!(a.opt_usize("samples", 1).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("serve --batch=32 --deadline-us=500");
        assert_eq!(a.opt_usize("batch", 0).unwrap(), 32);
        assert_eq!(a.opt_usize("deadline-us", 0).unwrap(), 500);
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --n abc");
        assert!(a.opt_usize("n", 1).is_err());
    }

    #[test]
    fn unknown_rejected() {
        let a = parse("x --good 1 --bad 2");
        assert!(a.expect_known(&["good"]).is_err());
        assert!(a.expect_known(&["good", "bad"]).is_ok());
    }

    #[test]
    fn positionals() {
        let a = parse("infer file1 file2");
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }
}
