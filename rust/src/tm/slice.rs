//! The bit-sliced transposed forward engine: evaluate one clause against
//! 64 samples per word op, count votes in carry-save vertical counters.
//!
//! The source paper's thesis is that popcount + argmax dominate TM
//! inference and are worth moving into a cheaper evaluation domain. The
//! row-major hot loop (`TmModel::forward_indexed_with`) already made
//! clause evaluation word-parallel *across literals*; this module makes
//! it word-parallel *across samples* — the software analogue of the
//! paper's "count votes without ever materializing integers" move:
//!
//! 1. **Transpose** the batch ([`crate::tm::bits::TransposedBatch`]
//!    layout): one `u64` plane per literal, bit `r` of word `g` = row
//!    `64g + r`. Built by the word-level 64×64 tile transpose
//!    ([`crate::tm::bits::transpose_64x64`]), never a per-bit loop.
//! 2. **Evaluate** each clause as the AND of its included literal planes
//!    over one 64-row group: the result word is the clause's fired bit
//!    for all 64 rows at once. The scan walks the *same* flat scan-order
//!    include arena as the row-major path (fallback slots first, then
//!    skip buckets), with a group-level skip: if a bucket's index
//!    literal plane word is 0, no row in the group sets that literal, so
//!    the whole bucket is skipped for all 64 rows. An AND chain whose
//!    accumulator hits 0 stops early — activity sparsity, the same lever
//!    the paper's event-driven follow-up pulls in hardware.
//! 3. **Count** per-class votes in CSA vertical counters
//!    ([`CsaAccumulator`]): fired planes of one class and polarity feed
//!    Harley–Seal style 3:2 compressors (three planes in, a sum plane at
//!    weight 1 and a carry plane at weight 2 out), so 64 rows' signed
//!    sums live in ~log₂(clauses_per_class) words and are expanded to
//!    `i32` exactly once per group.
//! 4. **Re-transpose** the per-clause fired planes back to row-major
//!    fired words (same 64×64 kernel), so [`ForwardOutput`] is laid out
//!    identically to the row-major engine's — bit-exact, fired words,
//!    ties and all.
//!
//! Dispatch: `TmModel::forward_packed_with` and
//! `ClauseShard::partial_class_sums_into` route batches of at least
//! [`SLICED_MIN_ROWS`] rows here and keep smaller batches on the
//! row-major loop, where transposition overhead would not amortize over
//! mostly-idle lanes. The crossover is observable only through the
//! `sliced_groups` / `sliced_rows` telemetry on
//! [`crate::tm::ForwardScratch`].

use std::ops::Range;

use anyhow::{ensure, Result};

use super::bits::{tail_mask, transpose_64x64, transpose_into, words_for, PackedBatch, WORD_BITS};
use super::model::{
    ClauseIndex, ClauseShard, ForwardOutput, ForwardScratch, IndexBucket, PartialOutput, TmModel,
};

/// Minimum batch size routed to the sliced engine. One full 64-lane
/// group is the break-even shape: below it, lanes sit idle while the
/// batch still pays the feature transpose and the counter expansion, and
/// the row-major loop's per-row early exits win; from one full group up,
/// every include-literal AND retires 64 rows of work and the sliced loop
/// dominates (`benches/sliced_forward.rs` records the measured ratio).
pub const SLICED_MIN_ROWS: usize = 64;

/// A carry-save vertical counter over 64 lanes: `levels[i]` holds bit
/// `i` of each lane's running count, so lane `r`'s count is
/// `Σ_i ((levels[i] >> r) & 1) << i`. Adding a plane is a ripple of
/// word-wide half-adders; [`CsaAccumulator::add3`] first compresses
/// three planes through one 3:2 CSA stage (Harley–Seal style) so most
/// planes never touch the ripple chain at weight 1. The level vector
/// grows on demand and is reused across groups (capacity is retained by
/// `clear`), so a counter allocates ~log₂(planes) words once per
/// scratch lifetime.
#[derive(Debug, Clone, Default)]
pub struct CsaAccumulator {
    levels: Vec<u64>,
}

impl CsaAccumulator {
    /// Zero the counter, keeping level capacity.
    pub fn clear(&mut self) {
        self.levels.clear();
    }

    /// Ripple `carry` into the counter starting at weight `2^lvl`. The
    /// level vector may be shorter than `lvl` (a carry can land above
    /// every populated level — e.g. `add3(a, a, 0)` produces a zero sum
    /// and a weight-2 carry into an empty counter), so growth zero-fills
    /// up to the landing level.
    #[inline]
    fn add_at(&mut self, mut carry: u64, mut lvl: usize) {
        while carry != 0 {
            if lvl >= self.levels.len() {
                self.levels.resize(lvl, 0);
                self.levels.push(carry);
                return;
            }
            let sum = self.levels[lvl] ^ carry;
            carry &= self.levels[lvl];
            self.levels[lvl] = sum;
            lvl += 1;
        }
    }

    /// Add one plane (each lane's bit counts 1).
    #[inline]
    pub fn add(&mut self, plane: u64) {
        self.add_at(plane, 0);
    }

    /// Add three planes through one 3:2 compressor: `sum = a ⊕ b ⊕ c`
    /// enters at weight 1 and `carry = ab + (a⊕b)c` at weight 2, so the
    /// ripple chain sees two words instead of three.
    #[inline]
    pub fn add3(&mut self, a: u64, b: u64, c: u64) {
        let u = a ^ b;
        let sum = u ^ c;
        let carry = (a & b) | (u & c);
        self.add_at(sum, 0);
        self.add_at(carry, 1);
    }

    /// Lane `r`'s count, expanded to an integer.
    #[inline]
    pub fn count(&self, lane: usize) -> i32 {
        debug_assert!(lane < WORD_BITS);
        let mut n = 0i32;
        for (i, &w) in self.levels.iter().enumerate() {
            n += (((w >> lane) & 1) as i32) << i;
        }
        n
    }
}

/// Assemble one group's literal planes `[x, ~x]` from the transposed
/// feature planes: the positive half is the feature plane word itself,
/// the negated half is its complement masked to the group's live lanes
/// (so invalid lanes stay zero in every plane — the plane-major mirror
/// of the row-major zero-tail invariant).
fn literal_planes_into(
    planes: &[u64],
    groups: usize,
    g: usize,
    n_features: usize,
    valid: u64,
    out: &mut [u64],
) {
    debug_assert_eq!(out.len(), 2 * n_features);
    for i in 0..n_features {
        let p = planes[i * groups + g];
        out[i] = p;
        out[n_features + i] = !p & valid;
    }
}

/// Evaluate one scan slot against a 64-row group: AND the included
/// literal planes into an accumulator seeded with the live-lane mask (a
/// vacuous-but-`nonempty` fallback clause therefore fires on every live
/// lane — the flag stays authoritative), stopping as soon as no lane
/// can still fire.
#[inline]
fn eval_slot(
    idx: &ClauseIndex,
    slot: usize,
    lit_planes: &[u64],
    valid: u64,
    fired_planes: &mut [u64],
) {
    let row = &idx.arena[slot * idx.stride..(slot + 1) * idx.stride];
    let mut acc = valid;
    'literals: for (w, &word) in row.iter().enumerate() {
        let mut word = word;
        while word != 0 {
            let lit = w * WORD_BITS + word.trailing_zeros() as usize;
            acc &= lit_planes[lit];
            if acc == 0 {
                break 'literals;
            }
            word &= word - 1;
        }
    }
    if acc != 0 {
        fired_planes[idx.clause_of[slot] as usize] = acc;
    }
}

/// One group's clause scan over a slot slice: fallback slots
/// unconditionally, then each bucket behind its group-level index-literal
/// check — a zero plane word means no row in the group sets the literal,
/// so the bucket's clauses are skipped for all 64 rows at once. Returns
/// the skipped clause count (per group; telemetry scales it by live
/// lanes to stay comparable with the row-major counters).
fn eval_group(
    idx: &ClauseIndex,
    fallback: Range<usize>,
    buckets: &[IndexBucket],
    lit_planes: &[u64],
    valid: u64,
    fired_planes: &mut [u64],
) -> usize {
    for slot in fallback {
        eval_slot(idx, slot, lit_planes, valid, fired_planes);
    }
    let mut skipped = 0usize;
    for b in buckets {
        if lit_planes[b.lit as usize] == 0 {
            skipped += (b.end - b.start) as usize;
            continue;
        }
        for slot in b.start as usize..b.end as usize {
            eval_slot(idx, slot, lit_planes, valid, fired_planes);
        }
    }
    skipped
}

/// Fold one class's fired planes of one polarity into a CSA counter,
/// three planes per compressor stage. Zero planes (clauses that fired on
/// no lane — including dead clauses and, on the shard path, clauses this
/// shard does not own) are skipped outright: vote counting inherits the
/// batch's activity sparsity.
fn fold_polarity(
    csa: &mut CsaAccumulator,
    class_planes: &[u64],
    polarity: &[i8],
    base: usize,
    want_positive: bool,
) {
    csa.clear();
    let (mut a, mut b) = (0u64, 0u64);
    let mut staged = 0usize;
    for (off, &plane) in class_planes.iter().enumerate() {
        if plane == 0 || (polarity[base + off] > 0) != want_positive {
            continue;
        }
        match staged {
            0 => {
                a = plane;
                staged = 1;
            }
            1 => {
                b = plane;
                staged = 2;
            }
            _ => {
                csa.add3(a, b, plane);
                staged = 0;
            }
        }
    }
    match staged {
        1 => csa.add(a),
        2 => csa.add3(a, b, 0),
        _ => {}
    }
}

/// Re-transpose per-clause fired planes into row-major fired words: each
/// 64-clause chunk is one 64×64 tile, so row `r`'s fired word `wc` drops
/// out of the same transpose kernel that built the feature planes. Tail
/// chunks pad with zero planes, so row words keep the zero-tail
/// invariant `PackedBatch::push_words` asserts.
fn retranspose_fired(fired_planes: &[u64], fired_words: usize, fired_rows: &mut [u64]) {
    let c_total = fired_planes.len();
    debug_assert_eq!(fired_rows.len(), WORD_BITS * fired_words);
    let mut tile = [0u64; 64];
    for wc in 0..fired_words {
        let n = (c_total - wc * WORD_BITS).min(WORD_BITS);
        tile[..n].copy_from_slice(&fired_planes[wc * WORD_BITS..wc * WORD_BITS + n]);
        tile[n..].fill(0);
        transpose_64x64(&mut tile);
        for (r, &word) in tile.iter().enumerate() {
            fired_rows[r * fired_words + wc] = word;
        }
    }
}

impl TmModel {
    /// The bit-sliced batched forward pass: transpose the batch to
    /// literal planes, evaluate each clause against 64 rows per word op
    /// through the shared clause-index arena, count votes in per-class
    /// CSA vertical counters, and re-transpose fired planes back to the
    /// row-major [`ForwardOutput`] layout. Bit-exact with
    /// [`TmModel::forward_indexed_with`] and
    /// `TmModel::forward_reference` — sums, predictions, fired words,
    /// and tie resolution (argmax ties → lowest class index). Public so
    /// benches and property suites can pin it directly; production
    /// callers go through the dispatching `TmModel::forward_packed_with`.
    pub fn forward_sliced_with(
        &self,
        batch: &PackedBatch,
        scratch: &mut ForwardScratch,
    ) -> Result<ForwardOutput> {
        ensure!(
            batch.is_empty() || batch.bits() == self.n_features,
            "batch feature width {} != model features {}",
            batch.bits(),
            self.n_features
        );
        let k = self.n_classes;
        let c_total = self.c_total();
        let cpc = self.clauses_per_class;
        let fired_words = words_for(c_total);
        let rows = batch.rows();
        let groups = rows.div_ceil(WORD_BITS);
        let mut out = ForwardOutput::empty(k, c_total);
        out.batch = rows;
        out.sums.reserve(rows * k);
        out.pred.reserve(rows);
        // One transpose per batch; every buffer below is per-group and
        // reused across groups and batches.
        let mut planes = std::mem::take(&mut scratch.planes);
        transpose_into(batch, &mut planes);
        scratch.lit_planes.resize(2 * self.n_features, 0);
        scratch.fired_planes.resize(c_total, 0);
        scratch.fired_rows.resize(WORD_BITS * fired_words, 0);
        scratch.csa_pos.resize_with(k, Default::default);
        scratch.csa_neg.resize_with(k, Default::default);
        let idx = &self.clause_index;
        for g in 0..groups {
            let n_valid = (rows - g * WORD_BITS).min(WORD_BITS);
            let valid = tail_mask(n_valid);
            let ForwardScratch { lit_planes, fired_planes, fired_rows, csa_pos, csa_neg, .. } =
                scratch;
            literal_planes_into(&planes, groups, g, self.n_features, valid, lit_planes);
            fired_planes.fill(0);
            let skipped =
                eval_group(idx, 0..idx.n_fallback, &idx.buckets, lit_planes, valid, fired_planes);
            for ki in 0..k {
                let base = ki * cpc;
                let class_planes = &fired_planes[base..base + cpc];
                fold_polarity(&mut csa_pos[ki], class_planes, &self.polarity, base, true);
                fold_polarity(&mut csa_neg[ki], class_planes, &self.polarity, base, false);
            }
            retranspose_fired(fired_planes, fired_words, fired_rows);
            for lane in 0..n_valid {
                let mut best = 0usize;
                let mut best_sum = i32::MIN;
                for ki in 0..k {
                    let s = csa_pos[ki].count(lane) - csa_neg[ki].count(lane);
                    // Ties resolve to the lowest class index (jnp.argmax).
                    if s > best_sum {
                        best = ki;
                        best_sum = s;
                    }
                    out.sums.push(s);
                }
                out.pred.push(best as i32);
                out.fired.push_words(&fired_rows[lane * fired_words..(lane + 1) * fired_words]);
            }
            scratch.rows += n_valid as u64;
            scratch.clauses_skipped += (skipped * n_valid) as u64;
            scratch.clauses_eligible += (c_total * n_valid) as u64;
            scratch.sliced_groups += 1;
            scratch.sliced_rows += n_valid as u64;
        }
        scratch.planes = planes;
        Ok(out)
    }
}

impl ClauseShard {
    /// The bit-sliced partial engine: same plane pipeline as
    /// [`TmModel::forward_sliced_with`], scanning only this shard's slot
    /// slice (fallback slice unconditionally, clipped buckets behind the
    /// group-level index-literal skip). Clauses the shard does not own
    /// keep zero fired planes, so the counters sum shard-owned votes
    /// only and the re-transposed fired rows carry shard-owned bits only
    /// — emitting partials bit-identical to
    /// [`ClauseShard::partial_indexed_into`]'s.
    pub fn partial_sliced_into(
        &self,
        batch: &PackedBatch,
        scratch: &mut ForwardScratch,
        out: &mut PartialOutput,
    ) -> Result<()> {
        let m: &TmModel = self.model();
        ensure!(
            batch.is_empty() || batch.bits() == m.n_features,
            "batch feature width {} != model features {}",
            batch.bits(),
            m.n_features
        );
        let k = m.n_classes;
        let c_total = m.c_total();
        let cpc = m.clauses_per_class;
        let fired_words = words_for(c_total);
        let rows = batch.rows();
        let groups = rows.div_ceil(WORD_BITS);
        out.batch = rows;
        out.n_classes = k;
        out.c_total = c_total;
        out.shard = self.index();
        out.n_shards = self.n_shards();
        out.sums.clear();
        out.sums.reserve(rows * k);
        if out.fired.bits() == c_total {
            out.fired.truncate_rows(0);
        } else {
            out.fired = PackedBatch::new(c_total);
        }
        let mut planes = std::mem::take(&mut scratch.planes);
        transpose_into(batch, &mut planes);
        scratch.lit_planes.resize(2 * m.n_features, 0);
        scratch.fired_planes.resize(c_total, 0);
        scratch.fired_rows.resize(WORD_BITS * fired_words, 0);
        scratch.csa_pos.resize_with(k, Default::default);
        scratch.csa_neg.resize_with(k, Default::default);
        let idx = &m.clause_index;
        for g in 0..groups {
            let n_valid = (rows - g * WORD_BITS).min(WORD_BITS);
            let valid = tail_mask(n_valid);
            let ForwardScratch { lit_planes, fired_planes, fired_rows, csa_pos, csa_neg, .. } =
                scratch;
            literal_planes_into(&planes, groups, g, m.n_features, valid, lit_planes);
            fired_planes.fill(0);
            let skipped = eval_group(
                idx,
                self.fallback_lo..self.fallback_hi,
                &self.buckets,
                lit_planes,
                valid,
                fired_planes,
            );
            for ki in 0..k {
                let base = ki * cpc;
                let class_planes = &fired_planes[base..base + cpc];
                fold_polarity(&mut csa_pos[ki], class_planes, &m.polarity, base, true);
                fold_polarity(&mut csa_neg[ki], class_planes, &m.polarity, base, false);
            }
            retranspose_fired(fired_planes, fired_words, fired_rows);
            for lane in 0..n_valid {
                for ki in 0..k {
                    out.sums.push(csa_pos[ki].count(lane) - csa_neg[ki].count(lane));
                }
                out.fired.push_words(&fired_rows[lane * fired_words..(lane + 1) * fired_words]);
            }
            scratch.rows += n_valid as u64;
            scratch.clauses_skipped += (skipped * n_valid) as u64;
            scratch.clauses_eligible += ((self.slot_hi - self.slot_lo) * n_valid) as u64;
            scratch.sliced_groups += 1;
            scratch.sliced_rows += n_valid as u64;
        }
        scratch.planes = planes;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    /// Reference count of one lane across a plane list.
    fn lane_count(planes: &[u64], lane: usize) -> i32 {
        planes.iter().map(|p| ((p >> lane) & 1) as i32).sum()
    }

    #[test]
    fn csa_counter_matches_scalar_counts() {
        let mut rng = SplitMix64::new(31);
        for n_planes in [0usize, 1, 2, 3, 4, 5, 7, 8, 63, 64, 100, 127] {
            let planes: Vec<u64> = (0..n_planes).map(|_| rng.next_u64()).collect();
            // Triple-compressed feed (the fold shape).
            let mut csa = CsaAccumulator::default();
            let mut chunks = planes.chunks_exact(3);
            for t in &mut chunks {
                csa.add3(t[0], t[1], t[2]);
            }
            for &p in chunks.remainder() {
                csa.add(p);
            }
            for lane in 0..64 {
                assert_eq!(csa.count(lane), lane_count(&planes, lane), "n={n_planes} lane={lane}");
            }
            // Plane-at-a-time feed reaches the same counts.
            let mut one = CsaAccumulator::default();
            for &p in &planes {
                one.add(p);
            }
            for lane in 0..64 {
                assert_eq!(one.count(lane), csa.count(lane), "n={n_planes} lane={lane}");
            }
            // clear() resets counts while keeping the counter reusable.
            csa.clear();
            assert_eq!(csa.count(0), 0);
            csa.add3(u64::MAX, u64::MAX, u64::MAX);
            for lane in 0..64 {
                assert_eq!(csa.count(lane), 3, "post-clear lane={lane}");
            }
        }
    }

    #[test]
    fn csa_add3_with_zero_padding_is_exact() {
        // The fold pads a 2-plane remainder with a zero third input.
        let mut csa = CsaAccumulator::default();
        csa.add3(0b1010, 0b0110, 0);
        assert_eq!(csa.count(0), 0);
        assert_eq!(csa.count(1), 2);
        assert_eq!(csa.count(2), 1);
        assert_eq!(csa.count(3), 1);
    }

    #[test]
    fn csa_carry_can_land_above_every_populated_level() {
        // add3(a, a, 0) has a zero sum and a weight-2 carry; into an
        // empty counter the carry lands above every populated level, so
        // the ripple must zero-fill on growth.
        let mut csa = CsaAccumulator::default();
        csa.add3(0b11, 0b11, 0);
        assert_eq!(csa.count(0), 2);
        assert_eq!(csa.count(1), 2);
        assert_eq!(csa.count(2), 0);
        // And the zero-filled level still participates in later adds.
        csa.add(0b01);
        assert_eq!(csa.count(0), 3);
        assert_eq!(csa.count(1), 2);
    }

    #[test]
    fn sliced_forward_matches_indexed_on_the_toy_model() {
        let model = crate::tm::model::tests::toy();
        let mut rng = SplitMix64::new(5);
        for rows in [1usize, 63, 64, 65, 130] {
            let data: Vec<Vec<bool>> = (0..rows)
                .map(|_| (0..model.n_features).map(|_| rng.next_bool(0.5)).collect())
                .collect();
            let batch = PackedBatch::from_rows(&data).unwrap();
            let mut s_idx = ForwardScratch::new();
            let mut s_sl = ForwardScratch::new();
            let indexed = model.forward_indexed_with(&batch, &mut s_idx).unwrap();
            let sliced = model.forward_sliced_with(&batch, &mut s_sl).unwrap();
            assert_eq!(sliced, indexed, "rows={rows}");
            assert_eq!(s_sl.rows, rows as u64, "rows={rows}: row telemetry");
            assert_eq!(s_sl.sliced_rows, rows as u64, "rows={rows}: sliced rows");
            assert_eq!(
                s_sl.sliced_groups,
                rows.div_ceil(64) as u64,
                "rows={rows}: sliced groups"
            );
            assert_eq!(
                s_sl.clauses_eligible,
                (rows * model.c_total()) as u64,
                "rows={rows}: eligible telemetry"
            );
            assert_eq!(s_idx.sliced_groups, 0, "indexed path reports no sliced work");
        }
    }

    #[test]
    fn dispatch_threshold_routes_large_batches_to_the_sliced_engine() {
        let model = crate::tm::model::tests::toy();
        let mut rng = SplitMix64::new(6);
        let data: Vec<Vec<bool>> = (0..SLICED_MIN_ROWS + 1)
            .map(|_| (0..model.n_features).map(|_| rng.next_bool(0.5)).collect())
            .collect();
        let small = PackedBatch::from_rows(&data[..SLICED_MIN_ROWS - 1]).unwrap();
        let large = PackedBatch::from_rows(&data).unwrap();
        let mut scratch = ForwardScratch::new();
        model.forward_packed_with(&small, &mut scratch).unwrap();
        assert_eq!(scratch.sliced_groups, 0, "small batches keep the row-major path");
        model.forward_packed_with(&large, &mut scratch).unwrap();
        assert_eq!(scratch.sliced_groups, 2, "large batches take the sliced path");
        assert_eq!(scratch.sliced_rows, (SLICED_MIN_ROWS + 1) as u64);
        assert_eq!(scratch.rows, (2 * SLICED_MIN_ROWS) as u64);
    }
}
