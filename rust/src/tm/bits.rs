//! Packed bit-plane primitives: the native currency of the request path.
//!
//! The paper's hardware never sees a clause bit as an integer — votes are
//! events counted by a time-domain popcount. The software mirror of that
//! is a dense `u64` bit plane: [`BitVec64`] is one logical bit vector
//! (LSB-first within each word, tail bits zero), [`PackedBatch`] is a
//! row-major batch of equal-width vectors. Feature rows, literal vectors,
//! clause-include masks, fired-clause outputs, and polarity masks all use
//! this one layout, so clause evaluation and class summation reduce to
//! word-wise `AND`/`popcount` (`count_ones`) — the software analogue of
//! the paper's popcount voter.
//!
//! Layout conventions (shared with `python/compile`, see rust/README.md
//! §Data plane):
//!
//! * bit `i` of a vector lives in word `i / 64`, position `i % 64`
//!   (LSB-first);
//! * words beyond the logical length are absent; bits beyond it in the
//!   last word are **always zero** (every constructor and mutator
//!   maintains this, so `count_ones` needs no masking);
//! * a [`PackedBatch`] stores its rows contiguously at
//!   `words_per_row = ceil(bits / 64)` words each, so row `r` is the word
//!   slice `[r * words_per_row, (r + 1) * words_per_row)`.

use anyhow::{ensure, Result};

/// Bits per storage word.
pub const WORD_BITS: usize = 64;

/// Number of `u64` words needed to hold `bits` bits.
#[inline]
pub fn words_for(bits: usize) -> usize {
    bits.div_ceil(WORD_BITS)
}

/// Mask selecting the valid bits of the last word of a `bits`-bit vector
/// (all ones when `bits` is a multiple of 64 or zero).
#[inline]
pub fn tail_mask(bits: usize) -> u64 {
    match bits % WORD_BITS {
        0 => u64::MAX,
        r => (1u64 << r) - 1,
    }
}

/// Bitwise subset test over word slices: true iff every bit set in `sub`
/// is also set in `sup` (`sub & !sup == 0` in every word). This is the
/// clause-evaluation kernel — `include ⊆ literals` — restructured for
/// autovectorization: words are consumed in 4×`u64` chunks whose four
/// AND-NOTs reduce through one OR accumulator (no per-word branch, so
/// LLVM can lift the chunk body into SIMD lanes), with one early-exit
/// check per chunk so a clause that dies in its first words still stops
/// after at most 4 of them.
///
/// Slices may differ in length; the comparison covers the shorter prefix
/// (callers pass equal-length slices; the zip keeps the contract of the
/// scalar loop this replaced).
#[inline]
pub fn is_subset(sub: &[u64], sup: &[u64]) -> bool {
    const LANES: usize = 4;
    let n = sub.len().min(sup.len());
    let (sub, sup) = (&sub[..n], &sup[..n]);
    let mut chunks_a = sub.chunks_exact(LANES);
    let mut chunks_b = sup.chunks_exact(LANES);
    for (a, b) in (&mut chunks_a).zip(&mut chunks_b) {
        let viol = (a[0] & !b[0]) | (a[1] & !b[1]) | (a[2] & !b[2]) | (a[3] & !b[3]);
        if viol != 0 {
            return false;
        }
    }
    let mut viol = 0u64;
    for (&a, &b) in chunks_a.remainder().iter().zip(chunks_b.remainder()) {
        viol |= a & !b;
    }
    viol == 0
}

/// Copy the low `n_bits` of `src` into `dst` starting at bit offset
/// `dst_off`, OR-ing into whatever is already there (callers start from
/// zeroed destinations). Bits of `src` beyond `n_bits` are ignored.
pub fn copy_bits(dst: &mut [u64], dst_off: usize, src: &[u64], n_bits: usize) {
    if n_bits == 0 {
        return;
    }
    let shift = dst_off % WORD_BITS;
    let base = dst_off / WORD_BITS;
    for w in 0..words_for(n_bits) {
        let valid = (n_bits - w * WORD_BITS).min(WORD_BITS);
        let v = if valid < WORD_BITS { src[w] & ((1u64 << valid) - 1) } else { src[w] };
        dst[base + w] |= v << shift;
        if shift != 0 {
            let hi = v >> (WORD_BITS - shift);
            if hi != 0 {
                dst[base + w + 1] |= hi;
            }
        }
    }
}

/// In-place 64×64 bit-matrix transpose in the crate's LSB-first
/// convention: bit `c` of word `r` moves to bit `r` of word `c`. This is
/// the recursive block-swap of Hacker's Delight §7-3 adapted to
/// LSB-first indexing (the shift directions flip): at each level, the
/// off-diagonal `j×j` blocks of the current 2j×2j tiles are exchanged
/// with three XORs, halving the block size from 32 down to 1 — 6 levels,
/// no per-bit loop. It is its own inverse (a transpose is an
/// involution), which the property suite pins down.
///
/// Both directions of the sliced data plane run through this one kernel:
/// [`TransposedBatch::from_packed`] turns row-major feature words into
/// per-literal planes, and the sliced forward pass turns per-clause
/// fired planes back into row-major fired words.
pub fn transpose_64x64(a: &mut [u64; 64]) {
    let mut j = 32usize;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k = 0usize;
        while k < 64 {
            // Swap the high-j block of words k..k+j with the low-j block
            // of words k+j..k+2j (LSB-first mirror of HD's masks).
            let t = ((a[k] >> j) ^ a[k + j]) & m;
            a[k] ^= t << j;
            a[k + j] ^= t;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

/// A plane-major transposed batch: one `u64` plane per bit position,
/// where bit `r` of word `g` of plane `i` is bit `i` of row `64g + r` of
/// the source [`PackedBatch`]. Rows group in blocks of 64 (`groups =
/// ceil(rows / 64)`); lanes past the last row are zero in every plane,
/// the plane-major mirror of the row-major zero-tail invariant.
///
/// This is the batch layout of the bit-sliced forward path
/// (`tm::slice`): with one word per literal per 64-row group, a clause
/// evaluates against 64 samples with one `AND` per included literal —
/// the software shape of the paper's "evaluate everything at once, count
/// votes without integers" move.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TransposedBatch {
    rows: usize,
    bits: usize,
    groups: usize,
    /// `bits * groups` words, plane-major: plane `i` is the word slice
    /// `[i * groups, (i + 1) * groups)`.
    planes: Vec<u64>,
}

/// Core of [`TransposedBatch::from_packed`], writing into a caller-held
/// plane buffer (resized to `bits * groups`, fully overwritten) so the
/// batched forward path can reuse one allocation across batches.
pub fn transpose_into(batch: &PackedBatch, planes: &mut Vec<u64>) {
    let (rows, bits) = (batch.rows(), batch.bits());
    let groups = rows.div_ceil(WORD_BITS);
    let wpr = batch.words_per_row();
    planes.clear();
    planes.resize(bits * groups, 0);
    let mut tile = [0u64; 64];
    for g in 0..groups {
        let n_rows = (rows - g * WORD_BITS).min(WORD_BITS);
        for w in 0..wpr {
            // Gather word column `w` of the group's rows (missing rows
            // stay zero — the zero-lane invariant), transpose the 64×64
            // tile, and scatter each output word to its plane.
            tile.fill(0);
            for r in 0..n_rows {
                tile[r] = batch.row(g * WORD_BITS + r)[w];
            }
            transpose_64x64(&mut tile);
            let n_bits = (bits - w * WORD_BITS).min(WORD_BITS);
            for (j, &word) in tile[..n_bits].iter().enumerate() {
                planes[(w * WORD_BITS + j) * groups + g] = word;
            }
        }
    }
}

impl TransposedBatch {
    /// Transpose a row-major batch into plane-major form via the
    /// word-level 64×64 tile transpose (no per-bit loop anywhere).
    pub fn from_packed(batch: &PackedBatch) -> TransposedBatch {
        let mut planes = Vec::new();
        transpose_into(batch, &mut planes);
        TransposedBatch {
            rows: batch.rows(),
            bits: batch.bits(),
            groups: batch.rows().div_ceil(WORD_BITS),
            planes,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Bits per source row == number of planes.
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// 64-row groups (`ceil(rows / 64)`).
    pub fn groups(&self) -> usize {
        self.groups
    }

    /// Plane `i`: one word per 64-row group, bit `r` of word `g` = bit
    /// `i` of row `64g + r`.
    pub fn plane(&self, i: usize) -> &[u64] {
        assert!(i < self.bits, "plane {i} out of range {}", self.bits);
        &self.planes[i * self.groups..(i + 1) * self.groups]
    }

    /// All planes, plane-major.
    pub fn planes(&self) -> &[u64] {
        &self.planes
    }

    /// Bit `i` of row `r` (debug/test accessor — not a hot path).
    pub fn get(&self, r: usize, i: usize) -> bool {
        assert!(r < self.rows, "row {r} out of range {}", self.rows);
        assert!(i < self.bits, "bit {i} out of range {}", self.bits);
        (self.planes[i * self.groups + r / WORD_BITS] >> (r % WORD_BITS)) & 1 == 1
    }

    /// Transpose back to the row-major layout. Exact inverse of
    /// [`TransposedBatch::from_packed`] (the transpose property suite
    /// pins `untranspose(transpose(b)) == b` across ragged shapes).
    pub fn untranspose(&self) -> PackedBatch {
        let mut out = PackedBatch::new(self.bits);
        let wpr = words_for(self.bits);
        let mut tile = [0u64; 64];
        let mut row_words = vec![0u64; wpr];
        for g in 0..self.groups {
            let n_rows = (self.rows - g * WORD_BITS).min(WORD_BITS);
            let mut group_rows = vec![0u64; n_rows * wpr];
            for w in 0..wpr {
                let n_bits = (self.bits - w * WORD_BITS).min(WORD_BITS);
                tile.fill(0);
                for j in 0..n_bits {
                    tile[j] = self.planes[(w * WORD_BITS + j) * self.groups + g];
                }
                transpose_64x64(&mut tile);
                for r in 0..n_rows {
                    group_rows[r * wpr + w] = tile[r];
                }
            }
            for r in 0..n_rows {
                row_words.copy_from_slice(&group_rows[r * wpr..(r + 1) * wpr]);
                out.push_words(&row_words);
            }
        }
        out
    }
}

/// OR `src` into `dst` word-wise (equal lengths). The reduce half of
/// clause sharding leans on this: shards of one plan own disjoint bit
/// sets over the same `c_total`-bit row space, so OR-ing their
/// shard-local fired rows reconstructs the unsharded fired row exactly
/// (see `tm::model::merge_partials`).
#[inline]
pub fn or_into(dst: &mut [u64], src: &[u64]) {
    debug_assert_eq!(dst.len(), src.len(), "or_into: word-length mismatch");
    for (d, &s) in dst.iter_mut().zip(src) {
        *d |= s;
    }
}

/// One bit vector backed by `u64` words (LSB-first, zero tail).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BitVec64 {
    bits: usize,
    words: Vec<u64>,
}

impl BitVec64 {
    /// All-zeros vector of `bits` bits.
    pub fn zeros(bits: usize) -> BitVec64 {
        BitVec64 { bits, words: vec![0u64; words_for(bits)] }
    }

    /// Construct from pre-packed words (tail bits must already be zero).
    pub fn from_words(bits: usize, words: Vec<u64>) -> BitVec64 {
        assert_eq!(words.len(), words_for(bits), "word count mismatch for {bits} bits");
        debug_assert!(
            words.is_empty() || words[words.len() - 1] & !tail_mask(bits) == 0,
            "tail bits beyond the logical length must be zero"
        );
        BitVec64 { bits, words }
    }

    /// Pack a bool slice.
    pub fn from_bools(bits: &[bool]) -> BitVec64 {
        let mut v = BitVec64::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.words[i / WORD_BITS] |= 1u64 << (i % WORD_BITS);
            }
        }
        v
    }

    /// Logical length in bits.
    pub fn len(&self) -> usize {
        self.bits
    }

    pub fn is_empty(&self) -> bool {
        self.bits == 0
    }

    /// Backing words (tail bits guaranteed zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Consume into the backing words (tail bits guaranteed zero).
    pub fn into_words(self) -> Vec<u64> {
        self.words
    }

    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.bits, "bit {i} out of range {}", self.bits);
        (self.words[i / WORD_BITS] >> (i % WORD_BITS)) & 1 == 1
    }

    pub fn set(&mut self, i: usize, v: bool) {
        assert!(i < self.bits, "bit {i} out of range {}", self.bits);
        let mask = 1u64 << (i % WORD_BITS);
        if v {
            self.words[i / WORD_BITS] |= mask;
        } else {
            self.words[i / WORD_BITS] &= !mask;
        }
    }

    /// Population count (no masking needed: tail bits are zero).
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Unpack to bools (interchange/debug only — not a hot path).
    pub fn to_bools(&self) -> Vec<bool> {
        (0..self.bits).map(|i| self.get(i)).collect()
    }
}

/// A row-major batch of equal-width packed bit vectors.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PackedBatch {
    rows: usize,
    bits: usize,
    words: Vec<u64>,
}

impl PackedBatch {
    /// Empty batch of `bits`-bit rows.
    pub fn new(bits: usize) -> PackedBatch {
        PackedBatch { rows: 0, bits, words: Vec::new() }
    }

    /// Pack a uniform-width bool matrix. An empty slice yields a
    /// zero-row, zero-bit batch (accepted by every backend).
    pub fn from_rows(rows: &[Vec<bool>]) -> Result<PackedBatch> {
        let bits = rows.first().map_or(0, |r| r.len());
        let mut b = PackedBatch::new(bits);
        for row in rows {
            b.push_bools(row)?;
        }
        Ok(b)
    }

    /// Single-row batch (the CLI / example convenience).
    pub fn single(row: &[bool]) -> PackedBatch {
        let mut b = PackedBatch::new(row.len());
        b.push_bools(row).expect("width matches by construction");
        b
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Bits per row.
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Words per row (`ceil(bits / 64)`).
    pub fn words_per_row(&self) -> usize {
        words_for(self.bits)
    }

    /// All backing words, row-major.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Word slice of row `r`.
    pub fn row(&self, r: usize) -> &[u64] {
        let wpr = self.words_per_row();
        &self.words[r * wpr..(r + 1) * wpr]
    }

    /// Bit `i` of row `r`.
    pub fn bit(&self, r: usize, i: usize) -> bool {
        assert!(i < self.bits, "bit {i} out of range {}", self.bits);
        (self.row(r)[i / WORD_BITS] >> (i % WORD_BITS)) & 1 == 1
    }

    /// Unpack row `r` to bools (interchange/debug only).
    pub fn row_bools(&self, r: usize) -> Vec<bool> {
        (0..self.bits).map(|i| self.bit(r, i)).collect()
    }

    /// Append one bool row (must match the batch width).
    pub fn push_bools(&mut self, row: &[bool]) -> Result<()> {
        ensure!(
            row.len() == self.bits,
            "row width {} != batch width {}",
            row.len(),
            self.bits
        );
        let wpr = self.words_per_row();
        let base = self.words.len();
        self.words.resize(base + wpr, 0);
        for (i, &b) in row.iter().enumerate() {
            if b {
                self.words[base + i / WORD_BITS] |= 1u64 << (i % WORD_BITS);
            }
        }
        self.rows += 1;
        Ok(())
    }

    /// Append an already-packed row — a word memcpy, the ingestion hot
    /// path (the coordinator packs each request once at submit and batch
    /// assembly reuses the words).
    pub fn push_bitvec(&mut self, row: &BitVec64) -> Result<()> {
        ensure!(
            row.len() == self.bits,
            "row width {} != batch width {}",
            row.len(),
            self.bits
        );
        self.words.extend_from_slice(row.words());
        self.rows += 1;
        Ok(())
    }

    /// Append a row given as pre-masked words (tail bits must be zero;
    /// `debug_assert`ed). Used by forward passes emitting fired words.
    pub fn push_words(&mut self, row: &[u64]) {
        debug_assert_eq!(row.len(), self.words_per_row());
        debug_assert!(
            row.is_empty() || row[row.len() - 1] & !tail_mask(self.bits) == 0,
            "tail bits beyond row width must be zero"
        );
        self.words.extend_from_slice(row);
        self.rows += 1;
    }

    /// Concatenate another batch's rows onto this one.
    pub fn append(&mut self, other: &PackedBatch) -> Result<()> {
        ensure!(
            other.is_empty() || other.bits == self.bits,
            "cannot append {}-bit rows onto a {}-bit batch",
            other.bits,
            self.bits
        );
        self.words.extend_from_slice(&other.words);
        self.rows += other.rows;
        Ok(())
    }

    /// Keep only the first `n` rows (PJRT padding truncation).
    pub fn truncate_rows(&mut self, n: usize) {
        if n < self.rows {
            self.words.truncate(n * self.words_per_row());
            self.rows = n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn or_into_unions_disjoint_partitions() {
        // Split a random word row bit-wise across three "shards"; OR-ing
        // the parts back must reconstruct the original exactly.
        let mut rng = SplitMix64::new(77);
        let full: Vec<u64> = (0..5).map(|_| rng.next_u64()).collect();
        let mask: Vec<u64> = (0..5).map(|_| rng.next_u64()).collect();
        let mask2: Vec<u64> = (0..5).map(|_| rng.next_u64()).collect();
        let a: Vec<u64> = full.iter().zip(&mask).map(|(&f, &m)| f & m).collect();
        let b: Vec<u64> =
            full.iter().zip(&mask).zip(&mask2).map(|((&f, &m), &m2)| f & !m & m2).collect();
        let c: Vec<u64> =
            full.iter().zip(&mask).zip(&mask2).map(|((&f, &m), &m2)| f & !m & !m2).collect();
        let mut acc = vec![0u64; 5];
        for part in [&a, &b, &c] {
            or_into(&mut acc, part);
        }
        assert_eq!(acc, full);
    }

    #[test]
    fn bitvec_roundtrip_across_word_boundaries() {
        for n in [0usize, 1, 63, 64, 65, 127, 128, 130] {
            let mut rng = SplitMix64::new(n as u64 + 1);
            let bools: Vec<bool> = (0..n).map(|_| rng.next_bool(0.5)).collect();
            let v = BitVec64::from_bools(&bools);
            assert_eq!(v.len(), n);
            assert_eq!(v.words().len(), words_for(n));
            assert_eq!(v.to_bools(), bools, "n={n}");
            assert_eq!(v.count_ones(), bools.iter().filter(|&&b| b).count(), "n={n}");
            // Tail invariant: bits beyond the logical length are zero.
            if let Some(&last) = v.words().last() {
                assert_eq!(last & !tail_mask(n), 0, "n={n}");
            }
        }
    }

    #[test]
    fn bitvec_set_get() {
        let mut v = BitVec64::zeros(70);
        v.set(0, true);
        v.set(63, true);
        v.set(64, true);
        v.set(69, true);
        assert!(v.get(0) && v.get(63) && v.get(64) && v.get(69));
        assert!(!v.get(1) && !v.get(65));
        assert_eq!(v.count_ones(), 4);
        v.set(63, false);
        assert!(!v.get(63));
        assert_eq!(v.count_ones(), 3);
    }

    #[test]
    fn is_subset_matches_bitwise_definition() {
        let mut rng = SplitMix64::new(41);
        // Lengths straddling the 4-word chunk boundary: remainder of 0–3
        // words, plus the empty slice (vacuously a subset).
        for words in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 12, 13] {
            for _ in 0..50 {
                let sup: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
                // Derive `sub` from `sup` so true subsets actually occur.
                let sub: Vec<u64> = sup
                    .iter()
                    .map(|&w| {
                        let mask = rng.next_u64();
                        if rng.next_bool(0.5) {
                            w & mask // subset of this word
                        } else {
                            mask // arbitrary
                        }
                    })
                    .collect();
                let expect = sub.iter().zip(&sup).all(|(&a, &b)| a & !b == 0);
                assert_eq!(is_subset(&sub, &sup), expect, "words={words}");
            }
        }
        assert!(is_subset(&[], &[]));
        assert!(is_subset(&[0, 0, 0, 0, 0], &[1, 2, 3, 4, 5]));
        assert!(!is_subset(&[0, 0, 0, 0, 1], &[u64::MAX, 2, 3, 4, 0]));
        // A violation inside a full chunk and inside the remainder.
        assert!(!is_subset(&[0, 4, 0, 0], &[u64::MAX, 3, u64::MAX, u64::MAX]));
        assert!(!is_subset(&[0, 0, 0, 0, 0, 4], &[0, 0, 0, 0, 0, 3]));
    }

    #[test]
    fn copy_bits_at_unaligned_offsets() {
        let mut rng = SplitMix64::new(99);
        for n in [1usize, 7, 63, 64, 65, 120] {
            for off in [0usize, 1, 31, 63, 64, 65] {
                let src_bools: Vec<bool> = (0..n).map(|_| rng.next_bool(0.5)).collect();
                let src = BitVec64::from_bools(&src_bools);
                let mut dst = vec![0u64; words_for(off + n)];
                copy_bits(&mut dst, off, src.words(), n);
                for (i, &b) in src_bools.iter().enumerate() {
                    let got = (dst[(off + i) / 64] >> ((off + i) % 64)) & 1 == 1;
                    assert_eq!(got, b, "n={n} off={off} bit {i}");
                }
                // Nothing below the offset was touched.
                for i in 0..off {
                    assert_eq!((dst[i / 64] >> (i % 64)) & 1, 0, "n={n} off={off} low bit {i}");
                }
            }
        }
    }

    #[test]
    fn transpose_64x64_matches_bit_definition_and_is_involutive() {
        let mut rng = SplitMix64::new(2024);
        for case in 0..20 {
            let orig: [u64; 64] = std::array::from_fn(|_| rng.next_u64());
            let mut t = orig;
            transpose_64x64(&mut t);
            for r in 0..64 {
                for c in 0..64 {
                    assert_eq!(
                        (t[c] >> r) & 1,
                        (orig[r] >> c) & 1,
                        "case {case}: bit ({r},{c})"
                    );
                }
            }
            transpose_64x64(&mut t);
            assert_eq!(t, orig, "case {case}: transpose is an involution");
        }
        // The identity matrix is its own transpose.
        let mut eye: [u64; 64] = std::array::from_fn(|i| 1u64 << i);
        let expect = eye;
        transpose_64x64(&mut eye);
        assert_eq!(eye, expect);
    }

    #[test]
    fn transposed_batch_agrees_with_rows_and_roundtrips() {
        let mut rng = SplitMix64::new(4096);
        for &bits in &[1usize, 31, 63, 64, 65, 130] {
            for &rows in &[1usize, 63, 64, 65, 130] {
                let data: Vec<Vec<bool>> =
                    (0..rows).map(|_| (0..bits).map(|_| rng.next_bool(0.5)).collect()).collect();
                let b = PackedBatch::from_rows(&data).unwrap();
                let t = TransposedBatch::from_packed(&b);
                assert_eq!(t.rows(), rows);
                assert_eq!(t.bits(), bits);
                assert_eq!(t.groups(), rows.div_ceil(64), "bits={bits} rows={rows}");
                for r in 0..rows {
                    for i in 0..bits {
                        assert_eq!(t.get(r, i), b.bit(r, i), "bits={bits} rows={rows} ({r},{i})");
                    }
                }
                // Lanes past the last row are zero in every plane word.
                if rows % 64 != 0 {
                    let g = t.groups() - 1;
                    for i in 0..bits {
                        assert_eq!(
                            t.plane(i)[g] & !tail_mask(rows),
                            0,
                            "bits={bits} rows={rows}: ragged-lane zeros, plane {i}"
                        );
                    }
                }
                assert_eq!(t.untranspose(), b, "bits={bits} rows={rows}: round trip");
            }
        }
    }

    #[test]
    fn packed_batch_row_access() {
        let rows = vec![
            vec![true, false, true],
            vec![false, true, true],
            vec![false, false, false],
        ];
        let b = PackedBatch::from_rows(&rows).unwrap();
        assert_eq!(b.rows(), 3);
        assert_eq!(b.bits(), 3);
        assert_eq!(b.words_per_row(), 1);
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(&b.row_bools(r), row, "row {r}");
        }
        assert!(b.bit(0, 0) && !b.bit(0, 1) && b.bit(1, 2));
    }

    #[test]
    fn packed_batch_rejects_ragged_rows() {
        assert!(PackedBatch::from_rows(&[vec![true; 4], vec![true; 5]]).is_err());
        let mut b = PackedBatch::new(8);
        assert!(b.push_bitvec(&BitVec64::zeros(9)).is_err());
        assert!(b.push_bools(&[true; 7]).is_err());
        assert_eq!(b.rows(), 0, "failed pushes must not grow the batch");
    }

    #[test]
    fn packed_batch_append_and_truncate() {
        let mut a = PackedBatch::from_rows(&[vec![true; 65], vec![false; 65]]).unwrap();
        let b = PackedBatch::from_rows(&[vec![true; 65]]).unwrap();
        a.append(&b).unwrap();
        assert_eq!(a.rows(), 3);
        assert!(a.bit(2, 64));
        // Appending an empty batch is the identity regardless of width.
        a.append(&PackedBatch::new(0)).unwrap();
        assert_eq!(a.rows(), 3);
        let mut c = PackedBatch::new(4);
        assert!(c.append(&a).is_err(), "width mismatch must be rejected");
        a.truncate_rows(1);
        assert_eq!(a.rows(), 1);
        assert_eq!(a.words().len(), a.words_per_row());
    }

    #[test]
    fn empty_batch_conventions() {
        let b = PackedBatch::from_rows(&[]).unwrap();
        assert!(b.is_empty());
        assert_eq!(b.bits(), 0);
        let s = PackedBatch::single(&[true, false]);
        assert_eq!(s.rows(), 1);
        assert_eq!(s.row_bools(0), vec![true, false]);
    }
}
