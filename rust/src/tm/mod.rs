//! Tsetlin Machine core: trained-model loading, clause evaluation, and
//! dataset access on the Rust side.
//!
//! Models are trained once on the Python build path (`make artifacts`) and
//! interchange as JSON under `artifacts/models/`; this module loads them
//! for the hardware substrates (the simulators need per-sample clause bits)
//! and for functional cross-checks against the PJRT-executed HLO.

pub mod artifact;
pub mod bits;
pub mod datasets;
pub mod model;
pub mod slice;

pub use artifact::{ArtifactError, PayloadCache, Store, StoreManifest};
pub use bits::{BitVec64, PackedBatch, TransposedBatch};
pub use datasets::TestSet;
pub use model::{
    merge_partials, ClauseIndexStats, ClauseShard, ForwardScratch, HotLoopStats, PartialOutput,
    TmModel, WorkloadSpec,
};
pub use slice::{CsaAccumulator, SLICED_MIN_ROWS};

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json;

/// The artifact manifest (`artifacts/manifest.json`) — the index the Python
/// AOT path emits for everything the Rust side consumes.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub batch_sizes: Vec<usize>,
    pub models: Vec<ManifestEntry>,
}

/// One model configuration in the manifest (a row of the paper's Table I).
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub dataset: String,
    pub n_classes: usize,
    pub n_features: usize,
    pub clauses_per_class: usize,
    pub t: f64,
    pub s: f64,
    /// Test accuracy achieved at training time (%).
    pub accuracy: f64,
    /// The paper's Table I accuracy (%).
    pub paper_accuracy: f64,
    pub model_path: PathBuf,
    /// HLO file per batch size.
    pub hlo_paths: Vec<(usize, PathBuf)>,
    pub golden_path: PathBuf,
    pub test_data_path: PathBuf,
}

impl Manifest {
    /// Default artifacts root: `$TDPC_ARTIFACTS` or `./artifacts`.
    pub fn default_root() -> PathBuf {
        std::env::var("TDPC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn load_default() -> Result<Manifest> {
        Self::load(&Self::default_root())
    }

    pub fn load(root: &Path) -> Result<Manifest> {
        let doc = json::parse_file(&root.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts` first)")?;
        let batch_sizes = doc
            .get("batch_sizes")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let mut models = Vec::new();
        for (name, m) in doc.get("models")?.as_obj()? {
            let mut hlo_paths = Vec::new();
            for (b, p) in m.get("hlo")?.as_obj()? {
                hlo_paths.push((b.parse::<usize>()?, root.join(p.as_str()?)));
            }
            hlo_paths.sort_by_key(|(b, _)| *b);
            models.push(ManifestEntry {
                name: name.clone(),
                dataset: m.get("dataset")?.as_str()?.to_string(),
                n_classes: m.get("n_classes")?.as_usize()?,
                n_features: m.get("n_features")?.as_usize()?,
                clauses_per_class: m.get("clauses_per_class")?.as_usize()?,
                t: m.get("T")?.as_f64()?,
                s: m.get("s")?.as_f64()?,
                accuracy: m.get("accuracy")?.as_f64()?,
                paper_accuracy: m.get("paper_accuracy")?.as_f64()?,
                model_path: root.join(m.get("model")?.as_str()?),
                golden_path: root.join(m.get("golden")?.as_str()?),
                test_data_path: root.join(m.get("test_data")?.as_str()?),
                hlo_paths,
            });
        }
        models.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(Manifest { root: root.to_path_buf(), batch_sizes, models })
    }

    pub fn entry(&self, name: &str) -> Result<&ManifestEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| format!("model {name:?} not in manifest"))
    }

    pub fn hlo_path(&self, name: &str, batch: usize) -> Result<PathBuf> {
        let e = self.entry(name)?;
        e.hlo_paths
            .iter()
            .find(|(b, _)| *b == batch)
            .map(|(_, p)| p.clone())
            .with_context(|| format!("no HLO for {name} at batch {batch}"))
    }

    /// Largest artifact batch size ≤ `n` (falls back to the smallest
    /// available). `None` iff the manifest lists no batch sizes.
    pub fn best_batch(&self, n: usize) -> Option<usize> {
        self.batch_sizes
            .iter()
            .copied()
            .filter(|&b| b <= n.max(1))
            .max()
            .or_else(|| self.batch_sizes.iter().copied().min())
    }

    /// Execution batch for `n` queued requests: the *smallest* artifact
    /// batch that fits all of them (padding beats splitting into many
    /// small executions — §Perf L3), else the largest available. `None`
    /// iff the manifest lists no batch sizes.
    pub fn exec_batch(&self, n: usize) -> Option<usize> {
        self.batch_sizes
            .iter()
            .copied()
            .filter(|&b| b >= n.max(1))
            .min()
            .or_else(|| self.batch_sizes.iter().copied().max())
    }

    /// Write a minimal artifact tree at `root` serving `models` —
    /// `manifest.json` plus one `models/<name>.json` per model in
    /// [`TmModel::load`]'s interchange layout. The result is loadable by
    /// [`Manifest::load`] and every manifest-backed [`crate::runtime::BackendSpec`]
    /// (HLO and golden/test-data entries are placeholders: nothing on
    /// the native serving path reads them).
    ///
    /// This is the substrate for hot-swap exercises without the Python
    /// build path: write v1, serve, overwrite the model file with v2,
    /// `Coordinator::reload`. Calling it again with a changed model
    /// overwrites in place.
    pub fn write_synthetic(root: &Path, models: &[&TmModel]) -> Result<()> {
        // Every file lands via temp + rename: a reader racing the writer
        // (Coordinator::reload opens these mid-swap) sees the old
        // complete file or the new complete file, never a torn write —
        // and a crashed writer can't leave a half-written manifest that
        // a later reload then opens.
        fn write_atomic(path: &Path, contents: &str) -> Result<()> {
            let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
            std::fs::write(&tmp, contents)
                .with_context(|| format!("writing {}", tmp.display()))?;
            std::fs::rename(&tmp, path)
                .with_context(|| format!("publishing {}", path.display()))
        }
        let model_dir = root.join("models");
        std::fs::create_dir_all(&model_dir)
            .with_context(|| format!("creating {}", model_dir.display()))?;
        let mut entries = Vec::with_capacity(models.len());
        for m in models {
            anyhow::ensure!(
                !m.name.is_empty()
                    && m.name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
                "synthetic artifact names must be [A-Za-z0-9_-]+, got {:?}",
                m.name
            );
            let path = model_dir.join(format!("{}.json", m.name));
            write_atomic(&path, &m.to_json())
                .with_context(|| format!("writing {}", path.display()))?;
            entries.push(format!(
                "    \"{n}\": {{\n      \"dataset\": \"synthetic\",\n      \
                 \"n_classes\": {k},\n      \"n_features\": {f},\n      \
                 \"clauses_per_class\": {c},\n      \"T\": 0,\n      \"s\": 0,\n      \
                 \"accuracy\": {a},\n      \"paper_accuracy\": 0,\n      \
                 \"model\": \"models/{n}.json\",\n      \
                 \"golden\": \"models/{n}.golden.json\",\n      \
                 \"test_data\": \"models/{n}.test.json\",\n      \"hlo\": {{}}\n    }}",
                n = m.name,
                k = m.n_classes,
                f = m.n_features,
                c = m.clauses_per_class,
                a = m.accuracy,
            ));
        }
        let manifest = format!(
            "{{\n  \"batch_sizes\": [1, 32],\n  \"models\": {{\n{}\n  }}\n}}\n",
            entries.join(",\n")
        );
        write_atomic(&root.join("manifest.json"), &manifest)
            .with_context(|| format!("writing {}", root.join("manifest.json").display()))?;
        Ok(())
    }
}

/// Decode a "0101…" bitstring (the artifact JSON compaction).
pub fn parse_bits(s: &str) -> Result<Vec<bool>> {
    s.chars()
        .map(|c| match c {
            '0' => Ok(false),
            '1' => Ok(true),
            other => anyhow::bail!("invalid bit char {other:?}"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_bits_roundtrip() {
        assert_eq!(parse_bits("0101").unwrap(), vec![false, true, false, true]);
        assert!(parse_bits("01x1").is_err());
        assert!(parse_bits("").unwrap().is_empty());
    }

    #[test]
    fn batch_planning_on_manifest() {
        let manifest = Manifest {
            root: PathBuf::from("/nonexistent"),
            batch_sizes: vec![1, 32],
            models: vec![],
        };
        assert_eq!(manifest.best_batch(100), Some(32));
        assert_eq!(manifest.best_batch(32), Some(32));
        assert_eq!(manifest.best_batch(31), Some(1));
        assert_eq!(manifest.best_batch(0), Some(1));
        // exec_batch: smallest artifact batch that fits everything.
        assert_eq!(manifest.exec_batch(1), Some(1));
        assert_eq!(manifest.exec_batch(2), Some(32));
        assert_eq!(manifest.exec_batch(32), Some(32));
        assert_eq!(manifest.exec_batch(100), Some(32));
        let empty = Manifest { root: PathBuf::from("/x"), batch_sizes: vec![], models: vec![] };
        assert_eq!(empty.best_batch(4), None);
        assert_eq!(empty.exec_batch(4), None);
    }

    #[test]
    fn write_synthetic_roundtrips_through_manifest_load() {
        let root =
            std::env::temp_dir().join(format!("tdpc-synth-artifacts-{}", std::process::id()));
        let a = TmModel::synthetic("synth_a", 3, 6, 17, 0.2, 1);
        let b = TmModel::synthetic("synth_b", 2, 4, 33, 0.3, 2);
        Manifest::write_synthetic(&root, &[&a, &b]).unwrap();
        let manifest = Manifest::load(&root).unwrap();
        assert_eq!(manifest.models.len(), 2);
        for (m, entry_name) in [(&a, "synth_a"), (&b, "synth_b")] {
            let e = manifest.entry(entry_name).unwrap();
            assert_eq!(e.n_features, m.n_features);
            assert_eq!(e.n_classes, m.n_classes);
            let loaded = TmModel::load(&e.model_path).unwrap();
            assert_eq!(loaded.include, m.include);
        }
        // Overwriting one model in place is the hot-swap write path.
        let a2 = TmModel::synthetic("synth_a", 3, 6, 17, 0.2, 99);
        Manifest::write_synthetic(&root, &[&a2, &b]).unwrap();
        let reloaded =
            TmModel::load(&Manifest::load(&root).unwrap().entry("synth_a").unwrap().model_path)
                .unwrap();
        assert_eq!(reloaded.include, a2.include);
        assert_ne!(reloaded.include, a.include, "the rewrite must actually change the model");
        // Names that would corrupt the JSON are refused.
        let bad = TmModel::synthetic("bad\"name", 2, 2, 4, 0.2, 3);
        assert!(Manifest::write_synthetic(&root, &[&bad]).is_err());
        std::fs::remove_dir_all(&root).ok();
    }
}
