//! Dataset access for the Rust side.
//!
//! Booleanized test sets are exported by the Python AOT path
//! (`artifacts/data/<name>_test.json`): the Rust substrate never
//! re-implements the stroke renderer — it consumes the exact bits the model
//! was evaluated on, so functional results are bit-comparable across the
//! HLO path, the Rust clause evaluator and the Python oracle.
//!
//! For scaling sweeps that need unlimited synthetic inputs (Figs. 10–12),
//! [`synthetic_clause_bits`] draws clause-output vectors directly with a
//! controlled fire rate and margin structure — the quantities the PDL/
//! arbiter latency actually depends on.

use std::path::Path;

use anyhow::{ensure, Result};

use crate::util::{json, SplitMix64};

use super::{model::WorkloadSpec, parse_bits};

/// A Booleanized test set exported from the build path.
#[derive(Debug, Clone)]
pub struct TestSet {
    pub name: String,
    pub n_features: usize,
    /// Boolean feature vectors.
    pub x: Vec<Vec<bool>>,
    /// Ground-truth labels.
    pub y: Vec<usize>,
}

impl TestSet {
    pub fn load(path: &Path) -> Result<TestSet> {
        let doc = json::parse_file(path)?;
        let n = doc.get("n")?.as_usize()?;
        let n_features = doc.get("n_features")?.as_usize()?;
        let x = doc
            .get("x")?
            .as_arr()?
            .iter()
            .map(|row| parse_bits(row.as_str()?))
            .collect::<Result<Vec<_>>>()?;
        let y = doc
            .get("y")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?;
        ensure!(x.len() == n && y.len() == n, "test set length mismatch");
        for row in &x {
            ensure!(row.len() == n_features);
        }
        let name = doc.get("name")?.as_str()?.to_string();
        Ok(TestSet { name, n_features, x, y })
    }

    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }
}

/// Draw per-class clause-bit vectors for one synthetic sample.
///
/// One class (the "winner") fires clauses at `spec.fire_rate`; the others
/// fire at a reduced rate, creating the class-sum margin distribution the
/// async latency depends on. Polarity alternates +,− as in training, so a
/// fired even-index clause supports and a fired odd-index clause opposes.
pub fn synthetic_clause_bits(
    spec: &WorkloadSpec,
    winner: usize,
    rng: &mut SplitMix64,
) -> Vec<Vec<bool>> {
    (0..spec.n_classes)
        .map(|k| {
            let (p_pos, p_neg) = if k == winner {
                // Winning class: positive clauses likely, negatives rare.
                (spec.fire_rate, spec.fire_rate * 0.25)
            } else {
                // Losing classes: weaker support, more opposition.
                (spec.fire_rate * 0.55, spec.fire_rate * 0.45)
            };
            (0..spec.clauses_per_class)
                .map(|j| rng.next_bool(if j % 2 == 0 { p_pos } else { p_neg }))
                .collect()
        })
        .collect()
}

/// Signed class sum of one clause-bit vector (alternating polarity).
pub fn signed_sum(bits: &[bool]) -> i32 {
    bits.iter()
        .enumerate()
        .map(|(j, &b)| {
            if !b {
                0
            } else if j % 2 == 0 {
                1
            } else {
                -1
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_margins_favor_winner() {
        let spec = WorkloadSpec {
            n_classes: 6,
            clauses_per_class: 100,
            n_features: 784,
            fire_rate: 0.5,
        };
        let mut rng = SplitMix64::new(7);
        let mut wins = 0;
        let n = 300;
        for i in 0..n {
            let winner = i % spec.n_classes;
            let bits = synthetic_clause_bits(&spec, winner, &mut rng);
            let sums: Vec<i32> = bits.iter().map(|b| signed_sum(b)).collect();
            let best = (0..sums.len()).max_by_key(|&k| sums[k]).unwrap();
            if best == winner {
                wins += 1;
            }
        }
        assert!(wins as f64 / n as f64 > 0.9, "winner should usually argmax ({wins}/{n})");
    }

    #[test]
    fn signed_sum_alternates() {
        assert_eq!(signed_sum(&[true, true, true, true]), 0);
        assert_eq!(signed_sum(&[true, false, true, false]), 2);
        assert_eq!(signed_sum(&[false, true, false, true]), -2);
    }

    #[test]
    fn load_rejects_malformed() {
        let dir = std::env::temp_dir().join("tdpc_testset");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.json");
        std::fs::write(&p, r#"{"name":"x","n":2,"n_features":3,"x":["010"],"y":[0,1]}"#)
            .unwrap();
        assert!(TestSet::load(&p).is_err());
        let q = dir.join("good.json");
        std::fs::write(&q, r#"{"name":"x","n":2,"n_features":3,"x":["010","111"],"y":[0,1]}"#)
            .unwrap();
        let ts = TestSet::load(&q).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts.x[1], vec![true, true, true]);
    }
}
