//! Trained TM model: clause evaluation and class sums on the Rust side.
//!
//! This mirrors the semantics of the Pallas kernel / jnp oracle exactly
//! (see `python/compile/kernels/ref.py`): a clause fires iff every included
//! literal is 1 and the clause is non-empty; class sums are signed votes.
//! The hardware simulators consume the *clause bits* (they are the PDL
//! select inputs); `class_sums` is used for functional cross-checks.
//!
//! The request path is fully packed (§Data plane, rust/README.md):
//! [`TmModel::forward_packed`] consumes a [`PackedBatch`] of feature rows
//! and emits packed fired-clause words, with class sums computed as
//! `popcount(fired & pos) − popcount(fired & neg)` over precomputed
//! class-major polarity masks — the software analogue of the paper's
//! time-domain popcount voter.

use std::path::Path;

use anyhow::{ensure, Result};

use crate::util::json;

use super::bits::{copy_bits, tail_mask, words_for, BitVec64, PackedBatch, WORD_BITS};
use super::parse_bits;

/// Output of one batched TM forward pass (mirrors `model.tm_forward` on the
/// Python side; identical layout across every backend — re-exported as
/// `runtime::ForwardOutput`, the type every [`crate::runtime::InferenceBackend`]
/// returns).
///
/// Clause bits are stored *bit-packed*: `fired` holds one `c_total`-bit
/// row per sample (class-major clause order, LSB-first `u64` words — the
/// layout of [`crate::tm::bits`]). At MNIST clause counts this is 32×
/// smaller than the old `Vec<i32>` row (1000 clauses: 16 words vs 1000
/// i32s), and it is the form the polarity-mask popcount voter consumes
/// directly. Consumers that want bools (hardware sims, goldens) go
/// through [`ForwardOutput::clause_bits_row`] / [`ForwardOutput::fired_row`].
#[derive(Debug, Clone, PartialEq)]
pub struct ForwardOutput {
    pub batch: usize,
    pub n_classes: usize,
    pub c_total: usize,
    /// (batch × n_classes) row-major signed class sums.
    pub sums: Vec<i32>,
    /// Bit-packed clause outputs: one `c_total`-bit row per sample.
    pub fired: PackedBatch,
    /// (batch) argmax predictions.
    pub pred: Vec<i32>,
}

impl ForwardOutput {
    /// An output with zero rows (identity for [`ForwardOutput::append`]).
    pub fn empty(n_classes: usize, c_total: usize) -> ForwardOutput {
        ForwardOutput {
            batch: 0,
            n_classes,
            c_total,
            sums: Vec::new(),
            fired: PackedBatch::new(c_total),
            pred: Vec::new(),
        }
    }

    /// Concatenate another output's rows onto this one (used by backends
    /// that execute a logical batch as several fixed-size chunks).
    pub fn append(&mut self, other: ForwardOutput) -> Result<()> {
        ensure!(
            self.n_classes == other.n_classes && self.c_total == other.c_total,
            "cannot append outputs of different shapes ({}/{} vs {}/{})",
            self.n_classes,
            self.c_total,
            other.n_classes,
            other.c_total
        );
        self.batch += other.batch;
        self.sums.extend(other.sums);
        self.fired.append(&other.fired)?;
        self.pred.extend(other.pred);
        Ok(())
    }

    pub fn sums_row(&self, b: usize) -> &[i32] {
        &self.sums[b * self.n_classes..(b + 1) * self.n_classes]
    }

    /// Packed fired-clause words of sample `b` (the native popcount form).
    pub fn fired_words_row(&self, b: usize) -> &[u64] {
        self.fired.row(b)
    }

    /// Flat clause bits of sample `b`, class-major (unpacked — for
    /// goldens and tests, not the hot path).
    pub fn fired_row(&self, b: usize) -> Vec<bool> {
        self.fired.row_bools(b)
    }

    /// Clause bits of sample `b`, grouped per class (PDL select inputs).
    pub fn clause_bits_row(&self, b: usize) -> Vec<Vec<bool>> {
        let per = self.c_total / self.n_classes;
        (0..self.n_classes)
            .map(|k| (k * per..(k + 1) * per).map(|c| self.fired.bit(b, c)).collect())
            .collect()
    }
}

/// A trained multi-class TM in the interchange layout (clause axis
/// flattened class-major, literals `[x, ~x]`).
#[derive(Debug, Clone)]
pub struct TmModel {
    pub name: String,
    pub n_classes: usize,
    pub n_features: usize,
    pub clauses_per_class: usize,
    /// Include masks, one bitvec of length `2 * n_features` per clause.
    pub include: Vec<Vec<bool>>,
    /// +1 / −1 vote per clause (class-major).
    pub polarity: Vec<i8>,
    /// Clause has ≥1 include.
    pub nonempty: Vec<bool>,
    /// Training-time test accuracy (%).
    pub accuracy: f64,
    /// Bit-packed include masks (64 literals per word, same clause order) —
    /// the clause-evaluation hot path works word-wise (§Perf L3: ~50×
    /// over the bool-wise loop on MNIST-scale literal counts).
    packed_include: Vec<Vec<u64>>,
    /// Per-class polarity masks over the packed fired-clause words
    /// (§Perf L3: class sums by word-level popcount, no per-clause loop).
    class_masks: Vec<ClassMasks>,
}

/// Polarity masks for one class over the flat class-major fired bit
/// space. `pos`/`neg` cover only the word span the class's clauses
/// occupy (starting at word `start`), with every bit outside the class's
/// clause range already zeroed — so the class sum is exactly
/// `Σ_w popcount(fired[start+w] & pos[w]) − popcount(fired[start+w] & neg[w])`.
#[derive(Debug, Clone)]
struct ClassMasks {
    start: usize,
    pos: Vec<u64>,
    neg: Vec<u64>,
}

/// A synthetic workload description used by the scaling sweeps (Figs.
/// 10–12), where no trained model exists: clause bits are generated from a
/// target fire-rate instead.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadSpec {
    pub n_classes: usize,
    pub clauses_per_class: usize,
    /// Number of Boolean input features (for clause-block depth).
    pub n_features: usize,
    /// Probability a clause fires on a given sample.
    pub fire_rate: f64,
}

/// Pack a bit vector into u64 words (LSB-first within each word) — thin
/// wrapper over the one packing loop in [`crate::tm::bits`].
pub(crate) fn pack_bits(bits: &[bool]) -> Vec<u64> {
    BitVec64::from_bools(bits).into_words()
}

/// Build the per-class polarity masks. A clause contributes to the mask
/// only if it is non-empty (an empty clause's fired bit is always 0
/// anyway, but keeping the masks tight makes them self-describing).
fn build_class_masks(
    n_classes: usize,
    clauses_per_class: usize,
    polarity: &[i8],
    nonempty: &[bool],
) -> Vec<ClassMasks> {
    (0..n_classes)
        .map(|k| {
            let lo = k * clauses_per_class;
            let hi = lo + clauses_per_class;
            let start = lo / WORD_BITS;
            let span = if clauses_per_class == 0 { 0 } else { (hi - 1) / WORD_BITS + 1 - start };
            let mut pos = vec![0u64; span];
            let mut neg = vec![0u64; span];
            for c in lo..hi {
                if !nonempty[c] {
                    continue;
                }
                let w = c / WORD_BITS - start;
                let bit = 1u64 << (c % WORD_BITS);
                if polarity[c] > 0 {
                    pos[w] |= bit;
                } else {
                    neg[w] |= bit;
                }
            }
            ClassMasks { start, pos, neg }
        })
        .collect()
}

impl TmModel {
    /// Construct from parts (computes the packed representation).
    pub fn assemble(
        name: String,
        n_classes: usize,
        n_features: usize,
        clauses_per_class: usize,
        include: Vec<Vec<bool>>,
        polarity: Vec<i8>,
        nonempty: Vec<bool>,
        accuracy: f64,
    ) -> TmModel {
        let packed_include = include.iter().map(|row| pack_bits(row)).collect();
        let class_masks = build_class_masks(n_classes, clauses_per_class, &polarity, &nonempty);
        TmModel {
            name,
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            nonempty,
            accuracy,
            packed_include,
            class_masks,
        }
    }

    /// [`TmModel::assemble`] with `nonempty` derived from the include
    /// masks — the invariant trained artifacts satisfy; synthetic model
    /// builders should use this instead of deriving it by hand.
    pub fn assemble_derived(
        name: String,
        n_classes: usize,
        n_features: usize,
        clauses_per_class: usize,
        include: Vec<Vec<bool>>,
        polarity: Vec<i8>,
        accuracy: f64,
    ) -> TmModel {
        let nonempty = include.iter().map(|row| row.iter().any(|&b| b)).collect();
        TmModel::assemble(
            name,
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            nonempty,
            accuracy,
        )
    }

    /// Deterministic random model for synthetic workloads (benches and
    /// the artifact-free coordinator tests): include masks drawn at
    /// `density`, alternating clause polarity.
    pub fn synthetic(
        name: &str,
        n_classes: usize,
        clauses_per_class: usize,
        n_features: usize,
        density: f64,
        seed: u64,
    ) -> TmModel {
        let mut rng = crate::util::SplitMix64::new(seed);
        let c_total = n_classes * clauses_per_class;
        let include: Vec<Vec<bool>> = (0..c_total)
            .map(|_| (0..2 * n_features).map(|_| rng.next_bool(density)).collect())
            .collect();
        let polarity: Vec<i8> =
            (0..c_total).map(|c| if c % 2 == 0 { 1 } else { -1 }).collect();
        TmModel::assemble_derived(
            name.to_string(),
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            0.0,
        )
    }

    /// Serialize to the artifact-JSON interchange layout —
    /// [`TmModel::load`]'s exact inverse (include masks as `"0101…"`
    /// bitstrings, `nonempty` as 0/1). This is how tests and the
    /// multi-model smoke driver materialize (and *re*-materialize, for
    /// hot-swap) model artifacts on disk without the Python build path.
    pub fn to_json(&self) -> String {
        fn bitstring(bits: &[bool]) -> String {
            bits.iter().map(|&b| if b { '1' } else { '0' }).collect()
        }
        let include: Vec<String> =
            self.include.iter().map(|row| format!("\"{}\"", bitstring(row))).collect();
        let polarity: Vec<String> = self.polarity.iter().map(|p| p.to_string()).collect();
        let nonempty: Vec<String> =
            self.nonempty.iter().map(|&b| if b { "1" } else { "0" }.to_string()).collect();
        format!(
            "{{\n  \"name\": \"{}\",\n  \"n_classes\": {},\n  \"n_features\": {},\n  \
             \"clauses_per_class\": {},\n  \"accuracy\": {},\n  \"include\": [{}],\n  \
             \"polarity\": [{}],\n  \"nonempty\": [{}]\n}}\n",
            self.name,
            self.n_classes,
            self.n_features,
            self.clauses_per_class,
            self.accuracy,
            include.join(", "),
            polarity.join(", "),
            nonempty.join(", ")
        )
    }

    pub fn load(path: &Path) -> Result<TmModel> {
        let doc = json::parse_file(path)?;
        let n_classes = doc.get("n_classes")?.as_usize()?;
        let n_features = doc.get("n_features")?.as_usize()?;
        let clauses_per_class = doc.get("clauses_per_class")?.as_usize()?;
        let include = doc
            .get("include")?
            .as_arr()?
            .iter()
            .map(|row| parse_bits(row.as_str()?))
            .collect::<Result<Vec<_>>>()?;
        let polarity = doc
            .get("polarity")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_i64()? as i8))
            .collect::<Result<Vec<_>>>()?;
        let nonempty = doc
            .get("nonempty")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_i64()? != 0))
            .collect::<Result<Vec<_>>>()?;
        let c_total = n_classes * clauses_per_class;
        ensure!(
            include.len() == c_total,
            "corrupt model artifact {}: {} include rows != {c_total} clauses \
             ({n_classes} classes × {clauses_per_class} clauses/class)",
            path.display(),
            include.len()
        );
        ensure!(
            polarity.len() == c_total,
            "corrupt model artifact {}: {} polarity entries != {c_total} clauses",
            path.display(),
            polarity.len()
        );
        ensure!(
            nonempty.len() == c_total,
            "corrupt model artifact {}: {} nonempty flags != {c_total} clauses",
            path.display(),
            nonempty.len()
        );
        for (c, row) in include.iter().enumerate() {
            ensure!(
                row.len() == 2 * n_features,
                "corrupt model artifact {}: clause {c} has {} literals, expected {} \
                 (2 × {n_features} features)",
                path.display(),
                row.len(),
                2 * n_features
            );
        }
        let name = doc
            .get_opt("name")
            .and_then(|v| v.as_str().ok().map(String::from))
            .unwrap_or_else(|| "unnamed".into());
        let accuracy = doc.get_opt("accuracy").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
        Ok(TmModel::assemble(
            name,
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            nonempty,
            accuracy,
        ))
    }

    pub fn c_total(&self) -> usize {
        self.n_classes * self.clauses_per_class
    }

    /// Literal vector `[x, ~x]` for one Boolean input sample.
    pub fn literals(&self, x_bool: &[bool]) -> Vec<bool> {
        debug_assert_eq!(x_bool.len(), self.n_features);
        let mut lits = Vec::with_capacity(2 * self.n_features);
        lits.extend_from_slice(x_bool);
        lits.extend(x_bool.iter().map(|&b| !b));
        lits
    }

    /// Packed literal vector `[x, ~x]` from packed features: the `~x`
    /// half is built word-wise (negate + tail-mask + bit-shift into
    /// place), so no per-bit loop runs at any feature width.
    pub fn packed_literals(&self, x_words: &[u64]) -> BitVec64 {
        let mut out = vec![0u64; words_for(2 * self.n_features)];
        let mut negated = Vec::with_capacity(x_words.len());
        self.packed_literals_into(x_words, &mut negated, &mut out);
        BitVec64::from_words(2 * self.n_features, out)
    }

    /// Allocation-free core of [`TmModel::packed_literals`]: writes the
    /// literal words into `out` (length `words_for(2 * n_features)`,
    /// overwritten) using `negated` as reusable scratch — the batched
    /// forward pass hoists both buffers out of its row loop.
    fn packed_literals_into(&self, x_words: &[u64], negated: &mut Vec<u64>, out: &mut [u64]) {
        let f = self.n_features;
        debug_assert_eq!(x_words.len(), words_for(f));
        debug_assert_eq!(out.len(), words_for(2 * f));
        out.fill(0);
        copy_bits(out, 0, x_words, f);
        // ~x, masked to the feature width so no stray tail bits leak in.
        negated.clear();
        negated.extend(x_words.iter().map(|w| !w));
        if let Some(last) = negated.last_mut() {
            *last &= tail_mask(f);
        }
        copy_bits(out, f, negated, f);
    }

    /// Evaluate one clause on a pre-packed literal vector (pack once with
    /// [`TmModel::packed_literals`], reuse across every clause).
    #[inline]
    pub fn clause_fires(&self, clause: usize, lits: &BitVec64) -> bool {
        self.clause_fires_packed(clause, lits.words())
    }

    /// Word-wise clause evaluation: fires iff the clause is non-empty and
    /// every included literal is 1, i.e. `include & !literals == 0` in
    /// every word. This is the single `nonempty` checkpoint on the
    /// evaluation path.
    #[inline]
    pub fn clause_fires_packed(&self, clause: usize, lit_words: &[u64]) -> bool {
        if !self.nonempty[clause] {
            return false;
        }
        self.packed_include[clause]
            .iter()
            .zip(lit_words)
            .all(|(&inc, &lit)| inc & !lit == 0)
    }

    /// Fired-clause words for one pre-packed literal vector: one bit per
    /// clause, class-major, `words_for(c_total)` words. `out` is
    /// overwritten.
    fn fired_words_into(&self, lit_words: &[u64], out: &mut [u64]) {
        debug_assert_eq!(out.len(), words_for(self.c_total()));
        out.fill(0);
        for c in 0..self.c_total() {
            if self.clause_fires_packed(c, lit_words) {
                out[c / WORD_BITS] |= 1u64 << (c % WORD_BITS);
            }
        }
    }

    /// Class sums from packed fired-clause words via the polarity masks:
    /// `popcount(fired & pos) − popcount(fired & neg)` per class — the
    /// software analogue of the paper's time-domain popcount voter.
    pub fn class_sums_from_fired(&self, fired_words: &[u64]) -> Vec<i32> {
        debug_assert_eq!(fired_words.len(), words_for(self.c_total()));
        self.class_masks
            .iter()
            .map(|m| {
                let mut s = 0i32;
                for (w, (&p, &n)) in m.pos.iter().zip(&m.neg).enumerate() {
                    let f = fired_words[m.start + w];
                    s += (f & p).count_ones() as i32 - (f & n).count_ones() as i32;
                }
                s
            })
            .collect()
    }

    /// Per-clause signed summation over packed fired words — the pre-
    /// packed-data-path voter, kept (not on the request path) as the
    /// differential baseline for `benches/packed_popcount.rs` and the
    /// property suites.
    pub fn class_sums_per_clause(&self, fired_words: &[u64]) -> Vec<i32> {
        let mut sums = vec![0i32; self.n_classes];
        for c in 0..self.c_total() {
            if (fired_words[c / WORD_BITS] >> (c % WORD_BITS)) & 1 == 1 {
                sums[c / self.clauses_per_class] += self.polarity[c] as i32;
            }
        }
        sums
    }

    /// Batched packed forward pass — the request path. Consumes packed
    /// feature rows, emits packed fired words per sample, class sums via
    /// the polarity-mask popcount, and argmax predictions (ties → lowest
    /// index, matching `jnp.argmax`).
    pub fn forward_packed(&self, batch: &PackedBatch) -> Result<ForwardOutput> {
        ensure!(
            batch.is_empty() || batch.bits() == self.n_features,
            "batch feature width {} != model features {}",
            batch.bits(),
            self.n_features
        );
        let k = self.n_classes;
        let mut out = ForwardOutput::empty(k, self.c_total());
        out.batch = batch.rows();
        out.sums.reserve(batch.rows() * k);
        out.pred.reserve(batch.rows());
        // All scratch is hoisted out of the row loop: the per-sample body
        // allocates nothing (§Perf L3).
        let mut lits = vec![0u64; words_for(2 * self.n_features)];
        let mut negated = Vec::with_capacity(words_for(self.n_features));
        let mut fired = vec![0u64; words_for(self.c_total())];
        for r in 0..batch.rows() {
            self.packed_literals_into(batch.row(r), &mut negated, &mut lits);
            self.fired_words_into(&lits, &mut fired);
            let sums = self.class_sums_from_fired(&fired);
            let mut best = 0usize;
            for (ki, &s) in sums.iter().enumerate() {
                // Ties resolve to the lowest class index (jnp.argmax).
                if s > sums[best] {
                    best = ki;
                }
            }
            out.fired.push_words(&fired);
            out.sums.extend_from_slice(&sums);
            out.pred.push(best as i32);
        }
        Ok(out)
    }

    /// Clause outputs for one sample, grouped per class — the PDL select
    /// inputs of the hardware. Packs the literal vector once and evaluates
    /// all clauses word-wise (§Perf L3).
    pub fn clause_bits(&self, x_bool: &[bool]) -> Vec<Vec<bool>> {
        let lits = self.packed_literals(BitVec64::from_bools(x_bool).words());
        (0..self.n_classes)
            .map(|k| {
                let lo = k * self.clauses_per_class;
                (lo..lo + self.clauses_per_class)
                    .map(|c| self.clause_fires_packed(c, lits.words()))
                    .collect()
            })
            .collect()
    }

    /// Signed class sums for one sample (single-row convenience over the
    /// packed path).
    pub fn class_sums(&self, x_bool: &[bool]) -> Vec<i32> {
        let lits = self.packed_literals(BitVec64::from_bools(x_bool).words());
        let mut fired = vec![0u64; words_for(self.c_total())];
        self.fired_words_into(lits.words(), &mut fired);
        self.class_sums_from_fired(&fired)
    }

    /// Functional argmax prediction (ties resolve to the lowest index,
    /// matching `jnp.argmax`).
    pub fn predict(&self, x_bool: &[bool]) -> usize {
        let sums = self.class_sums(x_bool);
        let mut best = 0usize;
        for (k, &s) in sums.iter().enumerate() {
            if s > sums[best] {
                best = k;
            }
        }
        best
    }

    /// The maximum clause fan-in (number of includes) — determines the
    /// clause block's LUT-tree depth for the bundled-data delay.
    pub fn max_clause_fanin(&self) -> usize {
        self.include
            .iter()
            .map(|row| row.iter().filter(|&&b| b).count())
            .max()
            .unwrap_or(0)
    }

    /// Naive reference forward pass for one sample — bool-wise loops, no
    /// bit packing. The clause-evaluation *loop* is deliberately
    /// independent of the packed hot path so differential tests
    /// (`tests/native_backend.rs`) can pit the `NativeBackend` against it
    /// on randomized models; the stored `nonempty` mask is consulted like
    /// the production path does (it is authoritative, not re-derived).
    ///
    /// Returns `(fired, sums, pred)`: flat clause bits (class-major),
    /// signed class sums, and the argmax prediction (ties → lowest index).
    pub fn forward_reference(&self, x_bool: &[bool]) -> (Vec<bool>, Vec<i32>, usize) {
        assert_eq!(x_bool.len(), self.n_features, "feature width mismatch");
        let lits = self.literals(x_bool);
        let mut fired = Vec::with_capacity(self.c_total());
        for clause in 0..self.c_total() {
            let mut all = true;
            for (&lit, &inc) in lits.iter().zip(&self.include[clause]) {
                if inc && !lit {
                    all = false;
                }
            }
            fired.push(self.nonempty[clause] && all);
        }
        let mut sums = vec![0i32; self.n_classes];
        for (clause, &f) in fired.iter().enumerate() {
            if f {
                sums[clause / self.clauses_per_class] += self.polarity[clause] as i32;
            }
        }
        let mut pred = 0usize;
        for (k, &s) in sums.iter().enumerate() {
            if s > sums[pred] {
                pred = k;
            }
        }
        (fired, sums, pred)
    }

    /// Workload view of this model (for the shared hardware builders).
    pub fn workload(&self) -> WorkloadSpec {
        WorkloadSpec {
            n_classes: self.n_classes,
            clauses_per_class: self.clauses_per_class,
            n_features: self.n_features,
            fire_rate: 0.5,
        }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A tiny hand-built model: 2 classes × 2 clauses over 2 features.
    /// Class 0: clause0 (+) includes x0; clause1 (−) includes x1.
    /// Class 1: clause0 (+) includes ~x0; clause1 (−) empty.
    pub(crate) fn toy() -> TmModel {
        TmModel::assemble(
            "toy".into(),
            2,
            2,
            2,
            vec![
                vec![true, false, false, false],  // x0
                vec![false, true, false, false],  // x1
                vec![false, false, true, false],  // ~x0
                vec![false, false, false, false], // empty
            ],
            vec![1, -1, 1, -1],
            vec![true, true, true, false],
            100.0,
        )
    }

    #[test]
    fn literals_layout() {
        let m = toy();
        assert_eq!(m.literals(&[true, false]), vec![true, false, false, true]);
    }

    #[test]
    fn packed_literals_match_bool_literals() {
        // Word-boundary feature counts: the ~x half lands at offsets
        // 63/64/65 and must shift across words correctly.
        for f in [1usize, 2, 31, 32, 33, 63, 64, 65, 100] {
            let mut rng = crate::util::SplitMix64::new(f as u64);
            let m = TmModel::synthetic("lit", 2, 3, f, 0.2, 9);
            let x: Vec<bool> = (0..f).map(|_| rng.next_bool(0.5)).collect();
            let packed = m.packed_literals(BitVec64::from_bools(&x).words());
            assert_eq!(packed.to_bools(), m.literals(&x), "f={f}");
        }
    }

    #[test]
    fn clause_semantics() {
        let m = toy();
        let lits = m.packed_literals(BitVec64::from_bools(&[true, true]).words());
        assert!(m.clause_fires(0, &lits)); // x0=1
        assert!(m.clause_fires(1, &lits)); // x1=1
        assert!(!m.clause_fires(2, &lits)); // ~x0=0
        assert!(!m.clause_fires(3, &lits)); // empty never fires
    }

    #[test]
    fn class_sums_signed() {
        let m = toy();
        // x = [1, 0]: class0 = +1 (c0 fires) − 0 = 1; class1 = 0.
        assert_eq!(m.class_sums(&[true, false]), vec![1, 0]);
        // x = [1, 1]: class0 = +1 − 1 = 0; class1 = 0.
        assert_eq!(m.class_sums(&[true, true]), vec![0, 0]);
        // x = [0, 0]: class0 = 0; class1 = +1.
        assert_eq!(m.class_sums(&[false, false]), vec![0, 1]);
    }

    #[test]
    fn popcount_sums_agree_with_per_clause_sums() {
        // The popcount voter vs the per-clause loop, on shapes whose
        // class boundaries are word-unaligned.
        for (k, cpc) in [(2usize, 2usize), (3, 21), (5, 13), (2, 32), (1, 127)] {
            let m = TmModel::synthetic("sum", k, cpc, 24, 0.2, 3);
            let mut rng = crate::util::SplitMix64::new(17);
            for _ in 0..8 {
                let x: Vec<bool> = (0..24).map(|_| rng.next_bool(0.5)).collect();
                let lits = m.packed_literals(BitVec64::from_bools(&x).words());
                let mut fired = vec![0u64; words_for(m.c_total())];
                m.fired_words_into(lits.words(), &mut fired);
                assert_eq!(
                    m.class_sums_from_fired(&fired),
                    m.class_sums_per_clause(&fired),
                    "k={k} cpc={cpc}"
                );
            }
        }
    }

    #[test]
    fn predict_argmax_lowest_tie() {
        let m = toy();
        assert_eq!(m.predict(&[true, false]), 0);
        assert_eq!(m.predict(&[false, false]), 1);
        assert_eq!(m.predict(&[true, true]), 0, "tie → lowest index (jnp.argmax)");
    }

    #[test]
    fn clause_bits_grouping() {
        let m = toy();
        let bits = m.clause_bits(&[true, false]);
        assert_eq!(bits.len(), 2);
        assert_eq!(bits[0], vec![true, false]);
        assert_eq!(bits[1], vec![false, false]);
    }

    #[test]
    fn max_fanin() {
        assert_eq!(toy().max_clause_fanin(), 1);
    }

    #[test]
    fn reference_forward_agrees_with_packed_path() {
        let m = toy();
        for x in [[true, false], [true, true], [false, false], [false, true]] {
            let (fired, sums, pred) = m.forward_reference(&x);
            assert_eq!(sums, m.class_sums(&x), "{x:?}");
            assert_eq!(pred, m.predict(&x), "{x:?}");
            let packed: Vec<bool> = m.clause_bits(&x).concat();
            assert_eq!(fired, packed, "{x:?}");
        }
    }

    #[test]
    fn forward_packed_matches_reference() {
        let m = TmModel::synthetic("fwd", 3, 10, 19, 0.25, 5);
        let mut rng = crate::util::SplitMix64::new(8);
        let rows: Vec<Vec<bool>> =
            (0..7).map(|_| (0..19).map(|_| rng.next_bool(0.5)).collect()).collect();
        let out = m.forward_packed(&PackedBatch::from_rows(&rows).unwrap()).unwrap();
        assert_eq!(out.batch, 7);
        for (i, row) in rows.iter().enumerate() {
            let (fired, sums, pred) = m.forward_reference(row);
            assert_eq!(out.sums_row(i), &sums[..], "row {i}");
            assert_eq!(out.pred[i] as usize, pred, "row {i}");
            assert_eq!(out.fired_row(i), fired, "row {i}");
        }
    }

    #[test]
    fn to_json_roundtrips_through_load() {
        let dir = std::env::temp_dir();
        for (tag, m) in [
            ("toy", toy()),
            ("synth", TmModel::synthetic("round_trip", 3, 7, 19, 0.25, 42)),
        ] {
            let path = dir.join(format!("tdpc-roundtrip-{}-{tag}.json", std::process::id()));
            std::fs::write(&path, m.to_json()).unwrap();
            let loaded = TmModel::load(&path).unwrap();
            std::fs::remove_file(&path).ok();
            assert_eq!(loaded.name, m.name, "{tag}");
            assert_eq!(loaded.n_classes, m.n_classes, "{tag}");
            assert_eq!(loaded.n_features, m.n_features, "{tag}");
            assert_eq!(loaded.clauses_per_class, m.clauses_per_class, "{tag}");
            assert_eq!(loaded.include, m.include, "{tag}");
            assert_eq!(loaded.polarity, m.polarity, "{tag}");
            assert_eq!(loaded.nonempty, m.nonempty, "{tag}");
            assert_eq!(loaded.accuracy, m.accuracy, "{tag}");
            // Behavior identical, not just fields.
            let mut rng = crate::util::SplitMix64::new(7);
            for _ in 0..16 {
                let x: Vec<bool> =
                    (0..m.n_features).map(|_| rng.next_bool(0.5)).collect();
                assert_eq!(loaded.class_sums(&x), m.class_sums(&x), "{tag}");
            }
        }
    }

    #[test]
    fn forward_packed_rejects_wrong_width() {
        let m = toy();
        let batch = PackedBatch::from_rows(&[vec![true; 3]]).unwrap();
        assert!(m.forward_packed(&batch).is_err());
        // Empty batches pass regardless of their (zero) width.
        assert_eq!(m.forward_packed(&PackedBatch::new(0)).unwrap().batch, 0);
    }
}
