//! Trained TM model: clause evaluation and class sums on the Rust side.
//!
//! This mirrors the semantics of the Pallas kernel / jnp oracle exactly
//! (see `python/compile/kernels/ref.py`): a clause fires iff every included
//! literal is 1 and the clause is non-empty; class sums are signed votes.
//! The hardware simulators consume the *clause bits* (they are the PDL
//! select inputs); `class_sums` is used for functional cross-checks.

use std::path::Path;

use anyhow::{ensure, Result};

use crate::util::json;

use super::parse_bits;

/// A trained multi-class TM in the interchange layout (clause axis
/// flattened class-major, literals `[x, ~x]`).
#[derive(Debug, Clone)]
pub struct TmModel {
    pub name: String,
    pub n_classes: usize,
    pub n_features: usize,
    pub clauses_per_class: usize,
    /// Include masks, one bitvec of length `2 * n_features` per clause.
    pub include: Vec<Vec<bool>>,
    /// +1 / −1 vote per clause (class-major).
    pub polarity: Vec<i8>,
    /// Clause has ≥1 include.
    pub nonempty: Vec<bool>,
    /// Training-time test accuracy (%).
    pub accuracy: f64,
    /// Bit-packed include masks (64 literals per word, same clause order) —
    /// the clause-evaluation hot path works word-wise (§Perf L3: ~50×
    /// over the bool-wise loop on MNIST-scale literal counts).
    packed_include: Vec<Vec<u64>>,
}

/// A synthetic workload description used by the scaling sweeps (Figs.
/// 10–12), where no trained model exists: clause bits are generated from a
/// target fire-rate instead.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadSpec {
    pub n_classes: usize,
    pub clauses_per_class: usize,
    /// Number of Boolean input features (for clause-block depth).
    pub n_features: usize,
    /// Probability a clause fires on a given sample.
    pub fire_rate: f64,
}

/// Pack a bit vector into u64 words (LSB-first within each word).
pub(crate) fn pack_bits(bits: &[bool]) -> Vec<u64> {
    let mut words = vec![0u64; bits.len().div_ceil(64)];
    for (i, &b) in bits.iter().enumerate() {
        if b {
            words[i / 64] |= 1u64 << (i % 64);
        }
    }
    words
}

impl TmModel {
    /// Construct from parts (computes the packed representation).
    pub fn assemble(
        name: String,
        n_classes: usize,
        n_features: usize,
        clauses_per_class: usize,
        include: Vec<Vec<bool>>,
        polarity: Vec<i8>,
        nonempty: Vec<bool>,
        accuracy: f64,
    ) -> TmModel {
        let packed_include = include.iter().map(|row| pack_bits(row)).collect();
        TmModel {
            name,
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            nonempty,
            accuracy,
            packed_include,
        }
    }

    /// [`TmModel::assemble`] with `nonempty` derived from the include
    /// masks — the invariant trained artifacts satisfy; synthetic model
    /// builders should use this instead of deriving it by hand.
    pub fn assemble_derived(
        name: String,
        n_classes: usize,
        n_features: usize,
        clauses_per_class: usize,
        include: Vec<Vec<bool>>,
        polarity: Vec<i8>,
        accuracy: f64,
    ) -> TmModel {
        let nonempty = include.iter().map(|row| row.iter().any(|&b| b)).collect();
        TmModel::assemble(
            name,
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            nonempty,
            accuracy,
        )
    }

    /// Deterministic random model for synthetic workloads (benches and
    /// the artifact-free coordinator tests): include masks drawn at
    /// `density`, alternating clause polarity.
    pub fn synthetic(
        name: &str,
        n_classes: usize,
        clauses_per_class: usize,
        n_features: usize,
        density: f64,
        seed: u64,
    ) -> TmModel {
        let mut rng = crate::util::SplitMix64::new(seed);
        let c_total = n_classes * clauses_per_class;
        let include: Vec<Vec<bool>> = (0..c_total)
            .map(|_| (0..2 * n_features).map(|_| rng.next_bool(density)).collect())
            .collect();
        let polarity: Vec<i8> =
            (0..c_total).map(|c| if c % 2 == 0 { 1 } else { -1 }).collect();
        TmModel::assemble_derived(
            name.to_string(),
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            0.0,
        )
    }

    pub fn load(path: &Path) -> Result<TmModel> {
        let doc = json::parse_file(path)?;
        let n_classes = doc.get("n_classes")?.as_usize()?;
        let n_features = doc.get("n_features")?.as_usize()?;
        let clauses_per_class = doc.get("clauses_per_class")?.as_usize()?;
        let include = doc
            .get("include")?
            .as_arr()?
            .iter()
            .map(|row| parse_bits(row.as_str()?))
            .collect::<Result<Vec<_>>>()?;
        let polarity = doc
            .get("polarity")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_i64()? as i8))
            .collect::<Result<Vec<_>>>()?;
        let nonempty = doc
            .get("nonempty")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_i64()? != 0))
            .collect::<Result<Vec<_>>>()?;
        let c_total = n_classes * clauses_per_class;
        ensure!(include.len() == c_total, "include rows {} != {c_total}", include.len());
        ensure!(polarity.len() == c_total);
        ensure!(nonempty.len() == c_total);
        for row in &include {
            ensure!(row.len() == 2 * n_features, "literal width mismatch");
        }
        let name = doc
            .get_opt("name")
            .and_then(|v| v.as_str().ok().map(String::from))
            .unwrap_or_else(|| "unnamed".into());
        let accuracy = doc.get_opt("accuracy").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
        Ok(TmModel::assemble(
            name,
            n_classes,
            n_features,
            clauses_per_class,
            include,
            polarity,
            nonempty,
            accuracy,
        ))
    }

    pub fn c_total(&self) -> usize {
        self.n_classes * self.clauses_per_class
    }

    /// Literal vector `[x, ~x]` for one Boolean input sample.
    pub fn literals(&self, x_bool: &[bool]) -> Vec<bool> {
        debug_assert_eq!(x_bool.len(), self.n_features);
        let mut lits = Vec::with_capacity(2 * self.n_features);
        lits.extend_from_slice(x_bool);
        lits.extend(x_bool.iter().map(|&b| !b));
        lits
    }

    /// Evaluate one clause on a literal vector.
    #[inline]
    pub fn clause_fires(&self, clause: usize, lits: &[bool]) -> bool {
        if !self.nonempty[clause] {
            return false;
        }
        self.clause_fires_packed(clause, &pack_bits(lits))
    }

    /// Word-wise clause evaluation: fires iff every included literal is 1,
    /// i.e. `include & !literals == 0` in every word.
    #[inline]
    fn clause_fires_packed(&self, clause: usize, lit_words: &[u64]) -> bool {
        if !self.nonempty[clause] {
            return false;
        }
        self.packed_include[clause]
            .iter()
            .zip(lit_words)
            .all(|(&inc, &lit)| inc & !lit == 0)
    }

    /// Clause outputs for one sample, grouped per class — the PDL select
    /// inputs of the hardware. Packs the literal vector once and evaluates
    /// all clauses word-wise (§Perf L3).
    pub fn clause_bits(&self, x_bool: &[bool]) -> Vec<Vec<bool>> {
        let lit_words = pack_bits(&self.literals(x_bool));
        (0..self.n_classes)
            .map(|k| {
                let lo = k * self.clauses_per_class;
                (lo..lo + self.clauses_per_class)
                    .map(|c| self.clause_fires_packed(c, &lit_words))
                    .collect()
            })
            .collect()
    }

    /// Signed class sums for one sample.
    pub fn class_sums(&self, x_bool: &[bool]) -> Vec<i32> {
        let bits = self.clause_bits(x_bool);
        (0..self.n_classes)
            .map(|k| {
                bits[k]
                    .iter()
                    .enumerate()
                    .map(|(j, &fired)| {
                        if fired {
                            self.polarity[k * self.clauses_per_class + j] as i32
                        } else {
                            0
                        }
                    })
                    .sum()
            })
            .collect()
    }

    /// Functional argmax prediction (ties resolve to the lowest index,
    /// matching `jnp.argmax`).
    pub fn predict(&self, x_bool: &[bool]) -> usize {
        let sums = self.class_sums(x_bool);
        let mut best = 0usize;
        for (k, &s) in sums.iter().enumerate() {
            if s > sums[best] {
                best = k;
            }
        }
        best
    }

    /// The maximum clause fan-in (number of includes) — determines the
    /// clause block's LUT-tree depth for the bundled-data delay.
    pub fn max_clause_fanin(&self) -> usize {
        self.include
            .iter()
            .map(|row| row.iter().filter(|&&b| b).count())
            .max()
            .unwrap_or(0)
    }

    /// Naive reference forward pass for one sample — bool-wise loops, no
    /// bit packing. The clause-evaluation *loop* is deliberately
    /// independent of the packed hot path so differential tests
    /// (`tests/native_backend.rs`) can pit the `NativeBackend` against it
    /// on randomized models; the stored `nonempty` mask is consulted like
    /// the production path does (it is authoritative, not re-derived).
    ///
    /// Returns `(fired, sums, pred)`: flat clause bits (class-major),
    /// signed class sums, and the argmax prediction (ties → lowest index).
    pub fn forward_reference(&self, x_bool: &[bool]) -> (Vec<bool>, Vec<i32>, usize) {
        assert_eq!(x_bool.len(), self.n_features, "feature width mismatch");
        let lits = self.literals(x_bool);
        let mut fired = Vec::with_capacity(self.c_total());
        for clause in 0..self.c_total() {
            let mut all = true;
            for (&lit, &inc) in lits.iter().zip(&self.include[clause]) {
                if inc && !lit {
                    all = false;
                }
            }
            fired.push(self.nonempty[clause] && all);
        }
        let mut sums = vec![0i32; self.n_classes];
        for (clause, &f) in fired.iter().enumerate() {
            if f {
                sums[clause / self.clauses_per_class] += self.polarity[clause] as i32;
            }
        }
        let mut pred = 0usize;
        for (k, &s) in sums.iter().enumerate() {
            if s > sums[pred] {
                pred = k;
            }
        }
        (fired, sums, pred)
    }

    /// Workload view of this model (for the shared hardware builders).
    pub fn workload(&self) -> WorkloadSpec {
        WorkloadSpec {
            n_classes: self.n_classes,
            clauses_per_class: self.clauses_per_class,
            n_features: self.n_features,
            fire_rate: 0.5,
        }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// A tiny hand-built model: 2 classes × 2 clauses over 2 features.
    /// Class 0: clause0 (+) includes x0; clause1 (−) includes x1.
    /// Class 1: clause0 (+) includes ~x0; clause1 (−) empty.
    pub(crate) fn toy() -> TmModel {
        TmModel::assemble(
            "toy".into(),
            2,
            2,
            2,
            vec![
                vec![true, false, false, false],  // x0
                vec![false, true, false, false],  // x1
                vec![false, false, true, false],  // ~x0
                vec![false, false, false, false], // empty
            ],
            vec![1, -1, 1, -1],
            vec![true, true, true, false],
            100.0,
        )
    }

    #[test]
    fn literals_layout() {
        let m = toy();
        assert_eq!(m.literals(&[true, false]), vec![true, false, false, true]);
    }

    #[test]
    fn clause_semantics() {
        let m = toy();
        let lits = m.literals(&[true, true]);
        assert!(m.clause_fires(0, &lits)); // x0=1
        assert!(m.clause_fires(1, &lits)); // x1=1
        assert!(!m.clause_fires(2, &lits)); // ~x0=0
        assert!(!m.clause_fires(3, &lits)); // empty never fires
    }

    #[test]
    fn class_sums_signed() {
        let m = toy();
        // x = [1, 0]: class0 = +1 (c0 fires) − 0 = 1; class1 = 0.
        assert_eq!(m.class_sums(&[true, false]), vec![1, 0]);
        // x = [1, 1]: class0 = +1 − 1 = 0; class1 = 0.
        assert_eq!(m.class_sums(&[true, true]), vec![0, 0]);
        // x = [0, 0]: class0 = 0; class1 = +1.
        assert_eq!(m.class_sums(&[false, false]), vec![0, 1]);
    }

    #[test]
    fn predict_argmax_lowest_tie() {
        let m = toy();
        assert_eq!(m.predict(&[true, false]), 0);
        assert_eq!(m.predict(&[false, false]), 1);
        assert_eq!(m.predict(&[true, true]), 0, "tie → lowest index (jnp.argmax)");
    }

    #[test]
    fn clause_bits_grouping() {
        let m = toy();
        let bits = m.clause_bits(&[true, false]);
        assert_eq!(bits.len(), 2);
        assert_eq!(bits[0], vec![true, false]);
        assert_eq!(bits[1], vec![false, false]);
    }

    #[test]
    fn max_fanin() {
        assert_eq!(toy().max_clause_fanin(), 1);
    }

    #[test]
    fn reference_forward_agrees_with_packed_path() {
        let m = toy();
        for x in [[true, false], [true, true], [false, false], [false, true]] {
            let (fired, sums, pred) = m.forward_reference(&x);
            assert_eq!(sums, m.class_sums(&x), "{x:?}");
            assert_eq!(pred, m.predict(&x), "{x:?}");
            let packed: Vec<bool> = m.clause_bits(&x).concat();
            assert_eq!(fired, packed, "{x:?}");
        }
    }
}
